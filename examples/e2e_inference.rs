//! End-to-end driver: the full system on a real small workload.
//!
//! HyperNet-20 (a ResNet-20-style BWN, 20 binary conv layers + FC head,
//! ~270 k binary weights) runs through every layer of the stack:
//!
//!   1. JAX/Pallas (build time) lowered each layer to an HLO artifact and
//!      produced golden logits (`make artifacts`);
//!   2. the Rust coordinator plans FMM memory (§IV-B ping-pong, peak ==
//!      WCL), packs the binary weights into the Tbl-I stream format and
//!      walks the step list;
//!   3. PJRT executes each layer's compiled kernel; a batch of requests
//!      is served FIFO with latency statistics;
//!   4. the result is cross-checked against the JAX golden logits, and
//!      the silicon model reports what the taped-out chip would do on
//!      the same network (cycles, energy, I/O).
//!
//!     make artifacts && cargo run --release --example e2e_inference

use hyperdrive::coordinator::schedule::{schedule_network, DepthwisePolicy};
use hyperdrive::coordinator::tiling::MeshPlan;
use hyperdrive::coordinator::wcl;
use hyperdrive::energy::model::energy_per_image;
use hyperdrive::runtime::InferenceEngine;
use hyperdrive::util::{fmt_bits, SplitMix64};
use hyperdrive::ChipConfig;

fn main() -> anyhow::Result<()> {
    let engine = InferenceEngine::load("artifacts")?;
    let net = &engine.manifest.network;
    println!(
        "loaded {} ({} steps, {} binary weights) on PJRT `{}`",
        net.name,
        net.steps.len(),
        fmt_bits(net.weight_bits()),
        engine.runtime.platform()
    );
    println!(
        "memory plan: peak {} words == WCL {} words (§IV-B realized)",
        engine.memory_plan.peak_words,
        wcl::analyze(net).wcl_words
    );

    // --- correctness: golden check ------------------------------------
    let input = engine.manifest.golden("e2e_input.bin")?;
    let golden = engine.manifest.golden("e2e_golden.bin")?;
    let logits = engine.infer(&input)?;
    let max_err = logits
        .iter()
        .zip(&golden)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |logits − JAX golden| = {max_err:.3e}");
    assert!(max_err < 1e-3, "golden mismatch");
    let argmax = |v: &[f32]| {
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    assert_eq!(argmax(&logits), argmax(&golden));
    println!("predicted class {} (matches golden)", argmax(&logits));

    // --- serving: batched requests with latency stats ------------------
    let mut rng = SplitMix64::new(7);
    let batch: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..input.len()).map(|_| rng.next_gauss()).collect())
        .collect();
    let (_, stats) = engine.serve(&batch)?;
    println!(
        "served {} requests: mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms, {:.1} req/s, {:.2} GOp/s",
        stats.requests,
        stats.mean_ms,
        stats.p50_ms,
        stats.p99_ms,
        stats.requests as f64 / stats.total_s,
        stats.ops_per_s / 1e9
    );

    // --- what the silicon would do on this network ---------------------
    let cfg = ChipConfig::default();
    let sched = schedule_network(net, &cfg, DepthwisePolicy::default());
    let plan = MeshPlan {
        rows: 1,
        cols: 1,
        per_chip_wcl_words: 0,
    };
    let rep = energy_per_image(net, &cfg, &plan, 0.5, 1.5, DepthwisePolicy::default());
    println!(
        "simulated silicon @0.5V+1.5FBB: {} cycles/frame, {:.0} fps, \
         {:.3} mJ/frame ({:.3} core + {:.3} I/O), {:.2} TOp/s/W system",
        sched.total_cycles(),
        rep.frame_rate_hz,
        rep.total_j() * 1e3,
        rep.core_j * 1e3,
        rep.io_j * 1e3,
        rep.system_efficiency_ops_w() / 1e12
    );
    println!("e2e_inference OK");
    Ok(())
}

//! End-to-end driver: the full system on a real small workload, through
//! the unified `Engine` façade on its PJRT backend.
//!
//! HyperNet-20 (a ResNet-20-style BWN, 20 binary conv layers + FC head,
//! ~270 k binary weights) runs through every layer of the stack:
//!
//!   1. JAX/Pallas (build time) lowered each layer to an HLO artifact and
//!      produced golden logits (`make artifacts`);
//!   2. `Engine::builder().model("manifest:artifacts#hypernet20")` on
//!      the PJRT backend loads the manifest, plans FMM memory (§IV-B
//!      ping-pong, peak == WCL) and packs the binary weights into the
//!      Tbl-I stream format;
//!   3. PJRT executes each layer's compiled kernel; a batch of requests
//!      is served through the bounded-queue worker pool;
//!   4. the result is cross-checked against the JAX golden logits, and
//!      the typed report shows what the taped-out chip would do on the
//!      same network (cycles, energy, I/O).
//!
//!     make artifacts && cargo run --release --features pjrt --example e2e_inference

use hyperdrive::engine::{BackendKind, Engine, ServeOptions};
use hyperdrive::util::{fmt_bits, SplitMix64};

fn main() -> anyhow::Result<()> {
    // One model spec names both the network and the artifact directory;
    // forcing the PJRT backend makes the engine execute the compiled
    // artifacts (the same spec on the default backend would run the
    // manifest's trained weights on the functional simulator).
    let engine = Engine::builder()
        .model("manifest:artifacts#hypernet20")
        .backend(BackendKind::Pjrt)
        .build()?;
    let net = engine.network();
    println!(
        "loaded {} ({} steps, {} binary weights) on {}",
        net.name,
        net.steps.len(),
        fmt_bits(net.weight_bits()),
        engine.describe()
    );
    let report = engine.report();
    if let Some(plan) = engine.memory_plan() {
        println!(
            "memory plan: peak {} words == WCL {} words (§IV-B realized)",
            plan.peak_words, report.memory.wcl_words
        );
    }

    // --- correctness: golden check ------------------------------------
    let input = engine.golden("e2e_input.bin")?;
    let golden = engine.golden("e2e_golden.bin")?;
    let logits = engine.infer(&input)?;
    let max_err = logits
        .iter()
        .zip(&golden)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |logits − JAX golden| = {max_err:.3e}");
    assert!(max_err < 1e-3, "golden mismatch");
    let argmax = |v: &[f32]| {
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    assert_eq!(argmax(&logits), argmax(&golden));
    println!("predicted class {} (matches golden)", argmax(&logits));

    // --- serving: concurrent batch with latency stats ------------------
    let mut rng = SplitMix64::new(7);
    let batch: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..input.len()).map(|_| rng.next_gauss()).collect())
        .collect();
    let opts = ServeOptions { workers: 2, ..ServeOptions::default() };
    let (concurrent, stats) = engine.serve(&batch, &opts)?.outputs()?;
    println!("{}", engine.report_with_serve(stats.clone()).serve_summary());

    // Concurrency must not change results: sequential == concurrent.
    let seq_opts = ServeOptions { workers: 1, ..ServeOptions::default() };
    let (sequential, _) = engine.serve(&batch, &seq_opts)?.outputs()?;
    assert_eq!(concurrent, sequential, "worker pool changed the logits");
    println!("concurrent ({} workers) == sequential logits ✓", stats.workers);

    // --- what the silicon would do on this network ---------------------
    println!(
        "simulated silicon @{}V+{}FBB: {} cycles/frame, {:.0} fps, \
         {:.3} mJ/frame ({:.3} core + {:.3} I/O), {:.2} TOp/s/W system",
        report.vdd,
        report.vbb,
        report.schedule.total_cycles(),
        report.energy.frame_rate_hz,
        report.energy.total_j() * 1e3,
        report.energy.core_j * 1e3,
        report.energy.io_j * 1e3,
        report.energy.system_efficiency_ops_w() / 1e12
    );
    println!("e2e_inference OK");
    Ok(())
}

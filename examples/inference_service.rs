//! Multi-model serving: one long-lived `InferenceService` hosting
//! several registry models concurrently — the system-level shape of
//! Hyperdrive's pitch (weight streaming supports *arbitrary* networks,
//! so the serving API hosts arbitrary networks side by side).
//!
//!     cargo run --release --example inference_service
//!
//! Shows: named-model routing, per-request results (a model whose
//! every inference fails costs only its own requests), hot
//! add/remove, admission policies, live metrics, graceful shutdown.

use hyperdrive::engine::{AdmissionPolicy, InferRequest, InferenceService, ModelConfig};
use hyperdrive::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    // Two healthy models plus one that is guaranteed to fail at
    // inference time: HyperNet-20 on a 3×3 mesh builds (the analytic
    // plan is fine) but its 32×32 FMs do not divide over 3×3 chips, so
    // every request to it errors — per request, never per batch.
    let service = InferenceService::builder()
        .model_spec("hypernet20")
        .model("tiny-resnet", ModelConfig::new("resnet18@32x32"))
        .model("flaky", ModelConfig::new("hypernet20").mesh(3, 3))
        .workers(4)
        .queue_depth(8)
        .admission(AdmissionPolicy::Block)
        .build()?;
    println!("serving {:?} on {} workers", service.models(), service.worker_count());

    // A mixed workload round-robined over all three models.
    let mut rng = SplitMix64::new(42);
    let models = ["hypernet20", "tiny-resnet", "flaky"];
    let mut tickets = Vec::new();
    for i in 0..24u64 {
        let model = models[i as usize % models.len()];
        let input: Vec<f32> = (0..service.input_len(model).unwrap())
            .map(|_| rng.next_sym())
            .collect();
        tickets.push(service.submit(InferRequest {
            model: model.into(),
            input: input.into(),
            id: i,
            deadline_ms: None,
        })?);
    }
    let (mut ok, mut failed) = (0, 0);
    for ticket in tickets {
        match ticket.wait() {
            Ok(resp) => {
                ok += 1;
                if resp.id < 3 {
                    println!(
                        "  request {:>2} on {:<12} → {} values in {:.2} ms",
                        resp.id,
                        resp.model,
                        resp.output.len(),
                        resp.latency_ms
                    );
                }
            }
            Err(e) => {
                failed += 1;
                if failed == 1 {
                    println!("  (expected per-request failure: {e})");
                }
            }
        }
    }
    println!("{ok} ok, {failed} failed — the failures cost only their own slots");

    // Hot management: drop the flaky model, add a bigger one.
    service.remove_model("flaky")?;
    service.add_model("resnet34", ModelConfig::new("resnet34@64x64"))?;
    let input: Vec<f32> = (0..service.input_len("resnet34").unwrap())
        .map(|_| rng.next_sym())
        .collect();
    let out = service.infer("resnet34", input)?;
    println!("hot-added resnet34@64x64 → {} output values", out.len());
    println!("now serving {:?}", service.models());

    // Graceful shutdown drains the queues and returns final metrics.
    print!("{}", service.shutdown().render_table());
    println!("inference_service OK");
    Ok(())
}

//! Multi-chip systolic mesh demo (§V) through the unified `Engine`
//! façade: run HyperNet-20 on 2×2, 2×4 and 4×4 meshes of simulated
//! chips — real distributed tiles, real border/corner memories, real
//! send-once exchange protocol — and verify each is bit-exact against
//! the functional single-chip backend built from the *same* parameters.
//!
//!     cargo run --release --example multichip_mesh
//!
//! Uses the real (trained) manifest parameters when `artifacts/` exists
//! (`make artifacts`), seeded synthetic BWN parameters otherwise.

use std::sync::Arc;

use hyperdrive::coordinator::border;
use hyperdrive::coordinator::wcl;
use hyperdrive::engine::{Engine, Precision};
use hyperdrive::model;
use hyperdrive::util::{fmt_bits, SplitMix64};
use hyperdrive::ChipConfig;

fn main() -> anyhow::Result<()> {
    // Network + weights through one model spec: the manifest (trained
    // parameters; params are positional per step, so the net must come
    // from the same source) when artifacts exist, the registry twin
    // with its seeded weight source otherwise.
    let resolved = model::resolve("manifest:artifacts#hypernet20")
        .or_else(|_| model::resolve("hypernet20"))?;
    let net = resolved.network.clone();
    let params = Arc::new(resolved.weights.params(&net, 16)?);
    let input_vec: Vec<f32> = match &resolved.manifest {
        Some(nm) => nm.golden("e2e_input.bin")?,
        None => {
            let mut rng = SplitMix64::new(0xbeef);
            (0..net.in_ch * net.in_h * net.in_w)
                .map(|_| rng.next_sym())
                .collect()
        }
    };
    println!("{} with {}", net.name, resolved.weights.describe());

    // Single-chip FP16 reference through the same façade.
    let reference = Engine::builder()
        .network(net.clone())
        .params(params.clone())
        .precision(Precision::F16)
        .build()?;
    let want = reference.infer(&input_vec)?;

    for (rows, cols) in [(2usize, 2usize), (2, 4), (4, 4)] {
        let mesh = Engine::builder()
            .network(net.clone())
            .params(params.clone())
            .mesh(rows, cols)
            .precision(Precision::F16)
            .build()?;
        let got = mesh.infer(&input_vec)?;
        let exact = got == want;
        let stats = mesh.mesh_stats().expect("mesh backend records stats");
        println!(
            "{rows}x{cols} mesh: bit-exact = {} | border {} + corner {} exchanged, \
             {} link flits, {} exchange pairs completed",
            exact,
            fmt_bits(stats.border_bits),
            fmt_bits(stats.corner_bits),
            stats.flits,
            stats.flags.completed
        );
        assert!(exact, "mesh output diverged from single chip");
    }

    // Exchange-vs-compute slack (§V-D): the serial border links must
    // hide under the next layer's compute on the paper's big mesh.
    let cfg = ChipConfig::default();
    let net2k = model::network("resnet34@1024x2048")?;
    let slacks = border::exchange_slack(&net2k, &cfg, 5, 10);
    let worst = slacks
        .iter()
        .map(|s| s.exchange_cycles as f64 / s.next_compute_cycles as f64)
        .fold(0.0, f64::max);
    println!(
        "ResNet-34 @2k×1k on 10×5: all {} exchanges hidden under compute \
         (worst link occupies {:.0}% of the consumer layer's cycles)",
        slacks.len(),
        100.0 * worst
    );

    // Border/corner memory the silicon provisions for this (§V-C).
    let a = wcl::analyze(&net);
    println!(
        "BM {} / CM {} per chip for {} (ResNet-34 sizing: {} / {})",
        fmt_bits(border::border_memory_bits(&net, &a, 2, 2, cfg.fm_bits)),
        fmt_bits(border::corner_memory_bits(&net, cfg.fm_bits)),
        net.name,
        fmt_bits(459_000),
        fmt_bits(64_000),
    );
    println!("multichip_mesh OK");
    Ok(())
}

//! Multi-chip systolic mesh demo (§V): run HyperNet-20 *functionally* on
//! a 2×2 and 4×4 mesh of simulated chips — real distributed tiles, real
//! border/corner memories, real send-once exchange protocol — and verify
//! the result is bit-exact against the single-chip FP16 reference.
//!
//!     make artifacts && cargo run --release --example multichip_mesh

use hyperdrive::bwn::pack_weights;
use hyperdrive::coordinator::border;
use hyperdrive::coordinator::wcl;
use hyperdrive::network::TensorRef;
use hyperdrive::runtime::registry::NetworkManifest;
use hyperdrive::simulator::mesh::{MeshSim, StepParams};
use hyperdrive::simulator::{self, FeatureMap, Precision};
use hyperdrive::util::fmt_bits;
use hyperdrive::ChipConfig;

fn main() -> anyhow::Result<()> {
    // Real network + real (manifest) parameters, not random ones.
    let nm = NetworkManifest::load("artifacts")?;
    let net = &nm.network;
    let input_vec = nm.golden("e2e_input.bin")?;
    let input = FeatureMap::from_vec(net.in_ch, net.in_h, net.in_w, input_vec);

    let params: Vec<StepParams> = net
        .steps
        .iter()
        .map(|s| {
            let l = &s.layer;
            StepParams {
                stream: pack_weights(l, nm.blob(&l.name, "w").unwrap(), 16),
                gamma: nm.blob(&l.name, "gamma").unwrap().to_vec(),
                beta: nm.blob(&l.name, "beta").unwrap().to_vec(),
            }
        })
        .collect();

    // Single-chip FP16 reference.
    let mut ref_fms: Vec<FeatureMap> = Vec::new();
    for (i, s) in net.steps.iter().enumerate() {
        let src = match s.src {
            TensorRef::Input => &input,
            TensorRef::Step(j) => &ref_fms[j],
        };
        let byp = s.bypass.map(|b| match b {
            TensorRef::Input => input.clone(),
            TensorRef::Step(j) => ref_fms[j].clone(),
        });
        let lp = simulator::chip::LayerParams {
            layer: &s.layer,
            stream: &params[i].stream,
            gamma: &params[i].gamma,
            beta: &params[i].beta,
        };
        let (o, _) = simulator::run_layer(&lp, src, byp.as_ref(), Precision::F16, (7, 7));
        ref_fms.push(o);
    }
    let reference = ref_fms.last().unwrap();

    for (rows, cols) in [(2usize, 2usize), (2, 4), (4, 4)] {
        let sim = MeshSim::new(rows, cols, Precision::F16);
        let (out, stats) = sim.run_network(net, &params, &input);
        let diff = out.max_abs_diff(reference);
        println!(
            "{rows}x{cols} mesh: bit-exact = {} | border {} + corner {} exchanged, \
             {} link flits, {} exchange pairs completed",
            diff == 0.0,
            fmt_bits(stats.border_bits),
            fmt_bits(stats.corner_bits),
            stats.flits,
            stats.flags.completed
        );
        assert_eq!(diff, 0.0, "mesh output diverged from single chip");
    }

    // Exchange-vs-compute slack (§V-D): the serial border links must
    // hide under the next layer's compute on the paper's big mesh.
    let cfg = ChipConfig::default();
    let net2k = hyperdrive::network::zoo::resnet34(1024, 2048);
    let slacks = border::exchange_slack(&net2k, &cfg, 5, 10);
    let worst = slacks
        .iter()
        .map(|s| s.exchange_cycles as f64 / s.next_compute_cycles as f64)
        .fold(0.0, f64::max);
    println!(
        "ResNet-34 @2k×1k on 10×5: all {} exchanges hidden under compute \
         (worst link occupies {:.0}% of the consumer layer's cycles)",
        slacks.len(),
        100.0 * worst
    );

    // Border/corner memory the silicon provisions for this (§V-C).
    let a = wcl::analyze(net);
    println!(
        "BM {} / CM {} per chip for {} (ResNet-34 sizing: {} / {})",
        fmt_bits(border::border_memory_bits(net, &a, 2, 2, cfg.fm_bits)),
        fmt_bits(border::corner_memory_bits(net, cfg.fm_bits)),
        net.name,
        fmt_bits(459_000),
        fmt_bits(64_000),
    );
    println!("multichip_mesh OK");
    Ok(())
}

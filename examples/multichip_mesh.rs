//! Multi-chip systolic mesh demo (§V) through the unified `Engine`
//! façade: run HyperNet-20 on 2×2, 2×4 and 4×4 meshes of simulated
//! chips — real distributed tiles, real border/corner memories, real
//! send-once exchange protocol — and verify each is bit-exact against
//! the functional single-chip backend built from the *same* parameters.
//!
//!     cargo run --release --example multichip_mesh
//!
//! Uses the real (trained) manifest parameters when `artifacts/` exists
//! (`make artifacts`), seeded synthetic BWN parameters otherwise.

use std::sync::Arc;

use hyperdrive::coordinator::border;
use hyperdrive::coordinator::wcl;
use hyperdrive::engine::{Engine, NetworkParams, Precision};
use hyperdrive::network::zoo;
use hyperdrive::runtime::NetworkManifest;
use hyperdrive::util::{fmt_bits, SplitMix64};
use hyperdrive::ChipConfig;

fn main() -> anyhow::Result<()> {
    // Network + parameters + input: the manifest's own network when
    // artifacts exist (params are positional per step, so the net must
    // come from the same source), the zoo twin with seeded parameters
    // otherwise.
    let (net, params, input_vec, source) = match NetworkManifest::load("artifacts") {
        Ok(nm) => {
            let p = NetworkParams::from_manifest(&nm, 16)?;
            let input = nm.golden("e2e_input.bin")?;
            (
                nm.network.clone(),
                Arc::new(p),
                input,
                "manifest (trained) parameters",
            )
        }
        Err(_) => {
            let net = zoo::hypernet20();
            let mut rng = SplitMix64::new(0xbeef);
            let input = (0..16 * 32 * 32).map(|_| rng.next_sym()).collect();
            let p = NetworkParams::seeded(&net, 16, 0xabcd);
            (net, Arc::new(p), input, "seeded synthetic parameters")
        }
    };
    println!("{} with {source}", net.name);

    // Single-chip FP16 reference through the same façade.
    let reference = Engine::builder()
        .network(net.clone())
        .params(params.clone())
        .precision(Precision::F16)
        .build()?;
    let want = reference.infer(&input_vec)?;

    for (rows, cols) in [(2usize, 2usize), (2, 4), (4, 4)] {
        let mesh = Engine::builder()
            .network(net.clone())
            .params(params.clone())
            .mesh(rows, cols)
            .precision(Precision::F16)
            .build()?;
        let got = mesh.infer(&input_vec)?;
        let exact = got == want;
        let stats = mesh.mesh_stats().expect("mesh backend records stats");
        println!(
            "{rows}x{cols} mesh: bit-exact = {} | border {} + corner {} exchanged, \
             {} link flits, {} exchange pairs completed",
            exact,
            fmt_bits(stats.border_bits),
            fmt_bits(stats.corner_bits),
            stats.flits,
            stats.flags.completed
        );
        assert!(exact, "mesh output diverged from single chip");
    }

    // Exchange-vs-compute slack (§V-D): the serial border links must
    // hide under the next layer's compute on the paper's big mesh.
    let cfg = ChipConfig::default();
    let net2k = zoo::resnet34(1024, 2048);
    let slacks = border::exchange_slack(&net2k, &cfg, 5, 10);
    let worst = slacks
        .iter()
        .map(|s| s.exchange_cycles as f64 / s.next_compute_cycles as f64)
        .fold(0.0, f64::max);
    println!(
        "ResNet-34 @2k×1k on 10×5: all {} exchanges hidden under compute \
         (worst link occupies {:.0}% of the consumer layer's cycles)",
        slacks.len(),
        100.0 * worst
    );

    // Border/corner memory the silicon provisions for this (§V-C).
    let a = wcl::analyze(&net);
    println!(
        "BM {} / CM {} per chip for {} (ResNet-34 sizing: {} / {})",
        fmt_bits(border::border_memory_bits(&net, &a, 2, 2, cfg.fm_bits)),
        fmt_bits(border::corner_memory_bits(&net, cfg.fm_bits)),
        net.name,
        fmt_bits(459_000),
        fmt_bits(64_000),
    );
    println!("multichip_mesh OK");
    Ok(())
}

//! Object-detection scenario (the paper's motivating application)
//! through the unified `Engine` façade: YOLOv3 feature extraction at
//! 320×320 on a single Hyperdrive chip, and ResNet-34 features on
//! Cityscapes-class 2048×1024 frames on a 10×5 systolic mesh — the
//! workloads of Tbl V's bottom half — both read from one typed
//! `EngineReport` instead of hand-assembled tuples.
//!
//!     cargo run --release --example object_detection

use hyperdrive::baselines::published_rows;
use hyperdrive::engine::{DepthwisePolicy, Engine};
use hyperdrive::util::fmt_bits;

fn main() -> anyhow::Result<()> {
    // --- YOLOv3 @ 320² on one chip --------------------------------------
    let rep = Engine::builder()
        .model("yolov3@320x320")
        .depthwise(DepthwisePolicy::FullRate)
        .build()?
        .report();
    println!("== YOLOv3 @320x320, single chip ==");
    println!(
        "ops {} | cycles {} | conv-utilization {:.1}% (paper 82.8%)",
        fmt_bits(rep.schedule.total_ops()),
        rep.schedule.total_cycles(),
        100.0 * rep.schedule.conv_utilization(&rep.chip)
    );
    println!(
        "energy: {:.1} mJ/frame (core {:.1} + I/O {:.1}) → {:.2} TOp/s/W system \
         (paper: 14.5 mJ, 3.7 TOp/s/W)",
        rep.energy.total_j() * 1e3,
        rep.energy.core_j * 1e3,
        rep.energy.io_j * 1e3,
        rep.energy.system_efficiency_ops_w() / 1e12
    );
    println!("frame rate {:.1} fps at {} V\n", rep.energy.frame_rate_hz, rep.vdd);

    // --- ResNet-34 features @ 2048×1024 on a 10×5 mesh ------------------
    let rep = Engine::builder()
        .model("resnet34@1024x2048")
        .mesh(5, 10)
        .depthwise(DepthwisePolicy::FullRate)
        .build()?
        .report();
    println!(
        "== ResNet-34 features @2048x1024, {}x{} mesh ==",
        rep.plan.rows, rep.plan.cols
    );
    println!(
        "ops {} | per-chip cycles {} | {} chips",
        fmt_bits(rep.energy.ops),
        rep.energy.cycles,
        rep.energy.chips
    );
    println!(
        "I/O: weights {} (broadcast once) + input {} + border {} = {}",
        fmt_bits(rep.energy.io.weights),
        fmt_bits(rep.energy.io.input_fm),
        fmt_bits(rep.energy.io.border),
        fmt_bits(rep.energy.io.total())
    );
    println!(
        "energy: {:.1} mJ/frame (core {:.1} + I/O {:.1}) → {:.2} TOp/s/W system \
         (paper: 69.5 mJ, 4.3 TOp/s/W)",
        rep.energy.total_j() * 1e3,
        rep.energy.core_j * 1e3,
        rep.energy.io_j * 1e3,
        rep.energy.system_efficiency_ops_w() / 1e12
    );

    // --- headline: improvement over the FM-streaming state of the art ---
    let best = published_rows()
        .iter()
        .filter(|row| row.input == "2kx1k")
        .map(|row| row.efficiency_tops_w)
        .fold(0.0, f64::max);
    let ours = rep.energy.system_efficiency_ops_w() / 1e12;
    println!(
        "\nimprovement over best published FM-streaming accelerator ({best} TOp/s/W): \
         {:.1}x (paper claims 3.1x)",
        ours / best
    );
    assert!(ours / best > 2.0, "headline improvement collapsed");
    println!("object_detection OK");
    Ok(())
}

//! Object-detection scenario (the paper's motivating application):
//! YOLOv3 feature extraction at 320×320 on a single Hyperdrive chip, and
//! ResNet-34 features on Cityscapes-class 2048×1024 frames on a 10×5
//! systolic mesh — the workloads of Tbl V's bottom half.
//!
//!     cargo run --release --example object_detection

use hyperdrive::baselines::published_rows;
use hyperdrive::coordinator::schedule::{schedule_network, DepthwisePolicy};
use hyperdrive::coordinator::tiling::{plan_mesh_exact, MeshPlan};
use hyperdrive::energy::model::energy_per_image;
use hyperdrive::network::zoo;
use hyperdrive::util::fmt_bits;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    let dw = DepthwisePolicy::FullRate;

    // --- YOLOv3 @ 320² on one chip --------------------------------------
    let yolo = zoo::yolov3(320, 320);
    let sched = schedule_network(&yolo, &cfg, dw);
    let single = MeshPlan {
        rows: 1,
        cols: 1,
        per_chip_wcl_words: 0,
    };
    let r = energy_per_image(&yolo, &cfg, &single, 0.5, 1.5, dw);
    println!("== YOLOv3 @320x320, single chip ==");
    println!(
        "ops {} | cycles {} | conv-utilization {:.1}% (paper 82.8%)",
        fmt_bits(sched.total_ops()),
        sched.total_cycles(),
        100.0 * sched.conv_utilization(&cfg)
    );
    println!(
        "energy: {:.1} mJ/frame (core {:.1} + I/O {:.1}) → {:.2} TOp/s/W system \
         (paper: 14.5 mJ, 3.7 TOp/s/W)",
        r.total_j() * 1e3,
        r.core_j * 1e3,
        r.io_j * 1e3,
        r.system_efficiency_ops_w() / 1e12
    );
    println!("frame rate {:.1} fps at 0.5 V\n", r.frame_rate_hz);

    // --- ResNet-34 features @ 2048×1024 on a 10×5 mesh ------------------
    let net = zoo::resnet34(1024, 2048);
    let plan = plan_mesh_exact(&net, &cfg, 5, 10);
    let r = energy_per_image(&net, &cfg, &plan, 0.5, 1.5, dw);
    println!("== ResNet-34 features @2048x1024, {}x{} mesh ==", plan.rows, plan.cols);
    println!(
        "ops {} | per-chip cycles {} | {} chips",
        fmt_bits(r.ops),
        r.cycles,
        r.chips
    );
    println!(
        "I/O: weights {} (broadcast once) + input {} + border {} = {}",
        fmt_bits(r.io.weights),
        fmt_bits(r.io.input_fm),
        fmt_bits(r.io.border),
        fmt_bits(r.io.total())
    );
    println!(
        "energy: {:.1} mJ/frame (core {:.1} + I/O {:.1}) → {:.2} TOp/s/W system \
         (paper: 69.5 mJ, 4.3 TOp/s/W)",
        r.total_j() * 1e3,
        r.core_j * 1e3,
        r.io_j * 1e3,
        r.system_efficiency_ops_w() / 1e12
    );

    // --- headline: improvement over the FM-streaming state of the art ---
    let best = published_rows()
        .iter()
        .filter(|row| row.input == "2kx1k")
        .map(|row| row.efficiency_tops_w)
        .fold(0.0, f64::max);
    let ours = r.system_efficiency_ops_w() / 1e12;
    println!(
        "\nimprovement over best published FM-streaming accelerator ({best} TOp/s/W): \
         {:.1}x (paper claims 3.1x)",
        ours / best
    );
    assert!(ours / best > 2.0, "headline improvement collapsed");
    println!("object_detection OK");
}

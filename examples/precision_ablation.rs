//! Precision ablation (§VI-D): what the paper's own estimate — "moving
//! from FP16 to Q12 would lead to an energy efficiency boost … around 3×
//! for the core" — does to the system-level numbers, re-planning the
//! chip mesh for the narrower FM words (the same 6.4 Mbit of SRAM holds
//! more Q12/Q8 words, so fewer chips are needed at 2048×1024).
//!
//! Runs through `Engine::builder()` — the ablation rows are an engine
//! capability, like the rest of the typed report.
//!
//!     cargo run --release --example precision_ablation

use hyperdrive::energy::ablation::render;
use hyperdrive::engine::Engine;

fn main() -> anyhow::Result<()> {
    for spec in ["resnet34@224x224", "yolov3@320x320", "resnet34@1024x2048"] {
        let engine = Engine::builder().model(spec).build()?;
        let rows = engine.ablation();
        let rep = engine.report();
        println!("{}", render(&rep.network, &rows));
        let q12_vs_soa = rows[1].system_eff_ops_w / 1e12 / 1.4;
        let (_, ih, _) = rep.input_shape;
        if rep.network == "ResNet-34" && ih > 128 {
            println!(
                "Q12 vs best FM-streaming SoA (1.4 TOp/s/W): {q12_vs_soa:.1}x \
                 (paper's estimate: ~6.8x)\n"
            );
        }
    }
    Ok(())
}

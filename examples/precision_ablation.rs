//! Precision ablation (§VI-D): what the paper's own estimate — "moving
//! from FP16 to Q12 would lead to an energy efficiency boost … around 3×
//! for the core" — does to the system-level numbers, re-planning the
//! chip mesh for the narrower FM words (the same 6.4 Mbit of SRAM holds
//! more Q12/Q8 words, so fewer chips are needed at 2048×1024).
//!
//!     cargo run --release --example precision_ablation

use hyperdrive::energy::ablation::{precision_ablation, render};
use hyperdrive::network::zoo;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    for net in [
        zoo::resnet34(224, 224),
        zoo::yolov3(320, 320),
        zoo::resnet34(1024, 2048),
    ] {
        let rows = precision_ablation(&net, &cfg);
        println!("{}", render(&net.name, &rows));
        let q12_vs_soa = rows[1].system_eff_ops_w / 1e12 / 1.4;
        if net.name == "ResNet-34" && net.in_h > 128 {
            println!(
                "Q12 vs best FM-streaming SoA (1.4 TOp/s/W): {q12_vs_soa:.1}x \
                 (paper's estimate: ~6.8x)\n"
            );
        }
    }
}

//! Quickstart: the unified `Engine` façade in one page — build an
//! engine over the functional chip simulator, run a traced inference,
//! serve a concurrent batch, host two models in an `InferenceService`,
//! and read the typed report.
//!
//!     cargo run --release --example quickstart
//!
//! (No artifacts needed: the simulator backends generate deterministic
//! seeded BWN parameters. For the PJRT backend see `e2e_inference`.)

use hyperdrive::engine::{Engine, InferRequest, InferenceService, ModelConfig, Precision, ServeOptions};
use hyperdrive::model;
use hyperdrive::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    // HyperNet-20 (the e2e validation network) resolved through the
    // model registry; its weight source is the seeded ±1 generator.
    let resolved = model::resolve("hypernet20")?;
    let params = resolved.weights.params(&resolved.network, 16)?;
    println!(
        "{} via {}: {} layers, first layer {} words × 16 bit \
         (16x smaller than FP16 weights)",
        resolved.network.name,
        resolved.weights.describe(),
        params.steps.len(),
        params.steps[0].stream.words.len(),
    );

    // 1) Build: functional single-chip backend, FP16 like the silicon.
    let engine = Engine::builder()
        .network(resolved.network)
        .params(params)
        .precision(Precision::F16)
        .build()?;

    // 2) One traced inference: the hook sees every layer's output FM.
    let mut rng = SplitMix64::new(7);
    let input: Vec<f32> = (0..engine.input_len()).map(|_| rng.next_sym()).collect();
    let mut layers = 0usize;
    let out = engine.infer_traced(&input, &mut |t| {
        if t.step < 2 {
            println!("  step {:>2} `{}` → {:?}", t.step, t.layer, t.shape);
        }
        layers += 1;
    })?;
    println!("ran {layers} layers; final FM has {} values, out[0..4] = {:?}",
             out.len(), &out[..4]);

    // 3) Concurrent serving: bounded queue, 2 workers, per-request
    //    results (a failing request costs only its own slot), stats.
    let batch: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..engine.input_len()).map(|_| rng.next_sym()).collect())
        .collect();
    let opts = ServeOptions { workers: 2, ..ServeOptions::default() };
    let outcome = engine.serve(&batch, &opts)?;
    println!("{}", engine.report_with_serve(outcome.stats.clone()).serve_summary());
    let (outs, _stats) = outcome.outputs()?; // all-or-nothing view
    assert_eq!(outs.len(), 8);

    // 4) Multi-model serving: one long-lived InferenceService hosting
    //    two registry models under a shared worker budget, routed by
    //    name, with live metrics.
    let service = InferenceService::builder()
        .model_spec("hypernet20")
        .model("tiny-resnet", ModelConfig::new("resnet18@32x32"))
        .workers(2)
        .build()?;
    let input: Vec<f32> = (0..service.input_len("hypernet20").unwrap())
        .map(|_| rng.next_sym())
        .collect();
    let ticket = service.submit(InferRequest {
        model: "hypernet20".into(),
        input: input.into(),
        id: 0,
        deadline_ms: None,
    })?;
    let response = ticket.wait()?;
    println!(
        "service: request {} on `{}` took {:.2} ms",
        response.id, response.model, response.latency_ms
    );
    print!("{}", service.shutdown().render_table());

    // 5) What the silicon would do for this network (typed report).
    println!("{}", engine.report().summary());
    println!("quickstart OK");
    Ok(())
}

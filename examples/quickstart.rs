//! Quickstart: run one binary-weight convolution layer through the whole
//! stack — pack the binary weights into the chip's stream format, load
//! the AOT-compiled Pallas kernel on PJRT, execute, and cross-check
//! against the Rust functional chip simulator.
//!
//!     make artifacts && cargo run --release --example quickstart

use hyperdrive::bwn::pack_weights;
use hyperdrive::network::ConvLayer;
use hyperdrive::runtime::Runtime;
use hyperdrive::simulator::{self, FeatureMap, Precision};
use hyperdrive::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    // The first HyperNet-20 layer: 16→16 channels, 32×32 FM, 3×3 conv.
    let layer = ConvLayer::new("quickstart", 16, 16, 32, 32, 3, 1);
    let artifact = "conv_k3s1_i16o16_h32w32_bp0_relu1";

    // Synthetic input FM and real-valued weights → binarized stream.
    let mut rng = SplitMix64::new(42);
    let input: Vec<f32> = (0..16 * 32 * 32).map(|_| rng.next_gauss()).collect();
    let weights: Vec<f32> = (0..16 * 16 * 9).map(|_| rng.next_gauss()).collect();
    let gamma = vec![1.0 / (16.0 * 9.0); 16];
    let beta = vec![0.0f32; 16];

    // 1) The chip's on-pin format: binary weights packed in Tbl-I order.
    let stream = pack_weights(&layer, &weights, 16);
    println!(
        "weight stream: {} words × 16 bit = {} bits ({}× smaller than FP16 weights)",
        stream.words.len(),
        stream.wire_bits(),
        16
    );

    // 2) Execute the AOT-lowered Pallas kernel on PJRT.
    let mut rt = Runtime::cpu()?;
    rt.load_artifact(artifact, std::path::Path::new(&format!("artifacts/{artifact}.hlo.txt")))?;
    let dense = stream.unpack_dense(); // what the weight buffer holds
    let out = rt.execute(
        artifact,
        &[
            (&input, &[16, 32, 32]),
            (&dense, &[16, 16, 3, 3]),
            (&gamma, &[16]),
            (&beta, &[16]),
        ],
    )?;
    println!("PJRT output: {} values, out[0..4] = {:?}", out.len(), &out[..4]);

    // 3) Cross-check with the functional chip simulator (f32 datapath).
    let fm = FeatureMap::from_vec(16, 32, 32, input);
    let params = simulator::chip::LayerParams {
        layer: &layer,
        stream: &stream,
        gamma: &gamma,
        beta: &beta,
    };
    let (sim, counts) = simulator::run_layer(&params, &fm, None, Precision::F32, (7, 7));
    let max_err = sim
        .data
        .iter()
        .zip(&out)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("simulator vs PJRT max |err| = {max_err:.3e}");
    assert!(max_err < 1e-4, "simulator and PJRT disagree");

    // 4) What the silicon would do for this layer.
    println!(
        "chip accesses: {} FMM reads, {} FMM writes, {} stream words, {} WBuf reads",
        counts.fmm_reads, counts.fmm_writes, counts.stream_words, counts.wbuf_reads
    );
    println!("quickstart OK");
    Ok(())
}

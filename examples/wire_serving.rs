//! Serving over the wire: a `WireServer` on a loopback TCP port, a
//! handful of `WireClient` connections talking the length-prefixed
//! binary frame protocol, and a pipelined load-generation sweep — the
//! network-facing shape of Hyperdrive's system-level pitch (the paper
//! counts interface I/O, so the serving stack gets a real interface).
//!
//!     cargo run --release --example wire_serving
//!
//! Shows: the Hello handshake advertising the hosted model table,
//! call-response and pipelined inference, results bit-exact with an
//! in-process `Engine::infer`, metrics over the wire, backpressure
//! telemetry, and an orderly Goodbye.

use std::sync::Arc;

use hyperdrive::engine::{
    run_loadgen, Engine, InferenceService, LoadGenConfig, RetryPolicy, WireClient, WireServer,
};
use hyperdrive::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    // One sharded service, two models, four workers — then a TCP
    // frontend on an OS-assigned loopback port.
    let service = Arc::new(
        InferenceService::builder()
            .model_spec("hypernet20")
            .model_spec("resnet18@32x32")
            .workers(4)
            .queue_depth(32)
            .build()?,
    );
    let server = WireServer::start(service.clone(), "127.0.0.1:0")
        .map_err(|e| anyhow::anyhow!("bind failed: {e}"))?;
    let addr = server.local_addr().to_string();
    println!("wire server listening on {addr}");

    // Handshake: the server's Hello carries every hosted model and its
    // input length, so a client knows the tensor shapes up front.
    let mut client = WireClient::connect(&addr).map_err(|e| anyhow::anyhow!("{e}"))?;
    for (name, input_len) in client.models() {
        println!("  hosted: {name:<16} ({input_len} input values)");
    }

    // Call-response inference, checked bit-exact against a direct
    // in-process Engine built from the same spec (the synthetic
    // parameters are seed-deterministic, so the wire path must agree
    // to the last bit).
    let reference = Engine::builder().model("hypernet20").build()?;
    let mut rng = SplitMix64::new(7);
    let input: Vec<f32> = (0..reference.input_len()).map(|_| rng.next_sym()).collect();
    let over_wire = client
        .infer("hypernet20", &input)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let direct = reference.infer(&input)?;
    assert_eq!(over_wire, direct);
    println!("TCP result is bit-exact vs direct Engine::infer ({} values)", direct.len());

    // The server's metrics table travels the wire too.
    let table = client.metrics_table().map_err(|e| anyhow::anyhow!("{e}"))?;
    print!("{table}");
    client.goodbye().map_err(|e| anyhow::anyhow!("{e}"))?;

    // A pipelined multi-connection load-generation pass — the same
    // engine behind the `loadgen` CLI subcommand.
    let report = run_loadgen(&LoadGenConfig {
        addr,
        connections: 4,
        in_flight: 8,
        requests: 64,
        models: vec!["hypernet20".into(), "resnet18@32x32".into()],
        seed: 11,
        retry: RetryPolicy::default(),
        deadline_ms: None,
        chaos: None,
    })
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "loadgen: {} ok, {} failed, {} rejected → {:.1} req/s, p50 {:.2} ms, p99 {:.2} ms",
        report.ok, report.failed, report.rejected_backpressure,
        report.req_per_s, report.p50_ms, report.p99_ms
    );

    // Orderly teardown: the server first, then the service it fed.
    let stats = server.shutdown();
    println!(
        "wire: {} connections, {} frames in, {} frames out, {} malformed, peak in-flight {}",
        stats.connections, stats.frames_rx, stats.frames_tx, stats.malformed, stats.max_in_flight
    );
    let service = Arc::try_unwrap(service)
        .unwrap_or_else(|_| panic!("server threads are joined; this is the last Arc"));
    print!("{}", service.shutdown().render_table());
    println!("wire_serving OK");
    Ok(())
}

"""AOT compile path: lower every Hyperdrive layer variant to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 rust crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (all under ``artifacts/``):
  * ``<artifact>.hlo.txt``  — one per distinct layer spec + the head;
  * ``manifest.tsv``        — artifact table, the HyperNet-20 step list and
    the parameter-blob index (whitespace-separated ``key=value`` records —
    deliberately trivial to parse from Rust without a JSON dependency);
  * ``e2e_params.bin`` / ``e2e_input.bin`` / ``e2e_golden.bin`` /
    ``e2e_final_fm.bin`` — raw little-endian f32 blobs for the end-to-end
    example (synthetic deterministic parameters + golden outputs).

Run via ``make artifacts`` (no-op when inputs are unchanged). Python never
runs at inference time.
"""

from __future__ import annotations

import argparse
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.bwn_conv import ConvSpec


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_conv(spec: ConvSpec) -> str:
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((spec.n_in, spec.h, spec.w), f32),
        jax.ShapeDtypeStruct((spec.n_out, spec.n_in, spec.k, spec.k), f32),
        jax.ShapeDtypeStruct((spec.n_out,), f32),
        jax.ShapeDtypeStruct((spec.n_out,), f32),
    ]
    if spec.has_bypass:
        args.append(
            jax.ShapeDtypeStruct((spec.n_out, spec.h_out, spec.w_out), f32))
    fn = M.make_layer_fn(spec)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_head() -> str:
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((M.HEAD_IN_CH, M.HEAD_IN_HW, M.HEAD_IN_HW), f32),
        jax.ShapeDtypeStruct((M.N_CLASSES, M.HEAD_IN_CH), f32),
        jax.ShapeDtypeStruct((M.N_CLASSES,), f32),
    ]
    return to_hlo_text(jax.jit(M.make_head_fn()).lower(*args))


def conv_manifest_row(name: str, spec: ConvSpec) -> str:
    return ("artifact name={n} kind=conv k={k} stride={s} n_in={i} n_out={o} "
            "h={h} w={w} bypass={b} relu={r} dtype=f32 file={n}.hlo.txt"
            .format(n=name, k=spec.k, s=spec.stride, i=spec.n_in,
                    o=spec.n_out, h=spec.h, w=spec.w,
                    b=int(spec.has_bypass), r=int(spec.relu)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output directory (or a path inside it)")
    ap.add_argument("--seed", type=int, default=2018)
    args = ap.parse_args()
    outdir = args.out
    if outdir.endswith(".txt") or outdir.endswith(".tsv"):
        outdir = os.path.dirname(outdir)  # tolerate `--out ../artifacts/x.txt`
    os.makedirs(outdir, exist_ok=True)

    steps = M.hypernet20_steps()
    specs: dict[str, ConvSpec] = {}
    for st in steps:
        specs.setdefault(M.artifact_name(st.spec), st.spec)

    manifest: list[str] = ["# Hyperdrive AOT artifact manifest (generated)"]

    # -- lower every distinct conv spec -----------------------------------
    for name, spec in sorted(specs.items()):
        path = os.path.join(outdir, f"{name}.hlo.txt")
        text = lower_conv(spec)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(conv_manifest_row(name, spec))
        print(f"lowered {name}: {len(text)} chars")

    head_text = lower_head()
    with open(os.path.join(outdir, "head.hlo.txt"), "w") as f:
        f.write(head_text)
    manifest.append(
        f"artifact name=head kind=head c={M.HEAD_IN_CH} hw={M.HEAD_IN_HW} "
        f"classes={M.N_CLASSES} dtype=f32 file=head.hlo.txt")

    # -- network step list -------------------------------------------------
    manifest.append(f"network name=hypernet20 steps={len(steps)} "
                    f"in_ch=16 in_h=32 in_w=32 classes={M.N_CLASSES}")
    for i, st in enumerate(steps):
        manifest.append(
            f"step idx={i} name={st.name} artifact={M.artifact_name(st.spec)} "
            f"src={st.src} bypass={st.bypass_src}")

    # -- parameter blob + goldens ------------------------------------------
    params = M.init_params(args.seed)
    blob = bytearray()

    def put(step_name: str, field: str, arr: np.ndarray) -> str:
        off = len(blob) // 4
        flat = np.ascontiguousarray(arr, dtype=np.float32).ravel()
        blob.extend(flat.tobytes())
        return (f"blob step={step_name} field={field} off={off} "
                f"len={flat.size}")

    for st in steps:
        p = params[st.name]
        manifest.append(put(st.name, "w", p["w"]))
        manifest.append(put(st.name, "gamma", p["gamma"]))
        manifest.append(put(st.name, "beta", p["beta"]))
    manifest.append(put("head", "w_fc", params["head"]["w_fc"]))
    manifest.append(put("head", "b_fc", params["head"]["b_fc"]))

    with open(os.path.join(outdir, "e2e_params.bin"), "wb") as f:
        f.write(blob)

    x = M.make_input()
    with open(os.path.join(outdir, "e2e_input.bin"), "wb") as f:
        f.write(x.tobytes())

    logits, fms = M.forward(params, jnp.asarray(x), use_pallas=True)
    logits = np.asarray(logits, dtype=np.float32)
    final_fm = np.asarray(fms[-1], dtype=np.float32)
    with open(os.path.join(outdir, "e2e_golden.bin"), "wb") as f:
        f.write(logits.tobytes())
    with open(os.path.join(outdir, "e2e_final_fm.bin"), "wb") as f:
        f.write(final_fm.tobytes())
    manifest.append("golden file=e2e_golden.bin kind=logits "
                    f"len={logits.size} seed={args.seed}")
    manifest.append("golden file=e2e_final_fm.bin kind=final_fm "
                    f"len={final_fm.size} seed={args.seed}")
    manifest.append("golden file=e2e_input.bin kind=input "
                    f"len={x.size} seed=7")
    digest = hashlib.sha256(bytes(blob)).hexdigest()[:16]
    manifest.append(f"blobfile file=e2e_params.bin words={len(blob)//4} "
                    f"sha256_16={digest}")

    with open(os.path.join(outdir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(specs)} conv artifacts + head, "
          f"{len(blob)//4} param words, manifest.tsv")


if __name__ == "__main__":
    main()

"""L1 — Pallas binary-weight convolution kernel (the Hyperdrive hot-spot).

Implements the paper's Algorithm 1 as a feature-map-stationary Pallas
kernel:

  * the FM tile lives in VMEM for the whole layer (the FMM of the chip),
  * the binary weights are *streamed* per output-channel tile ``C`` (the
    weight buffer / weight stream of the chip) — expressed as the only
    grid-blocked operand,
  * the binary weight is applied as the *sign* of the accumulation
    (Algorithm 1, line 17: ``v += x`` if ``w = 1`` else ``v -= x``); on the
    MXU this is a ±1 matmul, which is the TPU-native expression of the
    sign-input FP16 adder array (see DESIGN.md §Hardware adaptation),
  * the stall-free post-op order of §IV-B is fused in:
    convolution → scale (bnorm) → bypass add → bias → ReLU → store.

The kernel must be lowered with ``interpret=True``: real-TPU Pallas emits a
Mosaic custom-call which the CPU PJRT client cannot execute.

Spatial M×N tile parallelism of the silicon maps to VPU vector lanes within
the block rather than to the Pallas grid — overlapping (halo) grid blocks
are not expressible in a ``BlockSpec``, and the halo exchange is precisely
what the paper's L3 border-memory machinery does (reproduced in
``rust/src/simulator/mesh.rs``).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


class ConvSpec(NamedTuple):
    """Static shape/config of one Hyperdrive layer invocation.

    Mirrors one row of the rust artifact manifest (see ``aot.py``).
    """

    n_in: int
    n_out: int
    h: int          # input spatial height
    w: int          # input spatial width
    k: int          # kernel size (1 or 3 — the only sizes the chip supports)
    stride: int     # 1 or 2
    has_bypass: bool
    relu: bool
    cpar: int = 16  # C — output-channel parallelism of the Tile-PU array

    @property
    def h_out(self) -> int:
        return self.h // self.stride

    @property
    def w_out(self) -> int:
        return self.w // self.stride

    @property
    def pad(self) -> int:
        return self.k // 2


def _bwn_conv_kernel(x_ref, w_ref, gamma_ref, beta_ref, *rest, spec: ConvSpec):
    """One grid step = one output-channel tile of C channels (Tbl I schedule).

    x_ref:     (n_in, h + 2p, w + 2p)  — zero-padded input FM, fully resident
    w_ref:     (C, n_in, k, k)         — binary weights (±1) for this c_out tile
    gamma_ref: (C,)                    — bnorm scale (α) for this tile
    beta_ref:  (C,)                    — merged bias (β + bnorm shift)
    byp_ref:   (C, h_out, w_out)       — optional residual bypass input
    o_ref:     (C, h_out, w_out)
    """
    if spec.has_bypass:
        byp_ref, o_ref = rest
    else:
        (o_ref,) = rest

    x = x_ref[...]
    wts = w_ref[...]
    n_in, k, s = spec.n_in, spec.k, spec.stride
    ho, wo = spec.h_out, spec.w_out

    # Accumulate over the k·k filter taps (loop order of Algorithm 1 lines
    # 7–19: filter tap outer, input channel inner — the inner c_in reduction
    # is the ±1 matmul feeding the MXU).
    acc = jnp.zeros((spec.cpar, ho * wo), dtype=jnp.float32)
    for dy in range(k):
        for dx in range(k):
            # Aligned neighbour read (DDU): shifted, strided window of the
            # stationary FM. Shapes are static — unrolled at trace time.
            window = jax.lax.slice(
                x, (0, dy, dx), (n_in, dy + s * ho - s + 1, dx + s * wo - s + 1),
                (1, s, s),
            )  # (n_in, ho, wo)
            xs = window.reshape(n_in, ho * wo).astype(jnp.float32)
            # w ∈ {−1,+1}: sign-select accumulate, expressed as a matmul so
            # the TPU lowering targets the MXU systolic array.
            acc = acc + jnp.dot(wts[:, :, dy, dx].astype(jnp.float32), xs)

    v = acc.reshape(spec.cpar, ho, wo)
    # Stall-free post-op order of §IV-B: scale → bypass → bias (→ ReLU).
    v = v * gamma_ref[...][:, None, None]
    if spec.has_bypass:
        v = v + byp_ref[...].astype(jnp.float32)
    v = v + beta_ref[...][:, None, None]
    if spec.relu:
        v = jnp.maximum(v, 0.0)
    o_ref[...] = v.astype(o_ref.dtype)


def bwn_conv(x, w, gamma, beta, bypass=None, *, spec: ConvSpec,
             interpret: bool = True):
    """Binary-weight convolution of one full layer via the Pallas kernel.

    Args:
      x:      (n_in, h, w) input feature map.
      w:      (n_out, n_in, k, k) binary weights, values in {−1, +1}.
      gamma:  (n_out,) per-output-channel scale (folded batch-norm α).
      beta:   (n_out,) per-output-channel bias (folded bias + bn shift β).
      bypass: optional (n_out, h_out, w_out) residual input added before β.
      spec:   static layer configuration; ``spec.n_out`` must divide by
              ``spec.cpar`` (pad channels upstream otherwise).

    Returns: (n_out, h_out, w_out) output feature map, dtype of ``x``.
    """
    assert spec.n_out % spec.cpar == 0, "n_out must be a multiple of C"
    assert spec.k in (1, 3), "the chip supports only 1x1 and 3x3 kernels"
    assert spec.stride in (1, 2)
    p = spec.pad
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p)))  # DDU zero-padding
    n_tiles = spec.n_out // spec.cpar
    out_shape = jax.ShapeDtypeStruct((spec.n_out, spec.h_out, spec.w_out),
                                     x.dtype)

    in_specs = [
        # FM stationary: every grid step sees the whole padded FM.
        pl.BlockSpec((spec.n_in, spec.h + 2 * p, spec.w + 2 * p),
                     lambda c: (0, 0, 0)),
        # Weight streaming: one C-sized output-channel tile per grid step.
        pl.BlockSpec((spec.cpar, spec.n_in, spec.k, spec.k),
                     lambda c: (c, 0, 0, 0)),
        pl.BlockSpec((spec.cpar,), lambda c: (c,)),
        pl.BlockSpec((spec.cpar,), lambda c: (c,)),
    ]
    args = [xp, w, gamma, beta]
    if spec.has_bypass:
        assert bypass is not None
        in_specs.append(
            pl.BlockSpec((spec.cpar, spec.h_out, spec.w_out),
                         lambda c: (c, 0, 0)))
        args.append(bypass)

    kernel = functools.partial(_bwn_conv_kernel, spec=spec)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((spec.cpar, spec.h_out, spec.w_out),
                               lambda c: (c, 0, 0)),
        out_shape=out_shape,
        interpret=interpret,
    )(*args)


def vmem_bytes(spec: ConvSpec, fm_bytes: int = 2) -> dict:
    """Estimate the per-grid-step VMEM residency of the kernel blocks.

    Used by the perf pass (EXPERIMENTS.md §Perf) to check the real-TPU
    mapping against the ~16 MiB/core VMEM budget; weights are 1 bit in the
    silicon but ``fm_bytes`` wide in the lowered kernel (documented gap).
    """
    p = spec.pad
    fm_in = spec.n_in * (spec.h + 2 * p) * (spec.w + 2 * p) * fm_bytes
    wts = spec.cpar * spec.n_in * spec.k * spec.k * fm_bytes
    out = spec.cpar * spec.h_out * spec.w_out * 4  # f32 accumulator
    byp = out if spec.has_bypass else 0
    return {"fm_in": fm_in, "weights": wts, "acc_out": out, "bypass": byp,
            "total": fm_in + wts + out + byp}

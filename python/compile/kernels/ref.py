"""Pure-jnp correctness oracle for the Pallas BWN convolution kernel.

Uses ``jax.lax.conv_general_dilated`` — a completely independent code path
from the hand-scheduled kernel in ``bwn_conv.py`` — with the same fused
post-op order (scale → bypass → bias → ReLU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .bwn_conv import ConvSpec


def bwn_conv_ref(x, w, gamma, beta, bypass=None, *, spec: ConvSpec):
    """Reference BWN convolution. Same signature/semantics as ``bwn_conv``."""
    p = spec.pad
    lhs = x[None].astype(jnp.float32)          # (1, n_in, h, w)
    rhs = w.astype(jnp.float32)                # (n_out, n_in, k, k)
    out = jax.lax.conv_general_dilated(
        lhs, rhs,
        window_strides=(spec.stride, spec.stride),
        padding=((p, p), (p, p)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]                                       # (n_out, h_out, w_out)
    v = out * gamma.astype(jnp.float32)[:, None, None]
    if spec.has_bypass:
        v = v + bypass.astype(jnp.float32)
    v = v + beta.astype(jnp.float32)[:, None, None]
    if spec.relu:
        v = jnp.maximum(v, 0.0)
    return v.astype(x.dtype)


def binarize_ref(w):
    """Reference binarization: sign(w) with sign(0) := +1 (paper's BWN)."""
    return jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype)

"""L2 — the JAX model layer of the Hyperdrive stack.

Defines the BWN networks that get AOT-lowered, layer by layer, to HLO text
artifacts for the Rust coordinator:

  * ``make_layer_fn(spec)``   — one Hyperdrive-mappable layer (1×1/3×3 BWN
    conv + fused bnorm/bypass/bias/ReLU) calling the L1 Pallas kernel;
  * ``make_head_fn(...)``     — the off-chip head (global-avg-pool + FC);
    the paper runs first/last layers off the accelerator, we run the head
    as its own PJRT artifact;
  * ``hypernet20_steps()``    — "HyperNet-20", the ResNet-20-style BWN
    network used by the end-to-end example (3 stages of 16/32/64 channels
    on 32×32 input FMs, strided transitions with 1×1 bypass convolutions —
    the exact block structure of Fig. 4a scaled to tiny-corpus size);
  * ``init_params`` / ``forward`` — deterministic synthetic parameters and
    the golden forward pass used to cross-check the Rust runtime.

Python here runs at *build time only*; the Rust binary never imports it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.bwn_conv import ConvSpec, bwn_conv
from .kernels.ref import bwn_conv_ref


def artifact_name(spec: ConvSpec) -> str:
    """Canonical artifact key for a layer spec (one HLO file per key)."""
    return (f"conv_k{spec.k}s{spec.stride}_i{spec.n_in}o{spec.n_out}"
            f"_h{spec.h}w{spec.w}_bp{int(spec.has_bypass)}"
            f"_relu{int(spec.relu)}")


def make_layer_fn(spec: ConvSpec):
    """Build the jax function for one layer, ready for jit/lowering."""
    if spec.has_bypass:
        def fn(x, w, gamma, beta, byp):
            return (bwn_conv(x, w, gamma, beta, byp, spec=spec),)
    else:
        def fn(x, w, gamma, beta):
            return (bwn_conv(x, w, gamma, beta, spec=spec),)
    return fn


def make_layer_ref_fn(spec: ConvSpec):
    """Oracle twin of ``make_layer_fn`` (conv_general_dilated path)."""
    if spec.has_bypass:
        def fn(x, w, gamma, beta, byp):
            return (bwn_conv_ref(x, w, gamma, beta, byp, spec=spec),)
    else:
        def fn(x, w, gamma, beta):
            return (bwn_conv_ref(x, w, gamma, beta, spec=spec),)
    return fn


def make_head_fn():
    """Off-chip head: global average pool + fully-connected classifier."""
    def fn(x, w_fc, b_fc):
        pooled = jnp.mean(x, axis=(1, 2))          # (c,)
        return (w_fc @ pooled + b_fc,)             # (n_classes,)
    return fn


# --------------------------------------------------------------------------
# HyperNet-20 — the end-to-end validation network
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Step:
    """One scheduled layer of a network.

    ``src`` / ``bypass_src`` are step indices (-1 = the network input);
    the Rust coordinator replays exactly this step list from the manifest.
    """
    name: str
    spec: ConvSpec
    src: int
    bypass_src: int = -2       # -2 = no bypass, -1 = network input, >=0 step


def hypernet20_steps() -> list[Step]:
    """ResNet-20-style BWN step list (20 convs), basic blocks of Fig. 4a."""
    steps: list[Step] = []

    def add(name, spec, src, bypass_src=-2):
        steps.append(Step(name, spec, src, bypass_src))
        return len(steps) - 1

    # conv spec templates per stage
    s1 = dict(n_in=16, n_out=16, h=32, w=32, k=3, stride=1)
    s2 = dict(n_in=32, n_out=32, h=16, w=16, k=3, stride=1)
    s3 = dict(n_in=64, n_out=64, h=8, w=8, k=3, stride=1)

    prev = -1
    # stage 1: three basic blocks, identity bypass
    for b in range(3):
        c1 = add(f"s1b{b}c1", ConvSpec(**s1, has_bypass=False, relu=True), prev)
        prev_block_in = prev
        prev = add(f"s1b{b}c2", ConvSpec(**s1, has_bypass=True, relu=True),
                   c1, bypass_src=prev_block_in)

    # transition to stage 2: strided block with 1×1 strided bypass conv
    t2c1 = add("s2b0c1", ConvSpec(n_in=16, n_out=32, h=32, w=32, k=3, stride=2,
                                  has_bypass=False, relu=True), prev)
    t2sk = add("s2b0sk", ConvSpec(n_in=16, n_out=32, h=32, w=32, k=1, stride=2,
                                  has_bypass=False, relu=False), prev)
    prev = add("s2b0c2", ConvSpec(**s2, has_bypass=True, relu=True),
               t2c1, bypass_src=t2sk)

    # stage 2: two more basic blocks
    for b in (1, 2):
        c1 = add(f"s2b{b}c1", ConvSpec(**s2, has_bypass=False, relu=True), prev)
        block_in = prev
        prev = add(f"s2b{b}c2", ConvSpec(**s2, has_bypass=True, relu=True),
                   c1, bypass_src=block_in)

    # transition to stage 3
    t3c1 = add("s3b0c1", ConvSpec(n_in=32, n_out=64, h=16, w=16, k=3, stride=2,
                                  has_bypass=False, relu=True), prev)
    t3sk = add("s3b0sk", ConvSpec(n_in=32, n_out=64, h=16, w=16, k=1, stride=2,
                                  has_bypass=False, relu=False), prev)
    prev = add("s3b0c2", ConvSpec(**s3, has_bypass=True, relu=True),
               t3c1, bypass_src=t3sk)

    # stage 3: two more basic blocks
    for b in (1, 2):
        c1 = add(f"s3b{b}c1", ConvSpec(**s3, has_bypass=False, relu=True), prev)
        block_in = prev
        prev = add(f"s3b{b}c2", ConvSpec(**s3, has_bypass=True, relu=True),
                   c1, bypass_src=block_in)

    return steps


N_CLASSES = 10
HEAD_IN_CH = 64
HEAD_IN_HW = 8


def binarize(w: np.ndarray) -> np.ndarray:
    """sign(w) with sign(0) := +1 — the paper's BWN weight quantization."""
    return np.where(w >= 0, 1.0, -1.0).astype(np.float32)


def init_params(seed: int = 2018) -> dict:
    """Deterministic synthetic parameters for HyperNet-20.

    Real-valued Gaussian weights are binarized to ±1; the per-channel BWN
    scale α = E|w| (as in BinaryConnect/BWN training) is folded into gamma,
    emulating the paper's merged batch-norm/scale coefficients.
    """
    rng = np.random.default_rng(seed)
    params = {}
    for step in hypernet20_steps():
        s = step.spec
        wr = rng.normal(0.0, 1.0, size=(s.n_out, s.n_in, s.k, s.k))
        alpha = np.abs(wr).reshape(s.n_out, -1).mean(axis=1)
        fan_in = s.n_in * s.k * s.k
        params[step.name] = {
            "w": binarize(wr),
            # α/fan_in keeps activations O(1) through the binarized stack
            "gamma": (alpha / fan_in).astype(np.float32),
            "beta": rng.normal(0.0, 0.02, size=(s.n_out,)).astype(np.float32),
        }
    params["head"] = {
        "w_fc": rng.normal(0.0, 1.0 / np.sqrt(HEAD_IN_CH),
                           size=(N_CLASSES, HEAD_IN_CH)).astype(np.float32),
        "b_fc": np.zeros((N_CLASSES,), dtype=np.float32),
    }
    return params


def make_input(seed: int = 7) -> np.ndarray:
    """Synthetic 16-channel input FM (the off-chip first conv's output)."""
    rng = np.random.default_rng(seed)
    return rng.normal(0.0, 1.0, size=(16, 32, 32)).astype(np.float32)


def forward(params: dict, x, *, use_pallas: bool = True):
    """Golden forward pass over the step list; returns (logits, fm_trace)."""
    steps = hypernet20_steps()
    outs: list = []
    for step in steps:
        p = params[step.name]
        src = x if step.src == -1 else outs[step.src]
        make = make_layer_fn if use_pallas else make_layer_ref_fn
        fn = make(step.spec)
        args = [src, jnp.asarray(p["w"]), jnp.asarray(p["gamma"]),
                jnp.asarray(p["beta"])]
        if step.spec.has_bypass:
            byp = x if step.bypass_src == -1 else outs[step.bypass_src]
            args.append(byp)
        outs.append(fn(*args)[0])
    head = make_head_fn()
    logits = head(outs[-1], jnp.asarray(params["head"]["w_fc"]),
                  jnp.asarray(params["head"]["b_fc"]))[0]
    return logits, outs

"""AOT pipeline checks: HLO text is loadable-format (no 64-bit-id protos)
and the manifest/blob layout is consistent with the model.
"""

import os

import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels.bwn_conv import ConvSpec

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_conv_produces_hlo_text():
    spec = ConvSpec(8, 16, 8, 8, 3, 1, False, True)
    text = aot.lower_conv(spec)
    assert "HloModule" in text
    assert "ROOT" in text
    # return_tuple=True → tuple-shaped root.
    assert "(f32[16,8,8]" in text.replace(" ", "")[:2000] or "tuple" in text


def test_lower_head_shapes():
    text = aot.lower_head()
    assert "HloModule" in text
    assert "f32[10]" in text.replace(" ", "")


def test_manifest_row_format():
    spec = ConvSpec(16, 32, 32, 32, 3, 2, False, True)
    row = aot.conv_manifest_row(M.artifact_name(spec), spec)
    assert row.startswith("artifact name=conv_k3s2_i16o32_h32w32_bp0_relu1")
    assert "k=3 stride=2 n_in=16 n_out=32" in row


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.tsv")),
                    reason="run `make artifacts` first")
class TestGeneratedArtifacts:
    def test_manifest_lists_all_step_artifacts(self):
        with open(os.path.join(ART, "manifest.tsv")) as f:
            text = f.read()
        steps = M.hypernet20_steps()
        for s in steps:
            assert M.artifact_name(s.spec) in text, s.name
        assert "network name=hypernet20 steps=20" in text

    def test_blob_matches_params(self):
        params = M.init_params(seed=2018)
        blob = np.fromfile(os.path.join(ART, "e2e_params.bin"),
                           dtype=np.float32)
        # First blob entry is step 0's weights.
        s0 = M.hypernet20_steps()[0]
        w0 = params[s0.name]["w"].ravel()
        np.testing.assert_array_equal(blob[: w0.size], w0)

    def test_golden_logits_reproducible(self):
        params = M.init_params(seed=2018)
        x = M.make_input()
        import jax.numpy as jnp
        logits, _ = M.forward(params, jnp.asarray(x), use_pallas=True)
        golden = np.fromfile(os.path.join(ART, "e2e_golden.bin"),
                             dtype=np.float32)
        np.testing.assert_allclose(np.asarray(logits), golden,
                                   rtol=1e-5, atol=1e-6)

    def test_every_artifact_file_exists(self):
        from compile.model import hypernet20_steps, artifact_name
        for s in hypernet20_steps():
            path = os.path.join(ART, artifact_name(s.spec) + ".hlo.txt")
            assert os.path.exists(path), path
        assert os.path.exists(os.path.join(ART, "head.hlo.txt"))

"""L1 correctness: the Pallas BWN convolution kernel vs the pure-jnp
oracle — the core correctness signal of the compile path.

Hypothesis sweeps shapes/strides/kernel sizes/dtypes; every case asserts
allclose against ``jax.lax.conv_general_dilated``-based ``ref.py``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.bwn_conv import ConvSpec, bwn_conv, vmem_bytes
from compile.kernels.ref import binarize_ref, bwn_conv_ref


def make_case(rng, spec: ConvSpec, dtype=np.float32):
    x = rng.normal(size=(spec.n_in, spec.h, spec.w)).astype(dtype)
    w = np.where(rng.normal(size=(spec.n_out, spec.n_in, spec.k, spec.k)) >= 0,
                 1.0, -1.0).astype(dtype)
    gamma = (0.25 + rng.random(spec.n_out)).astype(dtype)
    beta = rng.normal(size=spec.n_out).astype(dtype) * 0.1
    byp = (rng.normal(size=(spec.n_out, spec.h_out, spec.w_out)).astype(dtype)
           if spec.has_bypass else None)
    return x, w, gamma, beta, byp


def run_both(spec, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    x, w, gamma, beta, byp = make_case(rng, spec, dtype)
    out = bwn_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gamma),
                   jnp.asarray(beta),
                   jnp.asarray(byp) if byp is not None else None, spec=spec)
    ref = bwn_conv_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gamma),
                       jnp.asarray(beta),
                       jnp.asarray(byp) if byp is not None else None, spec=spec)
    return np.asarray(out), np.asarray(ref)


@st.composite
def conv_specs(draw):
    k = draw(st.sampled_from([1, 3]))
    stride = draw(st.sampled_from([1, 2]))
    cpar = 16
    n_in = draw(st.integers(1, 24))
    n_out = cpar * draw(st.integers(1, 3))
    h = stride * draw(st.integers(max(1, k // 2 + 1), 8))
    w = stride * draw(st.integers(max(1, k // 2 + 1), 8))
    has_bypass = draw(st.booleans())
    relu = draw(st.booleans())
    return ConvSpec(n_in, n_out, h, w, k, stride, has_bypass, relu, cpar)


@settings(max_examples=60, deadline=None)
@given(spec=conv_specs(), seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_oracle_hypothesis(spec, seed):
    out, ref = run_both(spec, seed=seed)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("spec", [
    ConvSpec(16, 16, 32, 32, 3, 1, False, True),   # HyperNet stage-1 conv
    ConvSpec(16, 16, 32, 32, 3, 1, True, True),    # … with bypass
    ConvSpec(16, 32, 32, 32, 3, 2, False, True),   # strided transition
    ConvSpec(16, 32, 32, 32, 1, 2, False, False),  # 1×1 strided shortcut
    ConvSpec(32, 32, 16, 16, 3, 1, True, True),
    ConvSpec(64, 64, 8, 8, 3, 1, True, True),
])
def test_hypernet_layer_shapes(spec):
    out, ref = run_both(spec)
    assert out.shape == (spec.n_out, spec.h_out, spec.w_out)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_float16_feature_maps():
    # The chip stores FP16 FMs; the kernel must also trace in f16.
    spec = ConvSpec(8, 16, 8, 8, 3, 1, False, True)
    out, ref = run_both(spec, dtype=np.float16)
    assert out.dtype == np.float16
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=2e-2, atol=2e-2)


def test_sign_convention_zero_is_positive():
    w = jnp.asarray([-0.0, 0.0, 1e-30, -1e-30])
    b = np.asarray(binarize_ref(w))
    # sign(±0) := +1 — matches rust `bwn::binarize` exactly.
    assert b[0] == 1.0 and b[1] == 1.0 and b[2] == 1.0 and b[3] == -1.0


def test_relu_flag_controls_activation():
    spec_on = ConvSpec(4, 16, 4, 4, 1, 1, False, True)
    spec_off = spec_on._replace(relu=False)
    rng = np.random.default_rng(3)
    x, w, gamma, beta, _ = make_case(rng, spec_off)
    beta = beta - 10.0  # push outputs negative
    on = np.asarray(bwn_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gamma),
                             jnp.asarray(beta), spec=spec_on))
    off = np.asarray(bwn_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gamma),
                              jnp.asarray(beta), spec=spec_off))
    assert (on >= 0).all()
    assert (off < 0).any()
    np.testing.assert_allclose(on, np.maximum(off, 0.0), rtol=1e-5, atol=1e-5)


def test_bypass_added_before_bias_order():
    # §IV-B order: v = γ·conv + bypass + β. Constructed case where a
    # wrong order (bias before scale, etc.) changes the result.
    spec = ConvSpec(1, 16, 2, 2, 1, 1, True, False)
    x = jnp.ones((1, 2, 2), jnp.float32)
    w = jnp.ones((16, 1, 1, 1), jnp.float32)
    gamma = jnp.full((16,), 2.0)
    beta = jnp.full((16,), 3.0)
    byp = jnp.full((16, 2, 2), 5.0)
    out = np.asarray(bwn_conv(x, w, gamma, beta, byp, spec=spec))
    np.testing.assert_allclose(out, 1 * 2 + 5 + 3)


def test_weight_stationarity_grid_matches_cout_tiles():
    # The kernel's grid (weight streaming) must iterate n_out/C tiles.
    spec = ConvSpec(8, 48, 4, 4, 3, 1, False, True)
    assert spec.n_out % spec.cpar == 0
    out, ref = run_both(spec)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_vmem_estimate_within_tpu_budget():
    # Real-TPU mapping check: every HyperNet-20 layer block fits VMEM.
    from compile.model import hypernet20_steps
    for step in hypernet20_steps():
        v = vmem_bytes(step.spec)
        assert v["total"] < 16 * 2**20, f"{step.name}: {v}"


def test_invalid_specs_rejected():
    with pytest.raises(AssertionError):
        bwn_conv(jnp.zeros((4, 4, 4)), jnp.zeros((20, 4, 3, 3)),
                 jnp.zeros(20), jnp.zeros(20),
                 spec=ConvSpec(4, 20, 4, 4, 3, 1, False, True))  # 20 % 16
    with pytest.raises(AssertionError):
        bwn_conv(jnp.zeros((4, 4, 4)), jnp.zeros((16, 4, 5, 5)),
                 jnp.zeros(16), jnp.zeros(16),
                 spec=ConvSpec(4, 16, 4, 4, 5, 1, False, True))  # k = 5

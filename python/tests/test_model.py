"""L2 correctness: the HyperNet-20 model — step-list integrity, shape
chaining, golden forward pass, and pallas-vs-oracle agreement on the
whole network.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels.bwn_conv import ConvSpec


def test_step_list_structure():
    steps = M.hypernet20_steps()
    assert len(steps) == 20
    names = [s.name for s in steps]
    assert len(set(names)) == 20, "step names must be unique"
    # Transitions have 1×1 strided shortcut convs.
    assert "s2b0sk" in names and "s3b0sk" in names
    for s in steps:
        if s.spec.has_bypass:
            assert s.bypass_src != -2
        else:
            assert s.bypass_src == -2


def test_shapes_chain():
    steps = M.hypernet20_steps()
    shapes = {-1: (16, 32, 32)}
    for i, s in enumerate(steps):
        src = shapes[s.src]
        assert src == (s.spec.n_in, s.spec.h, s.spec.w), s.name
        shapes[i] = (s.spec.n_out, s.spec.h_out, s.spec.w_out)
        if s.spec.has_bypass:
            assert shapes[s.bypass_src] == shapes[i], s.name
    assert shapes[len(steps) - 1] == (64, 8, 8)


def test_artifact_names_dedupe_to_ten():
    steps = M.hypernet20_steps()
    names = {M.artifact_name(s.spec) for s in steps}
    assert len(names) == 10


def test_params_deterministic_and_binary():
    p1 = M.init_params(seed=2018)
    p2 = M.init_params(seed=2018)
    for step in M.hypernet20_steps():
        np.testing.assert_array_equal(p1[step.name]["w"], p2[step.name]["w"])
        w = p1[step.name]["w"]
        assert set(np.unique(w)) <= {-1.0, 1.0}
        assert (p1[step.name]["gamma"] > 0).all()


def test_forward_pallas_matches_oracle():
    params = M.init_params(seed=5)
    x = jnp.asarray(M.make_input(seed=9))
    logits_pl, fms_pl = M.forward(params, x, use_pallas=True)
    logits_ref, fms_ref = M.forward(params, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(logits_pl), np.asarray(logits_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fms_pl[-1]), np.asarray(fms_ref[-1]),
                               rtol=1e-4, atol=1e-4)


def test_forward_activations_bounded():
    # The α/fan-in folded scaling keeps the binarized stack numerically
    # tame (no blow-up over 20 layers).
    params = M.init_params(seed=2018)
    x = jnp.asarray(M.make_input(seed=7))
    logits, fms = M.forward(params, x, use_pallas=False)
    for i, fm in enumerate(fms):
        m = float(jnp.abs(fm).max())
        assert m < 100.0, f"step {i} exploded: {m}"
    assert float(jnp.abs(logits).max()) < 50.0


def test_head_is_global_avgpool_plus_fc():
    fn = M.make_head_fn()
    x = jnp.ones((64, 8, 8))
    w = jnp.zeros((10, 64)).at[3, :].set(1.0)
    b = jnp.arange(10.0)
    (out,) = fn(x, w, b)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(b) + np.eye(10)[3] * 64.0)


def test_layer_fn_signature_matches_bypass():
    spec_b = ConvSpec(16, 16, 8, 8, 3, 1, True, True)
    spec_n = ConvSpec(16, 16, 8, 8, 3, 1, False, True)
    import inspect
    assert len(inspect.signature(M.make_layer_fn(spec_b)).parameters) == 5
    assert len(inspect.signature(M.make_layer_fn(spec_n)).parameters) == 4


@pytest.mark.parametrize("seed", [0, 1, 2018])
def test_binarize_is_sign(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=100)
    b = M.binarize(w)
    assert ((w >= 0) == (b == 1.0)).all()

"""Stdlib-only mirrors of the Rust resilience plane (`rust/src/faults/`
and the circuit breaker in `rust/src/engine/service/mod.rs`).

The container has no Rust toolchain, so these tests pin the *algorithms*
independently: the SplitMix64 decision hash (chaos reproducibility rests
on it being stateless and well-mixed), the XOR-fold halo checksum (must
detect every single-bit flip, the fault model injects exactly one), and
the Healthy/Degraded/Open breaker state machine (transition invariants,
not timing). Constants here are transliterated from the Rust source; if
either side changes, these tests disagree with `cargo test` and one of
them is wrong.
"""

MASK = (1 << 64) - 1


def splitmix64(x: int) -> int:
    # Mirror of `faults::splitmix64` (reference SplitMix64 constants).
    x = (x + 0x9E3779B97F4A7C15) & MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & MASK
    return (x ^ (x >> 31)) & MASK


def draw(seed: int, tag: int, seq: int) -> float:
    # Mirror of `faults::draw`: (seed, site tag, seq) -> uniform [0, 1).
    h = splitmix64(seed ^ splitmix64(tag) ^ splitmix64((seq * 0x9E37) & MASK))
    return (h >> 11) / float(1 << 53)


def halo_checksum(bits: int) -> int:
    # Mirror of `faults::halo_checksum`: fold 32 payload bits to a
    # parity byte.
    h = bits ^ (bits >> 16)
    b = h ^ (h >> 8)
    return b & 0xFF


# Site tags as in `FaultKind::tag` ("CHIP", "HALO", "WDG", "DROP", "SLOW").
TAGS = [0x43484950, 0x48414C4F, 0x574447, 0x44524F50, 0x534C4F57]


def test_splitmix64_reference_vector():
    # The canonical SplitMix64 test vector: state 0 emits this sequence
    # (seed 0, then feeding each output back in is NOT the stream —
    # SplitMix increments its state by the golden gamma, which our
    # stateless use reproduces by hashing 0, 1, 2, ... times gamma).
    # Hash of 0 is the first reference output.
    assert splitmix64(0) == 0xE220A8397B1DCDAF
    assert splitmix64(0x9E3779B97F4A7C15) == 0x6E789E6AA1B965F4


def test_draw_is_uniform_enough_and_seed_sensitive():
    n = 4000
    for tag in TAGS:
        hits = sum(1 for s in range(n) if draw(42, tag, s) < 0.25)
        assert 800 <= hits <= 1200, (tag, hits)
    a = [draw(42, TAGS[0], s) for s in range(256)]
    b = [draw(43, TAGS[0], s) for s in range(256)]
    assert a != b
    # Same inputs, same decisions — the reproducibility contract.
    assert a == [draw(42, TAGS[0], s) for s in range(256)]


def test_sites_draw_independently():
    # Identical seed and seq, different site tag -> different pattern,
    # so a chip-death rule cannot shadow a connection-drop rule.
    p = 0.5
    fires = [
        [draw(7, tag, s) < p for s in range(256)]
        for tag in TAGS
    ]
    for i in range(len(fires)):
        for j in range(i + 1, len(fires)):
            assert fires[i] != fires[j], (i, j)


def test_halo_checksum_detects_every_single_bit_flip():
    for bits in (0, 1, 0x3F800000, 0xDEADBEEF, 0xFFFFFFFF):
        base = halo_checksum(bits)
        for flip in range(32):
            assert halo_checksum(bits ^ (1 << flip)) != base, (bits, flip)


class Breaker:
    """Mirror of `update_breaker` + the submit-path half-open probe.

    States: healthy / degraded / open. Time is abstract: `cooled` stands
    in for `breaker_opened_at.elapsed() >= cooldown`.
    """

    def __init__(self, consecutive_failures: int, p99_ms: float):
        self.threshold = consecutive_failures
        self.p99_ms = p99_ms
        self.state = "healthy"
        self.consec = 0
        self.trips = 0

    def record(self, ok: bool, recent_p99: float = 0.0):
        if ok:
            self.consec = 0
            if self.state != "open":
                self.state = "degraded" if recent_p99 > self.p99_ms else "healthy"
        else:
            self.consec += 1
            if self.state != "open" and self.consec >= self.threshold:
                self.state = "open"
                self.trips += 1

    def admit(self, cooled: bool) -> bool:
        # Mirror of the submit() gate: Open sheds until cooled, then
        # admits one half-open probe in Degraded with the failure
        # counter primed one below the trip threshold.
        if self.state != "open":
            return True
        if not cooled:
            return False
        self.state = "degraded"
        self.consec = self.threshold - 1
        return True


def test_breaker_trips_after_consecutive_failures_only():
    b = Breaker(3, float("inf"))
    for _ in range(2):
        b.record(False)
    b.record(True)  # success resets the streak
    for _ in range(2):
        b.record(False)
    assert b.state == "healthy" and b.trips == 0
    b.record(False)
    assert b.state == "open" and b.trips == 1
    # Further failures while open don't re-trip.
    b.record(False)
    assert b.trips == 1


def test_breaker_latency_degrades_but_never_opens():
    b = Breaker(3, 250.0)
    b.record(True, recent_p99=400.0)
    assert b.state == "degraded"
    b.record(True, recent_p99=100.0)
    assert b.state == "healthy"
    assert b.trips == 0


def test_open_breaker_sheds_until_cooldown_then_probes():
    b = Breaker(3, float("inf"))
    for _ in range(3):
        b.record(False)
    assert b.state == "open"
    assert not b.admit(cooled=False)
    assert b.admit(cooled=True)
    assert b.state == "degraded" and b.consec == b.threshold - 1
    # A failed probe re-opens immediately (one more failure reaches
    # the threshold); a successful probe heals.
    b.record(False)
    assert b.state == "open" and b.trips == 2
    assert b.admit(cooled=True)
    b.record(True)
    assert b.state == "healthy" and b.consec == 0

"""Stdlib-only mirror of the Rust streaming-video dirty tracker
(`rust/src/video/dirty.rs`).

The container has no Rust toolchain, so this pins the *algorithm*
independently: ``propagate`` must be exact receptive-field reachability
through a same-padded k×k/stride conv — not a superset, not an
undercount — and ``upsample`` must be exact through the 2× nearest
replication. Both are checked against a brute-force per-output-pixel
tap walk over randomized shapes, tile sizes, and dirty patterns.
Constants (the ``-(k//2)`` tap anchor, ceil-div output dims, same
padding clamped to the FM) are transliterated from the Rust source; if
either side changes, these tests disagree with ``cargo test`` and one
of them is wrong.
"""

import random


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class DirtyMap:
    """Mirror of `video::DirtyMap` (geometry + propagate/upsample)."""

    def __init__(self, h: int, w: int, tile: int):
        assert h > 0 and w > 0 and tile > 0
        self.h, self.w, self.tile = h, w, tile
        self.th, self.tw = ceil_div(h, tile), ceil_div(w, tile)
        self.bits = [[False] * self.tw for _ in range(self.th)]

    def mark(self, ty: int, tx: int):
        self.bits[ty][tx] = True

    def is_dirty(self, ty: int, tx: int) -> bool:
        return self.bits[ty][tx]

    def rect_dirty_incl(self, y0: int, y1: int, x0: int, x1: int) -> bool:
        # Inclusive pixel bounds, like the Rust helper.
        for ty in range(y0 // self.tile, y1 // self.tile + 1):
            for tx in range(x0 // self.tile, x1 // self.tile + 1):
                if self.bits[ty][tx]:
                    return True
        return False

    def propagate(self, h: int, w: int, k: int, stride: int) -> "DirtyMap":
        # Mirror of `DirtyMap::propagate`: the input rows/cols a tile of
        # output pixels can tap form one contiguous rect (same padding,
        # clamped), so a rect-overlap test is exact reachability.
        assert (self.h, self.w) == (h, w)
        ho, wo = ceil_div(h, stride), ceil_div(w, stride)
        dlo = -(k // 2)
        dhi = k - 1 + dlo
        out = DirtyMap(ho, wo, self.tile)

        def span(o0: int, o1: int, dim: int):
            lo = max(o0 * stride + dlo, 0)
            hi = min((o1 - 1) * stride + dhi, dim - 1)
            return lo, hi

        for ty in range(out.th):
            for tx in range(out.tw):
                oy0, oy1 = ty * out.tile, min((ty + 1) * out.tile, ho)
                ox0, ox1 = tx * out.tile, min((tx + 1) * out.tile, wo)
                y0, y1 = span(oy0, oy1, h)
                x0, x1 = span(ox0, ox1, w)
                if self.rect_dirty_incl(y0, y1, x0, x1):
                    out.mark(ty, tx)
        return out

    def upsample(self) -> "DirtyMap":
        out = DirtyMap(self.h * 2, self.w * 2, self.tile)
        for y in range(self.h * 2):
            for x in range(self.w * 2):
                if self.is_dirty((y // 2) // self.tile, (x // 2) // self.tile):
                    out.mark(y // self.tile, x // self.tile)
        return out


def brute_force_propagate(m: DirtyMap, h, w, k, stride) -> DirtyMap:
    # Per-output-pixel tap walk: an output tile is dirty iff any pixel
    # of it has any in-bounds tap in a dirty input tile.
    ho, wo = ceil_div(h, stride), ceil_div(w, stride)
    dlo = -(k // 2)
    out = DirtyMap(ho, wo, m.tile)
    for oy in range(ho):
        for ox in range(wo):
            dirty = False
            for dy in range(k):
                for dx in range(k):
                    iy = oy * stride + dlo + dy
                    ix = ox * stride + dlo + dx
                    if 0 <= iy < h and 0 <= ix < w:
                        dirty |= m.is_dirty(iy // m.tile, ix // m.tile)
            if dirty:
                out.mark(oy // m.tile, ox // m.tile)
    return out


def random_map(h, w, tile, rng) -> DirtyMap:
    m = DirtyMap(h, w, tile)
    for ty in range(m.th):
        for tx in range(m.tw):
            if rng.random() < 0.3:
                m.mark(ty, tx)
    return m


def maps_equal(a: DirtyMap, b: DirtyMap) -> bool:
    return (a.h, a.w, a.tile) == (b.h, b.w, b.tile) and a.bits == b.bits


def test_propagate_is_exact_reachability():
    rng = random.Random(0xD117)
    for _ in range(300):
        h = rng.randrange(4, 17)
        w = rng.randrange(4, 17)
        tile = rng.randrange(1, 5)
        k = rng.choice([1, 3])
        stride = rng.choice([1, 2])
        m = random_map(h, w, tile, rng)
        got = m.propagate(h, w, k, stride)
        want = brute_force_propagate(m, h, w, k, stride)
        assert maps_equal(got, want), (h, w, tile, k, stride)


def test_upsample_is_exact_reachability():
    rng = random.Random(0x0B5)
    for _ in range(100):
        h = rng.randrange(2, 13)
        w = rng.randrange(2, 13)
        tile = rng.randrange(1, 5)
        m = random_map(h, w, tile, rng)
        up = m.upsample()
        # Brute force: out (y, x) reads (y//2, x//2).
        for y in range(h * 2):
            for x in range(w * 2):
                src_dirty = m.is_dirty((y // 2) // tile, (x // 2) // tile)
                if src_dirty:
                    assert up.is_dirty(y // tile, x // tile)
        # And no spurious dirt: every dirty output tile contains at
        # least one pixel whose source pixel's tile is dirty.
        for ty in range(up.th):
            for tx in range(up.tw):
                if not up.is_dirty(ty, tx):
                    continue
                reachable = any(
                    m.is_dirty((y // 2) // tile, (x // 2) // tile)
                    for y in range(ty * tile, min((ty + 1) * tile, h * 2))
                    for x in range(tx * tile, min((tx + 1) * tile, w * 2))
                )
                assert reachable, (h, w, tile, ty, tx)


def test_clean_input_stays_clean_and_full_stays_full():
    for h, w, tile, k, stride in [(8, 8, 2, 3, 1), (12, 10, 4, 3, 2), (9, 7, 3, 1, 1)]:
        clean = DirtyMap(h, w, tile)
        out = clean.propagate(h, w, k, stride)
        assert not any(any(row) for row in out.bits)
        full = DirtyMap(h, w, tile)
        for ty in range(full.th):
            for tx in range(full.tw):
                full.mark(ty, tx)
        out = full.propagate(h, w, k, stride)
        assert all(all(row) for row in out.bits)

//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. bypass fusion (§IV-B's "+50% memory avoided"),
//! 2. depth-wise FMM-bank serialization (ShuffleNet utilization),
//! 3. FM precision (FP16 → Q12/Q8, the paper's §VI-D projection),
//! 4. projection vs identity shortcuts (Tbl II weight accounting),
//! 5. aspect-matched vs minimal mesh planning.

mod bench_util;

use hyperdrive::coordinator::schedule::{schedule_network, DepthwisePolicy};
use hyperdrive::coordinator::tiling::{plan_mesh, plan_mesh_exact};
use hyperdrive::coordinator::wcl;
use hyperdrive::energy::ablation::{precision_ablation, render};
use hyperdrive::model;
use hyperdrive::util::fmt_bits;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();

    // 1. Bypass fusion ablation.
    println!("== ablation 1: on-the-fly bypass accumulation (§IV-B) ==");
    for net in [model::network("resnet34@224x224").unwrap(), model::network("resnet50@224x224").unwrap()] {
        let fused = wcl::analyze(&net).wcl_words;
        let unfused = wcl::analyze_with(&net, false).wcl_words;
        println!(
            "{:<12} WCL fused {} vs unfused {} ({:+.0}% without fusion)",
            net.name,
            fmt_bits(fused * 16),
            fmt_bits(unfused * 16),
            100.0 * (unfused as f64 / fused as f64 - 1.0)
        );
    }

    // 2. Depth-wise policy ablation.
    println!("\n== ablation 2: depth-wise bank serialization (ShuffleNet) ==");
    let net = model::network("shufflenet@224x224").unwrap();
    for (name, dw) in [
        ("full-rate", DepthwisePolicy::FullRate),
        ("bank-serialized", DepthwisePolicy::BankSerialized),
    ] {
        let s = schedule_network(&net, &cfg, dw);
        println!(
            "{:<16} cycles {:>8}  util {:>5.1}%  conv-util {:>5.1}%",
            name,
            s.total_cycles(),
            100.0 * s.utilization(&cfg),
            100.0 * s.conv_utilization(&cfg)
        );
    }

    // 3. Precision ablation.
    println!("\n== ablation 3: FM precision (§VI-D projection) ==");
    for net in [model::network("resnet34@224x224").unwrap(), model::network("resnet34@1024x2048").unwrap()] {
        let rows = precision_ablation(&net, &cfg);
        println!("{}", render(&net.name, &rows));
    }

    // 4. Shortcut kind (weight accounting).
    println!("== ablation 4: projection vs identity shortcuts ==");
    for net in [model::network("resnet34@224x224").unwrap(), model::network("resnet50@224x224").unwrap(), model::network("resnet152@224x224").unwrap()] {
        let proj = model::projection_weight_bits(&net);
        println!(
            "{:<12} weights {} with projections, {} identity-only",
            net.name,
            fmt_bits(net.weight_bits()),
            fmt_bits(net.weight_bits() - proj)
        );
    }

    // 5. Mesh planning policy.
    println!("\n== ablation 5: mesh planning (ResNet-34 @2048x1024) ==");
    let net2k = model::network("resnet34@1024x2048").unwrap();
    let auto = plan_mesh(&net2k, &cfg);
    let paper = plan_mesh_exact(&net2k, &cfg, 5, 10);
    for (name, p) in [("aspect-matched", auto), ("paper 10x5", paper)] {
        println!(
            "{:<16} {}x{} = {} chips, per-chip WCL {} words",
            name,
            p.rows,
            p.cols,
            p.chips(),
            p.per_chip_wcl_words
        );
    }

    // Timing anchor for the whole ablation suite.
    bench_util::bench("full ablation suite", 1, 10, || {
        let rows = precision_ablation(&model::network("resnet34@224x224").unwrap(), &cfg);
        assert_eq!(rows.len(), 3);
    });
}

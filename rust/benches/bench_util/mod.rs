//! Tiny timing harness shared by the paper-table benches (the vendored
//! crate set has no criterion; `harness = false` benches time with
//! `std::time::Instant`).

use std::time::Instant;

/// Time `f` over `iters` iterations after `warmup` runs; prints
/// mean/min per-iteration time and returns the mean seconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    println!("bench {name:<40} mean {:>10.3} µs   min {:>10.3} µs   ({iters} iters)",
             mean * 1e6, min * 1e6);
    mean
}

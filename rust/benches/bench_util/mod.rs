//! Tiny timing harness shared by the paper-table benches (the vendored
//! crate set has no criterion; `harness = false` benches time with
//! `std::time::Instant`).

use std::time::Instant;

/// Result of one timed benchmark run.
#[allow(dead_code)] // not every bench consumes the full record
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
    pub iters: usize,
}

/// Time `f` over `iters` iterations after `warmup` runs; prints
/// mean/min per-iteration time and returns the full statistics.
#[allow(dead_code)] // each harness=false bench compiles its own copy
pub fn bench_stats<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    println!("bench {name:<40} mean {:>10.3} µs   min {:>10.3} µs   ({iters} iters)",
             mean * 1e6, min * 1e6);
    BenchStats {
        name: name.to_string(),
        mean_s: mean,
        min_s: min,
        iters,
    }
}

/// [`bench_stats`], returning only the mean seconds (the original API).
#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> f64 {
    bench_stats(name, warmup, iters, f).mean_s
}

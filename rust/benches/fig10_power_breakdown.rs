//! Fig 10 — component power/energy breakdown at the 0.5 V point, from
//! schedule-derived access counts × per-access energies.

mod bench_util;

use hyperdrive::coordinator::tiling::MeshPlan;
use hyperdrive::energy::breakdown::breakdown;
use hyperdrive::model;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::fig10(&cfg));
    let net = model::network("resnet34@224x224").unwrap();
    let plan = MeshPlan { rows: 1, cols: 1, per_chip_wcl_words: 0 };
    bench_util::bench("breakdown(ResNet-34)", 3, 200, || {
        let b = breakdown(&net, &cfg, &plan);
        assert!(b.total_j() > 0.0);
    });
}

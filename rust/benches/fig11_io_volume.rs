//! Fig 11 — I/O bits: weight-stationary (FM streaming) vs Hyperdrive
//! (weight streaming + border exchange) across image sizes and mesh
//! tilings.

mod bench_util;

use hyperdrive::baselines::weight_stationary::hyperdrive_fig11_bits;
use hyperdrive::baselines::weight_stationary_io_bits;
use hyperdrive::coordinator::tiling::plan_mesh;
use hyperdrive::model;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::fig11(&cfg));
    bench_util::bench("fig11 point (build + plan + both I/O models)", 2, 50, || {
        let net = model::network("resnet34@448x448").unwrap();
        let plan = plan_mesh(&net, &cfg);
        let ws = weight_stationary_io_bits(&net, 16);
        let hd = hyperdrive_fig11_bits(&net, &plan, 16);
        assert!(ws > hd);
    });
}

//! Fig 8 — energy efficiency vs throughput across forward-body-bias
//! settings (ResNet-34, incl. I/O).

mod bench_util;

use hyperdrive::energy::scaling;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::fig8(&cfg));
    bench_util::bench("vdd_for_freq bisection ×100", 3, 200, || {
        for i in 0..100 {
            let f = 60e6 + i as f64 * 1e6;
            let _ = scaling::vdd_for_freq(f, 1.5);
        }
    });
}

//! Fig 9 — energy efficiency and throughput vs VDD.

mod bench_util;

use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::fig9(&cfg));
    bench_util::bench("fig9 series generation", 3, 200, || {
        let s = report::fig9(&cfg);
        assert!(!s.is_empty());
    });
}

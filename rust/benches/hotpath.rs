//! Hot-path micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! the functional simulator's conv inner loop, FP16 rounding, weight
//! packing/unpacking, the mesh exchange, the engine serving layer, and
//! the memory planner.

mod bench_util;

use hyperdrive::bwn::pack_weights;
use hyperdrive::coordinator::memory;
use hyperdrive::engine::{Engine, ServeOptions};
use hyperdrive::model;
use hyperdrive::network::ConvLayer;
use hyperdrive::simulator::mesh::{MeshSim, StepParams};
use hyperdrive::simulator::{self, FeatureMap, Precision};
use hyperdrive::util::f16::round_f16;
use hyperdrive::util::SplitMix64;

fn main() {
    let mut rng = SplitMix64::new(1);

    // FP16 rounding primitive (inner-inner loop of the F16 datapath).
    let xs: Vec<f32> = (0..4096).map(|_| rng.next_gauss()).collect();
    bench_util::bench("round_f16 ×4096", 10, 2000, || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += round_f16(x);
        }
        std::hint::black_box(acc);
    });

    // Functional chip simulator, one mid-size layer, both precisions.
    let l = ConvLayer::new("hot", 64, 64, 28, 28, 3, 1);
    let w: Vec<f32> = (0..64 * 64 * 9).map(|_| rng.next_sym()).collect();
    let stream = pack_weights(&l, &w, 16);
    let gamma = vec![0.01f32; 64];
    let beta = vec![0.0f32; 64];
    let input = FeatureMap::from_vec(64, 28, 28, (0..64 * 784).map(|_| rng.next_sym()).collect());
    let params = simulator::chip::LayerParams {
        layer: &l,
        stream: &stream,
        gamma: &gamma,
        beta: &beta,
    };
    for (name, prec) in [("F32", Precision::F32), ("F16", Precision::F16)] {
        bench_util::bench(
            &format!("chip sim conv 64×64×28² 3×3 ({name})"),
            2,
            20,
            || {
                let (out, _) = simulator::run_layer(&params, &input, None, prec, (7, 7));
                std::hint::black_box(out.data[0]);
            },
        );
    }

    // Weight packing + unpacking (the stream on/off-pin path).
    bench_util::bench("pack_weights 64×64×3×3", 5, 200, || {
        let s = pack_weights(&l, &w, 16);
        std::hint::black_box(s.words.len());
    });
    bench_util::bench("unpack_dense 64×64×3×3", 5, 200, || {
        let d = stream.unpack_dense();
        std::hint::black_box(d.len());
    });

    // Mesh run (whole HyperNet-20 on 2×2, FP16) — exchange included.
    let net = model::network("hypernet20").unwrap();
    let sparams: Vec<StepParams> = net
        .steps
        .iter()
        .map(|s| {
            let l = &s.layer;
            let nie = l.n_in / l.groups;
            let w: Vec<f32> = (0..l.n_out * nie * l.k * l.k).map(|_| rng.next_sym()).collect();
            StepParams {
                stream: pack_weights(l, &w, 16),
                gamma: vec![0.01; l.n_out],
                beta: vec![0.0; l.n_out],
            }
        })
        .collect();
    let inp = FeatureMap::from_vec(16, 32, 32, (0..16 * 1024).map(|_| rng.next_sym()).collect());
    bench_util::bench("mesh 2×2 HyperNet-20 (F16, full run)", 1, 5, || {
        let sim = MeshSim::new(2, 2, Precision::F16);
        let (out, _) = sim.run_network(&net, &sparams, &inp);
        std::hint::black_box(out.data[0]);
    });

    // Engine serving layer: bounded queue + worker pool over the
    // functional backend (1 vs 4 workers shows the concurrency win).
    let engine = Engine::builder()
        .network(model::network("hypernet20").unwrap())
        .seed(7)
        .precision(Precision::F16)
        .build()
        .unwrap();
    let batch: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..engine.input_len()).map(|_| rng.next_sym()).collect())
        .collect();
    for workers in [1usize, 4] {
        bench_util::bench(
            &format!("engine serve HyperNet-20 ×4 ({workers} workers)"),
            1,
            3,
            || {
                let opts = ServeOptions { workers, ..ServeOptions::default() };
                let (outs, _) = engine.serve(&batch, &opts).unwrap();
                std::hint::black_box(outs.len());
            },
        );
    }

    // Memory planner on the deepest network.
    let deep = model::network("resnet152@224x224").unwrap();
    bench_util::bench("memory::plan_tight(ResNet-152)", 2, 50, || {
        let p = memory::plan_tight(&deep).unwrap();
        std::hint::black_box(p.peak_words);
    });
}

//! Hot-path micro-benchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! the shared Tile-PU datapath kernel (single-thread and fanned out
//! over the thread knob), FP16 rounding, weight packing/unpacking, the
//! mesh exchange, the engine serving layer, and the memory planner.
//!
//! Besides the printed table, the run emits a machine-readable
//! `BENCH_hotpath.json` (per-entry wall time, MACs/s where the entry is
//! a conv workload, the thread count, a host fingerprint, and the
//! benched conv's packed resident weight bytes) so the
//! perf trajectory is tracked across PRs instead of only printed. The
//! conv workload is additionally timed on the *pre-optimization*
//! kernel (`testkit::reference_run_tile` — the "… reference kernel"
//! entries), giving every run a live, machine-local baseline;
//! `scripts/bench_diff.py` gates the optimized kernel's speedup and
//! diffs against the committed `benches/BENCH_hotpath.baseline.json`.
//! `HOTPATH_TINY=1` runs a reduced spec (CI smoke: the JSON contract
//! and the gates, not publication numbers).

mod bench_util;

use bench_util::BenchStats;
use hyperdrive::bwn::pack_weights;
use hyperdrive::coordinator::memory;
use hyperdrive::engine::{Engine, ServeOptions};
use hyperdrive::model;
use hyperdrive::network::ConvLayer;
use hyperdrive::simulator::datapath::{resolve_threads, TileGeom};
use hyperdrive::simulator::mesh::{MeshSim, StepParams};
use hyperdrive::simulator::{self, FeatureMap, Precision};
use hyperdrive::testkit::reference_run_tile;
use hyperdrive::util::f16::round_f16;
use hyperdrive::util::SplitMix64;

/// One JSON record: timing plus the conv rate where it applies.
struct Entry {
    stats: BenchStats,
    macs_per_s: Option<f64>,
}

fn record(entries: &mut Vec<Entry>, stats: BenchStats, macs_per_iter: Option<f64>) {
    let macs_per_s = macs_per_iter.map(|m| m / stats.mean_s);
    entries.push(Entry { stats, macs_per_s });
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Stable-ish machine fingerprint: `scripts/bench_diff.py` only
/// compares absolute times between runs that report the same host.
/// Without `/proc/cpuinfo` (macOS/Windows) the fallback is only
/// `os arch xN` — coarse enough that two different CPUs can collide,
/// which is why the speedup gate (not the absolute diff) is the
/// machine-independent check.
fn host_fingerprint(threads: usize) -> String {
    let cpu = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|v| v.trim().to_string())
        })
        .unwrap_or_else(|| std::env::consts::ARCH.to_string());
    format!("{} {cpu} x{threads}", std::env::consts::OS)
}

fn write_json(
    path: &str,
    threads: usize,
    tiny: bool,
    host: &str,
    packed_weight_bytes: u64,
    entries: &[Entry],
) {
    let mut body = String::new();
    body.push_str(&format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"threads\": {threads},\n  \"tiny\": {tiny},\n  \"host\": \"{}\",\n  \"packed_weight_bytes\": {packed_weight_bytes},\n  \"entries\": [\n",
        json_escape(host)
    ));
    for (i, e) in entries.iter().enumerate() {
        let macs = match e.macs_per_s {
            Some(r) => format!("{r:.3e}"),
            None => "null".to_string(),
        };
        body.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_s\": {:.9}, \"min_s\": {:.9}, \"iters\": {}, \"macs_per_s\": {}}}{}\n",
            json_escape(&e.stats.name),
            e.stats.mean_s,
            e.stats.min_s,
            e.stats.iters,
            macs,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n}\n");
    match std::fs::write(path, &body) {
        Ok(()) => println!("wrote {path} ({} entries)", entries.len()),
        Err(e) => {
            eprintln!("error: could not write {path}: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let tiny = std::env::var_os("HOTPATH_TINY").is_some();
    let threads = resolve_threads(0);
    // Tiny mode: same coverage, small iteration counts and a small conv.
    let it = |n: usize| if tiny { 1.max(n / 10) } else { n };
    let mut rng = SplitMix64::new(1);
    let mut entries: Vec<Entry> = Vec::new();

    // FP16 rounding primitive (inner-inner loop of the F16 datapath).
    let xs: Vec<f32> = (0..4096).map(|_| rng.next_gauss()).collect();
    let s = bench_util::bench_stats("round_f16 ×4096", if tiny { 1 } else { 10 }, it(2000), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += round_f16(x);
        }
        std::hint::black_box(acc);
    });
    record(&mut entries, s, None);

    // The shared datapath kernel, one mid-size layer, both precisions,
    // then the thread fan-out at the resolved knob.
    let (ch, hw) = if tiny { (16usize, 14usize) } else { (64, 28) };
    let l = ConvLayer::new("hot", ch, ch, hw, hw, 3, 1);
    let w: Vec<f32> = (0..ch * ch * 9).map(|_| rng.next_sym()).collect();
    let stream = pack_weights(&l, &w, 16);
    let gamma = vec![0.01f32; ch];
    let beta = vec![0.0f32; ch];
    let input = FeatureMap::from_vec(
        ch,
        hw,
        hw,
        (0..ch * hw * hw).map(|_| rng.next_sym()).collect(),
    );
    let params = simulator::chip::LayerParams {
        layer: &l,
        stream: &stream,
        gamma: &gamma,
        beta: &beta,
    };
    let layer_macs = l.macs() as f64;
    // These two entries (and their reference-kernel twins below) feed
    // the speedup gate in scripts/bench_diff.py, so even tiny mode
    // warms up once and runs enough iterations for a stable min.
    for (name, prec) in [("F32", Precision::F32), ("F16", Precision::F16)] {
        let s = bench_util::bench_stats(
            &format!("chip sim conv {ch}×{ch}×{hw}² 3×3 ({name}, 1 thread)"),
            if tiny { 1 } else { 2 },
            it(20).max(5),
            || {
                let (out, _) = simulator::run_layer(&params, &input, None, prec, (7, 7));
                std::hint::black_box(out.data[0]);
            },
        );
        record(&mut entries, s, Some(layer_macs));
    }
    let s = bench_util::bench_stats(
        &format!("chip sim conv {ch}×{ch}×{hw}² 3×3 (F16, {threads} threads)"),
        if tiny { 0 } else { 2 },
        it(20),
        || {
            let (out, _) = simulator::run_layer_threads(
                &params,
                &input,
                None,
                Precision::F16,
                (7, 7),
                threads,
            );
            std::hint::black_box(out.data[0]);
        },
    );
    record(&mut entries, s, Some(layer_macs));

    // The pre-optimization per-element kernel (preserved in testkit as
    // the correctness oracle), timed on the same conv: the *live*
    // baseline. scripts/bench_diff.py gates the fast kernel's speedup
    // against these entries on every run, machine-independently.
    let geom = TileGeom {
        oy0: 0,
        oy1: hw,
        ox0: 0,
        ox1: hw,
        iy0: 0,
        ix0: 0,
        tile_h: hw.div_ceil(7).max(1),
        tile_w: hw.div_ceil(7).max(1),
        in_tile_h: hw.div_ceil(7).max(1),
        in_tile_w: hw.div_ceil(7).max(1),
    };
    let mut ref_out = vec![0.0f32; ch * hw * hw];
    for (name, prec) in [("F32", Precision::F32), ("F16", Precision::F16)] {
        let s = bench_util::bench_stats(
            &format!("chip sim conv {ch}×{ch}×{hw}² 3×3 ({name}, 1 thread, reference kernel)"),
            if tiny { 1 } else { 2 },
            it(20).max(5),
            || {
                let mut write = |co: usize, oy: usize, ox: usize, v: f32| {
                    ref_out[(co * hw + oy) * hw + ox] = v;
                };
                let acc = reference_run_tile(
                    &l,
                    &stream,
                    &gamma,
                    &beta,
                    (0, ch),
                    &input,
                    None::<&FeatureMap>,
                    prec,
                    &geom,
                    &mut write,
                );
                std::hint::black_box(acc.accumulates);
                // Keep the accumulate chain observable, like the
                // optimized twin's black_box(out.data[0]) — otherwise
                // the dead stores to ref_out could be elided and the
                // live baseline corrupted.
                std::hint::black_box(ref_out[0]);
            },
        );
        record(&mut entries, s, Some(layer_macs));
    }

    // Weight packing + unpacking (the stream on/off-pin path).
    let s = bench_util::bench_stats(
        &format!("pack_weights {ch}×{ch}×3×3"),
        if tiny { 0 } else { 5 },
        it(200),
        || {
            let s = pack_weights(&l, &w, 16);
            std::hint::black_box(s.word_count());
        },
    );
    record(&mut entries, s, None);
    let s = bench_util::bench_stats(
        &format!("unpack_dense {ch}×{ch}×3×3"),
        if tiny { 0 } else { 5 },
        it(200),
        || {
            let d = stream.unpack_dense();
            std::hint::black_box(d.len());
        },
    );
    record(&mut entries, s, None);

    // Mesh run (whole HyperNet-20 on 2×2, FP16) — exchange included —
    // single-thread vs the chip fan-out.
    let net = model::network("hypernet20").unwrap();
    let net_macs = (net.conv_ops() / 2) as f64;
    let sparams: Vec<StepParams> = net
        .steps
        .iter()
        .map(|s| {
            let l = &s.layer;
            let nie = l.n_in / l.groups;
            let w: Vec<f32> = (0..l.n_out * nie * l.k * l.k).map(|_| rng.next_sym()).collect();
            StepParams {
                stream: pack_weights(l, &w, 16),
                gamma: vec![0.01; l.n_out],
                beta: vec![0.0; l.n_out],
            }
        })
        .collect();
    let inp = FeatureMap::from_vec(16, 32, 32, (0..16 * 1024).map(|_| rng.next_sym()).collect());
    for t in [1usize, threads] {
        let s = bench_util::bench_stats(
            &format!("mesh 2×2 HyperNet-20 (F16, full run, {t} threads)"),
            if tiny { 0 } else { 1 },
            it(10).max(2),
            || {
                let mut sim = MeshSim::new(2, 2, Precision::F16);
                sim.threads = t;
                let (out, _) = sim.run_network(&net, &sparams, &inp).unwrap();
                std::hint::black_box(out.data[0]);
            },
        );
        record(&mut entries, s, Some(net_macs));
        if threads == 1 {
            break; // avoid duplicating the identical entry
        }
    }

    // Engine serving layer: bounded queue + worker pool over the
    // functional backend (1 vs 4 workers shows the concurrency win).
    let engine = Engine::builder()
        .network(model::network("hypernet20").unwrap())
        .seed(7)
        .precision(Precision::F16)
        .threads(threads)
        .build()
        .unwrap();
    let batch: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..engine.input_len()).map(|_| rng.next_sym()).collect())
        .collect();
    for workers in [1usize, 4] {
        let s = bench_util::bench_stats(
            &format!("engine serve HyperNet-20 ×4 ({workers} workers)"),
            if tiny { 0 } else { 1 },
            it(10).max(2),
            || {
                let opts = ServeOptions { workers, ..ServeOptions::default() };
                let outcome = engine.serve(&batch, &opts).unwrap();
                assert_eq!(outcome.failed(), 0);
                std::hint::black_box(outcome.results.len());
            },
        );
        record(&mut entries, s, Some(4.0 * net_macs));
    }

    // Memory planner on the deepest network.
    let deep = model::network("resnet152@224x224").unwrap();
    let s = bench_util::bench_stats(
        "memory::plan_tight(ResNet-152)",
        if tiny { 0 } else { 2 },
        it(50),
        || {
            let p = memory::plan_tight(&deep).unwrap();
            std::hint::black_box(p.peak_words);
        },
    );
    record(&mut entries, s, None);

    write_json(
        "BENCH_hotpath.json",
        threads,
        tiny,
        &host_fingerprint(threads),
        // True resident footprint of the benched conv's weight stream
        // (u64 bitplanes, 1 bit/weight) — `bench-smoke` asserts it.
        stream.packed_bytes(),
        &entries,
    );
}

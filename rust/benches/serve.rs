//! Serving-layer benchmark: a multi-model `InferenceService` driven by
//! an interleaved synthetic workload at several worker counts, emitting
//! `BENCH_serve.json` (throughput + worst-model p99 per worker count)
//! so the serving scalability trajectory is tracked across PRs like the
//! kernel numbers in `BENCH_hotpath.json`. A second section
//! (`batch_entries`) sweeps the micro-batch curve B ∈ {1, 2, 4, 8}:
//! batched vs sequential throughput plus the weight-stream traffic,
//! whose ratio must fall as ~1/B (gated by `scripts/bench_diff.py
//! --serve`).
//!
//!     cargo bench --bench serve
//!
//! A third section (`sweep`) scales workers 1→16 under pipelined
//! concurrency (C connections × K in-flight each) over two transports —
//! in-process ticket windows vs loopback TCP through the wire protocol
//! — recording req/s and p50/p99 per point, so the wire frontend's
//! overhead and the sharded core's scaling are both on the record
//! (`bench_diff.py --serve` validates the section and gates p99
//! blow-ups).
//!
//! `SERVE_TINY=1` (or `HOTPATH_TINY=1`, so CI smoke jobs set one knob)
//! runs a reduced request count — the JSON contract, not publication
//! numbers. The CI `bench-smoke` job validates the emitted file.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use hyperdrive::engine::{
    percentile, run_loadgen, Engine, InferRequest, InferenceService, LoadGenConfig, RetryPolicy, Ticket,
    WireServer,
};
use hyperdrive::util::SplitMix64;
use hyperdrive::video::SynthVideo;

const MODELS: [&str; 2] = ["hypernet20", "resnet18@32x32"];

struct Row {
    workers: usize,
    ok: usize,
    failed: usize,
    total_s: f64,
    req_per_s: f64,
    p99_ms: f64,
}

fn run(workers: usize, requests: usize) -> Row {
    let mut builder = InferenceService::builder().workers(workers).queue_depth(8);
    for model in MODELS {
        builder = builder.model_spec(model);
    }
    let service = builder.build().expect("service build");
    let mut rng = SplitMix64::new(42);
    // Pre-generate the workload so input synthesis is not timed.
    let workload: Vec<(String, Vec<f32>)> = (0..requests)
        .map(|i| {
            let model = MODELS[i % MODELS.len()];
            let len = service.input_len(model).expect("hosted model");
            (model.to_string(), (0..len).map(|_| rng.next_sym()).collect())
        })
        .collect();

    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = workload
        .into_iter()
        .enumerate()
        .map(|(i, (model, input))| {
            service
                .submit(InferRequest {
                    model,
                    input: input.into(),
                    id: i as u64,
                    deadline_ms: None,
                })
                .expect("admission (Block policy) cannot fail here")
        })
        .collect();
    let (mut ok, mut failed) = (0usize, 0usize);
    for ticket in tickets {
        match ticket.wait() {
            Ok(_) => ok += 1,
            Err(_) => failed += 1,
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    let metrics = service.shutdown();
    let p99_ms = metrics
        .per_model
        .iter()
        .map(|m| m.p99_ms)
        .fold(0.0f64, f64::max);
    Row {
        workers,
        ok,
        failed,
        total_s,
        req_per_s: if total_s > 0.0 { ok as f64 / total_s } else { 0.0 },
        p99_ms,
    }
}

struct SweepRow {
    workers: usize,
    transport: &'static str,
    connections: usize,
    in_flight: usize,
    requests: usize,
    ok: u64,
    failed: u64,
    rejected: u64,
    total_s: f64,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn sweep_service(workers: usize, depth: usize) -> InferenceService {
    let mut builder = InferenceService::builder().workers(workers).queue_depth(depth);
    for model in MODELS {
        builder = builder.model_spec(model);
    }
    builder.build().expect("service build")
}

/// One in-process sweep point: C driver threads each keep a K-deep
/// window of tickets outstanding — the same pipelining shape the TCP
/// load generator produces, minus the sockets, so the delta between
/// the two transports is the wire overhead alone.
fn run_sweep_inproc(workers: usize, conns: usize, in_flight: usize, requests: usize) -> SweepRow {
    let service = Arc::new(sweep_service(workers, conns * in_flight));
    let per = requests / conns;
    let rem = requests % conns;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..conns)
        .map(|c| {
            let service = service.clone();
            let quota = per + usize::from(c < rem);
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(42 ^ (c as u64).wrapping_mul(0x9e37_79b9));
                let payloads: Vec<(String, Arc<[f32]>)> = MODELS
                    .iter()
                    .map(|m| {
                        let len = service.input_len(m).expect("hosted model");
                        let data: Vec<f32> = (0..len).map(|_| rng.next_sym()).collect();
                        (m.to_string(), data.into())
                    })
                    .collect();
                let mut window: VecDeque<(Ticket, Instant)> = VecDeque::new();
                let mut lat = Vec::with_capacity(quota);
                let (mut ok, mut failed) = (0u64, 0u64);
                let mut sent = 0usize;
                while (ok + failed) < quota as u64 {
                    while sent < quota && window.len() < in_flight {
                        let (model, input) = &payloads[sent % payloads.len()];
                        let ticket = service
                            .submit(InferRequest {
                                model: model.clone(),
                                input: input.clone(),
                                id: sent as u64,
                                deadline_ms: None,
                            })
                            .expect("Block admission cannot fail here");
                        window.push_back((ticket, Instant::now()));
                        sent += 1;
                    }
                    let (ticket, sent_at) = window.pop_front().expect("window is non-empty");
                    match ticket.wait() {
                        Ok(_) => {
                            ok += 1;
                            lat.push(sent_at.elapsed().as_secs_f64() * 1e3);
                        }
                        Err(_) => failed += 1,
                    }
                }
                (ok, failed, lat)
            })
        })
        .collect();
    let (mut ok, mut failed) = (0u64, 0u64);
    let mut latencies = Vec::new();
    for h in handles {
        let (o, f, l) = h.join().expect("driver thread");
        ok += o;
        failed += f;
        latencies.extend(l);
    }
    let total_s = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    Arc::try_unwrap(service)
        .unwrap_or_else(|_| panic!("driver threads joined; last Arc"))
        .shutdown();
    SweepRow {
        workers,
        transport: "in-process",
        connections: conns,
        in_flight,
        requests,
        ok,
        failed,
        rejected: 0,
        total_s,
        req_per_s: if total_s > 0.0 { ok as f64 / total_s } else { 0.0 },
        p50_ms: percentile(&latencies, 0.50).unwrap_or(0.0),
        p99_ms: percentile(&latencies, 0.99).unwrap_or(0.0),
    }
}

/// One loopback-TCP sweep point: a real `WireServer` on 127.0.0.1
/// driven by the same load generator the `loadgen` CLI uses.
fn run_sweep_tcp(workers: usize, conns: usize, in_flight: usize, requests: usize) -> SweepRow {
    let service = Arc::new(sweep_service(workers, conns * in_flight));
    let server = WireServer::start(service.clone(), "127.0.0.1:0").expect("bind loopback");
    let report = run_loadgen(&LoadGenConfig {
        addr: server.local_addr().to_string(),
        connections: conns,
        in_flight,
        requests,
        models: MODELS.iter().map(|m| m.to_string()).collect(),
        seed: 42,
        retry: RetryPolicy::default(),
        deadline_ms: None,
        chaos: None,
        video: None,
        video_delta: 0.0,
    })
    .expect("loadgen run");
    assert_eq!(report.transport_errors, 0, "loopback connections died");
    server.shutdown();
    Arc::try_unwrap(service)
        .unwrap_or_else(|_| panic!("server joined; last Arc"))
        .shutdown();
    SweepRow {
        workers,
        transport: "tcp",
        connections: conns,
        in_flight,
        requests,
        ok: report.ok,
        failed: report.failed,
        rejected: report.rejected_backpressure,
        total_s: report.total_s,
        req_per_s: report.req_per_s,
        p50_ms: report.p50_ms,
        p99_ms: report.p99_ms,
    }
}

struct BatchRow {
    model: &'static str,
    batch: usize,
    stream_words: u64,
    stream_words_seq: u64,
    seq_s: f64,
    batch_s: f64,
}

/// The micro-batch curve for one model: B images through one
/// `Engine::infer_batch` pass vs B sequential `Engine::infer` calls,
/// with the batch's analytic weight-stream counters.
fn run_batch_curve(model: &'static str, batches: &[usize]) -> Vec<BatchRow> {
    let engine = Engine::builder().model(model).build().expect("engine build");
    let mut rng = SplitMix64::new(7);
    let mut rows = Vec::new();
    for &b in batches {
        let inputs: Vec<Vec<f32>> = (0..b)
            .map(|_| (0..engine.input_len()).map(|_| rng.next_sym()).collect())
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|x| x.as_slice()).collect();
        let t0 = std::time::Instant::now();
        for x in &refs {
            engine.infer(x).expect("sequential inference");
        }
        let seq_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let run = engine.infer_batch(&refs);
        let batch_s = t0.elapsed().as_secs_f64();
        assert!(
            run.outputs.iter().all(|r| r.is_ok()),
            "batch inference failed"
        );
        rows.push(BatchRow {
            model,
            batch: b,
            stream_words: run.stream_words,
            stream_words_seq: run.sequential_stream_words,
            seq_s,
            batch_s,
        });
    }
    rows
}

struct VideoRow {
    model: &'static str,
    delta: f64,
    frames: usize,
    mac_dirty_fraction: f64,
    saved_mac_ratio: f64,
    fps: f64,
    bit_exact: bool,
}

/// The streaming-video curve for one model: a seeded synthetic clip
/// through a `FrameSession` per delta point. Saved-MAC ratio must equal
/// 1 − the MAC-weighted dirty fraction analytically (clean tiles are
/// spliced, dirty tiles recomputed — there is no third bucket), which
/// `bench_diff.py --serve` gates, alongside monotonicity over delta.
/// Frame 0 primes the session (fully dirty by construction) and is
/// excluded from the savings aggregate; the fps clock covers only the
/// session frames, with the full-recompute bit-exactness audit after.
fn run_video_curve(model: &'static str, deltas: &[f64], frames: usize) -> Vec<VideoRow> {
    let engine = Engine::builder().model(model).build().expect("engine build");
    let net = engine.network();
    let (c, h, w) = (net.in_ch, net.in_h, net.in_w);
    let mut rows = Vec::new();
    for &delta in deltas {
        let mut session = engine.video_session(8, 0.0).expect("video session");
        let mut clip = SynthVideo::new(c, h, w, delta, 7);
        let mut processed = Vec::with_capacity(frames);
        let t0 = Instant::now();
        for _ in 0..frames {
            let frame = clip.next_flat();
            let out = session.process_flat(&frame).expect("video frame");
            processed.push((frame, out));
        }
        let fps = frames as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        let mut bit_exact = true;
        let (mut done, mut saved) = (0u64, 0u64);
        let mut dirty_sum = 0.0;
        for (frame, (out, stats)) in &processed {
            if stats.frame > 0 {
                done += stats.access.accumulates;
                saved += stats.access.saved_macs;
                dirty_sum += stats.mac_dirty_fraction;
            }
            bit_exact &= *out == engine.infer(frame).expect("full recompute");
        }
        rows.push(VideoRow {
            model,
            delta,
            frames,
            mac_dirty_fraction: dirty_sum / (frames - 1).max(1) as f64,
            saved_mac_ratio: saved as f64 / (done + saved).max(1) as f64,
            fps,
            bit_exact,
        });
    }
    rows
}

fn main() {
    let tiny =
        std::env::var_os("SERVE_TINY").is_some() || std::env::var_os("HOTPATH_TINY").is_some();
    let requests = if tiny { 16 } else { 128 };

    let mut rows = Vec::new();
    for workers in [1usize, 2, 4] {
        let row = run(workers, requests);
        println!(
            "workers {}: {}/{} ok in {:.3} s → {:.1} req/s, worst-model p99 {:.2} ms",
            row.workers, row.ok, requests, row.total_s, row.req_per_s, row.p99_ms
        );
        rows.push(row);
    }

    let mut body = format!(
        "{{\n  \"bench\": \"serve\",\n  \"tiny\": {tiny},\n  \"requests\": {requests},\n  \
         \"models\": [\"{}\", \"{}\"],\n  \"entries\": [\n",
        MODELS[0], MODELS[1]
    );
    for (i, r) in rows.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"workers\": {}, \"ok\": {}, \"failed\": {}, \"total_s\": {:.6}, \
             \"req_per_s\": {:.3}, \"p99_ms\": {:.4}}}{}\n",
            r.workers,
            r.ok,
            r.failed,
            r.total_s,
            r.req_per_s,
            r.p99_ms,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    body.push_str("  ],\n");

    // Worker × transport sweep under pipelined concurrency: the wire
    // frontend vs the in-process path at identical workload shape.
    let sweep_workers: &[usize] = if tiny { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let (conns, in_flight) = if tiny { (2, 8) } else { (4, 64) };
    let sweep_requests = if tiny { 32 } else { 512 };
    let mut sweep_rows = Vec::new();
    for &workers in sweep_workers {
        for transport in ["in-process", "tcp"] {
            let row = if transport == "tcp" {
                run_sweep_tcp(workers, conns, in_flight, sweep_requests)
            } else {
                run_sweep_inproc(workers, conns, in_flight, sweep_requests)
            };
            println!(
                "sweep {} workers {} ({}×{} in flight): {}/{} ok → {:.1} req/s, \
                 p50 {:.2} ms, p99 {:.2} ms",
                row.transport,
                row.workers,
                row.connections,
                row.in_flight,
                row.ok,
                sweep_requests,
                row.req_per_s,
                row.p50_ms,
                row.p99_ms
            );
            sweep_rows.push(row);
        }
    }
    body.push_str(&format!(
        "  \"sweep\": {{\"connections\": {conns}, \"in_flight\": {in_flight}, \
         \"requests_per_point\": {sweep_requests}, \"entries\": [\n"
    ));
    for (i, r) in sweep_rows.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"workers\": {}, \"transport\": \"{}\", \"connections\": {}, \
             \"in_flight\": {}, \"requests\": {}, \"ok\": {}, \"failed\": {}, \
             \"rejected\": {}, \"total_s\": {:.6}, \"req_per_s\": {:.3}, \
             \"p50_ms\": {:.4}, \"p99_ms\": {:.4}}}{}\n",
            r.workers,
            r.transport,
            r.connections,
            r.in_flight,
            r.requests,
            r.ok,
            r.failed,
            r.rejected,
            r.total_s,
            r.req_per_s,
            r.p50_ms,
            r.p99_ms,
            if i + 1 < sweep_rows.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]},\n");

    // The B ∈ {1, 2, 4, 8} micro-batch curve: weight traffic must fall
    // as ~1/B of the sequential words (bench_diff.py --serve gates it).
    let mut batch_rows = Vec::new();
    for model in MODELS {
        batch_rows.extend(run_batch_curve(model, &[1, 2, 4, 8]));
    }
    body.push_str("  \"batch_entries\": [\n");
    for (i, r) in batch_rows.iter().enumerate() {
        let ratio = r.stream_words as f64 / r.stream_words_seq.max(1) as f64;
        let req_per_s = |s: f64| if s > 0.0 { r.batch as f64 / s } else { 0.0 };
        println!(
            "{} B={}: stream ratio {:.4} (1/B = {:.4}), {:.1} req/s batched vs {:.1} sequential",
            r.model,
            r.batch,
            ratio,
            1.0 / r.batch as f64,
            req_per_s(r.batch_s),
            req_per_s(r.seq_s)
        );
        body.push_str(&format!(
            "    {{\"model\": \"{}\", \"batch\": {}, \"stream_words\": {}, \
             \"stream_words_seq\": {}, \"ratio\": {:.6}, \"req_per_s_batched\": {:.3}, \
             \"req_per_s_sequential\": {:.3}}}{}\n",
            r.model,
            r.batch,
            r.stream_words,
            r.stream_words_seq,
            ratio,
            req_per_s(r.batch_s),
            req_per_s(r.seq_s),
            if i + 1 < batch_rows.len() { "," } else { "" }
        ));
    }
    body.push_str("  ],\n");

    // Streaming-video curve: saved MACs vs frame-to-frame delta. Frame 0
    // primes the session; every later frame recomputes only dirty tiles.
    let video_frames = if tiny { 4 } else { 8 };
    let video_rows = run_video_curve(MODELS[0], &[0.0, 0.05, 0.25, 1.0], video_frames);
    body.push_str("  \"video_entries\": [\n");
    for (i, r) in video_rows.iter().enumerate() {
        println!(
            "video {} delta {:.2}: MACs {:.1}% dirty → {:.1}% saved, {:.1} fps, bit-exact {}",
            r.model,
            r.delta,
            r.mac_dirty_fraction * 100.0,
            r.saved_mac_ratio * 100.0,
            r.fps,
            r.bit_exact
        );
        body.push_str(&format!(
            "    {{\"model\": \"{}\", \"delta\": {:.4}, \"frames\": {}, \
             \"mac_dirty_fraction\": {:.6}, \"saved_mac_ratio\": {:.6}, \
             \"fps\": {:.3}, \"bit_exact\": {}}}{}\n",
            r.model,
            r.delta,
            r.frames,
            r.mac_dirty_fraction,
            r.saved_mac_ratio,
            r.fps,
            r.bit_exact,
            if i + 1 < video_rows.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve.json", &body) {
        Ok(()) => println!(
            "wrote BENCH_serve.json ({} worker counts, {} sweep points, {} batch points, \
             {} video points)",
            rows.len(),
            sweep_rows.len(),
            batch_rows.len(),
            video_rows.len()
        ),
        Err(e) => {
            eprintln!("error: could not write BENCH_serve.json: {e}");
            std::process::exit(1);
        }
    }
}

//! Tbl I — the cycle-exact weight-stream schedule of a 16→64-FM 3×3
//! convolution (first/last cycles of the trace + trace-generation perf).

mod bench_util;

use hyperdrive::coordinator::schedule::trace_layer;
use hyperdrive::network::ConvLayer;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    println!("{}", report::table1());
    let cfg = ChipConfig::default();
    let l = ConvLayer::new("t", 16, 64, 56, 56, 3, 1);
    bench_util::bench("trace_layer(16→64 3×3, full 36.8k cycles)", 3, 100, || {
        let t = trace_layer(&l, &cfg, 40_000);
        assert_eq!(t.len(), 36_864);
    });
}

//! Tbl II — data volumes (weights / all FMs / worst-case memory) for the
//! zoo networks, regenerated from the graph IR + WCL liveness analysis.

mod bench_util;

use hyperdrive::coordinator::wcl;
use hyperdrive::model;
use hyperdrive::report;

fn main() {
    println!("{}", report::table2());
    // Perf: the WCL liveness analysis itself (coordinator hot path).
    let net = model::network("resnet152@1024x2048").unwrap();
    bench_util::bench("wcl::analyze(ResNet-152 @2k×1k)", 3, 50, || {
        let a = wcl::analyze(&net);
        assert!(a.wcl_words > 0);
    });
    let net34 = model::network("resnet34@224x224").unwrap();
    bench_util::bench("zoo build + analyze (ResNet-34)", 3, 50, || {
        let a = wcl::analyze(&net34);
        assert_eq!(a.wcl_words, 401_408);
    });
}

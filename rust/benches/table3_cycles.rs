//! Tbl III — ResNet-34 cycle/throughput breakdown from the Algorithm-1
//! schedule model, consumed through the engine's typed report.

mod bench_util;

use hyperdrive::coordinator::schedule::{schedule_network, DepthwisePolicy};
use hyperdrive::engine::Engine;
use hyperdrive::model;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::table3(&cfg));

    // The typed report carries the same schedule the table prints.
    let rep = Engine::builder()
        .model("resnet34@224x224")
        .chip(cfg)
        .build()
        .unwrap()
        .report();
    assert_eq!(rep.schedule.cycles.conv, 4_521_984);

    // Perf: the raw schedule model (coordinator hot path).
    let net = model::network("resnet34@224x224").unwrap();
    bench_util::bench("schedule_network(ResNet-34)", 3, 200, || {
        let s = schedule_network(&net, &cfg, DepthwisePolicy::default());
        assert_eq!(s.cycles.conv, 4_521_984);
    });
}

//! Tbl III — ResNet-34 cycle/throughput breakdown from the Algorithm-1
//! schedule model.

mod bench_util;

use hyperdrive::coordinator::schedule::{schedule_network, DepthwisePolicy};
use hyperdrive::network::zoo;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::table3(&cfg));
    let net = zoo::resnet34(224, 224);
    bench_util::bench("schedule_network(ResNet-34)", 3, 200, || {
        let s = schedule_network(&net, &cfg, DepthwisePolicy::default());
        assert_eq!(s.cycles.conv, 4_521_984);
    });
}

//! Tbl IV — measured operating points + model interpolation.

mod bench_util;

use hyperdrive::energy::scaling;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::table4(&cfg));
    bench_util::bench("scaling model full (V,VBB) grid", 3, 1000, || {
        let mut acc = 0.0;
        for v in [0.4, 0.5, 0.6, 0.7, 0.8] {
            for b in [0.0, 0.5, 1.0, 1.5, 1.8] {
                acc += scaling::energy_per_cycle_j(v, b);
            }
        }
        assert!(acc > 0.0);
    });
}

//! Tbl V — comparison with the state-of-the-art BWN accelerators:
//! published competitor rows + Hyperdrive rows from our calibrated model
//! (incl. the 10×5 and 20×10 multi-chip object-detection rows), all
//! derived from the engine's typed report.

mod bench_util;

use hyperdrive::engine::{DepthwisePolicy, Engine};
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::table5(&cfg));

    // Perf: one full engine build + typed report for the big mesh row
    // (plan validation, schedule, WCL liveness, energy model).
    bench_util::bench("EngineReport(ResNet-34 @2k×1k, 10×5)", 3, 50, || {
        let rep = Engine::builder()
            .model("resnet34@1024x2048")
            .chip(cfg)
            .mesh(5, 10)
            .depthwise(DepthwisePolicy::FullRate)
            .build()
            .unwrap()
            .report();
        assert!(rep.energy.system_efficiency_ops_w() > 3e12);
    });
}

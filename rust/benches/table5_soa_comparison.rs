//! Tbl V — comparison with the state-of-the-art BWN accelerators:
//! published competitor rows + Hyperdrive rows from our calibrated model
//! (incl. the 10×5 and 20×10 multi-chip object-detection rows).

mod bench_util;

use hyperdrive::coordinator::schedule::DepthwisePolicy;
use hyperdrive::coordinator::tiling::plan_mesh_exact;
use hyperdrive::energy::model::energy_per_image;
use hyperdrive::network::zoo;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::table5(&cfg));
    let net = zoo::resnet34(1024, 2048);
    let plan = plan_mesh_exact(&net, &cfg, 5, 10);
    bench_util::bench("energy_per_image(ResNet-34 @2k×1k, 10×5)", 3, 100, || {
        let r = energy_per_image(&net, &cfg, &plan, 0.5, 1.5, DepthwisePolicy::FullRate);
        assert!(r.system_efficiency_ops_w() > 3e12);
    });
}

//! Tbl VI — Tile-PU utilization per network (total and conv-phase), with
//! the depth-wise serialization ablation.

mod bench_util;

use hyperdrive::coordinator::schedule::{schedule_network, DepthwisePolicy};
use hyperdrive::network::zoo;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::table6(&cfg));
    let yolo = zoo::yolov3(320, 320);
    bench_util::bench("schedule_network(YOLOv3 @320²)", 3, 200, || {
        let s = schedule_network(&yolo, &cfg, DepthwisePolicy::FullRate);
        assert!(s.total_cycles() > 0);
    });
}

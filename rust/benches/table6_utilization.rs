//! Tbl VI — Tile-PU utilization per network (total and conv-phase), with
//! the depth-wise serialization ablation.

mod bench_util;

use hyperdrive::coordinator::schedule::{schedule_network, DepthwisePolicy};
use hyperdrive::model;
use hyperdrive::report;
use hyperdrive::ChipConfig;

fn main() {
    let cfg = ChipConfig::default();
    println!("{}", report::table6(&cfg));
    let yolo = model::network("yolov3@320x320").unwrap();
    bench_util::bench("schedule_network(YOLOv3 @320²)", 3, 200, || {
        let s = schedule_network(&yolo, &cfg, DepthwisePolicy::FullRate);
        assert!(s.total_cycles() > 0);
    });
}

//! State-of-the-art comparator models (Tbl V, Fig 11).
//!
//! * [`published`] — the competitor rows of Tbl V (YodaNN, Wang et al.,
//!   UNPU) as published, used verbatim for the comparison table exactly
//!   as the paper does;
//! * [`weight_stationary`] — the generic FM-streaming dataflow I/O model
//!   behind Fig 11's green curve and the "I/O energy wall" argument.

pub mod published;
pub mod weight_stationary;

pub use published::{published_rows, PublishedRow};
pub use weight_stationary::weight_stationary_io_bits;

//! Competitor rows of Tbl V, as published (the paper compares against
//! the numbers reported by the respective silicon papers; so do we).

/// One comparison row (energies in mJ/image, efficiency in TOp/s/W).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PublishedRow {
    pub name: &'static str,
    pub technology: &'static str,
    pub dnn: &'static str,
    pub input: &'static str,
    pub precision: &'static str,
    pub core_v: f64,
    pub eff_throughput_gops: f64,
    pub core_e_mj: f64,
    pub io_e_mj: f64,
    pub total_e_mj: f64,
    pub efficiency_tops_w: f64,
    pub area_mge: f64,
}

/// All competitor rows of Tbl V (image classification + object
/// detection sections).
pub fn published_rows() -> Vec<PublishedRow> {
    vec![
        PublishedRow {
            name: "YodaNN (layout) [26] @1.2V",
            technology: "umc65",
            dnn: "ResNet-34",
            input: "224x224",
            precision: "Bin./Q12",
            core_v: 1.20,
            eff_throughput_gops: 490.0,
            core_e_mj: 0.9,
            io_e_mj: 3.6,
            total_e_mj: 4.5,
            efficiency_tops_w: 1.6,
            area_mge: 1.3,
        },
        PublishedRow {
            name: "YodaNN (layout) [26] @0.6V",
            technology: "umc65",
            dnn: "ResNet-34",
            input: "224x224",
            precision: "Bin./Q12",
            core_v: 0.60,
            eff_throughput_gops: 18.0,
            core_e_mj: 0.1,
            io_e_mj: 3.6,
            total_e_mj: 3.7,
            efficiency_tops_w: 2.0,
            area_mge: 1.3,
        },
        PublishedRow {
            name: "Wang w/ 25 Mbit SRAM",
            technology: "SMIC130",
            dnn: "ResNet-34",
            input: "224x224",
            precision: "Bin./ENQ6",
            core_v: 1.08,
            eff_throughput_gops: 876.0,
            core_e_mj: 5.4,
            io_e_mj: 1.7,
            total_e_mj: 7.2,
            efficiency_tops_w: 1.0,
            area_mge: 9.9,
        },
        PublishedRow {
            name: "UNPU (chip) [44]",
            technology: "65nm",
            dnn: "ResNet-34",
            input: "224x224",
            precision: "Bin./Q16",
            core_v: 0.77,
            eff_throughput_gops: 346.0,
            core_e_mj: 2.3,
            io_e_mj: 3.6,
            total_e_mj: 6.0,
            efficiency_tops_w: 1.2,
            area_mge: 11.1,
        },
        PublishedRow {
            name: "Wang w/ 25 Mbit SRAM",
            technology: "SMIC130",
            dnn: "ShuffleNet",
            input: "224x224",
            precision: "Bin./ENQ6",
            core_v: 1.08,
            eff_throughput_gops: 876.0,
            core_e_mj: 0.3,
            io_e_mj: 0.4,
            total_e_mj: 0.7,
            efficiency_tops_w: 0.5,
            area_mge: 9.9,
        },
        PublishedRow {
            name: "UNPU (chip) [44]",
            technology: "65nm",
            dnn: "ShuffleNet",
            input: "224x224",
            precision: "Bin./Q16",
            core_v: 0.77,
            eff_throughput_gops: 346.0,
            core_e_mj: 0.1,
            io_e_mj: 1.0,
            total_e_mj: 1.1,
            efficiency_tops_w: 0.3,
            area_mge: 11.1,
        },
        PublishedRow {
            name: "Wang w/ 25 Mbit SRAM",
            technology: "SMIC130",
            dnn: "YOLOv3 (COCO)",
            input: "320x320",
            precision: "Bin./ENQ6",
            core_v: 1.08,
            eff_throughput_gops: 876.0,
            core_e_mj: 40.9,
            io_e_mj: 4.2,
            total_e_mj: 45.1,
            efficiency_tops_w: 1.2,
            area_mge: 9.9,
        },
        PublishedRow {
            name: "UNPU (chip) [44]",
            technology: "65nm",
            dnn: "YOLOv3",
            input: "320x320",
            precision: "Bin./Q16",
            core_v: 0.77,
            eff_throughput_gops: 346.0,
            core_e_mj: 17.2,
            io_e_mj: 9.1,
            total_e_mj: 26.4,
            efficiency_tops_w: 2.0,
            area_mge: 11.1,
        },
        PublishedRow {
            name: "Wang w/ 25 Mbit SRAM",
            technology: "SMIC130",
            dnn: "ResNet-34",
            input: "2kx1k",
            precision: "Bin./ENQ6",
            core_v: 1.08,
            eff_throughput_gops: 876.0,
            core_e_mj: 243.4,
            io_e_mj: 40.5,
            total_e_mj: 283.9,
            efficiency_tops_w: 1.0,
            area_mge: 9.9,
        },
        PublishedRow {
            name: "UNPU (chip) [44]",
            technology: "65nm",
            dnn: "ResNet-34",
            input: "2kx1k",
            precision: "Bin./Q16",
            core_v: 0.77,
            eff_throughput_gops: 346.0,
            core_e_mj: 97.7,
            io_e_mj: 105.6,
            total_e_mj: 203.3,
            efficiency_tops_w: 1.4,
            area_mge: 11.1,
        },
    ]
}

/// Best competitor efficiency for a workload class (for the improvement
/// factors at the bottom of Tbl V).
pub fn best_competitor_efficiency(dnn: &str, input: &str) -> f64 {
    published_rows()
        .iter()
        .filter(|r| r.dnn.starts_with(dnn) && r.input == input)
        .map(|r| r.efficiency_tops_w)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_internally_consistent() {
        for r in published_rows() {
            assert!(
                (r.core_e_mj + r.io_e_mj - r.total_e_mj).abs() < 0.11,
                "{}: {} + {} != {}",
                r.name,
                r.core_e_mj,
                r.io_e_mj,
                r.total_e_mj
            );
        }
    }

    #[test]
    fn best_competitors_match_paper_improvement_baselines() {
        // Image classification baseline: YodaNN @0.6 V (2.0 TOp/s/W) →
        // paper claims 1.8× with Hyperdrive's 3.6.
        assert_eq!(best_competitor_efficiency("ResNet-34", "224x224"), 2.0);
        // Object detection baseline: UNPU @2k×1k (1.4) → paper claims
        // 3.1× with 4.3.
        assert_eq!(best_competitor_efficiency("ResNet-34", "2kx1k"), 1.4);
    }

    #[test]
    fn fm_streaming_io_dominates_for_baselines() {
        // The I/O-wall premise: for the high-resolution workload, I/O is
        // a large share of every FM-streaming competitor's energy.
        for r in published_rows().iter().filter(|r| r.input == "2kx1k") {
            let share = r.io_e_mj / r.total_e_mj;
            assert!(share > 0.14, "{}: I/O share {share}", r.name);
        }
    }
}

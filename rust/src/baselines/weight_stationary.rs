//! The weight-stationary (FM-streaming) dataflow I/O model — the green
//! curve of Fig 11 and the quantitative form of the paper's "I/O energy
//! wall" argument.
//!
//! A conventional accelerator keeps weights on-chip and streams every
//! layer's input and output feature map across the chip boundary once
//! (optimistic for the baseline: real chips with small line buffers
//! re-fetch input rows several times). Hyperdrive instead streams the
//! (16× smaller) binary weights and keeps FMs resident.

use crate::network::Network;

/// FM-streaming I/O bits per image: every layer's input is read and its
/// output written across the boundary once, at `act_bits` per value.
pub fn weight_stationary_io_bits(net: &Network, act_bits: usize) -> u64 {
    net.steps
        .iter()
        .map(|s| (s.layer.in_words() + s.layer.out_words()) * act_bits as u64)
        .sum()
}

/// Hyperdrive-side curve of Fig 11: weights (constant vs resolution) +
/// border exchange (grows once the FM tiles across chips).
pub fn hyperdrive_fig11_bits(
    net: &Network,
    plan: &crate::coordinator::tiling::MeshPlan,
    fm_bits: usize,
) -> u64 {
    net.weight_bits() + crate::coordinator::tiling::border_exchange_bits(net, plan, fm_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiling::{plan_mesh, plan_mesh_exact};
    use crate::model;
    use crate::ChipConfig;

    #[test]
    fn resnet34_fm_streaming_far_exceeds_weight_streaming() {
        // At 224² the FM traffic is ~100 Mbit vs 21.3 Mbit of weights —
        // the ~4–5× gap that motivates the whole architecture.
        let net = model::network("resnet34@224x224").unwrap();
        let ws = weight_stationary_io_bits(&net, 16);
        let hd = net.weight_bits();
        let ratio = ws as f64 / hd as f64;
        assert!((3.5..6.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fig11_io_reduction_at_2x2_tiling() {
        // Fig 11: at the first multi-chip step (2×2), Hyperdrive's total
        // I/O (weights + border exchange) is several times below the
        // FM-streaming baseline; the paper reports up to 2.7×.
        let net = model::network("resnet34@448x448").unwrap();
        let cfg = ChipConfig::default();
        let plan = plan_mesh(&net, &cfg);
        assert_eq!((plan.rows, plan.cols), (2, 2));
        let ws = weight_stationary_io_bits(&net, 16);
        let hd = hyperdrive_fig11_bits(&net, &plan, 16);
        let ratio = ws as f64 / hd as f64;
        assert!(ratio > 2.7, "reduction {ratio} (paper: up to 2.7×)");
    }

    #[test]
    fn fig11_reduction_persists_at_3x3() {
        let net = model::network("resnet34@672x672").unwrap();
        let cfg = ChipConfig::default();
        let plan = plan_mesh_exact(&net, &cfg, 3, 3);
        let ws = weight_stationary_io_bits(&net, 16);
        let hd = hyperdrive_fig11_bits(&net, &plan, 16);
        let ratio = ws as f64 / hd as f64;
        assert!(ratio > 2.5, "reduction {ratio} (paper: 2.5×)");
    }

    #[test]
    fn weight_io_constant_until_single_chip_limit() {
        // Fig 11's red plateau: weights don't grow with resolution.
        let a = model::network("resnet34@112x112").unwrap().weight_bits();
        let b = model::network("resnet34@224x224").unwrap().weight_bits();
        assert_eq!(a, b);
    }

    #[test]
    fn border_exchange_grows_with_mesh_but_stays_secondary() {
        let net = model::network("resnet34@1024x2048").unwrap();
        let cfg = ChipConfig::default();
        let p55 = plan_mesh_exact(&net, &cfg, 5, 10);
        let ws = weight_stationary_io_bits(&net, 16);
        let hd = hyperdrive_fig11_bits(&net, &p55, 16);
        assert!(ws as f64 / hd as f64 > 5.0, "{} / {}", ws, hd);
    }
}

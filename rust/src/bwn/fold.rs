//! Batch-norm / BWN-scale folding (§IV: "Batch normalization … can be
//! merged with biasing and scaling, as the coefficients stay constant
//! after training").
//!
//! A trained BWN conv layer carries: the binarized weights, the BWN
//! per-channel scale α = E|w| (BinaryConnect-style), and a batch-norm
//! (μ, σ², γ_bn, β_bn) plus an optional bias b. At inference all of it
//! folds into the chip's two per-channel coefficients:
//!
//!   γ = α · γ_bn / √(σ² + ε)
//!   β = β_bn + (b − μ) · γ_bn / √(σ² + ε)
//!
//! so the datapath computes `γ·(Σ ±x) + bypass + β` — exactly the fused
//! post sequence of Algorithm 1.

/// Raw per-channel training-time parameters of one conv layer.
#[derive(Debug, Clone)]
pub struct RawChannelParams {
    /// BWN scale α (mean absolute real-valued weight), > 0.
    pub alpha: f64,
    /// Convolution bias (0 if none).
    pub bias: f64,
    /// Batch-norm running mean / variance and affine parameters.
    pub bn_mean: f64,
    pub bn_var: f64,
    pub bn_gamma: f64,
    pub bn_beta: f64,
}

/// Folded coefficients the chip consumes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FoldedChannel {
    pub gamma: f32,
    pub beta: f32,
}

/// Fold one channel (ε guards σ² = 0).
pub fn fold_channel(p: &RawChannelParams, eps: f64) -> FoldedChannel {
    let inv_std = p.bn_gamma / (p.bn_var + eps).sqrt();
    FoldedChannel {
        gamma: (p.alpha * inv_std) as f32,
        beta: (p.bn_beta + (p.bias - p.bn_mean) * inv_std) as f32,
    }
}

/// Fold a whole layer.
pub fn fold_layer(params: &[RawChannelParams], eps: f64) -> (Vec<f32>, Vec<f32>) {
    let folded: Vec<FoldedChannel> = params.iter().map(|p| fold_channel(p, eps)).collect();
    (
        folded.iter().map(|f| f.gamma).collect(),
        folded.iter().map(|f| f.beta).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    /// Unfused reference: y = bn(conv_sum·α + b) with bn(z) =
    /// γ_bn·(z − μ)/√(σ²+ε) + β_bn.
    fn reference(p: &RawChannelParams, conv_sum: f64, eps: f64) -> f64 {
        let z = conv_sum * p.alpha + p.bias;
        p.bn_gamma * (z - p.bn_mean) / (p.bn_var + eps).sqrt() + p.bn_beta
    }

    #[test]
    fn folded_equals_unfused_property() {
        testkit::check("bn folding equivalence", 0xf01d, |rng| {
            let p = RawChannelParams {
                alpha: 0.01 + rng.next_f32() as f64,
                bias: rng.next_sym() as f64,
                bn_mean: rng.next_sym() as f64 * 3.0,
                bn_var: 0.01 + 2.0 * rng.next_f32() as f64,
                bn_gamma: 0.1 + rng.next_f32() as f64,
                bn_beta: rng.next_sym() as f64,
            };
            let eps = 1e-5;
            let f = fold_channel(&p, eps);
            for _ in 0..8 {
                let s = (rng.next_sym() * 50.0) as f64;
                let want = reference(&p, s, eps);
                let got = f.gamma as f64 * s + f.beta as f64;
                if (want - got).abs() > 1e-4 * (1.0 + want.abs()) {
                    return Err(format!("sum {s}: {got} vs {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn identity_bn_folds_to_alpha_and_bias() {
        let p = RawChannelParams {
            alpha: 0.25,
            bias: 1.5,
            bn_mean: 0.0,
            bn_var: 1.0,
            bn_gamma: 1.0,
            bn_beta: 0.0,
        };
        let f = fold_channel(&p, 0.0);
        assert!((f.gamma - 0.25).abs() < 1e-7);
        assert!((f.beta - 1.5).abs() < 1e-7);
    }

    #[test]
    fn fold_layer_is_elementwise() {
        let p = RawChannelParams {
            alpha: 0.5,
            bias: 0.0,
            bn_mean: 2.0,
            bn_var: 4.0,
            bn_gamma: 2.0,
            bn_beta: 1.0,
        };
        let (g, b) = fold_layer(&vec![p.clone(); 3], 0.0);
        assert_eq!(g.len(), 3);
        assert!((g[0] - 0.5).abs() < 1e-7); // 0.5·2/2
        assert!((b[0] - (1.0 - 2.0)).abs() < 1e-6); // 1 + (0−2)·2/2
    }

    #[test]
    fn zero_variance_guarded_by_eps() {
        let p = RawChannelParams {
            alpha: 1.0,
            bias: 0.0,
            bn_mean: 0.0,
            bn_var: 0.0,
            bn_gamma: 1.0,
            bn_beta: 0.0,
        };
        let f = fold_channel(&p, 1e-5);
        assert!(f.gamma.is_finite());
    }
}

//! Binary-weight handling: binarization, bit-packing, the weight *stream*
//! (the paper's key I/O object) and the latch-based weight-buffer model.
//!
//! The stream order follows Algorithm 1 / Tbl I exactly: for each output
//! channel tile of `C` FMs, for each filter tap Δ (row-major
//! `(−⌊k/2⌋..⌊k/2⌋)²`), for each input channel `c_in`, one `C`-bit word
//! whose bit `c` is the sign of `w[tile·C + c][c_in][Δ]` (1 = +1).

pub mod fold;
pub mod stream;
pub mod wbuf;

pub use fold::{fold_channel, fold_layer, RawChannelParams};
pub use stream::{
    binarize, network_packed_bytes, pack_weights, packed_footprint_bytes, unpack_word,
    PackedLayerWeights, WeightStream,
};
pub use wbuf::WeightBuffer;

//! The binary-weight stream: the only large input the chip reads per
//! layer (feature maps stay stationary). 16× smaller than streaming FP16
//! weights — the source of the paper's I/O-energy reduction.

use crate::network::ConvLayer;

/// Binarize a real-valued weight: `sign(w)` with `sign(0) := +1`.
#[inline]
pub fn binarize(w: f32) -> bool {
    w >= 0.0
}

/// One layer's weight stream: `C`-bit words in Algorithm-1 order, padded
/// with +1 weights when `n_out` is not a multiple of `C` (the idle
/// Tile-PU channels).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightStream {
    /// Output-channel parallelism the stream was packed for.
    pub c: usize,
    /// Stream words, one per (c_out-tile, Δ, c_in) step; bit `b` of a
    /// word is the weight for output channel `tile·C + b`.
    pub words: Vec<u16>,
    /// Layout for unpacking: (n_out tiles, taps, n_in per group view).
    pub n_out: usize,
    pub n_in_eff: usize,
    pub k: usize,
}

/// Pack a layer's real-valued weights `w[n_out][n_in/groups][k][k]`
/// (flattened, row-major) into the stream order of Tbl I.
///
/// `c` is the chip's output-channel parallelism (16 on the taped-out
/// chip; `c <= 16` supported since words are `u16`).
pub fn pack_weights(layer: &ConvLayer, weights: &[f32], c: usize) -> WeightStream {
    assert!(c <= 16, "stream words are u16");
    let n_in_eff = layer.n_in / layer.groups;
    let taps = layer.k * layer.k;
    assert_eq!(
        weights.len(),
        layer.n_out * n_in_eff * taps,
        "weight blob size mismatch for `{}`",
        layer.name
    );
    let n_tiles = layer.n_out.div_ceil(c);
    let mut words = Vec::with_capacity(n_tiles * taps * n_in_eff);
    for tile in 0..n_tiles {
        for tap in 0..taps {
            for ci in 0..n_in_eff {
                let mut word = 0u16;
                for b in 0..c {
                    let co = tile * c + b;
                    // Padded (idle) channels stream +1.
                    let bit = if co < layer.n_out {
                        binarize(weights[(co * n_in_eff + ci) * taps + tap])
                    } else {
                        true
                    };
                    if bit {
                        word |= 1 << b;
                    }
                }
                words.push(word);
            }
        }
    }
    WeightStream {
        c,
        words,
        n_out: layer.n_out,
        n_in_eff,
        k: layer.k,
    }
}

impl WeightStream {
    /// Total bits on the wire for this layer (words × C).
    pub fn wire_bits(&self) -> u64 {
        (self.words.len() * self.c) as u64
    }

    /// Stream word index for (c_out tile, tap, c_in).
    pub fn word_index(&self, tile: usize, tap: usize, ci: usize) -> usize {
        (tile * self.k * self.k + tap) * self.n_in_eff + ci
    }

    /// Signed weight (±1.0) for output channel `co`, input `ci`, tap Δ.
    pub fn weight(&self, co: usize, ci: usize, tap: usize) -> f32 {
        let tile = co / self.c;
        let bit = co % self.c;
        let w = self.words[self.word_index(tile, tap, ci)];
        if w & (1 << bit) != 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Unpack the whole stream back to a ±1.0 dense tensor
    /// `[n_out][n_in_eff][k][k]` (row-major) — used to build the PJRT
    /// weight literal on the inference path.
    pub fn unpack_dense(&self) -> Vec<f32> {
        let taps = self.k * self.k;
        let mut out = vec![0.0f32; self.n_out * self.n_in_eff * taps];
        for co in 0..self.n_out {
            for ci in 0..self.n_in_eff {
                for tap in 0..taps {
                    out[(co * self.n_in_eff + ci) * taps + tap] = self.weight(co, ci, tap);
                }
            }
        }
        out
    }
}

/// Unpack one stream word into `c` signs (+1.0 / −1.0).
pub fn unpack_word(word: u16, c: usize) -> Vec<f32> {
    (0..c)
        .map(|b| if word & (1 << b) != 0 { 1.0 } else { -1.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::ConvLayer;
    use crate::testkit;
    use crate::util::SplitMix64;

    fn layer(n_in: usize, n_out: usize, k: usize) -> ConvLayer {
        ConvLayer::new("t", n_in, n_out, 8, 8, k, 1)
    }

    #[test]
    fn stream_length_matches_schedule() {
        // Tbl I: 16→64 3×3 conv on C=16 → 4 tiles × 9 taps × 16 c_in words.
        let l = layer(16, 64, 3);
        let w = vec![1.0f32; 64 * 16 * 9];
        let s = pack_weights(&l, &w, 16);
        assert_eq!(s.words.len(), 4 * 9 * 16);
        assert_eq!(s.wire_bits(), 4 * 9 * 16 * 16);
    }

    #[test]
    fn wire_bits_equal_layer_weight_bits_when_c_divides() {
        let l = layer(16, 64, 3);
        let w = vec![-1.0f32; 64 * 16 * 9];
        assert_eq!(pack_weights(&l, &w, 16).wire_bits(), l.weight_bits());
    }

    #[test]
    fn padded_tail_channels_stream_plus_one() {
        let l = layer(4, 20, 1); // 20 outputs → 2 tiles of 16, 12 padded
        let w = vec![-1.0f32; 20 * 4];
        let s = pack_weights(&l, &w, 16);
        // Word for tile 1, tap 0, ci 0: bits 0..3 are real (−1 → 0),
        // bits 4..15 padding (+1 → 1).
        let word = s.words[s.word_index(1, 0, 0)];
        assert_eq!(word & 0x000f, 0);
        assert_eq!(word & 0xfff0, 0xfff0);
    }

    #[test]
    fn pack_unpack_round_trip_property() {
        testkit::check("pack/unpack round trip", 0x5eed, |rng| {
            let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
            let n_in = 1 + rng.next_below(24);
            let n_out = 1 + rng.next_below(40);
            let l = layer(n_in, n_out, k);
            let w: Vec<f32> = (0..n_out * n_in * k * k)
                .map(|_| {
                    let v = rng.next_sym();
                    if v == 0.0 {
                        0.5
                    } else {
                        v
                    }
                })
                .collect();
            let s = pack_weights(&l, &w, 16);
            let dense = s.unpack_dense();
            for (i, (&orig, &got)) in w.iter().zip(&dense).enumerate() {
                let want = if binarize(orig) { 1.0 } else { -1.0 };
                if got != want {
                    return Err(format!("index {i}: {orig} → {got}, want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pack_unpack_round_trip_odd_nout_and_groups() {
        // The padded-tail path: odd `n_out` (or odd multiples of the
        // group count) is never a multiple of C = 16, so the last
        // c_out tile always carries padding; grouped layers stream the
        // reduced `n_in / groups` fan-in.
        testkit::check("pack/unpack odd n_out + groups", 0x0dd5, |rng| {
            let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
            let groups = [1usize, 2, 4][rng.next_below(3)];
            let n_in = groups * (1 + rng.next_below(8));
            let n_out = groups * (2 * rng.next_below(20) + 1); // odd multiple
            let l = layer(n_in, n_out, k).with_groups(groups);
            let nie = n_in / groups;
            let w: Vec<f32> = (0..n_out * nie * k * k).map(|_| rng.next_sign()).collect();
            let s = pack_weights(&l, &w, 16);
            if s.n_in_eff != nie {
                return Err(format!("n_in_eff {} != {nie}", s.n_in_eff));
            }
            if s.wire_bits() % 16 != 0 {
                return Err(format!("wire bits {} not word-aligned", s.wire_bits()));
            }
            let dense = s.unpack_dense();
            if dense.len() != w.len() {
                return Err(format!("dense len {} != {}", dense.len(), w.len()));
            }
            for (i, (&orig, &got)) in w.iter().zip(&dense).enumerate() {
                if orig != got {
                    return Err(format!("index {i}: {orig} → {got}"));
                }
            }
            // Idle channels of the last tile stream +1 (never garbage).
            let tail = n_out % 16;
            if tail != 0 {
                let tile = n_out / 16;
                for tap in 0..k * k {
                    for ci in 0..nie {
                        let word = s.words[s.word_index(tile, tap, ci)];
                        for b in tail..16 {
                            if word & (1 << b) == 0 {
                                return Err(format!(
                                    "padded bit {b} of tile {tile} tap {tap} ci {ci} is -1"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sign_zero_is_plus_one() {
        assert!(binarize(0.0));
        assert!(binarize(1e-30));
        assert!(!binarize(-1e-30));
    }

    #[test]
    fn grouped_layer_streams_reduced_fan_in() {
        let l = layer(16, 32, 3).with_groups(4); // n_in_eff = 4
        let w: Vec<f32> = (0..32 * 4 * 9).map(|i| i as f32 - 300.0).collect();
        let s = pack_weights(&l, &w, 16);
        assert_eq!(s.n_in_eff, 4);
        assert_eq!(s.words.len(), 2 * 9 * 4);
        assert_eq!(s.wire_bits(), l.weight_bits());
    }

    #[test]
    fn unpack_word_bit_order() {
        let signs = unpack_word(0b0000_0000_0000_0101, 4);
        assert_eq!(signs, vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn deterministic_for_same_input() {
        let mut rng = SplitMix64::new(11);
        let l = layer(8, 16, 3);
        let w: Vec<f32> = (0..16 * 8 * 9).map(|_| rng.next_sym()).collect();
        assert_eq!(pack_weights(&l, &w, 16), pack_weights(&l, &w, 16));
    }
}

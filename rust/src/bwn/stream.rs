//! The binary-weight stream: the only large input the chip reads per
//! layer (feature maps stay stationary). 16× smaller than streaming FP16
//! weights — the source of the paper's I/O-energy reduction.
//!
//! Storage is *actually* 1 bit/weight: the `C`-bit stream words are laid
//! end-to-end into dense `u64` bitplanes (64 taps-by-channel weights per
//! word), so a resident stream costs `⌈words·C / 64⌉ · 8` bytes — the
//! footprint `packed_bytes()` reports and `ServiceMetrics` surfaces. The
//! word/weight accessors below decode straight from the planes.

use crate::network::{ConvLayer, Network};

/// Binarize a real-valued weight: `sign(w)` with `sign(0) := +1`.
#[inline]
pub fn binarize(w: f32) -> bool {
    w >= 0.0
}

/// One layer's weight stream: `C`-bit words in Algorithm-1 order, padded
/// with +1 weights when `n_out` is not a multiple of `C` (the idle
/// Tile-PU channels), stored as dense `u64` bitplanes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightStream {
    /// Output-channel parallelism the stream was packed for.
    pub c: usize,
    /// Dense bitplanes: stream bit `g = word_index·C + lane` lives at bit
    /// `g % 64` of `planes[g / 64]`. A `C ≤ 16`-bit word straddles at
    /// most two planes. Bits past the last word are zero padding.
    planes: Vec<u64>,
    /// Number of `C`-bit stream words packed into `planes`.
    word_count: usize,
    /// Layout for unpacking: (n_out tiles, taps, n_in per group view).
    pub n_out: usize,
    pub n_in_eff: usize,
    pub k: usize,
}

/// Pack a layer's real-valued weights `w[n_out][n_in/groups][k][k]`
/// (flattened, row-major) into the stream order of Tbl I.
///
/// `c` is the chip's output-channel parallelism (16 on the taped-out
/// chip; `c <= 16` supported since words decode to `u16`).
pub fn pack_weights(layer: &ConvLayer, weights: &[f32], c: usize) -> WeightStream {
    assert!((1..=16).contains(&c), "stream words decode to u16");
    let n_in_eff = layer.n_in / layer.groups;
    let taps = layer.k * layer.k;
    assert_eq!(
        weights.len(),
        layer.n_out * n_in_eff * taps,
        "weight blob size mismatch for `{}`",
        layer.name
    );
    let n_tiles = layer.n_out.div_ceil(c);
    let word_count = n_tiles * taps * n_in_eff;
    let mut planes = vec![0u64; (word_count * c).div_ceil(64)];
    let mut widx = 0usize;
    for tile in 0..n_tiles {
        for tap in 0..taps {
            for ci in 0..n_in_eff {
                let mut word = 0u64;
                for b in 0..c {
                    let co = tile * c + b;
                    // Padded (idle) channels stream +1.
                    let bit = if co < layer.n_out {
                        binarize(weights[(co * n_in_eff + ci) * taps + tap])
                    } else {
                        true
                    };
                    if bit {
                        word |= 1 << b;
                    }
                }
                let g = widx * c;
                let (lo, sh) = (g / 64, g % 64);
                planes[lo] |= word << sh;
                if sh + c > 64 {
                    planes[lo + 1] |= word >> (64 - sh);
                }
                widx += 1;
            }
        }
    }
    WeightStream {
        c,
        planes,
        word_count,
        n_out: layer.n_out,
        n_in_eff,
        k: layer.k,
    }
}

impl WeightStream {
    /// Total bits on the wire for this layer (words × C).
    pub fn wire_bits(&self) -> u64 {
        (self.word_count * self.c) as u64
    }

    /// Number of `C`-bit stream words.
    pub fn word_count(&self) -> usize {
        self.word_count
    }

    /// Number of `u64` bitplane words backing the stream.
    pub fn packed_words(&self) -> usize {
        self.planes.len()
    }

    /// True resident footprint of the packed stream, in bytes.
    pub fn packed_bytes(&self) -> u64 {
        (self.planes.len() * 8) as u64
    }

    /// Zero-fill bits in the last bitplane word (`< 64`): the difference
    /// between the `u64` storage and the wire bits.
    pub fn padding_bits(&self) -> u64 {
        (self.planes.len() * 64) as u64 - self.wire_bits()
    }

    /// Stream word index for (c_out tile, tap, c_in).
    pub fn word_index(&self, tile: usize, tap: usize, ci: usize) -> usize {
        (tile * self.k * self.k + tap) * self.n_in_eff + ci
    }

    /// Decode stream word `wi` (the low `C` bits are the tile's signs).
    #[inline]
    pub fn word(&self, wi: usize) -> u16 {
        debug_assert!(wi < self.word_count);
        let g = wi * self.c;
        let (lo, sh) = (g / 64, g % 64);
        let mut bits = self.planes[lo] >> sh;
        if sh + self.c > 64 {
            bits |= self.planes[lo + 1] << (64 - sh);
        }
        (bits as u16) & (u16::MAX >> (16 - self.c))
    }

    /// Sign bit for output channel `co`, input `ci`, tap Δ (1 = +1).
    #[inline]
    pub fn weight_bit(&self, co: usize, ci: usize, tap: usize) -> bool {
        let g = self.word_index(co / self.c, tap, ci) * self.c + co % self.c;
        (self.planes[g / 64] >> (g % 64)) & 1 != 0
    }

    /// Signed weight (±1.0) for output channel `co`, input `ci`, tap Δ.
    pub fn weight(&self, co: usize, ci: usize, tap: usize) -> f32 {
        if self.weight_bit(co, ci, tap) {
            1.0
        } else {
            -1.0
        }
    }

    /// Unpack the whole stream back to a ±1.0 dense tensor
    /// `[n_out][n_in_eff][k][k]` (row-major) — used to build the PJRT
    /// weight literal on the inference path.
    pub fn unpack_dense(&self) -> Vec<f32> {
        let taps = self.k * self.k;
        let mut out = vec![0.0f32; self.n_out * self.n_in_eff * taps];
        for co in 0..self.n_out {
            for ci in 0..self.n_in_eff {
                for tap in 0..taps {
                    out[(co * self.n_in_eff + ci) * taps + tap] = self.weight(co, ci, tap);
                }
            }
        }
        out
    }
}

/// Unpack one stream word into `c` signs (+1.0 / −1.0).
pub fn unpack_word(word: u16, c: usize) -> Vec<f32> {
    (0..c)
        .map(|b| if word & (1 << b) != 0 { 1.0 } else { -1.0 })
        .collect()
}

/// Resident packed footprint of one layer's stream at parallelism `c`,
/// in bytes — computed from the layer shape alone, so lazily-built
/// params (`engine::LazyParams`) can report it without materializing
/// weights. Matches `WeightStream::packed_bytes()` exactly.
pub fn packed_footprint_bytes(layer: &ConvLayer, c: usize) -> u64 {
    let words = (layer.n_out.div_ceil(c) * layer.k * layer.k * (layer.n_in / layer.groups)) as u64;
    (words * c as u64).div_ceil(64) * 8
}

/// Resident packed footprint of a whole network's weight streams, bytes.
pub fn network_packed_bytes(net: &Network, c: usize) -> u64 {
    net.steps
        .iter()
        .map(|s| packed_footprint_bytes(&s.layer, c))
        .sum()
}

/// One layer's binary weights expanded from the packed bitplanes into
/// the `u32` sign masks the datapath kernel XORs against FP32 bit
/// patterns (`0` = +1, `0x8000_0000` = −1), laid out
/// `[co][tap][c_in]` so `channel(co)` is the contiguous `wmask` plane
/// `run_tile`/`run_tile_batch` consume.
///
/// Build this **once per layer execution** and share it across tiles,
/// chips, mesh steps and batch slots — it hoists the per-output-channel
/// `weight() > 0` decode out of the hot path. It is scratch for one
/// pass, not a resident cache: keeping it alive would cost 32
/// bits/weight and undo the stream's ~32× packed-footprint advantage.
#[derive(Debug, Clone)]
pub struct PackedLayerWeights {
    masks: Vec<u32>,
    /// Plane stride: taps × n_in_eff masks per output channel.
    span: usize,
    pub n_out: usize,
}

impl PackedLayerWeights {
    pub fn new(stream: &WeightStream) -> Self {
        let taps = stream.k * stream.k;
        let nie = stream.n_in_eff;
        let span = taps * nie;
        let mut masks = vec![0u32; stream.n_out * span];
        for tile in 0..stream.n_out.div_ceil(stream.c) {
            let co_hi = ((tile + 1) * stream.c).min(stream.n_out);
            for tap in 0..taps {
                for ci in 0..nie {
                    // One word decode serves up to C output channels.
                    let word = stream.word(stream.word_index(tile, tap, ci));
                    for co in tile * stream.c..co_hi {
                        let neg = (word >> (co - tile * stream.c)) & 1 == 0;
                        masks[co * span + tap * nie + ci] = if neg { 0x8000_0000 } else { 0 };
                    }
                }
            }
        }
        PackedLayerWeights {
            masks,
            span,
            n_out: stream.n_out,
        }
    }

    /// The `taps × n_in_eff` sign-mask plane for output channel `co`.
    #[inline]
    pub fn channel(&self, co: usize) -> &[u32] {
        &self.masks[co * self.span..(co + 1) * self.span]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::ConvLayer;
    use crate::testkit;
    use crate::util::SplitMix64;

    fn layer(n_in: usize, n_out: usize, k: usize) -> ConvLayer {
        ConvLayer::new("t", n_in, n_out, 8, 8, k, 1)
    }

    #[test]
    fn stream_length_matches_schedule() {
        // Tbl I: 16→64 3×3 conv on C=16 → 4 tiles × 9 taps × 16 c_in words.
        let l = layer(16, 64, 3);
        let w = vec![1.0f32; 64 * 16 * 9];
        let s = pack_weights(&l, &w, 16);
        assert_eq!(s.word_count(), 4 * 9 * 16);
        assert_eq!(s.wire_bits(), 4 * 9 * 16 * 16);
    }

    #[test]
    fn wire_bits_equal_layer_weight_bits_when_c_divides() {
        let l = layer(16, 64, 3);
        let w = vec![-1.0f32; 64 * 16 * 9];
        assert_eq!(pack_weights(&l, &w, 16).wire_bits(), l.weight_bits());
    }

    #[test]
    fn padded_tail_channels_stream_plus_one() {
        let l = layer(4, 20, 1); // 20 outputs → 2 tiles of 16, 12 padded
        let w = vec![-1.0f32; 20 * 4];
        let s = pack_weights(&l, &w, 16);
        // Word for tile 1, tap 0, ci 0: bits 0..3 are real (−1 → 0),
        // bits 4..15 padding (+1 → 1).
        let word = s.word(s.word_index(1, 0, 0));
        assert_eq!(word & 0x000f, 0);
        assert_eq!(word & 0xfff0, 0xfff0);
    }

    #[test]
    fn pack_unpack_round_trip_property() {
        testkit::check("pack/unpack round trip", 0x5eed, |rng| {
            let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
            let n_in = 1 + rng.next_below(24);
            let n_out = 1 + rng.next_below(40);
            let l = layer(n_in, n_out, k);
            let w: Vec<f32> = (0..n_out * n_in * k * k)
                .map(|_| {
                    let v = rng.next_sym();
                    if v == 0.0 {
                        0.5
                    } else {
                        v
                    }
                })
                .collect();
            let s = pack_weights(&l, &w, 16);
            let dense = s.unpack_dense();
            for (i, (&orig, &got)) in w.iter().zip(&dense).enumerate() {
                let want = if binarize(orig) { 1.0 } else { -1.0 };
                if got != want {
                    return Err(format!("index {i}: {orig} → {got}, want {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pack_unpack_round_trip_odd_nout_and_groups() {
        // The padded-tail path: odd `n_out` (or odd multiples of the
        // group count) is never a multiple of C = 16, so the last
        // c_out tile always carries padding; grouped layers stream the
        // reduced `n_in / groups` fan-in.
        testkit::check("pack/unpack odd n_out + groups", 0x0dd5, |rng| {
            let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
            let groups = [1usize, 2, 4][rng.next_below(3)];
            let n_in = groups * (1 + rng.next_below(8));
            let n_out = groups * (2 * rng.next_below(20) + 1); // odd multiple
            let l = layer(n_in, n_out, k).with_groups(groups);
            let nie = n_in / groups;
            let w: Vec<f32> = (0..n_out * nie * k * k).map(|_| rng.next_sign()).collect();
            let s = pack_weights(&l, &w, 16);
            if s.n_in_eff != nie {
                return Err(format!("n_in_eff {} != {nie}", s.n_in_eff));
            }
            if s.wire_bits() % 16 != 0 {
                return Err(format!("wire bits {} not word-aligned", s.wire_bits()));
            }
            let dense = s.unpack_dense();
            if dense.len() != w.len() {
                return Err(format!("dense len {} != {}", dense.len(), w.len()));
            }
            for (i, (&orig, &got)) in w.iter().zip(&dense).enumerate() {
                if orig != got {
                    return Err(format!("index {i}: {orig} → {got}"));
                }
            }
            // Idle channels of the last tile stream +1 (never garbage).
            let tail = n_out % 16;
            if tail != 0 {
                let tile = n_out / 16;
                for tap in 0..k * k {
                    for ci in 0..nie {
                        let word = s.word(s.word_index(tile, tap, ci));
                        for b in tail..16 {
                            if word & (1 << b) == 0 {
                                return Err(format!(
                                    "padded bit {b} of tile {tile} tap {tap} ci {ci} is -1"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn round_trip_when_word_count_not_divisible_by_64() {
        // Deliberately awkward bitplane shapes: narrow `c` so stream
        // words straddle u64 boundaries, `n_in·k·k` and the word count
        // both not multiples of 64, plus the single-channel degenerate.
        testkit::check("pack/unpack vs u64 boundaries", 0xb17e5, |rng| {
            let c = 1 + rng.next_below(16); // any parallelism 1..=16
            let k = [1usize, 3][rng.next_below(2)];
            let n_in = 1 + rng.next_below(13); // n_in·k·k rarely % 64 == 0
            let n_out = 1 + rng.next_below(33);
            let l = layer(n_in, n_out, k);
            let w: Vec<f32> = (0..n_out * n_in * k * k).map(|_| rng.next_sign()).collect();
            let s = pack_weights(&l, &w, c);
            // Every word decodes to what a direct re-pack would emit.
            for wi in 0..s.word_count() {
                if s.word(wi) >> c != 0 {
                    return Err(format!("word {wi} has bits above lane {c}"));
                }
            }
            let dense = s.unpack_dense();
            for (i, (&orig, &got)) in w.iter().zip(&dense).enumerate() {
                if orig != got {
                    return Err(format!("c={c} index {i}: {orig} → {got}"));
                }
            }
            // Storage identity: wire bits = packed u64 words × 64 − padding.
            if s.wire_bits() != s.packed_words() as u64 * 64 - s.padding_bits() {
                return Err(format!(
                    "wire {} != {}·64 − {}",
                    s.wire_bits(),
                    s.packed_words(),
                    s.padding_bits()
                ));
            }
            if s.padding_bits() >= 64 {
                return Err(format!("padding {} ≥ 64", s.padding_bits()));
            }
            if s.packed_bytes() != packed_footprint_bytes(&l, c) {
                return Err(format!(
                    "packed_bytes {} != analytic {}",
                    s.packed_bytes(),
                    packed_footprint_bytes(&l, c)
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn single_channel_layer_packs_one_bit_per_word() {
        let l = layer(1, 1, 1);
        let s = pack_weights(&l, &[-2.0], 1);
        assert_eq!(s.word_count(), 1);
        assert_eq!(s.wire_bits(), 1);
        assert_eq!(s.packed_words(), 1);
        assert_eq!(s.padding_bits(), 63);
        assert_eq!(s.word(0), 0);
        assert_eq!(s.unpack_dense(), vec![-1.0]);
        assert_eq!(s.packed_bytes(), packed_footprint_bytes(&l, 1));
    }

    #[test]
    fn packed_layer_weights_match_weight_accessor() {
        testkit::check("mask planes vs weight()", 0x9a5c, |rng| {
            let c = 1 + rng.next_below(16);
            let k = [1usize, 3][rng.next_below(2)];
            let groups = [1usize, 2][rng.next_below(2)];
            let n_in = groups * (1 + rng.next_below(6));
            let n_out = groups * (1 + rng.next_below(12));
            let l = layer(n_in, n_out, k).with_groups(groups);
            let nie = n_in / groups;
            let w: Vec<f32> = (0..n_out * nie * k * k).map(|_| rng.next_sign()).collect();
            let s = pack_weights(&l, &w, c);
            let packed = PackedLayerWeights::new(&s);
            for co in 0..n_out {
                let plane = packed.channel(co);
                for tap in 0..k * k {
                    for ci in 0..nie {
                        let want = if s.weight(co, ci, tap) > 0.0 {
                            0
                        } else {
                            0x8000_0000
                        };
                        if plane[tap * nie + ci] != want {
                            return Err(format!("mask mismatch at co={co} tap={tap} ci={ci}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn network_packed_bytes_sums_layers() {
        use crate::network::TensorRef;
        let mut net = Network::new("t", 16, 8, 8);
        let s0 = net.push(layer(16, 64, 3), TensorRef::Input, None);
        net.push(layer(64, 20, 1), TensorRef::Step(s0), None);
        let want: u64 = net
            .steps
            .iter()
            .map(|s| packed_footprint_bytes(&s.layer, 16))
            .sum();
        assert_eq!(network_packed_bytes(&net, 16), want);
        assert!(want > 0);
    }

    #[test]
    fn sign_zero_is_plus_one() {
        assert!(binarize(0.0));
        assert!(binarize(1e-30));
        assert!(!binarize(-1e-30));
    }

    #[test]
    fn grouped_layer_streams_reduced_fan_in() {
        let l = layer(16, 32, 3).with_groups(4); // n_in_eff = 4
        let w: Vec<f32> = (0..32 * 4 * 9).map(|i| i as f32 - 300.0).collect();
        let s = pack_weights(&l, &w, 16);
        assert_eq!(s.n_in_eff, 4);
        assert_eq!(s.word_count(), 2 * 9 * 4);
        assert_eq!(s.wire_bits(), l.weight_bits());
    }

    #[test]
    fn unpack_word_bit_order() {
        let signs = unpack_word(0b0000_0000_0000_0101, 4);
        assert_eq!(signs, vec![1.0, -1.0, 1.0, -1.0]);
    }

    #[test]
    fn deterministic_for_same_input() {
        let mut rng = SplitMix64::new(11);
        let l = layer(8, 16, 3);
        let w: Vec<f32> = (0..16 * 8 * 9).map(|_| rng.next_sym()).collect();
        assert_eq!(pack_weights(&l, &w, 16), pack_weights(&l, &w, 16));
    }
}

//! Weight-buffer model (§III / §VI): a latch-based standard-cell memory
//! holding the binary weights of the *current* C output channels for all
//! input channels — so each weight crosses the chip boundary exactly once
//! per layer and is re-read from the (43× cheaper) SCM for every pixel.
//!
//! Capacity of the taped-out chip: 512 kernels × 3·3 taps × C = 73 728
//! bits (5×8 SCM blocks of 128×16 bit). Layers with more than 512 input
//! channels are tiled into 512-channel blocks with on-the-fly partial-sum
//! accumulation via the bypass path (§VI).

use crate::network::ConvLayer;

use super::stream::WeightStream;

/// Access statistics of one layer pass through the weight buffer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WBufStats {
    /// Words fetched from the off-chip stream (compulsory misses).
    pub stream_words: u64,
    /// Words served from the buffer (re-use across pixels).
    pub buffer_reads: u64,
    /// Number of input-channel tiles the layer needed (> 1 when the
    /// layer's weights exceed the buffer).
    pub cin_tiles: u64,
    /// True resident footprint of the layer's packed stream, in bytes
    /// (`u64` bitplanes at 1 bit/weight — `WeightStream::packed_bytes`).
    pub packed_bytes: u64,
}

/// The weight buffer of one chip.
#[derive(Debug, Clone)]
pub struct WeightBuffer {
    /// Capacity in binary weights.
    pub capacity_bits: usize,
    /// Output-channel parallelism (bits per stream word).
    pub c: usize,
}

impl WeightBuffer {
    pub fn new(capacity_bits: usize, c: usize) -> Self {
        WeightBuffer { capacity_bits, c }
    }

    /// Maximum input channels whose `k×k` kernels (for C outputs) fit.
    pub fn max_cin(&self, k: usize) -> usize {
        self.capacity_bits / (k * k * self.c)
    }

    /// Whether a layer's per-tile working set fits without c_in tiling.
    pub fn fits(&self, layer: &ConvLayer) -> bool {
        (layer.n_in / layer.groups) <= self.max_cin(layer.k)
    }

    /// Number of input-channel tiles needed for a layer.
    pub fn cin_tiles(&self, layer: &ConvLayer) -> usize {
        (layer.n_in / layer.groups).div_ceil(self.max_cin(layer.k))
    }

    /// Simulate one layer: every stream word is written once into the
    /// buffer (per c_in tile) and re-read once per pixel of the tile
    /// thereafter (Algorithm 1 lines 10–14).
    pub fn run_layer(&self, layer: &ConvLayer, stream: &WeightStream, tile_pixels: u64) -> WBufStats {
        assert_eq!(stream.c, self.c);
        let cin_tiles = self.cin_tiles(layer) as u64;
        let stream_words = stream.word_count() as u64;
        // Each word is used `tile_pixels` times per layer; the first use
        // comes from the stream, the rest from the buffer.
        let total_uses = stream_words * tile_pixels.max(1);
        WBufStats {
            stream_words,
            buffer_reads: total_uses - stream_words,
            cin_tiles,
            packed_bytes: stream.packed_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bwn::stream::pack_weights;
    use crate::network::ConvLayer;
    use crate::ChipConfig;

    fn wbuf() -> WeightBuffer {
        let cfg = ChipConfig::default();
        WeightBuffer::new(cfg.wbuf_bits, cfg.c)
    }

    #[test]
    fn taped_out_capacity_holds_512_kernels() {
        let b = wbuf();
        assert_eq!(b.max_cin(3), 512);
        assert_eq!(b.max_cin(1), 4608);
    }

    #[test]
    fn resnet_layers_fit_without_tiling() {
        let b = wbuf();
        let l = ConvLayer::new("c", 512, 512, 7, 7, 3, 1);
        assert!(b.fits(&l));
        assert_eq!(b.cin_tiles(&l), 1);
    }

    #[test]
    fn deep_1024_channel_layer_tiles_twice_for_3x3() {
        let b = wbuf();
        let l = ConvLayer::new("deep", 1024, 1024, 10, 10, 3, 1);
        assert!(!b.fits(&l));
        assert_eq!(b.cin_tiles(&l), 2);
    }

    #[test]
    fn stream_loaded_once_rest_from_buffer() {
        let b = wbuf();
        let l = ConvLayer::new("c", 16, 64, 56, 56, 3, 1);
        let w = vec![1.0f32; 64 * 16 * 9];
        let s = pack_weights(&l, &w, 16);
        let stats = b.run_layer(&l, &s, 64); // 8×8 pixels per tile
        assert_eq!(stats.stream_words, 4 * 9 * 16);
        assert_eq!(stats.buffer_reads, (4 * 9 * 16) * 63);
        assert_eq!(stats.cin_tiles, 1);
        // 4·9·16 words × 16 bits = 9216 bits → 144 u64 planes.
        assert_eq!(stats.packed_bytes, s.packed_bytes());
        assert_eq!(stats.packed_bytes, 144 * 8);
        // Total SCM traffic must equal uses exactly.
        assert_eq!(
            stats.stream_words + stats.buffer_reads,
            (4 * 9 * 16) * 64
        );
    }

    #[test]
    fn grouped_conv_reduces_buffer_pressure() {
        let b = wbuf();
        let dense = ConvLayer::new("d", 1536, 1536, 7, 7, 1, 1);
        let grouped = dense.clone().with_groups(8);
        assert_eq!(b.cin_tiles(&dense), 1); // 1×1 → 4608 cin fit
        assert_eq!(b.cin_tiles(&grouped), 1);
        let dw = ConvLayer::new("dw", 1536, 1536, 7, 7, 3, 1).with_groups(1536);
        assert!(b.fits(&dw));
    }
}

//! Border & Corner memory sizing and the exchange protocol bookkeeping
//! (§V-B/C, the blue blocks of Fig 1).
//!
//! Pixels owned by a neighbouring chip but needed for this chip's halo
//! are *sent once* after computation and stored locally in the Border
//! Memory (BM, two physically separate blocks so a vertical and a
//! horizontal read can happen in one cycle) or Corner Memory (CM, for
//! the diagonal neighbours' ⌊k/2⌋² patches, forwarded via the vertical
//! neighbour — no diagonal wires).

use crate::network::Network;
use crate::util::ceil_div;

use super::wcl::MemoryAnalysis;

/// Border-memory requirement in bits (§V-C formula): the WCL scaled by
/// the perimeter-to-area ratio of the per-chip tile at the WCL step.
///
/// For single-chip ResNet-34 at 224² (tile = 56×56) this is the paper's
/// 459 kbit (a 7% overhead on the 6.4 Mbit FMM).
pub fn border_memory_bits(
    net: &Network,
    analysis: &MemoryAnalysis,
    mesh_rows: usize,
    mesh_cols: usize,
    fm_bits: usize,
) -> u64 {
    let step = &net.steps[analysis.wcl_step];
    let (th, tw) = (
        ceil_div(step.layer.h, mesh_rows),
        ceil_div(step.layer.w, mesh_cols),
    );
    let m_bits = analysis.wcl_words * fm_bits as u64;
    // M · (2h + 2w)/(h·w), evaluated on the per-chip tile.
    m_bits * (2 * (th + tw)) as u64 / (th * tw) as u64
}

/// Corner-memory requirement in bits (§V-C): the deepest layer dominates
/// (`(n_in + n_out) · 4 corners · ⌊k/2⌋²` pixels) — striding does not
/// shrink it.
pub fn corner_memory_bits(net: &Network, fm_bits: usize) -> u64 {
    net.steps
        .iter()
        .map(|s| {
            let l = &s.layer;
            let halo = (l.k / 2) as u64;
            ((l.n_in + l.n_out) as u64) * 4 * halo * halo * fm_bits as u64
        })
        .max()
        .unwrap_or(0)
}

/// Physical BM implementation check: the taped-out chip uses 4
/// high-density single-port SRAMs of 1024 × (M·16 = 112) bit.
pub fn border_memory_srams(bm_bits: u64, m: usize, fm_bits: usize) -> u64 {
    let word = (m * fm_bits) as u64;
    ceil_div(ceil_div(bm_bits as usize, word as usize), 1024) as u64
}

/// Exchange-protocol state per chip border (§V-B): a border row/column
/// sent sets `awaiting_opposite` until the symmetric pixel arrives; a
/// corner additionally sets forwarding flags on the vertical neighbour.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExchangeFlags {
    /// Border pixels sent, waiting for the opposite neighbour's pixel.
    pub awaiting: u64,
    /// Satisfied waits (pixel pairs completed).
    pub completed: u64,
    /// Corner forwards performed on behalf of a diagonal neighbour.
    pub forwards: u64,
}

impl ExchangeFlags {
    /// Record sending a border pixel (sets the wait flag).
    pub fn sent(&mut self) {
        self.awaiting += 1;
    }

    /// Record receiving the symmetric pixel (clears one wait flag).
    pub fn received(&mut self) {
        assert!(self.awaiting > 0, "received without matching send");
        self.awaiting -= 1;
        self.completed += 1;
    }

    /// Record forwarding a corner pixel for a diagonal neighbour.
    pub fn forwarded(&mut self) {
        self.forwards += 1;
    }

    /// Protocol invariant at layer end: no outstanding waits.
    pub fn is_quiescent(&self) -> bool {
        self.awaiting == 0
    }
}

/// Serial border-interface cost model (§V-D): pixels cross chip-to-chip
/// links in 4-bit flits + 1 valid bit.
pub fn link_flits(pixels: u64, fm_bits: usize) -> u64 {
    pixels * ceil_div(fm_bits, 4) as u64
}

/// Border-interface buffer of the taped-out chip: `M·C = 7·16 = 112`
/// pixel entries per side (§V-D).
pub const BI_BUFFER_ENTRIES: usize = 112;

/// Per-layer exchange-vs-compute slack on a mesh (§V: "even with the
/// overhead of exchanging the border pixels").
///
/// A chip's border interface serializes its outgoing border pixels at
/// one 4-bit flit per cycle per link; the transfer of layer *l*'s halo
/// overlaps the remaining computation of layer *l* and the start of
/// layer *l+1* on interior pixels. Exchange is "hidden" when the flit
/// time of the busiest link is below the next layer's compute cycles.
#[derive(Debug, Clone)]
pub struct ExchangeSlack {
    pub layer: String,
    /// Flit cycles on the busiest outgoing link of any chip.
    pub exchange_cycles: u64,
    /// Compute cycles of the consuming layer (per chip).
    pub next_compute_cycles: u64,
}

impl ExchangeSlack {
    /// Exchange fully hidden under the next layer's compute?
    pub fn hidden(&self) -> bool {
        self.exchange_cycles <= self.next_compute_cycles
    }
}

/// Compute the exchange slack per producing layer for a mesh run.
pub fn exchange_slack(
    net: &Network,
    cfg: &crate::ChipConfig,
    rows: usize,
    cols: usize,
) -> Vec<ExchangeSlack> {
    use crate::coordinator::schedule::{layer_cycles_mesh, DepthwisePolicy};
    use crate::network::TensorRef;
    let tid = |r: TensorRef| match r {
        TensorRef::Input => 0usize,
        TensorRef::Step(i) => 1 + i,
    };
    // halo + first consumer index per tensor.
    let n = net.steps.len();
    let mut halo = vec![0usize; n + 1];
    let mut consumer = vec![None::<usize>; n + 1];
    for (i, s) in net.steps.iter().enumerate() {
        let h = s.layer.k / 2;
        for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
            let t = tid(r);
            halo[t] = halo[t].max(h);
            if consumer[t].is_none() {
                consumer[t] = Some(i);
            }
        }
    }
    let mut out = Vec::new();
    for (i, s) in net.steps.iter().enumerate() {
        let hw = halo[1 + i] as u64;
        let Some(ci) = consumer[1 + i] else { continue };
        if hw == 0 {
            continue;
        }
        let l = &s.layer;
        // Busiest link: a full tile edge row/column × n_out channels.
        let tile_h = ceil_div(l.h_out(), rows) as u64;
        let tile_w = ceil_div(l.w_out(), cols) as u64;
        let edge_pixels = hw * tile_h.max(tile_w) * l.n_out as u64;
        let exchange_cycles = link_flits(edge_pixels, 16);
        let next = layer_cycles_mesh(
            &net.steps[ci].layer,
            cfg,
            DepthwisePolicy::FullRate,
            rows,
            cols,
        )
        .total();
        out.push(ExchangeSlack {
            layer: l.name.clone(),
            exchange_cycles,
            next_compute_cycles: next,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::wcl;
    use crate::model;

    #[test]
    fn resnet34_border_memory_is_459_kbit() {
        let net = model::network("resnet34@224x224").unwrap();
        let a = wcl::analyze(&net);
        let bm = border_memory_bits(&net, &a, 1, 1, 16);
        // §V-C: M · (2·56+2·56)/(56·56) = 459 kbit (+7% of 6.4 Mbit).
        assert_eq!(bm, 6_422_528 * 224 / 3136);
        assert!((bm as f64 / 459e3 - 1.0).abs() < 0.01, "bm {bm}");
        let overhead = bm as f64 / a.wcl_bits(16) as f64;
        assert!((overhead - 0.07).abs() < 0.005, "overhead {overhead}");
    }

    #[test]
    fn resnet34_corner_memory_is_64_kbit() {
        // §V-C: (512+512) · 4 · 1 · 1 · 16 bit = 64 kbit.
        let net = model::network("resnet34@224x224").unwrap();
        assert_eq!(corner_memory_bits(&net, 16), 65_536);
    }

    #[test]
    fn bm_fits_four_srams_like_silicon() {
        let net = model::network("resnet34@224x224").unwrap();
        let a = wcl::analyze(&net);
        let bm = border_memory_bits(&net, &a, 1, 1, 16);
        assert_eq!(border_memory_srams(bm, 7, 16), 4);
    }

    #[test]
    fn corner_memory_ignores_1x1_layers() {
        let net = model::network("resnet50@224x224").unwrap();
        // Bottleneck nets still size CM from their 3×3 layers (mid
        // channels), not the wide 1×1s.
        let cm = corner_memory_bits(&net, 16);
        assert_eq!(cm, (512 + 512) * 4 * 16);
    }

    #[test]
    fn exchange_flags_protocol() {
        let mut f = ExchangeFlags::default();
        f.sent();
        f.sent();
        assert!(!f.is_quiescent());
        f.received();
        f.received();
        assert!(f.is_quiescent());
        assert_eq!(f.completed, 2);
        f.forwarded();
        assert_eq!(f.forwards, 1);
    }

    #[test]
    #[should_panic(expected = "received without matching send")]
    fn unmatched_receive_panics() {
        ExchangeFlags::default().received();
    }

    #[test]
    fn link_serialization_is_4bit_flits() {
        assert_eq!(link_flits(1, 16), 4);
        assert_eq!(link_flits(112, 16), 448); // one BM buffer line
    }

    #[test]
    fn exchange_hides_under_compute_on_paper_mesh() {
        // §V: the border exchange must not become the bottleneck on the
        // paper's 10×5 ResNet-34 @2k×1k configuration.
        let net = model::network("resnet34@1024x2048").unwrap();
        let slacks = exchange_slack(&net, &crate::ChipConfig::default(), 5, 10);
        assert!(!slacks.is_empty());
        let hidden = slacks.iter().filter(|s| s.hidden()).count();
        assert_eq!(
            hidden,
            slacks.len(),
            "unhidden exchanges: {:?}",
            slacks
                .iter()
                .filter(|s| !s.hidden())
                .map(|s| (&s.layer, s.exchange_cycles, s.next_compute_cycles))
                .collect::<Vec<_>>()
        );
        // And with healthy margin on the big 3×3 layers.
        let worst = slacks
            .iter()
            .map(|s| s.exchange_cycles as f64 / s.next_compute_cycles as f64)
            .fold(0.0, f64::max);
        assert!(worst < 0.5, "worst exchange/compute ratio {worst}");
    }

    #[test]
    fn bi_buffer_matches_taped_out_dimensions() {
        assert_eq!(BI_BUFFER_ENTRIES, 7 * 16);
    }
}

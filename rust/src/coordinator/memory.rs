//! Concrete FMM segment allocation for the inference path — the
//! generalized M1/M2/M3/M4 ping-pong plan of §IV-B.
//!
//! Walks the network in step order, placing every tensor in free regions
//! of the (word-addressed) FMM, freeing tensors after their last
//! consumer, and aliasing a bypass step's output onto the bypass tensor's
//! storage (the in-place read-add-write of §IV-B). A tensor may occupy
//! multiple non-contiguous extents: the FMM is multi-banked and the paper
//! itself splits segments ("M2 is split into two equal-size segments M2.1
//! and M2.2"), so contiguity is not a hardware requirement. The plan's
//! peak must equal the WCL analysis exactly (tested), proving the §IV-B
//! scheme is realizable with zero memory overhead.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::network::{Network, TensorRef};

use super::wcl;

/// One contiguous extent of a placed tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    /// Word offset in the FMM.
    pub offset: u64,
    /// Size in words.
    pub words: u64,
}

/// A placed FM tensor: one or more extents (paper's M2.1/M2.2 splitting).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Placement {
    pub extents: Vec<Extent>,
}

impl Placement {
    pub fn words(&self) -> u64 {
        self.extents.iter().map(|e| e.words).sum()
    }

    /// First extent's offset (canonical identity for aliasing checks).
    pub fn base(&self) -> u64 {
        self.extents.first().map_or(u64::MAX, |e| e.offset)
    }
}

/// The memory plan for one network on one chip (or one chip's tile).
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// Placement of the network input.
    pub input: Placement,
    /// Placement per step output (bypass-aliased steps share placements).
    pub outputs: Vec<Placement>,
    /// Peak allocated words over the whole run.
    pub peak_words: u64,
    /// FMM capacity the plan was made for.
    pub capacity_words: u64,
}

/// First-fit arena over free word-ranges, allowing split allocations.
struct Arena {
    capacity: u64,
    /// offset → length of free ranges.
    free: BTreeMap<u64, u64>,
    allocated: u64,
    peak: u64,
}

impl Arena {
    fn new(capacity: u64) -> Self {
        let mut free = BTreeMap::new();
        if capacity > 0 {
            free.insert(0, capacity);
        }
        Arena {
            capacity,
            free,
            allocated: 0,
            peak: 0,
        }
    }

    /// Allocate `words`, possibly split across several free ranges
    /// (lowest offsets first).
    fn alloc(&mut self, words: u64) -> Result<Placement> {
        if self.capacity - self.allocated < words {
            bail!(
                "FMM allocation of {words} words failed ({} free of {})",
                self.capacity - self.allocated,
                self.capacity
            );
        }
        let mut remaining = words;
        let mut extents = Vec::new();
        while remaining > 0 {
            let (&off, &len) = self.free.iter().next().expect("free space accounted");
            let take = len.min(remaining);
            self.free.remove(&off);
            if len > take {
                self.free.insert(off + take, len - take);
            }
            extents.push(Extent {
                offset: off,
                words: take,
            });
            remaining -= take;
        }
        self.allocated += words;
        self.peak = self.peak.max(self.allocated);
        Ok(Placement { extents })
    }

    fn release(&mut self, p: &Placement) {
        for e in &p.extents {
            if e.words == 0 {
                continue;
            }
            self.allocated -= e.words;
            let mut off = e.offset;
            let mut len = e.words;
            if let Some((&prev_off, &prev_len)) = self.free.range(..off).next_back() {
                if prev_off + prev_len == off {
                    self.free.remove(&prev_off);
                    off = prev_off;
                    len += prev_len;
                }
            }
            if let Some(&next_len) = self.free.get(&(off + len)) {
                self.free.remove(&(off + len));
                len += next_len;
            }
            self.free.insert(off, len);
        }
    }
}

/// Plan FMM placements for a network. `capacity_words` is the FMM size
/// (per chip; pass the per-chip tile network view for meshes).
pub fn plan(net: &Network, capacity_words: u64) -> Result<MemoryPlan> {
    let n = net.steps.len();
    let tid = |r: TensorRef| match r {
        TensorRef::Input => 0usize,
        TensorRef::Step(i) => 1 + i,
    };
    // Death step per tensor (final outputs are never freed).
    let mut death = vec![-1isize; n + 1];
    death[0] = 0;
    for (i, s) in net.steps.iter().enumerate() {
        for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
            death[tid(r)] = death[tid(r)].max(i as isize);
        }
    }
    // Aliased storage roots (bypass in-place accumulation).
    let mut storage_of = (0..=n).collect::<Vec<usize>>();
    for (i, s) in net.steps.iter().enumerate() {
        if let Some(b) = s.bypass {
            storage_of[1 + i] = storage_of[tid(b)];
        }
    }
    // Effective death of a root = max over its alias chain; the network's
    // final tensor is pinned (death = n).
    let mut root_death = death.clone();
    for t in 0..=n {
        let r = storage_of[t];
        if r != t {
            root_death[r] = root_death[r].max(death[t]);
        }
    }
    root_death[storage_of[n]] = root_death[storage_of[n]].max(n as isize);

    let mut arena = Arena::new(capacity_words);
    let mut placements: Vec<Option<Placement>> = vec![None; n + 1];
    let input_words = (net.in_ch * net.in_h * net.in_w) as u64;
    placements[0] = Some(arena.alloc(input_words)?);

    for (i, s) in net.steps.iter().enumerate() {
        let t = 1 + i;
        let root = storage_of[t];
        if root != t {
            // In-place accumulation into the bypass tensor's placement.
            let p = placements[root].clone().expect("bypass placement live");
            assert_eq!(
                p.words(),
                s.layer.out_words(),
                "aliased placement size mismatch at `{}`",
                s.layer.name
            );
            placements[t] = Some(p);
        } else {
            placements[t] = Some(arena.alloc(s.layer.out_words())?);
        }
        // Free every root storage whose last use is this step.
        for t2 in 0..=n {
            if storage_of[t2] == t2 && root_death[t2] == i as isize {
                if let Some(p) = &placements[t2] {
                    arena.release(p);
                }
            }
        }
    }

    Ok(MemoryPlan {
        input: placements[0].clone().unwrap(),
        outputs: (0..n).map(|i| placements[1 + i].clone().unwrap()).collect(),
        peak_words: arena.peak,
        capacity_words,
    })
}

/// Plan against the exact WCL capacity — must succeed with zero slack for
/// every zoo network (the §IV-B realizability claim).
pub fn plan_tight(net: &Network) -> Result<MemoryPlan> {
    let a = wcl::analyze(net);
    plan(net, a.wcl_words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;
    use crate::network::{ConvLayer, Network};

    #[test]
    fn resnet34_plans_tight_at_wcl() {
        // The allocator realizes the paper's 401 kword plan exactly.
        let net = model::network("resnet34@224x224").unwrap();
        let p = plan_tight(&net).unwrap();
        assert_eq!(p.peak_words, 401_408);
    }

    #[test]
    fn resnet50_and_152_plan_tight_at_wcl() {
        for net in [model::network("resnet50@224x224").unwrap(), model::network("resnet152@224x224").unwrap()] {
            let p = plan_tight(&net).unwrap();
            assert_eq!(p.peak_words, wcl::analyze(&net).wcl_words, "{}", net.name);
        }
    }

    #[test]
    fn hypernet20_plan_is_tight_and_aliased() {
        let net = model::network("hypernet20").unwrap();
        let p = plan_tight(&net).unwrap();
        assert_eq!(p.peak_words, 2 * 16 * 32 * 32);
        // Bypass steps share their shortcut's placement (here: the input).
        let c2 = net.step_by_name("s1b0c2").unwrap();
        assert_eq!(p.outputs[c2], p.input);
    }

    #[test]
    fn over_capacity_fails_cleanly() {
        let net = model::network("resnet34@224x224").unwrap();
        let err = plan(&net, 100_000).unwrap_err().to_string();
        assert!(err.contains("FMM allocation"), "{err}");
    }

    #[test]
    fn live_placements_never_overlap() {
        // At every step, gather placements of all live root tensors and
        // assert extent-level disjointness.
        let net = model::network("resnet50@224x224").unwrap();
        let a = wcl::analyze(&net);
        let p = plan(&net, a.wcl_words).unwrap();
        let n = net.steps.len();
        // Recompute deaths/roots the same way the planner does.
        let tid = |r: crate::network::TensorRef| match r {
            crate::network::TensorRef::Input => 0usize,
            crate::network::TensorRef::Step(i) => 1 + i,
        };
        let mut death = vec![-1isize; n + 1];
        death[0] = 0;
        for (i, s) in net.steps.iter().enumerate() {
            for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
                death[tid(r)] = death[tid(r)].max(i as isize);
            }
        }
        let mut storage_of = (0..=n).collect::<Vec<usize>>();
        for (i, s) in net.steps.iter().enumerate() {
            if let Some(b) = s.bypass {
                storage_of[1 + i] = storage_of[tid(b)];
            }
        }
        let mut root_death = death.clone();
        for t in 0..=n {
            let r = storage_of[t];
            if r != t {
                root_death[r] = root_death[r].max(death[t]);
            }
        }
        let place = |t: usize| -> &Placement {
            if t == 0 {
                &p.input
            } else {
                &p.outputs[t - 1]
            }
        };
        for i in 0..n {
            let mut live: Vec<&Placement> = Vec::new();
            for t in 0..=n {
                if storage_of[t] != t {
                    continue;
                }
                let birth = t as isize - 1;
                if birth <= i as isize && root_death[t] >= i as isize {
                    live.push(place(t));
                }
            }
            let mut spans: Vec<(u64, u64)> = live
                .iter()
                .flat_map(|pl| pl.extents.iter().map(|e| (e.offset, e.offset + e.words)))
                .collect();
            spans.sort_unstable();
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0, "overlap at step {i}: {:?}", w);
            }
        }
    }

    #[test]
    fn ping_pong_chain_alternates_two_segments() {
        let mut net = Network::new("chain", 16, 8, 8);
        let mut prev = crate::network::TensorRef::Input;
        for i in 0..4 {
            prev = crate::network::TensorRef::Step(net.push(
                ConvLayer::new(format!("c{i}"), 16, 16, 8, 8, 3, 1),
                prev,
                None,
            ));
        }
        let p = plan_tight(&net).unwrap();
        assert_eq!(p.peak_words, 2 * 16 * 64);
        // Outputs alternate between exactly two placements.
        assert_eq!(p.outputs[0].base(), p.outputs[2].base());
        assert_eq!(p.outputs[1].base(), p.outputs[3].base());
        assert_ne!(p.outputs[0].base(), p.outputs[1].base());
    }

    #[test]
    fn split_allocation_when_fragmented() {
        // Force fragmentation: a strided bottleneck-like pattern where
        // the only way to fit is a split tensor (M2.1/M2.2 of §IV-B).
        let net = model::network("resnet50@224x224").unwrap();
        let p = plan_tight(&net).unwrap();
        let any_split = p.outputs.iter().any(|pl| pl.extents.len() > 1);
        assert!(any_split, "expected at least one split placement");
    }
}

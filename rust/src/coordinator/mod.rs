//! The Hyperdrive coordinator — the paper's system contribution at L3.
//!
//! * [`wcl`] — worst-case-layer memory analysis (§IV-B): liveness over the
//!   network graph with the paper's in-place bypass-accumulation rule;
//!   sizes the FMM and decides multi-chip requirements (Tbl II).
//! * [`memory`] — the concrete ping-pong segment allocator used on the
//!   inference path (M1/M2/M3/M4 of §IV-B generalized to first-fit over
//!   graph liveness).
//! * [`schedule`] — Algorithm 1 as an explicit cycle schedule: weight
//!   stream order (Tbl I), weight-buffer traffic, per-layer cycle counts.
//! * [`tiling`] — the m×n systolic mesh planner (§V): per-chip FM tiles,
//!   chip types (NW/N/NE/…/Center), border-exchange traffic (Fig 11).
//! * [`border`] — border/corner memory sizing (§V-C) and the exchange
//!   protocol bookkeeping (§V-B).

pub mod border;
pub mod memory;
pub mod schedule;
pub mod tiling;
pub mod wcl;

pub use tiling::MeshPlan;
pub use wcl::MemoryAnalysis;

//! Algorithm 1 as an explicit schedule: cycle counts per layer phase
//! (conv / bnorm / bias / bypass — Tbl III), utilization (Tbl VI), the
//! weight-stream trace of Tbl I, and per-layer stream/buffer traffic.

use crate::network::{ConvLayer, Network};
use crate::util::ceil_div;
use crate::ChipConfig;

/// How depth-wise convolutions map onto the Tile-PU array.
///
/// The C Tile-PUs of a spatial tile share one FMM-bank read port; for a
/// depth-wise layer every PU needs a *different* input channel, so the
/// reads serialize ([`BankSerialized`], the faithful model — §IV-C's "no
/// local re-use of the input feature map data possible"). The paper's
/// ShuffleNet utilization figure (98.8%, Tbl VI) is only reachable if
/// depth-wise taps run at full rate ([`FullRate`]); both are provided and
/// the gap is reported in EXPERIMENTS.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DepthwisePolicy {
    /// One input word feeds all C Tile-PUs every cycle (optimistic).
    FullRate,
    /// Depth-wise reads serialize on the FMM bank port (realistic).
    #[default]
    BankSerialized,
}

/// Cycle counts of one layer, split by phase (Tbl III rows).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerCycles {
    pub conv: u64,
    pub bnorm: u64,
    pub bias: u64,
    pub bypass: u64,
}

impl LayerCycles {
    pub fn total(&self) -> u64 {
        self.conv + self.bnorm + self.bias + self.bypass
    }
}

/// Spatial pixels each Tile-PU processes for a layer's output FM
/// (zero-padded up to the M×N grid — the idle-tile effect behind
/// YOLOv3's 82.8% utilization).
pub fn tile_pixels(layer: &ConvLayer, cfg: &ChipConfig) -> u64 {
    tile_pixels_mesh(layer, cfg, 1, 1)
}

/// Per-Tile-PU pixels when the FM is additionally tiled over a
/// `rows×cols` chip mesh (§V): the global grid is `(M·rows)×(N·cols)`.
pub fn tile_pixels_mesh(layer: &ConvLayer, cfg: &ChipConfig, rows: usize, cols: usize) -> u64 {
    (ceil_div(layer.h_out(), cfg.m * rows) * ceil_div(layer.w_out(), cfg.n * cols)) as u64
}

/// Cycle model of one layer on one chip (Algorithm 1 loop nest).
pub fn layer_cycles(layer: &ConvLayer, cfg: &ChipConfig, dw: DepthwisePolicy) -> LayerCycles {
    layer_cycles_mesh(layer, cfg, dw, 1, 1)
}

/// Cycle model of one layer on a chip mesh (all chips run in lockstep;
/// the per-chip tile is what each chip's Tile-PUs iterate over).
pub fn layer_cycles_mesh(
    layer: &ConvLayer,
    cfg: &ChipConfig,
    dw: DepthwisePolicy,
    rows: usize,
    cols: usize,
) -> LayerCycles {
    let cout_tiles = ceil_div(layer.n_out, cfg.c) as u64;
    let tp = tile_pixels_mesh(layer, cfg, rows, cols);
    let taps = (layer.k * layer.k) as u64;
    let n_in_eff = (layer.n_in / layer.groups) as u64;

    let serial = if layer.is_depthwise() && dw == DepthwisePolicy::BankSerialized {
        cfg.c as u64 // C PUs contend for the bank port
    } else {
        1
    };
    let conv = cout_tiles * tp * taps * n_in_eff * serial;

    // Post-processing at one op per spatial tile per cycle (49 shared
    // FP16 multipliers / the 49-word memory bandwidth, §VI-B).
    let post = cout_tiles * cfg.c as u64 * tp;
    let bnorm = if layer.bnorm { post } else { 0 };
    let bias = post;
    // Separate read-add bypass pass only at strided/projected junctions
    // (identity bypasses are fused into the conv write-back for free).
    let bypass = if layer.has_bypass && layer.bypass_separate {
        2 * post // read pass + accumulate/write pass
    } else {
        0
    };

    LayerCycles {
        conv,
        bnorm,
        bias,
        bypass,
    }
}

/// Whole-network schedule summary (Tbl III / Tbl VI).
#[derive(Debug, Clone, Default)]
pub struct NetworkSchedule {
    pub cycles: LayerCycles,
    /// Op counts by the same phases (from the graph IR).
    pub conv_ops: u64,
    pub bnorm_ops: u64,
    pub bias_ops: u64,
    pub bypass_ops: u64,
    /// Weight-stream bits crossing the chip boundary (padded to C).
    pub stream_bits: u64,
    /// Weight-buffer reads (re-use hits).
    pub wbuf_reads: u64,
    pub per_layer: Vec<(String, LayerCycles)>,
}

impl NetworkSchedule {
    pub fn total_cycles(&self) -> u64 {
        self.cycles.total()
    }

    pub fn total_ops(&self) -> u64 {
        self.conv_ops + self.bnorm_ops + self.bias_ops + self.bypass_ops
    }

    /// Real throughput in Op/cycle (Tbl III "total" row).
    pub fn ops_per_cycle(&self) -> f64 {
        self.total_ops() as f64 / self.total_cycles() as f64
    }

    /// Utilization: actual vs peak throughput (Tbl VI).
    pub fn utilization(&self, cfg: &ChipConfig) -> f64 {
        self.ops_per_cycle() / cfg.ops_per_cycle() as f64
    }

    /// Convolution-phase utilization: conv ops over conv cycles only.
    ///
    /// Isolates the spatial/channel padding losses (idle Tile-PUs) from
    /// the 49-word-bandwidth post-processing phases — the quantity behind
    /// the paper's per-network utilization narrative for topologies whose
    /// 1×1-dominated blocks make the post phases non-negligible.
    pub fn conv_utilization(&self, cfg: &ChipConfig) -> f64 {
        (self.conv_ops as f64 / self.cycles.conv as f64) / cfg.ops_per_cycle() as f64
    }
}

/// Schedule a whole network on one chip.
pub fn schedule_network(net: &Network, cfg: &ChipConfig, dw: DepthwisePolicy) -> NetworkSchedule {
    schedule_network_mesh(net, cfg, dw, 1, 1)
}

/// Schedule a whole network on a `rows×cols` chip mesh (per-chip cycles;
/// all chips run the same schedule in lockstep, §V-A).
pub fn schedule_network_mesh(
    net: &Network,
    cfg: &ChipConfig,
    dw: DepthwisePolicy,
    rows: usize,
    cols: usize,
) -> NetworkSchedule {
    let mut s = NetworkSchedule::default();
    for step in &net.steps {
        let l = &step.layer;
        let lc = layer_cycles_mesh(l, cfg, dw, rows, cols);
        s.cycles.conv += lc.conv;
        s.cycles.bnorm += lc.bnorm;
        s.cycles.bias += lc.bias;
        s.cycles.bypass += lc.bypass;
        s.conv_ops += l.conv_ops();
        s.bnorm_ops += l.bnorm_ops();
        s.bias_ops += l.bias_ops();
        s.bypass_ops += l.bypass_ops();
        let stream_words =
            ceil_div(l.n_out, cfg.c) as u64 * (l.k * l.k) as u64 * (l.n_in / l.groups) as u64;
        s.stream_bits += stream_words * cfg.c as u64;
        s.wbuf_reads += stream_words * (tile_pixels_mesh(l, cfg, rows, cols).max(1) - 1);
        s.per_layer.push((l.name.clone(), lc));
    }
    s
}

// ---------------------------------------------------------------------
// Tbl I: the cycle-exact weight-stream trace of the inner loop.
// ---------------------------------------------------------------------

/// Where a cycle's weight word comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightSource {
    /// First use: streamed from off-chip (I/O active).
    Stream,
    /// Re-use: read from the weight buffer (no I/O).
    Buffer,
}

/// One cycle of the Algorithm-1 inner loop (all Tile-PUs in lockstep).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// 1-based cycle index, as in Tbl I.
    pub cycle: u64,
    /// Output-channel tile (0-based).
    pub cout_tile: usize,
    /// Pixel index within the spatial tile (0-based, row-major).
    pub pixel: usize,
    /// Filter tap index (row-major over k×k).
    pub tap: usize,
    /// Input channel.
    pub cin: usize,
    pub source: WeightSource,
}

/// Generate the first `max_events` trace events for a layer (Tbl I is the
/// 16→64-FM 3×3 case with 8×8 tiles).
pub fn trace_layer(layer: &ConvLayer, cfg: &ChipConfig, max_events: usize) -> Vec<TraceEvent> {
    let mut out = Vec::with_capacity(max_events);
    let cout_tiles = ceil_div(layer.n_out, cfg.c);
    let tp = tile_pixels(layer, cfg) as usize;
    let taps = layer.k * layer.k;
    let n_in_eff = layer.n_in / layer.groups;
    let mut cycle = 0u64;
    'outer: for tile in 0..cout_tiles {
        for pixel in 0..tp {
            for tap in 0..taps {
                for cin in 0..n_in_eff {
                    cycle += 1;
                    out.push(TraceEvent {
                        cycle,
                        cout_tile: tile,
                        pixel,
                        tap,
                        cin,
                        source: if pixel == 0 {
                            WeightSource::Stream
                        } else {
                            WeightSource::Buffer
                        },
                    });
                    if out.len() >= max_events {
                        break 'outer;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;
    use crate::network::ConvLayer;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn resnet34_cycle_breakdown_matches_table3() {
        // Tbl III: conv 4.52M, bnorm 59.90k, bias 59.90k, total ≈ 4.65M.
        let s = schedule_network(&model::network("resnet34@224x224").unwrap(), &cfg(), DepthwisePolicy::default());
        assert_eq!(s.cycles.conv, 4_521_984);
        assert_eq!(s.cycles.bnorm, 59_904);
        assert_eq!(s.cycles.bias, 59_904);
        // Paper reports 7.68k bypass cycles; our separate-pass model gives
        // 7.17k (same order, documented in EXPERIMENTS.md).
        assert!((s.cycles.bypass as f64 / 7_680.0 - 1.0).abs() < 0.1);
        let total = s.total_cycles() as f64;
        assert!((total / 4.65e6 - 1.0).abs() < 0.01, "total {total}");
    }

    #[test]
    fn resnet34_throughput_and_utilization_match_paper() {
        // Tbl III: 1.53 kOp/cycle; Tbl VI: 97.5% utilization.
        let s = schedule_network(&model::network("resnet34@224x224").unwrap(), &cfg(), DepthwisePolicy::default());
        let opc = s.ops_per_cycle();
        assert!((opc / 1_530.0 - 1.0).abs() < 0.01, "op/cycle {opc}");
        let u = s.utilization(&cfg());
        assert!((u - 0.975).abs() < 0.005, "utilization {u}");
    }

    #[test]
    fn yolov3_utilization_near_paper() {
        // Tbl VI: 82.8% — driven by 320/32=10-wide FMs padding to 14.
        let s = schedule_network(&model::network("yolov3@320x320").unwrap(), &cfg(), DepthwisePolicy::default());
        let u = s.conv_utilization(&cfg());
        assert!((0.73..0.90).contains(&u), "conv utilization {u}");
        // Total utilization (incl. post phases) is a few points lower.
        assert!(s.utilization(&cfg()) <= u);
    }

    #[test]
    fn shufflenet_conv_utilization_matches_paper_shape() {
        // Tbl VI reports 98.8% for ShuffleNet: its FMs (28/14/7, channel
        // counts ×16) tile perfectly, so *conv-phase* utilization is near
        // peak under full-rate depth-wise. The total including the
        // 49-word-bandwidth post phases is far lower for 1×1-dominated
        // blocks — documented deviation (EXPERIMENTS.md).
        let net = model::network("shufflenet@224x224").unwrap();
        let s = schedule_network(&net, &cfg(), DepthwisePolicy::FullRate);
        let cu = s.conv_utilization(&cfg());
        assert!(cu > 0.97, "conv utilization {cu}");
        // Faithful bank-serialized depth-wise costs conv-phase throughput…
        let s2 = schedule_network(&net, &cfg(), DepthwisePolicy::BankSerialized);
        assert!(s2.conv_utilization(&cfg()) < cu);
        // …and the paper-shape ordering ShuffleNet > ResNet-34 > YOLOv3
        // holds on conv-phase utilization.
        let r34 = schedule_network(&model::network("resnet34@224x224").unwrap(), &cfg(), DepthwisePolicy::FullRate);
        let yolo = schedule_network(&model::network("yolov3@320x320").unwrap(), &cfg(), DepthwisePolicy::FullRate);
        assert!(cu > yolo.conv_utilization(&cfg()));
        assert!(r34.conv_utilization(&cfg()) > yolo.conv_utilization(&cfg()));
    }

    #[test]
    fn stream_bits_equal_weight_bits_for_aligned_nets() {
        let net = model::network("resnet34@224x224").unwrap();
        let s = schedule_network(&net, &cfg(), DepthwisePolicy::default());
        assert_eq!(s.stream_bits, net.weight_bits());
    }

    #[test]
    fn depthwise_serialization_factor_is_c() {
        let dw = ConvLayer::new("dw", 64, 64, 14, 14, 3, 1).with_groups(64);
        let fast = layer_cycles(&dw, &cfg(), DepthwisePolicy::FullRate);
        let slow = layer_cycles(&dw, &cfg(), DepthwisePolicy::BankSerialized);
        assert_eq!(slow.conv, fast.conv * 16);
    }

    #[test]
    fn table1_trace_first_cycles() {
        // Tbl I: 16 in / 64 out FM 3×3 conv, 8×8 pixel tiles.
        let l = ConvLayer::new("t1", 16, 64, 56, 56, 3, 1);
        let tr = trace_layer(&l, &cfg(), 40_000);
        // cycle 1: tile 0, pixel (1,1), tap (−1,−1), input FM 1, stream.
        assert_eq!(
            tr[0],
            TraceEvent {
                cycle: 1,
                cout_tile: 0,
                pixel: 0,
                tap: 0,
                cin: 0,
                source: WeightSource::Stream
            }
        );
        // cycle 16: last input FM of the first tap.
        assert_eq!(tr[15].cin, 15);
        assert_eq!(tr[15].tap, 0);
        // cycle 17: tap advances to (−1, 0).
        assert_eq!(tr[16].tap, 1);
        assert_eq!(tr[16].cin, 0);
        // cycle 144: first pixel finishes all 9 taps × 16 channels.
        assert_eq!(tr[143].tap, 8);
        assert_eq!(tr[143].cin, 15);
        assert_eq!(tr[143].source, WeightSource::Stream);
        // cycle 145: pixel 2 — weights now come from the buffer (no I/O).
        assert_eq!(tr[144].pixel, 1);
        assert_eq!(tr[144].source, WeightSource::Buffer);
        // cycle 9216 = 64 pixels × 144: tile 0 done.
        assert_eq!(tr[9215].pixel, 63);
        // cycle 9217: next output-channel tile, streaming resumes.
        assert_eq!(tr[9216].cout_tile, 1);
        assert_eq!(tr[9216].source, WeightSource::Stream);
        // Whole layer: 4 tiles × 9216 = 36 864 cycles ("36.8k" in Tbl I).
        assert_eq!(
            layer_cycles(&l, &cfg(), DepthwisePolicy::default()).conv,
            36_864
        );
    }
}

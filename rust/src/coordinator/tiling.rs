//! Multi-chip systolic mesh planning (§V).
//!
//! The feature map is tiled onto an `m×n` array of Hyperdrive chips (then
//! further onto each chip's M×N Tile-PUs). The planner picks the smallest
//! mesh whose *per-chip* worst-case-layer slice fits the per-chip FMM,
//! preferring the FM's aspect ratio (the paper uses 10×5 for 2048×1024
//! ResNet-34 and 20×10 for ResNet-152).
//!
//! Border-exchange accounting (Fig 11, Tbl V bottom): after a layer's
//! output is computed, every chip sends its `⌊k_next/2⌋` boundary
//! rows/columns once to the adjacent neighbour that will need them
//! (option 3 of §V — send-once-and-store, not re-read).

use crate::network::{Network, TensorRef};
use crate::util::ceil_div;
use crate::ChipConfig;

use super::wcl;

/// A planned chip mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeshPlan {
    /// Mesh rows (vertical chip count).
    pub rows: usize,
    /// Mesh columns (horizontal chip count).
    pub cols: usize,
    /// Per-chip worst-case-layer requirement in words.
    pub per_chip_wcl_words: u64,
}

impl MeshPlan {
    pub fn chips(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_single_chip(&self) -> bool {
        self.chips() == 1
    }
}

/// Per-chip WCL: re-run the liveness analysis with per-chip tile volumes
/// (every tensor contributes `c · ceil(h/rows) · ceil(w/cols)` words —
/// border/corner pixels live in the separate BM/CM, §V-C).
pub fn per_chip_wcl_words(net: &Network, rows: usize, cols: usize) -> u64 {
    let a = wcl::analyze(net);
    if rows == 1 && cols == 1 {
        return a.wcl_words;
    }
    // Scale each step's live set by re-deriving tensor volumes per chip.
    // Reuse the exact liveness by constructing a "per-chip" network view:
    // tensor volumes scale with ceil-divided spatial dims.
    let tile_words = |r: TensorRef| -> u64 {
        let (c, h, w) = net.shape_of(r);
        (c * ceil_div(h, rows) * ceil_div(w, cols)) as u64
    };
    // Recompute liveness intervals identically to wcl::analyze but with
    // tiled volumes: cheapest correct approach is to scale each step's
    // live contribution tensor-by-tensor.
    let mut max_live = 0u64;
    let n = net.steps.len();
    let tid = |r: TensorRef| match r {
        TensorRef::Input => 0usize,
        TensorRef::Step(i) => 1 + i,
    };
    let mut death = vec![-1isize; n + 1];
    death[0] = 0;
    for (i, s) in net.steps.iter().enumerate() {
        for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
            death[tid(r)] = death[tid(r)].max(i as isize);
        }
    }
    let mut storage_of = (0..=n).collect::<Vec<usize>>();
    for (i, s) in net.steps.iter().enumerate() {
        if let Some(b) = s.bypass {
            storage_of[1 + i] = storage_of[tid(b)];
        }
    }
    let mut births = vec![0isize; n + 1];
    let mut deaths = vec![0isize; n + 1];
    let mut words = vec![0u64; n + 1];
    for t in 0..=n {
        births[t] = t as isize - 1;
        deaths[t] = death[t].max((t as isize - 1).max(0));
        words[t] = if t == 0 {
            tile_words(TensorRef::Input)
        } else {
            tile_words(TensorRef::Step(t - 1))
        };
    }
    for t in (0..=n).rev() {
        let root = storage_of[t];
        if root != t {
            deaths[root] = deaths[root].max(deaths[t]);
            words[t] = 0;
        }
    }
    for i in 0..n {
        let i = i as isize;
        let live: u64 = (0..=n)
            .filter(|&t| words[t] > 0 && births[t] <= i && deaths[t] >= i)
            .map(|t| words[t])
            .sum();
        max_live = max_live.max(live);
    }
    max_live
}

/// Plan the smallest aspect-matched mesh that fits `cfg.fmm_words` per
/// chip, or `None` if no mesh up to 64 rows does. The column/row ratio
/// follows the FM aspect ratio (e.g. 2048-wide × 1024-high → cols =
/// 2·rows → 10×5 for ResNet-34, exactly the paper's configuration).
pub fn try_plan_mesh(net: &Network, cfg: &ChipConfig) -> Option<MeshPlan> {
    let aspect = (net.in_w as f64 / net.in_h as f64).max(1e-6);
    for size in 1..=64usize {
        // Candidate meshes near the aspect ratio for this chip count.
        let rows = size;
        let cols = ((rows as f64 * aspect).round() as usize).max(1);
        let w = per_chip_wcl_words(net, rows, cols);
        if w <= cfg.fmm_words as u64 {
            return Some(MeshPlan {
                rows,
                cols,
                per_chip_wcl_words: w,
            });
        }
    }
    None
}

/// [`try_plan_mesh`], panicking when nothing fits (the original API;
/// `engine::EngineBuilder::auto_mesh` uses the fallible form).
pub fn plan_mesh(net: &Network, cfg: &ChipConfig) -> MeshPlan {
    try_plan_mesh(net, cfg)
        .unwrap_or_else(|| panic!("no mesh up to 64 rows fits the network — FMM too small"))
}

/// Plan an explicit mesh (for reproducing the paper's fixed 10×5 / 20×10
/// rows of Tbl V); panics if the per-chip slice does not fit.
pub fn plan_mesh_exact(net: &Network, cfg: &ChipConfig, rows: usize, cols: usize) -> MeshPlan {
    let w = per_chip_wcl_words(net, rows, cols);
    assert!(
        w <= cfg.fmm_words as u64,
        "{}x{} mesh per-chip WCL {w} exceeds FMM {}",
        rows,
        cols,
        cfg.fmm_words
    );
    MeshPlan {
        rows,
        cols,
        per_chip_wcl_words: w,
    }
}

/// Halo width (rows/cols) a consumer layer needs from its neighbours.
fn halo_of(k: usize) -> usize {
    k / 2
}

/// Border-exchange traffic in bits for the whole network on a mesh
/// (Fig 11's "including border exchange"; 0 for a 1×1 mesh).
///
/// For every step output consumed by at least one 3×3 layer, each
/// internal mesh edge carries the producer's boundary rows/columns once
/// in each direction; corner pixels additionally hop twice (forwarded by
/// the vertical neighbour, §V-B).
pub fn border_exchange_bits(net: &Network, plan: &MeshPlan, fm_bits: usize) -> u64 {
    if plan.is_single_chip() {
        return 0;
    }
    let (m, n) = (plan.rows as u64, plan.cols as u64);
    let mut bits = 0u64;
    // Halo each tensor's consumers need.
    let mut halo = vec![0usize; net.steps.len() + 1];
    let tid = |r: TensorRef| match r {
        TensorRef::Input => 0usize,
        TensorRef::Step(i) => 1 + i,
    };
    for s in &net.steps {
        let h = halo_of(s.layer.k);
        for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
            halo[tid(r)] = halo[tid(r)].max(h);
        }
    }
    // The network input arrives pre-distributed with its halo (part of
    // the input load, not exchange); step outputs are exchanged.
    for (i, _) in net.steps.iter().enumerate() {
        let hw = halo[1 + i] as u64;
        if hw == 0 {
            continue;
        }
        let (c, h, w) = net.shape_of(TensorRef::Step(i));
        let (c, h, w) = (c as u64, h as u64, w as u64);
        // Horizontal internal cuts: (m−1) cuts × full FM width, exchanged
        // both ways; vertical cuts symmetric.
        let edge_pixels = (m - 1) * w + (n - 1) * h;
        bits += 2 * hw * edge_pixels * c * fm_bits as u64;
        // Corner pixels: (m−1)(n−1) internal vertices × 4 diagonal
        // transfers of hw² pixels, each taking 2 serial hops.
        bits += (m - 1) * (n - 1) * 4 * 2 * (hw * hw) * c * fm_bits as u64;
    }
    bits
}

/// Chip position classes of §V-A (Fig 6d): all chips of a class execute
/// identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChipType {
    NW,
    N,
    NE,
    W,
    Center,
    E,
    SW,
    S,
    SE,
}

/// Classify a mesh position.
pub fn chip_type(row: usize, col: usize, plan: &MeshPlan) -> ChipType {
    let top = row == 0;
    let bottom = row == plan.rows - 1;
    let left = col == 0;
    let right = col == plan.cols - 1;
    match (top, bottom, left, right) {
        (true, _, true, _) => ChipType::NW,
        (true, _, _, true) => ChipType::NE,
        (_, true, true, _) => ChipType::SW,
        (_, true, _, true) => ChipType::SE,
        (true, _, _, _) => ChipType::N,
        (_, true, _, _) => ChipType::S,
        (_, _, true, _) => ChipType::W,
        (_, _, _, true) => ChipType::E,
        _ => ChipType::Center,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn resnet34_224_plans_single_chip() {
        let net = model::network("resnet34@224x224").unwrap();
        let p = plan_mesh(&net, &cfg());
        assert!(p.is_single_chip());
        assert_eq!(p.per_chip_wcl_words, 401_408);
    }

    #[test]
    fn resnet34_2kx1k_plans_10x5_like_paper() {
        let net = model::network("resnet34@1024x2048").unwrap(); // (h, w) = 1024×2048
        let p = plan_mesh(&net, &cfg());
        assert_eq!((p.rows, p.cols), (5, 10), "paper's Tbl V mesh");
        assert!(p.per_chip_wcl_words <= cfg().fmm_words as u64);
    }

    #[test]
    fn resnet152_2kx1k_fits_paper_mesh() {
        // The paper deploys 20×10 = 200 chips; our planner finds that a
        // slightly smaller aspect-matched mesh (9×18) already fits, and
        // the paper's round configuration validates as well.
        let net = model::network("resnet152@1024x2048").unwrap();
        let p = plan_mesh(&net, &cfg());
        assert!(p.chips() <= 200, "planner found {} chips", p.chips());
        let exact = plan_mesh_exact(&net, &cfg(), 10, 20);
        assert_eq!(exact.chips(), 200);
    }

    #[test]
    fn exact_plan_validates_capacity() {
        let net = model::network("resnet34@1024x2048").unwrap();
        let p = plan_mesh_exact(&net, &cfg(), 5, 10);
        assert_eq!(p.chips(), 50);
    }

    #[test]
    #[should_panic(expected = "exceeds FMM")]
    fn undersized_exact_plan_panics() {
        let net = model::network("resnet34@1024x2048").unwrap();
        let _ = plan_mesh_exact(&net, &cfg(), 2, 2);
    }

    #[test]
    fn per_chip_wcl_shrinks_with_mesh() {
        let net = model::network("resnet34@1024x2048").unwrap();
        let w1 = per_chip_wcl_words(&net, 1, 1);
        let w4 = per_chip_wcl_words(&net, 2, 2);
        let w50 = per_chip_wcl_words(&net, 5, 10);
        assert!(w4 < w1 && w50 < w4);
        // Ceil-division padding keeps it at or above the exact share.
        assert!(w4 >= w1 / 4);
    }

    #[test]
    fn border_exchange_zero_on_single_chip() {
        let net = model::network("resnet34@224x224").unwrap();
        let p = plan_mesh(&net, &cfg());
        assert_eq!(border_exchange_bits(&net, &p, 16), 0);
    }

    #[test]
    fn border_exchange_order_of_magnitude() {
        // ResNet-34 @ 2048×1024 on 10×5: a few hundred Mbit — small vs
        // the 2.5 Gbit of FMs that a streaming accelerator would move.
        let net = model::network("resnet34@1024x2048").unwrap();
        let p = plan_mesh_exact(&net, &cfg(), 5, 10);
        let bits = border_exchange_bits(&net, &p, 16) as f64;
        assert!(
            (1e8..6e8).contains(&bits),
            "border bits {bits:.3e} out of expected band"
        );
        let all_fm_bits = wcl::analyze(&net).all_fm_bits(16) as f64;
        assert!(bits < all_fm_bits / 5.0);
    }

    #[test]
    fn chip_types_cover_mesh() {
        let p = MeshPlan {
            rows: 3,
            cols: 3,
            per_chip_wcl_words: 0,
        };
        assert_eq!(chip_type(0, 0, &p), ChipType::NW);
        assert_eq!(chip_type(0, 1, &p), ChipType::N);
        assert_eq!(chip_type(1, 1, &p), ChipType::Center);
        assert_eq!(chip_type(2, 2, &p), ChipType::SE);
        assert_eq!(chip_type(1, 0, &p), ChipType::W);
        assert_eq!(chip_type(2, 1, &p), ChipType::S);
    }
}

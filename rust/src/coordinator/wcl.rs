//! Worst-case-layer (WCL) memory analysis — §IV-B.
//!
//! The FMM must hold, at every step, all live feature maps: the input
//! being read, the output being produced, and any tensor still needed by
//! a later step (residual bypasses). The paper's planning rules are
//! reproduced exactly:
//!
//! * **ping-pong**: input and output of a layer live in different
//!   segments (single-port SRAMs, no same-cycle read/write conflicts);
//! * **in-place bypass accumulation**: a layer with a residual bypass
//!   writes its output *into the bypass tensor's storage* (read-add-write
//!   with one cycle of latency, enabled by the scale→bypass→bias
//!   reordering of §IV-B) — so the output costs no extra memory;
//! * dead segments are reused freely (the M2.1/M2.2 splitting argument).
//!
//! For ResNet-18/34 this yields the paper's `2·n_in·h_in·w_in` (401 kword
//! at 224²), for bottleneck ResNets `1.625·n_in·h_in·w_in` at the
//! subsampled block (21 Mbit at 224², 878 Mbit at 2048×1024) — Tbl II.

use crate::network::{Network, TensorRef};

/// Result of the liveness analysis over a network.
#[derive(Debug, Clone)]
pub struct MemoryAnalysis {
    /// Live FMM words during each step.
    pub live_words: Vec<u64>,
    /// Worst-case layer requirement in words (max of `live_words`).
    pub wcl_words: u64,
    /// Step index attaining the WCL.
    pub wcl_step: usize,
    /// Total binary weight bits streamed (on-chip layers).
    pub weight_bits: u64,
    /// Sum of all FM volumes in words (input + every step output).
    pub all_fm_words: u64,
}

impl MemoryAnalysis {
    /// WCL in bits for a given FM word width.
    pub fn wcl_bits(&self, fm_bits: usize) -> u64 {
        self.wcl_words * fm_bits as u64
    }

    /// All-FM volume in bits.
    pub fn all_fm_bits(&self, fm_bits: usize) -> u64 {
        self.all_fm_words * fm_bits as u64
    }

    /// Whether the network fits a single chip with `fmm_words` of FMM.
    pub fn fits_single_chip(&self, fmm_words: usize) -> bool {
        self.wcl_words <= fmm_words as u64
    }
}

/// Storage intervals after bypass aliasing: `[birth, death]` in step
/// indices (birth −1 = network input, death = last reading step).
#[derive(Debug, Clone, Copy)]
struct Storage {
    birth: isize,
    death: isize,
    words: u64,
}

/// Run the liveness analysis (§IV-B rules) over a validated network.
pub fn analyze(net: &Network) -> MemoryAnalysis {
    analyze_with(net, true)
}

/// Liveness analysis with the in-place bypass accumulation optionally
/// disabled — the ablation behind §IV-B's "in order to avoid additional
/// memory (+50%), we perform an on-the-fly addition of the bypass path".
pub fn analyze_with(net: &Network, alias_bypass: bool) -> MemoryAnalysis {
    let n = net.steps.len();
    // Tensor ids: 0 = input, 1 + i = output of step i.
    let tid = |r: TensorRef| -> usize {
        match r {
            TensorRef::Input => 0,
            TensorRef::Step(i) => 1 + i,
        }
    };

    // Last step reading each tensor.
    let mut death = vec![-1isize; n + 1];
    death[0] = 0; // the input is at least live while step 0 runs
    for (i, s) in net.steps.iter().enumerate() {
        for r in std::iter::once(s.src)
            .chain(s.bypass)
            .chain(s.concat_extra)
        {
            death[tid(r)] = death[tid(r)].max(i as isize);
        }
    }

    // Storage aliasing: a bypass step's output lives in the bypass
    // tensor's storage. Chase chains (b bypassed into c bypassed into …).
    let mut storage_of = (0..=n).collect::<Vec<usize>>();
    if alias_bypass {
        for (i, s) in net.steps.iter().enumerate() {
            if let Some(b) = s.bypass {
                let root = storage_of[tid(b)];
                storage_of[1 + i] = root;
            }
        }
    }

    // Build storage intervals.
    let mut storages: Vec<Storage> = Vec::with_capacity(n + 1);
    for t in 0..=n {
        let words = if t == 0 {
            (net.in_ch * net.in_h * net.in_w) as u64
        } else {
            net.steps[t - 1].layer.out_words()
        };
        storages.push(Storage {
            birth: t as isize - 1,
            // A tensor is live at least while it is being produced (the
            // final output is never read but still occupies the FMM).
            death: death[t].max((t as isize - 1).max(0)),
            words,
        });
    }
    // Merge aliased tensors into their root storage's interval.
    for t in (0..=n).rev() {
        let root = storage_of[t];
        if root != t {
            let d = storages[t].death.max(storages[root].death);
            storages[root].death = d;
            storages[t].words = 0; // aliased: no own storage
        }
    }

    // Live words during each step i: storages with birth <= i <= death.
    // (The output storage of step i has birth = i; inputs have death >= i.)
    let mut live_words = vec![0u64; n];
    for (i, lw) in live_words.iter_mut().enumerate() {
        let i = i as isize;
        *lw = storages
            .iter()
            .filter(|s| s.words > 0 && s.birth <= i && s.death >= i)
            .map(|s| s.words)
            .sum();
    }

    let (wcl_step, &wcl_words) = live_words
        .iter()
        .enumerate()
        .max_by_key(|&(_, w)| *w)
        .expect("empty network");

    MemoryAnalysis {
        live_words,
        wcl_words,
        wcl_step,
        weight_bits: net.weight_bits(),
        all_fm_words: net.all_fm_words(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;
    use crate::network::{ConvLayer, Network, TensorRef};
    use crate::ChipConfig;

    #[test]
    fn resnet34_wcl_is_401_kwords() {
        // §IV-B: M = 2·n_in·h_in·w_in = 2·64·56·56 = 401 408 words.
        let a = analyze(&model::network("resnet34@224x224").unwrap());
        assert_eq!(a.wcl_words, 2 * 64 * 56 * 56);
        // 6.4 Mbit with FP16 — exactly the taped-out FMM size.
        assert_eq!(a.wcl_bits(16), 6_422_528);
        assert!(a.wcl_bits(16) as f64 / 6.4e6 < 1.01);
    }

    #[test]
    fn resnet18_wcl_equals_resnet34_wcl() {
        // Tbl II: both basic-block ResNets share the 6.4 Mbit WCL.
        let a18 = analyze(&model::network("resnet18@224x224").unwrap());
        let a34 = analyze(&model::network("resnet34@224x224").unwrap());
        assert_eq!(a18.wcl_words, a34.wcl_words);
    }

    #[test]
    fn bottleneck_wcl_is_1_625_m1() {
        // §IV-B subsampled bottleneck: M1+M2+M4 = 1.625·M1 with
        // M1 = 256·56·56 → 20.9 Mbit ("21M" in Tbl II).
        let a = analyze(&model::network("resnet50@224x224").unwrap());
        let m1 = 256u64 * 56 * 56;
        assert_eq!(a.wcl_words, m1 + m1 / 8 + m1 / 2);
        let mbit = a.wcl_bits(16) as f64 / 1e6;
        assert!((20.0..21.5).contains(&mbit), "{mbit} Mbit");
    }

    #[test]
    fn resnet152_wcl_independent_of_depth() {
        // Tbl II: ResNet-50 and ResNet-152 share the WCL (same blocks).
        let a50 = analyze(&model::network("resnet50@224x224").unwrap());
        let a152 = analyze(&model::network("resnet152@224x224").unwrap());
        assert_eq!(a50.wcl_words, a152.wcl_words);
    }

    #[test]
    fn high_resolution_wcl_matches_table2() {
        // ResNet-34 @ 2048×1024: 2·64·512·256 words = 268 Mbit (paper: 267M).
        let a = analyze(&model::network("resnet34@1024x2048").unwrap());
        assert_eq!(a.wcl_words, 2 * 64 * 256 * 512);
        let mbit = a.wcl_bits(16) as f64 / 1e6;
        assert!((265.0..270.0).contains(&mbit), "{mbit}");
        // ResNet-152 @ 2048×1024: 1.625·256·512·256 → ~872 Mbit (paper 878M).
        let a152 = analyze(&model::network("resnet152@1024x2048").unwrap());
        let mbit152 = a152.wcl_bits(16) as f64 / 1e6;
        assert!((860.0..885.0).contains(&mbit152), "{mbit152}");
    }

    #[test]
    fn resnet34_fits_taped_out_chip_at_224() {
        let cfg = ChipConfig::default();
        assert!(analyze(&model::network("resnet34@224x224").unwrap()).fits_single_chip(cfg.fmm_words));
        assert!(!analyze(&model::network("resnet34@1024x2048").unwrap()).fits_single_chip(cfg.fmm_words));
    }

    #[test]
    fn bypass_aliasing_saves_memory() {
        // A residual pair must not cost 3 buffers (§IV-B: +50% avoided).
        let mut net = Network::new("res", 16, 8, 8);
        let a = net.push(ConvLayer::new("a", 16, 16, 8, 8, 3, 1), TensorRef::Input, None);
        net.push(
            ConvLayer::new("b", 16, 16, 8, 8, 3, 1).with_bypass(true),
            TensorRef::Step(a),
            Some(TensorRef::Input),
        );
        let m = analyze(&net);
        let fm = 16 * 64u64;
        assert_eq!(m.wcl_words, 2 * fm); // not 3·fm
        assert_eq!(m.live_words, vec![2 * fm, 2 * fm]);
    }

    #[test]
    fn non_bypass_chain_uses_ping_pong_pair() {
        let mut net = Network::new("chain", 16, 8, 8);
        let mut prev = TensorRef::Input;
        for i in 0..4 {
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("c{i}"), 16, 16, 8, 8, 3, 1),
                prev,
                None,
            ));
        }
        let m = analyze(&net);
        assert!(m.live_words.iter().all(|&w| w == 2 * 16 * 64));
    }

    #[test]
    fn live_words_never_below_single_layer_need() {
        // Property: liveness can never be smaller than the layer's own
        // input + (non-aliased) output.
        for net in [model::network("resnet34@224x224").unwrap(), model::network("resnet50@224x224").unwrap()] {
            let m = analyze(&net);
            for (i, s) in net.steps.iter().enumerate() {
                let need = s.layer.in_words()
                    + if s.bypass.is_some() { 0 } else { s.layer.out_words() };
                assert!(
                    m.live_words[i] >= need,
                    "step {i} `{}`: live {} < need {need}",
                    s.layer.name,
                    m.live_words[i]
                );
            }
        }
    }

    #[test]
    fn disabling_bypass_fusion_costs_50_percent() {
        // §IV-B: without the on-the-fly bypass addition, the basic-block
        // WCL would need a third buffer (+50%).
        let net = model::network("resnet34@224x224").unwrap();
        let fused = analyze(&net).wcl_words;
        let unfused = analyze_with(&net, false).wcl_words;
        assert_eq!(unfused, 3 * 64 * 56 * 56);
        assert!((unfused as f64 / fused as f64 - 1.5).abs() < 1e-9);
    }

    #[test]
    fn hypernet20_fits_comfortably() {
        let a = analyze(&model::network("hypernet20").unwrap());
        // Stage-1 residual pair dominates: 2 × 16·32·32 = 32 768 words.
        assert_eq!(a.wcl_words, 2 * 16 * 32 * 32);
        assert!(a.fits_single_chip(ChipConfig::default().fmm_words));
    }
}

//! Precision ablation (§VI-D): the paper estimates that replacing the
//! FP16 FM datapath with Q12 fixed point would cut core energy ~3× and
//! boost system efficiency ~6.8× over the state of the art for
//! high-accuracy object detection — without changing the architecture.
//!
//! This module re-evaluates any workload under alternative FM precisions:
//! narrower FMs shrink (a) the arithmetic/memory energy per cycle, (b)
//! the per-bit I/O of the input FM and border exchange, and (c) the FMM
//! *word* capacity (fixed 6.4 Mbit of SRAM holds more words), which can
//! reduce the required mesh size.

use crate::coordinator::schedule::DepthwisePolicy;
use crate::coordinator::tiling::plan_mesh;
use crate::network::Network;
use crate::ChipConfig;

use super::model::{energy_per_image, EnergyReport};

/// A feature-map precision option.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Precision {
    pub name: &'static str,
    /// FM word width in bits.
    pub fm_bits: usize,
    /// Core energy/cycle relative to FP16 (paper: Q12 ≈ 1/3; Q8
    /// extrapolated from the same arithmetic-dominated breakdown).
    pub core_scale: f64,
}

/// The ablation grid: the taped-out FP16 chip plus the fixed-point
/// variants the paper discusses.
pub const PRECISIONS: [Precision; 3] = [
    Precision {
        name: "FP16",
        fm_bits: 16,
        core_scale: 1.0,
    },
    Precision {
        name: "Q12",
        fm_bits: 12,
        core_scale: 1.0 / 3.0,
    },
    Precision {
        name: "Q8",
        fm_bits: 8,
        core_scale: 1.0 / 4.5,
    },
];

/// One ablation row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub precision: Precision,
    pub chips: usize,
    pub report: EnergyReport,
    /// Core energy after the precision scale.
    pub core_j: f64,
    pub total_j: f64,
    pub system_eff_ops_w: f64,
}

/// Evaluate a network across the precision grid at the best energy
/// point, re-planning the mesh for each precision's word capacity.
pub fn precision_ablation(net: &Network, base: &ChipConfig) -> Vec<AblationRow> {
    PRECISIONS
        .iter()
        .map(|&p| {
            let cfg = ChipConfig {
                fm_bits: p.fm_bits,
                // Same 6.4 Mbit of SRAM holds more narrow words.
                fmm_words: base.fmm_bits() / p.fm_bits,
                ..*base
            };
            let plan = plan_mesh(net, &cfg);
            let report = energy_per_image(net, &cfg, &plan, 0.5, 1.5, DepthwisePolicy::FullRate);
            let core_j = report.core_j * p.core_scale;
            let total_j = core_j + report.io_j;
            AblationRow {
                precision: p,
                chips: plan.chips(),
                system_eff_ops_w: report.ops as f64 / total_j,
                core_j,
                total_j,
                report,
            }
        })
        .collect()
}

/// Render the ablation as a text table.
pub fn render(net_name: &str, rows: &[AblationRow]) -> String {
    let mut out = format!("Precision ablation — {net_name} (0.5 V + 1.5 V FBB)\n");
    out.push_str("prec   FM bits  chips  core[mJ]  I/O[mJ]  total[mJ]  eff[TOp/s/W]\n");
    let base = rows[0].system_eff_ops_w;
    for r in rows {
        out.push_str(&format!(
            "{:<6} {:>7} {:>6} {:>9.2} {:>8.2} {:>10.2} {:>13.2}  ({:.1}x)\n",
            r.precision.name,
            r.precision.fm_bits,
            r.chips,
            r.core_j * 1e3,
            r.report.io_j * 1e3,
            r.total_j * 1e3,
            r.system_eff_ops_w / 1e12,
            r.system_eff_ops_w / base,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    #[test]
    fn q12_boosts_detection_efficiency_like_paper_estimate() {
        // §VI-D: "moving from FP16 to Q12 … around 3× for the core …
        // system efficiency boost of 6.8× for high accuracy object
        // detection" (the 6.8× is vs the FM-streaming SoA at 1.4
        // TOp/s/W). Our model: Q12 system eff / SoA ∈ [5, 9].
        let net = model::network("resnet34@1024x2048").unwrap();
        let rows = precision_ablation(&net, &ChipConfig::default());
        let fp16 = &rows[0];
        let q12 = &rows[1];
        // The 3× core scale is applied exactly; the total vs FP16 also
        // reflects the re-planned (smaller) mesh's padding.
        assert!((q12.core_j - q12.report.core_j / 3.0).abs() < 1e-9);
        // Q12 also re-plans to a smaller mesh (32 vs 50 chips), whose
        // larger per-chip tiles change padding — the combined core ratio
        // is ~0.48 rather than the naive 1/3.
        let core_ratio = q12.core_j / fp16.core_j;
        assert!((0.25..0.55).contains(&core_ratio), "core ratio {core_ratio}");
        let vs_soa = q12.system_eff_ops_w / 1e12 / 1.4;
        assert!((5.0..9.0).contains(&vs_soa), "Q12 vs SoA {vs_soa}x");
    }

    #[test]
    fn narrower_fms_never_need_more_chips() {
        let net = model::network("resnet34@1024x2048").unwrap();
        let rows = precision_ablation(&net, &ChipConfig::default());
        assert!(rows[1].chips <= rows[0].chips);
        assert!(rows[2].chips <= rows[1].chips);
    }

    #[test]
    fn efficiency_monotone_in_precision_reduction() {
        for net in [model::network("resnet34@224x224").unwrap(), model::network("yolov3@320x320").unwrap()] {
            let rows = precision_ablation(&net, &ChipConfig::default());
            assert!(rows[1].system_eff_ops_w > rows[0].system_eff_ops_w);
            assert!(rows[2].system_eff_ops_w > rows[1].system_eff_ops_w);
        }
    }

    #[test]
    fn render_contains_all_rows() {
        let net = model::network("resnet34@224x224").unwrap();
        let rows = precision_ablation(&net, &ChipConfig::default());
        let text = render(&net.name, &rows);
        for p in ["FP16", "Q12", "Q8"] {
            assert!(text.contains(p), "{text}");
        }
    }
}

//! Component-level power/energy breakdown (Fig 10): where the 22 mW at
//! 0.5 V go — Tile-PU arithmetic, FMM array + periphery, weight buffer,
//! other logic, and I/O.
//!
//! Derived from the schedule's activity counts and the per-access
//! energies of [`super::constants`]; the component sum is cross-checked
//! against the measured-power calibration in the tests.

use crate::coordinator::schedule::{schedule_network, DepthwisePolicy};
use crate::coordinator::tiling::MeshPlan;
use crate::network::Network;
use crate::ChipConfig;

use super::constants::*;
use super::io::hyperdrive_io;

/// Energy per image by component, in J.
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    /// Tile-PU FP16 adders (the sign-select accumulates).
    pub tile_pu_add_j: f64,
    /// Shared FP16 multipliers + post adders (bnorm/bias/bypass).
    pub tile_pu_post_j: f64,
    /// FMM SRAM array reads/writes (112-bit lines).
    pub fmm_j: f64,
    /// Weight-buffer SCM reads.
    pub wbuf_j: f64,
    /// Clock/control/register overhead.
    pub other_j: f64,
    /// Off-chip I/O.
    pub io_j: f64,
}

impl Breakdown {
    pub fn core_j(&self) -> f64 {
        self.tile_pu_add_j + self.tile_pu_post_j + self.fmm_j + self.wbuf_j + self.other_j
    }

    pub fn total_j(&self) -> f64 {
        self.core_j() + self.io_j
    }

    /// Component fractions of the total (Fig 10's pie), in the order
    /// (tile-PU add, post, FMM, WBuf, other, I/O).
    pub fn fractions(&self) -> [f64; 6] {
        let t = self.total_j();
        [
            self.tile_pu_add_j / t,
            self.tile_pu_post_j / t,
            self.fmm_j / t,
            self.wbuf_j / t,
            self.other_j / t,
            self.io_j / t,
        ]
    }
}

/// Per-image component energies for a network on one chip.
pub fn breakdown(net: &Network, cfg: &ChipConfig, plan: &MeshPlan) -> Breakdown {
    let s = schedule_network(net, cfg, DepthwisePolicy::default());
    let pj = 1e-12;
    // Accumulates: one FP16 add per MAC (conv ops are 2 Op per MAC).
    let adds = (s.conv_ops / 2) as f64;
    // Post ops: bnorm multiplies, bias/bypass adds.
    let post_mults = s.bnorm_ops as f64;
    let post_adds = (s.bias_ops + s.bypass_ops) as f64;
    // FMM line traffic: M 112-bit line reads per conv cycle feed all
    // M×N Tile-PUs; writes are out-words / N pixels per line.
    let line_reads = s.cycles.conv as f64 * cfg.m as f64;
    let out_words: f64 = net
        .steps
        .iter()
        .map(|st| st.layer.out_words() as f64)
        .sum();
    let line_writes = out_words / cfg.n as f64;
    // Weight buffer: one C-bit word per conv cycle.
    let wbuf_reads = s.cycles.conv as f64;
    let total_cycles = s.total_cycles() as f64;

    Breakdown {
        tile_pu_add_j: adds * E_FP16_ADD_PJ * pj,
        tile_pu_post_j: (post_mults * E_FP16_MUL_PJ + post_adds * E_FP16_ADD_PJ) * pj,
        fmm_j: (line_reads * E_SRAM_READ_PJ + line_writes * E_SRAM_WRITE_PJ) * pj,
        wbuf_j: wbuf_reads * E_SCM_READ_PJ * pj,
        other_j: total_cycles * E_OTHER_PJ_PER_CYCLE * pj,
        io_j: hyperdrive_io(net, plan, cfg.fm_bits).energy_j(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::scaling;
    use crate::model;

    fn resnet34_breakdown() -> Breakdown {
        let net = model::network("resnet34@224x224").unwrap();
        let plan = MeshPlan {
            rows: 1,
            cols: 1,
            per_chip_wcl_words: 0,
        };
        breakdown(&net, &ChipConfig::default(), &plan)
    }

    #[test]
    fn component_sum_matches_calibrated_core_energy() {
        // The bottom-up component sum must agree with the top-down
        // measured-power model within 20% (both anchored at 0.5 V).
        let b = resnet34_breakdown();
        let top_down = scaling::energy_per_cycle_j(0.5, 0.0) * 4.649e6;
        let ratio = b.core_j() / top_down;
        assert!((0.8..1.2).contains(&ratio), "bottom-up/top-down {ratio}");
    }

    #[test]
    fn arithmetic_dominates_like_fig10() {
        // §VI-A: "a considerable amount of the power is consumed into the
        // arithmetic units, while only a small overhead comes from memory
        // accesses and I/Os."
        let b = resnet34_breakdown();
        let f = b.fractions();
        let arith = f[0] + f[1];
        assert!(arith > 0.5, "arithmetic share {arith}");
        assert!(f[2] < 0.15, "FMM share {}", f[2]);
        assert!(f[3] < 0.01, "WBuf share {}", f[3]);
        assert!(f[5] < 0.35, "I/O share {}", f[5]);
    }

    #[test]
    fn io_share_matches_25_percent_statement() {
        // Fig 9 text: "system level energy drops by only 25% when
        // introducing the I/O energy" — i.e. I/O ≈ 20–30% of total at
        // the 0-FBB 0.5 V point for ResNet-34.
        let b = resnet34_breakdown();
        let share = b.io_j / b.total_j();
        assert!((0.15..0.35).contains(&share), "I/O share {share}");
    }

    #[test]
    fn scm_weight_buffer_is_negligible() {
        // The 43× SCM advantage [26] makes weight re-reads nearly free —
        // the architectural enabler for weight re-use.
        let b = resnet34_breakdown();
        assert!(b.wbuf_j < b.fmm_j / 20.0);
    }

    #[test]
    fn fractions_sum_to_one() {
        let f = resnet34_breakdown().fractions();
        let s: f64 = f.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}

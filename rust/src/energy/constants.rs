//! Calibrated constants of the energy model, with provenance.
//!
//! Anchors from the paper (all at 25 °C, GF 22FDX, LVT 8-track):
//!   Tbl IV measured points: (0.5 V, 57 MHz, 22 mW), (0.65, 135, 72),
//!   (0.8, 158, 134); leakage/dynamic ratio 4% at 0.5 V / 0 FBB; the FMM
//!   SRAM arrays are not body-biased; best energy point 0.5 V + 1.5 V FBB.
//!
//! The fit (see `scaling::tests::model_matches_measured_points`) keeps
//! every anchor within ±20% and the ResNet-34 core energy within ±10% of
//! the paper's 1.45 mJ/image.

/// Effective switched capacitance of the whole chip (dynamic power
/// `P = C_EFF · VDD² · f`). Fitted to the Tbl IV anchors.
pub const C_EFF_F: f64 = 1.2e-9;

/// Leakage power at VDD = 0.5 V, 0 V FBB (4% of the 22 mW anchor).
pub const P_LEAK0_W: f64 = 0.88e-3;

/// Exponential VDD sensitivity of leakage (per volt above 0.5 V).
pub const K_LEAK_VDD: f64 = 3.0;

/// Fraction of leakage in the (not body-biased) memory arrays.
pub const LEAK_MEM_FRACTION: f64 = 0.75;

/// Exponential FBB sensitivity of the *logic* leakage (per volt of VBB).
pub const K_LEAK_VBB: f64 = 0.5;

/// Frequency model `f(V) = F_A − F_B / (V − V_TH_EFF + K_BB·VBB)` —
/// saturating fit through the three measured points.
pub const F_A_HZ: f64 = 213.0e6;
pub const F_B_HZ_V: f64 = 23.4e6;
pub const V_TH_EFF: f64 = 0.35;
/// Threshold shift per volt of forward body bias.
pub const K_BB: f64 = 0.05;

/// Below this VDD the saturating fit is replaced by a near-threshold
/// exponential (leakage-dominated region of Fig 9).
pub const V_NEAR_THRESHOLD: f64 = 0.5;
/// Exponential slope of the near-threshold frequency roll-off (V/decade
/// equivalent; f halves roughly every 20 mV below 0.5 V).
pub const NEAR_VT_SLOPE_V: f64 = 0.028;

/// I/O energy per bit: LPDDR3 PHY estimate the paper uses (§VI), itself
/// from the Origami/28 nm measurement. "Quite optimistic for a low-cost
/// chip", i.e. conservative for Hyperdrive's advantage.
pub const IO_PJ_PER_BIT: f64 = 21.0;

// --- Per-access energies for the Fig-10 breakdown (0.5 V values) -------
// Chosen so that component sums reproduce the measured 22 mW split:
// arithmetic-dominated, small memory/IO overhead (§VI Fig 10), with the
// SCM weight buffer 43× cheaper than SRAM per access [26].

/// FP16 add/sub in a Tile-PU (sign-select accumulate).
pub const E_FP16_ADD_PJ: f64 = 0.30;
/// FP16 multiply (shared per-tile multiplier).
pub const E_FP16_MUL_PJ: f64 = 0.55;
/// One 112-bit FMM SRAM word read.
pub const E_SRAM_READ_PJ: f64 = 1.3;
/// One 112-bit FMM SRAM word write.
pub const E_SRAM_WRITE_PJ: f64 = 1.5;
/// One 16-bit SCM (weight buffer) read — 43× below SRAM (per [26]).
pub const E_SCM_READ_PJ: f64 = 1.3 / 43.0;
/// Control/clock/register overhead per active cycle ("Others" in Fig 10).
pub const E_OTHER_PJ_PER_CYCLE: f64 = 70.0;

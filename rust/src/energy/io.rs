//! I/O bit and energy accounting for the Hyperdrive dataflow.
//!
//! The chip's I/O per image is: the binary weight stream (broadcast once
//! to the mesh), the on-chip input FM load, the (tiny) final output FM,
//! and — on a multi-chip mesh — the border/corner exchange. The raw
//! camera image feeds the *host-side* first layer (§VI-B) and is not
//! accelerator I/O; for YOLOv3 (whose 3×3 first layer runs on-chip) the
//! image *is* the input FM.

use crate::coordinator::tiling::{border_exchange_bits, MeshPlan};
use crate::network::Network;

use super::constants::IO_PJ_PER_BIT;

/// I/O bit inventory for one inference.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoBits {
    /// Binary weight stream (padded to C; broadcast to all chips).
    pub weights: u64,
    /// On-chip input FM load (FP16 words).
    pub input_fm: u64,
    /// Final output FM read-back.
    pub output_fm: u64,
    /// Multi-chip border/corner exchange.
    pub border: u64,
}

impl IoBits {
    pub fn total(&self) -> u64 {
        self.weights + self.input_fm + self.output_fm + self.border
    }

    /// I/O energy in J at the paper's 21 pJ/bit.
    pub fn energy_j(&self) -> f64 {
        self.total() as f64 * IO_PJ_PER_BIT * 1e-12
    }
}

/// Hyperdrive's per-image I/O on a given mesh.
pub fn hyperdrive_io(net: &Network, plan: &MeshPlan, fm_bits: usize) -> IoBits {
    let (oc, oh, ow) = net.out_shape();
    IoBits {
        weights: net.weight_bits(),
        input_fm: (net.in_ch * net.in_h * net.in_w * fm_bits) as u64,
        output_fm: (oc * oh * ow * fm_bits) as u64,
        border: border_exchange_bits(net, plan, fm_bits),
    }
}

/// The single-chip plan constant (for networks that fit one die).
pub fn single_chip_plan() -> MeshPlan {
    MeshPlan {
        rows: 1,
        cols: 1,
        per_chip_wcl_words: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    #[test]
    fn resnet34_io_energy_matches_table5() {
        // Tbl V: Hyperdrive ResNet-34 @224²: I/O E = 0.5 mJ/image.
        let net = model::network("resnet34@224x224").unwrap();
        let io = hyperdrive_io(&net, &single_chip_plan(), 16);
        assert_eq!(io.border, 0);
        let mj = io.energy_j() * 1e3;
        assert!((mj / 0.5 - 1.0).abs() < 0.1, "I/O {mj} mJ vs 0.5");
        // Weights dominate: 21.3 Mbit vs 3.2 Mbit input FM.
        assert!(io.weights > 6 * io.input_fm);
    }

    #[test]
    fn yolov3_io_energy_matches_table5() {
        // Tbl V: Hyperdrive YOLOv3 @320²: I/O E = 1.4 mJ/image.
        let net = model::network("yolov3@320x320").unwrap();
        let io = hyperdrive_io(&net, &single_chip_plan(), 16);
        let mj = io.energy_j() * 1e3;
        assert!((1.1..1.7).contains(&mj), "I/O {mj} mJ vs 1.4");
    }

    #[test]
    fn shufflenet_io_energy_small_like_table5() {
        // Tbl V: ShuffleNet I/O E = 0.1 mJ.
        let net = model::network("shufflenet@224x224").unwrap();
        let io = hyperdrive_io(&net, &single_chip_plan(), 16);
        let mj = io.energy_j() * 1e3;
        assert!((0.05..0.2).contains(&mj), "I/O {mj} mJ");
    }

    #[test]
    fn multichip_io_stays_small_vs_fm_streaming() {
        // Tbl V bottom: ResNet-34 @2048×1024 on 10×5 → 7.6 mJ in the
        // paper; our border model lands in the same few-mJ band, an
        // order of magnitude below UNPU's 105.6 mJ.
        let net = model::network("resnet34@1024x2048").unwrap();
        let plan = crate::coordinator::tiling::plan_mesh_exact(
            &net,
            &crate::ChipConfig::default(),
            5,
            10,
        );
        let io = hyperdrive_io(&net, &plan, 16);
        let mj = io.energy_j() * 1e3;
        assert!((5.0..13.0).contains(&mj), "I/O {mj} mJ vs paper 7.6");
        assert!(io.border > io.weights, "border dominates at 2k×1k");
    }
}

//! Calibrated energy/power model of the Hyperdrive chip.
//!
//! The GF 22FDX silicon is replaced by an analytic model calibrated to
//! the paper's measured operating points (Tbl IV) and its architectural
//! statements (4% leakage at 0.5 V, FMM arrays not body-biased, 21 pJ/bit
//! LPDDR3-class I/O). Components:
//!
//! * [`constants`] — every calibrated constant with provenance;
//! * [`scaling`] — VDD / forward-body-bias → frequency & power (Figs 8, 9);
//! * [`opchar`] — the measured operating points (Tbl IV);
//! * [`io`] — I/O bit and energy accounting for the Hyperdrive dataflow;
//! * [`model`] — per-image core/I-O energy & efficiency (Tbl V);
//! * [`breakdown`] — component power split from access counts (Fig 10).

pub mod ablation;
pub mod breakdown;
pub mod constants;
pub mod io;
pub mod model;
pub mod opchar;
pub mod scaling;

pub use model::{energy_per_image, EnergyReport};
pub use opchar::MEASURED_POINTS;

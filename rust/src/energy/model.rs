//! Per-image energy & efficiency of Hyperdrive on a workload — the
//! quantities of Tbl V (core E, I/O E, total E, TOp/s/W, frame rate).

use crate::coordinator::schedule::{schedule_network_mesh, DepthwisePolicy, NetworkSchedule};
use crate::coordinator::tiling::MeshPlan;
use crate::network::Network;
use crate::ChipConfig;

use super::io::{hyperdrive_io, IoBits};
use super::scaling;

/// Energy/performance report for one network at one operating point.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub vdd: f64,
    pub vbb: f64,
    pub chips: usize,
    /// Per-chip cycles for one image (chips run in lockstep).
    pub cycles: u64,
    pub ops: u64,
    pub core_j: f64,
    pub io: IoBits,
    pub io_j: f64,
    /// Effective throughput in Op/s across the whole mesh.
    pub throughput_ops_s: f64,
    pub frame_rate_hz: f64,
}

impl EnergyReport {
    pub fn total_j(&self) -> f64 {
        self.core_j + self.io_j
    }

    /// System-level (core + I/O) efficiency in Op/s/W — the paper's
    /// headline metric.
    pub fn system_efficiency_ops_w(&self) -> f64 {
        self.ops as f64 / self.total_j()
    }

    /// Core-only efficiency in Op/s/W.
    pub fn core_efficiency_ops_w(&self) -> f64 {
        self.ops as f64 / self.core_j
    }
}

/// Evaluate a network on a mesh at `(vdd, vbb)`.
pub fn energy_per_image(
    net: &Network,
    cfg: &ChipConfig,
    plan: &MeshPlan,
    vdd: f64,
    vbb: f64,
    dw: DepthwisePolicy,
) -> EnergyReport {
    let sched: NetworkSchedule = schedule_network_mesh(net, cfg, dw, plan.rows, plan.cols);
    let cycles = sched.total_cycles();
    let ops = sched.total_ops();
    let f = scaling::freq_hz(vdd, vbb);
    let e_cycle = scaling::energy_per_cycle_j(vdd, vbb);
    let chips = plan.chips();
    let core_j = cycles as f64 * e_cycle * chips as f64;
    let io = hyperdrive_io(net, plan, cfg.fm_bits);
    let seconds = cycles as f64 / f;
    EnergyReport {
        vdd,
        vbb,
        chips,
        cycles,
        ops,
        core_j,
        io,
        io_j: io.energy_j(),
        throughput_ops_s: ops as f64 / seconds,
        frame_rate_hz: 1.0 / seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tiling::{plan_mesh_exact, MeshPlan};
    use crate::model;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    fn single() -> MeshPlan {
        MeshPlan {
            rows: 1,
            cols: 1,
            per_chip_wcl_words: 0,
        }
    }

    #[test]
    fn resnet34_system_efficiency_matches_table5() {
        // Tbl V: 3.6 TOp/s/W at 0.5 V (best point, incl. I/O), 1.9 mJ/im.
        let net = model::network("resnet34@224x224").unwrap();
        let r = energy_per_image(&net, &cfg(), &single(), 0.5, 1.5, DepthwisePolicy::default());
        let eff = r.system_efficiency_ops_w() / 1e12;
        assert!((3.1..4.1).contains(&eff), "system eff {eff} TOp/s/W");
        let total_mj = r.total_j() * 1e3;
        assert!((1.7..2.2).contains(&total_mj), "total {total_mj} mJ vs 1.9");
    }

    #[test]
    fn resnet34_at_1v_matches_low_efficiency_row() {
        // Tbl V second Hyperdrive row: 1.0 V → ~1.0 TOp/s/W, ~7 mJ/im.
        // (Our VDD model tops out at 0.9 V; 0.8 V already shows the
        // CV² collapse: < 2 TOp/s/W.)
        let net = model::network("resnet34@224x224").unwrap();
        let r = energy_per_image(&net, &cfg(), &single(), 0.8, 0.0, DepthwisePolicy::default());
        let eff = r.system_efficiency_ops_w() / 1e12;
        assert!(eff < 2.2, "eff {eff} must collapse at high VDD");
    }

    #[test]
    fn frame_rate_near_paper_at_0v65() {
        // §VI-D: 46.7 fps for ResNet-34 at 0.65 V (135 MHz / 4.65 M cyc
        // ≈ 29 fps by pure cycles; the paper's figure includes the
        // body-biased frequency — accept the 25–50 band).
        let net = model::network("resnet34@224x224").unwrap();
        let r = energy_per_image(&net, &cfg(), &single(), 0.65, 0.0, DepthwisePolicy::default());
        assert!((25.0..50.0).contains(&r.frame_rate_hz), "{}", r.frame_rate_hz);
    }

    #[test]
    fn multichip_resnet34_2kx1k_headline() {
        // Tbl V bottom: 10×5 mesh, 4.3 TOp/s/W system, 69.5 mJ/image,
        // 4547 GOp/s effective. Our model (with real padding overheads)
        // must land within ~25% on energy and preserve the >3× gap to
        // the FM-streaming baselines (UNPU: 1.4 TOp/s/W).
        let net = model::network("resnet34@1024x2048").unwrap();
        let plan = plan_mesh_exact(&net, &cfg(), 5, 10);
        let r = energy_per_image(&net, &cfg(), &plan, 0.5, 1.5, DepthwisePolicy::default());
        let eff = r.system_efficiency_ops_w() / 1e12;
        assert!((3.2..5.0).contains(&eff), "system eff {eff} vs paper 4.3");
        let total_mj = r.total_j() * 1e3;
        assert!((55.0..95.0).contains(&total_mj), "total {total_mj} vs 69.5");
        // Paper's 4547 GOp/s assumes the 58 MHz un-biased clock; at the
        // body-biased best energy point our model clocks at ~109 MHz, so
        // assert internal consistency (mesh peak × utilization) instead.
        let f = crate::energy::scaling::freq_hz(0.5, 1.5);
        let peak = r.chips as f64 * cfg().ops_per_cycle() as f64 * f;
        let util = r.throughput_ops_s / peak;
        assert!((0.75..1.0).contains(&util), "mesh utilization {util}");
        let gops_unbiased = r.throughput_ops_s / f * 58e6 / 1e9;
        assert!((3500.0..5200.0).contains(&gops_unbiased), "{gops_unbiased} vs 4547");
        assert_eq!(r.chips, 50);
    }

    #[test]
    fn io_share_is_small_fraction_of_total() {
        // §VI-A: introducing I/O drops efficiency by only ~25% at most
        // (7–30% across applications) — vs >70% for FM-streaming chips.
        for (net, plan) in [
            (model::network("resnet34@224x224").unwrap(), single()),
            (model::network("yolov3@320x320").unwrap(), single()),
        ] {
            let r = energy_per_image(&net, &cfg(), &plan, 0.5, 1.5, DepthwisePolicy::default());
            let share = r.io_j / r.total_j();
            assert!((0.02..0.35).contains(&share), "{}: I/O share {share}", net.name);
        }
    }

    #[test]
    fn resolution_independent_frame_rate_with_mesh() {
        // §VI-D: "performance is independent of the image resolution" —
        // per-chip cycles at 2k×1k on 10×5 stay within ~25% of the 224²
        // single-chip cycles (padding overhead only).
        let net224 = model::network("resnet34@224x224").unwrap();
        let r224 = energy_per_image(&net224, &cfg(), &single(), 0.5, 0.0, DepthwisePolicy::default());
        let net2k = model::network("resnet34@1024x2048").unwrap();
        let plan = plan_mesh_exact(&net2k, &cfg(), 5, 10);
        let r2k = energy_per_image(&net2k, &cfg(), &plan, 0.5, 0.0, DepthwisePolicy::default());
        let ratio = r2k.cycles as f64 / r224.cycles as f64;
        assert!((0.9..1.35).contains(&ratio), "cycle ratio {ratio}");
    }
}

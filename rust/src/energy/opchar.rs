//! Measured operating points of the silicon prototype (Tbl IV).
//!
//! These are the paper's Advantest SoC V93000 measurements and serve as
//! the calibration anchors; `report::table4` prints them together with
//! the model's interpolation.

use crate::ChipConfig;

/// One measured operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub vdd: f64,
    /// Measured operating frequency in Hz.
    pub freq_hz: f64,
    /// Measured core power in W.
    pub power_w: f64,
}

/// Tbl IV rows (0 V body bias column set).
pub const MEASURED_POINTS: [OperatingPoint; 3] = [
    OperatingPoint {
        vdd: 0.5,
        freq_hz: 57.0e6,
        power_w: 22.0e-3,
    },
    OperatingPoint {
        vdd: 0.65,
        freq_hz: 135.0e6,
        power_w: 72.0e-3,
    },
    OperatingPoint {
        vdd: 0.8,
        freq_hz: 158.0e6,
        power_w: 134.0e-3,
    },
];

impl OperatingPoint {
    /// Peak throughput in Op/s (1568 Op/cycle on the taped-out chip).
    pub fn peak_throughput_ops(&self, cfg: &ChipConfig) -> f64 {
        self.freq_hz * cfg.ops_per_cycle() as f64
    }

    /// Core energy efficiency in Op/s/W at a real Op/cycle rate.
    pub fn core_efficiency(&self, ops_per_cycle: f64) -> f64 {
        ops_per_cycle * self.freq_hz / self.power_w
    }

    /// Core energy per cycle in J.
    pub fn energy_per_cycle_j(&self) -> f64 {
        self.power_w / self.freq_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_throughput_column() {
        let cfg = ChipConfig::default();
        // Tbl IV: 88 / 212 / 248 GOp/s.
        let t: Vec<f64> = MEASURED_POINTS
            .iter()
            .map(|p| p.peak_throughput_ops(&cfg) / 1e9)
            .collect();
        assert!((t[0] - 89.4).abs() < 2.0, "{}", t[0]);
        assert!((t[1] - 211.7).abs() < 2.0, "{}", t[1]);
        assert!((t[2] - 247.7).abs() < 2.0, "{}", t[2]);
    }

    #[test]
    fn measured_efficiency_ordering() {
        // Efficiency decreases with VDD (Tbl IV: 4.9 / 3.0 / 1.9 core
        // TOp/s/W at the body-biased points; ordering is what matters).
        let e: Vec<f64> = MEASURED_POINTS
            .iter()
            .map(|p| p.core_efficiency(1527.0))
            .collect();
        assert!(e[0] > e[1] && e[1] > e[2]);
        // 0.5 V point: ≈ 4.0 TOp/s/W at 0 FBB; the paper's 4.9 is at
        // 1.5 V FBB (covered by scaling::tests).
        assert!((e[0] / 3.96e12 - 1.0).abs() < 0.05, "{}", e[0]);
    }

    #[test]
    fn energy_per_cycle_monotone_in_vdd() {
        let e: Vec<f64> = MEASURED_POINTS
            .iter()
            .map(|p| p.energy_per_cycle_j())
            .collect();
        assert!(e[0] < e[1] && e[1] < e[2]);
    }
}

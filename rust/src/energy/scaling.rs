//! VDD / forward-body-bias scaling: frequency and power (Figs 8 and 9).
//!
//! `f(VDD, VBB)` is a saturating fit through the three measured points
//! with a near-threshold exponential below 0.5 V; power is
//! `C_EFF·V²·f + leakage(V, VBB)` with the memory-array share of leakage
//! insensitive to body bias (the arrays are not forward-biased, §VI-A).

use super::constants::*;

/// Operating frequency in Hz at a supply/body-bias point.
pub fn freq_hz(vdd: f64, vbb: f64) -> f64 {
    let v_eff = vdd - V_TH_EFF + K_BB * vbb;
    if vdd >= V_NEAR_THRESHOLD {
        (F_A_HZ - F_B_HZ_V / v_eff).max(0.0)
    } else {
        // Near-threshold: exponential roll-off anchored at 0.5 V.
        let f0 = F_A_HZ - F_B_HZ_V / (V_NEAR_THRESHOLD - V_TH_EFF + K_BB * vbb);
        f0 * ((vdd - V_NEAR_THRESHOLD) / NEAR_VT_SLOPE_V).exp()
    }
}

/// Leakage power in W.
pub fn leakage_w(vdd: f64, vbb: f64) -> f64 {
    let v_scale = (K_LEAK_VDD * (vdd - 0.5)).exp();
    let logic = (1.0 - LEAK_MEM_FRACTION) * (K_LEAK_VBB * vbb).exp();
    P_LEAK0_W * v_scale * (logic + LEAK_MEM_FRACTION)
}

/// Total core power in W when clocked at `freq_hz(vdd, vbb)`.
pub fn power_w(vdd: f64, vbb: f64) -> f64 {
    C_EFF_F * vdd * vdd * freq_hz(vdd, vbb) + leakage_w(vdd, vbb)
}

/// Core energy per cycle in J.
pub fn energy_per_cycle_j(vdd: f64, vbb: f64) -> f64 {
    power_w(vdd, vbb) / freq_hz(vdd, vbb)
}

/// Peak-throughput core energy efficiency in Op/s/W for a given real
/// Op/cycle rate (e.g. 1527 for ResNet-34).
pub fn core_efficiency_ops_per_j(vdd: f64, vbb: f64, ops_per_cycle: f64) -> f64 {
    ops_per_cycle / energy_per_cycle_j(vdd, vbb)
}

/// Lowest VDD (within [0.4, 0.9]) reaching a target frequency at a given
/// body bias — the mechanism behind Fig 8's up-and-left shift with FBB.
pub fn vdd_for_freq(target_hz: f64, vbb: f64) -> Option<f64> {
    let mut lo = 0.40;
    let mut hi = 0.90;
    if freq_hz(hi, vbb) < target_hz {
        return None;
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if freq_hz(mid, vbb) >= target_hz {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_matches_measured_points() {
        // (VDD, f_meas MHz, P_meas mW) from Tbl IV; model within ±20%.
        for (v, f_mhz, p_mw) in [(0.5, 57.0, 22.0), (0.65, 135.0, 72.0), (0.8, 158.0, 134.0)] {
            let f = freq_hz(v, 0.0) / 1e6;
            let p = power_w(v, 0.0) * 1e3;
            assert!(
                (f / f_mhz - 1.0).abs() < 0.05,
                "f({v}) = {f} vs {f_mhz} MHz"
            );
            assert!((p / p_mw - 1.0).abs() < 0.20, "P({v}) = {p} vs {p_mw} mW");
        }
    }

    #[test]
    fn leakage_fraction_is_4_percent_at_anchor() {
        let frac = leakage_w(0.5, 0.0) / power_w(0.5, 0.0);
        assert!((0.03..0.06).contains(&frac), "leakage fraction {frac}");
    }

    #[test]
    fn fbb_raises_frequency_without_memory_leakage() {
        assert!(freq_hz(0.5, 1.5) > 1.4 * freq_hz(0.5, 0.0));
        // Memory share of leakage is FBB-insensitive: total leakage grows
        // far slower than the pure-logic exponential would.
        let ratio = leakage_w(0.5, 1.8) / leakage_w(0.5, 0.0);
        assert!(ratio < (K_LEAK_VBB * 1.8_f64).exp() * 0.6, "ratio {ratio}");
    }

    #[test]
    fn fbb_improves_iso_throughput_efficiency() {
        // Fig 8's main message: at the same throughput, FBB lets VDD drop
        // and efficiency rise.
        let target = 100e6;
        let v0 = vdd_for_freq(target, 0.0).unwrap();
        let v15 = vdd_for_freq(target, 1.5).unwrap();
        assert!(v15 < v0);
        let e0 = energy_per_cycle_j(v0, 0.0);
        let e15 = energy_per_cycle_j(v15, 1.5);
        assert!(e15 < e0, "e15 {e15} !< e0 {e0}");
    }

    #[test]
    fn best_energy_point_is_half_volt_1v5_fbb() {
        // Fig 8 / §VI-A: scan the (VDD, VBB) grid the paper sweeps; the
        // minimum energy/cycle must land at 0.5 V, 1.5 V FBB.
        let mut best = (0.0, 0.0, f64::MAX);
        for &vdd in &[0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8] {
            for &vbb in &[0.0, 0.5, 1.0, 1.5, 1.8] {
                let e = energy_per_cycle_j(vdd, vbb);
                if e < best.2 {
                    best = (vdd, vbb, e);
                }
            }
        }
        assert_eq!((best.0, best.1), (0.5, 1.5), "best point {best:?}");
    }

    #[test]
    fn efficiency_peaks_at_0v5_over_vdd_sweep() {
        // Fig 9: efficiency drops below 0.5 V (leakage-dominated) and
        // above (CV²).
        let eff = |v: f64| core_efficiency_ops_per_j(v, 0.0, 1527.0);
        assert!(eff(0.5) > eff(0.42));
        assert!(eff(0.5) > eff(0.65));
        assert!(eff(0.65) > eff(0.8));
    }

    #[test]
    fn resnet34_core_energy_near_paper() {
        // 4.65 M cycles at the best point ≈ 1.45 mJ (paper), core
        // efficiency ≈ 4.9 TOp/s/W.
        let e_cycle = energy_per_cycle_j(0.5, 1.5);
        let e_image = e_cycle * 4.649e6;
        assert!(
            (e_image / 1.45e-3 - 1.0).abs() < 0.15,
            "core E {e_image:.3e} vs 1.45 mJ"
        );
        let eff = core_efficiency_ops_per_j(0.5, 1.5, 1527.0) / 1e12;
        assert!((4.2..5.5).contains(&eff), "core eff {eff} TOp/s/W");
    }

    #[test]
    fn vdd_for_freq_is_inverse_of_freq() {
        for &vbb in &[0.0, 1.0, 1.8] {
            for &f in &[60e6, 120e6, 150e6] {
                if let Some(v) = vdd_for_freq(f, vbb) {
                    assert!(freq_hz(v, vbb) >= f * 0.999);
                    assert!(freq_hz(v - 0.01, vbb) < f * 1.01);
                }
            }
        }
    }
}

//! The backend abstraction of the unified engine: one trait that the
//! PJRT runtime, the single-chip functional simulator and the multi-chip
//! mesh simulator all implement, plus the shared per-step parameter set
//! ([`NetworkParams`]) the simulator backends consume.

use std::sync::{Arc, OnceLock};

use crate::bwn::pack_weights;
use crate::network::Network;
use crate::runtime::NetworkManifest;
use crate::simulator::mesh::StepParams;
use crate::util::SplitMix64;

use super::EngineError;

/// Which execution backend an [`super::Engine`] runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Single-chip functional simulator (`simulator::chip`, Algorithm 1
    /// bit-faithfully, optionally FP16 like the taped-out datapath).
    Functional,
    /// Multi-chip systolic mesh simulator (`simulator::mesh`, §V): real
    /// distributed FM tiles and the send-once border/corner exchange.
    Mesh,
    /// PJRT runtime executing the AOT-compiled Pallas artifacts
    /// (`runtime::InferenceEngine`; requires the `pjrt` cargo feature
    /// and `make artifacts`).
    Pjrt,
}

impl BackendKind {
    /// Short name used in reports and CLI output.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Functional => "functional-sim",
            BackendKind::Mesh => "mesh-sim",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// One per-layer trace event delivered to [`Backend::infer_traced`]
/// hooks: the step's full output feature map, flattened `[c][y][x]`.
pub struct LayerTrace<'a> {
    /// Step index in the network's step list.
    pub step: usize,
    /// Layer name (unique within a network).
    pub layer: &'a str,
    /// Output shape `(c, h, w)`.
    pub shape: (usize, usize, usize),
    /// Flattened output values.
    pub output: &'a [f32],
}

/// Result of one [`Backend::infer_batch`] micro-batch pass.
///
/// Outputs come back per input, in submission order, so one failing
/// input (e.g. a wrong-length tensor) fails only its own slot — the
/// failure-isolation contract the service's ticket scatter relies on.
/// The two stream counters quantify the weight-traffic amortization the
/// batch achieved: a backend that truly batches fetches each weight
/// block once (`stream_words ≈ sequential_stream_words / B`), while the
/// loop fallback reports zero for both (no amortization to claim).
#[derive(Debug, Default)]
pub struct BatchRun {
    /// Per-input results, aligned with the `inputs` slice.
    pub outputs: Vec<Result<Vec<f32>, EngineError>>,
    /// Off-chip weight-stream words this batch actually fetched.
    pub stream_words: u64,
    /// Stream words the same images would have fetched as sequential
    /// single-image inferences (`per-image words × images batched`).
    pub sequential_stream_words: u64,
}

impl BatchRun {
    /// Stream words saved vs sequential execution — the service's
    /// cumulative `weight_traffic_saved` metric.
    pub fn stream_words_saved(&self) -> u64 {
        self.sequential_stream_words.saturating_sub(self.stream_words)
    }
}

/// A backend that can run inferences for one fixed network.
///
/// `Send + Sync` is required so the serving layers — the single-model
/// batch wrapper ([`super::serve`]) and the long-lived multi-model
/// [`super::service::InferenceService`] — can drive one backend from
/// several worker threads concurrently (the service additionally holds
/// backends as `Arc<dyn Backend>` handles shared with their engines).
pub trait Backend: Send + Sync {
    /// Which backend this is.
    fn kind(&self) -> BackendKind;

    /// The chip-mesh footprint the backend executes on (`(1, 1)` for
    /// single-chip backends).
    fn mesh_shape(&self) -> (usize, usize) {
        (1, 1)
    }

    /// Run one inference. `input` is the flattened on-chip input FM
    /// (`c·h·w` values); the result is the backend's final output — the
    /// last feature map for the simulator backends, the class logits
    /// (off-chip FC head included) for the PJRT backend.
    fn infer(&self, input: &[f32]) -> Result<Vec<f32>, EngineError> {
        self.infer_traced(input, &mut |_| {})
    }

    /// Run one inference, calling `hook` once per executed layer with
    /// that layer's full output FM (cross-validation / debugging).
    fn infer_traced(
        &self,
        input: &[f32],
        hook: &mut dyn FnMut(LayerTrace<'_>),
    ) -> Result<Vec<f32>, EngineError>;

    /// Run a micro-batch of same-network inferences. Per-input outputs
    /// must be **bit-identical** to calling [`Self::infer`] on each
    /// input sequentially, and one failing input fails only its own
    /// slot of [`BatchRun::outputs`].
    ///
    /// The default is the sequential loop fallback (correct for any
    /// backend, no amortization — both stream counters stay zero). The
    /// simulator backends override it with the batch-resident datapath
    /// pass that streams each weight block once across all images.
    fn infer_batch(&self, inputs: &[&[f32]]) -> BatchRun {
        BatchRun {
            outputs: inputs.iter().map(|i| self.infer(i)).collect(),
            stream_words: 0,
            sequential_stream_words: 0,
        }
    }
}

/// Per-step parameters (packed weight stream + folded batch-norm γ/β)
/// for a whole network — what both simulator backends consume.
#[derive(Clone)]
pub struct NetworkParams {
    pub steps: Vec<StepParams>,
}

impl NetworkParams {
    /// Deterministic synthetic parameters from a seed: ±1 weights and
    /// BWN-style `α/fan-in` batch-norm scales that keep FP16 activations
    /// in range over deep stacks (overflow would give `inf − inf = NaN`).
    ///
    /// `c` is the chip's output-channel parallelism (stream word width).
    pub fn seeded(net: &Network, c: usize, seed: u64) -> NetworkParams {
        let mut rng = SplitMix64::new(seed);
        let steps = net
            .steps
            .iter()
            .map(|s| {
                let l = &s.layer;
                let nie = l.n_in / l.groups;
                let w: Vec<f32> = (0..l.n_out * nie * l.k * l.k)
                    .map(|_| rng.next_sym())
                    .collect();
                let fan_in = (nie * l.k * l.k) as f32;
                StepParams {
                    stream: pack_weights(l, &w, c),
                    gamma: (0..l.n_out)
                        .map(|_| (0.25 + 0.5 * rng.next_f32()) / fan_in)
                        .collect(),
                    beta: (0..l.n_out).map(|_| 0.1 * rng.next_sym()).collect(),
                }
            })
            .collect();
        NetworkParams { steps }
    }

    /// Real (trained, binarized) parameters from an AOT artifact
    /// manifest — the exact tensors the PJRT backend executes with.
    pub fn from_manifest(nm: &NetworkManifest, c: usize) -> Result<NetworkParams, EngineError> {
        let mut steps = Vec::with_capacity(nm.network.steps.len());
        for s in &nm.network.steps {
            let l = &s.layer;
            let w = nm
                .blob(&l.name, "w")
                .map_err(|e| EngineError::Backend(format!("{e:#}")))?;
            let gamma = nm
                .blob(&l.name, "gamma")
                .map_err(|e| EngineError::Backend(format!("{e:#}")))?;
            let beta = nm
                .blob(&l.name, "beta")
                .map_err(|e| EngineError::Backend(format!("{e:#}")))?;
            steps.push(StepParams {
                stream: pack_weights(l, w, c),
                gamma: gamma.to_vec(),
                beta: beta.to_vec(),
            });
        }
        Ok(NetworkParams { steps })
    }
}

/// Where a simulator backend's parameters come from. Seeded parameters
/// are materialized lazily on the first inference, so building an
/// engine purely for its analytic [`super::EngineReport`] (e.g.
/// ResNet-152 @ 2048×1024) never allocates weight tensors.
pub(crate) enum ParamSource {
    Seeded(u64),
    Explicit(Arc<NetworkParams>),
}

pub(crate) struct LazyParams {
    source: ParamSource,
    cell: OnceLock<Arc<NetworkParams>>,
}

impl LazyParams {
    pub(crate) fn new(source: ParamSource) -> LazyParams {
        LazyParams {
            source,
            cell: OnceLock::new(),
        }
    }

    pub(crate) fn get(&self, net: &Network, c: usize) -> Arc<NetworkParams> {
        self.cell
            .get_or_init(|| match &self.source {
                ParamSource::Seeded(seed) => Arc::new(NetworkParams::seeded(net, c, *seed)),
                ParamSource::Explicit(p) => p.clone(),
            })
            .clone()
    }
}

//! Single-chip functional backend: walks the network step list through
//! `simulator::chip::run_layer_threads` — Algorithm 1 via the shared
//! Tile-PU datapath kernel, bit-faithful, optionally with the silicon's
//! FP16 datapath rounding, fanned out over output channels on the
//! engine's thread knob. 2× upsample steps (YOLOv3's FPN laterals) are
//! free nearest-neighbour replication, as on the chip's DDUs.

use crate::network::{Network, TensorRef};
use crate::simulator::chip::{run_layer_batch_threads, run_layer_threads, LayerParams};
use crate::simulator::{FeatureMap, Precision};

use super::backend::{Backend, BackendKind, BatchRun, LayerTrace, LazyParams};
use super::EngineError;

pub struct FunctionalBackend {
    net: Network,
    params: LazyParams,
    precision: Precision,
    /// M×N spatial Tile-PU grid (only affects access counting).
    tiles: (usize, usize),
    /// Output-channel parallelism the weight streams are packed for.
    stream_c: usize,
    /// Datapath worker threads (≥ 1; bit-identical at any value).
    threads: usize,
}

impl FunctionalBackend {
    pub(crate) fn new(
        net: Network,
        params: LazyParams,
        precision: Precision,
        tiles: (usize, usize),
        stream_c: usize,
        threads: usize,
    ) -> FunctionalBackend {
        FunctionalBackend {
            net,
            params,
            precision,
            tiles,
            stream_c,
            threads,
        }
    }

    /// Resolved parameters + datapath knobs, handed to
    /// [`crate::video::FrameSession`] by [`super::Engine::video_session`].
    pub(crate) fn video_parts(
        &self,
    ) -> (
        std::sync::Arc<super::backend::NetworkParams>,
        Precision,
        (usize, usize),
        usize,
    ) {
        (
            self.params.get(&self.net, self.stream_c),
            self.precision,
            self.tiles,
            self.threads,
        )
    }
}

impl Backend for FunctionalBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Functional
    }

    fn infer_traced(
        &self,
        input: &[f32],
        hook: &mut dyn FnMut(LayerTrace<'_>),
    ) -> Result<Vec<f32>, EngineError> {
        let net = &self.net;
        let want = net.in_ch * net.in_h * net.in_w;
        if input.len() != want {
            return Err(EngineError::Input(format!(
                "input has {} values, {} expects {want} ({}x{}x{})",
                input.len(),
                net.name,
                net.in_ch,
                net.in_h,
                net.in_w
            )));
        }
        let params = self.params.get(net, self.stream_c);
        let input_fm = FeatureMap::from_vec(net.in_ch, net.in_h, net.in_w, input.to_vec());
        let mut fms: Vec<FeatureMap> = Vec::with_capacity(net.steps.len());

        fn resolve<'a>(
            input_fm: &'a FeatureMap,
            fms: &'a [FeatureMap],
            r: TensorRef,
        ) -> &'a FeatureMap {
            match r {
                TensorRef::Input => input_fm,
                TensorRef::Step(j) => &fms[j],
            }
        }

        for (i, s) in net.steps.iter().enumerate() {
            let src = resolve(&input_fm, &fms, s.src);
            let concatenated;
            let src = if let Some(extra) = s.concat_extra {
                concatenated = src.concat_channels(resolve(&input_fm, &fms, extra));
                &concatenated
            } else {
                src
            };
            let byp = s.bypass.map(|b| resolve(&input_fm, &fms, b));
            let p = &params.steps[i];
            let lp = LayerParams {
                layer: &s.layer,
                stream: &p.stream,
                gamma: &p.gamma,
                beta: &p.beta,
            };
            let (out, _counts) =
                run_layer_threads(&lp, src, byp, self.precision, self.tiles, self.threads);
            // FPN lateral upsampling: free DDU pixel replication, stored 4×.
            let out = if s.upsample2x {
                out.upsample2x_nearest()
            } else {
                out
            };
            hook(LayerTrace {
                step: i,
                layer: &s.layer.name,
                shape: (out.c, out.h, out.w),
                output: &out.data,
            });
            fms.push(out);
        }
        Ok(fms.pop().expect("non-empty network").data)
    }

    /// Batch-resident pass: all valid inputs walk the step list
    /// together through [`run_layer_batch_threads`], so each weight
    /// block streams once per batch instead of once per image. Bad
    /// inputs (wrong length) fail only their own slot; the valid subset
    /// still runs as one batch.
    fn infer_batch(&self, inputs: &[&[f32]]) -> BatchRun {
        let net = &self.net;
        let want = net.in_ch * net.in_h * net.in_w;
        let mut outputs: Vec<Option<Result<Vec<f32>, EngineError>>> = inputs
            .iter()
            .map(|input| {
                (input.len() != want).then(|| {
                    Err(EngineError::Input(format!(
                        "input has {} values, {} expects {want} ({}x{}x{})",
                        input.len(),
                        net.name,
                        net.in_ch,
                        net.in_h,
                        net.in_w
                    )))
                })
            })
            .collect();
        let valid: Vec<usize> = (0..inputs.len())
            .filter(|&i| outputs[i].is_none())
            .collect();
        let nb = valid.len();
        let mut run = BatchRun::default();
        if nb > 0 {
            let params = self.params.get(net, self.stream_c);
            let input_fms: Vec<FeatureMap> = valid
                .iter()
                .map(|&i| FeatureMap::from_vec(net.in_ch, net.in_h, net.in_w, inputs[i].to_vec()))
                .collect();
            // fms[step][image]: every intermediate stays resident for
            // the whole batch, like the B on-chip feature maps.
            let mut fms: Vec<Vec<FeatureMap>> = Vec::with_capacity(net.steps.len());

            fn resolve<'a>(
                input_fms: &'a [FeatureMap],
                fms: &'a [Vec<FeatureMap>],
                bi: usize,
                r: TensorRef,
            ) -> &'a FeatureMap {
                match r {
                    TensorRef::Input => &input_fms[bi],
                    TensorRef::Step(j) => &fms[j][bi],
                }
            }

            for (i, s) in net.steps.iter().enumerate() {
                let concatenated: Vec<FeatureMap>;
                let srcs: Vec<&FeatureMap> = if let Some(extra) = s.concat_extra {
                    concatenated = (0..nb)
                        .map(|bi| {
                            resolve(&input_fms, &fms, bi, s.src)
                                .concat_channels(resolve(&input_fms, &fms, bi, extra))
                        })
                        .collect();
                    concatenated.iter().collect()
                } else {
                    (0..nb).map(|bi| resolve(&input_fms, &fms, bi, s.src)).collect()
                };
                let byps: Option<Vec<&FeatureMap>> = s
                    .bypass
                    .map(|b| (0..nb).map(|bi| resolve(&input_fms, &fms, bi, b)).collect());
                let p = &params.steps[i];
                let lp = LayerParams {
                    layer: &s.layer,
                    stream: &p.stream,
                    gamma: &p.gamma,
                    beta: &p.beta,
                };
                let (outs, counts) = run_layer_batch_threads(
                    &lp,
                    &srcs,
                    byps.as_deref(),
                    self.precision,
                    self.tiles,
                    self.threads,
                );
                run.stream_words += counts.stream_words;
                let outs = if s.upsample2x {
                    outs.into_iter().map(|o| o.upsample2x_nearest()).collect()
                } else {
                    outs
                };
                fms.push(outs);
            }
            // Each layer's words streamed once per batch vs once per
            // image sequentially.
            run.sequential_stream_words = run.stream_words * nb as u64;
            let finals = fms.pop().expect("non-empty network");
            for (&slot, out) in valid.iter().zip(finals) {
                outputs[slot] = Some(Ok(out.data));
            }
        }
        run.outputs = outputs
            .into_iter()
            .map(|o| o.expect("every slot resolved"))
            .collect();
        run
    }
}

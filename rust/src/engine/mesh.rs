//! Multi-chip mesh backend: runs the whole network on the §V systolic
//! array simulator (`simulator::mesh::MeshSim`) — real distributed FM
//! tiles, real border/corner exchange — and keeps the traffic statistics
//! of the last inference for reporting.

use std::sync::Mutex;

use crate::network::{Network, TensorRef};
use crate::simulator::mesh::{MeshSim, MeshStats};
use crate::simulator::{FeatureMap, Precision};

use super::backend::{Backend, BackendKind, BatchRun, LayerTrace, LazyParams};
use super::EngineError;

pub struct MeshBackend {
    net: Network,
    params: LazyParams,
    rows: usize,
    cols: usize,
    precision: Precision,
    fm_bits: usize,
    stream_c: usize,
    /// Datapath worker threads for the per-step chip fan-out (≥ 1;
    /// bit-identical results and statistics at any value).
    threads: usize,
    /// Traffic statistics of the most recent inference.
    last_stats: Mutex<Option<MeshStats>>,
}

impl MeshBackend {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        net: Network,
        params: LazyParams,
        rows: usize,
        cols: usize,
        precision: Precision,
        fm_bits: usize,
        stream_c: usize,
        threads: usize,
    ) -> MeshBackend {
        MeshBackend {
            net,
            params,
            rows,
            cols,
            precision,
            fm_bits,
            stream_c,
            threads,
            last_stats: Mutex::new(None),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Border/corner traffic of the most recent inference, if any.
    pub fn last_stats(&self) -> Option<MeshStats> {
        self.last_stats.lock().unwrap().clone()
    }

    /// Resolved parameters + datapath knobs, handed to
    /// [`crate::video::FrameSession`] by [`super::Engine::video_session`]
    /// — after the same divisibility check every inference runs.
    pub(crate) fn video_parts(
        &self,
    ) -> Result<
        (
            std::sync::Arc<super::backend::NetworkParams>,
            Precision,
            usize,
        ),
        EngineError,
    > {
        self.check_divisibility()?;
        Ok((
            self.params.get(&self.net, self.stream_c),
            self.precision,
            self.fm_bits,
        ))
    }

    /// The mesh simulator requires every tensor's spatial dims to divide
    /// evenly over the chip grid; reject cleanly instead of panicking.
    fn check_divisibility(&self) -> Result<(), EngineError> {
        let check = |what: &str, h: usize, w: usize| -> Result<(), EngineError> {
            if h % self.rows != 0 || w % self.cols != 0 {
                return Err(EngineError::Unsupported(format!(
                    "{what} is {h}x{w}, not divisible over a {}x{} mesh",
                    self.rows, self.cols
                )));
            }
            Ok(())
        };
        check("input FM", self.net.in_h, self.net.in_w)?;
        for (i, s) in self.net.steps.iter().enumerate() {
            let (_, h, w) = self.net.shape_of(TensorRef::Step(i));
            check(&format!("step {i} (`{}`) output", s.layer.name), h, w)?;
            // Upsample steps compute on the pre-upsample grid first; it
            // must divide too (shape_of only reports the doubled dims).
            if s.upsample2x {
                check(
                    &format!("step {i} (`{}`) pre-upsample output", s.layer.name),
                    s.layer.h_out(),
                    s.layer.w_out(),
                )?;
            }
        }
        Ok(())
    }
}

impl Backend for MeshBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Mesh
    }

    fn mesh_shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Untraced inference skips the per-step global-FM reassembly the
    /// trace observer needs — `serve()` requests pay only the compute
    /// and exchange, like `MeshSim::run_network` always did.
    fn infer(&self, input: &[f32]) -> Result<Vec<f32>, EngineError> {
        self.run(input, None)
    }

    fn infer_traced(
        &self,
        input: &[f32],
        hook: &mut dyn FnMut(LayerTrace<'_>),
    ) -> Result<Vec<f32>, EngineError> {
        self.run(input, Some(hook))
    }

    /// Batch-resident mesh pass: the valid inputs run through
    /// [`MeshSim::run_network_batch`], which broadcasts each weight
    /// block once per chip per batch. Wrong-length inputs fail only
    /// their own slot; a whole-mesh failure (e.g. indivisible FM dims)
    /// fails each slot with the same typed error, exactly as sequential
    /// `infer` calls would.
    fn infer_batch(&self, inputs: &[&[f32]]) -> BatchRun {
        let net = &self.net;
        let want = net.in_ch * net.in_h * net.in_w;
        let mut outputs: Vec<Option<Result<Vec<f32>, EngineError>>> = inputs
            .iter()
            .map(|input| {
                (input.len() != want).then(|| {
                    Err(EngineError::Input(format!(
                        "input has {} values, {} expects {want} ({}x{}x{})",
                        input.len(),
                        net.name,
                        net.in_ch,
                        net.in_h,
                        net.in_w
                    )))
                })
            })
            .collect();
        let valid: Vec<usize> = (0..inputs.len())
            .filter(|&i| outputs[i].is_none())
            .collect();
        let mut run = BatchRun::default();
        if !valid.is_empty() {
            if self.check_divisibility().is_err() {
                // Every batched request sees the exact typed error its
                // own sequential inference would have hit.
                for &slot in &valid {
                    outputs[slot] = Some(Err(self
                        .check_divisibility()
                        .expect_err("divisibility failed above")));
                }
            } else {
                match self.run_batch(inputs, &valid) {
                    Ok((outs, stream_words)) => {
                        run.stream_words = stream_words;
                        run.sequential_stream_words = stream_words * valid.len() as u64;
                        for (&slot, out) in valid.iter().zip(outs) {
                            outputs[slot] = Some(Ok(out));
                        }
                    }
                    Err(me) => {
                        for &slot in &valid {
                            outputs[slot] = Some(Err(me.clone().into()));
                        }
                    }
                }
            }
        }
        run.outputs = outputs
            .into_iter()
            .map(|o| o.expect("every slot resolved"))
            .collect();
        run
    }
}

impl MeshBackend {
    fn run(
        &self,
        input: &[f32],
        hook: Option<&mut dyn FnMut(LayerTrace<'_>)>,
    ) -> Result<Vec<f32>, EngineError> {
        let net = &self.net;
        let want = net.in_ch * net.in_h * net.in_w;
        if input.len() != want {
            return Err(EngineError::Input(format!(
                "input has {} values, {} expects {want} ({}x{}x{})",
                input.len(),
                net.name,
                net.in_ch,
                net.in_h,
                net.in_w
            )));
        }
        self.check_divisibility()?;
        let params = self.params.get(net, self.stream_c);
        let input_fm = FeatureMap::from_vec(net.in_ch, net.in_h, net.in_w, input.to_vec());
        let mut sim = MeshSim::new(self.rows, self.cols, self.precision);
        sim.fm_bits = self.fm_bits;
        sim.threads = self.threads;
        let (out, stats) = match hook {
            Some(hook) => {
                let mut adapter = |step: usize, fm: &FeatureMap| {
                    hook(LayerTrace {
                        step,
                        layer: &net.steps[step].layer.name,
                        shape: (fm.c, fm.h, fm.w),
                        output: &fm.data,
                    });
                };
                sim.run_network_traced(net, &params.steps, &input_fm, &mut adapter)?
            }
            None => sim.run_network(net, &params.steps, &input_fm)?,
        };
        *self.last_stats.lock().unwrap() = Some(stats);
        Ok(out.data)
    }

    /// The already-validated subset of a batch through the mesh batch
    /// pass. Returns per-image outputs (in `valid` order) and the
    /// batch's off-chip stream words.
    fn run_batch(
        &self,
        inputs: &[&[f32]],
        valid: &[usize],
    ) -> Result<(Vec<Vec<f32>>, u64), crate::simulator::mesh::MeshError> {
        let net = &self.net;
        let params = self.params.get(net, self.stream_c);
        let input_fms: Vec<FeatureMap> = valid
            .iter()
            .map(|&i| FeatureMap::from_vec(net.in_ch, net.in_h, net.in_w, inputs[i].to_vec()))
            .collect();
        let in_refs: Vec<&FeatureMap> = input_fms.iter().collect();
        let mut sim = MeshSim::new(self.rows, self.cols, self.precision);
        sim.fm_bits = self.fm_bits;
        sim.threads = self.threads;
        let (outs, stats) = sim.run_network_batch(net, &params.steps, &in_refs)?;
        let stream_words = stats.access.stream_words;
        *self.last_stats.lock().unwrap() = Some(stats);
        Ok((outs.into_iter().map(|o| o.data).collect(), stream_words))
    }
}

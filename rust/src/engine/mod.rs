//! # The unified Hyperdrive engine
//!
//! One backend-agnostic façade over the three execution paths of this
//! reproduction — the PJRT runtime that executes the AOT-compiled
//! Pallas artifacts, the single-chip functional simulator and the
//! multi-chip systolic mesh simulator — mirroring how the paper
//! presents one accelerator abstraction that scales from a single chip
//! to a 2D mesh without the caller caring which is underneath.
//!
//! Construction goes through the fluent [`EngineBuilder`]; networks are
//! named by [`ModelSpec`] strings resolved through the
//! [`crate::model::NetworkRegistry`]:
//!
//! ```no_run
//! use hyperdrive::engine::{Engine, ServeOptions};
//! use hyperdrive::simulator::Precision;
//!
//! # fn main() -> Result<(), hyperdrive::engine::EngineError> {
//! // Functional single-chip simulator, FP16 datapath like the silicon.
//! let engine = Engine::builder()
//!     .model("hypernet20")
//!     .precision(Precision::F16)
//!     .build()?;
//! let input = vec![0.0f32; engine.input_len()];
//! let logits = engine.infer(&input)?;
//!
//! // 2×2 systolic mesh, same spec + seed → bit-exact same logits.
//! let mesh = Engine::builder().model("hypernet20").mesh(2, 2).build()?;
//! assert_eq!(mesh.infer(&input)?, logits);
//!
//! // Concurrent serving on any backend: per-request results (one
//! // failing request never discards another's output) + statistics.
//! let batch = vec![input; 8];
//! let opts = ServeOptions { workers: 4, ..ServeOptions::default() };
//! let outcome = engine.serve(&batch, &opts)?;
//! println!("{}", engine.report_with_serve(outcome.stats.clone()).serve_summary());
//! let (outs, _stats) = outcome.outputs()?; // all-or-nothing view
//! # let _ = outs;
//! # Ok(()) }
//! ```
//!
//! `Engine::serve` is a compatibility wrapper over the long-lived,
//! multi-model [`service::InferenceService`] — the first-class serving
//! subsystem (named models, routed [`InferRequest`]s, admission
//! policies, live [`ServiceMetrics`], hot add/remove); see
//! [`service`].
//!
//! Every engine also yields a typed [`EngineReport`] (schedule, WCL
//! memory analysis, mesh plan, energy breakdown) that the CLI, the
//! examples, the benches and `report::*` consume.

pub mod backend;
pub mod functional;
pub mod mesh;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod report;
pub mod serve;
pub mod service;
pub mod wire;

use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::schedule::schedule_network_mesh;
use crate::coordinator::tiling::{self, MeshPlan};
use crate::coordinator::wcl;
use crate::energy::ablation::AblationRow;
use crate::energy::model::energy_per_image;
use crate::model::{ModelError, ModelSpec, NetworkRegistry};
use crate::network::Network;
use crate::simulator::mesh::{MeshError, MeshStats};
use crate::ChipConfig;

pub use backend::{Backend, BackendKind, BatchRun, LayerTrace, NetworkParams};
pub use report::EngineReport;
pub use serve::{percentile, ServeOptions, ServeOutcome, ServeStats};
pub use service::{
    AdmissionPolicy, BatchPolicy, BreakerPolicy, BreakerState, InferRequest, InferResponse,
    InferenceService, ModelConfig, ModelMetrics, ServeError, ServiceBuilder, ServiceMetrics,
    Ticket,
};
pub use wire::{
    run_loadgen, LoadGenConfig, LoadGenReport, RetryPolicy, WireClient, WireError, WireServer,
    WireStats,
};
// Re-exported so engine consumers need no coordinator/simulator paths.
pub use crate::coordinator::schedule::DepthwisePolicy;
pub use crate::simulator::Precision;

use backend::{LazyParams, ParamSource};
use functional::FunctionalBackend;
use mesh::MeshBackend;

/// Errors of the unified engine API.
#[derive(Debug)]
pub enum EngineError {
    /// Builder misconfiguration (e.g. a mesh without a network).
    Builder(String),
    /// A `.model(..)` spec failed to parse or resolve.
    Model(ModelError),
    /// The requested mesh's per-chip WCL slice exceeds the FMM.
    FmmOverflow {
        rows: usize,
        cols: usize,
        per_chip_wcl_words: u64,
        fmm_words: usize,
    },
    /// Backend compiled out or its artifacts are missing.
    Unavailable(String),
    /// A request input does not match the network.
    Input(String),
    /// The chosen backend cannot execute this network feature.
    Unsupported(String),
    /// Runtime failure inside a backend.
    Backend(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Builder(m) => write!(f, "builder: {m}"),
            EngineError::Model(e) => write!(f, "model: {e}"),
            EngineError::FmmOverflow {
                rows,
                cols,
                per_chip_wcl_words,
                fmm_words,
            } => write!(
                f,
                "{rows}x{cols} mesh: per-chip WCL {per_chip_wcl_words} words \
                 exceeds the {fmm_words}-word FMM"
            ),
            EngineError::Unavailable(m) => write!(f, "backend unavailable: {m}"),
            EngineError::Input(m) => write!(f, "bad input: {m}"),
            EngineError::Unsupported(m) => write!(f, "unsupported: {m}"),
            EngineError::Backend(m) => write!(f, "backend: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<ModelError> for EngineError {
    fn from(e: ModelError) -> Self {
        EngineError::Model(e)
    }
}

impl From<MeshError> for EngineError {
    fn from(e: MeshError) -> Self {
        EngineError::Backend(format!("mesh: {e}"))
    }
}

impl From<ServeError> for EngineError {
    fn from(e: ServeError) -> Self {
        EngineError::Backend(format!("serve: {e}"))
    }
}

enum BackendImpl {
    Functional(FunctionalBackend),
    Mesh(MeshBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

impl BackendImpl {
    fn as_dyn(&self) -> &dyn Backend {
        match self {
            BackendImpl::Functional(b) => b,
            BackendImpl::Mesh(b) => b,
            #[cfg(feature = "pjrt")]
            BackendImpl::Pjrt(b) => b,
        }
    }
}

// Delegated so an `Arc<BackendImpl>` coerces to `Arc<dyn Backend>` —
// that one shared handle is what lets an engine's backend be hosted by
// an [`service::InferenceService`] (and by the `Engine::serve` compat
// wrapper) without cloning the engine.
impl Backend for BackendImpl {
    fn kind(&self) -> BackendKind {
        self.as_dyn().kind()
    }

    fn mesh_shape(&self) -> (usize, usize) {
        self.as_dyn().mesh_shape()
    }

    fn infer(&self, input: &[f32]) -> Result<Vec<f32>, EngineError> {
        self.as_dyn().infer(input)
    }

    fn infer_traced(
        &self,
        input: &[f32],
        hook: &mut dyn FnMut(LayerTrace<'_>),
    ) -> Result<Vec<f32>, EngineError> {
        self.as_dyn().infer_traced(input, hook)
    }

    // Explicit: without this the trait's sequential-loop default would
    // shadow the simulator backends' batch-resident overrides for every
    // caller holding the `Arc<BackendImpl>` (i.e. the whole service).
    fn infer_batch(&self, inputs: &[&[f32]]) -> backend::BatchRun {
        self.as_dyn().infer_batch(inputs)
    }
}

/// Fluent constructor for [`Engine`]; see the [module docs](self) for
/// a per-backend example.
pub struct EngineBuilder {
    model: Option<String>,
    registry: Option<NetworkRegistry>,
    network: Option<Network>,
    chip: ChipConfig,
    kind: Option<BackendKind>,
    mesh: Option<(usize, usize)>,
    auto_mesh: bool,
    precision: Precision,
    dw: DepthwisePolicy,
    vdd: f64,
    vbb: f64,
    params: Option<Arc<NetworkParams>>,
    seed: u64,
    artifacts: Option<PathBuf>,
    threads: Option<usize>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            model: None,
            registry: None,
            network: None,
            chip: ChipConfig::default(),
            kind: None,
            mesh: None,
            auto_mesh: false,
            precision: Precision::F16,
            dw: DepthwisePolicy::default(),
            vdd: 0.5,
            vbb: 1.5,
            params: None,
            seed: 0x42,
            artifacts: None,
            threads: None,
        }
    }
}

impl EngineBuilder {
    /// Resolve the network from a [`ModelSpec`] string (the preferred
    /// entry point): `resnet34@512x1024`, `yolov3@416`,
    /// `manifest:artifacts#hypernet20`, … — parsed and resolved through
    /// the registry at `build()` time.
    ///
    /// Registry specs keep the builder's lazy [`seed`](Self::seed)ed
    /// parameters; `manifest:` specs additionally load the trained
    /// parameter blobs for the simulator backends (unless explicit
    /// [`params`](Self::params) are given or the PJRT backend was
    /// forced, which reads the artifacts itself).
    pub fn model(mut self, spec: impl Into<String>) -> Self {
        self.model = Some(spec.into());
        self
    }

    /// Resolve `.model(..)` against a custom registry instead of
    /// [`NetworkRegistry::builtin`].
    pub fn registry(mut self, registry: NetworkRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// The network to run, pre-built (alternative to [`model`](Self::model);
    /// the PJRT backend reads its network from the artifact manifest).
    pub fn network(mut self, net: Network) -> Self {
        self.network = Some(net);
        self
    }

    /// Chip architecture parameters (defaults to the taped-out config).
    pub fn chip(mut self, cfg: ChipConfig) -> Self {
        self.chip = cfg;
        self
    }

    /// Force a specific backend (normally inferred: `.artifacts(..)` →
    /// PJRT, `.mesh(..)`/`.auto_mesh()` → mesh, otherwise functional).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.kind = Some(kind);
        self
    }

    /// Run on an explicit `rows×cols` systolic mesh (validated against
    /// the per-chip FMM capacity at `build()`).
    pub fn mesh(mut self, rows: usize, cols: usize) -> Self {
        self.mesh = Some((rows, cols));
        self
    }

    /// Plan the smallest aspect-matched mesh that fits the FMM (§V),
    /// like the paper's 10×5 for ResNet-34 @ 2048×1024.
    pub fn auto_mesh(mut self) -> Self {
        self.auto_mesh = true;
        self
    }

    /// Datapath precision of the simulator backends (default: the
    /// silicon's bit-exact FP16).
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// Depth-wise convolution scheduling policy.
    pub fn depthwise(mut self, dw: DepthwisePolicy) -> Self {
        self.dw = dw;
        self
    }

    /// Core supply voltage for the energy model (default 0.5 V).
    pub fn vdd(mut self, v: f64) -> Self {
        self.vdd = v;
        self
    }

    /// Forward body bias for the energy model (default 1.5 V).
    pub fn vbb(mut self, v: f64) -> Self {
        self.vbb = v;
        self
    }

    /// Explicit layer parameters for the simulator backends (share one
    /// `Arc<NetworkParams>` across engines for cross-backend checks).
    pub fn params(mut self, p: impl Into<Arc<NetworkParams>>) -> Self {
        self.params = Some(p.into());
        self
    }

    /// Seed for lazily-generated synthetic parameters (used when no
    /// explicit `params` are given; default `0x42`).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// AOT artifact directory — selects the PJRT backend.
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    /// Worker threads for the simulator backends' shared datapath
    /// kernel: the single-chip simulator fans each layer out over
    /// output-channel ranges, the mesh computes its chips concurrently
    /// per step. Defaults to `std::thread::available_parallelism()`.
    /// Outputs and traffic counters are bit-identical at any value
    /// (each pixel's FP16 rounding sequence runs on one worker); must
    /// be ≥ 1. Ignored by the PJRT backend (use
    /// [`ServeOptions::workers`] for serving concurrency).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    fn resolve_kind(&self) -> Result<BackendKind, EngineError> {
        if let Some(kind) = self.kind {
            return Ok(kind);
        }
        match (&self.artifacts, self.mesh.is_some() || self.auto_mesh) {
            (Some(_), true) => Err(EngineError::Builder(
                "both .artifacts(..) and .mesh(..) given — pick a backend explicitly".into(),
            )),
            (Some(_), false) => Ok(BackendKind::Pjrt),
            (None, true) => Ok(BackendKind::Mesh),
            (None, false) => Ok(BackendKind::Functional),
        }
    }

    /// Resolve a pending `.model(..)` spec into `network` (and, for
    /// manifest specs, `params`/`artifacts`).
    fn resolve_model(&mut self) -> Result<(), EngineError> {
        // With the PJRT backend compiled out, a forced-PJRT build must
        // keep reporting `Unavailable` (from `build_pjrt`) rather than
        // failing here on manifest loading.
        #[cfg(not(feature = "pjrt"))]
        if self.kind == Some(BackendKind::Pjrt) {
            return Ok(());
        }
        let Some(spec) = self.model.take() else {
            return Ok(());
        };
        if self.network.is_some() {
            return Err(EngineError::Builder(
                "both .model(..) and .network(..) given — name the network one way".into(),
            ));
        }
        let spec: ModelSpec = spec.parse().map_err(ModelError::Spec)?;
        // A forced PJRT backend loads the network and tensors from the
        // artifacts itself: take the directory (and check the `#name`
        // fragment against the manifest header only) instead of a full
        // registry resolution, which would read the parameter blob a
        // second time.
        #[cfg(feature = "pjrt")]
        if self.kind == Some(BackendKind::Pjrt) {
            if let ModelSpec::Manifest { dir, network } = &spec {
                if self.artifacts.is_none() {
                    self.artifacts = Some(dir.clone());
                }
                if let Some(expected) = network {
                    use crate::model::registry::normalize;
                    let found = crate::util::manifest::Manifest::load(dir)
                        .and_then(|m| Ok(m.unique("network")?.get("name")?.to_string()))
                        .map_err(|e| ModelError::Manifest(format!("{e:#}")))?;
                    if normalize(expected) != normalize(&found) {
                        return Err(EngineError::Model(ModelError::ManifestNetworkMismatch {
                            expected: expected.clone(),
                            found,
                        }));
                    }
                }
                return Ok(());
            }
        }
        let registry = self.registry.take().unwrap_or_else(NetworkRegistry::builtin);
        let resolved = registry.resolve(&spec)?;
        // Materialize real weight tensors for the simulator backends;
        // seeded sources stay on the builder's lazy `seed` path, and the
        // PJRT backend loads its own tensors from the artifacts. (An
        // out-of-range chip `c` is left for `build_sim`'s typed error.)
        let pjrt_bound = self.kind == Some(BackendKind::Pjrt) || self.artifacts.is_some();
        if self.params.is_none()
            && resolved.weights.seed().is_none()
            && !pjrt_bound
            && self.chip.c <= 16
        {
            let p = resolved.weights.params(&resolved.network, self.chip.c)?;
            self.params = Some(Arc::new(p));
        }
        self.network = Some(resolved.network);
        Ok(())
    }

    /// Validate the configuration and construct the engine.
    pub fn build(mut self) -> Result<Engine, EngineError> {
        self.resolve_model()?;
        let kind = self.resolve_kind()?;
        // A forced backend must not silently ignore conflicting knobs:
        // a mesh request on a non-mesh backend (or artifacts on a
        // simulator backend) would otherwise yield a 1x1 plan/report
        // that looks valid but answers a different question.
        if kind != BackendKind::Mesh && (self.mesh.is_some() || self.auto_mesh) {
            return Err(EngineError::Builder(format!(
                ".mesh(..)/.auto_mesh() conflicts with the {} backend",
                kind.name()
            )));
        }
        if kind != BackendKind::Pjrt && self.artifacts.is_some() {
            return Err(EngineError::Builder(format!(
                ".artifacts(..) conflicts with the {} backend",
                kind.name()
            )));
        }
        match kind {
            BackendKind::Pjrt => self.build_pjrt(),
            kind => self.build_sim(kind),
        }
    }

    #[cfg(feature = "pjrt")]
    fn build_pjrt(self) -> Result<Engine, EngineError> {
        let dir = self
            .artifacts
            .clone()
            .unwrap_or_else(|| PathBuf::from("artifacts"));
        let be = pjrt::PjrtBackend::load(dir)?;
        let net = be.network().clone();
        if let Some(built) = &self.network {
            if built.name != net.name {
                return Err(EngineError::Builder(format!(
                    "builder network `{}` does not match artifact network `{}`",
                    built.name, net.name
                )));
            }
        }
        let plan = MeshPlan {
            rows: 1,
            cols: 1,
            per_chip_wcl_words: wcl::analyze(&net).wcl_words,
        };
        self.finish(net, plan, BackendKind::Pjrt, |_, _| Ok(BackendImpl::Pjrt(be)))
    }

    #[cfg(not(feature = "pjrt"))]
    fn build_pjrt(self) -> Result<Engine, EngineError> {
        Err(EngineError::Unavailable(
            "the PJRT backend needs the `pjrt` cargo feature (vendored xla-rs) \
             — see DESIGN.md §Substitutions"
                .into(),
        ))
    }

    fn build_sim(self, kind: BackendKind) -> Result<Engine, EngineError> {
        let net = self.network.clone().ok_or_else(|| {
            EngineError::Builder(format!(
                "the {} backend needs .network(..) before .build()",
                kind.name()
            ))
        })?;
        if net.steps.is_empty() {
            return Err(EngineError::Builder(format!(
                "network `{}` has no on-chip steps",
                net.name
            )));
        }
        if self.chip.c > 16 {
            return Err(EngineError::Builder(format!(
                "chip c = {} unsupported: weight-stream words are u16",
                self.chip.c
            )));
        }
        let threads = match self.threads {
            Some(0) => {
                return Err(EngineError::Builder(
                    ".threads(0) is invalid — give a positive count (or omit \
                     for available_parallelism)"
                        .into(),
                ))
            }
            Some(n) => n,
            None => crate::simulator::datapath::resolve_threads(0),
        };
        let plan = match (kind, self.mesh) {
            (BackendKind::Mesh, Some((rows, cols))) => {
                if rows == 0 || cols == 0 {
                    return Err(EngineError::Builder(format!(
                        "mesh dimensions must be positive, got {rows}x{cols}"
                    )));
                }
                let w = tiling::per_chip_wcl_words(&net, rows, cols);
                if w > self.chip.fmm_words as u64 {
                    return Err(EngineError::FmmOverflow {
                        rows,
                        cols,
                        per_chip_wcl_words: w,
                        fmm_words: self.chip.fmm_words,
                    });
                }
                MeshPlan {
                    rows,
                    cols,
                    per_chip_wcl_words: w,
                }
            }
            (BackendKind::Mesh, None) => self.plan_auto(&net)?,
            _ => MeshPlan {
                rows: 1,
                cols: 1,
                per_chip_wcl_words: wcl::analyze(&net).wcl_words,
            },
        };
        let source = match &self.params {
            Some(p) => ParamSource::Explicit(p.clone()),
            None => ParamSource::Seeded(self.seed),
        };
        self.finish(net, plan, kind, |net, b| {
            Ok(match kind {
                BackendKind::Functional => BackendImpl::Functional(FunctionalBackend::new(
                    net.clone(),
                    LazyParams::new(source),
                    b.precision,
                    (b.chip.m, b.chip.n),
                    b.chip.c,
                    threads,
                )),
                BackendKind::Mesh => BackendImpl::Mesh(MeshBackend::new(
                    net.clone(),
                    LazyParams::new(source),
                    plan.rows,
                    plan.cols,
                    b.precision,
                    b.chip.fm_bits,
                    b.chip.c,
                    threads,
                )),
                BackendKind::Pjrt => unreachable!("handled in build()"),
            })
        })
    }

    /// Aspect-matched smallest mesh that fits the FMM, as an error
    /// instead of `tiling::plan_mesh`'s panic.
    fn plan_auto(&self, net: &Network) -> Result<MeshPlan, EngineError> {
        tiling::try_plan_mesh(net, &self.chip).ok_or_else(|| {
            EngineError::Builder(format!(
                "no aspect-matched mesh up to 64 rows fits `{}` in the {}-word FMM",
                net.name, self.chip.fmm_words
            ))
        })
    }

    /// Shared tail: derive the analytic report, then build the backend.
    fn finish(
        self,
        net: Network,
        plan: MeshPlan,
        kind: BackendKind,
        make: impl FnOnce(&Network, &EngineBuilder) -> Result<BackendImpl, EngineError>,
    ) -> Result<Engine, EngineError> {
        let schedule = schedule_network_mesh(&net, &self.chip, self.dw, plan.rows, plan.cols);
        let memory = wcl::analyze(&net);
        let energy = energy_per_image(&net, &self.chip, &plan, self.vdd, self.vbb, self.dw);
        let border_bits = tiling::border_exchange_bits(&net, &plan, self.chip.fm_bits);
        let report = EngineReport {
            network: net.name.clone(),
            input_shape: (net.in_ch, net.in_h, net.in_w),
            backend: kind,
            chip: self.chip,
            plan,
            precision: self.precision,
            depthwise: self.dw,
            vdd: self.vdd,
            vbb: self.vbb,
            schedule,
            memory,
            energy,
            border_bits,
            serve: None,
        };
        let backend = Arc::new(make(&net, &self)?);
        Ok(Engine {
            backend,
            net,
            cfg: self.chip,
            report,
        })
    }
}

/// A built engine: one network bound to one backend, ready to infer,
/// serve and report. See the [module docs](self). The backend sits
/// behind an `Arc` so a [`service::InferenceService`] can host it
/// while the engine stays usable.
pub struct Engine {
    backend: Arc<BackendImpl>,
    net: Network,
    cfg: ChipConfig,
    report: EngineReport,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    pub fn chip(&self) -> &ChipConfig {
        &self.cfg
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.backend.as_dyn().kind()
    }

    /// Flattened input length the network expects (`c·h·w`).
    pub fn input_len(&self) -> usize {
        self.net.in_ch * self.net.in_h * self.net.in_w
    }

    /// Resident packed binary-weight footprint of the whole network,
    /// in bytes: the `u64` bitplanes every layer's
    /// [`WeightStream`](crate::bwn::WeightStream) occupies at
    /// 1 bit/weight with this chip's `C`. This is the serving-side
    /// working set a hosted model costs, surfaced per model by
    /// [`service::ServiceMetrics`].
    pub fn resident_weight_bytes(&self) -> u64 {
        crate::bwn::network_packed_bytes(&self.net, self.cfg.c)
    }

    /// Run one inference.
    pub fn infer(&self, input: &[f32]) -> Result<Vec<f32>, EngineError> {
        self.backend.as_dyn().infer(input)
    }

    /// Run one inference with a per-layer trace hook.
    pub fn infer_traced(
        &self,
        input: &[f32],
        hook: &mut dyn FnMut(LayerTrace<'_>),
    ) -> Result<Vec<f32>, EngineError> {
        self.backend.as_dyn().infer_traced(input, hook)
    }

    /// Run a micro-batch: all inputs stay resident while each weight
    /// block streams once (§III-B amortization). Per-input outputs are
    /// bit-identical to sequential [`infer`](Self::infer) calls, one
    /// failing input fails only its own slot, and the returned
    /// [`BatchRun`] counters quantify the weight traffic saved.
    pub fn infer_batch(&self, inputs: &[&[f32]]) -> BatchRun {
        self.backend.as_dyn().infer_batch(inputs)
    }

    /// Serve a FIFO batch over a bounded queue and `opts.workers`
    /// concurrent workers — a thin compatibility wrapper over a
    /// temporary single-model [`service::InferenceService`]. Results
    /// come back **per request** in submission order
    /// ([`ServeOutcome`]): a failing or panicking request costs its
    /// own slot, never the batch. Use [`ServeOutcome::outputs`] for
    /// the historical all-or-nothing view.
    ///
    /// Because the service's workers outlive this borrow, the wrapper
    /// copies each input once to hand the service ownership. Hot
    /// serving paths should submit through
    /// [`service::InferenceService`] directly — its
    /// [`InferRequest`] takes ownership and never copies.
    pub fn serve(
        &self,
        inputs: &[Vec<f32>],
        opts: &ServeOptions,
    ) -> Result<ServeOutcome, EngineError> {
        let want = self.input_len();
        for (i, x) in inputs.iter().enumerate() {
            if x.len() != want {
                return Err(EngineError::Input(format!(
                    "request {i}: input has {} values, network expects {want}",
                    x.len()
                )));
            }
        }
        serve::serve_outcome_on(
            self.shared_backend(),
            &self.net.name,
            self.net.total_ops(),
            self.resident_weight_bytes(),
            inputs,
            opts,
        )
    }

    /// The engine's backend as a shareable handle — how a
    /// [`service::InferenceService`] (and the serve wrapper) hosts
    /// this engine's execution path without cloning the engine.
    pub(crate) fn shared_backend(&self) -> Arc<dyn Backend> {
        self.backend.clone()
    }

    /// The analytic report (schedule, memory, energy, mesh plan).
    pub fn report(&self) -> EngineReport {
        self.report.clone()
    }

    /// The analytic report with serving statistics attached.
    pub fn report_with_serve(&self, stats: ServeStats) -> EngineReport {
        let mut r = self.report.clone();
        r.serve = Some(stats);
        r
    }

    /// The §VI-D precision-ablation rows for this network/chip.
    pub fn ablation(&self) -> Vec<AblationRow> {
        crate::energy::ablation::precision_ablation(&self.net, &self.cfg)
    }

    /// Open a streaming-video session on this engine's simulator
    /// backend: the previous frame's activations stay resident and each
    /// new frame recomputes only the tiles whose receptive fields
    /// changed — bit-exact versus a full per-frame recompute at
    /// `eps = 0.0`. `tile` is the dirty-map tile edge in pixels. The
    /// PJRT backend has no resident-activation hook and is rejected as
    /// [`EngineError::Unsupported`]. See [`crate::video`].
    pub fn video_session(
        &self,
        tile: usize,
        eps: f32,
    ) -> Result<crate::video::FrameSession, EngineError> {
        use crate::video::{FrameSession, VideoConfig};
        match &*self.backend {
            BackendImpl::Functional(b) => {
                let (params, precision, tiles_mn, threads) = b.video_parts();
                Ok(FrameSession::new(
                    self.net.clone(),
                    params,
                    VideoConfig {
                        precision,
                        tile,
                        eps,
                        tiles_mn,
                        threads,
                        mesh: None,
                        fm_bits: self.cfg.fm_bits,
                    },
                ))
            }
            BackendImpl::Mesh(m) => {
                let (params, precision, fm_bits) = m.video_parts()?;
                Ok(FrameSession::new(
                    self.net.clone(),
                    params,
                    VideoConfig {
                        precision,
                        tile,
                        eps,
                        tiles_mn: (self.cfg.m, self.cfg.n),
                        threads: 1,
                        mesh: Some((m.rows(), m.cols())),
                        fm_bits,
                    },
                ))
            }
            #[cfg(feature = "pjrt")]
            BackendImpl::Pjrt(_) => Err(EngineError::Unsupported(
                "video sessions run on the simulator backends (functional or mesh)".into(),
            )),
        }
    }

    /// Measured border/corner traffic of the mesh backend's most recent
    /// inference (`None` on other backends or before any inference).
    pub fn mesh_stats(&self) -> Option<MeshStats> {
        match &*self.backend {
            BackendImpl::Mesh(m) => m.last_stats(),
            _ => None,
        }
    }

    /// One-line description of the backend under the façade.
    pub fn describe(&self) -> String {
        match &*self.backend {
            BackendImpl::Functional(_) => format!(
                "functional chip simulator ({:?} datapath)",
                self.report.precision
            ),
            BackendImpl::Mesh(m) => format!(
                "{}x{} systolic mesh simulator ({:?} datapath)",
                m.rows(),
                m.cols(),
                self.report.precision
            ),
            #[cfg(feature = "pjrt")]
            BackendImpl::Pjrt(p) => format!(
                "PJRT `{}` with {} compiled artifacts",
                p.platform(),
                p.loaded()
            ),
        }
    }

    /// Load a golden f32 file from the PJRT artifact directory.
    pub fn golden(&self, file: &str) -> Result<Vec<f32>, EngineError> {
        #[cfg(feature = "pjrt")]
        if let BackendImpl::Pjrt(p) = &*self.backend {
            return p.golden(file);
        }
        Err(EngineError::Unsupported(format!(
            "golden file `{file}` requires the PJRT backend"
        )))
    }

    /// The §IV-B memory plan of the PJRT backend (peak == WCL).
    #[cfg(feature = "pjrt")]
    pub fn memory_plan(&self) -> Option<crate::coordinator::memory::MemoryPlan> {
        match &*self.backend {
            BackendImpl::Pjrt(p) => Some(p.memory_plan().clone()),
            _ => None,
        }
    }
}

//! PJRT backend: wraps `runtime::InferenceEngine` (the AOT-artifact
//! executor) behind the backend-agnostic [`Backend`] trait.
//!
//! Concurrency: the xla-rs wrapper types are conservatively `!Send`
//! (raw pointers), so the engine is kept behind a `Mutex` and inferences
//! serialize on it — the serving layer's worker pool still overlaps
//! queueing/collection, but PJRT compute runs one request at a time.
//! The PJRT C API itself is thread-safe, which is what makes moving the
//! locked engine across worker threads sound (see DESIGN.md §Engine).

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::coordinator::memory::MemoryPlan;
use crate::network::{Network, TensorRef};
use crate::runtime::InferenceEngine;

use super::backend::{Backend, BackendKind, LayerTrace};
use super::EngineError;

pub struct PjrtBackend {
    inner: Mutex<InferenceEngine>,
    /// Copies of read-only metadata, accessible without the lock.
    net: Network,
    memory_plan: MemoryPlan,
    platform: String,
    loaded: usize,
    dir: PathBuf,
}

// SAFETY: all access to the xla-rs types goes through `inner`'s mutex,
// and the PJRT CPU client/executables are thread-safe at the C-API
// level; the `!Send` on the Rust wrappers is raw-pointer conservatism.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    /// Load artifacts + parameters from an AOT artifact directory.
    pub fn load(dir: impl Into<PathBuf>) -> Result<PjrtBackend, EngineError> {
        let dir = dir.into();
        let inner = InferenceEngine::load(&dir).map_err(|e| {
            EngineError::Unavailable(format!(
                "PJRT artifacts at `{}`: {e:#} (run `make artifacts` first)",
                dir.display()
            ))
        })?;
        let net = inner.manifest.network.clone();
        let memory_plan = inner.memory_plan.clone();
        let platform = inner.runtime.platform();
        let loaded = inner.runtime.loaded();
        Ok(PjrtBackend {
            inner: Mutex::new(inner),
            net,
            memory_plan,
            platform,
            loaded,
            dir,
        })
    }

    pub fn network(&self) -> &Network {
        &self.net
    }

    /// The §IV-B memory plan validated at load (peak == WCL).
    pub fn memory_plan(&self) -> &MemoryPlan {
        &self.memory_plan
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Number of compiled artifacts.
    pub fn loaded(&self) -> usize {
        self.loaded
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Load a golden f32 file from the artifact directory.
    pub fn golden(&self, file: &str) -> Result<Vec<f32>, EngineError> {
        self.inner
            .lock()
            .unwrap()
            .manifest
            .golden(file)
            .map_err(|e| EngineError::Backend(format!("{e:#}")))
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn infer_traced(
        &self,
        input: &[f32],
        hook: &mut dyn FnMut(LayerTrace<'_>),
    ) -> Result<Vec<f32>, EngineError> {
        let want = self.net.in_ch * self.net.in_h * self.net.in_w;
        if input.len() != want {
            return Err(EngineError::Input(format!(
                "input has {} values, {} expects {want}",
                input.len(),
                self.net.name
            )));
        }
        let (fms, logits) = self
            .inner
            .lock()
            .unwrap()
            .infer_trace(input)
            .map_err(|e| EngineError::Backend(format!("{e:#}")))?;
        for (i, fm) in fms.iter().enumerate() {
            hook(LayerTrace {
                step: i,
                layer: &self.net.steps[i].layer.name,
                shape: self.net.shape_of(TensorRef::Step(i)),
                output: fm,
            });
        }
        Ok(logits)
    }
}

//! The single typed report every consumer (CLI, examples, benches,
//! `report::*` tables) reads instead of re-deriving its own tuples:
//! schedule, WCL/memory analysis, mesh plan, energy breakdown and —
//! when a batch has been served — the serving statistics.

use crate::coordinator::schedule::{DepthwisePolicy, NetworkSchedule};
use crate::coordinator::tiling::{self, MeshPlan};
use crate::coordinator::wcl::MemoryAnalysis;
use crate::energy::EnergyReport;
use crate::simulator::Precision;
use crate::util::fmt_bits;
use crate::ChipConfig;

use super::backend::BackendKind;
use super::serve::ServeStats;

/// Everything the engine derives about a network on a chip mesh at one
/// operating point. Produced by [`super::Engine::report`].
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Network name.
    pub network: String,
    /// On-chip input FM shape `(c, h, w)`.
    pub input_shape: (usize, usize, usize),
    pub backend: BackendKind,
    pub chip: ChipConfig,
    pub plan: MeshPlan,
    pub precision: Precision,
    pub depthwise: DepthwisePolicy,
    pub vdd: f64,
    pub vbb: f64,
    /// Algorithm-1 cycle schedule (per chip, lockstep over the mesh).
    pub schedule: NetworkSchedule,
    /// Single-chip WCL liveness analysis (§IV-B).
    pub memory: MemoryAnalysis,
    /// Energy/performance at `(vdd, vbb)` (Tbl V quantities).
    pub energy: EnergyReport,
    /// Analytic border-exchange traffic for the planned mesh (Fig 11).
    pub border_bits: u64,
    /// Serving statistics, when attached via
    /// [`super::Engine::report_with_serve`].
    pub serve: Option<ServeStats>,
}

impl EngineReport {
    /// Mesh-wide utilization in `[0, 1]` (per-chip schedule vs the whole
    /// mesh's peak throughput).
    pub fn mesh_utilization(&self) -> f64 {
        self.schedule.utilization(&self.chip) / self.plan.chips() as f64
    }

    /// The `simulate` summary: schedule, memory, energy in one block.
    pub fn summary(&self) -> String {
        let (_, ih, iw) = self.input_shape;
        format!(
            "{} @ {}x{} on {}x{} chips ({} total, {} backend)\n\
             ops {} | per-chip cycles {} | mesh utilization {:.1}%\n\
             WCL {} words ({}); per-chip WCL {} words\n\
             @({} V, {} V FBB): {:.1} fps, {:.0} GOp/s\n\
             core {:.2} mJ/im + I/O {:.2} mJ/im (weights {} + input {} + border {})\n\
             = {:.2} mJ/im → system efficiency {:.2} TOp/s/W",
            self.network,
            iw,
            ih,
            self.plan.rows,
            self.plan.cols,
            self.plan.chips(),
            self.backend.name(),
            fmt_bits(self.schedule.total_ops()),
            self.schedule.total_cycles(),
            100.0 * self.mesh_utilization(),
            self.memory.wcl_words,
            fmt_bits(self.memory.wcl_bits(self.chip.fm_bits)),
            self.plan.per_chip_wcl_words,
            self.vdd,
            self.vbb,
            self.energy.frame_rate_hz,
            self.energy.throughput_ops_s / 1e9,
            self.energy.core_j * 1e3,
            self.energy.io_j * 1e3,
            fmt_bits(self.energy.io.weights),
            fmt_bits(self.energy.io.input_fm),
            fmt_bits(self.energy.io.border),
            self.energy.total_j() * 1e3,
            self.energy.system_efficiency_ops_w() / 1e12,
        )
    }

    /// The `mesh` summary: plan, per-chip WCL, border exchange and the
    /// §V-A chip-type classes of the top-left corner of the mesh.
    pub fn mesh_summary(&self) -> String {
        let (_, ih, iw) = self.input_shape;
        let mut types = String::new();
        for r in 0..self.plan.rows.min(4) {
            for c in 0..self.plan.cols.min(8) {
                types.push_str(&format!("{:?} ", tiling::chip_type(r, c, &self.plan)));
            }
            types.push('\n');
        }
        format!(
            "{} @ {}x{}: mesh {}x{} = {} chips\n\
             per-chip WCL {} words (FMM capacity {})\n\
             border exchange per inference: {}\n\
             chip types (top-left corner of the mesh):\n{}",
            self.network,
            iw,
            ih,
            self.plan.rows,
            self.plan.cols,
            self.plan.chips(),
            self.plan.per_chip_wcl_words,
            self.chip.fmm_words,
            fmt_bits(self.border_bits),
            types
        )
    }

    /// One-line latency/throughput summary of the attached serve stats
    /// (quantiles are over the completed requests; a partially-failed
    /// batch shows `ok < requests`).
    pub fn serve_summary(&self) -> String {
        match &self.serve {
            Some(s) if s.requests > 0 => format!(
                "served {} requests ({} ok) on {} workers in {:.2} ms: mean {:.2} ms, \
                 p50 {:.2} ms, p99 {:.2} ms — {:.1} req/s, {:.2} MOp/s",
                s.requests,
                s.completed,
                s.workers,
                s.total_s * 1e3,
                s.mean_ms,
                s.p50_ms,
                s.p99_ms,
                s.completed as f64 / s.total_s,
                s.ops_per_s / 1e6
            ),
            Some(_) => "served 0 requests".to_string(),
            None => "no serve statistics recorded".to_string(),
        }
    }
}

//! Single-model batch serving — the compatibility layer over the
//! multi-model [`super::service::InferenceService`].
//!
//! [`super::Engine::serve`] spins up a temporary single-model service
//! (same bounded-queue admission, same worker pool, same panic
//! capture), submits the batch, waits every ticket and folds the
//! per-request results into a [`ServeOutcome`]: completed outputs stay
//! available even when other requests fail — a panicking request no
//! longer discards the whole batch. Callers that want the historical
//! all-or-nothing view use [`ServeOutcome::outputs`].

use std::sync::Arc;
use std::time::Instant;

use super::backend::Backend;
use super::service::{AdmissionPolicy, InferRequest, InferenceService, ServeError};
use super::EngineError;

/// Serving configuration of [`super::Engine::serve`].
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Concurrent worker threads (validated ≥ 1; clamped to the batch
    /// size — extra workers would only idle).
    pub workers: usize,
    /// Bounded request-queue depth (validated ≥ 1); admission blocks
    /// when full.
    pub queue_depth: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 2,
            queue_depth: 8,
        }
    }
}

impl ServeOptions {
    /// Like `EngineBuilder::threads`, a zero knob is a typed error —
    /// not a silent clamp that answers a different question.
    pub fn validate(&self) -> Result<(), EngineError> {
        if self.workers == 0 {
            return Err(EngineError::Builder(
                "ServeOptions.workers must be ≥ 1, got 0".into(),
            ));
        }
        if self.queue_depth == 0 {
            return Err(EngineError::Builder(
                "ServeOptions.queue_depth must be ≥ 1, got 0".into(),
            ));
        }
        Ok(())
    }
}

/// Latency/throughput statistics of a served batch.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests submitted (completed + failed).
    pub requests: usize,
    /// Requests that produced an output.
    pub completed: usize,
    /// Worker threads actually used.
    pub workers: usize,
    pub total_s: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// End-to-end Op/s (network ops × completed request rate).
    pub ops_per_s: f64,
}

/// Nearest-rank percentile over an ascending-sorted slice, using a
/// *rounded* rank: `round((n−1)·p)`. A truncating rank made p99 of a
/// 50-request batch read the p96 sample; rounding keeps p50/p99 on the
/// conventional sample for batch sizes from 1 to 10k+. `None` on an
/// empty slice (it used to panic, which is unacceptable for a `pub`
/// helper fed by live metrics windows).
pub fn percentile(sorted: &[f64], p: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let rank = ((sorted.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
    Some(sorted[rank.min(sorted.len() - 1)])
}

/// The result of serving one batch: one `Result` per request, in
/// submission order, plus the batch statistics. A failing request
/// costs exactly its own slot.
#[derive(Debug)]
pub struct ServeOutcome {
    /// Per-request results, in submission order.
    pub results: Vec<Result<Vec<f32>, ServeError>>,
    /// Batch latency/throughput statistics (quantiles over the
    /// completed requests).
    pub stats: ServeStats,
}

impl ServeOutcome {
    /// Requests that produced an output.
    pub fn completed(&self) -> usize {
        self.results.iter().filter(|r| r.is_ok()).count()
    }

    /// Requests that failed.
    pub fn failed(&self) -> usize {
        self.results.len() - self.completed()
    }

    /// The first failure, if any request failed.
    pub fn first_error(&self) -> Option<&ServeError> {
        self.results.iter().find_map(|r| r.as_ref().err())
    }

    /// The historical all-or-nothing view: every output in submission
    /// order, or the first failure as an [`EngineError`].
    pub fn outputs(self) -> Result<(Vec<Vec<f32>>, ServeStats), EngineError> {
        let mut outs = Vec::with_capacity(self.results.len());
        for (i, result) in self.results.into_iter().enumerate() {
            match result {
                Ok(out) => outs.push(out),
                Err(e) => return Err(EngineError::Backend(format!("request {i}: {e}"))),
            }
        }
        Ok((outs, self.stats))
    }
}

/// Assemble batch statistics from the completed requests' latencies.
fn stats_from_latencies(
    requests: usize,
    workers: usize,
    total_s: f64,
    total_ops: u64,
    mut lat_ms: Vec<f64>,
) -> ServeStats {
    lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let completed = lat_ms.len();
    ServeStats {
        requests,
        completed,
        workers,
        total_s,
        mean_ms: if completed > 0 {
            lat_ms.iter().sum::<f64>() / completed as f64
        } else {
            0.0
        },
        p50_ms: percentile(&lat_ms, 0.50).unwrap_or(0.0),
        p99_ms: percentile(&lat_ms, 0.99).unwrap_or(0.0),
        ops_per_s: if total_s > 0.0 {
            total_ops as f64 * completed as f64 / total_s
        } else {
            0.0
        },
    }
}

/// Serve `inputs` FIFO through a temporary single-model
/// [`InferenceService`] over `opts.workers` threads. Per-request
/// results come back in submission order; `total_ops` is the
/// per-inference op count used for the throughput figure and
/// `weight_bytes` the model's resident packed-weight footprint.
pub(crate) fn serve_outcome_on(
    backend: Arc<dyn Backend>,
    model: &str,
    total_ops: u64,
    weight_bytes: u64,
    inputs: &[Vec<f32>],
    opts: &ServeOptions,
) -> Result<ServeOutcome, EngineError> {
    opts.validate()?;
    let workers = opts.workers.min(inputs.len().max(1));
    if inputs.is_empty() {
        return Ok(ServeOutcome {
            results: Vec::new(),
            stats: ServeStats {
                workers,
                ..ServeStats::default()
            },
        });
    }
    let svc = InferenceService::single(
        model,
        backend,
        inputs[0].len(),
        total_ops,
        weight_bytes,
        workers,
        opts.queue_depth,
        // Backpressure like the historical bounded sync_channel:
        // admission blocks while the queue is full, bounding memory no
        // matter how large the batch is.
        AdmissionPolicy::Block,
    );
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(inputs.len());
    for (i, input) in inputs.iter().enumerate() {
        // Admission failures (e.g. a length mismatch the caller did not
        // pre-validate) are per-request results too, not batch aborts.
        tickets.push(svc.submit(InferRequest {
            model: model.to_string(),
            input: input.clone().into(),
            id: i as u64,
            deadline_ms: None,
        }));
    }
    let mut results = Vec::with_capacity(inputs.len());
    let mut lat_ms = Vec::with_capacity(inputs.len());
    for ticket in tickets {
        match ticket {
            Ok(t) => match t.wait() {
                Ok(resp) => {
                    lat_ms.push(resp.latency_ms);
                    results.push(Ok(resp.output));
                }
                Err(e) => results.push(Err(e)),
            },
            Err(e) => results.push(Err(e)),
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    drop(svc); // drains (already empty) and joins the worker pool
    let stats = stats_from_latencies(inputs.len(), workers, total_s, total_ops, lat_ms);
    Ok(ServeOutcome { results, stats })
}

#[cfg(test)]
mod tests {
    use super::super::backend::{BackendKind, LayerTrace};
    use super::*;

    /// Trivial backend for pool tests: doubles its input.
    struct Doubler;

    impl Backend for Doubler {
        fn kind(&self) -> BackendKind {
            BackendKind::Functional
        }

        fn infer_traced(
            &self,
            input: &[f32],
            hook: &mut dyn FnMut(LayerTrace<'_>),
        ) -> Result<Vec<f32>, EngineError> {
            let out: Vec<f32> = input.iter().map(|x| 2.0 * x).collect();
            hook(LayerTrace {
                step: 0,
                layer: "double",
                shape: (1, 1, out.len()),
                output: &out,
            });
            Ok(out)
        }
    }

    fn outcome_on(
        inputs: &[Vec<f32>],
        opts: &ServeOptions,
        backend: Arc<dyn Backend>,
    ) -> ServeOutcome {
        serve_outcome_on(backend, "test", 10, 0, inputs, opts).unwrap()
    }

    #[test]
    fn outputs_keep_submission_order_across_workers() {
        let inputs: Vec<Vec<f32>> = (0..32).map(|i| vec![i as f32]).collect();
        for workers in [1, 2, 4, 7] {
            let opts = ServeOptions {
                workers,
                queue_depth: 3,
            };
            let outcome = outcome_on(&inputs, &opts, Arc::new(Doubler));
            let (outs, stats) = outcome.outputs().unwrap();
            assert_eq!(outs.len(), 32);
            for (i, o) in outs.iter().enumerate() {
                assert_eq!(o, &vec![2.0 * i as f32], "request {i} out of order");
            }
            assert_eq!(stats.requests, 32);
            assert_eq!(stats.completed, 32);
            assert_eq!(stats.workers, workers);
            assert!(stats.total_s > 0.0 && stats.ops_per_s > 0.0);
        }
    }

    #[test]
    fn workers_clamp_to_batch_size() {
        let inputs = vec![vec![1.0f32]; 2];
        let opts = ServeOptions {
            workers: 16,
            queue_depth: 1,
        };
        let outcome = outcome_on(&inputs, &opts, Arc::new(Doubler));
        assert_eq!(outcome.stats.workers, 2);
    }

    #[test]
    fn zero_knobs_are_typed_errors_not_clamps() {
        let inputs = vec![vec![1.0f32]];
        for opts in [
            ServeOptions {
                workers: 0,
                queue_depth: 8,
            },
            ServeOptions {
                workers: 2,
                queue_depth: 0,
            },
        ] {
            let err =
                serve_outcome_on(Arc::new(Doubler), "test", 1, 0, &inputs, &opts).unwrap_err();
            assert!(matches!(err, EngineError::Builder(_)), "{err}");
            assert!(err.to_string().contains("≥ 1"), "{err}");
        }
    }

    /// Backend that panics on negative inputs.
    struct Panicky;

    impl Backend for Panicky {
        fn kind(&self) -> BackendKind {
            BackendKind::Functional
        }

        fn infer_traced(
            &self,
            input: &[f32],
            _hook: &mut dyn FnMut(LayerTrace<'_>),
        ) -> Result<Vec<f32>, EngineError> {
            assert!(input[0] >= 0.0, "negative request");
            Ok(input.to_vec())
        }
    }

    #[test]
    fn mixed_batch_keeps_the_good_outputs() {
        // The historical behavior discarded the whole batch on the
        // first failure; per-request results must keep the completed
        // outputs next to the panicking request's own error.
        let inputs = vec![vec![1.0f32], vec![-1.0], vec![2.0], vec![-3.0], vec![4.0]];
        let opts = ServeOptions {
            workers: 2,
            queue_depth: 2,
        };
        let outcome = outcome_on(&inputs, &opts, Arc::new(Panicky));
        assert_eq!(outcome.results.len(), 5);
        assert_eq!(outcome.completed(), 3);
        assert_eq!(outcome.failed(), 2);
        for (i, expect) in [(0usize, 1.0f32), (2, 2.0), (4, 4.0)] {
            assert_eq!(
                outcome.results[i].as_ref().unwrap(),
                &vec![expect],
                "good request {i} lost"
            );
        }
        for i in [1usize, 3] {
            let err = outcome.results[i].as_ref().unwrap_err();
            assert!(matches!(err, ServeError::Panicked { .. }), "{err}");
            assert!(err.to_string().contains("negative request"), "{err}");
        }
        assert_eq!(outcome.stats.requests, 5);
        assert_eq!(outcome.stats.completed, 3);
        assert!(matches!(
            outcome.first_error(),
            Some(ServeError::Panicked { .. })
        ));
        // The strict view reports the first failure, with its index.
        let err = outcome.outputs().unwrap_err();
        assert!(matches!(err, EngineError::Backend(_)), "{err}");
        assert!(err.to_string().contains("request 1"), "{err}");
        assert!(err.to_string().contains("panicked"), "{err}");
    }

    #[test]
    fn all_panicking_batch_resolves_every_request() {
        // Every request panics; a naive pool would die and leave the
        // bounded submitter blocked forever. Every slot must resolve.
        let inputs: Vec<Vec<f32>> = (0..16).map(|_| vec![-1.0f32]).collect();
        let opts = ServeOptions {
            workers: 2,
            queue_depth: 2,
        };
        let outcome = outcome_on(&inputs, &opts, Arc::new(Panicky));
        assert_eq!(outcome.failed(), 16);
        assert_eq!(outcome.stats.completed, 0);
        assert_eq!(outcome.stats.p99_ms, 0.0);
    }

    #[test]
    fn empty_batch_is_fine() {
        let outcome = outcome_on(&[], &ServeOptions::default(), Arc::new(Doubler));
        assert!(outcome.results.is_empty());
        assert_eq!(outcome.stats.requests, 0);
    }

    #[test]
    fn percentile_uses_rounded_rank() {
        // 50 samples 1..=50: p99 must be the top sample (the truncating
        // rank used to return sample 49 — the p96 value).
        let v: Vec<f64> = (1..=50).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.99), Some(50.0));
        assert_eq!(percentile(&v, 0.50), Some(26.0)); // round(24.5) = 25 → 26.0
        assert_eq!(percentile(&v, 0.0), Some(1.0));
        assert_eq!(percentile(&v, 1.0), Some(50.0));
    }

    #[test]
    fn percentile_of_empty_is_none_not_panic() {
        assert_eq!(percentile(&[], 0.5), None);
        assert_eq!(percentile(&[], 0.99), None);
    }

    #[test]
    fn percentile_across_batch_sizes() {
        for n in [1usize, 2, 3, 10, 100, 1000, 10_000] {
            let v: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let p50 = percentile(&v, 0.50).unwrap();
            let p99 = percentile(&v, 0.99).unwrap();
            assert!(p99 >= p50, "n={n}");
            // Rounded rank: within half a sample of the exact position.
            let exact99 = (n - 1) as f64 * 0.99;
            assert!((p99 - exact99).abs() <= 0.5 + 1e-9, "n={n}: {p99} vs {exact99}");
            let exact50 = (n - 1) as f64 * 0.50;
            assert!((p50 - exact50).abs() <= 0.5 + 1e-9, "n={n}: {p50} vs {exact50}");
        }
        assert_eq!(percentile(&[7.0], 0.99), Some(7.0));
    }
}

//! Backend-generic serving layer: a bounded FIFO request queue drained
//! by a pool of worker threads, with per-request latency capture.
//!
//! This replaces the PJRT-only `InferenceEngine::serve` of earlier
//! revisions — any [`Backend`] can be served, and the simulator
//! backends genuinely run `workers` inferences in parallel (the PJRT
//! backend serializes on its internal runtime lock; see
//! `engine::pjrt`). Admission is backpressured: once `queue_depth`
//! requests are in flight the submitter blocks, bounding memory no
//! matter how large the submitted batch is.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Mutex};
use std::thread;
use std::time::Instant;

use super::backend::Backend;
use super::EngineError;

/// Serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Concurrent worker threads (clamped to at least 1 and to the
    /// batch size).
    pub workers: usize,
    /// Bounded request-queue depth; admission blocks when full.
    pub queue_depth: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 2,
            queue_depth: 8,
        }
    }
}

/// Latency/throughput statistics of a served batch.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: usize,
    /// Worker threads actually used.
    pub workers: usize,
    pub total_s: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// End-to-end Op/s (network ops × completed request rate).
    pub ops_per_s: f64,
}

/// Nearest-rank percentile over an ascending-sorted slice, using a
/// *rounded* rank: `round((n−1)·p)`. The previous truncating rank made
/// p99 of a 50-request batch read the p96 sample; rounding keeps
/// p50/p99 on the conventional sample for batch sizes from 1 to 10k+.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty batch");
    let rank = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Serve `inputs` FIFO over `opts.workers` threads; returns outputs in
/// submission order plus the latency statistics. `total_ops` is the
/// per-inference op count used for the throughput figure.
pub(crate) fn serve_on(
    backend: &dyn Backend,
    total_ops: u64,
    inputs: &[Vec<f32>],
    opts: &ServeOptions,
) -> Result<(Vec<Vec<f32>>, ServeStats), EngineError> {
    let workers = opts.workers.max(1).min(inputs.len().max(1));
    if inputs.is_empty() {
        return Ok((
            Vec::new(),
            ServeStats {
                workers,
                ..ServeStats::default()
            },
        ));
    }

    // Bounded FIFO: `sync_channel` blocks the submitter when the queue
    // holds `queue_depth` pending requests.
    let (tx, rx) = mpsc::sync_channel::<usize>(opts.queue_depth.max(1));
    let rx = Mutex::new(rx);
    // One slot per request, filled by whichever worker ran it.
    let slots: Vec<Mutex<Option<Result<(Vec<f32>, f64), EngineError>>>> =
        inputs.iter().map(|_| Mutex::new(None)).collect();

    let t0 = Instant::now();
    thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let next = rx.lock().unwrap().recv();
                let Ok(i) = next else { break };
                let t = Instant::now();
                // A panicking backend must not kill the worker: a dead
                // pool leaves the bounded `tx.send` below blocked forever
                // (the Receiver outlives the scope, so send never errors).
                // Convert the panic into a per-request backend error.
                let result = catch_unwind(AssertUnwindSafe(|| backend.infer(&inputs[i])))
                    .unwrap_or_else(|payload| {
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "opaque panic payload".to_string());
                        Err(EngineError::Backend(format!("inference panicked: {msg}")))
                    });
                let ms = t.elapsed().as_secs_f64() * 1e3;
                *slots[i].lock().unwrap() = Some(result.map(|out| (out, ms)));
            });
        }
        for i in 0..inputs.len() {
            tx.send(i).expect("worker pool died");
        }
        drop(tx); // workers drain the queue, then exit
    });
    let total_s = t0.elapsed().as_secs_f64();

    let mut outs = Vec::with_capacity(inputs.len());
    let mut lat_ms = Vec::with_capacity(inputs.len());
    for slot in slots {
        match slot.into_inner().unwrap().expect("request not completed") {
            Ok((out, ms)) => {
                outs.push(out);
                lat_ms.push(ms);
            }
            Err(e) => return Err(e),
        }
    }
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = ServeStats {
        requests: inputs.len(),
        workers,
        total_s,
        mean_ms: lat_ms.iter().sum::<f64>() / lat_ms.len() as f64,
        p50_ms: percentile(&lat_ms, 0.50),
        p99_ms: percentile(&lat_ms, 0.99),
        ops_per_s: total_ops as f64 * inputs.len() as f64 / total_s,
    };
    Ok((outs, stats))
}

#[cfg(test)]
mod tests {
    use super::super::backend::{BackendKind, LayerTrace};
    use super::*;

    /// Trivial backend for pool tests: doubles its input.
    struct Doubler;

    impl Backend for Doubler {
        fn kind(&self) -> BackendKind {
            BackendKind::Functional
        }

        fn infer_traced(
            &self,
            input: &[f32],
            hook: &mut dyn FnMut(LayerTrace<'_>),
        ) -> Result<Vec<f32>, EngineError> {
            let out: Vec<f32> = input.iter().map(|x| 2.0 * x).collect();
            hook(LayerTrace {
                step: 0,
                layer: "double",
                shape: (1, 1, out.len()),
                output: &out,
            });
            Ok(out)
        }
    }

    #[test]
    fn outputs_keep_submission_order_across_workers() {
        let inputs: Vec<Vec<f32>> = (0..32).map(|i| vec![i as f32]).collect();
        for workers in [1, 2, 4, 7] {
            let opts = ServeOptions {
                workers,
                queue_depth: 3,
            };
            let (outs, stats) = serve_on(&Doubler, 10, &inputs, &opts).unwrap();
            assert_eq!(outs.len(), 32);
            for (i, o) in outs.iter().enumerate() {
                assert_eq!(o, &vec![2.0 * i as f32], "request {i} out of order");
            }
            assert_eq!(stats.requests, 32);
            assert_eq!(stats.workers, workers);
            assert!(stats.total_s > 0.0 && stats.ops_per_s > 0.0);
        }
    }

    #[test]
    fn workers_clamp_to_batch_size() {
        let inputs = vec![vec![1.0f32]; 2];
        let opts = ServeOptions {
            workers: 16,
            queue_depth: 1,
        };
        let (_, stats) = serve_on(&Doubler, 1, &inputs, &opts).unwrap();
        assert_eq!(stats.workers, 2);
    }

    /// Backend that panics on negative inputs.
    struct Panicky;

    impl Backend for Panicky {
        fn kind(&self) -> BackendKind {
            BackendKind::Functional
        }

        fn infer_traced(
            &self,
            input: &[f32],
            _hook: &mut dyn FnMut(LayerTrace<'_>),
        ) -> Result<Vec<f32>, EngineError> {
            assert!(input[0] >= 0.0, "negative request");
            Ok(input.to_vec())
        }
    }

    #[test]
    fn panicking_backend_errors_instead_of_hanging() {
        // Every request panics; a naive pool would die and leave the
        // bounded submitter blocked forever. Must return Err promptly.
        let inputs: Vec<Vec<f32>> = (0..16).map(|_| vec![-1.0f32]).collect();
        let opts = ServeOptions {
            workers: 2,
            queue_depth: 2,
        };
        let err = serve_on(&Panicky, 1, &inputs, &opts).unwrap_err();
        assert!(matches!(err, EngineError::Backend(_)), "{err}");
        assert!(err.to_string().contains("panicked"), "{err}");
        // Mixed batch: good requests still complete.
        let mixed = vec![vec![1.0f32], vec![-1.0], vec![2.0]];
        let err = serve_on(&Panicky, 1, &mixed, &opts).unwrap_err();
        assert!(matches!(err, EngineError::Backend(_)), "{err}");
    }

    #[test]
    fn empty_batch_is_fine() {
        let (outs, stats) = serve_on(&Doubler, 1, &[], &ServeOptions::default()).unwrap();
        assert!(outs.is_empty());
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn percentile_uses_rounded_rank() {
        // 50 samples 1..=50: p99 must be the top sample (the truncating
        // rank used to return sample 49 — the p96 value).
        let v: Vec<f64> = (1..=50).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.99), 50.0);
        assert_eq!(percentile(&v, 0.50), 26.0); // round(24.5) = 25 → 26.0
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 50.0);
    }

    #[test]
    fn percentile_across_batch_sizes() {
        for n in [1usize, 2, 3, 10, 100, 1000, 10_000] {
            let v: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let p50 = percentile(&v, 0.50);
            let p99 = percentile(&v, 0.99);
            assert!(p99 >= p50, "n={n}");
            // Rounded rank: within half a sample of the exact position.
            let exact99 = (n - 1) as f64 * 0.99;
            assert!((p99 - exact99).abs() <= 0.5 + 1e-9, "n={n}: {p99} vs {exact99}");
            let exact50 = (n - 1) as f64 * 0.50;
            assert!((p50 - exact50).abs() <= 0.5 + 1e-9, "n={n}: {p50} vs {exact50}");
        }
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }
}

//! Per-model micro-batch assembly for the [`super::InferenceService`]
//! worker loop.
//!
//! Hyperdrive streams weights past stationary feature maps, so the cost
//! of a layer's weight fetch is paid once no matter how many images are
//! resident (§III-B): serving B same-model requests as one
//! [`Backend::infer_batch`] pass divides the off-chip weight traffic by
//! ~B. The assembler coalesces queued same-model requests under a
//! [`BatchPolicy`] — greedily taking whatever is already queued, then
//! optionally holding the batch open for stragglers — while keeping the
//! per-request [`super::Ticket`] contract intact: every job still
//! resolves its own ticket, and one failing request fails only itself.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::MutexGuard;
use std::time::{Duration, Instant};

use super::super::backend::Backend;
use super::{Job, ServeError, Shard, ShardState, Shared};

/// How a model's worker coalesces queued requests into one
/// batch-resident inference pass.
///
/// The default (`max_batch == 1`) disables coalescing entirely — every
/// request runs alone, exactly like the pre-batching service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Most requests one [`Backend::infer_batch`] pass may serve
    /// (resident images). Must be ≥ 1.
    pub max_batch: usize,
    /// How long a short batch may hold its queue slot waiting for
    /// stragglers before running anyway. `0` never waits: the batch is
    /// whatever is already queued.
    pub max_wait_ms: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 1,
            max_wait_ms: 0,
        }
    }
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait_ms,
        }
    }
}

/// Grow `jobs` (the already-popped head of this shard's queue) toward
/// `max_batch`: take everything queued now, then — if the policy grants
/// a wait budget — hold for stragglers on the shard's `arrivals`
/// condvar until the batch fills, the deadline passes, the service
/// starts draining or the model is removed.
///
/// Every job taken is counted `in_flight` immediately (and deducted
/// from the doorbell's pending count), so metrics snapshots taken
/// mid-hold still add up. Returns the re-acquired state guard plus a
/// flag: `true` means the model was removed mid-hold and the caller
/// must fail the held jobs fast instead of running them. A `draining`
/// service breaks the hold but still runs the batch — admitted tickets
/// resolve successfully through shutdown.
pub(super) fn fill_batch<'a>(
    shared: &Shared,
    shard: &'a Shard,
    mut st: MutexGuard<'a, ShardState>,
    jobs: &mut Vec<Job>,
) -> (MutexGuard<'a, ShardState>, bool) {
    let policy = shard.batch;
    let take = |st: &mut ShardState, jobs: &mut Vec<Job>| {
        let mut taken = 0u64;
        while jobs.len() < policy.max_batch {
            match st.queue.pop_front() {
                Some(j) => {
                    st.in_flight += 1;
                    taken += 1;
                    jobs.push(j);
                }
                None => break,
            }
        }
        taken
    };
    let mut taken = take(&mut st, jobs);
    if jobs.len() < policy.max_batch && policy.max_wait_ms > 0 {
        let deadline = Instant::now() + Duration::from_millis(policy.max_wait_ms);
        loop {
            if st.removed {
                // Hot removal mid-hold: the held jobs must fail fast
                // with ModelRemoved, not sleep out the window.
                if taken > 0 {
                    shared.dec_pending(taken);
                }
                return (st, true);
            }
            if jobs.len() >= policy.max_batch || st.draining {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // Submitters notify `arrivals` on every push (notify_all),
            // and remove_model/shutdown notify it too, so a holding
            // worker observes arrivals and teardown as they land.
            let (guard, _) = shard.arrivals.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            taken += take(&mut st, jobs);
        }
    }
    if taken > 0 {
        shared.dec_pending(taken);
    }
    (st, false)
}

/// Run one assembled batch with panic capture, scattering the
/// [`crate::engine::BatchRun`] back to per-job results. Returns the per-job
/// results (aligned with `jobs`) and the stream words the batch saved
/// vs sequential execution.
pub(super) fn run_batch(
    backend: &dyn Backend,
    model: &str,
    jobs: &[Job],
) -> (Vec<Result<Vec<f32>, ServeError>>, u64) {
    let inputs: Vec<&[f32]> = jobs.iter().map(|j| &*j.input).collect();
    match catch_unwind(AssertUnwindSafe(|| backend.infer_batch(&inputs))) {
        Ok(run) => {
            let saved = run.stream_words_saved();
            let mut results: Vec<Result<Vec<f32>, ServeError>> = run
                .outputs
                .into_iter()
                .take(jobs.len())
                .map(|r| {
                    r.map_err(|e| ServeError::Failed {
                        model: model.to_string(),
                        message: e.to_string(),
                    })
                })
                .collect();
            // A misbehaving backend that returns too few slots must not
            // strand the tail's tickets.
            while results.len() < jobs.len() {
                results.push(Err(ServeError::Failed {
                    model: model.to_string(),
                    message: "backend returned too few batch outputs".to_string(),
                }));
            }
            (results, saved)
        }
        Err(payload) => {
            let message = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            (
                jobs.iter()
                    .map(|_| {
                        Err(ServeError::Panicked {
                            model: model.to_string(),
                            message: message.clone(),
                        })
                    })
                    .collect(),
                0,
            )
        }
    }
}

//! Live serving metrics of an [`super::InferenceService`].
//!
//! Each hosted model accumulates counters and latency samples inside
//! its own shard lock ([`MetricsAccum`]); a [`ServiceMetrics`] row is a
//! consistent copy taken under that lock, so a model's totals always
//! add up (`submitted == completed + failed + queued + in_flight` at
//! the instant the row was captured). The latency
//! quantiles reuse the single-model serving math
//! ([`crate::engine::serve::percentile`]) so a one-model service
//! reports the same p50/p99 a direct [`crate::engine::Engine::serve`]
//! batch would.

use std::time::Instant;

use super::BreakerState;
use crate::engine::serve::{percentile, ServeStats};

/// Most recent completed-request latencies kept per model for the
/// p50/p99 window. Counters and the mean are over the whole lifetime;
/// only the quantiles are windowed, which bounds memory on a
/// long-lived service.
const LATENCY_WINDOW: usize = 4096;

/// Per-model accumulator, mutated under the service state lock.
#[derive(Debug, Default)]
pub(crate) struct MetricsAccum {
    submitted: u64,
    completed: u64,
    failed: u64,
    lat_sum_ms: f64,
    window: Vec<f64>,
    next: usize,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
    /// Executed batch passes (a lone request counts as a batch of 1).
    batches: u64,
    /// Requests served across all batch passes (`Σ batch sizes`).
    batch_images: u64,
    /// Largest batch executed so far.
    batch_max: u64,
    /// Cumulative weight-stream words saved vs sequential execution.
    weight_saved: u64,
    /// Submissions shed at admission (Reject queue-full / Timeout
    /// expiry). Not counted in `submitted`.
    rejected: u64,
    /// Payload bytes those shed submissions carried (load the wire
    /// frontend accepted but the service refused).
    shed_bytes: u64,
    /// Times a submission found the queue full (counted once per
    /// submission, whatever the admission policy did next).
    queue_full_events: u64,
    /// Requests shed because their deadline passed before a worker ran
    /// them (also counted in `failed`).
    deadline_exceeded: u64,
    /// Client-signalled retry attempts observed by the wire server
    /// (`Infer` frames with `attempt > 0`).
    retries: u64,
    /// Times the circuit breaker tripped open on this model.
    breaker_trips: u64,
    /// Faults the chaos plan injected into this model's execution
    /// (worker stalls + slow batches).
    faults_injected: u64,
}

impl MetricsAccum {
    pub(crate) fn record_submit(&mut self, now: Instant) {
        self.submitted += 1;
        self.first_submit.get_or_insert(now);
    }

    /// One executed batch pass of `size` requests that saved `saved`
    /// weight-stream words vs sequential execution.
    pub(crate) fn record_batch(&mut self, size: usize, saved: u64) {
        self.batches += 1;
        self.batch_images += size as u64;
        self.batch_max = self.batch_max.max(size as u64);
        self.weight_saved += saved;
    }

    pub(crate) fn record_ok(&mut self, latency_ms: f64, now: Instant) {
        self.completed += 1;
        self.lat_sum_ms += latency_ms;
        if self.window.len() < LATENCY_WINDOW {
            self.window.push(latency_ms);
        } else {
            self.window[self.next] = latency_ms;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
        self.last_done = Some(now);
    }

    pub(crate) fn record_failure(&mut self, now: Instant) {
        self.failed += 1;
        self.last_done = Some(now);
    }

    /// A submission found the queue full (before the admission policy
    /// decided whether to shed it).
    pub(crate) fn record_queue_full(&mut self) {
        self.queue_full_events += 1;
    }

    /// A submission was shed at admission; `input_len` is its payload
    /// length in `f32` values.
    pub(crate) fn record_rejected(&mut self, input_len: usize) {
        self.rejected += 1;
        self.shed_bytes += 4 * input_len as u64;
    }

    /// A request was shed because its deadline had already passed.
    /// Callers also `record_failure` so totals stay consistent.
    pub(crate) fn record_deadline_exceeded(&mut self) {
        self.deadline_exceeded += 1;
    }

    /// The wire server observed a client retry attempt for this model.
    pub(crate) fn record_retry(&mut self) {
        self.retries += 1;
    }

    /// The circuit breaker tripped open.
    pub(crate) fn record_breaker_trip(&mut self) {
        self.breaker_trips += 1;
    }

    /// The chaos plan injected `n` faults into this model's execution.
    pub(crate) fn record_faults(&mut self, n: u64) {
        self.faults_injected += n;
    }

    /// p99 over the recent latency window — the circuit breaker's
    /// Degraded signal. 0.0 before any completion.
    pub(crate) fn recent_p99(&self) -> f64 {
        let mut lat = self.window.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        percentile(&lat, 0.99).unwrap_or(0.0)
    }

    pub(crate) fn snapshot(
        &self,
        model: &str,
        removed: bool,
        queued: usize,
        in_flight: usize,
        total_ops: u64,
        weight_bytes: u64,
        breaker: BreakerState,
    ) -> ModelMetrics {
        let mut lat = self.window.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        // The active window is first submission → last completion: a
        // service that sat idle for an hour before its first request
        // does not dilute its throughput figure.
        let active_s = match (self.first_submit, self.last_done) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        let per_s = |n: f64| if active_s > 0.0 { n / active_s } else { 0.0 };
        ModelMetrics {
            model: model.to_string(),
            removed,
            submitted: self.submitted,
            completed: self.completed,
            failed: self.failed,
            queued,
            in_flight,
            mean_ms: if self.completed > 0 {
                self.lat_sum_ms / self.completed as f64
            } else {
                0.0
            },
            p50_ms: percentile(&lat, 0.50).unwrap_or(0.0),
            p99_ms: percentile(&lat, 0.99).unwrap_or(0.0),
            req_per_s: per_s(self.completed as f64),
            ops_per_s: per_s(total_ops as f64 * self.completed as f64),
            active_s,
            batch_mean: if self.batches > 0 {
                self.batch_images as f64 / self.batches as f64
            } else {
                0.0
            },
            batch_max: self.batch_max,
            weight_traffic_saved: self.weight_saved,
            weight_bytes,
            rejected_backpressure: self.rejected,
            shed_bytes: self.shed_bytes,
            queue_full_events: self.queue_full_events,
            deadline_exceeded: self.deadline_exceeded,
            retries: self.retries,
            breaker_trips: self.breaker_trips,
            breaker,
            faults_injected: self.faults_injected,
        }
    }
}

/// One model's serving statistics at a snapshot instant.
#[derive(Debug, Clone)]
pub struct ModelMetrics {
    /// The model's service name (the submit routing key).
    pub model: String,
    /// The model was hot-removed; counters are its historical totals.
    pub removed: bool,
    /// Requests admitted (excludes typed submit rejections).
    pub submitted: u64,
    /// Requests that completed with an output.
    pub completed: u64,
    /// Requests that failed in the worker (or were drained by a
    /// hot-remove).
    pub failed: u64,
    /// Requests queued but not yet picked up, at the snapshot instant.
    pub queued: usize,
    /// Requests executing in a worker, at the snapshot instant.
    pub in_flight: usize,
    /// Mean execution latency over all completed requests.
    pub mean_ms: f64,
    /// Median execution latency over the recent window.
    pub p50_ms: f64,
    /// 99th-percentile execution latency over the recent window.
    pub p99_ms: f64,
    /// Completed requests per second of the active window.
    pub req_per_s: f64,
    /// Network ops per second of the active window.
    pub ops_per_s: f64,
    /// First submission → last completion, in seconds.
    pub active_s: f64,
    /// Mean executed batch size (1.0 when batching is off; 0.0 before
    /// any execution).
    pub batch_mean: f64,
    /// Largest batch one pass served.
    pub batch_max: u64,
    /// Cumulative weight-stream words the model's batch passes saved
    /// vs sequential execution.
    pub weight_traffic_saved: u64,
    /// Resident packed binary-weight footprint of the hosted network,
    /// in bytes (1 bit/weight `u64` bitplanes — the serving-side
    /// working set a resident model costs; 0 for opaque backends whose
    /// weights the service cannot see).
    pub weight_bytes: u64,
    /// Submissions shed at admission (queue full under `Reject`, or
    /// `Timeout` budget expired). Excluded from `submitted`.
    pub rejected_backpressure: u64,
    /// Payload bytes carried by those shed submissions.
    pub shed_bytes: u64,
    /// Times a submission found the queue full (whatever the admission
    /// policy did next — blocked submissions that later got in still
    /// count one event).
    pub queue_full_events: u64,
    /// Requests shed because their deadline passed before a worker ran
    /// them (a subset of `failed`).
    pub deadline_exceeded: u64,
    /// Client retry attempts the wire server observed for this model.
    pub retries: u64,
    /// Times the circuit breaker tripped open.
    pub breaker_trips: u64,
    /// Circuit-breaker health state at the snapshot instant
    /// (`Healthy` when no breaker policy is configured).
    pub breaker: BreakerState,
    /// Faults the chaos plan injected into this model's execution.
    pub faults_injected: u64,
}

/// A consistent snapshot over every hosted model, produced by
/// [`super::InferenceService::metrics`] (and returned once more by
/// [`super::InferenceService::shutdown`] after the drain).
#[derive(Debug, Clone)]
pub struct ServiceMetrics {
    /// The service's shared worker-thread budget.
    pub workers: usize,
    /// One row per model, in registration order (hot-removed models
    /// keep their row, flagged `removed`).
    pub per_model: Vec<ModelMetrics>,
}

impl ServiceMetrics {
    /// The row for `model`, if it is (or was) hosted.
    pub fn model(&self, model: &str) -> Option<&ModelMetrics> {
        self.per_model.iter().find(|m| m.model == model)
    }

    pub fn total_submitted(&self) -> u64 {
        self.per_model.iter().map(|m| m.submitted).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.per_model.iter().map(|m| m.completed).sum()
    }

    pub fn total_failed(&self) -> u64 {
        self.per_model.iter().map(|m| m.failed).sum()
    }

    /// Cumulative weight-stream words saved by batching, service-wide.
    pub fn total_weight_traffic_saved(&self) -> u64 {
        self.per_model.iter().map(|m| m.weight_traffic_saved).sum()
    }

    /// Resident packed-weight bytes across every still-hosted model
    /// (hot-removed models no longer hold their stream).
    pub fn total_weight_bytes(&self) -> u64 {
        self.per_model
            .iter()
            .filter(|m| !m.removed)
            .map(|m| m.weight_bytes)
            .sum()
    }

    /// Submissions shed at admission, service-wide.
    pub fn total_rejected_backpressure(&self) -> u64 {
        self.per_model.iter().map(|m| m.rejected_backpressure).sum()
    }

    /// Payload bytes shed at admission, service-wide.
    pub fn total_shed_bytes(&self) -> u64 {
        self.per_model.iter().map(|m| m.shed_bytes).sum()
    }

    /// Requests shed past their deadline, service-wide.
    pub fn total_deadline_exceeded(&self) -> u64 {
        self.per_model.iter().map(|m| m.deadline_exceeded).sum()
    }

    /// Client retry attempts observed, service-wide.
    pub fn total_retries(&self) -> u64 {
        self.per_model.iter().map(|m| m.retries).sum()
    }

    /// Faults injected into execution, service-wide.
    pub fn total_faults_injected(&self) -> u64 {
        self.per_model.iter().map(|m| m.faults_injected).sum()
    }

    /// A model's row as single-model [`ServeStats`] (what
    /// [`crate::engine::Engine::report_with_serve`] consumes), with the
    /// service's active window standing in for the batch wall time.
    pub fn serve_stats(&self, model: &str) -> Option<ServeStats> {
        let m = self.model(model)?;
        Some(ServeStats {
            requests: m.submitted as usize,
            completed: m.completed as usize,
            workers: self.workers,
            total_s: m.active_s,
            mean_ms: m.mean_ms,
            p50_ms: m.p50_ms,
            p99_ms: m.p99_ms,
            ops_per_s: m.ops_per_s,
        })
    }

    /// The `serve` CLI's per-model metrics table.
    pub fn render_table(&self) -> String {
        let mut out = format!(
            "{:<28} {:>6} {:>6} {:>5} {:>5} {:>5} {:>9} {:>9} {:>9} {:>8} {:>9} {:>6} {:>6} {:>12} {:>8} {:>5} {:>5} {:>5} {:>5}\n",
            "model",
            "sub",
            "ok",
            "fail",
            "rej",
            "queue",
            "mean ms",
            "p50 ms",
            "p99 ms",
            "req/s",
            "MOp/s",
            "avg B",
            "max B",
            "words saved",
            "wt KiB",
            "ddl",
            "rtry",
            "flt",
            "brk"
        );
        for m in &self.per_model {
            out.push_str(&format!(
                "{:<28} {:>6} {:>6} {:>5} {:>5} {:>5} {:>9.2} {:>9.2} {:>9.2} {:>8.1} {:>9.2} {:>6.2} {:>6} {:>12} {:>8.1} {:>5} {:>5} {:>5} {:>5}{}\n",
                m.model,
                m.submitted,
                m.completed,
                m.failed,
                m.rejected_backpressure,
                m.queued,
                m.mean_ms,
                m.p50_ms,
                m.p99_ms,
                m.req_per_s,
                m.ops_per_s / 1e6,
                m.batch_mean,
                m.batch_max,
                m.weight_traffic_saved,
                m.weight_bytes as f64 / 1024.0,
                m.deadline_exceeded,
                m.retries,
                m.faults_injected,
                m.breaker.as_str(),
                if m.removed { "  (removed)" } else { "" }
            ));
        }
        out.push_str(&format!(
            "total: {} submitted, {} completed, {} failed, {} rejected-backpressure ({} B shed), {} past-deadline, {} retries, {} faults on {} workers\n",
            self.total_submitted(),
            self.total_completed(),
            self.total_failed(),
            self.total_rejected_backpressure(),
            self.total_shed_bytes(),
            self.total_deadline_exceeded(),
            self.total_retries(),
            self.total_faults_injected(),
            self.workers
        ));
        out
    }
}

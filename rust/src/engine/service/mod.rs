//! # `InferenceService` — long-lived, multi-model serving
//!
//! The serving layer as a first-class subsystem instead of a one-shot
//! batch call: one service hosts N named models (each its own
//! [`Engine`] backend — different networks, precisions, backends or
//! meshes side by side) behind a shared worker-thread budget, routes
//! typed [`InferRequest`]s by model name, and hands every submission a
//! [`Ticket`] that resolves to a **per-request** result — one failing
//! or panicking request never discards another request's output.
//!
//! This is the shape Hyperdrive's own pitch demands: the chip is
//! weight-streaming precisely so that *arbitrary* networks can share
//! the same silicon (unlike fixed-function BWN cores), so the serving
//! API hosts arbitrary networks concurrently rather than one at a
//! time.
//!
//! ```no_run
//! use hyperdrive::engine::{InferRequest, InferenceService, ModelConfig};
//!
//! # fn main() -> Result<(), hyperdrive::engine::EngineError> {
//! let svc = InferenceService::builder()
//!     .model_spec("hypernet20")
//!     .model("tiny-resnet", ModelConfig::new("resnet18@32x32"))
//!     .workers(4)
//!     .queue_depth(8)
//!     .build()?;
//! let input = vec![0.0f32; svc.input_len("hypernet20").unwrap()];
//! let ticket = svc.submit(InferRequest {
//!     model: "hypernet20".into(),
//!     input: input.into(),
//!     id: 0,
//!     deadline_ms: None,
//! })?;
//! let response = ticket.wait()?;
//! println!("request {} took {:.2} ms", response.id, response.latency_ms);
//! println!("{}", svc.shutdown().render_table());
//! # Ok(()) }
//! ```
//!
//! ## Threading model (sharded core)
//!
//! Every hosted model is a [`Shard`]: its bounded queue, in-flight
//! count and metrics live behind the **shard's own mutex**, with two
//! shard-local condvars (`arrivals` for workers holding a short batch
//! open, `space` for submitters blocked on a full queue). Submissions
//! to different models never contend on a lock; the old single
//! `Mutex<State>` + 2 global condvars design serialized every submit
//! and every metrics bump through one word of memory, which is a wall
//! at wire concurrency (the TCP frontend in [`super::wire`] feeds the
//! service from one reader thread per connection).
//!
//! `build()` spawns exactly `workers` OS threads that drain the shards
//! round-robin (an atomic cursor; one busy model cannot starve the
//! others). Idle workers park on a global **doorbell** — a mutex
//! holding the service-wide count of queued-but-unpopped jobs plus the
//! shutdown flag. A submitter increments the pending count *before*
//! its job becomes visible and rings the doorbell after, so a worker
//! that scans every shard and finds nothing can atomically decide
//! "really idle" (`pending == 0`) vs "rescan" — no lost wakeups, and
//! workers exit only when `pending == 0 && shutting_down`, which is
//! exactly the drain guarantee: every admitted ticket resolves.
//!
//! Lock order is `directory → shard.state → doorbell`; no path
//! acquires them in any other order, and inference always runs with no
//! lock held.
//!
//! Admission is per-model and policy-controlled ([`AdmissionPolicy`]):
//! `Block` applies backpressure, `Reject` and `Timeout` turn a full
//! queue into typed [`ServeError`]s — both are counted per model
//! (`rejected_backpressure`, `shed_bytes`, `queue_full_events` in
//! [`ModelMetrics`]) so load shedding is observable, not silent.
//! [`InferenceService::shutdown`] stops admission, drains every queue,
//! joins the workers and returns the final [`ServiceMetrics`];
//! dropping the service does the same.
//!
//! ## Micro-batching
//!
//! With a [`BatchPolicy`] (`max_batch > 1`), a worker that pops a
//! request coalesces further queued same-model requests into one
//! [`Backend::infer_batch`] pass — B images stay resident while each
//! weight block streams once, the amortization Hyperdrive's
//! weight-streaming datapath exists for. Per-request semantics are
//! unchanged: every request keeps its own [`Ticket`], outputs are
//! bit-identical to unbatched execution, and one failing request fails
//! only itself. The default policy (`max_batch == 1`) batches nothing.
//! A worker holding a batch open for stragglers wakes immediately on
//! `remove_model` (the held jobs fail fast with
//! [`ServeError::ModelRemoved`]) and on shutdown (the held batch runs
//! at once — admitted tickets still resolve successfully).
//!
//! ## Resilience
//!
//! Production serving has a failure model, not just a happy path
//! (`DESIGN.md` §Failure model):
//!
//! * **Deadlines** — a request may carry
//!   [`InferRequest::deadline_ms`] (or inherit
//!   [`ServiceBuilder::deadline_ms`]). A worker sheds a popped job
//!   whose deadline already passed with
//!   [`ServeError::DeadlineExceeded`] instead of burning backend
//!   cycles on a result nobody can use.
//! * **Circuit breaker** — with a [`BreakerPolicy`], each model runs a
//!   Healthy / Degraded / Open health machine ([`BreakerState`]),
//!   updated under the shard lock on every outcome: consecutive
//!   failures trip it Open (submissions shed fast with
//!   [`ServeError::BreakerOpen`] until the cooldown admits a half-open
//!   probe), a p99 above threshold marks it Degraded.
//! * **Watchdog** — with [`ServiceBuilder::watchdog_ms`], a scanner
//!   thread fails the in-flight tickets of any worker stuck past the
//!   limit ([`ServeError::WorkerStalled`]) and `shutdown()` detaches
//!   (rather than joins) workers the watchdog declared stuck — the
//!   drain guarantee survives a wedged backend.
//! * **Chaos** — a seeded [`crate::faults::FaultPlan`]
//!   ([`ServiceBuilder::faults`]) injects worker stalls and slow
//!   batches keyed by request id, so the machinery above is testable
//!   deterministically; see `tests/fault_injection.rs`.

mod batcher;
mod metrics;

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::faults::FaultPlan;
use crate::model::NetworkRegistry;
use crate::simulator::Precision;

use super::backend::{Backend, BackendKind};
use super::{Engine, EngineError};

pub use batcher::BatchPolicy;
pub use metrics::{ModelMetrics, ServiceMetrics};
use metrics::MetricsAccum;

/// What a full per-model queue does to the next submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Backpressure: `submit` blocks until a queue slot frees (or the
    /// service shuts down / the model is removed).
    Block,
    /// `submit` returns [`ServeError::QueueFull`] immediately.
    Reject,
    /// Like `Block`, but gives up with
    /// [`ServeError::AdmissionTimeout`] after this many milliseconds.
    Timeout(u64),
}

/// Per-model circuit-breaker thresholds ([`ServiceBuilder::breaker`]).
///
/// The health machine runs Healthy → Degraded → Open: `p99_ms` governs
/// the Degraded signal, `consecutive_failures` trips the breaker Open
/// (new submissions shed fast with [`ServeError::BreakerOpen`]), and
/// after `cooldown_ms` one half-open probe is admitted — its outcome
/// decides whether the breaker closes or re-trips.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BreakerPolicy {
    /// Consecutive request failures that trip the breaker Open.
    pub consecutive_failures: u64,
    /// Recent-window p99 latency (ms) above which the model is marked
    /// Degraded. `f64::INFINITY` disables the latency signal.
    pub p99_ms: f64,
    /// How long an Open breaker sheds before admitting a half-open
    /// probe request.
    pub cooldown_ms: u64,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy {
            consecutive_failures: 5,
            p99_ms: f64::INFINITY,
            cooldown_ms: 500,
        }
    }
}

/// A model's circuit-breaker health state (surfaced per model in
/// [`ModelMetrics::breaker`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum BreakerState {
    /// Serving normally.
    #[default]
    Healthy,
    /// Serving, but the recent p99 exceeds the policy threshold (or the
    /// breaker just admitted a half-open probe).
    Degraded,
    /// Shedding: recent consecutive failures tripped the breaker; new
    /// submissions fail fast until the cooldown admits a probe.
    Open,
}

impl BreakerState {
    /// Short label for metric tables.
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerState::Healthy => "ok",
            BreakerState::Degraded => "degr",
            BreakerState::Open => "open",
        }
    }
}

/// One typed inference request, routed by model name.
///
/// The input is a shared `Arc<[f32]>` slice: cloning a request (or
/// moving it through the queue and into a batch) never copies the
/// tensor data. `Vec<f32>` converts with `.into()`.
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Service name of the target model.
    pub model: String,
    /// Flattened input FM (`c·h·w` values of the model's network).
    pub input: Arc<[f32]>,
    /// Caller-chosen correlation id, echoed on the response.
    pub id: u64,
    /// Optional per-request deadline, measured from submission. A job
    /// still queued when it expires is shed with
    /// [`ServeError::DeadlineExceeded`] instead of executed. `None`
    /// inherits the service default ([`ServiceBuilder::deadline_ms`]).
    pub deadline_ms: Option<u64>,
}

/// A completed inference.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// The request's correlation id.
    pub id: u64,
    /// The model that served it.
    pub model: String,
    /// The backend's output (final FM / logits).
    pub output: Vec<f32>,
    /// Execution latency inside the worker (queueing time excluded —
    /// that shows up in throughput, not in the latency quantiles).
    pub latency_ms: f64,
}

/// Typed per-request serving errors. Admission errors come back from
/// [`InferenceService::submit`]; execution errors resolve through the
/// [`Ticket`] — either way, they are scoped to one request.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// No hosted model with that name; carries the hosted names.
    UnknownModel { model: String, known: Vec<String> },
    /// The input length does not match the model's network.
    BadInput {
        model: String,
        got: usize,
        want: usize,
    },
    /// The model's queue is full ([`AdmissionPolicy::Reject`]).
    QueueFull { model: String, depth: usize },
    /// No queue slot freed within the admission timeout.
    AdmissionTimeout { model: String, waited_ms: u64 },
    /// The model was hot-removed (pending requests are drained with
    /// this error; in-flight requests still complete).
    ModelRemoved { model: String },
    /// The service is shutting down; no new requests are admitted.
    ShuttingDown,
    /// The backend panicked on this request (the worker survives).
    Panicked { model: String, message: String },
    /// The backend returned an error for this request.
    Failed { model: String, message: String },
    /// The request's deadline passed before a worker could execute it;
    /// it was shed without spending backend cycles.
    DeadlineExceeded { model: String, deadline_ms: u64 },
    /// The model's circuit breaker is Open: recent failures tripped it
    /// and the cooldown has not yet admitted a probe.
    BreakerOpen { model: String },
    /// The watchdog declared the worker executing this request stuck
    /// after `stalled_ms` and failed its ticket.
    WorkerStalled { model: String, stalled_ms: u64 },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownModel { model, known } => {
                write!(f, "unknown model `{model}` — serving: {}", known.join(", "))
            }
            ServeError::BadInput { model, got, want } => {
                write!(f, "model `{model}`: input has {got} values, network expects {want}")
            }
            ServeError::QueueFull { model, depth } => {
                write!(f, "model `{model}`: queue full ({depth} pending)")
            }
            ServeError::AdmissionTimeout { model, waited_ms } => {
                write!(f, "model `{model}`: no queue slot within {waited_ms} ms")
            }
            ServeError::ModelRemoved { model } => write!(f, "model `{model}` was removed"),
            ServeError::ShuttingDown => write!(f, "service is shutting down"),
            ServeError::Panicked { model, message } => {
                write!(f, "model `{model}`: inference panicked: {message}")
            }
            ServeError::Failed { model, message } => write!(f, "model `{model}`: {message}"),
            ServeError::DeadlineExceeded { model, deadline_ms } => {
                write!(f, "model `{model}`: deadline of {deadline_ms} ms exceeded before execution")
            }
            ServeError::BreakerOpen { model } => {
                write!(f, "model `{model}`: circuit breaker is open")
            }
            ServeError::WorkerStalled { model, stalled_ms } => {
                write!(f, "model `{model}`: worker stalled for {stalled_ms} ms; request failed by watchdog")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Run one inference with panic capture: a panicking backend becomes a
/// per-request [`ServeError::Panicked`] instead of killing the worker
/// (a dead worker would strand queued tickets forever).
pub(crate) fn run_request(
    backend: &dyn Backend,
    model: &str,
    input: &[f32],
) -> Result<Vec<f32>, ServeError> {
    match catch_unwind(AssertUnwindSafe(|| backend.infer(input))) {
        Ok(Ok(output)) => Ok(output),
        Ok(Err(e)) => Err(ServeError::Failed {
            model: model.to_string(),
            message: e.to_string(),
        }),
        Err(payload) => {
            let message = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(ServeError::Panicked {
                model: model.to_string(),
                message,
            })
        }
    }
}

/// The write-once result slot a [`Ticket`] waits on.
struct TicketShared {
    slot: Mutex<Option<Result<InferResponse, ServeError>>>,
    cv: Condvar,
}

fn complete(shared: &TicketShared, result: Result<InferResponse, ServeError>) {
    *shared.slot.lock().unwrap() = Some(result);
    shared.cv.notify_all();
}

/// Handle to one submitted request; resolves independently of every
/// other request.
pub struct Ticket {
    id: u64,
    model: String,
    shared: Arc<TicketShared>,
}

impl Ticket {
    /// The request's correlation id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The model the request was routed to.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Block until the request resolves. Never deadlocks against
    /// shutdown: the drain completes every admitted ticket.
    pub fn wait(self) -> Result<InferResponse, ServeError> {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.shared.cv.wait(slot).unwrap();
        }
    }

    /// Whether the request has resolved (non-destructive — safe to
    /// poll and then [`wait`](Self::wait)).
    pub fn is_ready(&self) -> bool {
        self.shared.slot.lock().unwrap().is_some()
    }

    /// Non-blocking claim: the result if the request has resolved, or
    /// the ticket handed back to keep polling/waiting. Consuming the
    /// ticket is what makes the take safe — there is no handle left to
    /// `wait()` on an emptied slot.
    pub fn try_wait(self) -> Result<Result<InferResponse, ServeError>, Ticket> {
        let taken = self.shared.slot.lock().unwrap().take();
        match taken {
            Some(result) => Ok(result),
            None => Err(self),
        }
    }
}

/// One queued request.
struct Job {
    id: u64,
    input: Arc<[f32]>,
    ticket: Arc<TicketShared>,
    /// Expiry instant and the original budget in ms, if the request
    /// carried (or inherited) a deadline.
    deadline: Option<(Instant, u64)>,
}

/// The mutable half of a shard, behind the shard's own mutex.
struct ShardState {
    queue: VecDeque<Job>,
    in_flight: usize,
    removed: bool,
    /// Shutdown observed — waiters on this shard's condvars re-check
    /// this flag (it is written under the same mutex they wait with,
    /// so the wakeup cannot be lost).
    draining: bool,
    metrics: MetricsAccum,
    /// Circuit-breaker health; stays `Healthy` without a policy.
    breaker: BreakerState,
    /// Consecutive failures since the last success (breaker input).
    consec_failures: u64,
    /// When the breaker last tripped Open (cooldown epoch).
    breaker_opened_at: Option<Instant>,
}

/// One hosted model: immutable routing data plus its own lock + two
/// condvars. Shards are never deleted from the directory (hot removal
/// only tombstones them), so metrics rows survive removal and a
/// worker's `Arc<Shard>` stays valid across the unlocked execution
/// window.
struct Shard {
    name: String,
    backend: Arc<dyn Backend>,
    input_len: usize,
    total_ops: u64,
    /// Resident packed-weight footprint of the hosted network, in bytes
    /// (0 for opaque backends whose weights the service cannot see).
    weight_bytes: u64,
    queue_depth: usize,
    /// How queued requests coalesce into batch-resident passes.
    batch: BatchPolicy,
    /// Circuit-breaker thresholds; `None` disables the health machine.
    breaker: Option<BreakerPolicy>,
    /// Lock-free mirror of `state.removed` for name resolution —
    /// written once under the state lock, read without it.
    removed_hint: AtomicBool,
    state: Mutex<ShardState>,
    /// Workers holding a short batch open for stragglers wait here;
    /// submitters notify it on every push, removal/shutdown notify it
    /// to break the hold.
    arrivals: Condvar,
    /// Submitters blocked on a full queue wait here; workers notify it
    /// after popping, removal/shutdown notify it to refuse.
    space: Condvar,
}

impl Shard {
    fn new(
        name: String,
        backend: Arc<dyn Backend>,
        input_len: usize,
        total_ops: u64,
        weight_bytes: u64,
        queue_depth: usize,
        batch: BatchPolicy,
        breaker: Option<BreakerPolicy>,
    ) -> Shard {
        Shard {
            name,
            backend,
            input_len,
            total_ops,
            weight_bytes,
            queue_depth,
            batch,
            breaker,
            removed_hint: AtomicBool::new(false),
            state: Mutex::new(ShardState {
                queue: VecDeque::new(),
                in_flight: 0,
                removed: false,
                draining: false,
                metrics: MetricsAccum::default(),
                breaker: BreakerState::Healthy,
                consec_failures: 0,
                breaker_opened_at: None,
            }),
            arrivals: Condvar::new(),
            space: Condvar::new(),
        }
    }
}

/// Advance the breaker health machine on one request outcome. Called
/// under the shard lock wherever an outcome is recorded, so breaker
/// state and metrics move atomically.
fn update_breaker(shard: &Shard, st: &mut ShardState, ok: bool) {
    let Some(pol) = shard.breaker else { return };
    if ok {
        st.consec_failures = 0;
        if st.breaker != BreakerState::Open {
            st.breaker = if st.metrics.recent_p99() > pol.p99_ms {
                BreakerState::Degraded
            } else {
                BreakerState::Healthy
            };
        }
    } else {
        st.consec_failures += 1;
        if st.breaker != BreakerState::Open && st.consec_failures >= pol.consecutive_failures {
            st.breaker = BreakerState::Open;
            st.breaker_opened_at = Some(Instant::now());
            st.metrics.record_breaker_trip();
        }
    }
}

/// Service-wide idle/exit accounting: how many jobs are queued but not
/// yet popped, plus the shutdown flag. Both are only ever touched
/// under the doorbell mutex, which makes the worker exit condition
/// (`pending == 0 && shutting_down`) race-free against submitters —
/// a submitter bumps `pending` *before* its job becomes visible.
struct DoorbellState {
    pending: u64,
    shutting_down: bool,
}

/// Service-wide resilience knobs, set on the builder and threaded to
/// the workers, watchdog and submit path.
#[derive(Clone, Default)]
struct ResilienceConfig {
    /// Default deadline for requests that carry none.
    deadline_ms: Option<u64>,
    /// Circuit-breaker thresholds applied to every shard.
    breaker: Option<BreakerPolicy>,
    /// Stall limit after which the watchdog fails a worker's tickets.
    watchdog_ms: Option<u64>,
    /// Seeded chaos plan (worker stalls / slow batches).
    faults: Option<Arc<FaultPlan>>,
}

/// One worker's currently-executing work, registered in its
/// [`WorkerSlot`] so the watchdog can see (and fail) it.
struct InFlight {
    shard: Arc<Shard>,
    tickets: Vec<Arc<TicketShared>>,
    started: Instant,
    /// Written and read only under `shard.state`'s lock: the watchdog
    /// sets it when it fails this work, and the owning worker checks it
    /// before touching accounting — exactly one side resolves the
    /// tickets.
    abandoned: AtomicBool,
    /// Set by the worker (under the same lock) once it has accounted
    /// the work itself — the watchdog then keeps off even if the entry
    /// is still visible in the slot.
    done: AtomicBool,
}

/// Watchdog-visible mailbox: what a worker is executing right now.
#[derive(Default)]
struct WorkerSlot {
    current: Mutex<Option<Arc<InFlight>>>,
}

struct Shared {
    /// The shard directory. Grows on hot-add, never shrinks; readers
    /// clone the `Arc`s and drop the lock before touching any shard.
    shards: RwLock<Vec<Arc<Shard>>>,
    doorbell: Mutex<DoorbellState>,
    /// Idle workers park here; submitters ring it after every push.
    bell: Condvar,
    /// Round-robin cursor over the directory — one busy model cannot
    /// starve the others' queues. Plain atomic: the cursor is a
    /// fairness hint, not a correctness invariant.
    rr: AtomicUsize,
    /// Cheap pre-lock mirror of `doorbell.shutting_down`.
    shutting: AtomicBool,
    /// One slot per worker, in spawn order (parallel to the service's
    /// join handles). Empty when no watchdog is configured.
    slots: Vec<Arc<WorkerSlot>>,
    /// Resilience knobs shared by workers and the watchdog.
    resilience: ResilienceConfig,
    /// Tells the watchdog thread to exit (set after workers joined).
    watchdog_stop: AtomicBool,
}

impl Shared {
    /// Resolve a model name to its shard, or the typed routing error.
    fn find(&self, model: &str) -> Result<Arc<Shard>, ServeError> {
        let shards = self.shards.read().unwrap();
        let mut removed_seen = false;
        for s in shards.iter() {
            if s.name == model {
                if s.removed_hint.load(Ordering::Acquire) {
                    removed_seen = true;
                    continue;
                }
                return Ok(s.clone());
            }
        }
        if removed_seen {
            return Err(ServeError::ModelRemoved {
                model: model.to_string(),
            });
        }
        let known = shards
            .iter()
            .filter(|s| !s.removed_hint.load(Ordering::Acquire))
            .map(|s| s.name.clone())
            .collect();
        Err(ServeError::UnknownModel {
            model: model.to_string(),
            known,
        })
    }

    /// `pending -= n` for jobs just popped/drained. Called while
    /// holding a shard lock (order: shard.state → doorbell).
    fn dec_pending(&self, n: u64) {
        let mut db = self.doorbell.lock().unwrap();
        debug_assert!(db.pending >= n, "pending underflow");
        db.pending = db.pending.saturating_sub(n);
    }
}

/// One round-robin scan over a directory snapshot: pop (and, for a
/// batching shard, coalesce) from the first non-empty shard. Returns
/// the shard, the popped jobs, and whether the model was removed while
/// the batch was held open (the jobs must then fail fast).
fn try_pop(shared: &Shared, shards: &[Arc<Shard>]) -> Option<(Arc<Shard>, Vec<Job>, bool)> {
    let n = shards.len();
    if n == 0 {
        return None;
    }
    let start = shared.rr.load(Ordering::Relaxed) % n;
    for k in 0..n {
        let i = (start + k) % n;
        let shard = &shards[i];
        if shard.removed_hint.load(Ordering::Relaxed) {
            continue;
        }
        let mut st = shard.state.lock().unwrap();
        if st.removed {
            continue;
        }
        let Some(job) = st.queue.pop_front() else {
            continue;
        };
        st.in_flight += 1;
        shared.dec_pending(1);
        shared.rr.store((i + 1) % n, Ordering::Relaxed);
        let mut jobs = vec![job];
        let mut removed_mid_hold = false;
        if shard.batch.max_batch > 1 {
            let (guard, removed) = batcher::fill_batch(shared, shard, st, &mut jobs);
            st = guard;
            removed_mid_hold = removed;
        }
        drop(st);
        // Queue slots freed; wake submitters blocked on this shard.
        shard.space.notify_all();
        return Some((shard.clone(), jobs, removed_mid_hold));
    }
    None
}

/// Shed popped jobs whose deadline already passed — server-side
/// expiry: no backend cycles are spent on a result nobody can use.
/// Returns the still-live jobs.
fn shed_expired(shard: &Shard, jobs: Vec<Job>) -> Vec<Job> {
    let now = Instant::now();
    let (expired, live): (Vec<Job>, Vec<Job>) = jobs
        .into_iter()
        .partition(|j| j.deadline.is_some_and(|(at, _)| now >= at));
    if !expired.is_empty() {
        {
            let mut st = shard.state.lock().unwrap();
            st.in_flight -= expired.len();
            let t = Instant::now();
            for _ in &expired {
                st.metrics.record_deadline_exceeded();
                st.metrics.record_failure(t);
            }
        }
        for job in expired {
            let (_, deadline_ms) = job.deadline.expect("partitioned on Some");
            complete(
                &job.ticket,
                Err(ServeError::DeadlineExceeded {
                    model: shard.name.clone(),
                    deadline_ms,
                }),
            );
        }
    }
    live
}

/// Consult the chaos plan before running a batch: worker stalls and
/// slow batches are sleeps keyed by the first request id (schedule-
/// independent, so identical seeds inject identical faults). Returns
/// after sleeping out whatever fired.
fn inject_execution_faults(shard: &Shard, jobs: &[Job], faults: Option<&FaultPlan>) {
    let Some(plan) = faults else { return };
    let seq = jobs[0].id;
    let stall = plan.worker_stall(seq);
    let slow = plan.slow_model(seq);
    let fired = stall.is_some() as u64 + slow.is_some() as u64;
    if fired > 0 {
        shard.state.lock().unwrap().metrics.record_faults(fired);
    }
    if let Some(ms) = stall {
        std::thread::sleep(Duration::from_millis(ms));
    }
    if let Some(ms) = slow {
        std::thread::sleep(Duration::from_millis(ms));
    }
}

/// Execute popped jobs (single request or batch pass) with no lock
/// held, record metrics under the shard lock, resolve the tickets.
/// If the watchdog abandoned this work mid-execution (`watch`), the
/// tickets are already failed and accounted — the worker backs off.
fn execute(shard: &Shard, jobs: Vec<Job>, watch: Option<&InFlight>, faults: Option<&FaultPlan>) {
    inject_execution_faults(shard, &jobs, faults);
    let abandoned = |w: Option<&InFlight>| w.is_some_and(|w| w.abandoned.load(Ordering::Relaxed));
    let t = Instant::now();
    if jobs.len() == 1 {
        let job = jobs.into_iter().next().expect("one job");
        let result = run_request(&*shard.backend, &shard.name, &job.input);
        let latency_ms = t.elapsed().as_secs_f64() * 1e3;
        let response = result.map(|output| InferResponse {
            id: job.id,
            model: shard.name.clone(),
            output,
            latency_ms,
        });
        {
            let mut st = shard.state.lock().unwrap();
            if abandoned(watch) {
                return;
            }
            if let Some(w) = watch {
                w.done.store(true, Ordering::Relaxed);
            }
            st.in_flight -= 1;
            st.metrics.record_batch(1, 0);
            let now = Instant::now();
            match &response {
                Ok(_) => st.metrics.record_ok(latency_ms, now),
                Err(_) => st.metrics.record_failure(now),
            }
            update_breaker(shard, &mut st, response.is_ok());
        }
        complete(&job.ticket, response);
    } else {
        // Batch-resident pass: one infer_batch over B inputs, then
        // the results scatter back to their own tickets.
        let (results, saved) = batcher::run_batch(&*shard.backend, &shard.name, &jobs);
        let latency_ms = t.elapsed().as_secs_f64() * 1e3;
        let responses: Vec<Result<InferResponse, ServeError>> = jobs
            .iter()
            .zip(results)
            .map(|(job, result)| {
                result.map(|output| InferResponse {
                    id: job.id,
                    model: shard.name.clone(),
                    output,
                    latency_ms,
                })
            })
            .collect();
        {
            let mut st = shard.state.lock().unwrap();
            if abandoned(watch) {
                return;
            }
            if let Some(w) = watch {
                w.done.store(true, Ordering::Relaxed);
            }
            st.in_flight -= jobs.len();
            st.metrics.record_batch(jobs.len(), saved);
            let now = Instant::now();
            for r in &responses {
                match r {
                    Ok(_) => st.metrics.record_ok(latency_ms, now),
                    Err(_) => st.metrics.record_failure(now),
                }
                update_breaker(shard, &mut st, r.is_ok());
            }
        }
        for (job, response) in jobs.into_iter().zip(responses) {
            complete(&job.ticket, response);
        }
    }
}

/// Fail jobs whose model was hot-removed while their batch was held
/// open: the straggler window must not delay the `ModelRemoved`
/// verdict by up to `max_wait_ms`.
fn fail_removed(shard: &Shard, jobs: Vec<Job>) {
    {
        let mut st = shard.state.lock().unwrap();
        st.in_flight -= jobs.len();
        let now = Instant::now();
        for _ in &jobs {
            st.metrics.record_failure(now);
        }
    }
    for job in jobs {
        complete(
            &job.ticket,
            Err(ServeError::ModelRemoved {
                model: shard.name.clone(),
            }),
        );
    }
}

fn worker_loop(shared: &Shared, slot: &WorkerSlot) {
    let faults = shared.resilience.faults.as_deref();
    loop {
        let shards: Vec<Arc<Shard>> = shared.shards.read().unwrap().clone();
        if let Some((shard, jobs, removed_mid_hold)) = try_pop(shared, &shards) {
            if removed_mid_hold {
                fail_removed(&shard, jobs);
            } else {
                let jobs = shed_expired(&shard, jobs);
                if jobs.is_empty() {
                    continue;
                }
                // Register with the watchdog (if any) for the unlocked
                // execution window, then clear the mailbox.
                let watch = shared.resilience.watchdog_ms.map(|_| {
                    Arc::new(InFlight {
                        shard: shard.clone(),
                        tickets: jobs.iter().map(|j| j.ticket.clone()).collect(),
                        started: Instant::now(),
                        abandoned: AtomicBool::new(false),
                        done: AtomicBool::new(false),
                    })
                });
                if let Some(w) = &watch {
                    *slot.current.lock().unwrap() = Some(w.clone());
                }
                execute(&shard, jobs, watch.as_deref(), faults);
                if watch.is_some() {
                    *slot.current.lock().unwrap() = None;
                }
            }
            continue;
        }
        // Nothing found. The doorbell decides atomically whether that
        // scan raced a submit (pending > 0 → rescan against a fresh
        // directory snapshot) or the service is really idle.
        let db = shared.doorbell.lock().unwrap();
        if db.pending > 0 {
            continue;
        }
        // Exit only when idle *and* shutting down: the drain
        // guarantee — every admitted ticket resolves.
        if db.shutting_down {
            return;
        }
        drop(shared.bell.wait(db).unwrap());
    }
}

/// The watchdog: scan every worker's mailbox and fail the in-flight
/// tickets of any worker stuck past `limit_ms`. The stuck worker's
/// later accounting is suppressed by the `abandoned` flag (checked
/// under the same shard lock this writes it under), so exactly one
/// side resolves each ticket.
fn watchdog_loop(shared: &Shared, limit_ms: u64) {
    let tick = Duration::from_millis((limit_ms / 4).clamp(1, 50));
    while !shared.watchdog_stop.load(Ordering::Acquire) {
        std::thread::sleep(tick);
        for slot in &shared.slots {
            let entry = slot.current.lock().unwrap().clone();
            let Some(entry) = entry else { continue };
            if entry.started.elapsed() < Duration::from_millis(limit_ms) {
                continue;
            }
            let stalled_ms = entry.started.elapsed().as_millis() as u64;
            {
                let mut st = entry.shard.state.lock().unwrap();
                if entry.abandoned.load(Ordering::Relaxed) || entry.done.load(Ordering::Relaxed) {
                    continue; // already settled by an earlier scan / the worker
                }
                entry.abandoned.store(true, Ordering::Relaxed);
                st.in_flight -= entry.tickets.len();
                let now = Instant::now();
                for _ in &entry.tickets {
                    st.metrics.record_failure(now);
                }
                update_breaker(&entry.shard, &mut st, false);
            }
            for ticket in &entry.tickets {
                complete(
                    ticket,
                    Err(ServeError::WorkerStalled {
                        model: entry.shard.name.clone(),
                        stalled_ms,
                    }),
                );
            }
        }
    }
}

/// Per-model configuration for [`ServiceBuilder::model`] and
/// [`InferenceService::add_model`]: a [`crate::model::ModelSpec`]
/// string plus optional engine overrides (backend, precision, mesh,
/// seed, datapath threads) and a per-model queue depth.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    spec: String,
    backend: Option<BackendKind>,
    precision: Option<Precision>,
    mesh: Option<(usize, usize)>,
    sub_mesh: Option<crate::video::SubMesh>,
    seed: Option<u64>,
    threads: Option<usize>,
    queue_depth: Option<usize>,
    max_batch: Option<usize>,
    batch_wait_ms: Option<u64>,
}

impl ModelConfig {
    /// Configuration for the model named by `spec`
    /// (`resnet34@512x1024`, `manifest:artifacts#hypernet20`, …).
    pub fn new(spec: impl Into<String>) -> ModelConfig {
        ModelConfig {
            spec: spec.into(),
            backend: None,
            precision: None,
            mesh: None,
            sub_mesh: None,
            seed: None,
            threads: None,
            queue_depth: None,
            max_batch: None,
            batch_wait_ms: None,
        }
    }

    /// Force a backend for this model (like
    /// [`crate::engine::EngineBuilder::backend`]).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = Some(kind);
        self
    }

    /// Datapath precision override for this model.
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = Some(p);
        self
    }

    /// Run this model on an explicit `rows×cols` systolic mesh.
    pub fn mesh(mut self, rows: usize, cols: usize) -> Self {
        self.mesh = Some((rows, cols));
        self
    }

    /// Run this model on its [`crate::video::MeshPlacement`]-assigned
    /// slice of a shared chip pool: forces the mesh backend on the
    /// sub-mesh's `rows×cols` shape. The anchor coordinates matter only
    /// to the pool owner (chips are identical and the placement layer
    /// guarantees disjoint ownership); the engine sees a standalone
    /// `rows×cols` mesh.
    pub fn sub_mesh(mut self, sm: crate::video::SubMesh) -> Self {
        self.sub_mesh = Some(sm);
        self.backend = Some(BackendKind::Mesh);
        self.mesh = Some((sm.rows, sm.cols));
        self
    }

    /// The pool slice assigned via [`Self::sub_mesh`], if any — lets a
    /// serving frontend reconcile per-model metrics with the pool's
    /// ownership diagram.
    pub fn assigned_sub_mesh(&self) -> Option<crate::video::SubMesh> {
        self.sub_mesh
    }

    /// Seed for this model's lazily-generated synthetic parameters.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Datapath worker threads *per inference* of this model (distinct
    /// from the service's request-level worker budget).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Per-model queue depth, overriding the service default. Zero is
    /// a typed build error, not a silent clamp.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = Some(depth);
        self
    }

    /// Most queued requests one batch-resident pass may coalesce for
    /// this model (overrides the service default; see [`BatchPolicy`]).
    /// Zero is a typed build error.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = Some(n);
        self
    }

    /// How long a short batch of this model may hold for stragglers
    /// (overrides the service default; see [`BatchPolicy`]).
    pub fn batch_wait_ms(mut self, ms: u64) -> Self {
        self.batch_wait_ms = Some(ms);
        self
    }

    /// The model's effective batch policy over the service defaults.
    fn batch_policy(&self, default: BatchPolicy) -> BatchPolicy {
        BatchPolicy {
            max_batch: self.max_batch.unwrap_or(default.max_batch),
            max_wait_ms: self.batch_wait_ms.unwrap_or(default.max_wait_ms),
        }
    }

    fn build_engine(&self, registry: &NetworkRegistry) -> Result<Engine, EngineError> {
        let mut b = Engine::builder()
            .model(self.spec.as_str())
            .registry(registry.clone());
        if let Some(kind) = self.backend {
            b = b.backend(kind);
        }
        if let Some(p) = self.precision {
            b = b.precision(p);
        }
        if let Some((rows, cols)) = self.mesh {
            b = b.mesh(rows, cols);
        }
        if let Some(seed) = self.seed {
            b = b.seed(seed);
        }
        if let Some(n) = self.threads {
            b = b.threads(n);
        }
        b.build()
    }
}

enum PendingModel {
    Config(ModelConfig),
    Prebuilt {
        backend: Arc<dyn Backend>,
        input_len: usize,
        total_ops: u64,
        weight_bytes: u64,
    },
}

/// Fluent constructor for [`InferenceService`]; see the
/// [module docs](self).
pub struct ServiceBuilder {
    registry: Option<NetworkRegistry>,
    models: Vec<(String, PendingModel)>,
    workers: usize,
    queue_depth: usize,
    admission: AdmissionPolicy,
    batch: BatchPolicy,
    resilience: ResilienceConfig,
}

impl Default for ServiceBuilder {
    fn default() -> Self {
        ServiceBuilder {
            registry: None,
            models: Vec::new(),
            workers: 2,
            queue_depth: 8,
            admission: AdmissionPolicy::Block,
            batch: BatchPolicy::default(),
            resilience: ResilienceConfig::default(),
        }
    }
}

impl ServiceBuilder {
    /// Resolve model specs against a custom registry instead of
    /// [`NetworkRegistry::builtin`] (also used by hot
    /// [`InferenceService::add_model`] calls).
    pub fn registry(mut self, registry: NetworkRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Host a model under `name` with per-model configuration.
    pub fn model(mut self, name: impl Into<String>, config: ModelConfig) -> Self {
        self.models.push((name.into(), PendingModel::Config(config)));
        self
    }

    /// Host a model named by its spec string (name == spec).
    pub fn model_spec(self, spec: impl Into<String>) -> Self {
        let spec = spec.into();
        let config = ModelConfig::new(spec.clone());
        self.model(spec, config)
    }

    /// Host a pre-built [`Engine`] under `name` (shares the engine's
    /// backend; the engine itself stays usable). This is how manifest/
    /// PJRT engines or engines with explicit parameters enter a
    /// service.
    pub fn engine(mut self, name: impl Into<String>, engine: &Engine) -> Self {
        self.models.push((
            name.into(),
            PendingModel::Prebuilt {
                backend: engine.shared_backend(),
                input_len: engine.input_len(),
                total_ops: engine.network().total_ops(),
                weight_bytes: engine.resident_weight_bytes(),
            },
        ));
        self
    }

    /// Total worker threads shared by every hosted model (the service's
    /// thread budget). Zero is a typed error at `build()`.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Default per-model queue depth (overridable per model via
    /// [`ModelConfig::queue_depth`]). Zero is a typed error at
    /// `build()`.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    /// What a full queue does to the next submission (default:
    /// [`AdmissionPolicy::Block`]).
    pub fn admission(mut self, policy: AdmissionPolicy) -> Self {
        self.admission = policy;
        self
    }

    /// Default per-model batch cap: most queued requests one
    /// batch-resident pass coalesces (default 1 — no batching;
    /// overridable per model via [`ModelConfig::max_batch`]). Zero is
    /// a typed error at `build()`.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.batch.max_batch = n;
        self
    }

    /// Default straggler hold: how long a short batch keeps its queue
    /// slot open waiting for more same-model requests (default 0 — run
    /// with whatever is queued; overridable per model via
    /// [`ModelConfig::batch_wait_ms`]).
    pub fn batch_wait_ms(mut self, ms: u64) -> Self {
        self.batch.max_wait_ms = ms;
        self
    }

    /// Default per-request deadline for requests that carry none
    /// (default: no deadline). Jobs still queued when it expires are
    /// shed with [`ServeError::DeadlineExceeded`].
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.resilience.deadline_ms = Some(ms);
        self
    }

    /// Enable the per-model Healthy/Degraded/Open circuit breaker with
    /// these thresholds (default: no breaker).
    pub fn breaker(mut self, policy: BreakerPolicy) -> Self {
        self.resilience.breaker = Some(policy);
        self
    }

    /// Enable the watchdog: a worker executing one batch for longer
    /// than `ms` has its in-flight tickets failed with
    /// [`ServeError::WorkerStalled`], and `shutdown()` detaches it
    /// instead of hanging on its join (default: no watchdog).
    pub fn watchdog_ms(mut self, ms: u64) -> Self {
        self.resilience.watchdog_ms = Some(ms);
        self
    }

    /// Inject faults from a seeded chaos plan (worker stalls and slow
    /// batches, keyed by request id). Default: none.
    pub fn faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.resilience.faults = Some(plan);
        self
    }

    /// Validate, build every model's engine, spawn the worker pool.
    pub fn build(self) -> Result<InferenceService, EngineError> {
        if self.workers == 0 {
            return Err(EngineError::Builder(
                ".workers(0) is invalid — the service thread budget must be ≥ 1".into(),
            ));
        }
        if self.queue_depth == 0 {
            return Err(EngineError::Builder(
                ".queue_depth(0) is invalid — admission needs at least one queue slot".into(),
            ));
        }
        if self.batch.max_batch == 0 {
            return Err(EngineError::Builder(
                ".max_batch(0) is invalid — a batch pass needs at least one image".into(),
            ));
        }
        if self.models.is_empty() {
            return Err(EngineError::Builder(
                "a service needs at least one .model(..) / .model_spec(..) / .engine(..)".into(),
            ));
        }
        for (i, (name, _)) in self.models.iter().enumerate() {
            if self.models[..i].iter().any(|(n, _)| n == name) {
                return Err(EngineError::Builder(format!(
                    "model `{name}` is registered twice — service names must be unique"
                )));
            }
        }
        let registry = self.registry.unwrap_or_else(NetworkRegistry::builtin);
        let mut shards = Vec::with_capacity(self.models.len());
        for (name, pending) in self.models {
            let (backend, input_len, total_ops, weight_bytes, depth_override, batch) = match pending
            {
                PendingModel::Config(config) => {
                    if config.queue_depth == Some(0) {
                        return Err(EngineError::Builder(format!(
                            "model `{name}`: queue_depth(0) is invalid"
                        )));
                    }
                    if config.max_batch == Some(0) {
                        return Err(EngineError::Builder(format!(
                            "model `{name}`: max_batch(0) is invalid"
                        )));
                    }
                    let depth = config.queue_depth;
                    let batch = config.batch_policy(self.batch);
                    let engine = config.build_engine(&registry)?;
                    (
                        engine.shared_backend(),
                        engine.input_len(),
                        engine.network().total_ops(),
                        engine.resident_weight_bytes(),
                        depth,
                        batch,
                    )
                }
                PendingModel::Prebuilt {
                    backend,
                    input_len,
                    total_ops,
                    weight_bytes,
                } => (backend, input_len, total_ops, weight_bytes, None, self.batch),
            };
            shards.push(Shard::new(
                name,
                backend,
                input_len,
                total_ops,
                weight_bytes,
                depth_override.unwrap_or(self.queue_depth),
                batch,
                self.resilience.breaker,
            ));
        }
        Ok(InferenceService::start(
            shards,
            self.workers,
            self.queue_depth,
            self.admission,
            self.batch,
            registry,
            self.resilience,
        ))
    }
}

/// A running multi-model serving instance; see the
/// [module docs](self).
pub struct InferenceService {
    shared: Arc<Shared>,
    registry: NetworkRegistry,
    admission: AdmissionPolicy,
    default_depth: usize,
    default_batch: BatchPolicy,
    worker_count: usize,
    threads: Vec<JoinHandle<()>>,
    watchdog: Option<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl InferenceService {
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::default()
    }

    /// Internal: a single-model service over a raw backend — the
    /// engine-room of the [`Engine::serve`](super::Engine::serve)
    /// compatibility wrapper and of the in-crate pool tests.
    pub(crate) fn single(
        name: &str,
        backend: Arc<dyn Backend>,
        input_len: usize,
        total_ops: u64,
        weight_bytes: u64,
        workers: usize,
        queue_depth: usize,
        admission: AdmissionPolicy,
    ) -> InferenceService {
        debug_assert!(workers >= 1 && queue_depth >= 1, "callers validate the knobs");
        let shard = Shard::new(
            name.to_string(),
            backend,
            input_len,
            total_ops,
            weight_bytes,
            queue_depth,
            BatchPolicy::default(),
            None,
        );
        InferenceService::start(
            vec![shard],
            workers,
            queue_depth,
            admission,
            BatchPolicy::default(),
            NetworkRegistry::empty(),
            ResilienceConfig::default(),
        )
    }

    fn start(
        shards: Vec<Shard>,
        workers: usize,
        default_depth: usize,
        admission: AdmissionPolicy,
        default_batch: BatchPolicy,
        registry: NetworkRegistry,
        resilience: ResilienceConfig,
    ) -> InferenceService {
        let watchdog_ms = resilience.watchdog_ms;
        let shared = Arc::new(Shared {
            shards: RwLock::new(shards.into_iter().map(Arc::new).collect()),
            doorbell: Mutex::new(DoorbellState {
                pending: 0,
                shutting_down: false,
            }),
            bell: Condvar::new(),
            rr: AtomicUsize::new(0),
            shutting: AtomicBool::new(false),
            slots: (0..workers).map(|_| Arc::new(WorkerSlot::default())).collect(),
            resilience,
            watchdog_stop: AtomicBool::new(false),
        });
        let threads = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::spawn(move || {
                    let slot = shared.slots[i].clone();
                    worker_loop(&shared, &slot)
                })
            })
            .collect();
        let watchdog = watchdog_ms.map(|ms| {
            let shared = shared.clone();
            std::thread::spawn(move || watchdog_loop(&shared, ms))
        });
        InferenceService {
            shared,
            registry,
            admission,
            default_depth,
            default_batch,
            worker_count: workers,
            threads,
            watchdog,
            next_id: AtomicU64::new(0),
        }
    }

    /// Names of the currently-hosted models, in registration order.
    pub fn models(&self) -> Vec<String> {
        let shards = self.shared.shards.read().unwrap();
        shards
            .iter()
            .filter(|s| !s.removed_hint.load(Ordering::Acquire))
            .map(|s| s.name.clone())
            .collect()
    }

    /// Flattened input length a hosted model expects.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        let shards = self.shared.shards.read().unwrap();
        shards
            .iter()
            .find(|s| !s.removed_hint.load(Ordering::Acquire) && s.name == model)
            .map(|s| s.input_len)
    }

    /// The service's worker-thread budget.
    pub fn worker_count(&self) -> usize {
        self.worker_count
    }

    /// Submit one request; returns a [`Ticket`] on admission, or a
    /// typed error (unknown model, bad input length, queue full /
    /// admission timeout, shutting down) that is scoped to this
    /// request alone. Only this model's lock is touched — submissions
    /// to different models never contend.
    pub fn submit(&self, request: InferRequest) -> Result<Ticket, ServeError> {
        let InferRequest {
            model,
            input,
            id,
            deadline_ms,
        } = request;
        let start = Instant::now();
        if self.shared.shutting.load(Ordering::Acquire) {
            return Err(ServeError::ShuttingDown);
        }
        let shard = self.shared.find(&model)?;
        if input.len() != shard.input_len {
            return Err(ServeError::BadInput {
                model,
                got: input.len(),
                want: shard.input_len,
            });
        }
        let deadline = deadline_ms
            .or(self.shared.resilience.deadline_ms)
            .map(|ms| (start + Duration::from_millis(ms), ms));
        let mut st = shard.state.lock().unwrap();
        let mut counted_full = false;
        loop {
            if st.removed {
                return Err(ServeError::ModelRemoved { model });
            }
            if st.draining {
                return Err(ServeError::ShuttingDown);
            }
            // Circuit-breaker gate: an Open shard sheds load at the
            // door. Once the cooldown elapses it admits exactly one
            // half-open probe — the probe's outcome decides whether
            // the breaker re-trips or the shard recovers.
            if st.breaker == BreakerState::Open {
                let pol = shard.breaker.expect("Open breaker implies a policy");
                let cooled = st
                    .breaker_opened_at
                    .is_some_and(|at| at.elapsed() >= Duration::from_millis(pol.cooldown_ms));
                if cooled {
                    st.breaker = BreakerState::Degraded;
                    st.consec_failures = pol.consecutive_failures.saturating_sub(1);
                } else {
                    return Err(ServeError::BreakerOpen { model });
                }
            }
            if st.queue.len() < shard.queue_depth {
                // Admission gate: the doorbell decides atomically
                // whether the service still accepts, and counts this
                // job before it becomes visible — a worker can then
                // never conclude "idle" while an admitted job exists.
                {
                    let mut db = self.shared.doorbell.lock().unwrap();
                    if db.shutting_down {
                        return Err(ServeError::ShuttingDown);
                    }
                    db.pending += 1;
                }
                let ticket = Arc::new(TicketShared {
                    slot: Mutex::new(None),
                    cv: Condvar::new(),
                });
                st.metrics.record_submit(Instant::now());
                st.queue.push_back(Job {
                    id,
                    input,
                    ticket: ticket.clone(),
                    deadline,
                });
                drop(st);
                self.shared.bell.notify_all();
                // A worker holding a short batch of this model open
                // for stragglers must observe the arrival.
                shard.arrivals.notify_all();
                return Ok(Ticket {
                    id,
                    model,
                    shared: ticket,
                });
            }
            if !counted_full {
                st.metrics.record_queue_full();
                counted_full = true;
            }
            match self.admission {
                AdmissionPolicy::Reject => {
                    st.metrics.record_rejected(input.len());
                    return Err(ServeError::QueueFull {
                        depth: shard.queue_depth,
                        model,
                    });
                }
                AdmissionPolicy::Block => {
                    st = shard.space.wait(st).unwrap();
                }
                AdmissionPolicy::Timeout(ms) => {
                    let waited = start.elapsed();
                    let budget = Duration::from_millis(ms);
                    if waited >= budget {
                        st.metrics.record_rejected(input.len());
                        return Err(ServeError::AdmissionTimeout {
                            model,
                            waited_ms: waited.as_millis() as u64,
                        });
                    }
                    let (guard, _) = shard.space.wait_timeout(st, budget - waited).unwrap();
                    st = guard;
                }
            }
        }
    }

    /// Submit-and-wait convenience with an auto-assigned id.
    pub fn infer(
        &self,
        model: &str,
        input: impl Into<Arc<[f32]>>,
    ) -> Result<Vec<f32>, ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let ticket = self.submit(InferRequest {
            model: model.to_string(),
            input: input.into(),
            id,
            deadline_ms: None,
        })?;
        Ok(ticket.wait()?.output)
    }

    /// Hot-add a model while the service keeps serving. The engine is
    /// built outside every service lock (construction can be slow);
    /// the name must not collide with a hosted model.
    pub fn add_model(
        &self,
        name: impl Into<String>,
        config: ModelConfig,
    ) -> Result<(), EngineError> {
        let name = name.into();
        if config.queue_depth == Some(0) {
            return Err(EngineError::Builder(format!(
                "model `{name}`: queue_depth(0) is invalid"
            )));
        }
        if config.max_batch == Some(0) {
            return Err(EngineError::Builder(format!(
                "model `{name}`: max_batch(0) is invalid"
            )));
        }
        let engine = config.build_engine(&self.registry)?;
        let shard = Shard::new(
            name.clone(),
            engine.shared_backend(),
            engine.input_len(),
            engine.network().total_ops(),
            engine.resident_weight_bytes(),
            config.queue_depth.unwrap_or(self.default_depth),
            config.batch_policy(self.default_batch),
            self.shared.resilience.breaker,
        );
        let mut shards = self.shared.shards.write().unwrap();
        {
            let db = self.shared.doorbell.lock().unwrap();
            if db.shutting_down {
                return Err(EngineError::Builder(
                    "cannot add a model: the service is shutting down".into(),
                ));
            }
        }
        if shards
            .iter()
            .any(|s| !s.removed_hint.load(Ordering::Acquire) && s.name == name)
        {
            return Err(EngineError::Builder(format!(
                "model `{name}` is already registered"
            )));
        }
        shards.push(Arc::new(shard));
        Ok(())
    }

    /// Hot-remove a model: new submissions get
    /// [`ServeError::ModelRemoved`], pending (unstarted) requests are
    /// drained with the same error, a worker holding a batch open for
    /// stragglers wakes immediately and fails the held jobs the same
    /// way, in-flight (executing) requests complete normally, and the
    /// model's metrics row survives (flagged `removed`).
    pub fn remove_model(&self, model: &str) -> Result<(), ServeError> {
        let shard = self.shared.find(model)?;
        let drained: Vec<Job> = {
            let mut st = shard.state.lock().unwrap();
            if st.removed {
                // Raced another remove_model between find and lock.
                return Err(ServeError::ModelRemoved {
                    model: model.to_string(),
                });
            }
            st.removed = true;
            shard.removed_hint.store(true, Ordering::Release);
            let jobs: Vec<Job> = st.queue.drain(..).collect();
            if !jobs.is_empty() {
                self.shared.dec_pending(jobs.len() as u64);
            }
            let now = Instant::now();
            for _ in &jobs {
                st.metrics.record_failure(now);
            }
            jobs
        };
        for job in drained {
            complete(
                &job.ticket,
                Err(ServeError::ModelRemoved {
                    model: model.to_string(),
                }),
            );
        }
        // Blocked submitters observe the removal; a worker holding a
        // short batch open observes it mid-hold instead of sleeping
        // out its straggler window.
        shard.space.notify_all();
        shard.arrivals.notify_all();
        Ok(())
    }

    /// A [`ServiceMetrics`] snapshot. Each model's row is internally
    /// consistent (taken under that shard's lock); rows of different
    /// models are captured one after another.
    pub fn metrics(&self) -> ServiceMetrics {
        let shards: Vec<Arc<Shard>> = self.shared.shards.read().unwrap().clone();
        ServiceMetrics {
            workers: self.worker_count,
            per_model: shards
                .iter()
                .map(|s| {
                    let st = s.state.lock().unwrap();
                    st.metrics.snapshot(
                        &s.name,
                        st.removed,
                        st.queue.len(),
                        st.in_flight,
                        s.total_ops,
                        s.weight_bytes,
                        st.breaker,
                    )
                })
                .collect(),
        }
    }

    /// Record a client-reported retry against a model's metrics row.
    /// The wire server calls this when an `Infer` frame arrives with
    /// `attempt > 0` — the retry happened on the client, but the
    /// server-side table is where operators look.
    pub fn note_retry(&self, model: &str) {
        if let Ok(shard) = self.shared.find(model) {
            shard.state.lock().unwrap().metrics.record_retry();
        }
    }

    /// Counters of every fault the service's chaos plan has injected
    /// so far (all zeros when no plan is installed).
    pub fn fault_counters(&self) -> crate::faults::FaultCounters {
        self.shared
            .resilience
            .faults
            .as_ref()
            .map(|p| p.counters())
            .unwrap_or_default()
    }

    /// Graceful shutdown: stop admission, drain every queue (every
    /// admitted ticket resolves), join the workers, return the final
    /// metrics. Dropping the service does the same minus the return
    /// value.
    pub fn shutdown(mut self) -> ServiceMetrics {
        self.stop_and_join();
        self.metrics()
    }

    fn stop_and_join(&mut self) {
        {
            let mut db = self.shared.doorbell.lock().unwrap();
            db.shutting_down = true;
        }
        self.shared.shutting.store(true, Ordering::Release);
        let shards: Vec<Arc<Shard>> = self.shared.shards.read().unwrap().clone();
        for shard in &shards {
            // `draining` is written under the shard mutex its waiters
            // hold, so neither a blocked submitter nor a batch-holding
            // worker can miss the wakeup.
            shard.state.lock().unwrap().draining = true;
            shard.space.notify_all();
            shard.arrivals.notify_all();
        }
        self.shared.bell.notify_all();
        // Join workers, but never hang on one the watchdog has marked
        // abandoned (stalled past its limit): such a worker's tickets
        // were already failed with `WorkerStalled`, so it is detached
        // instead of joined. Without a watchdog every worker is joined
        // unconditionally (identical to pre-resilience behaviour).
        let mut handles: Vec<(usize, JoinHandle<()>)> =
            self.threads.drain(..).enumerate().collect();
        let mut detached = false;
        if self.shared.resilience.watchdog_ms.is_none() {
            for (_, handle) in handles.drain(..) {
                let _ = handle.join();
            }
        } else {
            while !handles.is_empty() {
                let mut remaining = Vec::with_capacity(handles.len());
                for (i, handle) in handles {
                    if handle.is_finished() {
                        let _ = handle.join();
                        continue;
                    }
                    let stuck = self.shared.slots.get(i).is_some_and(|slot| {
                        slot.current
                            .lock()
                            .unwrap()
                            .as_ref()
                            .is_some_and(|inf| inf.abandoned.load(Ordering::Acquire))
                    });
                    if stuck {
                        // Leak the thread: its jobs are resolved, its
                        // backend call may never return.
                        detached = true;
                        continue;
                    }
                    remaining.push((i, handle));
                }
                handles = remaining;
                if !handles.is_empty() {
                    self.shared.bell.notify_all();
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        self.shared.watchdog_stop.store(true, Ordering::Release);
        if let Some(wd) = self.watchdog.take() {
            let _ = wd.join();
        }
        if detached {
            // A detached worker cannot drain what it never popped.
            // Sweep every shard so each admitted ticket still
            // resolves (the shutdown drain guarantee).
            for shard in &shards {
                let leftovers: Vec<Job> = {
                    let mut st = shard.state.lock().unwrap();
                    let jobs: Vec<Job> = st.queue.drain(..).collect();
                    if !jobs.is_empty() {
                        self.shared.dec_pending(jobs.len() as u64);
                        let now = Instant::now();
                        for _ in &jobs {
                            st.metrics.record_failure(now);
                        }
                    }
                    jobs
                };
                for job in leftovers {
                    complete(&job.ticket, Err(ServeError::ShuttingDown));
                }
            }
        }
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::{BatchRun, LayerTrace};
    use super::*;

    /// Trivial backend: doubles its input.
    struct Doubler;

    impl Backend for Doubler {
        fn kind(&self) -> BackendKind {
            BackendKind::Functional
        }

        fn infer_traced(
            &self,
            input: &[f32],
            hook: &mut dyn FnMut(LayerTrace<'_>),
        ) -> Result<Vec<f32>, EngineError> {
            let out: Vec<f32> = input.iter().map(|x| 2.0 * x).collect();
            hook(LayerTrace {
                step: 0,
                layer: "double",
                shape: (1, 1, out.len()),
                output: &out,
            });
            Ok(out)
        }
    }

    /// Backend whose inferences block until the gate opens — makes
    /// queue-occupancy tests deterministic instead of racing a worker.
    struct Gated {
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl Gated {
        fn new() -> (Gated, Arc<(Mutex<bool>, Condvar)>) {
            let gate = Arc::new((Mutex::new(false), Condvar::new()));
            (Gated { gate: gate.clone() }, gate)
        }
    }

    fn open(gate: &Arc<(Mutex<bool>, Condvar)>) {
        *gate.0.lock().unwrap() = true;
        gate.1.notify_all();
    }

    impl Backend for Gated {
        fn kind(&self) -> BackendKind {
            BackendKind::Functional
        }

        fn infer_traced(
            &self,
            input: &[f32],
            _hook: &mut dyn FnMut(LayerTrace<'_>),
        ) -> Result<Vec<f32>, EngineError> {
            let mut opened = self.gate.0.lock().unwrap();
            while !*opened {
                opened = self.gate.1.wait(opened).unwrap();
            }
            Ok(input.to_vec())
        }
    }

    fn wait_until(mut pred: impl FnMut() -> bool) {
        for _ in 0..2000 {
            if pred() {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("condition not reached within 2 s");
    }

    fn single_doubler(workers: usize, depth: usize, admission: AdmissionPolicy) -> InferenceService {
        InferenceService::single("d", Arc::new(Doubler), 1, 10, 0, workers, depth, admission)
    }

    #[test]
    fn tickets_resolve_to_their_own_request() {
        let svc = single_doubler(4, 3, AdmissionPolicy::Block);
        let tickets: Vec<Ticket> = (0..32)
            .map(|i| {
                svc.submit(InferRequest {
                    model: "d".into(),
                    input: vec![i as f32].into(),
                    id: i,
                    deadline_ms: None,
                })
                .unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.id(), i as u64);
            let resp = t.wait().unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.model, "d");
            assert_eq!(resp.output, vec![2.0 * i as f32], "request {i}");
            assert!(resp.latency_ms >= 0.0);
        }
        let m = svc.shutdown();
        assert_eq!(m.total_submitted(), 32);
        assert_eq!(m.total_completed(), 32);
        assert_eq!(m.total_failed(), 0);
    }

    #[test]
    fn submit_errors_are_per_request() {
        let svc = single_doubler(1, 2, AdmissionPolicy::Block);
        match svc
            .submit(InferRequest {
                model: "nope".into(),
                input: vec![0.0].into(),
                id: 0,
                deadline_ms: None,
            })
            .unwrap_err()
        {
            ServeError::UnknownModel { model, known } => {
                assert_eq!(model, "nope");
                assert_eq!(known, vec!["d".to_string()]);
            }
            other => panic!("expected UnknownModel, got {other}"),
        }
        match svc
            .submit(InferRequest {
                model: "d".into(),
                input: vec![0.0; 7].into(),
                id: 0,
                deadline_ms: None,
            })
            .unwrap_err()
        {
            ServeError::BadInput { got, want, .. } => {
                assert_eq!((got, want), (7, 1));
            }
            other => panic!("expected BadInput, got {other}"),
        }
        // A rejected submission is not counted as submitted.
        assert_eq!(svc.shutdown().total_submitted(), 0);
    }

    #[test]
    fn reject_policy_returns_queue_full() {
        let (gated, gate) = Gated::new();
        let svc = InferenceService::single(
            "g",
            Arc::new(gated),
            1,
            1,
            0,
            1,
            1,
            AdmissionPolicy::Reject,
        );
        let t1 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![1.0].into(),
                id: 1,
                deadline_ms: None,
            })
            .unwrap();
        // Wait until the worker holds request 1 (queue empty again).
        wait_until(|| svc.metrics().model("g").unwrap().in_flight == 1);
        let t2 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![2.0].into(),
                id: 2,
                deadline_ms: None,
            })
            .unwrap();
        // Queue (depth 1) now holds request 2 → request 3 is rejected.
        let err = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![3.0].into(),
                id: 3,
                deadline_ms: None,
            })
            .unwrap_err();
        assert!(
            matches!(err, ServeError::QueueFull { depth: 1, .. }),
            "{err}"
        );
        open(&gate);
        assert_eq!(t1.wait().unwrap().output, vec![1.0]);
        assert_eq!(t2.wait().unwrap().output, vec![2.0]);
        let m = svc.shutdown();
        assert_eq!(m.total_submitted(), 2);
        assert_eq!(m.total_completed(), 2);
        // The rejection left a telemetry trail: one queue-full event,
        // one shed request, 4 bytes of shed payload (one f32).
        let g = m.model("g").unwrap();
        assert_eq!(g.rejected_backpressure, 1);
        assert_eq!(g.shed_bytes, 4);
        assert!(g.queue_full_events >= 1);
        assert_eq!(m.total_rejected_backpressure(), 1);
        assert_eq!(m.total_shed_bytes(), 4);
    }

    #[test]
    fn timeout_policy_gives_up_after_the_budget() {
        let (gated, gate) = Gated::new();
        let svc = InferenceService::single(
            "g",
            Arc::new(gated),
            1,
            1,
            0,
            1,
            1,
            AdmissionPolicy::Timeout(40),
        );
        let t1 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![1.0].into(),
                id: 1,
                deadline_ms: None,
            })
            .unwrap();
        wait_until(|| svc.metrics().model("g").unwrap().in_flight == 1);
        let t2 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![2.0].into(),
                id: 2,
                deadline_ms: None,
            })
            .unwrap();
        let t0 = Instant::now();
        let err = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![3.0].into(),
                id: 3,
                deadline_ms: None,
            })
            .unwrap_err();
        assert!(
            matches!(err, ServeError::AdmissionTimeout { .. }),
            "{err}"
        );
        assert!(
            t0.elapsed() >= Duration::from_millis(35),
            "returned after {:?}",
            t0.elapsed()
        );
        open(&gate);
        assert!(t1.wait().is_ok() && t2.wait().is_ok());
    }

    #[test]
    fn block_policy_applies_backpressure_then_admits() {
        let (gated, gate) = Gated::new();
        let svc = InferenceService::single(
            "g",
            Arc::new(gated),
            1,
            1,
            0,
            1,
            1,
            AdmissionPolicy::Block,
        );
        let t1 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![1.0].into(),
                id: 1,
                deadline_ms: None,
            })
            .unwrap();
        wait_until(|| svc.metrics().model("g").unwrap().in_flight == 1);
        let t2 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![2.0].into(),
                id: 2,
                deadline_ms: None,
            })
            .unwrap();
        // Open the gate from a helper thread while the main thread is
        // blocked in submit (queue full until the worker pops #2).
        let opener = {
            let gate = gate.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                open(&gate);
            })
        };
        let t0 = Instant::now();
        let t3 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![3.0].into(),
                id: 3,
                deadline_ms: None,
            })
            .unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(10),
            "submit should have blocked, returned after {:?}",
            t0.elapsed()
        );
        opener.join().unwrap();
        for (t, v) in [(t1, 1.0), (t2, 2.0), (t3, 3.0)] {
            assert_eq!(t.wait().unwrap().output, vec![v]);
        }
    }

    #[test]
    fn shutdown_drains_every_admitted_ticket() {
        let (gated, gate) = Gated::new();
        let svc = InferenceService::single(
            "g",
            Arc::new(gated),
            1,
            1,
            0,
            2,
            8,
            AdmissionPolicy::Block,
        );
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| {
                svc.submit(InferRequest {
                    model: "g".into(),
                    input: vec![i as f32].into(),
                    id: i,
                    deadline_ms: None,
                })
                .unwrap()
            })
            .collect();
        open(&gate);
        let m = svc.shutdown();
        assert_eq!(m.total_completed(), 6);
        assert_eq!(m.model("g").unwrap().queued, 0);
        for (i, t) in tickets.into_iter().enumerate() {
            // After the drain every ticket is resolved: the poll is
            // non-destructive and the consuming claim succeeds.
            assert!(t.is_ready());
            match t.try_wait() {
                Ok(result) => assert_eq!(result.unwrap().output, vec![i as f32]),
                Err(_) => panic!("ticket {i} was ready"),
            }
        }
    }

    #[test]
    fn remove_model_drains_pending_and_completes_in_flight() {
        let (gated, gate) = Gated::new();
        let svc = InferenceService::single(
            "g",
            Arc::new(gated),
            1,
            1,
            0,
            1,
            8,
            AdmissionPolicy::Block,
        );
        let t1 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![1.0].into(),
                id: 1,
                deadline_ms: None,
            })
            .unwrap();
        wait_until(|| svc.metrics().model("g").unwrap().in_flight == 1);
        let t2 = svc
            .submit(InferRequest {
                model: "g".into(),
                input: vec![2.0].into(),
                id: 2,
                deadline_ms: None,
            })
            .unwrap();
        svc.remove_model("g").unwrap();
        // Pending request 2 drains with ModelRemoved…
        assert!(matches!(
            t2.wait().unwrap_err(),
            ServeError::ModelRemoved { .. }
        ));
        // …new submissions are rejected…
        assert!(matches!(
            svc.submit(InferRequest {
                model: "g".into(),
                input: vec![4.0].into(),
                id: 4,
                deadline_ms: None,
            })
            .unwrap_err(),
            ServeError::ModelRemoved { .. }
        ));
        assert!(svc.models().is_empty());
        // …and the in-flight request still completes.
        open(&gate);
        assert_eq!(t1.wait().unwrap().output, vec![1.0]);
        // Double remove is a typed error too.
        assert!(matches!(
            svc.remove_model("g").unwrap_err(),
            ServeError::ModelRemoved { .. }
        ));
        let m = svc.shutdown();
        let g = m.model("g").unwrap();
        assert!(g.removed);
        assert_eq!((g.submitted, g.completed, g.failed), (2, 1, 1));
    }

    #[test]
    fn round_robin_interleaves_models() {
        // One worker, both queues loaded: round-robin must alternate
        // rather than draining one model first.
        let (gated_a, gate) = Gated::new();
        let gated_b = Gated { gate: gated_a.gate.clone() };
        let order: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

        struct Recorder {
            inner: Gated,
            name: &'static str,
            order: Arc<Mutex<Vec<String>>>,
        }
        impl Backend for Recorder {
            fn kind(&self) -> BackendKind {
                BackendKind::Functional
            }
            fn infer_traced(
                &self,
                input: &[f32],
                hook: &mut dyn FnMut(LayerTrace<'_>),
            ) -> Result<Vec<f32>, EngineError> {
                self.order.lock().unwrap().push(self.name.to_string());
                self.inner.infer_traced(input, hook)
            }
        }

        let mut shards = Vec::new();
        for (name, gated) in [("a", gated_a), ("b", gated_b)] {
            shards.push(Shard::new(
                name.to_string(),
                Arc::new(Recorder {
                    inner: gated,
                    name,
                    order: order.clone(),
                }),
                1,
                1,
                0,
                8,
                BatchPolicy::default(),
                None,
            ));
        }
        let svc = InferenceService::start(
            shards,
            1,
            8,
            AdmissionPolicy::Block,
            BatchPolicy::default(),
            NetworkRegistry::empty(),
            ResilienceConfig::default(),
        );
        // Gate closed: load 3 requests per model before any executes…
        // (the first pop may already have happened; the recorder logs
        // execution order, which is what round-robin is about).
        let mut tickets = Vec::new();
        for i in 0..3u64 {
            for model in ["a", "b"] {
                tickets.push(
                    svc.submit(InferRequest {
                        model: model.into(),
                        input: vec![i as f32].into(),
                        id: i,
                        deadline_ms: None,
                    })
                    .unwrap(),
                );
            }
        }
        open(&gate);
        for t in tickets {
            t.wait().unwrap();
        }
        svc.shutdown();
        let order = order.lock().unwrap();
        // Strict alternation from the second execution on: with both
        // queues non-empty a model never runs twice in a row.
        for pair in order.windows(2).skip(1).take(3) {
            assert_ne!(pair[0], pair[1], "round-robin violated: {order:?}");
        }
    }

    /// Identity backend whose batch pass reports synthetic stream
    /// counters — lets the batching test assert the metrics wiring
    /// without a real simulator underneath.
    struct BatchCounting;

    impl Backend for BatchCounting {
        fn kind(&self) -> BackendKind {
            BackendKind::Functional
        }

        fn infer_traced(
            &self,
            input: &[f32],
            _hook: &mut dyn FnMut(LayerTrace<'_>),
        ) -> Result<Vec<f32>, EngineError> {
            Ok(input.to_vec())
        }

        fn infer_batch(&self, inputs: &[&[f32]]) -> BatchRun {
            BatchRun {
                outputs: inputs.iter().map(|i| Ok(i.to_vec())).collect(),
                stream_words: 100,
                sequential_stream_words: 100 * inputs.len() as u64,
            }
        }
    }

    fn single_batching(backend: Arc<dyn Backend>, policy: BatchPolicy) -> InferenceService {
        let shard = Shard::new("b".to_string(), backend, 1, 1, 0, 8, policy, None);
        InferenceService::start(
            vec![shard],
            1,
            8,
            AdmissionPolicy::Block,
            BatchPolicy::default(),
            NetworkRegistry::empty(),
            ResilienceConfig::default(),
        )
    }

    #[test]
    fn batcher_coalesces_up_to_max_batch_and_records_savings() {
        // One worker, max_batch 4, a hold window far longer than the
        // submissions take: the worker must coalesce all 4 requests
        // into one batch pass (it stops holding the moment the batch
        // fills, so the test never actually waits out the window).
        let svc = single_batching(Arc::new(BatchCounting), BatchPolicy::new(4, 10_000));
        let tickets: Vec<Ticket> = (0..4u64)
            .map(|i| {
                svc.submit(InferRequest {
                    model: "b".into(),
                    input: vec![i as f32].into(),
                    id: i,
                    deadline_ms: None,
                })
                .unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = t.wait().unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.output, vec![i as f32], "request {i}");
        }
        let m = svc.shutdown();
        let b = m.model("b").unwrap();
        assert_eq!((b.submitted, b.completed, b.failed), (4, 4, 0));
        assert_eq!(b.batch_max, 4);
        assert!((b.batch_mean - 4.0).abs() < 1e-9, "mean {}", b.batch_mean);
        // One pass streamed 100 words instead of 4 × 100 sequentially.
        assert_eq!(b.weight_traffic_saved, 300);
        assert_eq!(m.total_weight_traffic_saved(), 300);
    }

    #[test]
    fn default_policy_never_batches() {
        let svc = single_doubler(2, 8, AdmissionPolicy::Block);
        for i in 0..6u64 {
            assert_eq!(svc.infer("d", vec![i as f32]).unwrap(), vec![2.0 * i as f32]);
        }
        let m = svc.shutdown();
        let d = m.model("d").unwrap();
        assert_eq!(d.batch_max, 1);
        assert!((d.batch_mean - 1.0).abs() < 1e-9);
        assert_eq!(d.weight_traffic_saved, 0);
    }

    #[test]
    fn remove_model_wakes_a_batch_holding_worker_fast() {
        // Regression: a worker holding one job under a 10 s straggler
        // window must wake on remove_model and fail its held jobs
        // immediately — not after max_wait_ms expires.
        let svc = single_batching(Arc::new(BatchCounting), BatchPolicy::new(4, 10_000));
        let ticket = svc
            .submit(InferRequest {
                model: "b".into(),
                input: vec![1.0].into(),
                id: 1,
                deadline_ms: None,
            })
            .unwrap();
        // The worker has popped the job and is holding for stragglers.
        wait_until(|| svc.metrics().model("b").unwrap().in_flight == 1);
        let t0 = Instant::now();
        svc.remove_model("b").unwrap();
        let err = ticket.wait().unwrap_err();
        assert!(matches!(err, ServeError::ModelRemoved { .. }), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "held job should fail fast on remove, took {:?}",
            t0.elapsed()
        );
        let m = svc.shutdown();
        let b = m.model("b").unwrap();
        assert_eq!((b.submitted, b.completed, b.failed), (1, 0, 1));
        assert_eq!(b.in_flight, 0);
    }

    #[test]
    fn shutdown_wakes_a_batch_holding_worker_and_runs_the_batch() {
        // Regression: shutdown mid-hold must run the held batch at
        // once (admitted tickets resolve successfully), not sleep out
        // the straggler window.
        let svc = single_batching(Arc::new(BatchCounting), BatchPolicy::new(4, 10_000));
        let ticket = svc
            .submit(InferRequest {
                model: "b".into(),
                input: vec![7.0].into(),
                id: 7,
                deadline_ms: None,
            })
            .unwrap();
        wait_until(|| svc.metrics().model("b").unwrap().in_flight == 1);
        let t0 = Instant::now();
        let m = svc.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "shutdown should break the hold, took {:?}",
            t0.elapsed()
        );
        assert_eq!(ticket.wait().unwrap().output, vec![7.0]);
        let b = m.model("b").unwrap();
        assert_eq!((b.submitted, b.completed, b.failed), (1, 1, 0));
    }
}

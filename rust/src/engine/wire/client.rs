//! Client side of the wire protocol: a blocking [`WireClient`] for
//! one connection, and a multi-connection pipelined [`LoadGen`]
//! (`loadgen` CLI subcommand) that measures what the serving stack
//! sustains over real sockets.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::serve::percentile;
use crate::faults::FaultPlan;
use crate::util::rng::SplitMix64;
use crate::video::SynthVideo;

use super::frame::{ErrorCode, Frame, WireError, WIRE_VERSION};

/// One blocking connection to a [`super::WireServer`]. `connect`
/// performs the `Hello` handshake and learns the hosted model table;
/// [`infer`](Self::infer) is the simple call-response path and
/// [`send`](Self::send)/[`recv`](Self::recv) the pipelined one (up to
/// the caller to match ids).
pub struct WireClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    models: Vec<(String, u32)>,
}

impl WireClient {
    /// Connect and handshake. Fails with a typed [`WireError`] on
    /// version/magic mismatch or a non-`Hello` reply.
    pub fn connect(addr: &str) -> Result<WireClient, WireError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone()?;
        let mut client = WireClient {
            reader: BufReader::new(stream),
            writer: BufWriter::new(write_half),
            models: Vec::new(),
        };
        let hello = Frame::Hello {
            version: WIRE_VERSION,
            models: Vec::new(),
        };
        hello.write_to(&mut client.writer)?;
        client.writer.flush()?;
        match Frame::read_from(&mut client.reader)? {
            Frame::Hello { version, models } => {
                if version != WIRE_VERSION {
                    return Err(WireError::VersionMismatch {
                        ours: WIRE_VERSION,
                        theirs: version,
                    });
                }
                client.models = models;
                Ok(client)
            }
            Frame::Error { code, message, .. } => Err(WireError::Remote { code, message }),
            _ => Err(WireError::Handshake("server's reply was not Hello".into())),
        }
    }

    /// The server's model table (name, input length) from the
    /// handshake.
    pub fn models(&self) -> &[(String, u32)] {
        &self.models
    }

    /// Input length of a hosted model, from the handshake table.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        self.models
            .iter()
            .find(|(name, _)| name == model)
            .map(|(_, len)| *len as usize)
    }

    /// Fire one `Infer` without waiting (pipelining primitive), with
    /// no deadline and attempt 0.
    pub fn send(&mut self, id: u64, model: &str, input: Arc<[f32]>) -> Result<(), WireError> {
        self.send_with(id, model, input, 0, 0)
    }

    /// Fire one `Infer` carrying an explicit deadline budget
    /// (milliseconds, 0 = none) and retry-attempt counter.
    pub fn send_with(
        &mut self,
        id: u64,
        model: &str,
        input: Arc<[f32]>,
        deadline_ms: u64,
        attempt: u8,
    ) -> Result<(), WireError> {
        Frame::Infer {
            id,
            model: model.to_string(),
            input,
            deadline_ms,
            attempt,
        }
        .write_to(&mut self.writer)?;
        self.writer.flush()?;
        Ok(())
    }

    /// Receive the next response frame (`Result` or `Error`).
    pub fn recv(&mut self) -> Result<Frame, WireError> {
        Frame::read_from(&mut self.reader)
    }

    /// Call-response convenience: one `Infer`, wait for its answer.
    /// A per-request server error comes back as
    /// [`WireError::Remote`].
    pub fn infer(&mut self, model: &str, input: &[f32]) -> Result<Vec<f32>, WireError> {
        self.send(0, model, input.to_vec().into())?;
        match self.recv()? {
            Frame::Result { output, .. } => Ok(output),
            Frame::Error { code, message, .. } => Err(WireError::Remote { code, message }),
            other => Err(WireError::Handshake(format!(
                "expected Result/Error, got {other:?}"
            ))),
        }
    }

    /// Call-response with client-side resilience: re-send on
    /// retryable server errors (full queue, admission timeout,
    /// breaker open, worker stalled) with exponential backoff, and
    /// carry `deadline_ms` (0 = none) on every attempt. Non-retryable
    /// errors and transport errors surface immediately; exhausting
    /// the retry budget surfaces the last server error.
    pub fn infer_with_retry(
        &mut self,
        model: &str,
        input: &[f32],
        deadline_ms: u64,
        policy: RetryPolicy,
    ) -> Result<Vec<f32>, WireError> {
        let payload: Arc<[f32]> = input.to_vec().into();
        let mut attempt: u8 = 0;
        loop {
            self.send_with(0, model, payload.clone(), deadline_ms, attempt)?;
            let err = match self.recv()? {
                Frame::Result { output, .. } => return Ok(output),
                Frame::Error { code, message, .. } => WireError::Remote { code, message },
                other => {
                    return Err(WireError::Handshake(format!(
                        "expected Result/Error, got {other:?}"
                    )))
                }
            };
            let retryable = matches!(
                &err,
                WireError::Remote { code, .. }
                    if ErrorCode::from_u8(*code).is_some_and(ErrorCode::is_retryable)
            );
            if !retryable || u32::from(attempt) >= policy.max_retries {
                return Err(err);
            }
            std::thread::sleep(Duration::from_millis(policy.backoff_ms(attempt)));
            attempt = attempt.saturating_add(1);
        }
    }

    /// Fetch the server's rendered metrics table.
    pub fn metrics_table(&mut self) -> Result<String, WireError> {
        Frame::MetricsRequest.write_to(&mut self.writer)?;
        self.writer.flush()?;
        match self.recv()? {
            Frame::MetricsReply { table } => Ok(table),
            Frame::Error { code, message, .. } => Err(WireError::Remote { code, message }),
            other => Err(WireError::Handshake(format!(
                "expected MetricsReply, got {other:?}"
            ))),
        }
    }

    /// Orderly teardown: `Goodbye`, wait for the server's `Goodbye`.
    pub fn goodbye(mut self) -> Result<(), WireError> {
        Frame::Goodbye.write_to(&mut self.writer)?;
        self.writer.flush()?;
        loop {
            match Frame::read_from(&mut self.reader)? {
                Frame::Goodbye => return Ok(()),
                // Late responses to pipelined requests drain first.
                Frame::Result { .. } | Frame::Error { .. } | Frame::MetricsReply { .. } => {}
                other => {
                    return Err(WireError::Handshake(format!(
                        "expected Goodbye, got {other:?}"
                    )))
                }
            }
        }
    }
}

/// How a client re-sends requests that failed with a retryable
/// server error ([`ErrorCode::is_retryable`]). Attempt `k` (0-based)
/// backs off `base_backoff_ms << k` milliseconds before re-sending.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-sends after the first attempt (0 = never retry).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub base_backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            base_backoff_ms: 5,
        }
    }
}

impl RetryPolicy {
    /// Backoff before re-sending after failed attempt `attempt`
    /// (0-based), capped at one second.
    pub fn backoff_ms(&self, attempt: u8) -> u64 {
        self.base_backoff_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(1000)
    }
}

/// Load-generation parameters (`loadgen` CLI subcommand).
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent connections.
    pub connections: usize,
    /// Requests each connection keeps outstanding (pipelining window).
    pub in_flight: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// Models to cycle through round-robin per connection.
    pub models: Vec<String>,
    /// Seed for the synthetic input payloads.
    pub seed: u64,
    /// Client-side retry policy for retryable server errors.
    pub retry: RetryPolicy,
    /// Deadline budget stamped on every request (None = none).
    pub deadline_ms: Option<u64>,
    /// Client-side chaos: a seeded plan whose `connection_drop`
    /// decisions (keyed by request id) sever the TCP connection
    /// mid-run — outstanding requests are counted `lost` and the
    /// connection re-established.
    pub chaos: Option<Arc<FaultPlan>>,
    /// Video replay mode: instead of one static payload per model,
    /// every connection streams *sequential* synthetic frames from the
    /// [`SynthVideo`] delta generator — the smart-camera workload a
    /// [`crate::video::FrameSession`]-backed server exploits. `Some(n)`
    /// re-seeds a fresh clip every `n` frames per model.
    pub video: Option<usize>,
    /// Changed-area fraction per frame in video mode (ignored
    /// otherwise).
    pub video_delta: f64,
}

/// Aggregated outcome of one load-generation run.
#[derive(Debug, Clone, Default)]
pub struct LoadGenReport {
    pub connections: usize,
    pub in_flight: usize,
    /// Requests put on the wire.
    pub sent: u64,
    /// `Result` frames received.
    pub ok: u64,
    /// Per-request `Error` frames other than admission shedding.
    pub failed: u64,
    /// Admission shedding observed on the wire (`QueueFull` /
    /// `AdmissionTimeout` error codes) — the client-side view of the
    /// server's `rejected_backpressure` counter.
    pub rejected_backpressure: u64,
    /// Connections that died mid-run (handshake or socket failures).
    pub transport_errors: u64,
    /// Requests outstanding on a connection when it dropped — they
    /// got no response at all. `sent == ok + failed +
    /// rejected_backpressure + lost` always holds, so the client's
    /// ledger reconciles against the server's even under chaos.
    pub lost: u64,
    /// Re-sends of requests that failed with a retryable error
    /// (counted separately from `sent`, which counts first sends).
    pub retried: u64,
    /// Wall-clock of the whole run.
    pub total_s: f64,
    pub req_per_s: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Outcome of one connection's worker thread.
struct ConnOutcome {
    sent: u64,
    ok: u64,
    failed: u64,
    rejected: u64,
    lost: u64,
    retried: u64,
    transport_error: bool,
    latencies_ms: Vec<f64>,
}

/// Drive `cfg.requests` requests through `cfg.connections` pipelined
/// connections and aggregate the outcome. Latency is wire round-trip
/// (send → matching response), which includes queueing — the number a
/// remote caller actually experiences.
pub fn run_loadgen(cfg: &LoadGenConfig) -> Result<LoadGenReport, WireError> {
    assert!(cfg.connections >= 1 && cfg.in_flight >= 1 && !cfg.models.is_empty());
    let t0 = Instant::now();
    let per_conn = cfg.requests / cfg.connections;
    let remainder = cfg.requests % cfg.connections;
    let handles: Vec<std::thread::JoinHandle<ConnOutcome>> = (0..cfg.connections)
        .map(|c| {
            let cfg = cfg.clone();
            let quota = per_conn + usize::from(c < remainder);
            std::thread::spawn(move || run_connection(&cfg, c, quota))
        })
        .collect();
    let mut report = LoadGenReport {
        connections: cfg.connections,
        in_flight: cfg.in_flight,
        ..LoadGenReport::default()
    };
    let mut latencies = Vec::new();
    for h in handles {
        let o = h.join().expect("loadgen connection thread panicked");
        report.sent += o.sent;
        report.ok += o.ok;
        report.failed += o.failed;
        report.rejected_backpressure += o.rejected;
        report.lost += o.lost;
        report.retried += o.retried;
        report.transport_errors += u64::from(o.transport_error);
        latencies.extend(o.latencies_ms);
    }
    report.total_s = t0.elapsed().as_secs_f64();
    if report.total_s > 0.0 {
        report.req_per_s = report.ok as f64 / report.total_s;
    }
    if !latencies.is_empty() {
        report.mean_ms = latencies.iter().sum::<f64>() / latencies.len() as f64;
        latencies.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
        report.p50_ms = percentile(&latencies, 0.50).unwrap_or(0.0);
        report.p99_ms = percentile(&latencies, 0.99).unwrap_or(0.0);
    }
    Ok(report)
}

/// One in-flight loadgen request. Carries its own payload so a retry
/// retransmits the identical content — in video mode a frame exists
/// only once in the generator's stream.
struct Pending {
    id: u64,
    sent_at: Instant,
    attempt: u8,
    model_idx: usize,
    payload: Arc<[f32]>,
}

/// One connection's run: keep up to `in_flight` requests outstanding,
/// cycling models round-robin, until `quota` requests are resolved
/// (answered, retries exhausted, or lost to an injected drop).
fn run_connection(cfg: &LoadGenConfig, index: usize, quota: usize) -> ConnOutcome {
    let mut out = ConnOutcome {
        sent: 0,
        ok: 0,
        failed: 0,
        rejected: 0,
        lost: 0,
        retried: 0,
        transport_error: false,
        latencies_ms: Vec::with_capacity(quota),
    };
    if quota == 0 {
        return out;
    }
    let mut client = match WireClient::connect(&cfg.addr) {
        Ok(c) => c,
        Err(_) => {
            out.transport_error = true;
            return out;
        }
    };
    let mut rng = SplitMix64::new(cfg.seed ^ (index as u64).wrapping_mul(0x9e37_79b9));
    let lens: Vec<usize> = cfg
        .models
        .iter()
        .map(|m| client.input_len(m).unwrap_or(0))
        .collect();
    // Payload source. Static mode: one payload per model (contents
    // don't affect the serving path; regenerating per request would
    // just slow the generator down). Video mode: a per-model synthetic
    // frame stream whose frames this connection sends *sequentially*,
    // re-seeded into a fresh clip every `clip` frames.
    let statics: Vec<Arc<[f32]>> = if cfg.video.is_none() {
        lens.iter()
            .map(|&len| (0..len).map(|_| rng.next_sym()).collect::<Vec<f32>>().into())
            .collect()
    } else {
        Vec::new()
    };
    let clip = cfg.video.unwrap_or(0).max(1);
    let gen_seed = |mi: usize, epoch: u64| {
        cfg.seed ^ ((index as u64) << 20) ^ ((mi as u64) << 8) ^ epoch
    };
    let mut gens: Vec<(SynthVideo, usize)> = if cfg.video.is_some() {
        lens.iter()
            .enumerate()
            .map(|(mi, &len)| {
                (SynthVideo::flat(len.max(1), cfg.video_delta, gen_seed(mi, 0)), 0)
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut payload_for = |id: u64| -> (usize, Arc<[f32]>) {
        let mi = (id as usize) % cfg.models.len();
        if cfg.video.is_none() {
            return (mi, statics[mi].clone());
        }
        let (gen, produced) = &mut gens[mi];
        if *produced == clip {
            *gen = SynthVideo::flat(
                lens[mi].max(1),
                cfg.video_delta,
                gen_seed(mi, 1 + id / clip as u64),
            );
            *produced = 0;
        }
        *produced += 1;
        (mi, gen.next_flat().into())
    };
    let deadline_ms = cfg.deadline_ms.unwrap_or(0);
    let mut outstanding: Vec<Pending> = Vec::with_capacity(cfg.in_flight);
    let mut next = 0u64;
    let mut done = 0usize;
    while done < quota {
        // Fill the pipelining window…
        while out.sent < quota as u64 && outstanding.len() < cfg.in_flight {
            // Client-side chaos: a drop decision on this request id
            // severs the connection before the send. Everything
            // outstanding is lost (no response will ever come) and
            // the connection is re-established.
            if cfg.chaos.as_ref().is_some_and(|p| p.connection_drop(next)) {
                out.lost += outstanding.len() as u64;
                done += outstanding.len();
                outstanding.clear();
                drop(client);
                client = match WireClient::connect(&cfg.addr) {
                    Ok(c) => c,
                    // `lost` only counts *sent* requests; the rest of
                    // the quota was never put on the wire.
                    Err(_) => {
                        out.transport_error = true;
                        return out;
                    }
                };
            }
            let (model_idx, payload) = payload_for(next);
            let model = &cfg.models[model_idx];
            if client.send_with(next, model, payload.clone(), deadline_ms, 0).is_err() {
                out.transport_error = true;
                out.lost += outstanding.len() as u64;
                return out;
            }
            outstanding.push(Pending {
                id: next,
                sent_at: Instant::now(),
                attempt: 0,
                model_idx,
                payload,
            });
            out.sent += 1;
            next += 1;
        }
        // …then take one response off the wire.
        let frame = match client.recv() {
            Ok(f) => f,
            Err(_) => {
                out.transport_error = true;
                out.lost += outstanding.len() as u64;
                return out;
            }
        };
        let (id, is_ok, code) = match frame {
            Frame::Result { id, .. } => (id, true, 0),
            Frame::Error { id, code, .. } => (id, false, code),
            _ => {
                out.transport_error = true;
                out.lost += outstanding.len() as u64;
                return out;
            }
        };
        if let Some(pos) = outstanding.iter().position(|p| p.id == id) {
            let pending = outstanding.swap_remove(pos);
            if is_ok {
                out.ok += 1;
                out.latencies_ms
                    .push(pending.sent_at.elapsed().as_secs_f64() * 1e3);
                done += 1;
                continue;
            }
            let retryable =
                ErrorCode::from_u8(code).is_some_and(ErrorCode::is_retryable);
            if retryable && u32::from(pending.attempt) < cfg.retry.max_retries {
                std::thread::sleep(Duration::from_millis(
                    cfg.retry.backoff_ms(pending.attempt),
                ));
                let attempt = pending.attempt.saturating_add(1);
                let model = &cfg.models[pending.model_idx];
                if client
                    .send_with(id, model, pending.payload.clone(), deadline_ms, attempt)
                    .is_err()
                {
                    out.transport_error = true;
                    out.lost += outstanding.len() as u64 + 1;
                    return out;
                }
                out.retried += 1;
                outstanding.push(Pending {
                    id,
                    sent_at: pending.sent_at,
                    attempt,
                    model_idx: pending.model_idx,
                    payload: pending.payload,
                });
                continue;
            }
            if code == ErrorCode::QueueFull.as_u8()
                || code == ErrorCode::AdmissionTimeout.as_u8()
            {
                out.rejected += 1;
            } else {
                out.failed += 1;
            }
            done += 1;
        }
    }
    let _ = client.goodbye();
    out
}

//! The Hyperdrive wire frame codec.
//!
//! Every message is one length-prefixed binary frame, little-endian
//! throughout:
//!
//! ```text
//! ┌────────────┬──────────┬──────────────────────────────┐
//! │ u32 length │ u8 kind  │ kind-specific fields …       │
//! └────────────┴──────────┴──────────────────────────────┘
//!   (of body)    body[0]      body[1..]
//! ```
//!
//! The length counts the body (kind byte included), never itself. A
//! zero-length body, a body longer than [`MAX_BODY`], an unknown kind,
//! a field that runs past the body or trailing bytes after the last
//! field are all typed [`WireError`]s — a malformed peer can never make
//! the decoder panic, allocate unboundedly, or misparse the next frame.
//!
//! | kind | frame        | body fields after the kind byte            |
//! |------|--------------|--------------------------------------------|
//! | 1    | `Hello`      | u32 magic, u16 version, u16 n, n × (u16 name-len, name, u32 input-len) |
//! | 2    | `Infer`      | u64 id, u16 model-len, model, u32 count, count × f32, u64 deadline-ms (0 = none), u8 attempt |
//! | 3    | `Result`     | u64 id, f64 latency-ms, u32 count, count × f32 |
//! | 4    | `Error`      | u64 id, u8 code, u32 msg-len, msg          |
//! | 5    | `MetricsRequest` | (empty)                                |
//! | 6    | `MetricsReply`   | u32 len, UTF-8 table                   |
//! | 7    | `Goodbye`    | (empty)                                    |
//!
//! The client's `Hello` carries an empty model table; the server's
//! reply carries the hosted models and their input lengths, so a
//! client knows every model's tensor shape before the first `Infer`.

use std::fmt;
use std::io::{self, Read, Write};
use std::sync::Arc;

use crate::engine::ServeError;

/// `b"HDRV"` as a little-endian u32 — the first field of every
/// `Hello`. A peer that is not speaking this protocol fails here, on
/// the first frame, with [`WireError::BadMagic`].
pub const WIRE_MAGIC: u32 = u32::from_le_bytes(*b"HDRV");

/// Protocol version negotiated in `Hello`. A mismatch is a typed
/// [`WireError::VersionMismatch`], answered on the wire with error
/// code [`ErrorCode::VersionMismatch`] before the server closes.
///
/// v2 extends `Infer` with a per-request deadline and a retry-attempt
/// counter, and adds error codes 9–11 (deadline exceeded, breaker
/// open, worker stalled). v1 peers are rejected at the handshake — the
/// frame layout itself changed, so there is no silent downgrade.
pub const WIRE_VERSION: u16 = 2;

/// Hard cap on a frame body (64 MiB): a hostile or corrupt length
/// prefix must not drive an unbounded allocation.
pub const MAX_BODY: usize = 1 << 26;

/// The `id` used on `Error` frames that concern the connection itself
/// (handshake failures, malformed frames) rather than one request.
pub const CONNECTION_ID: u64 = u64::MAX;

/// Typed wire-layer errors. Everything a malformed peer, a dead
/// socket or a version skew can produce is one of these — never a
/// panic.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// Underlying socket error.
    Io(String),
    /// The peer closed the connection cleanly between frames.
    Closed,
    /// The stream ended inside a frame (prefix or body).
    Truncated { expected: usize, got: usize },
    /// The length prefix exceeds [`MAX_BODY`].
    Oversized { len: usize, max: usize },
    /// The kind byte names no known frame.
    UnknownKind(u8),
    /// The `Hello` magic was wrong — the peer speaks something else.
    BadMagic(u32),
    /// The peer runs an incompatible protocol version.
    VersionMismatch { ours: u16, theirs: u16 },
    /// A structurally invalid body (field past the end, trailing
    /// bytes, bad UTF-8, empty body …).
    Malformed(String),
    /// The handshake broke protocol (first frame not `Hello`, reply
    /// not `Hello`, …).
    Handshake(String),
    /// The server answered with an `Error` frame.
    Remote { code: u8, message: String },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "socket error: {e}"),
            WireError::Closed => write!(f, "connection closed"),
            WireError::Truncated { expected, got } => {
                write!(f, "truncated frame: expected {expected} bytes, got {got}")
            }
            WireError::Oversized { len, max } => {
                write!(f, "frame body of {len} bytes exceeds the {max}-byte cap")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::BadMagic(m) => {
                write!(f, "bad hello magic {m:#010x} (expected {WIRE_MAGIC:#010x})")
            }
            WireError::VersionMismatch { ours, theirs } => {
                write!(f, "protocol version mismatch: ours {ours}, peer's {theirs}")
            }
            WireError::Malformed(why) => write!(f, "malformed frame: {why}"),
            WireError::Handshake(why) => write!(f, "handshake violation: {why}"),
            WireError::Remote { code, message } => {
                write!(f, "server error (code {code}): {message}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e.to_string())
    }
}

/// Error codes carried on `Error` frames. Codes 1–8 mirror the
/// [`ServeError`] variants one-to-one so a remote client sees exactly
/// the typed failure an in-process caller would; 100+ are wire-layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    UnknownModel = 1,
    BadInput = 2,
    QueueFull = 3,
    AdmissionTimeout = 4,
    ModelRemoved = 5,
    ShuttingDown = 6,
    Panicked = 7,
    Failed = 8,
    /// The request's deadline expired before (or while) it ran.
    DeadlineExceeded = 9,
    /// The model's circuit breaker is Open — shed at the door.
    BreakerOpen = 10,
    /// The watchdog failed this request after its worker stalled.
    WorkerStalled = 11,
    /// The connection broke protocol (malformed frame, unexpected
    /// kind); scoped to the connection, not a request.
    Protocol = 100,
    /// The `Hello` versions disagree.
    VersionMismatch = 101,
}

impl ErrorCode {
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    pub fn from_u8(code: u8) -> Option<ErrorCode> {
        Some(match code {
            1 => ErrorCode::UnknownModel,
            2 => ErrorCode::BadInput,
            3 => ErrorCode::QueueFull,
            4 => ErrorCode::AdmissionTimeout,
            5 => ErrorCode::ModelRemoved,
            6 => ErrorCode::ShuttingDown,
            7 => ErrorCode::Panicked,
            8 => ErrorCode::Failed,
            9 => ErrorCode::DeadlineExceeded,
            10 => ErrorCode::BreakerOpen,
            11 => ErrorCode::WorkerStalled,
            100 => ErrorCode::Protocol,
            101 => ErrorCode::VersionMismatch,
            _ => return None,
        })
    }

    /// Whether a request failing with this code is worth re-sending.
    /// Transient congestion (full queue, admission timeout, tripped
    /// breaker) and a stalled worker are; semantic failures (unknown
    /// model, bad input, deadline already blown) are not — a retry
    /// would fail identically or arrive too late to matter.
    pub fn is_retryable(self) -> bool {
        matches!(
            self,
            ErrorCode::QueueFull
                | ErrorCode::AdmissionTimeout
                | ErrorCode::BreakerOpen
                | ErrorCode::WorkerStalled
        )
    }
}

/// The wire error code a [`ServeError`] travels as.
pub fn error_code_for(err: &ServeError) -> ErrorCode {
    match err {
        ServeError::UnknownModel { .. } => ErrorCode::UnknownModel,
        ServeError::BadInput { .. } => ErrorCode::BadInput,
        ServeError::QueueFull { .. } => ErrorCode::QueueFull,
        ServeError::AdmissionTimeout { .. } => ErrorCode::AdmissionTimeout,
        ServeError::ModelRemoved { .. } => ErrorCode::ModelRemoved,
        ServeError::ShuttingDown => ErrorCode::ShuttingDown,
        ServeError::Panicked { .. } => ErrorCode::Panicked,
        ServeError::Failed { .. } => ErrorCode::Failed,
        ServeError::DeadlineExceeded { .. } => ErrorCode::DeadlineExceeded,
        ServeError::BreakerOpen { .. } => ErrorCode::BreakerOpen,
        ServeError::WorkerStalled { .. } => ErrorCode::WorkerStalled,
    }
}

/// One decoded wire frame. `Infer` carries its payload as
/// `Arc<[f32]>` so the server hands the tensor straight to
/// [`crate::engine::InferRequest`] without a copy.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Handshake. The client sends an empty model table; the server
    /// replies with the hosted models and their input lengths.
    Hello {
        version: u16,
        models: Vec<(String, u32)>,
    },
    /// One inference request, client → server. `deadline_ms` is the
    /// client's remaining latency budget (0 = none) — the server sheds
    /// the request with [`ErrorCode::DeadlineExceeded`] once it
    /// expires instead of burning backend cycles on a result nobody
    /// will read. `attempt` counts client-side retries (0 = first
    /// send) so the server's metrics can attribute them.
    Infer {
        id: u64,
        model: String,
        input: Arc<[f32]>,
        deadline_ms: u64,
        attempt: u8,
    },
    /// One successful inference, server → client.
    Result {
        id: u64,
        latency_ms: f64,
        output: Vec<f32>,
    },
    /// A per-request (or, with [`CONNECTION_ID`], per-connection)
    /// failure, server → client.
    Error { id: u64, code: u8, message: String },
    /// Ask the server for its metrics table.
    MetricsRequest,
    /// The rendered [`crate::engine::ServiceMetrics`] table.
    MetricsReply { table: String },
    /// Orderly half of a connection teardown (either direction).
    Goodbye,
}

const KIND_HELLO: u8 = 1;
const KIND_INFER: u8 = 2;
const KIND_RESULT: u8 = 3;
const KIND_ERROR: u8 = 4;
const KIND_METRICS_REQUEST: u8 = 5;
const KIND_METRICS_REPLY: u8 = 6;
const KIND_GOODBYE: u8 = 7;

/// Bounded little-endian field reader over a frame body. Every take
/// checks the remaining length, so a lying length field inside the
/// body is a typed error, not a slice panic.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Malformed(format!(
                "{what}: needs {n} bytes, {} left",
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, WireError> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        let b = self.take(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self, what: &str) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn string(&mut self, len: usize, what: &str) -> Result<String, WireError> {
        let b = self.take(len, what)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| WireError::Malformed(format!("{what}: not valid UTF-8")))
    }

    fn f32s(&mut self, count: usize, what: &str) -> Result<Vec<f32>, WireError> {
        let b = self.take(count * 4, what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn finish(self, kind: &str) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed(format!(
                "{kind}: {} trailing bytes after the last field",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn push_f32s(out: &mut Vec<u8>, values: &[f32]) {
    out.extend_from_slice(&(values.len() as u32).to_le_bytes());
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

impl Frame {
    /// The complete wire bytes of this frame: u32 length prefix plus
    /// body.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            Frame::Hello { version, models } => {
                body.push(KIND_HELLO);
                body.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
                body.extend_from_slice(&version.to_le_bytes());
                body.extend_from_slice(&(models.len() as u16).to_le_bytes());
                for (name, input_len) in models {
                    body.extend_from_slice(&(name.len() as u16).to_le_bytes());
                    body.extend_from_slice(name.as_bytes());
                    body.extend_from_slice(&input_len.to_le_bytes());
                }
            }
            Frame::Infer {
                id,
                model,
                input,
                deadline_ms,
                attempt,
            } => {
                body.push(KIND_INFER);
                body.extend_from_slice(&id.to_le_bytes());
                body.extend_from_slice(&(model.len() as u16).to_le_bytes());
                body.extend_from_slice(model.as_bytes());
                push_f32s(&mut body, input);
                body.extend_from_slice(&deadline_ms.to_le_bytes());
                body.push(*attempt);
            }
            Frame::Result {
                id,
                latency_ms,
                output,
            } => {
                body.push(KIND_RESULT);
                body.extend_from_slice(&id.to_le_bytes());
                body.extend_from_slice(&latency_ms.to_bits().to_le_bytes());
                push_f32s(&mut body, output);
            }
            Frame::Error { id, code, message } => {
                body.push(KIND_ERROR);
                body.extend_from_slice(&id.to_le_bytes());
                body.push(*code);
                body.extend_from_slice(&(message.len() as u32).to_le_bytes());
                body.extend_from_slice(message.as_bytes());
            }
            Frame::MetricsRequest => body.push(KIND_METRICS_REQUEST),
            Frame::MetricsReply { table } => {
                body.push(KIND_METRICS_REPLY);
                body.extend_from_slice(&(table.len() as u32).to_le_bytes());
                body.extend_from_slice(table.as_bytes());
            }
            Frame::Goodbye => body.push(KIND_GOODBYE),
        }
        debug_assert!(!body.is_empty() && body.len() <= MAX_BODY);
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Decode one frame body (the bytes after the length prefix).
    /// Every structural defect is a typed [`WireError`]; a valid frame
    /// must consume the body exactly.
    pub fn decode(body: &[u8]) -> Result<Frame, WireError> {
        let mut c = Cursor::new(body);
        let kind = c.u8("kind byte")?;
        let frame = match kind {
            KIND_HELLO => {
                let magic = c.u32("hello magic")?;
                if magic != WIRE_MAGIC {
                    return Err(WireError::BadMagic(magic));
                }
                let version = c.u16("hello version")?;
                let n = c.u16("hello model count")? as usize;
                let mut models = Vec::with_capacity(n);
                for _ in 0..n {
                    let name_len = c.u16("hello model name length")? as usize;
                    let name = c.string(name_len, "hello model name")?;
                    let input_len = c.u32("hello model input length")?;
                    models.push((name, input_len));
                }
                Frame::Hello { version, models }
            }
            KIND_INFER => {
                let id = c.u64("infer id")?;
                let model_len = c.u16("infer model length")? as usize;
                let model = c.string(model_len, "infer model name")?;
                let count = c.u32("infer value count")? as usize;
                let input: Arc<[f32]> = c.f32s(count, "infer payload")?.into();
                let deadline_ms = c.u64("infer deadline")?;
                let attempt = c.u8("infer attempt")?;
                Frame::Infer {
                    id,
                    model,
                    input,
                    deadline_ms,
                    attempt,
                }
            }
            KIND_RESULT => {
                let id = c.u64("result id")?;
                let latency_ms = c.f64("result latency")?;
                let count = c.u32("result value count")? as usize;
                let output = c.f32s(count, "result payload")?;
                Frame::Result {
                    id,
                    latency_ms,
                    output,
                }
            }
            KIND_ERROR => {
                let id = c.u64("error id")?;
                let code = c.u8("error code")?;
                let msg_len = c.u32("error message length")? as usize;
                let message = c.string(msg_len, "error message")?;
                Frame::Error { id, code, message }
            }
            KIND_METRICS_REQUEST => Frame::MetricsRequest,
            KIND_METRICS_REPLY => {
                let len = c.u32("metrics table length")? as usize;
                let table = c.string(len, "metrics table")?;
                Frame::MetricsReply { table }
            }
            KIND_GOODBYE => Frame::Goodbye,
            other => return Err(WireError::UnknownKind(other)),
        };
        c.finish(match frame {
            Frame::Hello { .. } => "hello",
            Frame::Infer { .. } => "infer",
            Frame::Result { .. } => "result",
            Frame::Error { .. } => "error",
            Frame::MetricsRequest => "metrics request",
            Frame::MetricsReply { .. } => "metrics reply",
            Frame::Goodbye => "goodbye",
        })?;
        Ok(frame)
    }

    /// Read one complete frame from the stream. A clean EOF *between*
    /// frames is [`WireError::Closed`]; an EOF inside a frame is
    /// [`WireError::Truncated`].
    pub fn read_from(r: &mut impl Read) -> Result<Frame, WireError> {
        let mut prefix = [0u8; 4];
        let got = read_full(r, &mut prefix)?;
        if got == 0 {
            return Err(WireError::Closed);
        }
        if got < 4 {
            return Err(WireError::Truncated {
                expected: 4,
                got,
            });
        }
        let len = u32::from_le_bytes(prefix) as usize;
        if len == 0 {
            return Err(WireError::Malformed("empty frame body".into()));
        }
        if len > MAX_BODY {
            return Err(WireError::Oversized { len, max: MAX_BODY });
        }
        let mut body = vec![0u8; len];
        let got = read_full(r, &mut body)?;
        if got < len {
            return Err(WireError::Truncated { expected: len, got });
        }
        Frame::decode(&body)
    }

    /// Write this frame to the stream (no flush — the caller batches).
    pub fn write_to(&self, w: &mut impl Write) -> Result<(), WireError> {
        w.write_all(&self.encode())?;
        Ok(())
    }
}

/// Read until `buf` is full or EOF; returns the bytes actually read.
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<usize, WireError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(got)
}

//! # Wire protocol — the TCP serving frontend
//!
//! Hyperdrive's claim is *system-level* efficiency: the paper counts
//! interface I/O, not just core arithmetic, and beats core-only
//! accelerators on exactly that ledger. This module gives the serving
//! stack its interface story — a binary wire protocol
//! ([`frame`]), a TCP server feeding the sharded
//! [`InferenceService`](crate::engine::InferenceService) with
//! zero-copy payload handoff ([`server`]), and a pipelined
//! multi-connection load generator ([`client`]) — all std-only, no
//! dependencies.
//!
//! A remote caller sees the same contract an in-process caller does:
//! per-request results, typed errors (the [`frame::ErrorCode`] table
//! mirrors [`ServeError`](crate::engine::ServeError) one-to-one), and
//! failure isolation — a malformed frame or dropped connection costs
//! only that connection's requests.
//!
//! The CLI front ends are `hyperdrive serve --listen ADDR` (server)
//! and `hyperdrive loadgen --connect ADDR` (load generator); see the
//! repo README's serving quickstart.

pub mod client;
pub mod frame;
pub mod server;

pub use client::{run_loadgen, LoadGenConfig, LoadGenReport, RetryPolicy, WireClient};
pub use frame::{ErrorCode, Frame, WireError, MAX_BODY, WIRE_MAGIC, WIRE_VERSION};
pub use server::{WireServer, WireStats};

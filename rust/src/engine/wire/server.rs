//! TCP serving frontend: N connections feeding one
//! [`InferenceService`].
//!
//! Each accepted connection gets a **reader thread** (decodes frames,
//! submits [`InferRequest`]s — the `Infer` payload is already an
//! `Arc<[f32]>`, so admission is zero-copy) and a **writer thread**
//! (resolves [`Ticket`]s and encodes responses **in submission
//! order**). Splitting the directions means a slow response never
//! stops the reader from admitting the connection's next request — the
//! pipelining that makes `--in-flight K` load generation work.
//!
//! Failure isolation mirrors the service's per-request contract: a
//! malformed frame or a dropped connection kills *that connection's*
//! pending requests only (the service still executes what was already
//! admitted; the writer drains the tickets even when the socket is
//! gone so in-flight accounting stays exact). Every other connection
//! is untouched.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::engine::{InferRequest, InferenceService, ServeError, Ticket};

use super::frame::{error_code_for, ErrorCode, Frame, WireError, CONNECTION_ID, WIRE_VERSION};

/// Backpressure/traffic telemetry of a [`WireServer`], snapshotted by
/// [`WireServer::stats`] and returned by [`WireServer::shutdown`].
#[derive(Debug, Clone, Default)]
pub struct WireStats {
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
    /// Frames decoded from clients (handshakes included).
    pub frames_rx: u64,
    /// Frames written to clients.
    pub frames_tx: u64,
    /// Protocol violations observed (malformed/unexpected frames).
    pub malformed: u64,
    /// `Infer` frames received.
    pub infer_rx: u64,
    /// `Result` frames sent.
    pub results_tx: u64,
    /// `Error` frames sent (admission rejections included — this is
    /// where wire-visible backpressure shows up).
    pub errors_tx: u64,
    /// Connections currently open.
    pub active: usize,
    /// Highest per-connection in-flight depth observed (requests
    /// admitted but not yet answered on one connection).
    pub max_in_flight: usize,
}

struct ServerShared {
    service: Arc<InferenceService>,
    stop: AtomicBool,
    connections: AtomicU64,
    frames_rx: AtomicU64,
    frames_tx: AtomicU64,
    malformed: AtomicU64,
    infer_rx: AtomicU64,
    results_tx: AtomicU64,
    errors_tx: AtomicU64,
    active: AtomicUsize,
    max_in_flight: AtomicUsize,
}

impl ServerShared {
    fn stats(&self) -> WireStats {
        WireStats {
            connections: self.connections.load(Ordering::Relaxed),
            frames_rx: self.frames_rx.load(Ordering::Relaxed),
            frames_tx: self.frames_tx.load(Ordering::Relaxed),
            malformed: self.malformed.load(Ordering::Relaxed),
            infer_rx: self.infer_rx.load(Ordering::Relaxed),
            results_tx: self.results_tx.load(Ordering::Relaxed),
            errors_tx: self.errors_tx.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            max_in_flight: self.max_in_flight.load(Ordering::Relaxed),
        }
    }
}

/// What the reader hands the writer, in submission order. The reader
/// always enqueues exactly one terminal entry (`Bye`/`Fatal`/`Drop`)
/// last, so the writer loop always terminates.
enum Pending {
    /// An admitted request: wait the ticket, answer `Result`/`Error`.
    Ticket(Ticket),
    /// An admission rejection: answer `Error` without a ticket.
    Reject { id: u64, err: ServeError },
    /// Answer a rendered metrics table.
    Metrics(String),
    /// Clean teardown: answer `Goodbye` and close.
    Bye,
    /// Protocol violation: answer a connection-scoped `Error`, close.
    Fatal(String),
    /// The socket died; close without writing.
    Drop,
}

struct PendingQueue {
    q: Mutex<VecDeque<Pending>>,
    cv: Condvar,
}

impl PendingQueue {
    fn push(&self, p: Pending) {
        self.q.lock().unwrap().push_back(p);
        self.cv.notify_all();
    }

    fn pop(&self) -> Pending {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(p) = q.pop_front() {
                return p;
            }
            q = self.cv.wait(q).unwrap();
        }
    }
}

/// A TCP frontend bound to one address, feeding one
/// [`InferenceService`]. Dropping the server stops accepting, closes
/// every connection and joins every thread; [`shutdown`](Self::shutdown)
/// does the same and returns the final [`WireStats`].
pub struct WireServer {
    shared: Arc<ServerShared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<ConnSlot>>>,
}

struct ConnSlot {
    stream: Option<TcpStream>,
    handle: Option<JoinHandle<()>>,
}

impl WireServer {
    /// Bind `addr` (use port 0 for an OS-assigned port — see
    /// [`local_addr`](Self::local_addr)) and start accepting.
    pub fn start(service: Arc<InferenceService>, addr: &str) -> Result<WireServer, WireError> {
        let listener = TcpListener::bind(addr)?;
        // Nonblocking listener: the accept loop polls (WouldBlock →
        // check the stop flag, nap, retry) instead of parking inside
        // `accept()` — shutdown then needs no wake-up connection.
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(ServerShared {
            service,
            stop: AtomicBool::new(false),
            connections: AtomicU64::new(0),
            frames_rx: AtomicU64::new(0),
            frames_tx: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
            infer_rx: AtomicU64::new(0),
            results_tx: AtomicU64::new(0),
            errors_tx: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            max_in_flight: AtomicUsize::new(0),
        });
        let conns: Arc<Mutex<Vec<ConnSlot>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::spawn(move || accept_loop(&listener, &shared, &conns))
        };
        Ok(WireServer {
            shared,
            addr: local,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A live telemetry snapshot.
    pub fn stats(&self) -> WireStats {
        self.shared.stats()
    }

    /// Stop accepting, close every connection, join every thread and
    /// return the final telemetry. The underlying service is left
    /// running (it belongs to the caller).
    pub fn shutdown(mut self) -> WireStats {
        self.stop();
        self.shared.stats()
    }

    fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        // The nonblocking accept loop observes the flag on its next
        // poll tick (≤ a few ms).
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let slots: Vec<ConnSlot> = std::mem::take(&mut *self.conns.lock().unwrap());
        for mut slot in slots {
            if let Some(stream) = slot.stream.take() {
                let _ = stream.shutdown(Shutdown::Both);
            }
            if let Some(h) = slot.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: &Arc<ServerShared>,
    conns: &Arc<Mutex<Vec<ConnSlot>>>,
) {
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        let stream = match listener.accept() {
            Ok((stream, _)) => {
                // The listener is nonblocking so `accept` never parks
                // this thread, but each connection's reader/writer
                // threads use plain blocking I/O.
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                stream
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
                continue;
            }
            Err(_) => {
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                // Transient accept failure (EMFILE, ECONNABORTED…):
                // back off briefly instead of hot-spinning.
                std::thread::sleep(std::time::Duration::from_millis(5));
                continue;
            }
        };
        shared.connections.fetch_add(1, Ordering::Relaxed);
        shared.active.fetch_add(1, Ordering::Relaxed);
        let tracked = stream.try_clone().ok();
        let handle = {
            let shared = shared.clone();
            std::thread::spawn(move || {
                handle_connection(&shared, stream);
                shared.active.fetch_sub(1, Ordering::Relaxed);
            })
        };
        let mut slots = conns.lock().unwrap();
        // Reap finished connections so a long-lived server does not
        // accumulate dead handles.
        slots.retain_mut(|s| match &s.handle {
            Some(h) if h.is_finished() => {
                if let Some(h) = s.handle.take() {
                    let _ = h.join();
                }
                false
            }
            _ => true,
        });
        slots.push(ConnSlot {
            stream: tracked,
            handle: Some(handle),
        });
    }
}

/// One connection, start to finish: handshake, then the reader loop
/// (this thread) feeding the writer thread in submission order.
fn handle_connection(shared: &Arc<ServerShared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(stream);
    let mut writer = BufWriter::new(write_half);

    // Handshake: the first frame must be a version-matched Hello; the
    // reply advertises the hosted models and their input lengths.
    match Frame::read_from(&mut reader) {
        Ok(Frame::Hello { version, .. }) => {
            shared.frames_rx.fetch_add(1, Ordering::Relaxed);
            if version != WIRE_VERSION {
                let err = WireError::VersionMismatch {
                    ours: WIRE_VERSION,
                    theirs: version,
                };
                send_connection_error(shared, &mut writer, ErrorCode::VersionMismatch, &err);
                return;
            }
        }
        Ok(_) => {
            shared.frames_rx.fetch_add(1, Ordering::Relaxed);
            shared.malformed.fetch_add(1, Ordering::Relaxed);
            let err = WireError::Handshake("first frame was not Hello".into());
            send_connection_error(shared, &mut writer, ErrorCode::Protocol, &err);
            return;
        }
        Err(WireError::Closed) => return,
        Err(err) => {
            shared.malformed.fetch_add(1, Ordering::Relaxed);
            send_connection_error(shared, &mut writer, ErrorCode::Protocol, &err);
            return;
        }
    }
    let models: Vec<(String, u32)> = shared
        .service
        .models()
        .into_iter()
        .map(|name| {
            let len = shared.service.input_len(&name).unwrap_or(0) as u32;
            (name, len)
        })
        .collect();
    let hello = Frame::Hello {
        version: WIRE_VERSION,
        models,
    };
    if hello.write_to(&mut writer).is_err() || writer.flush().is_err() {
        return;
    }
    shared.frames_tx.fetch_add(1, Ordering::Relaxed);

    let pending = Arc::new(PendingQueue {
        q: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
    });
    let in_flight = Arc::new(AtomicUsize::new(0));
    let writer_thread = {
        let shared = shared.clone();
        let pending = pending.clone();
        let in_flight = in_flight.clone();
        std::thread::spawn(move || writer_loop(&shared, &pending, &in_flight, writer))
    };

    loop {
        match Frame::read_from(&mut reader) {
            Ok(Frame::Infer {
                id,
                model,
                input,
                deadline_ms,
                attempt,
            }) => {
                shared.frames_rx.fetch_add(1, Ordering::Relaxed);
                shared.infer_rx.fetch_add(1, Ordering::Relaxed);
                if attempt > 0 {
                    // A client-side retry: attribute it on the
                    // server's per-model metrics row.
                    shared.service.note_retry(&model);
                }
                let deadline_ms = (deadline_ms > 0).then_some(deadline_ms);
                match shared.service.submit(InferRequest {
                    model,
                    input,
                    id,
                    deadline_ms,
                }) {
                    Ok(ticket) => {
                        let depth = in_flight.fetch_add(1, Ordering::Relaxed) + 1;
                        shared.max_in_flight.fetch_max(depth, Ordering::Relaxed);
                        pending.push(Pending::Ticket(ticket));
                    }
                    Err(err) => pending.push(Pending::Reject { id, err }),
                }
            }
            Ok(Frame::MetricsRequest) => {
                shared.frames_rx.fetch_add(1, Ordering::Relaxed);
                pending.push(Pending::Metrics(shared.service.metrics().render_table()));
            }
            Ok(Frame::Goodbye) => {
                shared.frames_rx.fetch_add(1, Ordering::Relaxed);
                pending.push(Pending::Bye);
                break;
            }
            Ok(_) => {
                // Hello after the handshake, or a server→client kind:
                // a protocol violation that poisons this connection.
                shared.frames_rx.fetch_add(1, Ordering::Relaxed);
                shared.malformed.fetch_add(1, Ordering::Relaxed);
                pending.push(Pending::Fatal("unexpected frame kind".into()));
                break;
            }
            Err(WireError::Closed) | Err(WireError::Io(_)) => {
                pending.push(Pending::Drop);
                break;
            }
            Err(err) => {
                shared.malformed.fetch_add(1, Ordering::Relaxed);
                pending.push(Pending::Fatal(err.to_string()));
                break;
            }
        }
    }
    let _ = writer_thread.join();
}

fn send_connection_error(
    shared: &ServerShared,
    writer: &mut BufWriter<TcpStream>,
    code: ErrorCode,
    err: &WireError,
) {
    let frame = Frame::Error {
        id: CONNECTION_ID,
        code: code.as_u8(),
        message: err.to_string(),
    };
    if frame.write_to(writer).is_ok() && writer.flush().is_ok() {
        shared.frames_tx.fetch_add(1, Ordering::Relaxed);
        shared.errors_tx.fetch_add(1, Ordering::Relaxed);
    }
}

/// Drain the pending queue in order, resolving tickets and writing
/// responses. If the socket dies mid-stream the loop keeps *waiting*
/// tickets without writing — the service's in-flight accounting and
/// this connection's counter both stay exact, and only this
/// connection's requests are lost.
fn writer_loop(
    shared: &Arc<ServerShared>,
    pending: &PendingQueue,
    in_flight: &AtomicUsize,
    mut writer: BufWriter<TcpStream>,
) {
    let mut dead = false;
    let mut send = |frame: &Frame, writer: &mut BufWriter<TcpStream>, dead: &mut bool| {
        if *dead {
            return;
        }
        if frame.write_to(writer).is_err() || writer.flush().is_err() {
            *dead = true;
            return;
        }
        shared.frames_tx.fetch_add(1, Ordering::Relaxed);
        match frame {
            Frame::Result { .. } => {
                shared.results_tx.fetch_add(1, Ordering::Relaxed);
            }
            Frame::Error { .. } => {
                shared.errors_tx.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
    };
    loop {
        match pending.pop() {
            Pending::Ticket(ticket) => {
                let id = ticket.id();
                let frame = match ticket.wait() {
                    Ok(resp) => Frame::Result {
                        id: resp.id,
                        latency_ms: resp.latency_ms,
                        output: resp.output,
                    },
                    Err(err) => Frame::Error {
                        id,
                        code: error_code_for(&err).as_u8(),
                        message: err.to_string(),
                    },
                };
                send(&frame, &mut writer, &mut dead);
                in_flight.fetch_sub(1, Ordering::Relaxed);
            }
            Pending::Reject { id, err } => {
                let frame = Frame::Error {
                    id,
                    code: error_code_for(&err).as_u8(),
                    message: err.to_string(),
                };
                send(&frame, &mut writer, &mut dead);
            }
            Pending::Metrics(table) => {
                send(&Frame::MetricsReply { table }, &mut writer, &mut dead);
            }
            Pending::Bye => {
                send(&Frame::Goodbye, &mut writer, &mut dead);
                break;
            }
            Pending::Fatal(message) => {
                let frame = Frame::Error {
                    id: CONNECTION_ID,
                    code: ErrorCode::Protocol.as_u8(),
                    message,
                };
                send(&frame, &mut writer, &mut dead);
                break;
            }
            Pending::Drop => break,
        }
    }
    let _ = writer.flush();
    if let Ok(stream) = writer.into_inner() {
        let _ = stream.shutdown(Shutdown::Both);
    }
}

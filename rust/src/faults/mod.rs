//! Deterministic fault-injection plane.
//!
//! A [`FaultPlan`] is a seeded set of rules, each pairing a [`FaultKind`]
//! (what breaks) with a [`Trigger`] (when it breaks). The plan threads into
//! the mesh simulator (`simulator::mesh::MeshSim`), the inference service
//! (`engine::service::InferenceService`) and the wire load generator
//! (`engine::wire::run_loadgen`), which consult it at well-defined *sites*:
//!
//! | kind               | site                                            |
//! |--------------------|-------------------------------------------------|
//! | `ChipDeath`        | mesh: before a chip's per-step job is collected |
//! | `CorruptExchange`  | mesh: a halo border transfer, after checksum    |
//! | `WorkerStall{ms}`  | service: a worker wedges before running a batch |
//! | `SlowModel{ms}`    | service: extra latency before running a batch   |
//! | `ConnectionDrop`   | loadgen: client severs its TCP connection       |
//!
//! Decisions are **stateless**: whether a rule fires for sequence number
//! `seq` at a given site is a pure hash of `(seed, site tag, seq)`. Two runs
//! with the same seed and the same per-site sequence numbering therefore
//! inject *identical* faults regardless of thread interleaving — which is
//! what makes chaos soaks reproducible and counter assertions exact.
//! Sequence numbers are chosen by each site to be schedule-independent
//! (request ids for the service and loadgen, `step * chips + chip` for mesh
//! chip death, the quiescent-flag transfer index for border exchanges).
//!
//! Fired faults are tallied in lock-free per-kind counters; snapshot them
//! with [`FaultPlan::counters`] and compare across runs.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// What kind of fault a rule injects. Duration-carrying kinds (`WorkerStall`,
/// `SlowModel`) embed the injected delay in milliseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// A mesh chip dies: its per-step job fails before execution.
    ChipDeath,
    /// A halo border transfer is corrupted in flight (single bit flip).
    CorruptExchange,
    /// A service worker wedges for `ms` before running its batch.
    WorkerStall {
        /// How long the worker stays wedged, in milliseconds.
        ms: u64,
    },
    /// A client connection is severed mid-stream by the load generator.
    ConnectionDrop,
    /// A model mysteriously slows down by `ms` for one batch.
    SlowModel {
        /// Added latency in milliseconds.
        ms: u64,
    },
}

impl FaultKind {
    /// Stable site tag mixed into the decision hash. Distinct per kind so
    /// the same seq at different sites draws independent decisions.
    fn tag(self) -> u64 {
        match self {
            FaultKind::ChipDeath => 0x43_48_49_50,        // "CHIP"
            FaultKind::CorruptExchange => 0x48_41_4c_4f,  // "HALO"
            FaultKind::WorkerStall { .. } => 0x57_44_47,  // "WDG"
            FaultKind::ConnectionDrop => 0x44_52_4f_50,   // "DROP"
            FaultKind::SlowModel { .. } => 0x53_4c_4f_57, // "SLOW"
        }
    }

    fn counter_index(self) -> usize {
        match self {
            FaultKind::ChipDeath => 0,
            FaultKind::CorruptExchange => 1,
            FaultKind::WorkerStall { .. } => 2,
            FaultKind::ConnectionDrop => 3,
            FaultKind::SlowModel { .. } => 4,
        }
    }
}

/// When a rule fires, as a function of the site's sequence number.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Fire on every decision.
    Always,
    /// Fire exactly once, on sequence number `n`.
    Nth(u64),
    /// Fire on every `n`-th decision (`seq % n == 0`; `n == 0` never fires).
    Every(u64),
    /// Fire with probability `p` per decision, derived from the seeded hash.
    Prob(f64),
}

/// One injection rule: a kind plus its trigger.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultRule {
    /// What breaks.
    pub kind: FaultKind,
    /// When it breaks.
    pub trigger: Trigger,
}

/// Snapshot of how many faults of each kind a plan has injected so far.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Chips killed before executing a mesh step.
    pub chip_deaths: u64,
    /// Halo transfers corrupted in flight.
    pub corrupt_exchanges: u64,
    /// Workers wedged before running a batch.
    pub worker_stalls: u64,
    /// Client connections severed by the load generator.
    pub connection_drops: u64,
    /// Batches slowed by injected latency.
    pub slow_models: u64,
}

impl FaultCounters {
    /// Total faults injected across all kinds.
    pub fn total(&self) -> u64 {
        self.chip_deaths
            + self.corrupt_exchanges
            + self.worker_stalls
            + self.connection_drops
            + self.slow_models
    }
}

impl fmt::Display for FaultCounters {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} chip deaths, {} corrupt exchanges, {} worker stalls, \
             {} connection drops, {} slow batches",
            self.chip_deaths,
            self.corrupt_exchanges,
            self.worker_stalls,
            self.connection_drops,
            self.slow_models
        )
    }
}

/// A seeded, deterministic fault plan. Cheap to share via `Arc`; all
/// counters are atomic so the same plan can be consulted from any thread.
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    fired: [AtomicU64; 5],
}

/// SplitMix64: a tiny, well-mixed stateless hash. Same constants as the
/// reference implementation; mirrored in `python/tests/test_resilience_mirror.py`.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Map `(seed, tag, seq)` to a uniform draw in `[0, 1)`.
fn draw(seed: u64, tag: u64, seq: u64) -> f64 {
    let h = splitmix64(seed ^ splitmix64(tag) ^ splitmix64(seq.wrapping_mul(0x9e37)));
    // 53 high bits -> uniform double in [0, 1).
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// An empty plan under `seed`; add rules with [`FaultPlan::rule`].
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            rules: Vec::new(),
            fired: Default::default(),
        }
    }

    /// An empty plan that never fires (useful as a no-op default).
    pub fn none() -> Self {
        FaultPlan::new(0)
    }

    /// Builder-style: append a rule.
    pub fn rule(mut self, kind: FaultKind, trigger: Trigger) -> Self {
        self.rules.push(FaultRule { kind, trigger });
        self
    }

    /// The seed this plan draws decisions from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// True if the plan has no rules and can never fire.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Snapshot the per-kind injection tallies.
    pub fn counters(&self) -> FaultCounters {
        FaultCounters {
            chip_deaths: self.fired[0].load(Ordering::Relaxed),
            corrupt_exchanges: self.fired[1].load(Ordering::Relaxed),
            worker_stalls: self.fired[2].load(Ordering::Relaxed),
            connection_drops: self.fired[3].load(Ordering::Relaxed),
            slow_models: self.fired[4].load(Ordering::Relaxed),
        }
    }

    /// Core decision: does any rule of kind-class `kind` fire at `seq`?
    /// Returns the (parameterised) kind of the first matching rule and
    /// bumps its counter.
    fn decide(&self, matches: impl Fn(FaultKind) -> bool, seq: u64) -> Option<FaultKind> {
        for rule in &self.rules {
            if !matches(rule.kind) {
                continue;
            }
            let fires = match rule.trigger {
                Trigger::Always => true,
                Trigger::Nth(n) => seq == n,
                Trigger::Every(n) => n > 0 && seq % n == 0,
                Trigger::Prob(p) => draw(self.seed, rule.kind.tag(), seq) < p,
            };
            if fires {
                self.fired[rule.kind.counter_index()].fetch_add(1, Ordering::Relaxed);
                return Some(rule.kind);
            }
        }
        None
    }

    /// Should the chip at decision index `seq` die this step?
    pub fn chip_death(&self, seq: u64) -> bool {
        self.decide(|k| matches!(k, FaultKind::ChipDeath), seq).is_some()
    }

    /// Should border transfer `seq` be corrupted in flight?
    pub fn corrupt_exchange(&self, seq: u64) -> bool {
        self.decide(|k| matches!(k, FaultKind::CorruptExchange), seq)
            .is_some()
    }

    /// Should the worker handling request `seq` wedge? Returns the stall
    /// duration in milliseconds.
    pub fn worker_stall(&self, seq: u64) -> Option<u64> {
        match self.decide(|k| matches!(k, FaultKind::WorkerStall { .. }), seq) {
            Some(FaultKind::WorkerStall { ms }) => Some(ms),
            _ => None,
        }
    }

    /// Should the client drop its connection before sending request `seq`?
    pub fn connection_drop(&self, seq: u64) -> bool {
        self.decide(|k| matches!(k, FaultKind::ConnectionDrop), seq)
            .is_some()
    }

    /// Should the batch for request `seq` run slow? Returns the added
    /// latency in milliseconds.
    pub fn slow_model(&self, seq: u64) -> Option<u64> {
        match self.decide(|k| matches!(k, FaultKind::SlowModel { .. }), seq) {
            Some(FaultKind::SlowModel { ms }) => Some(ms),
            _ => None,
        }
    }

    /// Parse a CLI chaos spec.
    ///
    /// Grammar: `SEED` alone, or `SEED:rule[,rule...]` where each rule is
    /// `kind@trigger`:
    ///
    /// * kinds — `chip-death`, `corrupt`, `stall:MS`, `drop`, `slow:MS`
    /// * triggers — `always`, `nth:N`, `every:N`, `prob:P`
    ///
    /// `SEED` alone expands to a default chaos mix (worker stalls and slow
    /// batches at low probability, an occasional connection drop):
    /// `SEED:slow:20@prob:0.1,stall:50@prob:0.05,drop@prob:0.05`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let (seed_str, rules_str) = match spec.split_once(':') {
            Some((s, r)) => (s, Some(r)),
            None => (spec, None),
        };
        let seed: u64 = seed_str
            .parse()
            .map_err(|_| format!("chaos spec: bad seed {seed_str:?}"))?;
        let mut plan = FaultPlan::new(seed);
        let Some(rules_str) = rules_str else {
            return Ok(plan
                .rule(FaultKind::SlowModel { ms: 20 }, Trigger::Prob(0.1))
                .rule(FaultKind::WorkerStall { ms: 50 }, Trigger::Prob(0.05))
                .rule(FaultKind::ConnectionDrop, Trigger::Prob(0.05)));
        };
        for rule in rules_str.split(',') {
            let (kind_str, trig_str) = rule
                .split_once('@')
                .ok_or_else(|| format!("chaos spec: rule {rule:?} missing '@trigger'"))?;
            let kind = match kind_str.split_once(':') {
                None => match kind_str {
                    "chip-death" => FaultKind::ChipDeath,
                    "corrupt" => FaultKind::CorruptExchange,
                    "drop" => FaultKind::ConnectionDrop,
                    other => return Err(format!("chaos spec: unknown kind {other:?}")),
                },
                Some((name, ms)) => {
                    let ms: u64 = ms
                        .parse()
                        .map_err(|_| format!("chaos spec: bad duration {ms:?}"))?;
                    match name {
                        "stall" => FaultKind::WorkerStall { ms },
                        "slow" => FaultKind::SlowModel { ms },
                        other => return Err(format!("chaos spec: unknown kind {other:?}")),
                    }
                }
            };
            let trigger = match trig_str.split_once(':') {
                None if trig_str == "always" => Trigger::Always,
                Some(("nth", n)) => Trigger::Nth(
                    n.parse()
                        .map_err(|_| format!("chaos spec: bad nth {n:?}"))?,
                ),
                Some(("every", n)) => Trigger::Every(
                    n.parse()
                        .map_err(|_| format!("chaos spec: bad every {n:?}"))?,
                ),
                Some(("prob", p)) => {
                    let p: f64 = p
                        .parse()
                        .map_err(|_| format!("chaos spec: bad prob {p:?}"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("chaos spec: prob {p} outside [0, 1]"));
                    }
                    Trigger::Prob(p)
                }
                _ => return Err(format!("chaos spec: unknown trigger {trig_str:?}")),
            };
            plan.rules.push(FaultRule { kind, trigger });
        }
        Ok(plan)
    }
}

/// Fold a halo payload's bits into a parity byte. XOR-folding detects every
/// single-bit flip (each payload bit lands in exactly one checksum bit), the
/// fault model `CorruptExchange` injects. Mirrored in
/// `python/tests/test_resilience_mirror.py`.
pub fn halo_checksum(bits: u32) -> u8 {
    let h = bits ^ (bits >> 16);
    let b = h ^ (h >> 8);
    (b & 0xff) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_fires() {
        let plan = FaultPlan::none();
        for seq in 0..1000 {
            assert!(!plan.chip_death(seq));
            assert!(!plan.corrupt_exchange(seq));
            assert!(plan.worker_stall(seq).is_none());
            assert!(!plan.connection_drop(seq));
            assert!(plan.slow_model(seq).is_none());
        }
        assert_eq!(plan.counters().total(), 0);
        assert!(plan.is_empty());
    }

    #[test]
    fn schedule_triggers_fire_exactly_when_asked() {
        let plan = FaultPlan::new(1)
            .rule(FaultKind::ChipDeath, Trigger::Nth(3))
            .rule(FaultKind::CorruptExchange, Trigger::Every(4));
        let deaths: Vec<u64> = (0..10).filter(|&s| plan.chip_death(s)).collect();
        assert_eq!(deaths, vec![3]);
        let corrupt: Vec<u64> = (0..10).filter(|&s| plan.corrupt_exchange(s)).collect();
        assert_eq!(corrupt, vec![0, 4, 8]);
        let c = plan.counters();
        assert_eq!(c.chip_deaths, 1);
        assert_eq!(c.corrupt_exchanges, 3);
        assert_eq!(c.total(), 4);
    }

    #[test]
    fn prob_trigger_is_deterministic_per_seed_and_roughly_calibrated() {
        let a = FaultPlan::new(42).rule(FaultKind::ConnectionDrop, Trigger::Prob(0.25));
        let b = FaultPlan::new(42).rule(FaultKind::ConnectionDrop, Trigger::Prob(0.25));
        let fa: Vec<bool> = (0..4000).map(|s| a.connection_drop(s)).collect();
        let fb: Vec<bool> = (0..4000).map(|s| b.connection_drop(s)).collect();
        assert_eq!(fa, fb, "same seed must make identical decisions");
        let hits = fa.iter().filter(|&&f| f).count();
        assert!(
            (800..=1200).contains(&hits),
            "p=0.25 over 4000 draws fired {hits} times"
        );
        let c = FaultPlan::new(43).rule(FaultKind::ConnectionDrop, Trigger::Prob(0.25));
        let fc: Vec<bool> = (0..4000).map(|s| c.connection_drop(s)).collect();
        assert_ne!(fa, fc, "different seeds should differ somewhere");
    }

    #[test]
    fn sites_draw_independent_decisions() {
        // Same trigger probability on two kinds: the fire patterns must not
        // be identical, because the site tag is mixed into the hash.
        let plan = FaultPlan::new(7)
            .rule(FaultKind::ChipDeath, Trigger::Prob(0.5))
            .rule(FaultKind::ConnectionDrop, Trigger::Prob(0.5));
        let a: Vec<bool> = (0..256).map(|s| plan.chip_death(s)).collect();
        let b: Vec<bool> = (0..256).map(|s| plan.connection_drop(s)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn duration_kinds_return_their_payload() {
        let plan = FaultPlan::new(9)
            .rule(FaultKind::WorkerStall { ms: 120 }, Trigger::Nth(2))
            .rule(FaultKind::SlowModel { ms: 35 }, Trigger::Always);
        assert_eq!(plan.worker_stall(1), None);
        assert_eq!(plan.worker_stall(2), Some(120));
        assert_eq!(plan.slow_model(77), Some(35));
    }

    #[test]
    fn parse_round_trips_the_grammar() {
        let plan = FaultPlan::parse("11:chip-death@nth:3,stall:50@prob:0.1,corrupt@every:8")
            .expect("valid spec");
        assert_eq!(plan.seed(), 11);
        assert_eq!(plan.rules.len(), 3);
        assert_eq!(
            plan.rules[1],
            FaultRule {
                kind: FaultKind::WorkerStall { ms: 50 },
                trigger: Trigger::Prob(0.1),
            }
        );
        // Seed-only spec expands to the default mix.
        let mix = FaultPlan::parse("5").expect("seed-only spec");
        assert_eq!(mix.seed(), 5);
        assert_eq!(mix.rules.len(), 3);
        // Errors are typed, not panics.
        assert!(FaultPlan::parse("x").is_err());
        assert!(FaultPlan::parse("3:martian@always").is_err());
        assert!(FaultPlan::parse("3:drop@prob:1.5").is_err());
        assert!(FaultPlan::parse("3:drop").is_err());
    }

    #[test]
    fn halo_checksum_detects_every_single_bit_flip() {
        for bits in [0u32, 1, 0x3f80_0000, 0xdead_beef, u32::MAX] {
            let base = halo_checksum(bits);
            for flip in 0..32 {
                assert_ne!(
                    halo_checksum(bits ^ (1 << flip)),
                    base,
                    "flip of bit {flip} in {bits:#x} went undetected"
                );
            }
        }
    }
}

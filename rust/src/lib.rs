//! # Hyperdrive — multi-chip systolically scalable BWN inference engine
//!
//! Full-system reproduction of *Hyperdrive: A Multi-Chip Systolically
//! Scalable Binary-Weight CNN Inference Engine* (Andri, Cavigelli, Rossi,
//! Benini — CS.DC 2018) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, Python)** — the BWN convolution hot-spot as a
//!   Pallas kernel and the per-layer JAX model, AOT-lowered to HLO text
//!   artifacts (`python/compile/`, `make artifacts`).
//! * **L3 (this crate)** — everything the paper's silicon + board does,
//!   fronted by **one backend-agnostic API**: [`engine::Engine`].
//!
//! ## The unified engine
//!
//! The paper's point is system-level: one accelerator abstraction that
//! scales from a single chip to a 2D systolic mesh without the caller
//! caring which is underneath. [`engine::Engine::builder`] is that seam —
//! it fronts three interchangeable execution backends:
//!
//! | backend | selected by | runs |
//! |---|---|---|
//! | functional-sim | *(default)* | [`simulator::chip`] — Algorithm 1, bit-exact FP16 |
//! | mesh-sim | `.mesh(r, c)` / `.auto_mesh()` | [`simulator::mesh`] — §V border/corner exchange |
//! | pjrt | `.artifacts(dir)` *(feature `pjrt`)* | [`runtime`] — AOT Pallas artifacts on PJRT |
//!
//! ```no_run
//! use hyperdrive::engine::Engine;
//!
//! # fn main() -> Result<(), hyperdrive::engine::EngineError> {
//! let engine = Engine::builder()
//!     .model("resnet34@224x224") // resolved through model::NetworkRegistry
//!     .auto_mesh()               // plan the smallest FMM-fitting chip mesh
//!     .vdd(0.5)
//!     .vbb(1.5)
//!     .build()?;
//! println!("{}", engine.report().summary());
//! # Ok(()) }
//! ```
//!
//! On top of the backends sits the multi-model serving subsystem
//! ([`engine::InferenceService`]): N named models hosted concurrently
//! under one shared worker budget, bounded per-model queues with typed
//! admission policies, per-request results (one failing request never
//! discards another's output), live per-model p50/p99/throughput
//! metrics and hot add/remove. [`engine::Engine::serve`] is the
//! single-model batch wrapper over it. Every engine also yields a
//! single typed [`engine::EngineReport`] (schedule, WCL/memory plan,
//! energy breakdown, serve statistics) that the CLI, the examples, the
//! benches and [`report`] all consume.
//!
//! ## Subsystems
//!
//! The typed model-description API — spec grammar, network registry and
//! weight sources — lives in [`model`] and is how every entry point
//! names a network ([`model::ModelSpec`] / [`model::NetworkRegistry`] /
//! [`model::WeightSource`]).
//! The CNN graph IR and model zoo ([`network`]), binary-weight packing
//! and streaming ([`bwn`]), the Algorithm-1 scheduler, worst-case-layer
//! memory planner and multi-chip tiling ([`coordinator`]), the
//! functional + cycle-accurate chip/mesh simulator ([`simulator`]), the
//! calibrated energy/power model ([`energy`]), the state-of-the-art
//! comparator models ([`baselines`]), the PJRT runtime that executes the
//! AOT artifacts ([`runtime`]) and the paper-table generators
//! ([`report`]).
//!
//! The chip itself (GF 22 nm FDX) is replaced by a simulator calibrated
//! to the paper's measured silicon numbers; see `DESIGN.md` for the
//! substitution table and the per-experiment index.

pub mod baselines;
pub mod bwn;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod faults;
pub mod model;
pub mod network;
pub mod report;
pub mod runtime;
pub mod simulator;
pub mod testkit;
pub mod util;
pub mod video;

/// Architecture parameters of one Hyperdrive chip (§III, §VI).
///
/// Defaults are the taped-out configuration: `M×N = 7×7` spatial tiles,
/// `C = 16` output-channel parallelism, 6.4 Mbit of FM memory, a weight
/// buffer of 512 × 3×3 × C binary weights, and one FP16 multiplier per
/// spatial tile (49 total) shared by the C depth-wise Tile-PUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChipConfig {
    /// M — vertical spatial tile parallelism.
    pub m: usize,
    /// N — horizontal spatial tile parallelism.
    pub n: usize,
    /// C — output-channel parallelism of each spatial tile.
    pub c: usize,
    /// Feature-map memory capacity in 16-bit words (6.4 Mbit = 400 kword).
    pub fmm_words: usize,
    /// Weight buffer capacity in binary weights (512 kernels × 3·3 × C).
    pub wbuf_bits: usize,
    /// FM word width in bits (FP16 → 16).
    pub fm_bits: usize,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            m: 7,
            n: 7,
            c: 16,
            fmm_words: 400 * 1024,
            wbuf_bits: 512 * 9 * 16,
            fm_bits: 16,
        }
    }
}

impl ChipConfig {
    /// Peak MACs per cycle (one per Tile-PU): `C·M·N`.
    pub fn macs_per_cycle(&self) -> usize {
        self.c * self.m * self.n
    }

    /// Peak Op/cycle (1 MAC = 2 Op — the paper's counting convention).
    pub fn ops_per_cycle(&self) -> usize {
        2 * self.macs_per_cycle()
    }

    /// Post-processing throughput in Op/cycle: one FP16 multiplier per
    /// spatial tile (`M·N` = 49 in the taped-out chip).
    pub fn post_ops_per_cycle(&self) -> usize {
        self.m * self.n
    }

    /// FMM capacity in bits.
    pub fn fmm_bits(&self) -> usize {
        self.fmm_words * self.fm_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taped_out_chip_peak_throughput() {
        let c = ChipConfig::default();
        assert_eq!(c.macs_per_cycle(), 784);
        assert_eq!(c.ops_per_cycle(), 1568); // Tbl III baseline row
        assert_eq!(c.post_ops_per_cycle(), 49);
        assert_eq!(c.fmm_bits(), 6_553_600); // 6.4 Mbit
    }
}

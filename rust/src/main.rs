//! `hyperdrive` — CLI for the Hyperdrive reproduction.
//!
//! Subcommands:
//!   report <table1|table2|table3|table4|table5|table6|fig8|fig9|fig10|fig11|all>
//!   list-models                                             the model registry
//!   serve     --model A[,B,...] [--requests N] [--mix M] [--workers W]
//!             multi-model InferenceService on a synthetic workload, or
//!             [--listen ADDR [--conn-limit N]] a TCP wire-protocol server
//!   loadgen   --connect ADDR --model A[,B,...] [--connections C] [--in-flight K]
//!             pipelined TCP load generator against a serve --listen instance;
//!             --video FRAMES replays seeded synthetic clips per connection
//!   video     --model SPEC [--frames N] [--delta D] streaming-video soak
//!             (temporal dirty-tile reuse, bit-exact vs full recompute), or
//!             --pool RxC --model A,B,... multi-model sub-mesh placement
//!   run-e2e   [--artifacts DIR] [--batch N] [--workers N]   end-to-end PJRT serving
//!   simulate  --model SPEC [--mesh RxC] [--vdd V] [--vbb V]
//!   mesh      --model SPEC
//!   help
//!
//! Networks are named by `--model` spec strings (`resnet34@512x1024`,
//! `yolov3@416`, `manifest:artifacts#hypernet20`) resolved through
//! `model::NetworkRegistry`; the legacy `--net NAME [--height H]
//! [--width W]` triple is still accepted and mapped onto a spec. A bare
//! `--net NAME` now uses the registry's default resolution (the paper's
//! per-network evaluation size — e.g. `yolov3` is 320x320, not the old
//! blanket 224x224). All
//! execution goes through the unified `engine::Engine` façade — the
//! CLI never touches the coordinator or the energy model directly.
//! Options accept both `--key value` and `--key=value`; duplicates are
//! rejected. (Hand-rolled argument parsing: the offline vendored crate
//! set has no `clap`; see DESIGN.md §Substitutions.)

use std::collections::HashMap;
use std::fmt;
use std::io::Write as _;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use hyperdrive::engine::{
    run_loadgen, AdmissionPolicy, BackendKind, BreakerPolicy, DepthwisePolicy, Engine, EngineError,
    InferRequest, InferenceService, LoadGenConfig, ModelConfig, RetryPolicy, ServeError,
    ServeOptions, WireError, WireServer,
};
use hyperdrive::faults::FaultPlan;
use hyperdrive::model::NetworkRegistry;
use hyperdrive::report;
use hyperdrive::util::SplitMix64;
use hyperdrive::video::{MeshPlacement, SynthVideo, VideoError};
use hyperdrive::ChipConfig;

fn usage() -> &'static str {
    "usage: hyperdrive <command> [options]\n\
     commands:\n\
       report <table1..table6|fig8..fig11|border|ablations|all>\n\
       list-models\n\
       serve --model SPEC[,SPEC...] [--requests N] [--mix round-robin|random]\n\
             [--workers W] [--queue-depth D] [--admission block|reject|timeout:MS]\n\
             [--max-batch B] [--batch-wait-ms MS] [--seed S]\n\
             [--deadline-ms MS] [--breaker FAILS:P99MS:COOLMS] [--watchdog-ms MS]\n\
             [--chaos SPEC]   resilience: per-request deadline, circuit\n\
             breaker, stalled-worker watchdog, seeded fault injection\n\
             [--listen ADDR [--conn-limit N]]   serve over TCP instead of a\n\
             synthetic in-process workload (port 0 picks a free port;\n\
             --conn-limit 0 serves forever)\n\
       loadgen --connect ADDR --model NAME[,NAME...] [--connections C]\n\
             [--in-flight K] [--requests N] [--seed S] [--retries N]\n\
             [--backoff-ms MS] [--deadline-ms MS] [--chaos SPEC]\n\
             [--video FRAMES [--video-delta D]]   drive a serve --listen\n\
             instance over TCP; --video replays seeded synthetic clips\n\
             (FRAMES sequential frames per clip) instead of static inputs\n\
       video --model SPEC [--frames N] [--delta D] [--tile T] [--eps E]\n\
             [--mesh RxC] [--seed S]   streaming-video soak: temporal\n\
             dirty-tile reuse on one FrameSession, checked bit-exact\n\
             against per-frame full recompute, with saved-MAC reporting\n\
       video --pool RxC --model SPEC[,SPEC...] [--min-chips N] [--frames N]\n\
             [--delta D] [--seed S]   carve one chip pool into per-model\n\
             sub-meshes and serve every model concurrently\n\
       run-e2e [--artifacts DIR] [--batch N] [--workers N]\n\
       simulate --model SPEC [--mesh RxC] [--vdd V] [--vbb V] [--threads N]\n\
       mesh --model SPEC\n\
       help\n\
     model specs: NAME[@HxW|@N] (see list-models) or manifest:DIR[#NET],\n\
     e.g. --model resnet34@512x1024, --model yolov3@416,\n\
     --model manifest:artifacts#hypernet20\n\
     (legacy: --net NAME [--height H] [--width W])\n\
     chaos specs: SEED alone (default chaos mix) or SEED:kind@trigger[,...]\n\
     with kinds chip-death|corrupt|stall:MS|drop|slow:MS and triggers\n\
     always|nth:N|every:N|prob:P, e.g. --chaos 7:slow:20@prob:0.1,drop@every:16\n\
     options may be given as `--key value` or `--key=value`; each key at most once"
}

/// Structured option-parsing errors of the unified CLI path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OptError {
    /// Token did not start with `--`.
    NotAnOption(String),
    /// `--key` given without a value.
    MissingValue(String),
    /// The same `--key` given more than once.
    Duplicate(String),
    /// A value failed to parse (key, value, expected).
    BadValue(String, String, &'static str),
}

impl fmt::Display for OptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OptError::NotAnOption(a) => write!(f, "expected --option, got `{a}`"),
            OptError::MissingValue(k) => write!(f, "--{k} needs a value"),
            OptError::Duplicate(k) => write!(f, "duplicate option --{k}"),
            OptError::BadValue(k, v, want) => {
                write!(f, "bad --{k} value `{v}`: expected {want}")
            }
        }
    }
}

/// Errors of the CLI: option parsing, engine failures, serving
/// admission failures, usage.
#[derive(Debug)]
enum CliError {
    Opt(OptError),
    Engine(EngineError),
    Serve(ServeError),
    Wire(WireError),
    Video(VideoError),
    Usage(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::Opt(e) => write!(f, "{e}"),
            CliError::Engine(e) => write!(f, "{e}"),
            CliError::Serve(e) => write!(f, "{e}"),
            CliError::Wire(e) => write!(f, "{e}"),
            CliError::Video(e) => write!(f, "{e}"),
            CliError::Usage(m) => write!(f, "{m}"),
        }
    }
}

impl From<OptError> for CliError {
    fn from(e: OptError) -> Self {
        CliError::Opt(e)
    }
}

impl From<EngineError> for CliError {
    fn from(e: EngineError) -> Self {
        CliError::Engine(e)
    }
}

impl From<ServeError> for CliError {
    fn from(e: ServeError) -> Self {
        CliError::Serve(e)
    }
}

impl From<WireError> for CliError {
    fn from(e: WireError) -> Self {
        CliError::Wire(e)
    }
}

impl From<VideoError> for CliError {
    fn from(e: VideoError) -> Self {
        CliError::Video(e)
    }
}

/// Parse `--key value` / `--key=value` options into a map; duplicate
/// keys are rejected.
fn parse_opts(args: &[String]) -> Result<HashMap<String, String>, OptError> {
    let mut m = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let body = a
            .strip_prefix("--")
            .ok_or_else(|| OptError::NotAnOption(a.clone()))?;
        let (key, val) = match body.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => {
                let v = it
                    .next()
                    .ok_or_else(|| OptError::MissingValue(body.to_string()))?;
                (body.to_string(), v.clone())
            }
        };
        if m.insert(key.clone(), val).is_some() {
            return Err(OptError::Duplicate(key));
        }
    }
    Ok(m)
}

/// Parse an option's value, defaulting when absent.
fn opt_parse<T: std::str::FromStr>(
    opts: &HashMap<String, String>,
    key: &'static str,
    default: T,
    want: &'static str,
) -> Result<T, OptError> {
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| OptError::BadValue(key.to_string(), v.clone(), want)),
    }
}

/// Parse `--breaker FAILS:P99MS:COOLMS` into a [`BreakerPolicy`]
/// (consecutive-failure trip threshold, Degraded p99 latency bound in
/// ms — `inf` disables the latency signal — and Open cooldown in ms).
fn parse_breaker(spec: &str) -> Result<BreakerPolicy, OptError> {
    let bad = || OptError::BadValue("breaker".into(), spec.into(), "FAILS:P99MS:COOLMS");
    let mut parts = spec.splitn(3, ':');
    let fails = parts.next().and_then(|s| s.parse::<u64>().ok());
    let p99 = parts.next().and_then(|s| s.parse::<f64>().ok());
    let cool = parts.next().and_then(|s| s.parse::<u64>().ok());
    match (fails, p99, cool) {
        (Some(consecutive_failures), Some(p99_ms), Some(cooldown_ms))
            if consecutive_failures > 0 && p99_ms > 0.0 =>
        {
            Ok(BreakerPolicy {
                consecutive_failures,
                p99_ms,
                cooldown_ms,
            })
        }
        _ => Err(bad()),
    }
}

/// Parse `--chaos SPEC` through [`FaultPlan::parse`], mapping grammar
/// errors onto the CLI's structured option error.
fn parse_chaos(opts: &HashMap<String, String>) -> Result<Option<Arc<FaultPlan>>, OptError> {
    match opts.get("chaos") {
        None => Ok(None),
        Some(spec) => FaultPlan::parse(spec)
            .map(|plan| Some(Arc::new(plan)))
            .map_err(|_| {
                OptError::BadValue(
                    "chaos".into(),
                    spec.clone(),
                    "SEED or SEED:kind@trigger[,...] (see `hyperdrive help`)",
                )
            }),
    }
}

/// The model spec of a command: `--model SPEC`, or the legacy
/// `--net NAME [--height H] [--width W]` triple mapped onto a spec
/// (`default_res` fills in for a bare `--net` when the command's
/// historical default differs from the registry's, as `mesh` does).
fn resolve_spec(
    opts: &HashMap<String, String>,
    default_res: Option<(usize, usize)>,
) -> Result<String, CliError> {
    match (opts.get("model"), opts.get("net")) {
        (Some(_), Some(_)) => Err(CliError::Usage(
            "give --model or --net, not both".into(),
        )),
        (Some(m), None) => {
            if opts.contains_key("height") || opts.contains_key("width") {
                return Err(CliError::Usage(
                    "--model carries its resolution (`name@HxW`); drop --height/--width".into(),
                ));
            }
            Ok(m.clone())
        }
        (None, Some(n)) => {
            let explicit = opts.contains_key("height") || opts.contains_key("width");
            match (explicit, default_res) {
                (false, None) => Ok(n.clone()), // registry default resolution
                (false, Some((h, w))) => Ok(format!("{n}@{h}x{w}")),
                (true, _) => {
                    // A missing dimension falls back to the command's
                    // historical default (mesh: 1024x2048), else to the
                    // old simulate defaults (224, square).
                    let dh = default_res.map_or(224, |(h, _)| h);
                    let h: usize = opt_parse(opts, "height", dh, "a positive integer")?;
                    let dw = default_res.map_or(h, |(_, w)| w);
                    let w: usize = opt_parse(opts, "width", dw, "a positive integer")?;
                    Ok(format!("{n}@{h}x{w}"))
                }
            }
        }
        (None, None) => Err(CliError::Usage(
            "--model <spec> required (try `hyperdrive list-models`)".into(),
        )),
    }
}

fn cmd_list_models() -> String {
    NetworkRegistry::builtin().render_listing()
}

fn cmd_report(which: &str, cfg: &ChipConfig) -> Result<String, CliError> {
    Ok(match which {
        "table1" => report::table1(),
        "table2" => report::table2(),
        "table3" => report::table3(cfg),
        "table4" => report::table4(cfg),
        "table5" => report::table5(cfg),
        "table6" => report::table6(cfg),
        "fig8" => report::fig8(cfg),
        "fig9" => report::fig9(cfg),
        "fig10" => report::fig10(cfg),
        "fig11" => report::fig11(cfg),
        "border" => report::border_memories(cfg),
        "ablations" => report::ablations(cfg),
        "all" => report::all(cfg),
        other => return Err(CliError::Usage(format!("unknown report `{other}`"))),
    })
}

fn cmd_run_e2e(opts: &HashMap<String, String>) -> Result<String, CliError> {
    let dir = opts
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let batch: usize = opt_parse(opts, "batch", 8, "a positive integer")?;
    let workers: usize = opt_parse(opts, "workers", 2, "a positive integer")?;

    // The manifest spec names both the network and the artifact dir.
    let engine = Engine::builder()
        .model(format!("manifest:{dir}"))
        .backend(BackendKind::Pjrt)
        .build()?;
    let input = engine.golden("e2e_input.bin")?;
    let golden = engine.golden("e2e_golden.bin")?;
    let inputs: Vec<Vec<f32>> = (0..batch.max(1)).map(|_| input.clone()).collect();
    let (outs, stats) = engine
        .serve(
            &inputs,
            &ServeOptions {
                workers,
                ..ServeOptions::default()
            },
        )?
        .outputs()?;
    let max_err = outs[0]
        .iter()
        .zip(&golden)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    let report = engine.report_with_serve(stats);
    Ok(format!(
        "{} e2e on {}:\n{}\nlogits[0..4] = {:?}\nmax |logits − JAX golden| = {:.3e} {}",
        report.network,
        engine.describe(),
        report.serve_summary(),
        &outs[0][..4.min(outs[0].len())],
        max_err,
        if max_err < 1e-3 { "— MATCH" } else { "— MISMATCH" }
    ))
}

/// `serve`: host every listed model in one `InferenceService` and
/// drive a synthetic multi-model workload through it, printing the
/// per-model metrics table.
fn cmd_serve(opts: &HashMap<String, String>) -> Result<String, CliError> {
    let specs: Vec<String> = opts
        .get("model")
        .ok_or_else(|| {
            CliError::Usage("serve needs --model SPEC[,SPEC...] (try `hyperdrive list-models`)".into())
        })?
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    if specs.is_empty() {
        return Err(CliError::Usage("serve needs at least one model spec".into()));
    }
    let requests: usize = opt_parse(opts, "requests", 32, "a positive integer")?;
    let workers: usize = opt_parse(opts, "workers", 4, "a positive integer")?;
    let queue_depth: usize = opt_parse(opts, "queue-depth", 8, "a positive integer")?;
    let max_batch: usize = opt_parse(opts, "max-batch", 1, "a positive integer")?;
    let batch_wait_ms: u64 = opt_parse(opts, "batch-wait-ms", 0, "an unsigned integer")?;
    let seed: u64 = opt_parse(opts, "seed", 7, "an unsigned integer")?;
    let mix = opts.get("mix").map(String::as_str).unwrap_or("round-robin");
    if mix != "round-robin" && mix != "random" {
        return Err(
            OptError::BadValue("mix".into(), mix.into(), "round-robin|random").into(),
        );
    }
    let admission = match opts.get("admission").map(String::as_str) {
        None | Some("block") => AdmissionPolicy::Block,
        Some("reject") => AdmissionPolicy::Reject,
        Some(other) => match other
            .strip_prefix("timeout:")
            .and_then(|ms| ms.parse::<u64>().ok())
        {
            Some(ms) => AdmissionPolicy::Timeout(ms),
            None => {
                return Err(OptError::BadValue(
                    "admission".into(),
                    other.into(),
                    "block|reject|timeout:MS",
                )
                .into())
            }
        },
    };

    let mut builder = InferenceService::builder()
        .workers(workers)
        .queue_depth(queue_depth)
        .admission(admission)
        .max_batch(max_batch)
        .batch_wait_ms(batch_wait_ms);
    if let Some(ms) = opts.get("deadline-ms") {
        let ms: u64 = ms.parse().map_err(|_| {
            OptError::BadValue("deadline-ms".into(), ms.clone(), "an unsigned integer")
        })?;
        builder = builder.deadline_ms(ms);
    }
    if let Some(spec) = opts.get("breaker") {
        builder = builder.breaker(parse_breaker(spec)?);
    }
    if let Some(ms) = opts.get("watchdog-ms") {
        let ms: u64 = ms.parse().ok().filter(|&ms| ms > 0).ok_or_else(|| {
            OptError::BadValue("watchdog-ms".into(), ms.clone(), "a positive integer")
        })?;
        builder = builder.watchdog_ms(ms);
    }
    let chaos = parse_chaos(opts)?;
    if let Some(plan) = &chaos {
        builder = builder.faults(plan.clone());
    }
    for spec in &specs {
        builder = builder.model_spec(spec.as_str());
    }
    let service = builder.build()?;

    if let Some(listen) = opts.get("listen") {
        let conn_limit: u64 = opt_parse(opts, "conn-limit", 0, "an unsigned integer")?;
        return cmd_serve_listen(service, listen, conn_limit, workers, &specs, chaos);
    }

    let mut rng = SplitMix64::new(seed);
    let mut tickets = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    for i in 0..requests {
        let model = match mix {
            "round-robin" => &specs[i % specs.len()],
            _ => &specs[rng.next_below(specs.len())],
        };
        let len = service.input_len(model).expect("model is hosted");
        let input: Vec<f32> = (0..len).map(|_| rng.next_sym()).collect();
        match service.submit(InferRequest {
            model: model.clone(),
            input: input.into(),
            id: i as u64,
            deadline_ms: None,
        }) {
            Ok(t) => tickets.push(t),
            // Reject/Timeout admission drops are part of the workload
            // report, not a CLI failure.
            Err(ServeError::QueueFull { .. }) | Err(ServeError::AdmissionTimeout { .. }) => {
                rejected += 1;
            }
            Err(e) => return Err(e.into()),
        }
    }
    let (mut ok, mut failed) = (0usize, 0usize);
    for ticket in tickets {
        match ticket.wait() {
            Ok(_) => ok += 1,
            Err(_) => failed += 1,
        }
    }
    let metrics = service.shutdown();
    let batching = if max_batch > 1 {
        format!(
            "batching: up to {max_batch} requests per pass, {} weight-stream words saved\n",
            metrics.total_weight_traffic_saved()
        )
    } else {
        String::new()
    };
    let chaos_line = match &chaos {
        Some(plan) => format!("chaos (seed {}): {}\n", plan.seed(), plan.counters()),
        None => String::new(),
    };
    Ok(format!(
        "served {requests} requests over {} model(s) on {workers} workers ({mix} mix): \
         {ok} ok, {failed} failed, {rejected} rejected at admission\n{}{batching}{chaos_line}",
        specs.len(),
        metrics.render_table()
    ))
}

/// `serve --listen`: expose the service over TCP. With a `--conn-limit`
/// the server runs until that many connections have come *and gone*
/// (the CI smoke's termination condition); with 0 it serves forever.
/// The "listening on" line is printed (and flushed) before the first
/// accept so a driver script can scrape the port.
fn cmd_serve_listen(
    service: InferenceService,
    listen: &str,
    conn_limit: u64,
    workers: usize,
    specs: &[String],
    chaos: Option<Arc<FaultPlan>>,
) -> Result<String, CliError> {
    let service = Arc::new(service);
    let server = WireServer::start(service.clone(), listen)?;
    println!("listening on {}", server.local_addr());
    let _ = std::io::stdout().flush();
    loop {
        let s = server.stats();
        if conn_limit > 0 && s.connections >= conn_limit && s.active == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let wire = server.shutdown();
    // The server's threads are joined, so ours is the last Arc; the
    // fallback only covers a caller that cloned the service elsewhere.
    let metrics = match Arc::try_unwrap(service) {
        Ok(svc) => svc.shutdown(),
        Err(arc) => arc.metrics(),
    };
    let chaos_line = match &chaos {
        Some(plan) => format!("\nchaos (seed {}): {}", plan.seed(), plan.counters()),
        None => String::new(),
    };
    Ok(format!(
        "served {} connection(s) over {} model(s) on {workers} workers\n{}\
         wire: {} connections, {} frames in, {} frames out, {} malformed, \
         {} infer requests, peak in-flight {}{chaos_line}",
        wire.connections,
        specs.len(),
        metrics.render_table(),
        wire.connections,
        wire.frames_rx,
        wire.frames_tx,
        wire.malformed,
        wire.infer_rx,
        wire.max_in_flight
    ))
}

/// `loadgen`: drive a `serve --listen` instance over TCP with C
/// pipelined connections and report client-observed throughput,
/// latency quantiles and backpressure.
fn cmd_loadgen(opts: &HashMap<String, String>) -> Result<String, CliError> {
    let addr = opts
        .get("connect")
        .ok_or_else(|| CliError::Usage("loadgen needs --connect HOST:PORT".into()))?
        .clone();
    let models: Vec<String> = opts
        .get("model")
        .ok_or_else(|| {
            CliError::Usage("loadgen needs --model NAME[,NAME...] (the server's model names)".into())
        })?
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    if models.is_empty() {
        return Err(CliError::Usage("loadgen needs at least one model name".into()));
    }
    let connections: usize = opt_parse(opts, "connections", 4, "a positive integer")?;
    let in_flight: usize = opt_parse(opts, "in-flight", 8, "a positive integer")?;
    let requests: usize = opt_parse(opts, "requests", 64, "a positive integer")?;
    let seed: u64 = opt_parse(opts, "seed", 7, "an unsigned integer")?;
    if connections == 0 || in_flight == 0 || requests == 0 {
        return Err(CliError::Usage(
            "loadgen needs --connections, --in-flight and --requests all ≥ 1".into(),
        ));
    }
    let max_retries: u32 = opt_parse(opts, "retries", 0, "an unsigned integer")?;
    let base_backoff_ms: u64 =
        opt_parse(opts, "backoff-ms", RetryPolicy::default().base_backoff_ms, "an unsigned integer")?;
    let deadline_ms: Option<u64> = match opts.get("deadline-ms") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            OptError::BadValue("deadline-ms".into(), v.clone(), "an unsigned integer")
        })?),
    };
    let chaos = parse_chaos(opts)?;
    let video: Option<usize> = match opts.get("video") {
        None => None,
        Some(v) => Some(v.parse().ok().filter(|&n| n > 0).ok_or_else(|| {
            OptError::BadValue("video".into(), v.clone(), "a positive frame count")
        })?),
    };
    let video_delta: f64 = opt_parse(opts, "video-delta", 0.05, "a fraction in [0,1]")?;
    if !(0.0..=1.0).contains(&video_delta) {
        return Err(CliError::Usage("--video-delta must be within [0,1]".into()));
    }
    if video.is_none() && opts.contains_key("video-delta") {
        return Err(CliError::Usage(
            "--video-delta only applies with --video FRAMES".into(),
        ));
    }
    let report = run_loadgen(&LoadGenConfig {
        addr,
        connections,
        in_flight,
        requests,
        models,
        seed,
        retry: RetryPolicy {
            max_retries,
            base_backoff_ms,
        },
        deadline_ms,
        chaos: chaos.clone(),
        video,
        video_delta,
    })?;
    let chaos_line = match &chaos {
        Some(plan) => format!("\nchaos (seed {}): {}", plan.seed(), plan.counters()),
        None => String::new(),
    };
    let video_line = match video {
        Some(f) => format!(
            "\nvideo replay: {f}-frame clips per connection, delta {:.1}%",
            video_delta * 100.0
        ),
        None => String::new(),
    };
    Ok(format!(
        "loadgen: {} sent, {} ok, {} failed, {} rejected, {} transport errors \
         over {} connections × in-flight {} ({} lost in flight, {} retried)\n\
         → {:.1} req/s, mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms{video_line}{chaos_line}",
        report.sent,
        report.ok,
        report.failed,
        report.rejected_backpressure,
        report.transport_errors,
        report.connections,
        report.in_flight,
        report.lost,
        report.retried,
        report.req_per_s,
        report.mean_ms,
        report.p50_ms,
        report.p99_ms
    ))
}

/// `video`: streaming-video soak. Runs a seeded synthetic clip through
/// one [`hyperdrive::video::FrameSession`] (temporal dirty-tile reuse),
/// re-runs every frame through the engine's ordinary full-recompute
/// path, and asserts the outputs are bit-identical while reporting the
/// per-frame MAC/traffic savings. With `--pool RxC` it instead carves
/// one chip pool into per-model sub-meshes and serves all models
/// concurrently (the multi-model placement half of the subsystem).
fn cmd_video(opts: &HashMap<String, String>, cfg: &ChipConfig) -> Result<String, CliError> {
    if opts.contains_key("pool") {
        return cmd_video_pool(opts);
    }
    let spec = resolve_spec(opts, None)?;
    let frames: usize = opt_parse(opts, "frames", 8, "a positive integer")?;
    let delta: f64 = opt_parse(opts, "delta", 0.05, "a fraction in [0,1]")?;
    let tile: usize = opt_parse(opts, "tile", 8, "a positive integer")?;
    let eps: f32 = opt_parse(opts, "eps", 0.0, "a non-negative threshold")?;
    let seed: u64 = opt_parse(opts, "seed", 7, "an unsigned integer")?;
    if frames == 0 || tile == 0 || !(0.0..=1.0).contains(&delta) || !(0.0..).contains(&eps) {
        return Err(CliError::Usage(
            "video needs --frames and --tile ≥ 1, --delta in [0,1], --eps ≥ 0".into(),
        ));
    }
    let mut builder = Engine::builder().model(spec.as_str()).chip(*cfg);
    if let Some(mesh) = opts.get("mesh") {
        let (r, c) = mesh
            .split_once('x')
            .ok_or_else(|| OptError::BadValue("mesh".into(), mesh.clone(), "RxC, e.g. 2x2"))?;
        let rows = r
            .parse()
            .map_err(|_| OptError::BadValue("mesh".into(), mesh.clone(), "integer mesh rows"))?;
        let cols = c
            .parse()
            .map_err(|_| OptError::BadValue("mesh".into(), mesh.clone(), "integer mesh cols"))?;
        builder = builder.mesh(rows, cols);
    }
    let engine = builder.build()?;
    let net = engine.network();
    let (in_ch, in_h, in_w) = (net.in_ch, net.in_h, net.in_w);
    let mut session = engine.video_session(tile, eps)?;
    let mut clip = SynthVideo::new(in_ch, in_h, in_w, delta, seed);
    let mut out = format!(
        "video: {} ({in_ch}x{in_h}x{in_w}), {frames} frames, delta {:.1}%, \
         tile {tile}, eps {eps}, {:?} backend\n",
        net.name,
        delta * 100.0,
        engine.backend_kind()
    );
    let mut exact = 0usize;
    let mut total_done: u64 = 0;
    let mut total_saved: u64 = 0;
    for _ in 0..frames {
        let frame = clip.next_flat();
        let (video_out, stats) = session.process_flat(&frame)?;
        let full_out = engine.infer(&frame)?;
        if video_out == full_out {
            exact += 1;
        }
        total_done += stats.access.accumulates;
        total_saved += stats.access.saved_macs;
        out.push_str(&format!(
            "frame {}: input {:5.1}% dirty, MACs {:5.1}% dirty → {:5.1}% MACs saved, \
             {} stream words ({} saved)\n",
            stats.frame,
            stats.input_dirty_fraction * 100.0,
            stats.mac_dirty_fraction * 100.0,
            stats.saved_mac_ratio() * 100.0,
            stats.access.stream_words,
            stats.access.saved_stream_words,
        ));
    }
    if exact != frames {
        return Err(CliError::Usage(format!(
            "BIT-EXACTNESS VIOLATION: only {exact}/{frames} frames matched full recompute"
        )));
    }
    let denom = (total_done + total_saved).max(1);
    out.push_str(&format!(
        "bit-exact vs full recompute on all {frames} frames\n\
         totals: {total_saved} of {denom} MACs saved ({:.1}%)",
        total_saved as f64 / denom as f64 * 100.0
    ));
    Ok(out)
}

/// `video --pool RxC`: place every `--model` spec onto one chip pool
/// (first-fit rectangular sub-meshes), host them all in one
/// [`InferenceService`], stream a seeded clip per model, and report the
/// ownership diagram plus per-model serving metrics.
fn cmd_video_pool(opts: &HashMap<String, String>) -> Result<String, CliError> {
    let pool = opts.get("pool").expect("checked by cmd_video");
    let (r, c) = pool
        .split_once('x')
        .ok_or_else(|| OptError::BadValue("pool".into(), pool.clone(), "RxC, e.g. 4x4"))?;
    let rows: usize = r
        .parse()
        .ok()
        .filter(|&n| n > 0)
        .ok_or_else(|| OptError::BadValue("pool".into(), pool.clone(), "integer pool rows"))?;
    let cols: usize = c
        .parse()
        .ok()
        .filter(|&n| n > 0)
        .ok_or_else(|| OptError::BadValue("pool".into(), pool.clone(), "integer pool cols"))?;
    let models: Vec<String> = opts
        .get("model")
        .ok_or_else(|| CliError::Usage("video --pool needs --model SPEC[,SPEC...]".into()))?
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    if models.is_empty() {
        return Err(CliError::Usage("video --pool needs at least one model".into()));
    }
    let min_chips: usize = opt_parse(opts, "min-chips", 4, "a positive integer")?;
    let frames: usize = opt_parse(opts, "frames", 4, "a positive integer")?;
    let delta: f64 = opt_parse(opts, "delta", 0.05, "a fraction in [0,1]")?;
    let seed: u64 = opt_parse(opts, "seed", 7, "an unsigned integer")?;
    if min_chips == 0 || frames == 0 || !(0.0..=1.0).contains(&delta) {
        return Err(CliError::Usage(
            "video --pool needs --min-chips and --frames ≥ 1, --delta in [0,1]".into(),
        ));
    }
    let mut placement = MeshPlacement::new(rows, cols);
    let mut sb = InferenceService::builder().workers(models.len());
    for spec in &models {
        let sm = placement
            .place(spec, min_chips)
            .map_err(|e| CliError::Usage(format!("placement failed: {e}")))?;
        sb = sb.model(spec.clone(), ModelConfig::new(spec.as_str()).sub_mesh(sm));
    }
    let service = sb.build()?;
    let mut tickets = Vec::new();
    for (mi, spec) in models.iter().enumerate() {
        let len = service
            .input_len(spec)
            .expect("model hosted above");
        let mut clip = SynthVideo::flat(len, delta, seed ^ ((mi as u64) << 8));
        for f in 0..frames {
            tickets.push(service.submit(InferRequest {
                model: spec.clone(),
                input: clip.next_flat().into(),
                id: (mi * frames + f) as u64,
                deadline_ms: None,
            })?);
        }
    }
    for t in tickets {
        t.wait()?;
    }
    let metrics = service.shutdown();
    let mut out = format!(
        "pool {rows}x{cols}, {} model(s), {} chips free\n{}",
        models.len(),
        placement.free_chips(),
        placement.render()
    );
    for m in &metrics.per_model {
        let sm = placement.get(&m.model).expect("placed above");
        out.push_str(&format!(
            "{}: sub-mesh {sm}, {} submitted, {} completed, {} failed, \
             mean {:.2} ms, p99 {:.2} ms\n",
            m.model, m.submitted, m.completed, m.failed, m.mean_ms, m.p99_ms
        ));
    }
    out.push_str(&format!(
        "total: {} submitted, {} completed, {} failed",
        metrics.total_submitted(),
        metrics.total_completed(),
        metrics.total_failed()
    ));
    Ok(out)
}

fn cmd_simulate(opts: &HashMap<String, String>, cfg: &ChipConfig) -> Result<String, CliError> {
    let spec = resolve_spec(opts, None)?;
    let vdd: f64 = opt_parse(opts, "vdd", 0.5, "a voltage")?;
    let vbb: f64 = opt_parse(opts, "vbb", 1.5, "a voltage")?;

    let mut builder = Engine::builder()
        .model(spec.as_str())
        .chip(*cfg)
        .depthwise(DepthwisePolicy::FullRate)
        .vdd(vdd)
        .vbb(vbb);
    // Datapath worker threads; absent → available_parallelism.
    if let Some(t) = opts.get("threads") {
        let n: usize = t
            .parse()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| OptError::BadValue("threads".into(), t.clone(), "a positive integer"))?;
        builder = builder.threads(n);
    }
    builder = match opts.get("mesh") {
        Some(mesh) => {
            let (r, c) = mesh.split_once('x').ok_or_else(|| {
                OptError::BadValue("mesh".into(), mesh.clone(), "RxC, e.g. 5x10")
            })?;
            let rows = r.parse().map_err(|_| {
                OptError::BadValue("mesh".into(), mesh.clone(), "integer mesh rows")
            })?;
            let cols = c.parse().map_err(|_| {
                OptError::BadValue("mesh".into(), mesh.clone(), "integer mesh cols")
            })?;
            builder.mesh(rows, cols)
        }
        None => builder.auto_mesh(),
    };
    let engine = builder.build()?;
    Ok(engine.report().summary())
}

fn cmd_mesh(opts: &HashMap<String, String>, cfg: &ChipConfig) -> Result<String, CliError> {
    // Historical default: Cityscapes-class 2048×1024 frames (§V).
    let spec = resolve_spec(opts, Some((1024, 2048)))?;
    let engine = Engine::builder()
        .model(spec.as_str())
        .chip(*cfg)
        .auto_mesh()
        .build()?;
    Ok(engine.report().mesh_summary())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg = ChipConfig::default();
    let result = match args.first().map(String::as_str) {
        Some("report") => match args.get(1) {
            Some(which) => cmd_report(which, &cfg),
            None => Err(CliError::Usage("report needs an argument".into())),
        },
        Some("list-models") => Ok(cmd_list_models()),
        Some("serve") => parse_opts(&args[1..])
            .map_err(CliError::from)
            .and_then(|o| cmd_serve(&o)),
        Some("loadgen") => parse_opts(&args[1..])
            .map_err(CliError::from)
            .and_then(|o| cmd_loadgen(&o)),
        Some("video") => parse_opts(&args[1..])
            .map_err(CliError::from)
            .and_then(|o| cmd_video(&o, &cfg)),
        Some("run-e2e") => parse_opts(&args[1..])
            .map_err(CliError::from)
            .and_then(|o| cmd_run_e2e(&o)),
        Some("simulate") => parse_opts(&args[1..])
            .map_err(CliError::from)
            .and_then(|o| cmd_simulate(&o, &cfg)),
        Some("mesh") => parse_opts(&args[1..])
            .map_err(CliError::from)
            .and_then(|o| cmd_mesh(&o, &cfg)),
        Some("help") | None => Ok(usage().to_string()),
        Some(other) => Err(CliError::Usage(format!("unknown command `{other}`\n{}", usage()))),
    };
    match result {
        Ok(text) => {
            println!("{text}");
            ExitCode::SUCCESS
        }
        Err(msg) => {
            eprintln!("error: {msg}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_space_and_equals_syntax() {
        let m = parse_opts(&args(&["--net", "resnet34", "--height=224"])).unwrap();
        assert_eq!(m.get("net").unwrap(), "resnet34");
        assert_eq!(m.get("height").unwrap(), "224");
    }

    #[test]
    fn rejects_duplicate_keys() {
        let e = parse_opts(&args(&["--net", "a", "--net=b"])).unwrap_err();
        assert_eq!(e, OptError::Duplicate("net".into()));
        let e = parse_opts(&args(&["--vdd=0.5", "--vdd", "0.6"])).unwrap_err();
        assert_eq!(e, OptError::Duplicate("vdd".into()));
    }

    #[test]
    fn rejects_missing_value_and_bare_words() {
        assert_eq!(
            parse_opts(&args(&["--net"])).unwrap_err(),
            OptError::MissingValue("net".into())
        );
        assert_eq!(
            parse_opts(&args(&["net"])).unwrap_err(),
            OptError::NotAnOption("net".into())
        );
    }

    #[test]
    fn equals_value_may_contain_equals() {
        let m = parse_opts(&args(&["--expr=a=b"])).unwrap();
        assert_eq!(m.get("expr").unwrap(), "a=b");
    }

    #[test]
    fn simulate_goes_through_the_engine() {
        let cfg = ChipConfig::default();
        let opts = parse_opts(&args(&["--net", "resnet34", "--height=224"])).unwrap();
        let out = cmd_simulate(&opts, &cfg).unwrap();
        assert!(out.contains("ResNet-34"), "{out}");
        assert!(out.contains("TOp/s/W"), "{out}");
    }

    #[test]
    fn simulate_accepts_model_specs() {
        let cfg = ChipConfig::default();
        let opts = parse_opts(&args(&["--model", "resnet34@224x224"])).unwrap();
        let out = cmd_simulate(&opts, &cfg).unwrap();
        assert!(out.contains("ResNet-34"), "{out}");
    }

    #[test]
    fn legacy_net_flags_map_onto_specs() {
        // Bare --net → registry default resolution.
        let opts = parse_opts(&args(&["--net", "resnet34"])).unwrap();
        assert_eq!(resolve_spec(&opts, None).unwrap(), "resnet34");
        // --height/--width → explicit spec resolution.
        let opts = parse_opts(&args(&["--net", "resnet34", "--height", "512"])).unwrap();
        assert_eq!(resolve_spec(&opts, None).unwrap(), "resnet34@512x512");
        // Command default (the mesh command's 2048×1024 frames).
        let opts = parse_opts(&args(&["--net", "resnet34"])).unwrap();
        assert_eq!(
            resolve_spec(&opts, Some((1024, 2048))).unwrap(),
            "resnet34@1024x2048"
        );
        // A partial legacy dimension keeps the command default for the
        // other dimension.
        let opts = parse_opts(&args(&["--net", "resnet34", "--width", "2048"])).unwrap();
        assert_eq!(
            resolve_spec(&opts, Some((1024, 2048))).unwrap(),
            "resnet34@1024x2048"
        );
        let opts = parse_opts(&args(&["--net", "resnet34", "--height", "512"])).unwrap();
        assert_eq!(
            resolve_spec(&opts, Some((1024, 2048))).unwrap(),
            "resnet34@512x2048"
        );
    }

    #[test]
    fn conflicting_model_flags_are_usage_errors() {
        let opts = parse_opts(&args(&["--model", "resnet34", "--net", "resnet50"])).unwrap();
        assert!(matches!(
            resolve_spec(&opts, None).unwrap_err(),
            CliError::Usage(_)
        ));
        let opts = parse_opts(&args(&["--model", "resnet34", "--height", "224"])).unwrap();
        assert!(matches!(
            resolve_spec(&opts, None).unwrap_err(),
            CliError::Usage(_)
        ));
        let opts = parse_opts(&args(&[])).unwrap();
        assert!(matches!(
            resolve_spec(&opts, None).unwrap_err(),
            CliError::Usage(_)
        ));
    }

    #[test]
    fn unknown_model_is_a_structured_engine_error() {
        let cfg = ChipConfig::default();
        let opts = parse_opts(&args(&["--model", "resnet99"])).unwrap();
        let err = cmd_simulate(&opts, &cfg).unwrap_err();
        match err {
            CliError::Engine(EngineError::Model(_)) => {}
            other => panic!("expected a model error, got {other}"),
        }
    }

    #[test]
    fn list_models_prints_the_registry() {
        let out = cmd_list_models();
        for name in ["resnet18", "resnet34", "yolov3", "hypernet20"] {
            assert!(out.contains(name), "missing {name} in:\n{out}");
        }
        assert!(out.contains("Mbit"), "{out}");
    }

    #[test]
    fn threads_option_is_validated() {
        let cfg = ChipConfig::default();
        let opts = parse_opts(&args(&["--net", "resnet34", "--threads", "2"])).unwrap();
        let out = cmd_simulate(&opts, &cfg).unwrap();
        assert!(out.contains("ResNet-34"), "{out}");
        for bad in ["0", "-1", "two"] {
            let opts =
                parse_opts(&args(&["--net", "resnet34", "--threads", bad])).unwrap();
            let err = cmd_simulate(&opts, &cfg).unwrap_err();
            assert!(
                matches!(err, CliError::Opt(OptError::BadValue(_, _, _))),
                "--threads {bad}: {err}"
            );
        }
    }

    #[test]
    fn bad_mesh_option_is_a_structured_error() {
        let cfg = ChipConfig::default();
        let opts = parse_opts(&args(&["--net", "resnet34", "--mesh", "5by10"])).unwrap();
        let err = cmd_simulate(&opts, &cfg).unwrap_err();
        assert!(matches!(err, CliError::Opt(OptError::BadValue(_, _, _))), "{err}");
    }

    #[test]
    fn serve_subcommand_round_robin_smoke() {
        let opts = parse_opts(&args(&[
            "--model",
            "hypernet20",
            "--requests",
            "6",
            "--workers",
            "2",
        ]))
        .unwrap();
        let out = cmd_serve(&opts).unwrap();
        assert!(out.contains("6 ok, 0 failed"), "{out}");
        assert!(out.contains("hypernet20"), "{out}");
        assert!(out.contains("p99 ms"), "{out}");
        assert!(out.contains("total: 6 submitted, 6 completed"), "{out}");
    }

    #[test]
    fn serve_subcommand_random_mix_over_two_models() {
        let opts = parse_opts(&args(&[
            "--model",
            "hypernet20,resnet18@32x32",
            "--requests",
            "4",
            "--workers",
            "2",
            "--mix",
            "random",
            "--admission",
            "timeout:5000",
        ]))
        .unwrap();
        let out = cmd_serve(&opts).unwrap();
        assert!(out.contains("2 model(s)"), "{out}");
        assert!(out.contains("resnet18@32x32"), "{out}");
    }

    #[test]
    fn serve_subcommand_batches_with_max_batch() {
        let opts = parse_opts(&args(&[
            "--model",
            "hypernet20",
            "--requests",
            "8",
            "--workers",
            "1",
            "--max-batch",
            "4",
            "--batch-wait-ms",
            "2000",
        ]))
        .unwrap();
        let out = cmd_serve(&opts).unwrap();
        assert!(out.contains("8 ok, 0 failed"), "{out}");
        assert!(out.contains("batching: up to 4 requests per pass"), "{out}");
        // With one worker and a hold window the passes coalesce, so the
        // functional backend's amortization must show up as savings.
        assert!(!out.contains("0 weight-stream words saved"), "{out}");
    }

    #[test]
    fn serve_subcommand_validates_options() {
        // Missing --model is a usage error.
        let opts = parse_opts(&args(&["--requests", "4"])).unwrap();
        assert!(matches!(cmd_serve(&opts).unwrap_err(), CliError::Usage(_)));
        // Bad mix / admission values are structured option errors.
        for bad in [
            &["--model", "hypernet20", "--mix", "zigzag"][..],
            &["--model", "hypernet20", "--admission", "sometimes"][..],
            &["--model", "hypernet20", "--admission", "timeout:soon"][..],
        ] {
            let opts = parse_opts(&args(bad)).unwrap();
            let err = cmd_serve(&opts).unwrap_err();
            assert!(
                matches!(err, CliError::Opt(OptError::BadValue(_, _, _))),
                "{bad:?}: {err}"
            );
        }
        // A zero thread budget is the service builder's typed error.
        let opts = parse_opts(&args(&["--model", "hypernet20", "--workers", "0"])).unwrap();
        let err = cmd_serve(&opts).unwrap_err();
        assert!(
            matches!(err, CliError::Engine(EngineError::Builder(_))),
            "{err}"
        );
        // An unknown spec surfaces the model resolution error.
        let opts = parse_opts(&args(&["--model", "resnet99"])).unwrap();
        let err = cmd_serve(&opts).unwrap_err();
        assert!(
            matches!(err, CliError::Engine(EngineError::Model(_))),
            "{err}"
        );
    }

    #[test]
    fn loadgen_subcommand_validates_options() {
        // Missing --connect / --model are usage errors.
        let opts = parse_opts(&args(&["--model", "hypernet20"])).unwrap();
        assert!(matches!(cmd_loadgen(&opts).unwrap_err(), CliError::Usage(_)));
        let opts = parse_opts(&args(&["--connect", "127.0.0.1:9"])).unwrap();
        assert!(matches!(cmd_loadgen(&opts).unwrap_err(), CliError::Usage(_)));
        // Zero knobs are usage errors too.
        let opts = parse_opts(&args(&[
            "--connect",
            "127.0.0.1:9",
            "--model",
            "hypernet20",
            "--connections",
            "0",
        ]))
        .unwrap();
        assert!(matches!(cmd_loadgen(&opts).unwrap_err(), CliError::Usage(_)));
    }

    #[test]
    fn breaker_spec_parses_thresholds_and_rejects_nonsense() {
        let pol = parse_breaker("5:250:1000").unwrap();
        assert_eq!(pol.consecutive_failures, 5);
        assert_eq!(pol.p99_ms, 250.0);
        assert_eq!(pol.cooldown_ms, 1000);
        // `inf` disables the latency signal but keeps the failure trip.
        let pol = parse_breaker("3:inf:500").unwrap();
        assert!(pol.p99_ms.is_infinite());
        for bad in ["", "5", "5:250", "0:250:1000", "5:-1:1000", "a:b:c"] {
            assert!(parse_breaker(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn resilience_flags_are_validated() {
        // A malformed chaos spec is a structured option error on both
        // subcommands (loadgen checks it before dialing out).
        let opts = parse_opts(&args(&["--model", "hypernet20", "--chaos", "7:warp@always"])).unwrap();
        assert!(matches!(
            cmd_serve(&opts).unwrap_err(),
            CliError::Opt(OptError::BadValue(_, _, _))
        ));
        let opts = parse_opts(&args(&[
            "--connect",
            "127.0.0.1:9",
            "--model",
            "hypernet20",
            "--chaos",
            "not-a-seed",
        ]))
        .unwrap();
        assert!(matches!(
            cmd_loadgen(&opts).unwrap_err(),
            CliError::Opt(OptError::BadValue(_, _, _))
        ));
        // Bad breaker / watchdog / deadline values too.
        for bad in [
            &["--model", "hypernet20", "--breaker", "5:250"][..],
            &["--model", "hypernet20", "--watchdog-ms", "0"][..],
            &["--model", "hypernet20", "--deadline-ms", "soon"][..],
        ] {
            let opts = parse_opts(&args(bad)).unwrap();
            assert!(
                matches!(cmd_serve(&opts).unwrap_err(), CliError::Opt(OptError::BadValue(_, _, _))),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn serve_subcommand_reports_chaos_counters() {
        // A 1 ms always-slow plan never fails anything but must show up
        // in the chaos ledger line.
        let opts = parse_opts(&args(&[
            "--model",
            "hypernet20",
            "--requests",
            "4",
            "--workers",
            "2",
            "--deadline-ms",
            "60000",
            "--breaker",
            "8:inf:1000",
            "--watchdog-ms",
            "60000",
            "--chaos",
            "5:slow:1@always",
        ]))
        .unwrap();
        let out = cmd_serve(&opts).unwrap();
        assert!(out.contains("4 ok, 0 failed"), "{out}");
        assert!(out.contains("chaos (seed 5): "), "{out}");
        assert!(out.contains("4 slow batches"), "{out}");
    }

    #[test]
    fn loadgen_drives_a_listening_server_end_to_end() {
        // A real loopback round trip: serve --listen on port 0, then
        // the loadgen path against it.
        let service = Arc::new(
            InferenceService::builder()
                .model_spec("hypernet20")
                .workers(2)
                .queue_depth(8)
                .build()
                .unwrap(),
        );
        let server = WireServer::start(service.clone(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let opts = parse_opts(&args(&[
            "--connect",
            &addr,
            "--model",
            "hypernet20",
            "--connections",
            "2",
            "--in-flight",
            "4",
            "--requests",
            "8",
        ]))
        .unwrap();
        let out = cmd_loadgen(&opts).unwrap();
        assert!(out.contains("8 sent, 8 ok, 0 failed"), "{out}");
        assert!(out.contains("2 connections × in-flight 4"), "{out}");
        let stats = server.shutdown();
        assert_eq!(stats.infer_rx, 8);
        assert_eq!(stats.connections, 2);
        assert_eq!(stats.malformed, 0);
        let metrics = match Arc::try_unwrap(service) {
            Ok(svc) => svc.shutdown(),
            Err(_) => panic!("server shutdown should drop its service handle"),
        };
        assert_eq!(metrics.total_completed(), 8);
    }

    #[test]
    fn video_subcommand_validates_options() {
        let cfg = ChipConfig::default();
        // Missing --model is a usage error.
        let opts = parse_opts(&args(&["--frames", "2"])).unwrap();
        assert!(matches!(cmd_video(&opts, &cfg).unwrap_err(), CliError::Usage(_)));
        // Out-of-range knobs are usage errors.
        for bad in [
            &["--model", "hypernet20", "--delta", "1.5"][..],
            &["--model", "hypernet20", "--frames", "0"][..],
        ] {
            let opts = parse_opts(&args(bad)).unwrap();
            assert!(
                matches!(cmd_video(&opts, &cfg).unwrap_err(), CliError::Usage(_)),
                "{bad:?}"
            );
        }
        // Malformed mesh / pool shapes are structured option errors.
        for bad in [
            &["--model", "hypernet20", "--mesh", "2by2"][..],
            &["--pool", "4by4", "--model", "hypernet20"][..],
        ] {
            let opts = parse_opts(&args(bad)).unwrap();
            assert!(
                matches!(
                    cmd_video(&opts, &cfg).unwrap_err(),
                    CliError::Opt(OptError::BadValue(_, _, _))
                ),
                "{bad:?}"
            );
        }
        // --video-delta on loadgen without --video is a usage error,
        // and a zero --video frame count is a structured option error.
        let opts = parse_opts(&args(&[
            "--connect",
            "127.0.0.1:9",
            "--model",
            "hypernet20",
            "--video-delta",
            "0.1",
        ]))
        .unwrap();
        assert!(matches!(cmd_loadgen(&opts).unwrap_err(), CliError::Usage(_)));
        let opts = parse_opts(&args(&[
            "--connect",
            "127.0.0.1:9",
            "--model",
            "hypernet20",
            "--video",
            "0",
        ]))
        .unwrap();
        assert!(matches!(
            cmd_loadgen(&opts).unwrap_err(),
            CliError::Opt(OptError::BadValue(_, _, _))
        ));
    }

    #[test]
    fn video_subcommand_soaks_bit_exact() {
        let cfg = ChipConfig::default();
        let opts = parse_opts(&args(&[
            "--model",
            "hypernet20",
            "--frames",
            "3",
            "--delta",
            "0.05",
            "--seed",
            "11",
        ]))
        .unwrap();
        let out = cmd_video(&opts, &cfg).unwrap();
        assert!(out.contains("bit-exact vs full recompute on all 3 frames"), "{out}");
        assert!(out.contains("MACs saved"), "{out}");
        // Frame 0 is the full-recompute prime; later frames save work.
        assert!(out.contains("frame 0: input 100.0% dirty"), "{out}");
    }

    #[test]
    fn video_pool_places_and_serves_two_models() {
        let cfg = ChipConfig::default();
        // Two service names resolving to the same small network keep
        // this placement round-trip cheap.
        let opts = parse_opts(&args(&[
            "--pool",
            "4x4",
            "--model",
            "hypernet20,hypernet20@32x32",
            "--frames",
            "2",
            "--min-chips",
            "4",
        ]))
        .unwrap();
        let out = cmd_video(&opts, &cfg).unwrap();
        assert!(out.contains("pool 4x4, 2 model(s)"), "{out}");
        assert!(out.contains("sub-mesh 2x2@"), "{out}");
        assert!(out.contains("total: 4 submitted, 4 completed, 0 failed"), "{out}");
    }
}

//! `hyperdrive` — CLI for the Hyperdrive reproduction.
//!
//! Subcommands:
//!   report <table1|table2|table3|table4|table5|table6|fig8|fig9|fig10|fig11|all>
//!   run-e2e   [--artifacts DIR] [--batch N]      end-to-end PJRT inference
//!   simulate  --net NAME [--height H] [--width W] [--mesh RxC]
//!   mesh      --net NAME [--height H] [--width W]
//!   help
//!
//! (Hand-rolled argument parsing: the offline vendored crate set has no
//! `clap`; see DESIGN.md §Substitutions.)

use std::collections::HashMap;
use std::process::ExitCode;

use hyperdrive::coordinator::schedule::{schedule_network_mesh, DepthwisePolicy};
use hyperdrive::coordinator::tiling::{self, plan_mesh};
use hyperdrive::coordinator::wcl;
use hyperdrive::energy::model::energy_per_image;
use hyperdrive::network::{zoo, Network};
use hyperdrive::report;
use hyperdrive::runtime::InferenceEngine;
use hyperdrive::util::fmt_bits;
use hyperdrive::ChipConfig;

fn usage() -> &'static str {
    "usage: hyperdrive <command> [options]\n\
     commands:\n\
       report <table1..table6|fig8..fig11|border|all>\n\
       run-e2e [--artifacts DIR] [--batch N]\n\
       simulate --net <resnet18|resnet34|resnet50|resnet152|shufflenet|yolov3|hypernet20>\n\
                [--height H] [--width W] [--mesh RxC] [--vdd V] [--vbb V]\n\
       mesh --net NAME [--height H] [--width W]\n\
       help"
}

/// Parse `--key value` options into a map.
fn parse_opts(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut m = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --option, got `{a}`"))?;
        let val = it.next().ok_or_else(|| format!("--{key} needs a value"))?;
        m.insert(key.to_string(), val.clone());
    }
    Ok(m)
}

fn build_net(name: &str, h: usize, w: usize) -> Result<Network, String> {
    Ok(match name {
        "resnet18" => zoo::resnet18(h, w),
        "resnet34" => zoo::resnet34(h, w),
        "resnet50" => zoo::resnet50(h, w),
        "resnet152" => zoo::resnet152(h, w),
        "shufflenet" => zoo::shufflenet(h, w),
        "yolov3" => zoo::yolov3(h, w),
        "hypernet20" => zoo::hypernet20(),
        other => return Err(format!("unknown network `{other}`")),
    })
}

fn cmd_report(which: &str, cfg: &ChipConfig) -> Result<String, String> {
    Ok(match which {
        "table1" => report::table1(),
        "table2" => report::table2(),
        "table3" => report::table3(cfg),
        "table4" => report::table4(cfg),
        "table5" => report::table5(cfg),
        "table6" => report::table6(cfg),
        "fig8" => report::fig8(cfg),
        "fig9" => report::fig9(cfg),
        "fig10" => report::fig10(cfg),
        "fig11" => report::fig11(cfg),
        "border" => report::border_memories(cfg),
        "ablations" => report::ablations(cfg),
        "all" => report::all(cfg),
        other => return Err(format!("unknown report `{other}`")),
    })
}

fn cmd_run_e2e(opts: &HashMap<String, String>) -> Result<String, String> {
    let dir = opts
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let batch: usize = opts
        .get("batch")
        .map(|v| v.parse().map_err(|_| "bad --batch"))
        .transpose()?
        .unwrap_or(8);
    let engine = InferenceEngine::load(dir).map_err(|e| format!("{e:#}"))?;
    let input = engine
        .manifest
        .golden("e2e_input.bin")
        .map_err(|e| format!("{e:#}"))?;
    let golden = engine
        .manifest
        .golden("e2e_golden.bin")
        .map_err(|e| format!("{e:#}"))?;
    let inputs: Vec<Vec<f32>> = (0..batch).map(|_| input.clone()).collect();
    let (outs, stats) = engine.serve(&inputs).map_err(|e| format!("{e:#}"))?;
    let max_err = outs[0]
        .iter()
        .zip(&golden)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    Ok(format!(
        "HyperNet-20 e2e on PJRT ({} artifacts, platform {}):\n\
         batch {} served in {:.2} ms total — mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms\n\
         throughput {:.2} MOp/s (Rust+PJRT CPU path)\n\
         logits[0..4] = {:?}\n\
         max |logits − JAX golden| = {:.3e} {}",
        engine.runtime.loaded(),
        engine.runtime.platform(),
        stats.requests,
        stats.total_s * 1e3,
        stats.mean_ms,
        stats.p50_ms,
        stats.p99_ms,
        stats.ops_per_s / 1e6,
        &outs[0][..4.min(outs[0].len())],
        max_err,
        if max_err < 1e-3 { "— MATCH" } else { "— MISMATCH" }
    ))
}

fn cmd_simulate(opts: &HashMap<String, String>, cfg: &ChipConfig) -> Result<String, String> {
    let name = opts.get("net").ok_or("--net required")?;
    let h: usize = opts.get("height").map_or(Ok(224), |v| v.parse()).map_err(|_| "bad --height")?;
    let w: usize = opts.get("width").map_or(Ok(h), |v| v.parse()).map_err(|_| "bad --width")?;
    let vdd: f64 = opts.get("vdd").map_or(Ok(0.5), |v| v.parse()).map_err(|_| "bad --vdd")?;
    let vbb: f64 = opts.get("vbb").map_or(Ok(1.5), |v| v.parse()).map_err(|_| "bad --vbb")?;
    let net = build_net(name, h, w)?;
    let plan = if let Some(mesh) = opts.get("mesh") {
        let (r, c) = mesh
            .split_once('x')
            .ok_or("expected --mesh RxC")?;
        tiling::plan_mesh_exact(
            &net,
            cfg,
            r.parse().map_err(|_| "bad mesh rows")?,
            c.parse().map_err(|_| "bad mesh cols")?,
        )
    } else {
        plan_mesh(&net, cfg)
    };
    let sched = schedule_network_mesh(&net, cfg, DepthwisePolicy::FullRate, plan.rows, plan.cols);
    let rep = energy_per_image(&net, cfg, &plan, vdd, vbb, DepthwisePolicy::FullRate);
    let a = wcl::analyze(&net);
    Ok(format!(
        "{} @ {}x{} on {}x{} chips ({} total)\n\
         ops {} | per-chip cycles {} | mesh utilization {:.1}%\n\
         WCL {} words ({}); per-chip WCL {} words\n\
         @({} V, {} V FBB): {:.1} fps, {:.0} GOp/s\n\
         core {:.2} mJ/im + I/O {:.2} mJ/im (weights {} + input {} + border {})\n\
         = {:.2} mJ/im → system efficiency {:.2} TOp/s/W",
        net.name,
        w,
        h,
        plan.rows,
        plan.cols,
        plan.chips(),
        fmt_bits(sched.total_ops()),
        sched.total_cycles(),
        100.0 * sched.utilization(cfg) / plan.chips() as f64,
        a.wcl_words,
        fmt_bits(a.wcl_bits(cfg.fm_bits)),
        plan.per_chip_wcl_words,
        vdd,
        vbb,
        rep.frame_rate_hz,
        rep.throughput_ops_s / 1e9,
        rep.core_j * 1e3,
        rep.io_j * 1e3,
        fmt_bits(rep.io.weights),
        fmt_bits(rep.io.input_fm),
        fmt_bits(rep.io.border),
        rep.total_j() * 1e3,
        rep.system_efficiency_ops_w() / 1e12,
    ))
}

fn cmd_mesh(opts: &HashMap<String, String>, cfg: &ChipConfig) -> Result<String, String> {
    let name = opts.get("net").ok_or("--net required")?;
    let h: usize = opts.get("height").map_or(Ok(1024), |v| v.parse()).map_err(|_| "bad --height")?;
    let w: usize = opts.get("width").map_or(Ok(2048), |v| v.parse()).map_err(|_| "bad --width")?;
    let net = build_net(name, h, w)?;
    let plan = plan_mesh(&net, cfg);
    let border = tiling::border_exchange_bits(&net, &plan, cfg.fm_bits);
    let mut types = String::new();
    for r in 0..plan.rows.min(4) {
        for c in 0..plan.cols.min(8) {
            types.push_str(&format!("{:?} ", tiling::chip_type(r, c, &plan)));
        }
        types.push('\n');
    }
    Ok(format!(
        "{} @ {}x{}: mesh {}x{} = {} chips\n\
         per-chip WCL {} words (FMM capacity {})\n\
         border exchange per inference: {}\n\
         chip types (top-left corner of the mesh):\n{}",
        net.name,
        w,
        h,
        plan.rows,
        plan.cols,
        plan.chips(),
        plan.per_chip_wcl_words,
        cfg.fmm_words,
        fmt_bits(border),
        types
    ))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg = ChipConfig::default();
    let result = match args.first().map(String::as_str) {
        Some("report") => match args.get(1) {
            Some(which) => cmd_report(which, &cfg),
            None => Err("report needs an argument".to_string()),
        },
        Some("run-e2e") => parse_opts(&args[1..]).and_then(|o| cmd_run_e2e(&o)),
        Some("simulate") => parse_opts(&args[1..]).and_then(|o| cmd_simulate(&o, &cfg)),
        Some("mesh") => parse_opts(&args[1..]).and_then(|o| cmd_mesh(&o, &cfg)),
        Some("help") | None => Ok(usage().to_string()),
        Some(other) => Err(format!("unknown command `{other}`\n{}", usage())),
    };
    match result {
        Ok(text) => {
            println!("{text}");
            ExitCode::SUCCESS
        }
        Err(msg) => {
            eprintln!("error: {msg}");
            ExitCode::FAILURE
        }
    }
}

//! # The unified model-description subsystem
//!
//! One API for getting a network (and its weights) into the system,
//! used by every entry point — the CLI (`--model`), the engine builder
//! (`Engine::builder().model(..)`), the examples and the benches:
//!
//! * [`ModelSpec`] — the parseable spec grammar (`resnet34@512x1024`,
//!   `yolov3@416`, `manifest:artifacts#hypernet20`) with typed
//!   [`SpecError`]s;
//! * [`NetworkRegistry`] — the registry that owns the zoo: builders are
//!   registered factories with resolution validation (non-divisible
//!   resolutions are typed [`ModelError::Resolution`] errors, not silent
//!   truncation) and output-shape inference;
//! * [`WeightSource`] — where parameters come from ([`Random`] seeded
//!   synthetic, [`ManifestBlobs`] trained AOT tensors, [`HostTensors`]
//!   caller-supplied), chosen per-model instead of per-call-site.
//!
//! ```
//! use hyperdrive::model;
//!
//! // Spec → network, through the built-in registry.
//! let net = model::network("resnet34@224x224")?;
//! assert_eq!(net.out_shape(), (512, 7, 7));
//!
//! // Spec → network + weight source.
//! let resolved = model::resolve("hypernet20")?;
//! let params = resolved.weights.params(&resolved.network, 16)?;
//! assert_eq!(params.steps.len(), resolved.network.steps.len());
//! # Ok::<(), model::ModelError>(())
//! ```

pub mod registry;
pub mod spec;
pub mod weights;

pub use registry::{
    ModelEntry, ModelError, ModelListing, NetworkRegistry, ResolvedModel, DEFAULT_SEED,
};
pub use spec::{ModelSpec, SpecError};
pub use weights::{HostTensors, ManifestBlobs, Random, StepTensors, WeightSource};

// Re-exported so report/bench code needs no direct `zoo` path.
pub use crate::network::zoo::projection_weight_bits;
pub use crate::network::ResolutionError;

use crate::network::Network;

/// Parse and resolve a spec string against the built-in registry.
pub fn resolve(spec: &str) -> Result<ResolvedModel, ModelError> {
    NetworkRegistry::builtin().resolve_str(spec)
}

/// [`resolve`], keeping only the network (tests, benches, tables).
pub fn network(spec: &str) -> Result<Network, ModelError> {
    Ok(resolve(spec)?.network)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convenience_resolvers_hit_the_builtin_registry() {
        assert_eq!(network("resnet34").unwrap().name, "ResNet-34");
        let m = resolve("tinyyolo@416x416").unwrap();
        assert_eq!(m.network.out_shape(), (255, 13, 13));
        assert!(matches!(
            network("nope").unwrap_err(),
            ModelError::UnknownModel { .. }
        ));
        assert!(matches!(
            network("resnet34@!!").unwrap_err(),
            ModelError::Spec(_)
        ));
    }
}

//! [`NetworkRegistry`] — the typed model registry that owns the zoo.
//! Builders are registered factories with resolution validation and
//! output-shape inference; [`NetworkRegistry::resolve`] turns a parsed
//! [`ModelSpec`] into a [`ResolvedModel`] (network + weight source).

use std::fmt;
use std::sync::Arc;

use crate::network::zoo::{self, ResolutionError};
use crate::network::Network;
use crate::runtime::NetworkManifest;

use super::spec::{ModelSpec, SpecError};
use super::weights::{ManifestBlobs, Random, WeightSource};

/// Seed of the [`Random`] weight source attached to registry-resolved
/// models (same default as `EngineBuilder::seed`).
pub const DEFAULT_SEED: u64 = 0x42;

/// Typed errors of model resolution.
#[derive(Debug)]
pub enum ModelError {
    /// The spec string failed to parse.
    Spec(SpecError),
    /// No registry entry with that name; carries the known names.
    UnknownModel { name: String, known: Vec<String> },
    /// The entry only exists at one input resolution (HyperNet-20's
    /// AOT twin) and a different one was requested.
    FixedResolution {
        name: String,
        requested: (usize, usize),
        fixed: (usize, usize),
    },
    /// The builder rejected the resolution (divisibility).
    Resolution(ResolutionError),
    /// The manifest could not be loaded or parsed.
    Manifest(String),
    /// The manifest describes a different network than `#name` asked for.
    ManifestNetworkMismatch { expected: String, found: String },
    /// A weight source could not materialize parameters.
    Weights(String),
}

impl fmt::Display for ModelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelError::Spec(e) => write!(f, "{e}"),
            ModelError::UnknownModel { name, known } => write!(
                f,
                "unknown model `{name}` — registered models: {}",
                known.join(", ")
            ),
            ModelError::FixedResolution {
                name,
                requested,
                fixed,
            } => write!(
                f,
                "model `{name}` has a fixed {}x{} input; requested {}x{}",
                fixed.0, fixed.1, requested.0, requested.1
            ),
            ModelError::Resolution(e) => write!(f, "{e}"),
            ModelError::Manifest(m) => write!(f, "manifest: {m}"),
            ModelError::ManifestNetworkMismatch { expected, found } => write!(
                f,
                "manifest describes network `{found}`, spec expected `{expected}`"
            ),
            ModelError::Weights(m) => write!(f, "weights: {m}"),
        }
    }
}

impl std::error::Error for ModelError {}

impl From<SpecError> for ModelError {
    fn from(e: SpecError) -> Self {
        ModelError::Spec(e)
    }
}

impl From<ResolutionError> for ModelError {
    fn from(e: ResolutionError) -> Self {
        ModelError::Resolution(e)
    }
}

/// One registered model: a validated factory plus the metadata the
/// registry needs for resolution checking, shape inference and the
/// `list-models` listing.
#[derive(Clone)]
pub struct ModelEntry {
    /// Registry name (the spec's `name` part).
    pub name: &'static str,
    /// One-line description for listings.
    pub description: &'static str,
    /// Default `(h, w)` image resolution.
    pub default_resolution: (usize, usize),
    /// Both dimensions must be divisible by this (the builder's
    /// truncating stride factors; see `zoo::ResolutionError`).
    pub stride_granularity: usize,
    /// The entry exists at exactly `default_resolution` (no override).
    pub fixed_resolution: bool,
    /// Output FM channels (shape inference).
    pub out_channels: usize,
    /// Total image→output-FM downsampling factor (shape inference).
    pub downsample: usize,
    builder: fn(usize, usize) -> Result<Network, ResolutionError>,
}

impl ModelEntry {
    /// A new entry for [`NetworkRegistry::register`]. The builder must
    /// itself reject resolutions it cannot realize exactly (see
    /// `zoo::check_resolution` for the pattern).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &'static str,
        description: &'static str,
        default_resolution: (usize, usize),
        stride_granularity: usize,
        fixed_resolution: bool,
        out_channels: usize,
        downsample: usize,
        builder: fn(usize, usize) -> Result<Network, ResolutionError>,
    ) -> ModelEntry {
        ModelEntry {
            name,
            description,
            default_resolution,
            stride_granularity,
            fixed_resolution,
            out_channels,
            downsample,
            builder,
        }
    }

    /// Build the network at `(h, w)`. The registry-level granularity
    /// check guards custom entries whose builder does not validate; the
    /// zoo builders additionally re-check themselves.
    pub fn build(&self, h: usize, w: usize) -> Result<Network, ModelError> {
        if h == 0 || w == 0 || h % self.stride_granularity != 0 || w % self.stride_granularity != 0
        {
            return Err(ModelError::Resolution(ResolutionError {
                network: self.name,
                h,
                w,
                granularity: self.stride_granularity,
            }));
        }
        Ok((self.builder)(h, w)?)
    }

    /// Infer the output FM shape `(c, h, w)` at an image resolution
    /// without building the network. Exact for every registered model:
    /// the stem divides exactly (enforced by `stride_granularity`) and
    /// chained same-padding `div_ceil` by 2 equals `div_ceil` by the
    /// product.
    pub fn output_shape(&self, h: usize, w: usize) -> (usize, usize, usize) {
        (
            self.out_channels,
            h.div_ceil(self.downsample),
            w.div_ceil(self.downsample),
        )
    }
}

/// A resolved model: the built network plus where its weights come from
/// (and, for manifest specs, the manifest itself for golden files).
pub struct ResolvedModel {
    /// The spec this model was resolved from.
    pub spec: ModelSpec,
    /// The built, shape-validated network.
    pub network: Network,
    /// Weight provisioning chosen per-model: [`Random`] for registry
    /// entries, [`ManifestBlobs`] for manifest specs.
    pub weights: Box<dyn WeightSource>,
    /// The loaded manifest for `manifest:` specs (`None` otherwise).
    pub manifest: Option<Arc<NetworkManifest>>,
}

/// One row of [`NetworkRegistry::listings`].
pub struct ModelListing {
    pub name: &'static str,
    pub default_resolution: (usize, usize),
    /// On-chip steps at the default resolution.
    pub steps: usize,
    /// Binary-weight megabits at the default resolution.
    pub weight_mbit: f64,
    pub description: &'static str,
}

/// The model registry: every network the system can run, by name.
///
/// [`NetworkRegistry::builtin`] registers the paper's zoo; callers can
/// [`register`](NetworkRegistry::register) additional entries (an entry
/// with an existing name replaces it). `Clone` is cheap (entries are
/// metadata + a builder fn pointer) — the multi-model serving layer
/// clones one registry per hosted model resolution.
#[derive(Clone)]
pub struct NetworkRegistry {
    entries: Vec<ModelEntry>,
}

impl NetworkRegistry {
    /// An empty registry.
    pub fn empty() -> NetworkRegistry {
        NetworkRegistry { entries: Vec::new() }
    }

    /// The built-in zoo: every network the paper evaluates plus the
    /// end-to-end validation network.
    pub fn builtin() -> NetworkRegistry {
        let mut r = NetworkRegistry::empty();
        let resnet = |name, builder: fn(usize, usize) -> Result<Network, ResolutionError>,
                      out_channels| ModelEntry {
            name,
            description: "",
            default_resolution: (224, 224),
            stride_granularity: zoo::STEM_GRANULARITY,
            fixed_resolution: false,
            out_channels,
            downsample: 32,
            builder,
        };
        r.register(ModelEntry {
            description: "ResNet-18, basic blocks (Fig. 4a)",
            ..resnet("resnet18", zoo::resnet18, 512)
        });
        r.register(ModelEntry {
            description: "ResNet-34 — the paper's main benchmark",
            ..resnet("resnet34", zoo::resnet34, 512)
        });
        r.register(ModelEntry {
            description: "ResNet-50, bottleneck blocks (Fig. 4b)",
            ..resnet("resnet50", zoo::resnet50, 2048)
        });
        r.register(ModelEntry {
            description: "ResNet-152, bottleneck blocks (Fig. 4b)",
            ..resnet("resnet152", zoo::resnet152, 2048)
        });
        r.register(ModelEntry {
            name: "shufflenet",
            description: "ShuffleNet v1 (g=8, 1.0x) — Tbl V/VI",
            default_resolution: (224, 224),
            stride_granularity: zoo::STEM_GRANULARITY,
            fixed_resolution: false,
            out_channels: 1536,
            downsample: 32,
            builder: zoo::shufflenet,
        });
        r.register(ModelEntry {
            name: "yolov3",
            description: "YOLOv3: Darknet-53 + 3-scale FPN heads — Tbl V/VI",
            default_resolution: (320, 320),
            stride_granularity: zoo::FPN_GRANULARITY,
            fixed_resolution: false,
            out_channels: 255,
            downsample: 8,
            builder: zoo::yolov3,
        });
        r.register(ModelEntry {
            name: "tinyyolo",
            description: "TinyYOLO-class 3x3/1x1 detector (§IV-C)",
            default_resolution: (416, 416),
            stride_granularity: 1,
            fixed_resolution: false,
            out_channels: 255,
            downsample: 32,
            builder: zoo::tinyyolo,
        });
        r.register(ModelEntry {
            name: "hypernet20",
            description: "HyperNet-20 — the AOT end-to-end validation network",
            default_resolution: (32, 32),
            stride_granularity: 1,
            fixed_resolution: true,
            out_channels: 64,
            downsample: 4,
            builder: |_, _| Ok(zoo::hypernet20()),
        });
        r
    }

    /// Register (or replace, by name) an entry.
    pub fn register(&mut self, entry: ModelEntry) {
        match self.entries.iter_mut().find(|e| e.name == entry.name) {
            Some(slot) => *slot = entry,
            None => self.entries.push(entry),
        }
    }

    /// Entry by name.
    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name).collect()
    }

    /// Resolve a parsed spec into a network plus its weight source.
    pub fn resolve(&self, spec: &ModelSpec) -> Result<ResolvedModel, ModelError> {
        match spec {
            ModelSpec::Registry { name, resolution } => {
                let entry = self.get(name).ok_or_else(|| ModelError::UnknownModel {
                    name: name.clone(),
                    known: self.names().iter().map(|n| n.to_string()).collect(),
                })?;
                let (h, w) = match *resolution {
                    Some(res) if entry.fixed_resolution && res != entry.default_resolution => {
                        return Err(ModelError::FixedResolution {
                            name: entry.name.to_string(),
                            requested: res,
                            fixed: entry.default_resolution,
                        })
                    }
                    Some(res) => res,
                    None => entry.default_resolution,
                };
                let network = entry.build(h, w)?;
                debug_assert_eq!(network.out_shape(), entry.output_shape(h, w));
                Ok(ResolvedModel {
                    spec: spec.clone(),
                    network,
                    weights: Box::new(Random { seed: DEFAULT_SEED }),
                    manifest: None,
                })
            }
            ModelSpec::Manifest { dir, network } => {
                let nm = NetworkManifest::load(dir)
                    .map_err(|e| ModelError::Manifest(format!("{e:#}")))?;
                if let Some(expected) = network {
                    if normalize(expected) != normalize(&nm.network.name) {
                        return Err(ModelError::ManifestNetworkMismatch {
                            expected: expected.clone(),
                            found: nm.network.name.clone(),
                        });
                    }
                }
                let nm = Arc::new(nm);
                Ok(ResolvedModel {
                    spec: spec.clone(),
                    network: nm.network.clone(),
                    weights: Box::new(ManifestBlobs::new(nm.clone())),
                    manifest: Some(nm),
                })
            }
        }
    }

    /// Parse + resolve in one call.
    pub fn resolve_str(&self, spec: &str) -> Result<ResolvedModel, ModelError> {
        self.resolve(&spec.parse::<ModelSpec>()?)
    }

    /// One listing row per entry whose default resolution builds. A
    /// custom entry with a broken default is skipped here (never a
    /// panic); `render_listing` annotates such rows and `resolve` still
    /// reports their typed error.
    pub fn listings(&self) -> Vec<ModelListing> {
        self.entries
            .iter()
            .filter_map(|e| {
                let (h, w) = e.default_resolution;
                let net = e.build(h, w).ok()?;
                Some(ModelListing {
                    name: e.name,
                    default_resolution: e.default_resolution,
                    steps: net.steps.len(),
                    weight_mbit: net.weight_bits() as f64 / 1e6,
                    description: e.description,
                })
            })
            .collect()
    }

    /// The `list-models` table.
    pub fn render_listing(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "Registered models (use --model <name>[@HxW|@N] or manifest:DIR[#NET]):\n",
        );
        out.push_str(&format!(
            "{:<12} {:>11} {:>14} {:>13}   {}\n",
            "name", "default res", "on-chip steps", "weights[Mbit]", "description"
        ));
        for e in &self.entries {
            let (h, w) = e.default_resolution;
            let res = format!("{h}x{w}");
            match e.build(h, w) {
                Ok(net) => out.push_str(&format!(
                    "{:<12} {:>11} {:>14} {:>13.2}   {}\n",
                    e.name,
                    res,
                    net.steps.len(),
                    net.weight_bits() as f64 / 1e6,
                    e.description
                )),
                Err(err) => out.push_str(&format!(
                    "{:<12} {:>11}   (default does not build: {err})\n",
                    e.name, res
                )),
            }
        }
        out
    }
}

impl Default for NetworkRegistry {
    fn default() -> Self {
        NetworkRegistry::builtin()
    }
}

/// Case- and punctuation-insensitive name form: `HyperNet-20` and
/// `hypernet20` compare equal. (`pub(crate)` so the engine's forced-PJRT
/// path can apply the same `#name` fragment check without a full
/// registry resolution.)
pub(crate) fn normalize(name: &str) -> String {
    name.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .map(|c| c.to_ascii_lowercase())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_has_the_full_zoo() {
        let r = NetworkRegistry::builtin();
        for name in [
            "resnet18",
            "resnet34",
            "resnet50",
            "resnet152",
            "shufflenet",
            "yolov3",
            "tinyyolo",
            "hypernet20",
        ] {
            assert!(r.get(name).is_some(), "missing `{name}`");
        }
    }

    #[test]
    fn unknown_model_error_lists_known_names() {
        let r = NetworkRegistry::builtin();
        let err = r.resolve_str("resnet99").unwrap_err();
        match &err {
            ModelError::UnknownModel { name, known } => {
                assert_eq!(name, "resnet99");
                assert!(known.iter().any(|n| n == "resnet34"));
            }
            other => panic!("expected UnknownModel, got {other}"),
        }
        assert!(err.to_string().contains("resnet34"), "{err}");
    }

    #[test]
    fn default_resolution_used_when_unspecified() {
        let r = NetworkRegistry::builtin();
        let m = r.resolve_str("resnet34").unwrap();
        assert_eq!(m.network.name, "ResNet-34");
        // Image 224x224 → on-chip input FM 64×56×56.
        assert_eq!(
            (m.network.in_ch, m.network.in_h, m.network.in_w),
            (64, 56, 56)
        );
        assert_eq!(m.network.out_shape(), (512, 7, 7));
    }

    #[test]
    fn explicit_resolution_overrides_default() {
        let r = NetworkRegistry::builtin();
        let m = r.resolve_str("resnet34@1024x2048").unwrap();
        assert_eq!((m.network.in_h, m.network.in_w), (256, 512));
    }

    #[test]
    fn bad_resolution_surfaces_the_zoo_error() {
        let r = NetworkRegistry::builtin();
        let err = r.resolve_str("resnet34@225x224").unwrap_err();
        match err {
            ModelError::Resolution(e) => {
                assert_eq!((e.h, e.w, e.granularity), (225, 224, 4));
            }
            other => panic!("expected Resolution, got {other}"),
        }
        assert!(matches!(
            r.resolve_str("yolov3@336").unwrap_err(),
            ModelError::Resolution(_)
        ));
    }

    #[test]
    fn fixed_resolution_entries_reject_overrides() {
        let r = NetworkRegistry::builtin();
        assert!(r.resolve_str("hypernet20").is_ok());
        // Spelling out the fixed resolution is allowed.
        assert!(r.resolve_str("hypernet20@32x32").is_ok());
        let err = r.resolve_str("hypernet20@64x64").unwrap_err();
        assert!(matches!(err, ModelError::FixedResolution { .. }), "{err}");
    }

    #[test]
    fn shape_inference_matches_built_networks() {
        let r = NetworkRegistry::builtin();
        for (spec, name) in [
            ("resnet18@224x224", "resnet18"),
            ("resnet34@512x1024", "resnet34"),
            ("resnet50@224x224", "resnet50"),
            ("shufflenet@224x224", "shufflenet"),
            ("yolov3@416x416", "yolov3"),
            ("tinyyolo@416x416", "tinyyolo"),
            // Non-divisible-by-32 sizes exercise the div_ceil identity.
            ("resnet34@112x112", "resnet34"),
            ("resnet34@168x168", "resnet34"),
        ] {
            let m = r.resolve_str(spec).unwrap();
            let entry = r.get(name).unwrap();
            let (h, w) = match m.spec {
                ModelSpec::Registry {
                    resolution: Some(res),
                    ..
                } => res,
                _ => unreachable!(),
            };
            assert_eq!(
                m.network.out_shape(),
                entry.output_shape(h, w),
                "{spec}"
            );
        }
    }

    #[test]
    fn registry_weight_source_is_seeded_random() {
        let r = NetworkRegistry::builtin();
        let m = r.resolve_str("hypernet20").unwrap();
        assert_eq!(m.weights.seed(), Some(DEFAULT_SEED));
        assert!(m.manifest.is_none());
        let p = m.weights.params(&m.network, 16).unwrap();
        assert_eq!(p.steps.len(), 20);
    }

    #[test]
    fn register_replaces_by_name() {
        let mut r = NetworkRegistry::builtin();
        let n = r.names().len();
        let mut entry = r.get("resnet34").unwrap().clone();
        entry.default_resolution = (512, 512);
        r.register(entry);
        assert_eq!(r.names().len(), n);
        assert_eq!(r.get("resnet34").unwrap().default_resolution, (512, 512));
        let m = r.resolve_str("resnet34").unwrap();
        assert_eq!((m.network.in_h, m.network.in_w), (128, 128));
    }

    #[test]
    fn registry_level_granularity_check_guards_custom_entries() {
        // tinyyolo's builder accepts any size; the entry's declared
        // granularity must still be enforced by the registry.
        let mut r = NetworkRegistry::empty();
        r.register(ModelEntry {
            name: "tiny8",
            description: "granularity-8 test entry",
            default_resolution: (64, 64),
            stride_granularity: 8,
            fixed_resolution: false,
            out_channels: 255,
            downsample: 32,
            builder: zoo::tinyyolo,
        });
        assert!(r.resolve_str("tiny8@64x64").is_ok());
        match r.resolve_str("tiny8@65x64").unwrap_err() {
            ModelError::Resolution(e) => assert_eq!(e.granularity, 8),
            other => panic!("expected Resolution, got {other}"),
        }
    }

    #[test]
    fn broken_default_resolution_is_reported_not_panicked() {
        let mut r = NetworkRegistry::builtin();
        let mut entry = r.get("resnet34").unwrap().clone();
        entry.name = "resnet34-bad";
        entry.default_resolution = (225, 225);
        r.register(entry);
        // listings() skips the broken row; render_listing annotates it.
        assert_eq!(r.listings().len(), r.names().len() - 1);
        let text = r.render_listing();
        assert!(text.contains("resnet34-bad"), "{text}");
        assert!(text.contains("does not build"), "{text}");
    }

    #[test]
    fn missing_manifest_is_a_typed_error() {
        let r = NetworkRegistry::builtin();
        let err = r.resolve_str("manifest:/nonexistent/dir").unwrap_err();
        assert!(matches!(err, ModelError::Manifest(_)), "{err}");
    }

    #[test]
    fn listings_cover_every_entry() {
        let r = NetworkRegistry::builtin();
        let ls = r.listings();
        assert_eq!(ls.len(), r.names().len());
        let rn34 = ls.iter().find(|l| l.name == "resnet34").unwrap();
        // Tbl II: ~21 Mbit of binary weights at 224².
        assert!((rn34.weight_mbit - 21.0).abs() < 2.0, "{}", rn34.weight_mbit);
        assert!(rn34.steps > 30);
        let text = r.render_listing();
        assert!(text.contains("resnet152"), "{text}");
        assert!(text.contains("hypernet20"), "{text}");
    }
}

//! The parseable model spec — one string grammar naming every network
//! the system can run, used by the CLI (`--model`), the engine builder
//! (`Engine::builder().model(..)`) and the examples/benches.
//!
//! Grammar (also in `DESIGN.md §ModelSpec`):
//!
//! ```text
//! spec       := registry | manifest
//! registry   := name [ "@" resolution ]        ; a NetworkRegistry entry
//! resolution := H "x" W | N                    ; height x width, or N x N
//! manifest   := "manifest:" dir [ "#" name ]   ; an AOT artifact manifest
//! ```
//!
//! Examples: `resnet34` (registry default resolution),
//! `resnet34@512x1024` (512 high, 1024 wide), `yolov3@416` (416×416),
//! `manifest:artifacts`, `manifest:artifacts/manifest.tsv#hypernet20`
//! (the `#name` fragment asserts which network the manifest describes).

use std::fmt;
use std::path::PathBuf;
use std::str::FromStr;

/// A parsed model description: either a registry entry (by name, with an
/// optional `(h, w)` resolution override) or an AOT artifact manifest
/// (by directory, with an optional expected network name).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModelSpec {
    /// `name[@HxW]` — resolved against a
    /// [`NetworkRegistry`](super::NetworkRegistry).
    Registry {
        /// Registry entry name (e.g. `resnet34`).
        name: String,
        /// `(h, w)` image resolution; `None` uses the entry's default.
        resolution: Option<(usize, usize)>,
    },
    /// `manifest:DIR[#NAME]` — an AOT artifact manifest directory (a
    /// direct path to `manifest.tsv` is also accepted).
    Manifest {
        /// The artifact directory.
        dir: PathBuf,
        /// Expected network name, compared case- and
        /// punctuation-insensitively (`hypernet20` matches
        /// `HyperNet-20`).
        network: Option<String>,
    },
}

/// Typed parse errors of the [`ModelSpec`] grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecError {
    /// The spec string is empty (or all whitespace).
    Empty,
    /// A registry spec with no name before `@`, or a manifest spec with
    /// an empty `#` fragment.
    EmptyName { spec: String },
    /// The text after `@` is not `HxW` or `N`.
    BadResolution { spec: String, what: &'static str },
    /// A resolution dimension parsed to zero.
    ZeroResolution { spec: String },
    /// `manifest:` with nothing after the colon.
    EmptyManifestDir { spec: String },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Empty => write!(f, "empty model spec"),
            SpecError::EmptyName { spec } => {
                write!(f, "model spec `{spec}` has an empty network name")
            }
            SpecError::BadResolution { spec, what } => write!(
                f,
                "model spec `{spec}`: {what} (expected `name@HxW` or `name@N`)"
            ),
            SpecError::ZeroResolution { spec } => {
                write!(f, "model spec `{spec}` has a zero resolution dimension")
            }
            SpecError::EmptyManifestDir { spec } => {
                write!(f, "model spec `{spec}` names no manifest directory")
            }
        }
    }
}

impl std::error::Error for SpecError {}

impl FromStr for ModelSpec {
    type Err = SpecError;

    fn from_str(s: &str) -> Result<ModelSpec, SpecError> {
        let s = s.trim();
        if s.is_empty() {
            return Err(SpecError::Empty);
        }
        if let Some(rest) = s.strip_prefix("manifest:") {
            let (dir, fragment) = match rest.split_once('#') {
                Some((d, f)) => (d, Some(f)),
                None => (rest, None),
            };
            if dir.is_empty() {
                return Err(SpecError::EmptyManifestDir { spec: s.into() });
            }
            if fragment == Some("") {
                return Err(SpecError::EmptyName { spec: s.into() });
            }
            // Accept both the directory and the manifest file itself.
            let mut dir = PathBuf::from(dir);
            if dir.file_name().is_some_and(|f| f == "manifest.tsv") {
                dir.pop();
            }
            return Ok(ModelSpec::Manifest {
                dir,
                network: fragment.map(str::to_string),
            });
        }
        let (name, resolution) = match s.split_once('@') {
            None => (s, None),
            Some((name, res)) => (name, Some(parse_resolution(s, res)?)),
        };
        if name.is_empty() {
            return Err(SpecError::EmptyName { spec: s.into() });
        }
        Ok(ModelSpec::Registry {
            name: name.to_string(),
            resolution,
        })
    }
}

fn parse_resolution(spec: &str, res: &str) -> Result<(usize, usize), SpecError> {
    let bad = |what| SpecError::BadResolution {
        spec: spec.into(),
        what,
    };
    let (h, w) = match res.split_once('x') {
        Some((h, w)) => (
            h.parse::<usize>().map_err(|_| bad("height is not an integer"))?,
            w.parse::<usize>().map_err(|_| bad("width is not an integer"))?,
        ),
        None => {
            let n = res
                .parse::<usize>()
                .map_err(|_| bad("resolution is not an integer"))?;
            (n, n)
        }
    };
    if h == 0 || w == 0 {
        return Err(SpecError::ZeroResolution { spec: spec.into() });
    }
    Ok((h, w))
}

impl fmt::Display for ModelSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelSpec::Registry { name, resolution } => match resolution {
                Some((h, w)) => write!(f, "{name}@{h}x{w}"),
                None => write!(f, "{name}"),
            },
            ModelSpec::Manifest { dir, network } => match network {
                Some(n) => write!(f, "manifest:{}#{n}", dir.display()),
                None => write!(f, "manifest:{}", dir.display()),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<ModelSpec, SpecError> {
        s.parse()
    }

    #[test]
    fn bare_name_has_no_resolution() {
        assert_eq!(
            parse("resnet34").unwrap(),
            ModelSpec::Registry {
                name: "resnet34".into(),
                resolution: None,
            }
        );
    }

    #[test]
    fn h_x_w_and_square_forms() {
        assert_eq!(
            parse("resnet34@512x1024").unwrap(),
            ModelSpec::Registry {
                name: "resnet34".into(),
                resolution: Some((512, 1024)),
            }
        );
        assert_eq!(
            parse("yolov3@416").unwrap(),
            ModelSpec::Registry {
                name: "yolov3".into(),
                resolution: Some((416, 416)),
            }
        );
    }

    #[test]
    fn manifest_forms() {
        assert_eq!(
            parse("manifest:artifacts").unwrap(),
            ModelSpec::Manifest {
                dir: PathBuf::from("artifacts"),
                network: None,
            }
        );
        // A direct manifest.tsv path resolves to its directory; the
        // fragment carries the expected network name.
        assert_eq!(
            parse("manifest:artifacts/manifest.tsv#hypernet20").unwrap(),
            ModelSpec::Manifest {
                dir: PathBuf::from("artifacts"),
                network: Some("hypernet20".into()),
            }
        );
    }

    #[test]
    fn typed_parse_errors() {
        assert_eq!(parse("").unwrap_err(), SpecError::Empty);
        assert_eq!(parse("   ").unwrap_err(), SpecError::Empty);
        assert!(matches!(
            parse("@224").unwrap_err(),
            SpecError::EmptyName { .. }
        ));
        assert!(matches!(
            parse("resnet34@axb").unwrap_err(),
            SpecError::BadResolution { .. }
        ));
        assert!(matches!(
            parse("resnet34@224x").unwrap_err(),
            SpecError::BadResolution { .. }
        ));
        assert!(matches!(
            parse("resnet34@0x224").unwrap_err(),
            SpecError::ZeroResolution { .. }
        ));
        assert!(matches!(
            parse("manifest:").unwrap_err(),
            SpecError::EmptyManifestDir { .. }
        ));
        assert!(matches!(
            parse("manifest:artifacts#").unwrap_err(),
            SpecError::EmptyName { .. }
        ));
    }

    #[test]
    fn display_round_trips() {
        for s in [
            "resnet34",
            "resnet34@512x1024",
            "manifest:artifacts",
            "manifest:artifacts#hypernet20",
        ] {
            let spec = parse(s).unwrap();
            assert_eq!(spec.to_string(), s);
            assert_eq!(parse(&spec.to_string()).unwrap(), spec);
        }
        // The square shorthand normalizes to the HxW form.
        assert_eq!(parse("yolov3@416").unwrap().to_string(), "yolov3@416x416");
    }
}

//! [`WeightSource`] — where a model's parameters come from. One trait
//! unifying the three provisioning paths that used to be chosen per call
//! site: seeded synthetic BWN parameters ([`Random`]), trained tensors
//! from an AOT artifact manifest ([`ManifestBlobs`]) and caller-supplied
//! host tensors ([`HostTensors`], packed through [`bwn::pack_weights`]).
//!
//! [`bwn::pack_weights`]: crate::bwn::pack_weights

use std::sync::Arc;

use crate::bwn::pack_weights;
use crate::engine::backend::NetworkParams;
use crate::network::Network;
use crate::runtime::NetworkManifest;
use crate::simulator::mesh::StepParams;

use super::ModelError;

/// A provider of per-step simulator parameters (packed weight streams +
/// folded batch-norm γ/β) for a network.
///
/// `Send + Sync` so a source can be shared across engines and serving
/// workers.
pub trait WeightSource: Send + Sync {
    /// One-line human description (reports, examples).
    fn describe(&self) -> String;

    /// Materialize the parameters for `net` at output-channel
    /// parallelism `c` (the chip's stream word width).
    fn params(&self, net: &Network, c: usize) -> Result<NetworkParams, ModelError>;

    /// `Some(seed)` when the source is a deterministic generator that
    /// the engine may materialize lazily; `None` for real tensors.
    fn seed(&self) -> Option<u64> {
        None
    }
}

/// Deterministic synthetic ±1 weights and BWN-style batch-norm scales
/// derived from a seed (see `NetworkParams::seeded`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Random {
    pub seed: u64,
}

impl WeightSource for Random {
    fn describe(&self) -> String {
        format!("seeded synthetic BWN parameters (seed {:#x})", self.seed)
    }

    fn params(&self, net: &Network, c: usize) -> Result<NetworkParams, ModelError> {
        Ok(NetworkParams::seeded(net, c, self.seed))
    }

    fn seed(&self) -> Option<u64> {
        Some(self.seed)
    }
}

/// Real (trained, binarized) tensors from an AOT artifact manifest —
/// the exact blobs the PJRT backend executes with.
pub struct ManifestBlobs {
    manifest: Arc<NetworkManifest>,
}

impl ManifestBlobs {
    pub fn new(manifest: Arc<NetworkManifest>) -> ManifestBlobs {
        ManifestBlobs { manifest }
    }

    /// The underlying manifest (golden files, blob index, …).
    pub fn manifest(&self) -> &NetworkManifest {
        &self.manifest
    }
}

impl WeightSource for ManifestBlobs {
    fn describe(&self) -> String {
        format!(
            "manifest (trained) parameters from {}",
            self.manifest.dir.display()
        )
    }

    fn params(&self, _net: &Network, c: usize) -> Result<NetworkParams, ModelError> {
        NetworkParams::from_manifest(&self.manifest, c)
            .map_err(|e| ModelError::Weights(e.to_string()))
    }
}

/// One step's raw host tensors: real-valued weights
/// `[n_out][n_in/groups][k][k]` (row-major, binarized at packing time)
/// plus folded batch-norm scale/offset.
#[derive(Debug, Clone)]
pub struct StepTensors {
    pub w: Vec<f32>,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
}

/// Caller-supplied host tensors, shape-checked against the network and
/// packed into Tbl-I weight streams.
#[derive(Debug, Clone)]
pub struct HostTensors {
    pub steps: Vec<StepTensors>,
}

impl WeightSource for HostTensors {
    fn describe(&self) -> String {
        format!("host tensors for {} steps", self.steps.len())
    }

    fn params(&self, net: &Network, c: usize) -> Result<NetworkParams, ModelError> {
        if self.steps.len() != net.steps.len() {
            return Err(ModelError::Weights(format!(
                "{} host tensor sets for a {}-step network",
                self.steps.len(),
                net.steps.len()
            )));
        }
        let mut steps = Vec::with_capacity(net.steps.len());
        for (s, t) in net.steps.iter().zip(&self.steps) {
            let l = &s.layer;
            let want = (l.weight_bits()) as usize;
            if t.w.len() != want {
                return Err(ModelError::Weights(format!(
                    "step `{}`: {} weight values, layer needs {want}",
                    l.name,
                    t.w.len()
                )));
            }
            if t.gamma.len() != l.n_out || t.beta.len() != l.n_out {
                return Err(ModelError::Weights(format!(
                    "step `{}`: gamma/beta have {}/{} values, layer has {} output channels",
                    l.name,
                    t.gamma.len(),
                    t.beta.len(),
                    l.n_out
                )));
            }
            steps.push(StepParams {
                stream: pack_weights(l, &t.w, c),
                gamma: t.gamma.clone(),
                beta: t.beta.clone(),
            });
        }
        Ok(NetworkParams { steps })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    #[test]
    fn random_source_matches_seeded_params() {
        let net = model::network("hypernet20").unwrap();
        let src = Random { seed: 0xE2E };
        assert_eq!(src.seed(), Some(0xE2E));
        let a = src.params(&net, 16).unwrap();
        let b = NetworkParams::seeded(&net, 16, 0xE2E);
        assert_eq!(a.steps.len(), b.steps.len());
        for (x, y) in a.steps.iter().zip(&b.steps) {
            assert_eq!(x.stream, y.stream);
            assert_eq!(x.gamma, y.gamma);
            assert_eq!(x.beta, y.beta);
        }
    }

    #[test]
    fn host_tensors_pack_and_shape_check() {
        let net = model::network("hypernet20").unwrap();
        let good: Vec<StepTensors> = net
            .steps
            .iter()
            .map(|s| {
                let l = &s.layer;
                StepTensors {
                    w: vec![-1.0; l.weight_bits() as usize],
                    gamma: vec![0.5; l.n_out],
                    beta: vec![0.0; l.n_out],
                }
            })
            .collect();
        let src = HostTensors { steps: good.clone() };
        let p = src.params(&net, 16).unwrap();
        assert_eq!(p.steps.len(), net.steps.len());
        // All-negative weights: every real (non-padded) stream bit is 0.
        assert_eq!(p.steps[0].stream.weight(0, 0, 0), -1.0);

        // Wrong step count.
        let short = HostTensors { steps: good[..5].to_vec() };
        assert!(matches!(
            short.params(&net, 16).unwrap_err(),
            ModelError::Weights(_)
        ));

        // Wrong per-step weight volume.
        let mut bad = HostTensors { steps: good };
        bad.steps[3].w.pop();
        let err = bad.params(&net, 16).unwrap_err();
        assert!(err.to_string().contains("weight values"), "{err}");
    }
}

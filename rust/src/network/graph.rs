//! Network graph: an ordered list of conv steps with explicit tensor
//! references, mirroring the step list the AOT manifest describes for the
//! Rust coordinator. Off-chip stages (the 7×7 first layer and the FC
//! head the paper executes on the host, §VI-B) are carried as metadata so
//! whole-network tables (Tbl II) can include them while the chip mapping
//! skips them.

use anyhow::{bail, Result};

use super::layer::ConvLayer;

/// Reference to a tensor in the network: the network input or the output
/// of an earlier step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorRef {
    /// The network's on-chip input FM.
    Input,
    /// Output of step `i`.
    Step(usize),
}

/// One scheduled layer execution.
#[derive(Debug, Clone)]
pub struct Step {
    pub layer: ConvLayer,
    /// Main input.
    pub src: TensorRef,
    /// Residual bypass input (present iff `layer.has_bypass`).
    pub bypass: Option<TensorRef>,
    /// Second input concatenated channel-wise with `src` (YOLOv3's
    /// feature-pyramid merges); `layer.n_in` = channels(src) +
    /// channels(concat_extra). Concatenation itself is free on the chip —
    /// the two tensors simply occupy adjacent FMM segments.
    pub concat_extra: Option<TensorRef>,
    /// The output of this step is 2× nearest-neighbour upsampled before
    /// storage (YOLOv3 FPN laterals). Replication is free on the chip
    /// (DDU addressing) but the stored FM is 4× larger.
    pub upsample2x: bool,
}

/// An off-chip stage (first 7×7 conv / FC head): only its op and weight
/// counts matter to the tables.
#[derive(Debug, Clone, Default)]
pub struct OffChipStage {
    pub name: String,
    pub ops: u64,
    pub weight_bits: u64,
    /// FM words streamed to/from the host for this stage (e.g. the raw
    /// RGB image for the first conv).
    pub io_words: u64,
}

/// A full network: on-chip step list plus off-chip pre/post stages.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    /// On-chip input FM shape (channels, height, width).
    pub in_ch: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub steps: Vec<Step>,
    pub pre: Option<OffChipStage>,
    pub post: Option<OffChipStage>,
}

impl Network {
    pub fn new(name: impl Into<String>, in_ch: usize, in_h: usize, in_w: usize) -> Self {
        Network {
            name: name.into(),
            in_ch,
            in_h,
            in_w,
            steps: Vec::new(),
            pre: None,
            post: None,
        }
    }

    /// Shape (c, h, w) of a tensor reference (after any 2× upsampling).
    pub fn shape_of(&self, r: TensorRef) -> (usize, usize, usize) {
        match r {
            TensorRef::Input => (self.in_ch, self.in_h, self.in_w),
            TensorRef::Step(i) => {
                let s = &self.steps[i];
                let l = &s.layer;
                let f = if s.upsample2x { 2 } else { 1 };
                (l.n_out, f * l.h_out(), f * l.w_out())
            }
        }
    }

    /// Volume in words of a tensor reference.
    pub fn words_of(&self, r: TensorRef) -> u64 {
        let (c, h, w) = self.shape_of(r);
        (c * h * w) as u64
    }

    /// Append a step; validates shape compatibility eagerly.
    pub fn push(&mut self, layer: ConvLayer, src: TensorRef, bypass: Option<TensorRef>) -> usize {
        let (c, h, w) = self.shape_of(src);
        assert_eq!(
            (c, h, w),
            (layer.n_in, layer.h, layer.w),
            "step `{}`: src shape mismatch",
            layer.name
        );
        self.push_validated(layer, src, bypass, None)
    }

    /// Append a step whose input is `src` concatenated channel-wise with
    /// `extra` (if any). Spatial dims must match; `layer.n_in` must equal
    /// the summed channel count.
    pub fn push_concat(
        &mut self,
        layer: ConvLayer,
        src: TensorRef,
        extra: Option<TensorRef>,
    ) -> usize {
        let Some(extra) = extra else {
            return self.push(layer, src, None);
        };
        let (c0, h0, w0) = self.shape_of(src);
        let (c1, h1, w1) = self.shape_of(extra);
        assert_eq!((h0, w0), (h1, w1), "step `{}`: concat spatial mismatch", layer.name);
        assert_eq!(
            (c0 + c1, h0, w0),
            (layer.n_in, layer.h, layer.w),
            "step `{}`: concat shape mismatch",
            layer.name
        );
        self.push_validated(layer, src, None, Some(extra))
    }

    fn push_validated(
        &mut self,
        layer: ConvLayer,
        src: TensorRef,
        bypass: Option<TensorRef>,
        concat_extra: Option<TensorRef>,
    ) -> usize {
        if layer.has_bypass {
            let b = bypass.expect("has_bypass layer without bypass ref");
            let bs = self.shape_of(b);
            assert_eq!(
                bs,
                (layer.n_out, layer.h_out(), layer.w_out()),
                "step `{}`: bypass shape mismatch",
                layer.name
            );
        } else {
            assert!(bypass.is_none(), "bypass ref on non-bypass layer");
        }
        self.steps.push(Step {
            layer,
            src,
            bypass,
            concat_extra,
            upsample2x: false,
        });
        self.steps.len() - 1
    }

    /// Mark the last-pushed step's output as 2× nearest-upsampled.
    pub fn upsample_last(&mut self) -> usize {
        let i = self.steps.len() - 1;
        self.steps[i].upsample2x = true;
        i
    }

    /// Validate the whole graph (reference ordering + shapes).
    pub fn validate(&self) -> Result<()> {
        for (i, s) in self.steps.iter().enumerate() {
            for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
                if let TensorRef::Step(j) = r {
                    if j >= i {
                        bail!("step {i} references future step {j}");
                    }
                }
            }
            let (c, h, w) = self.shape_of(s.src);
            let c_extra = s.concat_extra.map_or(0, |e| {
                let (ce, he, we) = self.shape_of(e);
                debug_assert_eq!((he, we), (h, w));
                ce
            });
            if (c + c_extra, h, w) != (s.layer.n_in, s.layer.h, s.layer.w) {
                bail!("step {i} ({}) shape mismatch", s.layer.name);
            }
            if s.layer.has_bypass != s.bypass.is_some() {
                bail!("step {i} bypass flag/ref mismatch");
            }
        }
        Ok(())
    }

    /// Total on-chip convolution ops.
    pub fn conv_ops(&self) -> u64 {
        self.steps.iter().map(|s| s.layer.conv_ops()).sum()
    }

    /// Total on-chip batch-norm ops.
    pub fn bnorm_ops(&self) -> u64 {
        self.steps.iter().map(|s| s.layer.bnorm_ops()).sum()
    }

    /// Total on-chip bias ops.
    pub fn bias_ops(&self) -> u64 {
        self.steps.iter().map(|s| s.layer.bias_ops()).sum()
    }

    /// Total on-chip residual bypass ops.
    pub fn bypass_ops(&self) -> u64 {
        self.steps.iter().map(|s| s.layer.bypass_ops()).sum()
    }

    /// All on-chip ops.
    pub fn total_ops(&self) -> u64 {
        self.conv_ops() + self.bnorm_ops() + self.bias_ops() + self.bypass_ops()
    }

    /// Whole-network ops including off-chip stages (Tbl II / §VI-B "7.3 GOp").
    pub fn total_ops_with_offchip(&self) -> u64 {
        self.total_ops()
            + self.pre.as_ref().map_or(0, |s| s.ops)
            + self.post.as_ref().map_or(0, |s| s.ops)
    }

    /// Total binary-weight bits streamed to the chip.
    pub fn weight_bits(&self) -> u64 {
        self.steps.iter().map(|s| s.layer.weight_bits()).sum()
    }

    /// Whole-network weight bits (off-chip stages use full precision in
    /// the paper, but Tbl II counts binary weights of conv layers only).
    pub fn weight_bits_with_offchip(&self) -> u64 {
        self.weight_bits()
            + self.pre.as_ref().map_or(0, |s| s.weight_bits)
            + self.post.as_ref().map_or(0, |s| s.weight_bits)
    }

    /// Sum of all FM volumes (input + every step output), in words —
    /// the "all FMs" column of Tbl II.
    pub fn all_fm_words(&self) -> u64 {
        let input = (self.in_ch * self.in_h * self.in_w) as u64;
        input + self.steps.iter().map(|s| s.layer.out_words()).sum::<u64>()
    }

    /// Largest single layer input+output footprint, in words (the naive
    /// per-layer ping-pong requirement before bypass-aware planning).
    pub fn max_layer_words(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| s.layer.in_words() + s.layer.out_words())
            .max()
            .unwrap_or(0)
    }

    /// Output shape of the last step.
    pub fn out_shape(&self) -> (usize, usize, usize) {
        self.shape_of(TensorRef::Step(self.steps.len() - 1))
    }

    /// Step index by layer name (names are unique in zoo networks).
    pub fn step_by_name(&self, name: &str) -> Option<usize> {
        self.steps.iter().position(|s| s.layer.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        let mut n = Network::new("tiny", 16, 8, 8);
        let a = n.push(
            ConvLayer::new("a", 16, 16, 8, 8, 3, 1),
            TensorRef::Input,
            None,
        );
        n.push(
            ConvLayer::new("b", 16, 16, 8, 8, 3, 1).with_bypass(true),
            TensorRef::Step(a),
            Some(TensorRef::Input),
        );
        n
    }

    #[test]
    fn shapes_chain_and_validate() {
        let n = tiny();
        n.validate().unwrap();
        assert_eq!(n.out_shape(), (16, 8, 8));
        assert_eq!(n.words_of(TensorRef::Input), 16 * 64);
    }

    #[test]
    fn op_totals_are_sums() {
        let n = tiny();
        assert_eq!(n.conv_ops(), 2 * 2 * 16 * 16 * 9 * 64);
        assert_eq!(n.bypass_ops(), 16 * 64);
        assert_eq!(
            n.total_ops(),
            n.conv_ops() + n.bnorm_ops() + n.bias_ops() + n.bypass_ops()
        );
    }

    #[test]
    fn all_fm_accounting() {
        let n = tiny();
        assert_eq!(n.all_fm_words(), 3 * 16 * 64);
    }

    #[test]
    #[should_panic(expected = "src shape mismatch")]
    fn mismatched_shapes_rejected() {
        let mut n = Network::new("bad", 16, 8, 8);
        n.push(
            ConvLayer::new("a", 32, 16, 8, 8, 3, 1),
            TensorRef::Input,
            None,
        );
    }

    #[test]
    fn forward_reference_rejected() {
        let mut n = tiny();
        // Manually corrupt: step 0 references step 1.
        n.steps[0].src = TensorRef::Step(1);
        assert!(n.validate().is_err());
    }

    #[test]
    fn offchip_stages_add_to_totals() {
        let mut n = tiny();
        n.pre = Some(OffChipStage {
            name: "conv7x7".into(),
            ops: 1000,
            weight_bits: 500,
            io_words: 99,
        });
        assert_eq!(n.total_ops_with_offchip(), n.total_ops() + 1000);
        assert_eq!(n.weight_bits_with_offchip(), n.weight_bits() + 500);
    }
}

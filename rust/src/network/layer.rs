//! Convolution layer description — the only compute primitive the chip
//! executes (§IV-C: 1×1 and 3×3 kernels, stride 1 or 2, optional groups
//! for ShuffleNet-style topologies, `groups == n_in == n_out` for
//! depth-wise convolutions).

/// One convolutional layer (batch-norm scale, bias, optional residual
/// bypass and ReLU are fused into the layer, as in the chip's datapath).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: String,
    /// Input channels.
    pub n_in: usize,
    /// Output channels.
    pub n_out: usize,
    /// Input spatial height/width.
    pub h: usize,
    pub w: usize,
    /// Kernel size (1 or 3 on the taped-out chip; 7 only off-chip).
    pub k: usize,
    /// Stride (1 or 2).
    pub stride: usize,
    /// Channel groups (1 = dense, `n_in` = depth-wise).
    pub groups: usize,
    /// Whether a residual bypass is accumulated into this layer's output.
    pub has_bypass: bool,
    /// Fused ReLU activation.
    pub relu: bool,
    /// Fused batch-norm scale (all real layers have it; the 1×1 bypass
    /// projections do not apply a separate activation scale in Fig. 4).
    pub bnorm: bool,
    /// The residual accumulation needs a separate read-add pass (§VI-B:
    /// at strided junctions the 49-word memory bandwidth limits bypass to
    /// one output FM at a time). Set by the zoo builders on
    /// strided-projection blocks; identity bypasses fuse for free.
    pub bypass_separate: bool,
}

impl ConvLayer {
    /// Dense conv constructor with the common defaults.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: impl Into<String>,
        n_in: usize,
        n_out: usize,
        h: usize,
        w: usize,
        k: usize,
        stride: usize,
    ) -> Self {
        ConvLayer {
            name: name.into(),
            n_in,
            n_out,
            h,
            w,
            k,
            stride,
            groups: 1,
            has_bypass: false,
            relu: true,
            bnorm: true,
            bypass_separate: false,
        }
    }

    pub fn with_groups(mut self, groups: usize) -> Self {
        assert_eq!(self.n_in % groups, 0, "groups must divide n_in");
        assert_eq!(self.n_out % groups, 0, "groups must divide n_out");
        self.groups = groups;
        self
    }

    pub fn with_bypass(mut self, has: bool) -> Self {
        self.has_bypass = has;
        self
    }

    pub fn with_bypass_separate(mut self, separate: bool) -> Self {
        self.bypass_separate = separate;
        self
    }

    pub fn with_relu(mut self, relu: bool) -> Self {
        self.relu = relu;
        self
    }

    pub fn with_bnorm(mut self, bnorm: bool) -> Self {
        self.bnorm = bnorm;
        self
    }

    /// Output spatial height (same-padding, as everywhere in the paper).
    pub fn h_out(&self) -> usize {
        self.h.div_ceil(self.stride)
    }

    /// Output spatial width.
    pub fn w_out(&self) -> usize {
        self.w.div_ceil(self.stride)
    }

    /// Output pixels.
    pub fn out_pixels(&self) -> u64 {
        (self.h_out() * self.w_out()) as u64
    }

    /// Input FM volume in words.
    pub fn in_words(&self) -> u64 {
        (self.n_in * self.h * self.w) as u64
    }

    /// Output FM volume in words.
    pub fn out_words(&self) -> u64 {
        self.n_out as u64 * self.out_pixels()
    }

    /// Number of binary weights (= number of MAC kernels × taps).
    pub fn weight_bits(&self) -> u64 {
        (self.n_out * (self.n_in / self.groups) * self.k * self.k) as u64
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        self.weight_bits() * self.out_pixels()
    }

    /// Convolution operations (paper convention: 1 MAC = 2 Op).
    pub fn conv_ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Batch-norm scale operations (one multiply per output pixel).
    pub fn bnorm_ops(&self) -> u64 {
        if self.bnorm {
            self.out_words()
        } else {
            0
        }
    }

    /// Bias-add operations (one add per output pixel).
    pub fn bias_ops(&self) -> u64 {
        self.out_words()
    }

    /// Residual bypass accumulation operations.
    pub fn bypass_ops(&self) -> u64 {
        if self.has_bypass {
            self.out_words()
        } else {
            0
        }
    }

    /// All operations attributable to this layer.
    pub fn total_ops(&self) -> u64 {
        self.conv_ops() + self.bnorm_ops() + self.bias_ops() + self.bypass_ops()
    }

    /// True if the layer is depth-wise (`groups == n_in`, 1 input channel
    /// per group).
    pub fn is_depthwise(&self) -> bool {
        self.groups == self.n_in && self.n_in == self.n_out
    }

    /// Whether the taped-out chip can execute this layer (§IV-C).
    pub fn chip_supported(&self) -> bool {
        matches!(self.k, 1 | 3) && matches!(self.stride, 1 | 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l() -> ConvLayer {
        ConvLayer::new("c", 64, 64, 56, 56, 3, 1)
    }

    #[test]
    fn shape_and_volume_accounting() {
        let c = l();
        assert_eq!(c.h_out(), 56);
        assert_eq!(c.in_words(), 64 * 56 * 56);
        assert_eq!(c.out_words(), 64 * 56 * 56);
        assert_eq!(c.weight_bits(), 64 * 64 * 9);
        assert_eq!(c.macs(), 64 * 64 * 9 * 56 * 56);
        assert_eq!(c.conv_ops(), 2 * c.macs());
    }

    #[test]
    fn strided_output_shapes() {
        let c = ConvLayer::new("s", 64, 128, 56, 56, 3, 2);
        assert_eq!((c.h_out(), c.w_out()), (28, 28));
        // Odd sizes round up (same padding), like YOLOv3's 5→3 stages.
        let o = ConvLayer::new("odd", 16, 16, 5, 5, 3, 2);
        assert_eq!((o.h_out(), o.w_out()), (3, 3));
    }

    #[test]
    fn grouped_and_depthwise_weights() {
        let g = ConvLayer::new("g", 240, 240, 28, 28, 1, 1).with_groups(8);
        assert_eq!(g.weight_bits(), 240 * 30);
        let dw = ConvLayer::new("dw", 240, 240, 28, 28, 3, 1).with_groups(240);
        assert!(dw.is_depthwise());
        assert_eq!(dw.weight_bits(), 240 * 9);
        assert_eq!(dw.macs(), 240 * 9 * 28 * 28);
    }

    #[test]
    fn post_op_accounting_follows_flags() {
        let c = l().with_bypass(true);
        assert_eq!(c.bypass_ops(), c.out_words());
        assert_eq!(c.bnorm_ops(), c.out_words());
        let nb = l().with_bnorm(false);
        assert_eq!(nb.bnorm_ops(), 0);
        assert_eq!(
            c.total_ops(),
            c.conv_ops() + 3 * c.out_words()
        );
    }

    #[test]
    fn chip_support_rules() {
        assert!(l().chip_supported());
        assert!(!ConvLayer::new("7x7", 3, 64, 224, 224, 7, 2).chip_supported());
    }

    #[test]
    #[should_panic(expected = "groups must divide")]
    fn invalid_groups_panic() {
        let _ = ConvLayer::new("bad", 30, 30, 8, 8, 1, 1).with_groups(4);
    }
}

//! CNN graph IR and model zoo.
//!
//! The paper evaluates Hyperdrive on ResNet-18/34/50/152, ShuffleNet and
//! YOLOv3 at several resolutions; [`zoo`] builds all of them (plus the
//! small end-to-end validation network) on top of the [`graph`] IR, which
//! is the single source of truth for op counts, FM volumes and layer
//! shapes used by the scheduler, the simulator, the energy model and the
//! paper-table generators.

pub mod graph;
pub mod layer;
pub mod zoo;

pub use graph::{Network, OffChipStage, Step, TensorRef};
pub use layer::ConvLayer;
pub use zoo::ResolutionError;

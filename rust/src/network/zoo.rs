//! Model zoo: every network the paper evaluates, built on the graph IR.
//!
//! * ResNet-18/34 (basic blocks, Fig. 4a) and ResNet-50/152 (bottleneck
//!   blocks, Fig. 4b) at arbitrary input resolution — used for Tbl II,
//!   III, V, VI and Fig 8/9/11;
//! * ShuffleNet v1 (g = 8, 1.0×) — Tbl V/VI;
//! * YOLOv3 (Darknet-53 backbone + 3-scale heads) — Tbl V/VI;
//! * HyperNet-20 — the end-to-end validation network, kept structurally
//!   identical to `python/compile/model.py::hypernet20_steps` (checked by
//!   an integration test against the AOT manifest).
//!
//! Residual shortcuts use 1×1 projection convolutions at stage
//! transitions (the paper analyses exactly this case as "more memory
//! critical", §IV-B). The first 7×7 convolution and the FC head of the
//! ResNets run off-chip (§VI-B) and are carried as [`OffChipStage`]s.

use std::fmt;

use super::graph::{Network, OffChipStage, TensorRef};
use super::layer::ConvLayer;

/// Typed rejection of an input resolution a builder cannot realize
/// exactly. Every zoo builder divides the image resolution by its
/// truncating stride factors (the ResNet/ShuffleNet stem's `h / 4`;
/// YOLOv3 additionally needs the full `h / 32` FPN grid alignment so the
/// 2× upsampled laterals match the next scale). Resolutions that are not
/// divisible by that granularity used to be silently truncated — now
/// they are rejected with this error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResolutionError {
    /// Network display name (e.g. `ResNet-34`).
    pub network: &'static str,
    /// Requested image height.
    pub h: usize,
    /// Requested image width.
    pub w: usize,
    /// Required divisor of both `h` and `w`.
    pub granularity: usize,
}

impl fmt::Display for ResolutionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: input resolution {}x{} is not divisible by the stage stride \
             product {} (the stem would silently truncate pixels)",
            self.network, self.h, self.w, self.granularity
        )
    }
}

impl std::error::Error for ResolutionError {}

/// Reject zero or non-divisible resolutions with a [`ResolutionError`].
fn check_resolution(
    network: &'static str,
    h: usize,
    w: usize,
    granularity: usize,
) -> Result<(), ResolutionError> {
    if h == 0 || w == 0 || h % granularity != 0 || w % granularity != 0 {
        return Err(ResolutionError {
            network,
            h,
            w,
            granularity,
        });
    }
    Ok(())
}

/// The ResNet/ShuffleNet stem divides the image by 4 exactly (7×7/s2
/// conv + maxpool); the later strided stages use same-padding `div_ceil`
/// and accept any size.
pub const STEM_GRANULARITY: usize = 4;

/// YOLOv3's FPN upsampling needs the full stride product: the 2×
/// nearest-upsampled `h/32` grid must land exactly on the `h/16` grid.
pub const FPN_GRANULARITY: usize = 32;

/// ResNet with basic blocks (Fig. 4a). `blocks` per stage, channels
/// 64/128/256/512. `(h, w)` is the *image* resolution; the on-chip input
/// FM is the post-conv1/maxpool `64 × h/4 × w/4`.
pub fn resnet_basic(
    name: &'static str,
    blocks: [usize; 4],
    h: usize,
    w: usize,
) -> Result<Network, ResolutionError> {
    check_resolution(name, h, w, STEM_GRANULARITY)?;
    let mut net = Network::new(name, 64, h / 4, w / 4);
    net.pre = Some(resnet_pre(h, w));
    let mut prev = TensorRef::Input;
    let mut ch = 64;
    let (mut fh, mut fw) = (h / 4, w / 4);
    for (stage, &nblocks) in blocks.iter().enumerate() {
        let out_ch = 64 << stage;
        for b in 0..nblocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let base = format!("s{}b{b}", stage + 2);
            let c1 = net.push(
                ConvLayer::new(format!("{base}c1"), ch, out_ch, fh, fw, 3, stride),
                prev,
                None,
            );
            // Shortcut: identity, or 1×1 strided projection at transitions.
            let shortcut = if stride == 1 && ch == out_ch {
                prev
            } else {
                TensorRef::Step(net.push(
                    ConvLayer::new(format!("{base}sk"), ch, out_ch, fh, fw, 1, stride)
                        .with_relu(false),
                    prev,
                    None,
                ))
            };
            let projected = stride != 1 || ch != out_ch;
            fh = fh.div_ceil(stride);
            fw = fw.div_ceil(stride);
            ch = out_ch;
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("{base}c2"), ch, ch, fh, fw, 3, 1)
                    .with_bypass(true)
                    .with_bypass_separate(projected),
                TensorRef::Step(c1),
                Some(shortcut),
            ));
        }
    }
    net.post = Some(resnet_post(ch));
    Ok(net)
}

/// ResNet with bottleneck blocks (Fig. 4b). Stage output channels
/// 256/512/1024/2048, mid channels out/4, stride in the first 1×1 of the
/// transition block (ResNet v1, the variant the paper's WCL analysis
/// assumes).
pub fn resnet_bottleneck(
    name: &'static str,
    blocks: [usize; 4],
    h: usize,
    w: usize,
) -> Result<Network, ResolutionError> {
    check_resolution(name, h, w, STEM_GRANULARITY)?;
    let mut net = Network::new(name, 64, h / 4, w / 4);
    net.pre = Some(resnet_pre(h, w));
    let mut prev = TensorRef::Input;
    let mut ch = 64;
    let (mut fh, mut fw) = (h / 4, w / 4);
    for (stage, &nblocks) in blocks.iter().enumerate() {
        let out_ch = 256 << stage;
        let mid = out_ch / 4;
        for b in 0..nblocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let base = format!("s{}b{b}", stage + 2);
            let a = net.push(
                ConvLayer::new(format!("{base}a"), ch, mid, fh, fw, 1, stride),
                prev,
                None,
            );
            // Projection shortcut whenever shape changes (every stage's
            // first block, including conv2_1's channel expansion).
            let projected = stride != 1 || ch != out_ch;
            let shortcut = if !projected {
                prev
            } else {
                TensorRef::Step(net.push(
                    ConvLayer::new(format!("{base}sk"), ch, out_ch, fh, fw, 1, stride)
                        .with_relu(false),
                    prev,
                    None,
                ))
            };
            fh = fh.div_ceil(stride);
            fw = fw.div_ceil(stride);
            ch = out_ch;
            let bmid = net.push(
                ConvLayer::new(format!("{base}b"), mid, mid, fh, fw, 3, 1),
                TensorRef::Step(a),
                None,
            );
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("{base}c"), mid, out_ch, fh, fw, 1, 1)
                    .with_bypass(true)
                    .with_bypass_separate(projected),
                TensorRef::Step(bmid),
                Some(shortcut),
            ));
        }
    }
    net.post = Some(resnet_post(ch));
    Ok(net)
}

fn resnet_pre(h: usize, w: usize) -> OffChipStage {
    // 7×7/s2 conv 3→64 + 3×3/s2 maxpool, computed on the host (§VI-B).
    let conv_ops = 2 * (3 * 64 * 49) as u64 * ((h / 2) * (w / 2)) as u64;
    OffChipStage {
        name: "conv1_7x7".into(),
        ops: conv_ops,
        weight_bits: (3 * 64 * 49) as u64,
        io_words: (3 * h * w) as u64, // raw image streamed to the host stage
    }
}

fn resnet_post(ch: usize) -> OffChipStage {
    OffChipStage {
        name: "fc".into(),
        ops: 2 * (ch * 1000) as u64,
        weight_bits: 0, // FC stays full-precision off-chip; not streamed
        io_words: ch as u64,
    }
}

/// ResNet-18 (basic, [2,2,2,2]).
pub fn resnet18(h: usize, w: usize) -> Result<Network, ResolutionError> {
    resnet_basic("ResNet-18", [2, 2, 2, 2], h, w)
}

/// ResNet-34 (basic, [3,4,6,3]) — the paper's main benchmark.
pub fn resnet34(h: usize, w: usize) -> Result<Network, ResolutionError> {
    resnet_basic("ResNet-34", [3, 4, 6, 3], h, w)
}

/// ResNet-50 (bottleneck, [3,4,6,3]).
pub fn resnet50(h: usize, w: usize) -> Result<Network, ResolutionError> {
    resnet_bottleneck("ResNet-50", [3, 4, 6, 3], h, w)
}

/// ResNet-152 (bottleneck, [3,8,36,3]).
pub fn resnet152(h: usize, w: usize) -> Result<Network, ResolutionError> {
    resnet_bottleneck("ResNet-152", [3, 8, 36, 3], h, w)
}

/// ShuffleNet v1, groups = 8, 1.0× (stage channels 384/768/1536) at image
/// resolution `(h, w)`.
///
/// Channel shuffles are free data routing on this chip (§VI-D) and the
/// strided blocks' `concat(avgpool(x), branch(x))` is approximated by a
/// full-width branch (the 3×3 average pool contributes < 1% of ops and
/// the widened 1×1 g-conv overcounts by the same order — documented
/// deviation, see EXPERIMENTS.md).
pub fn shufflenet(h: usize, w: usize) -> Result<Network, ResolutionError> {
    check_resolution("ShuffleNet", h, w, STEM_GRANULARITY)?;
    let mut net = Network::new("ShuffleNet", 24, h / 4, w / 4);
    // conv1 (3×3/s2, 24ch) runs on-chip in principle, but its 3-channel
    // input makes it host work in the paper's accounting; keep it off-chip
    // like the ResNet stem for comparability.
    net.pre = Some(OffChipStage {
        name: "conv1_3x3".into(),
        ops: 2 * (3 * 24 * 9) as u64 * ((h / 2) * (w / 2)) as u64,
        weight_bits: (3 * 24 * 9) as u64,
        io_words: (3 * h * w) as u64,
    });
    let stages = [(384usize, 4usize), (768, 8), (1536, 4)];
    let mut prev = TensorRef::Input;
    let mut ch = 24;
    let (mut fh, mut fw) = (h / 4, w / 4);
    for (si, &(out_ch, nblocks)) in stages.iter().enumerate() {
        for b in 0..nblocks {
            let strided = b == 0;
            let mid = out_ch / 4;
            let base = format!("st{}b{b}", si + 2);
            // First block of stage 2 uses g=1 (24 input channels).
            let g1 = if si == 0 && b == 0 { 1 } else { 8 };
            let a = net.push(
                ConvLayer::new(format!("{base}a"), ch, mid, fh, fw, 1, 1).with_groups(g1),
                prev,
                None,
            );
            let stride = if strided { 2 } else { 1 };
            let dw = net.push(
                ConvLayer::new(format!("{base}dw"), mid, mid, fh, fw, 3, stride)
                    .with_groups(mid)
                    .with_relu(false),
                TensorRef::Step(a),
                None,
            );
            fh = fh.div_ceil(stride);
            fw = fw.div_ceil(stride);
            let bypass = if strided { None } else { Some(prev) };
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("{base}c"), mid, out_ch, fh, fw, 1, 1)
                    .with_groups(8)
                    .with_bypass(bypass.is_some()),
                TensorRef::Step(dw),
                bypass,
            ));
            ch = out_ch;
        }
    }
    net.post = Some(OffChipStage {
        name: "fc".into(),
        ops: 2 * (ch * 1000) as u64,
        weight_bits: 0,
        io_words: ch as u64,
    });
    Ok(net)
}

/// YOLOv3: Darknet-53 backbone + 3-scale detection heads at image
/// resolution `(h, w)` (the paper uses 320×320, COCO classes → 255
/// output maps). Feature-pyramid concats are expressed with the IR's
/// `concat_extra` channel merge.
pub fn yolov3(h: usize, w: usize) -> Result<Network, ResolutionError> {
    check_resolution("YOLOv3", h, w, FPN_GRANULARITY)?;
    let mut net = Network::new("YOLOv3", 3, h, w);
    let mut prev = TensorRef::Input;
    let (mut fh, mut fw) = (h, w);
    let mut ch = 3;

    let conv = |net: &mut Network,
                    prev: &mut TensorRef,
                    ch: &mut usize,
                    fh: &mut usize,
                    fw: &mut usize,
                    name: String,
                    n_out: usize,
                    k: usize,
                    stride: usize| {
        let l = ConvLayer::new(name, *ch, n_out, *fh, *fw, k, stride);
        *prev = TensorRef::Step(net.push(l, *prev, None));
        *ch = n_out;
        *fh = fh.div_ceil(stride);
        *fw = fw.div_ceil(stride);
    };

    conv(&mut net, &mut prev, &mut ch, &mut fh, &mut fw, "d0".into(), 32, 3, 1);
    // (residual-count, channels) per Darknet-53 stage.
    let stages: [(usize, usize); 5] = [(1, 64), (2, 128), (8, 256), (8, 512), (4, 1024)];
    let mut route: Vec<TensorRef> = Vec::new(); // stage outputs for FPN
    for (si, &(nres, c)) in stages.iter().enumerate() {
        conv(&mut net, &mut prev, &mut ch, &mut fh, &mut fw,
             format!("d{}down", si + 1), c, 3, 2);
        for r in 0..nres {
            let block_in = prev;
            let a = net.push(
                ConvLayer::new(format!("d{}r{r}a", si + 1), c, c / 2, fh, fw, 1, 1),
                prev,
                None,
            );
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("d{}r{r}b", si + 1), c / 2, c, fh, fw, 3, 1)
                    .with_bypass(true),
                TensorRef::Step(a),
                Some(block_in),
            ));
        }
        route.push(prev);
    }

    // Detection heads (FPN): scale 0 at h/32, scale 1 at h/16, scale 2 at h/8.
    let mut upsampled: Option<(TensorRef, usize)> = None;
    for scale in 0..3usize {
        let backbone = route[4 - scale];
        let (bc, bh, bw) = net.shape_of(backbone);
        let mid = 512 >> scale;
        // 5-conv block; the first conv merges the upsampled FPN tensor.
        let mut cur = backbone;
        let mut cur_c = bc;
        for i in 0..5 {
            let k = if i % 2 == 0 { 1 } else { 3 };
            let n_out = if i % 2 == 0 { mid } else { mid * 2 };
            let n_in = if i == 0 {
                cur_c + upsampled.as_ref().map_or(0, |&(_, c)| c)
            } else {
                cur_c
            };
            let l = ConvLayer::new(format!("h{scale}c{i}"), n_in, n_out, bh, bw, k, 1);
            let extra = if i == 0 { upsampled.map(|(r, _)| r) } else { None };
            cur = TensorRef::Step(net.push_concat(l, cur, extra));
            cur_c = n_out;
        }
        // Detection pair: 3×3 ×2·mid then 1×1 to 255 output maps.
        let d = net.push(
            ConvLayer::new(format!("h{scale}det3"), cur_c, mid * 2, bh, bw, 3, 1),
            cur,
            None,
        );
        net.push(
            ConvLayer::new(format!("h{scale}det1"), mid * 2, 255, bh, bw, 1, 1)
                .with_relu(false),
            TensorRef::Step(d),
            None,
        );
        if scale < 2 {
            // FPN lateral: 1×1 to mid/2 then 2× nearest upsample (free on
            // chip: pixel replication by the DDUs).
            let lat = net.push(
                ConvLayer::new(format!("h{scale}lat"), cur_c, mid / 2, bh, bw, 1, 1),
                cur,
                None,
            );
            net.upsample_last();
            upsampled = Some((TensorRef::Step(lat), mid / 2));
        }
    }
    Ok(net)
}

/// TinyYOLO-style detector (§IV-C: "networks optimized for compute
/// effort, such as TinyYOLO … are often only composed of 3×3 and 1×1
/// convolution layers"): a 3×3 backbone with stride-2 downsampling folded
/// into the convolutions (the max-pools of the darknet reference are
/// reformulated as strided convs, a standard op-count-preserving
/// transformation) plus a 1×1/3×3 detection head.
pub fn tinyyolo(h: usize, w: usize) -> Result<Network, ResolutionError> {
    // All downsampling is same-padding `div_ceil`: any non-zero size works.
    check_resolution("TinyYOLO", h, w, 1)?;
    let mut net = Network::new("TinyYOLO", 3, h, w);
    let mut prev = TensorRef::Input;
    let (mut fh, mut fw) = (h, w);
    let mut ch = 3;
    let mut li = 0;
    for &(c, stride) in &[
        (16usize, 1usize),
        (32, 2),
        (64, 2),
        (128, 2),
        (256, 2),
        (512, 2),
        (1024, 1),
    ] {
        let l = ConvLayer::new(format!("t{li}"), ch, c, fh, fw, 3, stride);
        prev = TensorRef::Step(net.push(l, prev, None));
        ch = c;
        fh = fh.div_ceil(stride);
        fw = fw.div_ceil(stride);
        li += 1;
    }
    // Detection head: 1×1 256, 3×3 512, 1×1 255.
    let a = net.push(ConvLayer::new("h0", ch, 256, fh, fw, 1, 1), prev, None);
    let b = net.push(
        ConvLayer::new("h1", 256, 512, fh, fw, 3, 1),
        TensorRef::Step(a),
        None,
    );
    net.push(
        ConvLayer::new("h2", 512, 255, fh, fw, 1, 1).with_relu(false),
        TensorRef::Step(b),
        None,
    );
    Ok(net)
}

/// Binary-weight bits of the 1×1 projection shortcuts only — Tbl II's
/// weight column appears to use strided-identity (weight-free) shortcuts
/// for the bottleneck ResNets; subtracting this reconciles the counts.
pub fn projection_weight_bits(net: &Network) -> u64 {
    net.steps
        .iter()
        .filter(|s| s.layer.name.ends_with("sk"))
        .map(|s| s.layer.weight_bits())
        .sum()
}

/// HyperNet-20: the end-to-end validation network; must stay structurally
/// identical to `python/compile/model.py::hypernet20_steps`.
pub fn hypernet20() -> Network {
    let mut net = Network::new("HyperNet-20", 16, 32, 32);
    let mut prev = TensorRef::Input;
    let stage = |s: usize| match s {
        0 => (16usize, 32usize),
        1 => (32, 16),
        _ => (64, 8),
    };
    for s in 0..3usize {
        let (c, hw) = stage(s);
        for b in 0..3usize {
            let strided = s > 0 && b == 0;
            let (pc, phw) = if strided { stage(s - 1) } else { (c, hw) };
            let base = format!("s{}b{b}", s + 1);
            let stride = if strided { 2 } else { 1 };
            let c1 = net.push(
                ConvLayer::new(format!("{base}c1"), pc, c, phw, phw, 3, stride),
                prev,
                None,
            );
            let shortcut = if strided {
                TensorRef::Step(net.push(
                    ConvLayer::new(format!("{base}sk"), pc, c, phw, phw, 1, 2)
                        .with_relu(false),
                    prev,
                    None,
                ))
            } else {
                prev
            };
            prev = TensorRef::Step(net.push(
                ConvLayer::new(format!("{base}c2"), c, c, hw, hw, 3, 1)
                    .with_bypass(true)
                    .with_bypass_separate(strided),
                TensorRef::Step(c1),
                Some(shortcut),
            ));
        }
    }
    net.post = Some(OffChipStage {
        name: "head".into(),
        ops: 2 * (64 * 10) as u64,
        weight_bits: 0,
        io_words: 64,
    });
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet34_matches_paper_op_count() {
        // §VI-B: 7.09 GOp of conv on-chip, 7.3 GOp total; Tbl III:
        // bnorm/bias 2.94 MOp each, 4.52 M conv cycles at 1568 Op/cycle.
        let net = resnet34(224, 224).unwrap();
        net.validate().unwrap();
        let conv = net.conv_ops() as f64;
        assert!(
            (conv / 7.09e9 - 1.0).abs() < 0.02,
            "conv ops {conv:.3e} vs paper 7.09e9"
        );
        let bn = net.bnorm_ops() as f64;
        assert!((bn / 2.94e6 - 1.0).abs() < 0.02, "bnorm ops {bn:.3e}");
        assert_eq!(net.bnorm_ops(), net.bias_ops());
    }

    #[test]
    fn resnet34_weight_bits_match_table2() {
        let net = resnet34(224, 224).unwrap();
        let bits = net.weight_bits() as f64;
        assert!((bits / 21e6 - 1.0).abs() < 0.05, "weights {bits:.3e} vs 21M");
    }

    #[test]
    fn resnet18_weight_bits_match_table2() {
        let net = resnet18(224, 224).unwrap();
        let bits = net.weight_bits() as f64;
        assert!((bits / 11e6 - 1.0).abs() < 0.05, "weights {bits:.3e} vs 11M");
    }

    #[test]
    fn resnet152_weight_bits_match_table2() {
        let net = resnet152(224, 224).unwrap();
        let bits = net.weight_bits() as f64;
        // Paper: 55M (with identity-style shortcut accounting; projection
        // convs add ~5%).
        assert!((bits / 55e6 - 1.0).abs() < 0.08, "weights {bits:.3e} vs 55M");
    }

    #[test]
    fn resnet_shapes_reach_7x7_at_224() {
        let net = resnet34(224, 224).unwrap();
        assert_eq!(net.out_shape(), (512, 7, 7));
        let net50 = resnet50(224, 224).unwrap();
        assert_eq!(net50.out_shape(), (2048, 7, 7));
    }

    #[test]
    fn resnets_are_chip_supported() {
        for net in [resnet34(224, 224).unwrap(), resnet50(224, 224).unwrap()] {
            for s in &net.steps {
                assert!(s.layer.chip_supported(), "{}", s.layer.name);
            }
        }
    }

    #[test]
    fn shufflenet_mac_count_matches_architecture() {
        let net = shufflenet(224, 224).unwrap();
        net.validate().unwrap();
        let macs: f64 = net.steps.iter().map(|s| s.layer.macs() as f64).sum();
        // ShuffleNet v1 1.0× (g=8) is ~137 M multiply-adds (Zhang et al.).
        // The paper's Tbl VI lists "140 MOp", i.e. it counts the
        // architecture's published FLOPs figure directly; with this
        // repo's consistent 2 Op/MAC convention the same network is
        // ~275 MOp (documented in EXPERIMENTS.md).
        assert!(
            (macs / 137e6 - 1.0).abs() < 0.05,
            "shufflenet MACs {macs:.3e} vs 137e6"
        );
    }

    #[test]
    fn yolov3_op_count_near_paper() {
        let net = yolov3(320, 320).unwrap();
        net.validate().unwrap();
        let ops = net.total_ops() as f64;
        // Tbl VI: 53.1 GOp; public YOLOv3@320 figures are ~39 GFLOP + 2×
        // convention differences — accept the 39–56 G band and report the
        // exact number in EXPERIMENTS.md.
        assert!(
            ops > 39e9 && ops < 56e9,
            "yolov3 ops {ops:.3e} outside plausible band"
        );
    }

    #[test]
    fn resnet18_and_50_op_counts_sane() {
        // ResNet-18 @224²: ~3.6 GFLOPs total; on-chip conv share ~3.4G.
        let n18 = resnet18(224, 224).unwrap();
        let conv18 = n18.conv_ops() as f64;
        assert!((3.0e9..3.8e9).contains(&conv18), "{conv18:.3e}");
        // ResNet-50 @224²: ~4.1 G mult-adds = ~8 GOp, slightly above
        // ResNet-34 (the paper's "roughly 50% more compute-intensive"
        // overstates the standard counts).
        let n50 = resnet50(224, 224).unwrap();
        let conv50 = n50.conv_ops() as f64;
        assert!((7.0e9..8.6e9).contains(&conv50), "{conv50:.3e}");
        let ratio = conv50 / resnet34(224, 224).unwrap().conv_ops() as f64;
        assert!((1.0..1.25).contains(&ratio), "50/34 ratio {ratio}");
    }

    #[test]
    fn resnet50_memory_footprint_3_3x_of_34() {
        // §VI-B: ResNet-50's FM memory footprint is ~3.3× ResNet-34's.
        let a34 = crate::coordinator::wcl::analyze(&resnet34(224, 224).unwrap());
        let a50 = crate::coordinator::wcl::analyze(&resnet50(224, 224).unwrap());
        let ratio = a50.wcl_words as f64 / a34.wcl_words as f64;
        assert!((3.0..3.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn identity_shortcut_accounting_reconciles_table2() {
        // ResNet-50/152 weight bits minus projection shortcuts hit the
        // paper's 21M / 55M.
        let n50 = resnet50(224, 224).unwrap();
        let w50 = (n50.weight_bits() - projection_weight_bits(&n50)) as f64;
        assert!((w50 / 20.7e6 - 1.0).abs() < 0.03, "{w50:.3e}");
        let n152 = resnet152(224, 224).unwrap();
        let w152 = (n152.weight_bits() - projection_weight_bits(&n152)) as f64;
        assert!((w152 / 55e6 - 1.0).abs() < 0.03, "{w152:.3e}");
    }

    #[test]
    fn tinyyolo_is_chip_supported_and_sized() {
        let net = tinyyolo(416, 416).unwrap();
        net.validate().unwrap();
        for s in &net.steps {
            assert!(s.layer.chip_supported(), "{}", s.layer.name);
        }
        // TinyYOLO class: single-digit-M params, a few GOp at 416².
        let bits = net.weight_bits() as f64;
        assert!((6e6..14e6).contains(&bits), "weights {bits:.3e}");
        let ops = net.total_ops() as f64;
        assert!((4e9..8e9).contains(&ops), "ops {ops:.3e}");
    }

    #[test]
    fn hypernet20_matches_python_model() {
        let net = hypernet20();
        net.validate().unwrap();
        assert_eq!(net.steps.len(), 20);
        assert_eq!(net.out_shape(), (64, 8, 8));
        // Stage transitions have projection shortcuts.
        assert!(net.step_by_name("s2b0sk").is_some());
        assert!(net.step_by_name("s3b0sk").is_some());
        // Binary weight count must equal the AOT param blob's `w` words:
        // 272010 total − (gamma+beta = 2·Σn_out = 1536) − head (650).
        assert_eq!(net.weight_bits(), 269_824);
    }

    #[test]
    fn non_divisible_resolution_is_a_typed_error() {
        // 225 % 4 != 0: the stem would silently truncate `h / 4`.
        let err = resnet34(225, 224).unwrap_err();
        assert_eq!(
            err,
            ResolutionError {
                network: "ResNet-34",
                h: 225,
                w: 224,
                granularity: STEM_GRANULARITY,
            }
        );
        assert!(err.to_string().contains("stride"), "{err}");
        assert!(resnet50(224, 226).is_err());
        assert!(shufflenet(222, 224).is_err());
        // YOLOv3's FPN needs the full /32 alignment (336 % 32 = 16).
        let err = yolov3(336, 336).unwrap_err();
        assert_eq!(err.granularity, FPN_GRANULARITY);
    }

    #[test]
    fn zero_resolution_rejected_everywhere() {
        assert!(resnet18(0, 224).is_err());
        assert!(yolov3(320, 0).is_err());
        assert!(tinyyolo(0, 0).is_err());
    }

    #[test]
    fn div_ceil_resolutions_still_build() {
        // Divisible by the stem's 4 but not by the full stride product:
        // the strided stages use same-padding div_ceil, which is exact
        // conv arithmetic, not truncation (Fig 11's 112/168/336 points).
        for (h, w) in [(112, 112), (168, 168), (336, 336)] {
            let net = resnet34(h, w).unwrap();
            net.validate().unwrap();
            assert_eq!(net.out_shape().0, 512);
        }
        // TinyYOLO accepts any non-zero size.
        tinyyolo(417, 233).unwrap().validate().unwrap();
    }
}

//! Paper-table and figure generators: every table (I–VI) and figure
//! (8–11) of the evaluation section, printed as text rows/series. Used
//! by the benches (`rust/benches/*`), the CLI (`hyperdrive report …`)
//! and the examples. Schedule/energy-derived tables consume the typed
//! `engine::EngineReport` instead of re-deriving their own tuples.

use crate::baselines::weight_stationary::hyperdrive_fig11_bits;
use crate::baselines::{published_rows, weight_stationary_io_bits};
use crate::coordinator::border::{border_memory_bits, corner_memory_bits};
use crate::coordinator::schedule::{
    schedule_network, trace_layer, DepthwisePolicy, WeightSource,
};
use crate::coordinator::tiling::{plan_mesh, MeshPlan};
use crate::coordinator::wcl;
use crate::energy::{breakdown, opchar, scaling};
use crate::engine::{Engine, EngineReport};
use crate::model;
use crate::network::{ConvLayer, Network};
use crate::util::fmt_bits;
use crate::ChipConfig;

/// Build the analytic [`EngineReport`] for one registry model spec on
/// an optional explicit mesh — the single typed source every
/// schedule/energy table row reads from.
fn engine_report(
    spec: &str,
    cfg: &ChipConfig,
    mesh: Option<(usize, usize)>,
    dw: DepthwisePolicy,
) -> EngineReport {
    let mut b = Engine::builder().model(spec).chip(*cfg).depthwise(dw);
    if let Some((rows, cols)) = mesh {
        b = b.mesh(rows, cols);
    }
    b.build().expect("report engine").report()
}

fn single() -> MeshPlan {
    MeshPlan {
        rows: 1,
        cols: 1,
        per_chip_wcl_words: 0,
    }
}

/// Tbl I: the weight-stream schedule of a 16→64 3×3 convolution.
pub fn table1() -> String {
    let mut out = String::new();
    out.push_str("Table I — Hyperdrive time schedule (16 in / 64 out FM, 3x3 conv, 8x8 tiles)\n");
    out.push_str("cycle | cout-tile | pixel | tap(dy,dx) | c_in | weight source\n");
    let l = ConvLayer::new("tbl1", 16, 64, 56, 56, 3, 1);
    let cfg = ChipConfig::default();
    let tr = trace_layer(&l, &cfg, 40_000);
    let show = [0usize, 1, 15, 16, 143, 144, 287, 9215, 9216, 36863];
    for &i in &show {
        let e = tr[i];
        let dy = (e.tap / 3) as isize - 1;
        let dx = (e.tap % 3) as isize - 1;
        let src = match e.source {
            WeightSource::Stream => "stream (I/O)",
            WeightSource::Buffer => "weight buffer (no I/O)",
        };
        out.push_str(&format!(
            "{:>6} | {:>9} | {:>5} | ({dy:+},{dx:+})    | {:>4} | {src}\n",
            e.cycle, e.cout_tile, e.pixel, e.cin + 1
        ));
    }
    out.push_str(&format!("total cycles for the layer: {}\n", tr.len()));
    out
}

/// Tbl II: weights / all-FM / worst-case memory for the zoo networks.
pub fn table2() -> String {
    let rows: Vec<(Network, &str)> = vec![
        (model::network("resnet18@224x224").unwrap(), "224x224"),
        (model::network("resnet34@224x224").unwrap(), "224x224"),
        (model::network("resnet50@224x224").unwrap(), "224x224"),
        (model::network("resnet152@224x224").unwrap(), "224x224"),
        (model::network("resnet34@1024x2048").unwrap(), "2048x1024"),
        (model::network("resnet152@1024x2048").unwrap(), "2048x1024"),
    ];
    let c = ChipConfig::default().c;
    let mut out = String::new();
    out.push_str("Table II — data volumes (binary weights, 16-bit FMs)\n");
    out.push_str(&format!(
        "{:<12} {:>10} {:>10} {:>10} {:>10} {:>10}\n",
        "network", "resolution", "weights", "packed", "all FMs", "WC mem"
    ));
    for (net, res) in rows {
        let a = wcl::analyze(&net);
        // "packed" is the resident u64-bitplane stream footprint
        // (weights plus stream padding: tail channels of each C-block
        // and the final partial plane word).
        out.push_str(&format!(
            "{:<12} {:>10} {:>10} {:>10} {:>10} {:>10}\n",
            net.name,
            res,
            fmt_bits(net.weight_bits()),
            fmt_bits(crate::bwn::network_packed_bytes(&net, c) * 8),
            fmt_bits(a.all_fm_bits(16)),
            fmt_bits(a.wcl_bits(16)),
        ));
    }
    out.push_str("(paper: 11M/36M/6.4M, 21M/61M/6.4M, 21M/156M/21M, 55M/355M/21M,\n");
    out.push_str("        21M/2.5G/267M, 55M/14.8G/878M)\n");
    out
}

/// Tbl III: ResNet-34 cycle/throughput split.
pub fn table3(cfg: &ChipConfig) -> String {
    let rep = engine_report("resnet34@224x224", cfg, None, DepthwisePolicy::default());
    let s = &rep.schedule;
    let f = opchar::MEASURED_POINTS[0].freq_hz; // 0.5 V
    let mut out = String::new();
    out.push_str("Table III — cycles & throughput, ResNet-34 @224² (paper in parens)\n");
    out.push_str(&format!(
        "{:<8} {:>12} {:>12} {:>10}\n",
        "phase", "#cycles", "#Op", "#Op/cycle"
    ));
    let rows = [
        ("conv", s.cycles.conv, s.conv_ops, "(4.52M, 7.09G, 1568)"),
        ("bnorm", s.cycles.bnorm, s.bnorm_ops, "(59.9k, 2.94M, 49)"),
        ("bias", s.cycles.bias, s.bias_ops, "(59.9k, 2.94M, 49)"),
        ("bypass", s.cycles.bypass, s.bypass_ops, "(7.68k, 376k, 49)"),
    ];
    for (name, cyc, ops, paper) in rows {
        out.push_str(&format!(
            "{:<8} {:>12} {:>12} {:>10.0}   {paper}\n",
            name,
            cyc,
            ops,
            ops as f64 / cyc.max(1) as f64
        ));
    }
    out.push_str(&format!(
        "{:<8} {:>12} {:>12} {:>10.2}   (4.65M, 7.10G, 1.53k)\n",
        "total",
        s.total_cycles(),
        s.total_ops(),
        s.ops_per_cycle()
    ));
    out.push_str(&format!(
        "throughput @0.5V: {:.0} GOp/s (paper 431 G @ measured clock)\n",
        s.ops_per_cycle() * f / 1e9
    ));
    out
}

/// Tbl IV: operating points (measured anchors + model interpolation).
pub fn table4(cfg: &ChipConfig) -> String {
    let net = model::network("resnet34@224x224").unwrap();
    let s = schedule_network(&net, cfg, DepthwisePolicy::default());
    let opc = s.ops_per_cycle();
    let mut out = String::new();
    out.push_str("Table IV — operating points (measured anchors; model in parens)\n");
    out.push_str(&format!(
        "{:<10} {:>10} {:>10} {:>12} {:>14} {:>16}\n",
        "VDD [V]", "f [MHz]", "P [mW]", "Op/cycle", "Thr [GOp/s]", "Core eff [TOp/s/W]"
    ));
    for p in opchar::MEASURED_POINTS {
        let fm = scaling::freq_hz(p.vdd, 0.0) / 1e6;
        let pm = scaling::power_w(p.vdd, 0.0) * 1e3;
        out.push_str(&format!(
            "{:<10} {:>6.0} ({:>4.0}) {:>5.0} ({:>4.0}) {:>10} {:>14.0} {:>16.1}\n",
            p.vdd,
            p.freq_hz / 1e6,
            fm,
            p.power_w * 1e3,
            pm,
            cfg.ops_per_cycle(),
            p.peak_throughput_ops(cfg) / 1e9,
            p.core_efficiency(opc) / 1e12
        ));
    }
    out.push_str(&format!(
        "best point 0.5V + 1.5V FBB: core eff {:.1} TOp/s/W (paper 4.9)\n",
        scaling::core_efficiency_ops_per_j(0.5, 1.5, opc) / 1e12
    ));
    out
}

/// Tbl V: comparison with the state of the art.
pub fn table5(cfg: &ChipConfig) -> String {
    let mut out = String::new();
    out.push_str("Table V — comparison with state-of-the-art BWN accelerators\n");
    out.push_str(&format!(
        "{:<28} {:<10} {:<12} {:>8} {:>9} {:>9} {:>9} {:>11}\n",
        "name", "DNN", "input", "Thr[GOp/s]", "core[mJ]", "I/O[mJ]", "tot[mJ]", "eff[TOp/s/W]"
    ));
    for r in published_rows() {
        out.push_str(&format!(
            "{:<28} {:<10} {:<12} {:>8.0} {:>9.1} {:>9.1} {:>9.1} {:>11.1}\n",
            r.name, r.dnn, r.input, r.eff_throughput_gops, r.core_e_mj, r.io_e_mj,
            r.total_e_mj, r.efficiency_tops_w
        ));
    }
    // Hyperdrive rows from the unified engine's typed report.
    let dw = DepthwisePolicy::FullRate;
    let cases: Vec<(&str, Option<(usize, usize)>, &str)> = vec![
        ("resnet34@224x224", None, "224x224"),
        ("shufflenet@224x224", None, "224x224"),
        ("yolov3@320x320", None, "320x320"),
        ("resnet34@1024x2048", Some((5, 10)), "2kx1k(10x5)"),
        ("resnet152@1024x2048", Some((10, 20)), "2kx1k(20x10)"),
    ];
    for (spec, mesh, input) in cases {
        let rep = engine_report(spec, cfg, mesh, dw);
        let r = &rep.energy;
        out.push_str(&format!(
            "{:<28} {:<10} {:<12} {:>8.0} {:>9.1} {:>9.1} {:>9.1} {:>11.1}\n",
            format!("Hyperdrive (model, {} chip)", r.chips),
            rep.network,
            input,
            r.throughput_ops_s / 1e9,
            r.core_j * 1e3,
            r.io_j * 1e3,
            r.total_j() * 1e3,
            r.system_efficiency_ops_w() / 1e12
        ));
    }
    out.push_str("(paper Hyperdrive rows: ResNet-34 1.4/0.5/1.9 mJ 3.6 T; YOLOv3 13.1/1.4/14.5 3.7 T;\n");
    out.push_str(" ResNet-34 2kx1k 61.9/7.6/69.5 4.3 T; ResNet-152 185.2/21.6/206.8 4.4 T)\n");
    out
}

/// Tbl VI: utilization per network.
pub fn table6(cfg: &ChipConfig) -> String {
    let mut out = String::new();
    out.push_str("Table VI — utilization (total incl. post phases / conv-phase only)\n");
    out.push_str(&format!(
        "{:<22} {:>10} {:>12} {:>11} {:>9} {:>9}\n",
        "network", "#Op", "#cycles", "#Op/cycle", "util", "conv-util"
    ));
    out.push_str(&format!(
        "{:<22} {:>10} {:>12} {:>11} {:>9} {:>9}\n",
        "Baseline (peak)", "-", "-", cfg.ops_per_cycle(), "100.0%", "100.0%"
    ));
    let nets = [
        ("resnet34@224x224", "(97.5%)"),
        ("shufflenet@224x224", "(98.8%)"),
        ("yolov3@320x320", "(82.8%)"),
    ];
    for (spec, paper) in nets {
        let rep = engine_report(spec, cfg, None, DepthwisePolicy::FullRate);
        let s = &rep.schedule;
        out.push_str(&format!(
            "{:<22} {:>10} {:>12} {:>11.0} {:>8.1}% {:>8.1}% {paper}\n",
            rep.network,
            fmt_bits(s.total_ops()),
            s.total_cycles(),
            s.ops_per_cycle(),
            100.0 * s.utilization(cfg),
            100.0 * s.conv_utilization(cfg),
        ));
    }
    out.push_str("(ShuffleNet with bank-serialized depth-wise — the faithful model):\n");
    let rep = engine_report(
        "shufflenet@224x224",
        cfg,
        None,
        DepthwisePolicy::BankSerialized,
    );
    let s = &rep.schedule;
    out.push_str(&format!(
        "{:<22} {:>10} {:>12} {:>11.0} {:>8.1}% {:>8.1}%\n",
        "ShuffleNet (serial dw)",
        fmt_bits(s.total_ops()),
        s.total_cycles(),
        s.ops_per_cycle(),
        100.0 * s.utilization(cfg),
        100.0 * s.conv_utilization(cfg),
    ));
    out
}

/// Fig 8: efficiency vs throughput across body-bias settings.
pub fn fig8(cfg: &ChipConfig) -> String {
    let net = model::network("resnet34@224x224").unwrap();
    let s = schedule_network(&net, cfg, DepthwisePolicy::default());
    let opc = s.ops_per_cycle();
    let io_j = crate::energy::io::hyperdrive_io(&net, &single(), cfg.fm_bits).energy_j();
    let mut out = String::new();
    out.push_str("Fig 8 — energy efficiency vs throughput (ResNet-34 incl. I/O)\n");
    out.push_str("VBB[V]  VDD[V]  thr[GOp/s]  sys-eff[TOp/s/W]\n");
    for &vbb in &[0.0, 0.5, 1.0, 1.5, 1.8] {
        for &vdd in &[0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8] {
            let f = scaling::freq_hz(vdd, vbb);
            let thr = opc * f / 1e9;
            let core_j = scaling::energy_per_cycle_j(vdd, vbb) * s.total_cycles() as f64;
            let eff = s.total_ops() as f64 / (core_j + io_j) / 1e12;
            out.push_str(&format!(
                "{vbb:<7.1} {vdd:<7.2} {thr:>10.0} {eff:>15.2}\n"
            ));
        }
    }
    out.push_str("(paper: best point 0.5 V + 1.5 V FBB, 3.6 TOp/s/W at 88 GOp/s)\n");
    out
}

/// Fig 9: efficiency and throughput vs VDD.
pub fn fig9(cfg: &ChipConfig) -> String {
    let net = model::network("resnet34@224x224").unwrap();
    let s = schedule_network(&net, cfg, DepthwisePolicy::default());
    let opc = s.ops_per_cycle();
    let io_j = crate::energy::io::hyperdrive_io(&net, &single(), cfg.fm_bits).energy_j();
    let mut out = String::new();
    out.push_str("Fig 9 — energy efficiency & throughput vs VDD (0 V FBB)\n");
    out.push_str("VDD[V]  f[MHz]  thr[GOp/s]  core-eff[TOp/s/W]  sys-eff[TOp/s/W]\n");
    let mut v = 0.40;
    while v <= 0.801 {
        let f = scaling::freq_hz(v, 0.0);
        let core = scaling::core_efficiency_ops_per_j(v, 0.0, opc) / 1e12;
        let core_j = scaling::energy_per_cycle_j(v, 0.0) * s.total_cycles() as f64;
        let sys = s.total_ops() as f64 / (core_j + io_j) / 1e12;
        out.push_str(&format!(
            "{v:<7.2} {:<7.1} {:>10.1} {core:>18.2} {sys:>17.2}\n",
            f / 1e6,
            opc * f / 1e9
        ));
        v += 0.05;
    }
    out.push_str("(peak at 0.5 V; leakage-dominated below, CV² above — §VI-A)\n");
    out
}

/// Fig 10: power/energy breakdown at the 0.5 V point.
pub fn fig10(cfg: &ChipConfig) -> String {
    let net = model::network("resnet34@224x224").unwrap();
    let b = breakdown::breakdown(&net, cfg, &single());
    let f = b.fractions();
    let mut out = String::new();
    out.push_str("Fig 10 — energy breakdown, ResNet-34 @ 0.5 V\n");
    let names = [
        "Tile-PU adders (sign-accumulate)",
        "Tile-PU post (bnorm/bias/bypass)",
        "FMM SRAM (array+periphery)",
        "Weight buffer (SCM)",
        "Other logic (clock/ctrl)",
        "I/O (weights + input FM)",
    ];
    for (n, frac) in names.iter().zip(f) {
        out.push_str(&format!("{n:<36} {:>5.1}%\n", 100.0 * frac));
    }
    out.push_str(&format!(
        "core {:.2} mJ/im + I/O {:.2} mJ/im = {:.2} mJ/im\n",
        b.core_j() * 1e3,
        b.io_j * 1e3,
        b.total_j() * 1e3
    ));
    out.push_str("(paper: arithmetic dominates; memory+I/O are small — §VI-A)\n");
    out
}

/// Fig 11: I/O bits, weight-stationary vs Hyperdrive, vs image size.
pub fn fig11(cfg: &ChipConfig) -> String {
    let mut out = String::new();
    out.push_str("Fig 11 — I/O volume vs image size (ResNet-34 features)\n");
    out.push_str("size      mesh   weight-stationary   Hyperdrive(wgt+border)   reduction\n");
    for &(h, w) in &[
        (112usize, 112usize),
        (168, 168),
        (224, 224),
        (336, 336),
        (448, 448),
        (672, 672),
        (896, 896),
        (1024, 2048),
    ] {
        let net = model::network(&format!("resnet34@{h}x{w}")).unwrap();
        let plan = plan_mesh(&net, cfg);
        let ws = weight_stationary_io_bits(&net, 16);
        let hd = hyperdrive_fig11_bits(&net, &plan, 16);
        out.push_str(&format!(
            "{:<9} {:>2}x{:<2} {:>19} {:>24} {:>10.1}x\n",
            format!("{w}x{h}"),
            plan.rows,
            plan.cols,
            fmt_bits(ws),
            fmt_bits(hd),
            ws as f64 / hd as f64
        ));
    }
    out.push_str("(paper: weights constant at 21.6 Mbit on a single chip; border\n");
    out.push_str(" exchange grows with tiling; reduction up to 2.7x at 2x2, 2.5x at 3x3 —\n");
    out.push_str(" our honest FM-streaming baseline gives larger reductions)\n");
    out
}

/// Border/corner memory summary (§V-C, used by the mesh example).
pub fn border_memories(cfg: &ChipConfig) -> String {
    let net = model::network("resnet34@224x224").unwrap();
    let a = wcl::analyze(&net);
    let bm = border_memory_bits(&net, &a, 1, 1, cfg.fm_bits);
    let cm = corner_memory_bits(&net, cfg.fm_bits);
    format!(
        "Border memory: {} (paper 459 kbit, +7%); Corner memory: {} (paper 64 kbit, +1%)\n",
        fmt_bits(bm),
        fmt_bits(cm)
    )
}

/// Precision ablation table (§VI-D projection) for the CLI.
pub fn ablations(cfg: &ChipConfig) -> String {
    use crate::energy::ablation;
    let mut out = String::new();
    for net in [
        model::network("resnet34@224x224").unwrap(),
        model::network("resnet34@1024x2048").unwrap(),
    ] {
        let rows = ablation::precision_ablation(&net, cfg);
        out.push_str(&ablation::render(&net.name, &rows));
        out.push('\n');
    }
    out
}

/// All tables and figures concatenated.
pub fn all(cfg: &ChipConfig) -> String {
    let mut s = String::new();
    for part in [
        table1(),
        table2(),
        table3(cfg),
        table4(cfg),
        table5(cfg),
        table6(cfg),
        fig8(cfg),
        fig9(cfg),
        fig10(cfg),
        fig11(cfg),
        border_memories(cfg),
        ablations(cfg),
    ] {
        s.push_str(&part);
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_report_renders() {
        let cfg = ChipConfig::default();
        for (name, text) in [
            ("table1", table1()),
            ("table2", table2()),
            ("table3", table3(&cfg)),
            ("table4", table4(&cfg)),
            ("table5", table5(&cfg)),
            ("table6", table6(&cfg)),
            ("fig8", fig8(&cfg)),
            ("fig9", fig9(&cfg)),
            ("fig10", fig10(&cfg)),
            ("fig11", fig11(&cfg)),
        ] {
            assert!(text.lines().count() >= 5, "{name} too short:\n{text}");
        }
    }

    #[test]
    fn table2_contains_expected_rows() {
        let t = table2();
        assert!(t.contains("ResNet-18"));
        assert!(t.contains("ResNet-152"));
        assert!(t.contains("6.4M"), "{t}");
        // The resident-stream column sits beside the logical weights.
        assert!(t.contains("packed"), "{t}");
    }

    #[test]
    fn table5_reports_headline_efficiency() {
        let t = table5(&ChipConfig::default());
        assert!(t.contains("Hyperdrive"), "{t}");
        // The multichip detection row must be present.
        assert!(t.contains("2kx1k(10x5)"), "{t}");
    }
}

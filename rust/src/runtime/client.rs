//! PJRT client wrapper: HLO-text artifact → compiled executable → run.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py` and DESIGN.md).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// A PJRT CPU runtime holding compiled executables by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact under `name`.
    pub fn load_artifact(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn loaded(&self) -> usize {
        self.executables.len()
    }

    /// Execute an artifact with f32 tensor inputs; returns the flattened
    /// f32 output (artifacts are lowered with `return_tuple=True`, so the
    /// single result is unwrapped from a 1-tuple).
    pub fn execute(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact `{name}` not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal {name}: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in `rust/tests/pjrt_runtime.rs` (they
    // need the AOT artifacts from `make artifacts`).
}

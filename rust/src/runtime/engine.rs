//! The inference engine: walks the manifest's step list, streams packed
//! binary weights, and executes each layer's AOT artifact on PJRT.
//!
//! This is the request-path composition of the whole stack: weights go
//! through the real `bwn` pack → stream → unpack path (what the silicon
//! serializes over its pins), feature maps live in buffers whose peak is
//! bounded by the §IV-B memory plan, and every layer is one compiled
//! XLA executable produced from the Pallas kernel at build time.

use anyhow::{Context, Result};

use crate::bwn::pack_weights;
use crate::coordinator::memory::{self, MemoryPlan};
use crate::network::TensorRef;

use super::client::Runtime;
use super::registry::NetworkManifest;

/// The Hyperdrive inference engine (single chip, PJRT CPU backend).
/// Batch serving with latency statistics lives in the backend-generic
/// serving layer: `crate::engine::Engine::serve`.
pub struct InferenceEngine {
    pub runtime: Runtime,
    pub manifest: NetworkManifest,
    /// Dense ±1 weights per step, reconstructed from the packed stream
    /// (exactly what the chip's weight buffer deserializes).
    step_weights: Vec<Vec<f32>>,
    /// The §IV-B memory plan (peak == WCL, validated at load).
    pub memory_plan: MemoryPlan,
}

impl InferenceEngine {
    /// Load artifacts + parameters from an artifact directory.
    pub fn load(dir: impl Into<std::path::PathBuf>) -> Result<Self> {
        let manifest = NetworkManifest::load(dir)?;
        let mut runtime = Runtime::cpu()?;
        for a in manifest.artifacts.values() {
            runtime
                .load_artifact(&a.name, &a.file)
                .with_context(|| format!("loading artifact {}", a.name))?;
        }
        // Binary-weight path: blob → pack (stream words) → unpack. The
        // round trip is exact for ±1 weights; this is the on-pin format.
        let mut step_weights = Vec::new();
        for s in &manifest.network.steps {
            let w = manifest.blob(&s.layer.name, "w")?;
            let stream = pack_weights(&s.layer, w, 16);
            let dense = stream.unpack_dense();
            debug_assert_eq!(dense, w, "{}: pack/unpack must be exact", s.layer.name);
            step_weights.push(dense);
        }
        let memory_plan = memory::plan_tight(&manifest.network)?;
        Ok(InferenceEngine {
            runtime,
            manifest,
            step_weights,
            memory_plan,
        })
    }

    /// Run one inference; returns the class logits.
    pub fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
        Ok(self.infer_trace(input)?.1)
    }

    /// Run one inference keeping every intermediate FM (for
    /// cross-validation against the functional simulator).
    pub fn infer_trace(&self, input: &[f32]) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let net = &self.manifest.network;
        assert_eq!(input.len(), net.in_ch * net.in_h * net.in_w);
        let mut fms: Vec<Vec<f32>> = Vec::with_capacity(net.steps.len());
        for (i, s) in net.steps.iter().enumerate() {
            let l = &s.layer;
            let src: &[f32] = match s.src {
                TensorRef::Input => input,
                TensorRef::Step(j) => &fms[j],
            };
            let gamma = self.manifest.blob(&l.name, "gamma")?;
            let beta = self.manifest.blob(&l.name, "beta")?;
            let w = &self.step_weights[i];
            let wshape = [l.n_out, l.n_in, l.k, l.k];
            let in_shape = [l.n_in, l.h, l.w];
            let out_shape = [l.n_out, l.h_out(), l.w_out()];
            let artifact = &self.manifest.step_artifacts[i];
            let out = if let Some(b) = s.bypass {
                let byp: &[f32] = match b {
                    TensorRef::Input => input,
                    TensorRef::Step(j) => &fms[j],
                };
                self.runtime.execute(
                    artifact,
                    &[
                        (src, &in_shape),
                        (w.as_slice(), &wshape),
                        (gamma, &[l.n_out]),
                        (beta, &[l.n_out]),
                        (byp, &out_shape),
                    ],
                )?
            } else {
                self.runtime.execute(
                    artifact,
                    &[
                        (src, &in_shape),
                        (w.as_slice(), &wshape),
                        (gamma, &[l.n_out]),
                        (beta, &[l.n_out]),
                    ],
                )?
            };
            fms.push(out);
        }
        // Off-chip head (its own artifact, like the paper's host stage).
        let (c, h, w) = net.out_shape();
        let w_fc = self.manifest.blob("head", "w_fc")?;
        let b_fc = self.manifest.blob("head", "b_fc")?;
        let logits = self.runtime.execute(
            "head",
            &[
                (fms.last().unwrap().as_slice(), &[c, h, w]),
                (w_fc, &[self.manifest.n_classes, c]),
                (b_fc, &[self.manifest.n_classes]),
            ],
        )?;
        Ok((fms, logits))
    }
}

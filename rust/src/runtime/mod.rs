//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Python never runs at inference time: the artifacts are compiled once
//! by [`client::Runtime`] (PJRT CPU), the manifest is parsed by
//! [`registry`], and [`engine::InferenceEngine`] walks the network step
//! list feeding FM and (unpacked) binary-weight literals.
//!
//! The PJRT-dependent pieces ([`client`], [`engine`]) are gated behind
//! the `pjrt` cargo feature, which needs the vendored xla-rs bindings
//! (DESIGN.md §Substitutions). The manifest [`registry`] is always
//! available — the simulator backends use it to run with the real
//! trained parameters. Prefer the unified `crate::engine` API
//! (`Engine::builder().artifacts(..)`) over using this module directly;
//! batch serving lives in the backend-generic `crate::engine::serve`.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod registry;

#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use engine::InferenceEngine;
pub use registry::{ArtifactKind, NetworkManifest};

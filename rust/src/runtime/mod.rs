//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Python never runs at inference time: the artifacts are compiled once
//! by [`client::Runtime`] (PJRT CPU), the manifest is parsed by
//! [`registry`], and [`engine::InferenceEngine`] walks the network step
//! list feeding FM and (unpacked) binary-weight literals.

pub mod client;
pub mod engine;
pub mod registry;

pub use client::Runtime;
pub use engine::InferenceEngine;
pub use registry::{ArtifactKind, NetworkManifest};

//! Manifest-driven artifact registry: binds the AOT manifest to the
//! network IR, the parameter blob and the golden files.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::network::{ConvLayer, Network, TensorRef};
use crate::util::manifest::{read_f32_blob, Manifest};

/// Kind of an AOT artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Conv,
    Head,
}

/// One artifact entry from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: ArtifactKind,
    pub file: PathBuf,
    pub layer: Option<ConvLayer>,
}

/// A parameter tensor reference into the blob.
#[derive(Debug, Clone, Copy)]
pub struct BlobSlice {
    pub off: usize,
    pub len: usize,
}

/// The fully-parsed AOT manifest for a network.
pub struct NetworkManifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactInfo>,
    /// The network reconstructed from the step list.
    pub network: Network,
    /// Artifact name per step.
    pub step_artifacts: Vec<String>,
    /// Blob slices: (step name, field) → slice.
    pub blobs: HashMap<(String, String), BlobSlice>,
    /// The parameter blob (f32 words).
    pub params: Vec<f32>,
    pub n_classes: usize,
}

impl NetworkManifest {
    /// Load `dir/manifest.tsv` plus the parameter blob.
    pub fn load(dir: impl Into<PathBuf>) -> Result<NetworkManifest> {
        let dir = dir.into();
        let m = Manifest::load(&dir)?;

        let mut artifacts = HashMap::new();
        let mut layer_by_artifact: HashMap<String, ConvLayer> = HashMap::new();
        for r in m.of_kind("artifact") {
            let name = r.get("name")?.to_string();
            let kind = match r.get("kind")? {
                "conv" => ArtifactKind::Conv,
                "head" => ArtifactKind::Head,
                other => bail!("unknown artifact kind `{other}`"),
            };
            let layer = if kind == ArtifactKind::Conv {
                let l = ConvLayer::new(
                    name.clone(),
                    r.get_usize("n_in")?,
                    r.get_usize("n_out")?,
                    r.get_usize("h")?,
                    r.get_usize("w")?,
                    r.get_usize("k")?,
                    r.get_usize("stride")?,
                )
                .with_bypass(r.get_bool("bypass")?)
                .with_relu(r.get_bool("relu")?);
                layer_by_artifact.insert(name.clone(), l.clone());
                Some(l)
            } else {
                None
            };
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    kind,
                    file: m.file(r.get("file")?),
                    layer,
                },
            );
        }

        let netrec = m.unique("network")?;
        let mut network = Network::new(
            netrec.get("name")?,
            netrec.get_usize("in_ch")?,
            netrec.get_usize("in_h")?,
            netrec.get_usize("in_w")?,
        );
        let n_classes = netrec.get_usize("classes")?;

        let mut step_artifacts = Vec::new();
        for r in m.of_kind("step") {
            let aname = r.get("artifact")?;
            let mut layer = layer_by_artifact
                .get(aname)
                .with_context(|| format!("step references unknown artifact `{aname}`"))?
                .clone();
            layer.name = r.get("name")?.to_string();
            let src = match r.get_isize("src")? {
                -1 => TensorRef::Input,
                i if i >= 0 => TensorRef::Step(i as usize),
                other => bail!("bad src {other}"),
            };
            let bypass = match r.get_isize("bypass")? {
                -2 => None,
                -1 => Some(TensorRef::Input),
                i if i >= 0 => Some(TensorRef::Step(i as usize)),
                other => bail!("bad bypass {other}"),
            };
            network.push(layer, src, bypass);
            step_artifacts.push(aname.to_string());
        }
        network.validate()?;

        let mut blobs = HashMap::new();
        for r in m.of_kind("blob") {
            blobs.insert(
                (r.get("step")?.to_string(), r.get("field")?.to_string()),
                BlobSlice {
                    off: r.get_usize("off")?,
                    len: r.get_usize("len")?,
                },
            );
        }

        let params = read_f32_blob(m.file("e2e_params.bin"))?;
        let expect = m.unique("blobfile")?.get_usize("words")?;
        if params.len() != expect {
            bail!("param blob has {} words, manifest says {expect}", params.len());
        }

        Ok(NetworkManifest {
            dir,
            artifacts,
            network,
            step_artifacts,
            blobs,
            params,
            n_classes,
        })
    }

    /// Slice of the parameter blob for (step, field).
    pub fn blob(&self, step: &str, field: &str) -> Result<&[f32]> {
        let s = self
            .blobs
            .get(&(step.to_string(), field.to_string()))
            .with_context(|| format!("no blob for ({step}, {field})"))?;
        Ok(&self.params[s.off..s.off + s.len])
    }

    /// Load a golden f32 file by manifest name.
    pub fn golden(&self, file: &str) -> Result<Vec<f32>> {
        read_f32_blob(self.dir.join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifacts directory (tests are skipped when `make artifacts` has
    /// not run; integration tests assert its presence).
    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.tsv").exists().then_some(dir)
    }

    #[test]
    fn manifest_reconstructs_hypernet20() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let nm = NetworkManifest::load(dir).unwrap();
        assert_eq!(nm.network.steps.len(), 20);
        assert_eq!(nm.n_classes, 10);
        // Must agree with the zoo twin.
        let zoo_net = crate::model::network("hypernet20").unwrap();
        assert_eq!(nm.network.steps.len(), zoo_net.steps.len());
        for (a, b) in nm.network.steps.iter().zip(&zoo_net.steps) {
            assert_eq!(a.layer.name, b.layer.name);
            assert_eq!(
                (a.layer.n_in, a.layer.n_out, a.layer.k, a.layer.stride),
                (b.layer.n_in, b.layer.n_out, b.layer.k, b.layer.stride),
                "{}",
                a.layer.name
            );
            assert_eq!(a.src, b.src, "{}", a.layer.name);
            assert_eq!(a.bypass, b.bypass, "{}", a.layer.name);
        }
    }

    #[test]
    fn blob_slices_cover_all_steps() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let nm = NetworkManifest::load(dir).unwrap();
        for s in &nm.network.steps {
            let l = &s.layer;
            let w = nm.blob(&l.name, "w").unwrap();
            assert_eq!(w.len() as u64, l.weight_bits(), "{}", l.name);
            // Weights are strictly ±1 after python-side binarization.
            assert!(w.iter().all(|&v| v == 1.0 || v == -1.0), "{}", l.name);
            assert_eq!(nm.blob(&l.name, "gamma").unwrap().len(), l.n_out);
            assert_eq!(nm.blob(&l.name, "beta").unwrap().len(), l.n_out);
        }
        assert_eq!(nm.blob("head", "w_fc").unwrap().len(), 10 * 64);
        assert_eq!(nm.blob("head", "b_fc").unwrap().len(), 10);
    }
}

//! FMM banking model — validates §IV-A's claim that "all these accesses
//! are aligned (e.g., all the Tile-PUs are reading the FMM bank of their
//! corresponding top-left neighbor) and therefore no access conflicts
//! occur".
//!
//! Physical organisation (§VI): `M × 8 = 7×8` single-port SRAMs with
//! 1024 lines of `N·16 = 112`-bit words — one line holds the same local
//! pixel/channel word for *all N tile columns* of one tile row, so a
//! single read broadcasts to a whole row of Tile-PUs, and a horizontal
//! neighbour access is just a field selection within the same line.
//!
//! Per conv cycle every tile row issues exactly one line read to the
//! (possibly vertically adjacent) owner row's bank set; conflict-freedom
//! means: within a cycle, each (row, bank) is accessed at most once.

use crate::network::ConvLayer;
use crate::util::ceil_div;
use crate::ChipConfig;

/// Result of the bank-level simulation of one layer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BankStats {
    /// Conv cycles simulated.
    pub cycles: u64,
    /// Total SRAM line reads.
    pub line_reads: u64,
    /// Maximum simultaneous accesses observed on any single bank in any
    /// cycle (must be 1 for the §IV-A claim to hold).
    pub max_bank_concurrency: u32,
    /// Cycles in which an output write targeted a bank also being read
    /// (resolved by the ping-pong segment separation; reported to show
    /// the dual-port-free design is sound).
    pub read_write_same_bank_cycles: u64,
}

/// Number of banks per tile row in the taped-out chip.
pub const BANKS_PER_ROW: usize = 8;

/// Simulate the bank access pattern of one layer's conv phase.
///
/// Iterates Algorithm 1's (pixel, tap, c_in) cycle loop; for each cycle
/// computes the line address each tile row reads, asserts alignment, and
/// tracks per-bank concurrency. Output writes are modelled at the pixel
/// completion cycles with the ping-pong segment offset.
pub fn simulate_banked_layer(layer: &ConvLayer, cfg: &ChipConfig) -> BankStats {
    let l = layer;
    let (ho, wo) = (l.h_out(), l.w_out());
    let tile_h_out = ceil_div(ho, cfg.m).max(1);
    let tile_w_out = ceil_div(wo, cfg.n).max(1);
    let tile_h_in = ceil_div(l.h, cfg.m).max(1);
    let tile_w_in = ceil_div(l.w, cfg.n).max(1);
    let n_in_eff = l.n_in / l.groups;
    let taps = l.k * l.k;
    let half = (l.k / 2) as isize;

    let mut stats = BankStats::default();
    // Pending output write: issued one cycle after pixel completion
    // (§IV-B's read-add-write with one-cycle latency), i.e. during the
    // next pixel's first read cycle.
    let mut pending_write: Option<usize> = None;
    // One output-channel tile is representative (the pattern repeats).
    for ly in 0..tile_h_out {
        for lx in 0..tile_w_out {
            for tap in 0..taps {
                let dy = (tap / l.k) as isize - half;
                let dx = (tap % l.k) as isize - half;
                for ci in 0..n_in_eff {
                    stats.cycles += 1;
                    // Per tile row ty: which owner row and which line?
                    // All rows share the same local (iy_loc, ix_loc) by
                    // alignment; verify that and count bank accesses.
                    let mut accesses: Vec<(usize, usize)> = Vec::with_capacity(cfg.m);
                    let mut common_line: Option<usize> = None;
                    for ty in 0..cfg.m {
                        // Global y of this tile row's requested pixel for
                        // local output row `ly`.
                        let gy = (ty * tile_h_out + ly) as isize * l.stride as isize + dy;
                        if gy < 0 || gy >= l.h as isize {
                            continue; // DDU zero padding: no SRAM access
                        }
                        let owner_row = (gy as usize / tile_h_in).min(cfg.m - 1);
                        let iy_loc = gy as usize - owner_row * tile_h_in;
                        // Horizontal: all tile columns select fields of
                        // one line; compute the owner-local x from tile
                        // column 0 (alignment makes it identical).
                        let gx = (lx as isize) * l.stride as isize + dx;
                        let ix_loc = if gx < 0 {
                            continue;
                        } else {
                            let gx = gx as usize;
                            if gx >= l.w {
                                continue;
                            }
                            gx % tile_w_in
                        };
                        let line = (ci * tile_h_in + iy_loc) * tile_w_in + ix_loc;
                        // Alignment claim: every tile row reads the same
                        // line index (of its owner row's bank set).
                        match common_line {
                            None => common_line = Some(line),
                            Some(c) => assert_eq!(
                                c, line,
                                "§IV-A alignment violated at `{}`",
                                l.name
                            ),
                        }
                        accesses.push((owner_row, line % BANKS_PER_ROW));
                    }
                    stats.line_reads += accesses.len() as u64;
                    // Conflict check: each (row, bank) at most once.
                    accesses.sort_unstable();
                    let mut max_c = 1u32;
                    let mut run = 1u32;
                    for w in accesses.windows(2) {
                        if w[0] == w[1] {
                            run += 1;
                            max_c = max_c.max(run);
                        } else {
                            run = 1;
                        }
                    }
                    if !accesses.is_empty() {
                        stats.max_bank_concurrency = stats.max_bank_concurrency.max(max_c);
                    }
                    // Write modelling: the previous pixel's output write
                    // is issued during this (first) cycle — the §IV-B
                    // one-cycle-latency read-add-write.
                    if tap == 0 && ci == 0 {
                        if let Some(out_bank) = pending_write.take() {
                            // Same bank index = same physical SRAM as a
                            // read (different segment/line): possible
                            // only because the ping-pong separation puts
                            // the write on the *other* segment's lines.
                            if accesses.iter().any(|&(_, b)| b == out_bank) {
                                stats.read_write_same_bank_cycles += 1;
                            }
                        }
                    }
                    if tap == taps - 1 && ci == n_in_eff - 1 {
                        pending_write = Some((ly * tile_w_out + lx) % BANKS_PER_ROW);
                    }
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn resnet34_layers_are_conflict_free() {
        // §IV-A: no FMM bank conflicts across every ResNet-34 layer.
        for s in &model::network("resnet34@224x224").unwrap().steps {
            let st = simulate_banked_layer(&s.layer, &cfg());
            assert!(
                st.max_bank_concurrency <= 1,
                "{}: concurrency {}",
                s.layer.name,
                st.max_bank_concurrency
            );
        }
    }

    #[test]
    fn strided_and_1x1_layers_conflict_free() {
        for l in [
            crate::network::ConvLayer::new("s2", 64, 128, 56, 56, 3, 2),
            crate::network::ConvLayer::new("p1", 64, 128, 56, 56, 1, 1),
            crate::network::ConvLayer::new("p2", 64, 128, 56, 56, 1, 2),
        ] {
            let st = simulate_banked_layer(&l, &cfg());
            assert!(st.max_bank_concurrency <= 1, "{}", l.name);
        }
    }

    #[test]
    fn odd_sized_fms_stay_aligned() {
        // YOLOv3's 10×10 FMs on 7×7 tiles pad, but accesses stay aligned.
        let l = crate::network::ConvLayer::new("y", 512, 1024, 10, 10, 3, 1);
        let st = simulate_banked_layer(&l, &cfg());
        assert_eq!(st.max_bank_concurrency, 1);
    }

    #[test]
    fn line_read_count_matches_row_broadcast_model() {
        // Interior taps read one line per tile row: cycles × M at most,
        // fewer at the padded borders.
        let l = crate::network::ConvLayer::new("c", 16, 16, 56, 56, 3, 1);
        let st = simulate_banked_layer(&l, &cfg());
        assert!(st.line_reads <= st.cycles * cfg().m as u64);
        assert!(st.line_reads > st.cycles * (cfg().m as u64 - 1));
    }

    #[test]
    fn ping_pong_avoids_read_write_port_conflicts() {
        // Writes land on banks also being read in some cycles — exactly
        // why §IV-B needs the one-cycle-latency ping-pong trick. The
        // simulation must observe such cycles (they exist) while the
        // read path itself stays conflict-free.
        let l = crate::network::ConvLayer::new("c", 16, 16, 56, 56, 3, 1);
        let st = simulate_banked_layer(&l, &cfg());
        assert!(st.read_write_same_bank_cycles > 0);
        assert_eq!(st.max_bank_concurrency, 1);
    }
}

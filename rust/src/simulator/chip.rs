//! Single-chip functional simulator: Algorithm 1, bit-faithful.
//!
//! Executes one layer exactly in the chip's order by driving the shared
//! Tile-PU datapath kernel ([`super::datapath::run_tile`] — the same
//! code the mesh simulator runs per chip) over the full feature map,
//! optionally rounding every intermediate to FP16 like the silicon.
//! Counts all memory traffic for the energy breakdown (Fig 10).
//! [`run_layer_threads`] fans the kernel out over output-channel ranges
//! on scoped threads; results and counters are bit-identical at any
//! thread count because each output pixel's rounding sequence lives
//! entirely inside one kernel invocation.

use crate::bwn::{PackedLayerWeights, WeightStream};
use crate::network::ConvLayer;

use super::datapath::{
    analytic_counts, partition_ranges, resolve_threads, run_tile, run_tile_batch, weight_traffic,
    InputSurface, TileGeom,
};
use super::fm::FeatureMap;

pub use super::datapath::{AccessCounts, Precision};

/// Parameters of one layer execution.
pub struct LayerParams<'a> {
    pub layer: &'a ConvLayer,
    /// Packed binary weights in stream order.
    pub stream: &'a WeightStream,
    /// Per-output-channel scale (folded batch-norm α; 1.0 if none).
    pub gamma: &'a [f32],
    /// Per-output-channel bias (β).
    pub beta: &'a [f32],
}

/// Execute one layer on a full (single-chip) input FM.
///
/// `bypass` must be `Some` iff `layer.has_bypass`. Returns the output FM
/// and the access counts. Spatial tile bookkeeping (for neighbour-read
/// counting) uses `tile_h × tile_w` Tile-PU patches of `m×n` tiles.
pub fn run_layer(
    p: &LayerParams,
    input: &FeatureMap,
    bypass: Option<&FeatureMap>,
    prec: Precision,
    tiles_mn: (usize, usize),
) -> (FeatureMap, AccessCounts) {
    run_layer_threads(p, input, bypass, prec, tiles_mn, 1)
}

/// [`run_layer`] fanned out over `threads` scoped workers, each running
/// the shared datapath kernel over a contiguous output-channel range
/// (channels are independent in Algorithm 1 — the chip computes C of
/// them in parallel Tile-PU lanes for the same reason). `threads == 0`
/// means one worker per available core, like
/// [`super::mesh::MeshSim::threads`] (see
/// [`super::datapath::resolve_threads`]).
///
/// Outputs and [`AccessCounts`] are bit-identical for every `threads`
/// value: each output pixel's FP16 rounding sequence runs entirely
/// inside one worker, the workers write disjoint channel planes, and
/// the per-worker counters are exact partitions summed in channel
/// order.
pub fn run_layer_threads(
    p: &LayerParams,
    input: &FeatureMap,
    bypass: Option<&FeatureMap>,
    prec: Precision,
    tiles_mn: (usize, usize),
    threads: usize,
) -> (FeatureMap, AccessCounts) {
    let l = p.layer;
    assert_eq!((input.c, input.h, input.w), (l.n_in, l.h, l.w));
    assert_eq!(l.has_bypass, bypass.is_some());
    assert_eq!(p.gamma.len(), l.n_out);
    assert_eq!(p.beta.len(), l.n_out);

    let (ho, wo) = (l.h_out(), l.w_out());
    let (m, n) = tiles_mn;
    let geom = TileGeom {
        oy0: 0,
        oy1: ho,
        ox0: 0,
        ox1: wo,
        iy0: 0,
        ix0: 0,
        tile_h: ho.div_ceil(m).max(1),
        tile_w: wo.div_ceil(n).max(1),
        in_tile_h: l.h.div_ceil(m).max(1),
        in_tile_w: l.w.div_ceil(n).max(1),
    };
    let mut out = FeatureMap::zeros(l.n_out, ho, wo);
    let mut acc = AccessCounts::default();
    let plane = ho * wo;
    // Expand the packed bitplanes into sign-mask planes once per layer;
    // every worker below borrows the same expansion.
    let packed = PackedLayerWeights::new(p.stream);
    let packed = &packed;
    let workers = resolve_threads(threads).min(l.n_out).max(1);
    if workers <= 1 {
        let data = &mut out.data;
        let mut write =
            |co: usize, oy: usize, ox: usize, v: f32| data[(co * ho + oy) * wo + ox] = v;
        acc.add(&run_tile(
            l,
            packed,
            p.gamma,
            p.beta,
            (0, l.n_out),
            input,
            bypass,
            prec,
            &geom,
            &mut write,
        ));
    } else {
        // Balanced fan-out: every worker gets ⌊n/w⌋ or ⌈n/w⌉ channels.
        // (The former `div_ceil` chunking could idle trailing workers
        // entirely — 10 channels over 8 workers made chunks of 2, so
        // only 5 workers computed anything.)
        let ranges = partition_ranges(l.n_out, workers);
        let counts = std::thread::scope(|s| {
            let mut rest = out.data.as_mut_slice();
            let mut handles = Vec::with_capacity(ranges.len());
            for &(co0, co1) in &ranges {
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((co1 - co0) * plane);
                rest = tail;
                handles.push(s.spawn(move || {
                    let mut write = |co: usize, oy: usize, ox: usize, v: f32| {
                        chunk[((co - co0) * ho + oy) * wo + ox] = v;
                    };
                    run_tile(
                        l,
                        packed,
                        p.gamma,
                        p.beta,
                        (co0, co1),
                        input,
                        bypass,
                        prec,
                        &geom,
                        &mut write,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("datapath worker panicked"))
                .collect::<Vec<_>>()
        });
        // Deterministic reduction in channel-chunk order.
        for c in &counts {
            acc.add(c);
        }
    }
    // Weight traffic: every stream word enters once, then is re-read per
    // remaining pixel of the Tile-PU tile (Tbl I schedule).
    let (sw, wb) = weight_traffic(l, p.stream.c, (geom.tile_h * geom.tile_w) as u64);
    acc.stream_words += sw;
    acc.wbuf_reads += wb;
    (out, acc)
}

/// Change-based execution: recompute only the given output rectangles
/// of one layer and splice the fresh pixels into `out` (the cached
/// previous-frame output FM) — the single-chip leg of the
/// streaming-video dirty-tile mode.
///
/// Rectangles are `(oy0, oy1, ox0, ox1)` in output coordinates and must
/// be disjoint (the caller's dirty tracker produces a tile partition).
/// Each recomputed pixel runs the unmodified datapath kernel over the
/// full channel range with the exact per-pixel rounding chain of a full
/// [`run_layer_threads`] pass, so dirty pixels are bit-identical to a
/// full recompute and clean pixels keep their cached bits — which *are*
/// the full-recompute bits whenever the caller's dirty set covers every
/// changed receptive field.
///
/// Counters are the actual traffic of the partial pass: analytic counts
/// per rectangle, plus one weight stream iff at least one pixel is
/// recomputed (the stream passes once regardless of how many tiles
/// consume it; a fully-clean layer streams nothing). The `saved_*`
/// fields are charged with the difference against a full recompute of
/// the layer.
pub fn run_layer_rects(
    p: &LayerParams,
    input: &FeatureMap,
    bypass: Option<&FeatureMap>,
    prec: Precision,
    tiles_mn: (usize, usize),
    out: &mut FeatureMap,
    rects: &[(usize, usize, usize, usize)],
) -> AccessCounts {
    let l = p.layer;
    assert_eq!((input.c, input.h, input.w), (l.n_in, l.h, l.w));
    assert_eq!(l.has_bypass, bypass.is_some());
    assert_eq!(p.gamma.len(), l.n_out);
    assert_eq!(p.beta.len(), l.n_out);
    let (ho, wo) = (l.h_out(), l.w_out());
    assert_eq!((out.c, out.h, out.w), (l.n_out, ho, wo));

    let (m, n) = tiles_mn;
    let base = TileGeom {
        oy0: 0,
        oy1: ho,
        ox0: 0,
        ox1: wo,
        iy0: 0,
        ix0: 0,
        tile_h: ho.div_ceil(m).max(1),
        tile_w: wo.div_ceil(n).max(1),
        in_tile_h: l.h.div_ceil(m).max(1),
        in_tile_w: l.w.div_ceil(n).max(1),
    };
    // What a full recompute of this layer counts (the savings baseline).
    let mut full = analytic_counts(l, (0, l.n_out), bypass.is_some(), &base);
    let (fsw, fwb) = weight_traffic(l, p.stream.c, (base.tile_h * base.tile_w) as u64);
    full.stream_words += fsw;
    full.wbuf_reads += fwb;

    let mut acc = AccessCounts::default();
    let mut dirty_pixels = 0u64;
    let packed = PackedLayerWeights::new(p.stream);
    let data = &mut out.data;
    let mut write = |co: usize, oy: usize, ox: usize, v: f32| data[(co * ho + oy) * wo + ox] = v;
    for &(oy0, oy1, ox0, ox1) in rects {
        debug_assert!(oy1 <= ho && ox1 <= wo, "rect outside the output FM");
        if oy0 >= oy1 || ox0 >= ox1 {
            continue;
        }
        dirty_pixels += ((oy1 - oy0) * (ox1 - ox0)) as u64;
        let geom = TileGeom { oy0, oy1, ox0, ox1, ..base };
        acc.add(&run_tile(
            l,
            &packed,
            p.gamma,
            p.beta,
            (0, l.n_out),
            input,
            bypass,
            prec,
            &geom,
            &mut write,
        ));
    }
    if dirty_pixels > 0 {
        // The dirty tiles share the broadcast stream word like the full
        // schedule's m×n Tile-PUs do: the word enters once and is
        // re-read per remaining pixel a single PU consumes.
        let per_pu = dirty_pixels.div_ceil((m * n) as u64);
        let (sw, _) = weight_traffic(l, p.stream.c, per_pu);
        acc.stream_words += sw;
        acc.wbuf_reads += sw * (per_pu.max(1) - 1);
    }
    acc.with_saved_vs(&full)
}

/// [`run_layer_threads`] for a micro-batch of `B` resident images: the
/// shared batch kernel ([`run_tile_batch`]) streams each weight block
/// once and applies it to all `B` feature maps, so `stream_words` is
/// counted **once per batch** (the paper's serving amortization) while
/// every compute counter still scales with `B`. A word now serves
/// `B × tile_pixels` output pixels — the first use comes off the
/// stream, the remaining `B·tile_pixels − 1` from the weight buffer.
///
/// Per-image outputs are bit-identical to `B` sequential
/// [`run_layer_threads`] calls at any thread count: workers still own
/// disjoint output-channel ranges (now across all images), and each
/// image's per-pixel rounding chain is untouched by batching.
pub fn run_layer_batch_threads(
    p: &LayerParams,
    inputs: &[&FeatureMap],
    bypasses: Option<&[&FeatureMap]>,
    prec: Precision,
    tiles_mn: (usize, usize),
    threads: usize,
) -> (Vec<FeatureMap>, AccessCounts) {
    let l = p.layer;
    let b = inputs.len();
    if let Some(bps) = bypasses {
        assert_eq!(bps.len(), b, "one bypass FM per batched image");
    }
    assert_eq!(l.has_bypass, bypasses.is_some());
    assert_eq!(p.gamma.len(), l.n_out);
    assert_eq!(p.beta.len(), l.n_out);
    if b == 0 {
        return (Vec::new(), AccessCounts::default());
    }
    for input in inputs {
        assert_eq!((input.c, input.h, input.w), (l.n_in, l.h, l.w));
    }

    let (ho, wo) = (l.h_out(), l.w_out());
    let (m, n) = tiles_mn;
    let geom = TileGeom {
        oy0: 0,
        oy1: ho,
        ox0: 0,
        ox1: wo,
        iy0: 0,
        ix0: 0,
        tile_h: ho.div_ceil(m).max(1),
        tile_w: wo.div_ceil(n).max(1),
        in_tile_h: l.h.div_ceil(m).max(1),
        in_tile_w: l.w.div_ceil(n).max(1),
    };
    let mut outs: Vec<FeatureMap> = (0..b).map(|_| FeatureMap::zeros(l.n_out, ho, wo)).collect();
    let mut acc = AccessCounts::default();
    let plane = ho * wo;
    // The `&dyn InputSurface` views are built per worker (trait objects
    // do not carry `Sync`; the underlying `&FeatureMap`s do).
    fn view<'x>(fms: &[&'x FeatureMap]) -> Vec<&'x dyn InputSurface> {
        fms.iter().map(|f| *f as &dyn InputSurface).collect()
    }
    // One sign-mask expansion per layer, shared by every worker and
    // every batch slot of this pass.
    let packed = PackedLayerWeights::new(p.stream);
    let packed = &packed;
    let workers = resolve_threads(threads).min(l.n_out).max(1);
    if workers <= 1 {
        let ins = view(inputs);
        let byps = bypasses.map(view);
        let mut planes: Vec<&mut [f32]> =
            outs.iter_mut().map(|o| o.data.as_mut_slice()).collect();
        let mut write = |bi: usize, co: usize, oy: usize, ox: usize, v: f32| {
            planes[bi][(co * ho + oy) * wo + ox] = v;
        };
        acc.add(&run_tile_batch(
            l,
            packed,
            p.gamma,
            p.beta,
            (0, l.n_out),
            &ins,
            byps.as_deref(),
            prec,
            &geom,
            &mut write,
        ));
    } else {
        // Same balanced channel fan-out as the single-image path; each
        // worker owns its channel range of *every* image's output.
        let ranges = partition_ranges(l.n_out, workers);
        let counts = std::thread::scope(|s| {
            let mut per_range: Vec<Vec<&mut [f32]>> =
                ranges.iter().map(|_| Vec::with_capacity(b)).collect();
            for out in outs.iter_mut() {
                let mut rest = out.data.as_mut_slice();
                for (ri, &(co0, co1)) in ranges.iter().enumerate() {
                    let (chunk, tail) =
                        std::mem::take(&mut rest).split_at_mut((co1 - co0) * plane);
                    rest = tail;
                    per_range[ri].push(chunk);
                }
            }
            let mut handles = Vec::with_capacity(ranges.len());
            for (&(co0, co1), mut chunks) in ranges.iter().zip(per_range) {
                handles.push(s.spawn(move || {
                    let ins = view(inputs);
                    let byps = bypasses.map(view);
                    let mut write = |bi: usize, co: usize, oy: usize, ox: usize, v: f32| {
                        chunks[bi][((co - co0) * ho + oy) * wo + ox] = v;
                    };
                    run_tile_batch(
                        l,
                        packed,
                        p.gamma,
                        p.beta,
                        (co0, co1),
                        &ins,
                        byps.as_deref(),
                        prec,
                        &geom,
                        &mut write,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("batch datapath worker panicked"))
                .collect::<Vec<_>>()
        });
        for c in &counts {
            acc.add(c);
        }
    }
    // Weight traffic once per *batch*: each stream word enters once and
    // then serves B × tile_pixels output pixels from the weight buffer.
    let tile_pixels = (geom.tile_h * geom.tile_w) as u64;
    let (sw, _) = weight_traffic(l, p.stream.c, tile_pixels);
    acc.stream_words += sw;
    acc.wbuf_reads += sw * ((b as u64 * tile_pixels).max(1) - 1);
    (outs, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bwn::pack_weights;
    use crate::network::ConvLayer;
    use crate::testkit;
    use crate::util::SplitMix64;

    fn make_params(l: &ConvLayer, rng: &mut SplitMix64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let n_in_eff = l.n_in / l.groups;
        let w: Vec<f32> = (0..l.n_out * n_in_eff * l.k * l.k)
            .map(|_| rng.next_sym())
            .collect();
        let gamma: Vec<f32> = (0..l.n_out).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..l.n_out).map(|_| rng.next_sym()).collect();
        (w, gamma, beta)
    }

    /// Plain reference convolution (independent loop order, f32).
    fn ref_conv(
        l: &ConvLayer,
        w: &[f32],
        gamma: &[f32],
        beta: &[f32],
        input: &FeatureMap,
        bypass: Option<&FeatureMap>,
    ) -> FeatureMap {
        let (ho, wo) = (l.h_out(), l.w_out());
        let mut out = FeatureMap::zeros(l.n_out, ho, wo);
        let half = (l.k / 2) as isize;
        let gso = l.n_out / l.groups;
        let nie = l.n_in / l.groups;
        for co in 0..l.n_out {
            let cb = (co / gso) * nie;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut v = 0.0f64;
                    for ci in 0..nie {
                        for ky in 0..l.k {
                            for kx in 0..l.k {
                                let iy = (oy * l.stride) as isize + ky as isize - half;
                                let ix = (ox * l.stride) as isize + kx as isize - half;
                                let x = input.get_padded(cb + ci, iy, ix) as f64;
                                let wv = w[(co * nie + ci) * l.k * l.k + ky * l.k + kx];
                                let s = if wv >= 0.0 { 1.0 } else { -1.0 };
                                v += s * x;
                            }
                        }
                    }
                    let mut v = v as f32;
                    if l.bnorm {
                        v *= gamma[co];
                    }
                    if let Some(bp) = bypass {
                        v += bp.get(co, oy, ox);
                    }
                    v += beta[co];
                    if l.relu && v < 0.0 {
                        v = 0.0;
                    }
                    out.set(co, oy, ox, v);
                }
            }
        }
        out
    }

    #[test]
    fn matches_reference_f32_property() {
        testkit::check_n("chip sim vs ref conv", 0xc41b, 60, |rng| {
            let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
            let stride = if rng.next_u64() & 1 == 0 { 1 } else { 2 };
            let n_in = 1 + rng.next_below(8);
            let n_out = 1 + rng.next_below(20);
            let h = (stride * (1 + rng.next_below(6))).max(k);
            let l = ConvLayer::new("t", n_in, n_out, h, h, k, stride);
            let (w, gamma, beta) = make_params(&l, rng);
            let input = FeatureMap::from_vec(
                n_in,
                h,
                h,
                (0..n_in * h * h).map(|_| rng.next_sym()).collect(),
            );
            let stream = pack_weights(&l, &w, 16);
            let p = LayerParams {
                layer: &l,
                stream: &stream,
                gamma: &gamma,
                beta: &beta,
            };
            let (out, _) = run_layer(&p, &input, None, Precision::F32, (7, 7));
            let want = ref_conv(&l, &w, &gamma, &beta, &input, None);
            testkit::assert_allclose(&out.data, &want.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn bypass_accumulates_before_bias() {
        let mut rng = SplitMix64::new(3);
        let l = ConvLayer::new("b", 4, 4, 6, 6, 3, 1).with_bypass(true);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input = FeatureMap::from_vec(4, 6, 6, (0..4 * 36).map(|_| rng.next_sym()).collect());
        let byp = FeatureMap::from_vec(4, 6, 6, (0..4 * 36).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (out, _) = run_layer(&p, &input, Some(&byp), Precision::F32, (7, 7));
        let want = ref_conv(&l, &w, &gamma, &beta, &input, Some(&byp));
        testkit::assert_allclose(&out.data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn f16_rounding_bounds_error_vs_f32() {
        let mut rng = SplitMix64::new(9);
        let l = ConvLayer::new("f", 16, 16, 8, 8, 3, 1);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input =
            FeatureMap::from_vec(16, 8, 8, (0..16 * 64).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (o16, _) = run_layer(&p, &input, None, Precision::F16, (7, 7));
        let (o32, _) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        let d = o16.max_abs_diff(&o32);
        assert!(d > 0.0, "FP16 must actually round");
        // 144-term accumulation of O(1) values: error stays ~ulp·√n.
        assert!(d < 0.5, "f16 error too large: {d}");
    }

    #[test]
    fn access_counts_match_formulas() {
        let l = ConvLayer::new("a", 16, 64, 56, 56, 3, 1);
        let mut rng = SplitMix64::new(1);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input =
            FeatureMap::from_vec(16, 56, 56, (0..16 * 56 * 56).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (_, acc) = run_layer(&p, &input, None, Precision::F16, (7, 7));
        // Conv reads: n_out × h·w × 9 taps × 16 c_in.
        assert_eq!(acc.fmm_reads, 64 * 56 * 56 * 9 * 16);
        assert_eq!(acc.fmm_writes, 64 * 56 * 56);
        assert_eq!(acc.accumulates, acc.fmm_reads);
        // Stream: 4 c_out tiles × 9 × 16 words; re-read per pixel (8×8−1).
        assert_eq!(acc.stream_words, 4 * 9 * 16);
        assert_eq!(acc.wbuf_reads, 4 * 9 * 16 * 63);
        assert_eq!(acc.post_mults, 64 * 56 * 56);
        assert_eq!(acc.post_adds, 64 * 56 * 56); // bias only, no bypass
    }

    #[test]
    fn neighbor_reads_only_at_tile_borders() {
        // 1×1 conv never crosses tiles; 3×3 does at internal boundaries.
        let mut rng = SplitMix64::new(5);
        let l1 = ConvLayer::new("c1", 4, 16, 14, 14, 1, 1);
        let (w, g, b) = make_params(&l1, &mut rng);
        let input =
            FeatureMap::from_vec(4, 14, 14, (0..4 * 196).map(|_| rng.next_sym()).collect());
        let s = pack_weights(&l1, &w, 16);
        let p = LayerParams {
            layer: &l1,
            stream: &s,
            gamma: &g,
            beta: &b,
        };
        let (_, acc) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        assert_eq!(acc.neighbor_reads, 0);

        let l3 = ConvLayer::new("c3", 4, 16, 14, 14, 3, 1);
        let (w, g, b) = make_params(&l3, &mut rng);
        let s = pack_weights(&l3, &w, 16);
        let p = LayerParams {
            layer: &l3,
            stream: &s,
            gamma: &g,
            beta: &b,
        };
        let (_, acc3) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        // 7×7 tile grid on 14×14: each tile is 2×2; borders everywhere.
        assert!(acc3.neighbor_reads > 0);
        assert!(acc3.neighbor_reads < acc3.fmm_reads);
    }

    #[test]
    fn threaded_layer_is_bit_identical_with_equal_counts() {
        // Thread counts that divide n_out, don't divide it, and exceed
        // it must all reproduce the single-thread bits and counters.
        let mut rng = SplitMix64::new(0x7ead);
        let l = ConvLayer::new("p", 8, 20, 10, 10, 3, 1).with_bypass(true);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input =
            FeatureMap::from_vec(8, 10, 10, (0..800).map(|_| rng.next_sym()).collect());
        let byp =
            FeatureMap::from_vec(20, 10, 10, (0..2000).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        for prec in [Precision::F16, Precision::F32] {
            let (want, want_acc) =
                run_layer_threads(&p, &input, Some(&byp), prec, (7, 7), 1);
            for threads in [2usize, 3, 4, 7, 64] {
                let (got, acc) =
                    run_layer_threads(&p, &input, Some(&byp), prec, (7, 7), threads);
                assert_eq!(got.data, want.data, "threads={threads} {prec:?}");
                assert_eq!(acc, want_acc, "threads={threads} {prec:?}");
            }
        }
    }

    #[test]
    fn awkward_worker_counts_stay_balanced_and_bit_identical() {
        // 10 output channels over 8 workers is the case the old
        // `div_ceil` chunking mishandled (3 idle workers); together
        // with other non-dividing counts, the balanced split must keep
        // bits and counters identical to the single-thread run.
        let mut rng = SplitMix64::new(0xba1a);
        let l = ConvLayer::new("awk", 6, 10, 9, 9, 3, 1);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input =
            FeatureMap::from_vec(6, 9, 9, (0..6 * 81).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (want, want_acc) = run_layer_threads(&p, &input, None, Precision::F16, (7, 7), 1);
        for threads in [3usize, 4, 6, 8, 9, 10] {
            let (got, acc) =
                run_layer_threads(&p, &input, None, Precision::F16, (7, 7), threads);
            assert_eq!(got.data, want.data, "threads={threads}");
            assert_eq!(acc, want_acc, "threads={threads}");
        }
    }

    #[test]
    fn batched_layer_is_bit_identical_with_amortized_stream() {
        // A B-image batch must reproduce B sequential runs bit-for-bit
        // while fetching each stream word once (not B times), at every
        // thread count.
        let mut rng = SplitMix64::new(0xbb01);
        let l = ConvLayer::new("mb", 8, 20, 10, 10, 3, 1).with_bypass(true);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        const B: usize = 3;
        let inputs: Vec<FeatureMap> = (0..B)
            .map(|_| FeatureMap::from_vec(8, 10, 10, (0..800).map(|_| rng.next_sym()).collect()))
            .collect();
        let byps: Vec<FeatureMap> = (0..B)
            .map(|_| FeatureMap::from_vec(20, 10, 10, (0..2000).map(|_| rng.next_sym()).collect()))
            .collect();
        for prec in [Precision::F16, Precision::F32] {
            let mut seq = Vec::with_capacity(B);
            let mut seq_acc = AccessCounts::default();
            for bi in 0..B {
                let (out, acc) =
                    run_layer_threads(&p, &inputs[bi], Some(&byps[bi]), prec, (7, 7), 1);
                seq.push(out);
                seq_acc.add(&acc);
            }
            let in_refs: Vec<&FeatureMap> = inputs.iter().collect();
            let byp_refs: Vec<&FeatureMap> = byps.iter().collect();
            for threads in [1usize, 3, 7] {
                let (outs, acc) = run_layer_batch_threads(
                    &p,
                    &in_refs,
                    Some(&byp_refs),
                    prec,
                    (7, 7),
                    threads,
                );
                assert_eq!(outs.len(), B);
                for bi in 0..B {
                    assert_eq!(
                        outs[bi].data, seq[bi].data,
                        "image {bi} diverged ({prec:?}, threads={threads})"
                    );
                }
                // Stream words: once per batch = 1/B of sequential.
                assert_eq!(acc.stream_words * B as u64, seq_acc.stream_words);
                // Compute counters still scale with B.
                assert_eq!(acc.accumulates, seq_acc.accumulates);
                assert_eq!(acc.fmm_reads, seq_acc.fmm_reads);
                assert_eq!(acc.fmm_writes, seq_acc.fmm_writes);
                // Each word serves B·tile_pixels pixels, one off-stream.
                let tile_pixels =
                    (l.h_out().div_ceil(7).max(1) * l.w_out().div_ceil(7).max(1)) as u64;
                assert_eq!(
                    acc.wbuf_reads,
                    acc.stream_words * (B as u64 * tile_pixels - 1),
                    "{prec:?} threads={threads}"
                );
            }
        }
        // Empty batches are a no-op, not a panic.
        let (outs, acc) = run_layer_batch_threads(&p, &[], Some(&[]), Precision::F32, (7, 7), 2);
        assert!(outs.is_empty());
        assert_eq!(acc, AccessCounts::default());
    }

    #[test]
    fn rect_recompute_splices_bit_exact_with_savings() {
        // Perturb a small input region, recompute only the dilated
        // output rectangle on top of the cached old output: bits must
        // match a full recompute of the new input, and the skipped MACs
        // must show up as saved_macs.
        let mut rng = SplitMix64::new(0x51d3);
        let l = ConvLayer::new("v", 4, 8, 10, 10, 3, 1);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let a = FeatureMap::from_vec(4, 10, 10, (0..400).map(|_| rng.next_sym()).collect());
        let mut b = a.clone();
        for c in 0..4 {
            for y in 4..6 {
                for x in 4..6 {
                    b.set(c, y, x, rng.next_sym());
                }
            }
        }
        for prec in [Precision::F16, Precision::F32] {
            let (out_a, full_acc) = run_layer(&p, &a, None, prec, (7, 7));
            let (out_b, _) = run_layer(&p, &b, None, prec, (7, 7));
            let mut spliced = out_a.clone();
            // 3×3/stride-1 receptive dilation of input rows/cols 4..6.
            let acc = run_layer_rects(&p, &b, None, prec, (7, 7), &mut spliced, &[(3, 7, 3, 7)]);
            assert_eq!(spliced.data, out_b.data, "{prec:?} splice diverged");
            assert_eq!(acc.accumulates + acc.saved_macs, full_acc.accumulates);
            assert!(acc.saved_macs > 0, "partial pass must save MACs");
            // The stream still passes once; nothing was saved there.
            assert_eq!(acc.stream_words, full_acc.stream_words);
            assert_eq!(acc.saved_stream_words, 0);
        }
        // A fully-clean layer computes nothing and saves the stream too.
        let (out_a, full_acc) = run_layer(&p, &a, None, Precision::F16, (7, 7));
        let mut untouched = out_a.clone();
        let acc = run_layer_rects(&p, &a, None, Precision::F16, (7, 7), &mut untouched, &[]);
        assert_eq!(untouched.data, out_a.data);
        assert_eq!(acc.accumulates, 0);
        assert_eq!(acc.saved_macs, full_acc.accumulates);
        assert_eq!(acc.saved_stream_words, full_acc.stream_words);
    }

    #[test]
    fn relu_clamps_negative_outputs() {
        let l = ConvLayer::new("r", 1, 16, 2, 2, 1, 1);
        let w = vec![-1.0f32; 16];
        let gamma = vec![1.0f32; 16];
        let beta = vec![0.0f32; 16];
        let input = FeatureMap::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (out, _) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        assert!(out.data.iter().all(|&v| v == 0.0));
    }
}

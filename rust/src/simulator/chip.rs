//! Single-chip functional simulator: Algorithm 1, bit-faithful.
//!
//! Executes one layer exactly in the chip's order — filter-tap outer,
//! input-channel inner, the binary weight applied as the sign input of
//! the accumulator (line 17), then the stall-free scale → bypass → bias →
//! ReLU post sequence — optionally rounding every intermediate to FP16
//! like the silicon datapath. Counts all memory traffic for the energy
//! breakdown (Fig 10).

use crate::bwn::WeightStream;
use crate::network::ConvLayer;
use crate::util::f16::round_f16;

use super::fm::FeatureMap;

/// Datapath precision of the simulated Tile-PUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Bit-exact FP16 (round every accumulate) — the taped-out chip.
    #[default]
    F16,
    /// f32 (matches the PJRT CPU artifacts; used for cross-validation).
    F32,
}

#[inline]
fn rnd(p: Precision, x: f32) -> f32 {
    match p {
        Precision::F16 => round_f16(x),
        Precision::F32 => x,
    }
}

/// Memory/IO traffic of one simulated layer (word granularity).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AccessCounts {
    /// FMM word reads (input FM fetches incl. neighbour-bank reads).
    pub fmm_reads: u64,
    /// FMM word writes (output pixels; bypass read-modify adds a read).
    pub fmm_writes: u64,
    /// Weight words fetched from the off-chip stream.
    pub stream_words: u64,
    /// Weight words re-read from the weight buffer.
    pub wbuf_reads: u64,
    /// Reads that crossed a Tile-PU boundary (neighbour bank access).
    pub neighbor_reads: u64,
    /// Post-phase multiplies (bnorm) on the shared per-tile multiplier.
    pub post_mults: u64,
    /// Post-phase adds (bias + bypass).
    pub post_adds: u64,
    /// FP16 accumulates in the Tile-PU adders.
    pub accumulates: u64,
}

impl AccessCounts {
    pub fn add(&mut self, o: &AccessCounts) {
        self.fmm_reads += o.fmm_reads;
        self.fmm_writes += o.fmm_writes;
        self.stream_words += o.stream_words;
        self.wbuf_reads += o.wbuf_reads;
        self.neighbor_reads += o.neighbor_reads;
        self.post_mults += o.post_mults;
        self.post_adds += o.post_adds;
        self.accumulates += o.accumulates;
    }
}

/// Parameters of one layer execution.
pub struct LayerParams<'a> {
    pub layer: &'a ConvLayer,
    /// Packed binary weights in stream order.
    pub stream: &'a WeightStream,
    /// Per-output-channel scale (folded batch-norm α; 1.0 if none).
    pub gamma: &'a [f32],
    /// Per-output-channel bias (β).
    pub beta: &'a [f32],
}

/// Execute one layer on a full (single-chip) input FM.
///
/// `bypass` must be `Some` iff `layer.has_bypass`. Returns the output FM
/// and the access counts. Spatial tile bookkeeping (for neighbour-read
/// counting) uses `tile_h × tile_w` Tile-PU patches of `m×n` tiles.
pub fn run_layer(
    p: &LayerParams,
    input: &FeatureMap,
    bypass: Option<&FeatureMap>,
    prec: Precision,
    tiles_mn: (usize, usize),
) -> (FeatureMap, AccessCounts) {
    let l = p.layer;
    assert_eq!((input.c, input.h, input.w), (l.n_in, l.h, l.w));
    assert_eq!(l.has_bypass, bypass.is_some());
    assert_eq!(p.gamma.len(), l.n_out);
    assert_eq!(p.beta.len(), l.n_out);

    let (ho, wo) = (l.h_out(), l.w_out());
    let mut out = FeatureMap::zeros(l.n_out, ho, wo);
    let mut acc = AccessCounts::default();

    let (m, n) = tiles_mn;
    let tile_h = ho.div_ceil(m).max(1);
    let tile_w = wo.div_ceil(n).max(1);
    let in_tile_h = l.h.div_ceil(m).max(1);
    let in_tile_w = l.w.div_ceil(n).max(1);

    let half = (l.k / 2) as isize;
    let group_size_out = l.n_out / l.groups;
    let n_in_eff = l.n_in / l.groups;
    let taps = l.k * l.k;
    let c_par = p.stream.c;

    // Perf (§Perf log): the naive loop paid a div/mod-heavy
    // `stream.weight()` call plus four divisions of tile bookkeeping per
    // MAC. Weights are hoisted per output channel into a table of f32
    // *sign masks* (a −1 weight is an XOR of the sign bit — the literal
    // hardware meaning of "the binary weight is applied as the sign
    // input of the FP16 adder"), counters are bumped per tap instead of
    // per MAC, and fully-padded taps (DDU zeros) skip the accumulation
    // entirely (v ± 0 is exact in FP16 and f32).
    let mut wmask = vec![0u32; taps * n_in_eff];
    let mut local = AccessCounts::default();
    for co in 0..l.n_out {
        let g = co / group_size_out;
        let cin_base = g * n_in_eff;
        for tap in 0..taps {
            for ci in 0..n_in_eff {
                wmask[tap * n_in_eff + ci] = if p.stream.weight(co, ci, tap) > 0.0 {
                    0
                } else {
                    0x8000_0000
                };
            }
        }
        for oy in 0..ho {
            let ty = oy / tile_h;
            for ox in 0..wo {
                let tx = ox / tile_w;
                let mut v = 0.0f32;
                // Algorithm 1 lines 7–19: tap outer, input channel inner.
                for tap in 0..taps {
                    let dy = (tap / l.k) as isize - half;
                    let dx = (tap % l.k) as isize - half;
                    let iy = (oy * l.stride) as isize + dy;
                    let ix = (ox * l.stride) as isize + dx;
                    local.accumulates += n_in_eff as u64;
                    local.fmm_reads += n_in_eff as u64;
                    if iy < 0 || ix < 0 || iy >= l.h as isize || ix >= l.w as isize {
                        // Zero padding: the DDU injects zeros; v is
                        // unchanged (v ± 0 == v bit-exactly).
                        continue;
                    }
                    let (iy, ix) = (iy as usize, ix as usize);
                    if (iy / in_tile_h, ix / in_tile_w) != (ty, tx) {
                        local.neighbor_reads += n_in_eff as u64;
                    }
                    let row = &wmask[tap * n_in_eff..tap * n_in_eff + n_in_eff];
                    let base = ((cin_base) * l.h + iy) * l.w + ix;
                    let stride_c = l.h * l.w;
                    // Line 17: sign-select accumulate (sign-bit XOR).
                    match prec {
                        Precision::F32 => {
                            for (ci, &mask) in row.iter().enumerate() {
                                let x = input.data[base + ci * stride_c];
                                v += f32::from_bits(x.to_bits() ^ mask);
                            }
                        }
                        Precision::F16 => {
                            for (ci, &mask) in row.iter().enumerate() {
                                let x = input.data[base + ci * stride_c];
                                v = round_f16(v + f32::from_bits(x.to_bits() ^ mask));
                            }
                        }
                    }
                }
                // §IV-B order: scale → bypass → bias → ReLU.
                if l.bnorm {
                    v = rnd(prec, v * p.gamma[co]);
                    acc.post_mults += 1;
                }
                if let Some(bp) = bypass {
                    v = rnd(prec, v + bp.get(co, oy, ox));
                    acc.fmm_reads += 1;
                    acc.post_adds += 1;
                }
                v = rnd(prec, v + p.beta[co]);
                acc.post_adds += 1;
                if l.relu && v < 0.0 {
                    v = 0.0;
                }
                out.set(co, oy, ox, v);
                acc.fmm_writes += 1;
            }
        }
    }

    acc.add(&local);
    // Weight traffic: every stream word enters once, then is re-read per
    // remaining pixel of the Tile-PU tile (Tbl I schedule).
    let tile_pixels = (tile_h * tile_w) as u64;
    let cout_tiles = l.n_out.div_ceil(c_par) as u64;
    acc.stream_words = cout_tiles * taps as u64 * n_in_eff as u64;
    acc.wbuf_reads = acc.stream_words * (tile_pixels.max(1) - 1);
    (out, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bwn::pack_weights;
    use crate::network::ConvLayer;
    use crate::testkit;
    use crate::util::SplitMix64;

    fn make_params(l: &ConvLayer, rng: &mut SplitMix64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let n_in_eff = l.n_in / l.groups;
        let w: Vec<f32> = (0..l.n_out * n_in_eff * l.k * l.k)
            .map(|_| rng.next_sym())
            .collect();
        let gamma: Vec<f32> = (0..l.n_out).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..l.n_out).map(|_| rng.next_sym()).collect();
        (w, gamma, beta)
    }

    /// Plain reference convolution (independent loop order, f32).
    fn ref_conv(
        l: &ConvLayer,
        w: &[f32],
        gamma: &[f32],
        beta: &[f32],
        input: &FeatureMap,
        bypass: Option<&FeatureMap>,
    ) -> FeatureMap {
        let (ho, wo) = (l.h_out(), l.w_out());
        let mut out = FeatureMap::zeros(l.n_out, ho, wo);
        let half = (l.k / 2) as isize;
        let gso = l.n_out / l.groups;
        let nie = l.n_in / l.groups;
        for co in 0..l.n_out {
            let cb = (co / gso) * nie;
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut v = 0.0f64;
                    for ci in 0..nie {
                        for ky in 0..l.k {
                            for kx in 0..l.k {
                                let iy = (oy * l.stride) as isize + ky as isize - half;
                                let ix = (ox * l.stride) as isize + kx as isize - half;
                                let x = input.get_padded(cb + ci, iy, ix) as f64;
                                let wv = w[(co * nie + ci) * l.k * l.k + ky * l.k + kx];
                                let s = if wv >= 0.0 { 1.0 } else { -1.0 };
                                v += s * x;
                            }
                        }
                    }
                    let mut v = v as f32;
                    if l.bnorm {
                        v *= gamma[co];
                    }
                    if let Some(bp) = bypass {
                        v += bp.get(co, oy, ox);
                    }
                    v += beta[co];
                    if l.relu && v < 0.0 {
                        v = 0.0;
                    }
                    out.set(co, oy, ox, v);
                }
            }
        }
        out
    }

    #[test]
    fn matches_reference_f32_property() {
        testkit::check_n("chip sim vs ref conv", 0xc41b, 60, |rng| {
            let k = if rng.next_u64() & 1 == 0 { 1 } else { 3 };
            let stride = if rng.next_u64() & 1 == 0 { 1 } else { 2 };
            let n_in = 1 + rng.next_below(8);
            let n_out = 1 + rng.next_below(20);
            let h = (stride * (1 + rng.next_below(6))).max(k);
            let l = ConvLayer::new("t", n_in, n_out, h, h, k, stride);
            let (w, gamma, beta) = make_params(&l, rng);
            let input = FeatureMap::from_vec(
                n_in,
                h,
                h,
                (0..n_in * h * h).map(|_| rng.next_sym()).collect(),
            );
            let stream = pack_weights(&l, &w, 16);
            let p = LayerParams {
                layer: &l,
                stream: &stream,
                gamma: &gamma,
                beta: &beta,
            };
            let (out, _) = run_layer(&p, &input, None, Precision::F32, (7, 7));
            let want = ref_conv(&l, &w, &gamma, &beta, &input, None);
            testkit::assert_allclose(&out.data, &want.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn bypass_accumulates_before_bias() {
        let mut rng = SplitMix64::new(3);
        let l = ConvLayer::new("b", 4, 4, 6, 6, 3, 1).with_bypass(true);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input = FeatureMap::from_vec(4, 6, 6, (0..4 * 36).map(|_| rng.next_sym()).collect());
        let byp = FeatureMap::from_vec(4, 6, 6, (0..4 * 36).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (out, _) = run_layer(&p, &input, Some(&byp), Precision::F32, (7, 7));
        let want = ref_conv(&l, &w, &gamma, &beta, &input, Some(&byp));
        testkit::assert_allclose(&out.data, &want.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn f16_rounding_bounds_error_vs_f32() {
        let mut rng = SplitMix64::new(9);
        let l = ConvLayer::new("f", 16, 16, 8, 8, 3, 1);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input =
            FeatureMap::from_vec(16, 8, 8, (0..16 * 64).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (o16, _) = run_layer(&p, &input, None, Precision::F16, (7, 7));
        let (o32, _) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        let d = o16.max_abs_diff(&o32);
        assert!(d > 0.0, "FP16 must actually round");
        // 144-term accumulation of O(1) values: error stays ~ulp·√n.
        assert!(d < 0.5, "f16 error too large: {d}");
    }

    #[test]
    fn access_counts_match_formulas() {
        let l = ConvLayer::new("a", 16, 64, 56, 56, 3, 1);
        let mut rng = SplitMix64::new(1);
        let (w, gamma, beta) = make_params(&l, &mut rng);
        let input =
            FeatureMap::from_vec(16, 56, 56, (0..16 * 56 * 56).map(|_| rng.next_sym()).collect());
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (_, acc) = run_layer(&p, &input, None, Precision::F16, (7, 7));
        // Conv reads: n_out × h·w × 9 taps × 16 c_in.
        assert_eq!(acc.fmm_reads, 64 * 56 * 56 * 9 * 16);
        assert_eq!(acc.fmm_writes, 64 * 56 * 56);
        assert_eq!(acc.accumulates, acc.fmm_reads);
        // Stream: 4 c_out tiles × 9 × 16 words; re-read per pixel (8×8−1).
        assert_eq!(acc.stream_words, 4 * 9 * 16);
        assert_eq!(acc.wbuf_reads, 4 * 9 * 16 * 63);
        assert_eq!(acc.post_mults, 64 * 56 * 56);
        assert_eq!(acc.post_adds, 64 * 56 * 56); // bias only, no bypass
    }

    #[test]
    fn neighbor_reads_only_at_tile_borders() {
        // 1×1 conv never crosses tiles; 3×3 does at internal boundaries.
        let mut rng = SplitMix64::new(5);
        let l1 = ConvLayer::new("c1", 4, 16, 14, 14, 1, 1);
        let (w, g, b) = make_params(&l1, &mut rng);
        let input =
            FeatureMap::from_vec(4, 14, 14, (0..4 * 196).map(|_| rng.next_sym()).collect());
        let s = pack_weights(&l1, &w, 16);
        let p = LayerParams {
            layer: &l1,
            stream: &s,
            gamma: &g,
            beta: &b,
        };
        let (_, acc) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        assert_eq!(acc.neighbor_reads, 0);

        let l3 = ConvLayer::new("c3", 4, 16, 14, 14, 3, 1);
        let (w, g, b) = make_params(&l3, &mut rng);
        let s = pack_weights(&l3, &w, 16);
        let p = LayerParams {
            layer: &l3,
            stream: &s,
            gamma: &g,
            beta: &b,
        };
        let (_, acc3) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        // 7×7 tile grid on 14×14: each tile is 2×2; borders everywhere.
        assert!(acc3.neighbor_reads > 0);
        assert!(acc3.neighbor_reads < acc3.fmm_reads);
    }

    #[test]
    fn relu_clamps_negative_outputs() {
        let l = ConvLayer::new("r", 1, 16, 2, 2, 1, 1);
        let w = vec![-1.0f32; 16];
        let gamma = vec![1.0f32; 16];
        let beta = vec![0.0f32; 16];
        let input = FeatureMap::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let stream = pack_weights(&l, &w, 16);
        let p = LayerParams {
            layer: &l,
            stream: &stream,
            gamma: &gamma,
            beta: &beta,
        };
        let (out, _) = run_layer(&p, &input, None, Precision::F32, (7, 7));
        assert!(out.data.iter().all(|&v| v == 0.0));
    }
}

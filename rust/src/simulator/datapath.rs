//! The one Tile-PU datapath kernel (Algorithm 1).
//!
//! Hyperdrive's central claim is that the *same* Tile-PU datapath scales
//! from a single chip to an m×n systolic mesh (§V). This module is that
//! datapath in software: [`run_tile`] executes the sign-mask accumulate
//! (the binary weight applied as the sign input of the FP16 adder,
//! Algorithm 1 line 17) followed by the stall-free scale → bypass →
//! bias → ReLU post sequence for a rectangle of output pixels, reading
//! its input through the [`InputSurface`] abstraction — a flat
//! [`FeatureMap`](super::fm::FeatureMap) on the single-chip simulator, a
//! halo-ringed `ExtTile` on the mesh. Both simulators call this one
//! kernel, so the Fig-10/Tbl-II traffic counters ([`AccessCounts`]) come
//! from a single source of truth and the functional-vs-mesh bit-exactness
//! checks compare two memory systems, not two arithmetic
//! implementations.
//!
//! **Hot-path structure** (see DESIGN.md §Perf log). The kernel exploits
//! the same locality the silicon does — image window stationary, weights
//! streaming past it:
//!
//! * the binary weights arrive pre-expanded: callers build one
//!   [`PackedLayerWeights`] per layer execution (decoded straight from
//!   the stream's `u64` bitplanes) and every tile, chip, mesh step and
//!   batch slot borrows its per-channel `u32` sign-mask planes — no
//!   per-tile/per-channel `weight() > 0` decode loop in the hot path;
//! * the input rectangle is staged *once per output-channel block* into a
//!   channel-interleaved scratch buffer ([`InputSurface::gather`]), so the
//!   cache-hostile CHW channel stride is paid once, not `co1−co0` times;
//! * each output row is split into **interior** pixels (every filter tap
//!   in-bounds → a branch-free tap-outer/channel-inner loop over
//!   contiguous staged slices, `PIXEL_BLOCK` = 8 adjacent pixels'
//!   independent accumulator chains interleaved in a mask-XOR-then-sum
//!   shape the compiler can lift to SIMD) and **border** pixels (the
//!   checked zero-padding path — a thin perimeter);
//! * every [`AccessCounts`] field is computed in closed form by
//!   [`analytic_counts`] instead of per-element increments. The original
//!   per-element counting kernel is preserved verbatim as
//!   [`crate::testkit::reference_run_tile`], the oracle the equivalence
//!   property tests compare against.
//!
//! None of this changes a single rounding step: each output pixel's FP16
//! sequence is still tap-outer, channel-inner, inside one invocation in
//! a fixed order, so results are bit-identical at any thread count and
//! identical to the reference kernel at both precisions.
//!
//! The kernel is also the unit of parallelism: callers fan
//! [`run_tile`] invocations out over scoped threads (output-channel
//! ranges on a single chip, whole chips on the mesh — data-independent
//! between exchange phases, exactly the paper's execution model) using
//! the balanced [`partition_ranges`] split.

use crate::bwn::PackedLayerWeights;
use crate::network::ConvLayer;
use crate::util::f16::round_f16;

use super::fm::FeatureMap;

/// Datapath precision of the simulated Tile-PUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Bit-exact FP16 (round every accumulate) — the taped-out chip.
    #[default]
    F16,
    /// f32 (matches the PJRT CPU artifacts; used for cross-validation).
    F32,
}

#[inline]
pub(crate) fn rnd(p: Precision, x: f32) -> f32 {
    match p {
        Precision::F16 => round_f16(x),
        Precision::F32 => x,
    }
}

/// Memory/IO traffic of one simulated layer (word granularity).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AccessCounts {
    /// FMM word reads (input FM fetches incl. neighbour-bank reads).
    pub fmm_reads: u64,
    /// FMM word writes (output pixels; bypass read-modify adds a read).
    pub fmm_writes: u64,
    /// Weight words fetched from the off-chip stream.
    pub stream_words: u64,
    /// Weight words re-read from the weight buffer.
    pub wbuf_reads: u64,
    /// Reads that crossed a Tile-PU boundary (neighbour bank access).
    pub neighbor_reads: u64,
    /// Post-phase multiplies (bnorm) on the shared per-tile multiplier.
    pub post_mults: u64,
    /// Post-phase adds (bias + bypass).
    pub post_adds: u64,
    /// FP16 accumulates in the Tile-PU adders.
    pub accumulates: u64,
    /// MACs (accumulates) a full recompute would have issued but the
    /// streaming-video dirty-tile path skipped by splicing cached clean
    /// tiles. Zero everywhere outside video mode.
    pub saved_macs: u64,
    /// Off-chip weight-stream words skipped because every tile of a
    /// layer was clean (the stream for that layer never starts).
    pub saved_stream_words: u64,
    /// FMM word accesses (reads + writes) skipped by clean-tile splicing.
    pub saved_fm_words: u64,
}

impl AccessCounts {
    pub fn add(&mut self, o: &AccessCounts) {
        self.fmm_reads += o.fmm_reads;
        self.fmm_writes += o.fmm_writes;
        self.stream_words += o.stream_words;
        self.wbuf_reads += o.wbuf_reads;
        self.neighbor_reads += o.neighbor_reads;
        self.post_mults += o.post_mults;
        self.post_adds += o.post_adds;
        self.accumulates += o.accumulates;
        self.saved_macs += o.saved_macs;
        self.saved_stream_words += o.saved_stream_words;
        self.saved_fm_words += o.saved_fm_words;
    }

    /// Fold the savings of one partially-recomputed video layer into
    /// its actual counters: `self` holds what the dirty-tile pass
    /// really counted for the layer (saved fields still zero), `full`
    /// is what a full-frame recompute of the same layer counts.
    pub fn with_saved_vs(mut self, full: &AccessCounts) -> AccessCounts {
        self.saved_macs += full.accumulates.saturating_sub(self.accumulates);
        self.saved_stream_words += full.stream_words.saturating_sub(self.stream_words);
        self.saved_fm_words += (full.fmm_reads + full.fmm_writes)
            .saturating_sub(self.fmm_reads + self.fmm_writes);
        self
    }
}

/// A conv-input view addressed in *global* FM coordinates.
///
/// The kernel performs the DDU's zero-padding itself (a padded tap skips
/// the accumulation — `v ± 0` is exact in FP16 and f32), so `read` is
/// only ever called with coordinates inside the global FM bounds;
/// implementations may assert on anything else (the mesh's `ExtTile`
/// does, which is what catches never-exchanged halo pixels).
pub trait InputSurface {
    /// Value of channel `ch` at global `(gy, gx)`; both in-FM.
    fn read(&self, ch: usize, gy: isize, gx: isize) -> f32;

    /// Bulk read of channels `[ch0, ch1)` at global `(gy, gx)` into
    /// `out` (`out.len() == ch1 − ch0`) — the staging primitive of the
    /// hot path. Semantically identical to calling [`Self::read`] per
    /// channel (the default does exactly that); implementations
    /// override it to hoist the coordinate translation and bounds
    /// checks out of the channel loop.
    fn gather(&self, ch0: usize, ch1: usize, gy: isize, gx: isize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), ch1 - ch0);
        for (slot, ch) in out.iter_mut().zip(ch0..ch1) {
            *slot = self.read(ch, gy, gx);
        }
    }
}

impl InputSurface for FeatureMap {
    #[inline]
    fn read(&self, ch: usize, gy: isize, gx: isize) -> f32 {
        self.get(ch, gy as usize, gx as usize)
    }

    #[inline]
    fn gather(&self, ch0: usize, ch1: usize, gy: isize, gx: isize, out: &mut [f32]) {
        let plane = self.h * self.w;
        let base = gy as usize * self.w + gx as usize;
        for (slot, ch) in out.iter_mut().zip(ch0..ch1) {
            *slot = self.data[ch * plane + base];
        }
    }
}

/// Geometry of one [`run_tile`] invocation: which output rectangle to
/// compute and where the local Tile-PU patch grid sits, for
/// neighbour-read accounting.
#[derive(Debug, Clone, Copy)]
pub struct TileGeom {
    /// Output region `[oy0, oy1) × [ox0, ox1)` in global coordinates.
    pub oy0: usize,
    pub oy1: usize,
    pub ox0: usize,
    pub ox1: usize,
    /// Input-space origin of the local Tile-PU grid (the chip's owned
    /// input region starts here; 0 on a single chip). Reads at negative
    /// local coordinates are halo reads from a neighbouring chip and
    /// count as neighbour-bank traffic.
    pub iy0: isize,
    pub ix0: isize,
    /// Tile-PU patch size in output space (≥ 1).
    pub tile_h: usize,
    pub tile_w: usize,
    /// Tile-PU patch size in input space (≥ 1).
    pub in_tile_h: usize,
    pub in_tile_w: usize,
}

/// Every [`AccessCounts`] field of one [`run_tile`] invocation in closed
/// form per `(layer, co-range, geom)` rectangle — no per-element
/// increments on the compute path.
///
/// The only non-trivial field is `neighbor_reads`: a read is a
/// neighbour-bank access iff its input-space Tile-PU patch differs from
/// the output pixel's patch on *either* axis. Both the in-bounds
/// predicate and the patch-match predicate factor over the two axes, so
/// with `total_y/x` the per-axis count of in-bounds `(pixel, tap)`
/// pairs and `match_y/x` the in-bounds *and* patch-matching count, the
/// crossing pairs are `total_y·total_x − match_y·match_x` — an
/// `O((rows + cols)·k)` computation instead of `O(rows·cols·k²·c_in)`
/// increments. Equality with the per-element counting oracle
/// ([`crate::testkit::reference_run_tile`]) is property-tested in
/// `tests/datapath_equivalence.rs`.
pub fn analytic_counts(
    layer: &ConvLayer,
    (co0, co1): (usize, usize),
    has_bypass: bool,
    geom: &TileGeom,
) -> AccessCounts {
    let l = layer;
    let nco = co1.saturating_sub(co0) as u64;
    let rows = geom.oy1.saturating_sub(geom.oy0) as u64;
    let cols = geom.ox1.saturating_sub(geom.ox0) as u64;
    let pix = rows * cols;
    let nie = (l.n_in / l.groups) as u64;
    let taps = (l.k * l.k) as u64;
    let dlo = -((l.k / 2) as isize);
    let dhi = (l.k - 1) as isize + dlo;

    let axis = |o0: usize, o1: usize, dim: usize, origin: isize, out_tile: usize, in_tile: usize| {
        let mut total = 0u64;
        let mut matching = 0u64;
        for o in o0..o1 {
            let t_out = ((o - o0) / out_tile) as isize;
            for d in dlo..=dhi {
                let i = (o * l.stride) as isize + d;
                if i < 0 || i >= dim as isize {
                    continue;
                }
                total += 1;
                if (i - origin).div_euclid(in_tile as isize) == t_out {
                    matching += 1;
                }
            }
        }
        (total, matching)
    };
    let (ty, my) = axis(geom.oy0, geom.oy1, l.h, geom.iy0, geom.tile_h, geom.in_tile_h);
    let (tx, mx) = axis(geom.ox0, geom.ox1, l.w, geom.ix0, geom.tile_w, geom.in_tile_w);

    let conv = nco * pix * taps * nie;
    let per_pixel = nco * pix;
    let bypassed = if has_bypass { per_pixel } else { 0 };
    AccessCounts {
        fmm_reads: conv + bypassed,
        fmm_writes: per_pixel,
        stream_words: 0,
        wbuf_reads: 0,
        neighbor_reads: nco * nie * (ty * tx - my * mx),
        post_mults: if l.bnorm { per_pixel } else { 0 },
        post_adds: per_pixel + bypassed,
        accumulates: conv,
        ..AccessCounts::default()
    }
}

/// Split `0..n` into `min(parts, n)` contiguous non-empty ranges whose
/// lengths differ by at most one (`⌊n/p⌋` or `⌈n/p⌉`) — the fan-out
/// split used by `chip::run_layer_threads` (output-channel ranges) and
/// the mesh's per-step chip chunks. A plain `div_ceil` chunking can
/// idle trailing workers entirely (10 channels over 8 workers → chunks
/// of 2 → 5 busy, 3 idle); the balanced split keeps every worker busy.
pub fn partition_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let (base, rem) = (n / parts, n % parts);
    let mut ranges = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let end = start + base + usize::from(i < rem);
        ranges.push((start, end));
        start = end;
    }
    ranges
}

/// Number of adjacent interior pixels accumulated in lockstep. Their
/// per-pixel chains are independent, so the CPU overlaps the FP (and
/// FP16-rounding) latencies of `PIXEL_BLOCK` chains — a full 256-bit
/// SIMD lane's worth of f32 — while each pixel still sees its exact
/// serial accumulation order.
pub(crate) const PIXEL_BLOCK: usize = 8;

#[inline]
fn sign_apply(x: f32, mask: u32) -> f32 {
    f32::from_bits(x.to_bits() ^ mask)
}

/// Output-coordinate range `[lo, hi)` whose every tap displacement in
/// `dlo..=dhi` stays inside `[0, dim)` at the given stride (`hi < lo`
/// means no interior pixel exists; callers clamp).
fn interior_range(dim: usize, stride: usize, dlo: isize, dhi: isize) -> (usize, usize) {
    let lo = if dlo < 0 {
        ((-dlo) as usize).div_ceil(stride)
    } else {
        0
    };
    let hi = if dim > dhi as usize {
        (dim - 1 - dhi as usize) / stride + 1
    } else {
        0
    };
    (lo, hi)
}

/// Stage the `[sy0, sy1) × [sx0, sx1)` input rectangle of channels
/// `[ch0, ch0 + nie)` into the channel-interleaved scratch layout
/// `stage[(y·sw + x)·nie + ci]`.
fn stage_input<S: InputSurface + ?Sized>(
    input: &S,
    ch0: usize,
    nie: usize,
    (sy0, sy1, sx0, sx1): (usize, usize, usize, usize),
    stage: &mut [f32],
) {
    let sw = sx1 - sx0;
    for sy in 0..sy1 - sy0 {
        for sx in 0..sw {
            let o = (sy * sw + sx) * nie;
            input.gather(
                ch0,
                ch0 + nie,
                (sy0 + sy) as isize,
                (sx0 + sx) as isize,
                &mut stage[o..o + nie],
            );
        }
    }
}

/// One interior pixel: every tap in-bounds, so the accumulate is a
/// branch-free tap-outer/channel-inner pass over contiguous staged
/// slices (Algorithm 1 lines 7–19, exact order preserved).
#[inline]
fn accum_interior(
    stage: &[f32],
    wmask: &[u32],
    tap_off: &[isize],
    center: usize,
    nie: usize,
    prec: Precision,
) -> f32 {
    let mut v = 0.0f32;
    for (tap, &off) in tap_off.iter().enumerate() {
        let base = (center as isize + off) as usize;
        let xs = &stage[base..base + nie];
        let ms = &wmask[tap * nie..(tap + 1) * nie];
        match prec {
            Precision::F32 => {
                for (&x, &m) in xs.iter().zip(ms) {
                    v += sign_apply(x, m);
                }
            }
            Precision::F16 => {
                for (&x, &m) in xs.iter().zip(ms) {
                    v = round_f16(v + sign_apply(x, m));
                }
            }
        }
    }
    v
}

/// [`PIXEL_BLOCK`] adjacent interior pixels of one output row at once.
/// Each pixel's accumulator chain keeps its exact serial order (so the
/// result is bit-identical to the scalar path); interleaving the
/// independent chains is what hides the FP add / FP16-rounding latency.
///
/// The lanes are explicitly chunked as fixed-size `[f32; PIXEL_BLOCK]`
/// arrays over per-pixel staged subslices: the F32 path applies the
/// sign mask to all lanes (XOR), then adds all lanes — per input
/// channel, one XOR + one add per lane with no cross-lane dependency,
/// which the auto-vectorizer lifts to one SIMD XOR + one SIMD add. Each
/// lane's own chain still accumulates in the exact tap-outer /
/// channel-inner serial order, so widening the block can never change
/// a rounding step (cross-pixel chains were already independent).
#[inline]
#[allow(clippy::needless_range_loop)]
fn accum_block(
    stage: &[f32],
    wmask: &[u32],
    tap_off: &[isize],
    center: usize,
    step: usize,
    nie: usize,
    prec: Precision,
) -> [f32; PIXEL_BLOCK] {
    let mut v = [0.0f32; PIXEL_BLOCK];
    for (tap, &off) in tap_off.iter().enumerate() {
        let b0 = (center as isize + off) as usize;
        // One contiguous staged slice per pixel lane, length-checked
        // once per tap so the inner loops are bounds-check free.
        let s: [&[f32]; PIXEL_BLOCK] =
            std::array::from_fn(|p| &stage[b0 + p * step..b0 + p * step + nie]);
        let ms = &wmask[tap * nie..(tap + 1) * nie];
        match prec {
            Precision::F32 => {
                for i in 0..nie {
                    let m = ms[i];
                    // Mask-XOR every lane, then sum every lane.
                    let mut x = [0.0f32; PIXEL_BLOCK];
                    for p in 0..PIXEL_BLOCK {
                        x[p] = sign_apply(s[p][i], m);
                    }
                    for p in 0..PIXEL_BLOCK {
                        v[p] += x[p];
                    }
                }
            }
            Precision::F16 => {
                for i in 0..nie {
                    let m = ms[i];
                    for p in 0..PIXEL_BLOCK {
                        v[p] = round_f16(v[p] + sign_apply(s[p][i], m));
                    }
                }
            }
        }
    }
    v
}

/// One border pixel: per-tap bounds checks implement the DDU's zero
/// padding (a padded tap skips the accumulate — `v ± 0` is exact).
#[inline]
#[allow(clippy::too_many_arguments)]
fn accum_checked(
    stage: &[f32],
    wmask: &[u32],
    (k, dlo): (usize, isize),
    (h, w): (usize, usize),
    (sy0, sx0, sw): (usize, usize, usize),
    (iy, ix): (usize, usize),
    nie: usize,
    prec: Precision,
) -> f32 {
    let mut v = 0.0f32;
    for tap in 0..k * k {
        let ty = iy as isize + (tap / k) as isize + dlo;
        let tx = ix as isize + (tap % k) as isize + dlo;
        if ty < 0 || tx < 0 || ty >= h as isize || tx >= w as isize {
            continue;
        }
        let base = ((ty as usize - sy0) * sw + (tx as usize - sx0)) * nie;
        let xs = &stage[base..base + nie];
        let ms = &wmask[tap * nie..(tap + 1) * nie];
        match prec {
            Precision::F32 => {
                for (&x, &m) in xs.iter().zip(ms) {
                    v += sign_apply(x, m);
                }
            }
            Precision::F16 => {
                for (&x, &m) in xs.iter().zip(ms) {
                    v = round_f16(v + sign_apply(x, m));
                }
            }
        }
    }
    v
}

/// Execute Algorithm 1 for output channels `[co0, co1)` over the output
/// rectangle in `geom`, writing each finished pixel through `write(co,
/// gy, gx, v)` and returning the traffic counters of this invocation
/// (computed analytically — see [`analytic_counts`]).
///
/// Loop order is the chip's exactly: filter-tap outer, input-channel
/// inner (lines 7–19), the binary weight applied as a sign-bit XOR on
/// the FP32 representation (line 17) using the caller-supplied
/// [`PackedLayerWeights`] sign-mask planes — built **once per layer**
/// from the packed bitplanes and shared across every tile, chip and
/// thread of the pass — then the §IV-B scale → bypass → bias → ReLU
/// post sequence, optionally rounding every intermediate to FP16 like
/// the silicon. The input rectangle is staged once per output-channel
/// group into a channel-interleaved scratch buffer and re-read from
/// there for every channel of the block; interior pixels take a
/// branch-free blocked fast path, border pixels the checked padding
/// path (DESIGN.md §Perf log). Bit-identical to
/// [`crate::testkit::reference_run_tile`] at both precisions.
#[allow(clippy::too_many_arguments)]
pub fn run_tile<S, B, W>(
    layer: &ConvLayer,
    weights: &PackedLayerWeights,
    gamma: &[f32],
    beta: &[f32],
    (co0, co1): (usize, usize),
    input: &S,
    bypass: Option<&B>,
    prec: Precision,
    geom: &TileGeom,
    write: &mut W,
) -> AccessCounts
where
    S: InputSurface + ?Sized,
    B: InputSurface + ?Sized,
    W: FnMut(usize, usize, usize, f32),
{
    let l = layer;
    let acc = analytic_counts(l, (co0, co1), bypass.is_some(), geom);
    if co0 >= co1 || geom.oy0 >= geom.oy1 || geom.ox0 >= geom.ox1 {
        return acc;
    }
    let (k, stride) = (l.k, l.stride);
    let dlo = -((k / 2) as isize);
    let dhi = (k - 1) as isize + dlo;
    let group_size_out = l.n_out / l.groups;
    let nie = l.n_in / l.groups;
    let taps = k * k;

    // Staged rectangle: the in-bounds bounding box of every read the
    // output rectangle can issue.
    let sy0 = ((geom.oy0 * stride) as isize + dlo).clamp(0, l.h as isize) as usize;
    let sy1 = (((geom.oy1 - 1) * stride) as isize + dhi + 1).clamp(0, l.h as isize) as usize;
    let sx0 = ((geom.ox0 * stride) as isize + dlo).clamp(0, l.w as isize) as usize;
    let sx1 = (((geom.ox1 - 1) * stride) as isize + dhi + 1).clamp(0, l.w as isize) as usize;
    let (sh, sw) = (sy1 - sy0, sx1 - sx0);

    // Interior pixels: every tap lands inside the FM.
    let (yin_lo, yin_hi) = interior_range(l.h, stride, dlo, dhi);
    let (xin_lo, xin_hi) = interior_range(l.w, stride, dlo, dhi);
    let xi0 = xin_lo.clamp(geom.ox0, geom.ox1);
    let xi1 = xin_hi.clamp(xi0, geom.ox1);

    // Per-tap displacement inside the staged buffer, in f32 elements.
    let tap_off: Vec<isize> = (0..taps)
        .map(|t| {
            let dy = (t / k) as isize + dlo;
            let dx = (t % k) as isize + dlo;
            (dy * sw as isize + dx) * nie as isize
        })
        .collect();

    debug_assert_eq!(weights.n_out, l.n_out, "mask planes built for this layer");
    debug_assert_eq!(weights.channel(co0).len(), taps * nie);
    let mut stage = vec![0.0f32; sh * sw * nie];
    let mut staged_group = usize::MAX;

    // §IV-B order: scale → bypass → bias → ReLU.
    let mut emit = |co: usize, oy: usize, ox: usize, mut v: f32| {
        if l.bnorm {
            v = rnd(prec, v * gamma[co]);
        }
        if let Some(bp) = bypass {
            v = rnd(prec, v + bp.read(co, oy as isize, ox as isize));
        }
        v = rnd(prec, v + beta[co]);
        if l.relu && v < 0.0 {
            v = 0.0;
        }
        write(co, oy, ox, v);
    };

    for co in co0..co1 {
        let g = co / group_size_out;
        if g != staged_group {
            // Stage the group's input channels once; every output
            // channel of the block re-reads the interleaved buffer.
            stage_input(input, g * nie, nie, (sy0, sy1, sx0, sx1), &mut stage);
            staged_group = g;
        }
        // Line 17's binary weight as a sign-bit XOR mask: the plane was
        // expanded once per layer, shared by every tile of the pass.
        let wmask = weights.channel(co);
        for oy in geom.oy0..geom.oy1 {
            let iy = oy * stride;
            if oy < yin_lo || oy >= yin_hi {
                // Border row: every tap is bounds-checked.
                for ox in geom.ox0..geom.ox1 {
                    let v = accum_checked(
                        &stage,
                        wmask,
                        (k, dlo),
                        (l.h, l.w),
                        (sy0, sx0, sw),
                        (iy, ox * stride),
                        nie,
                        prec,
                    );
                    emit(co, oy, ox, v);
                }
                continue;
            }
            let row = (iy - sy0) * sw;
            for ox in geom.ox0..xi0 {
                let v = accum_checked(
                    &stage,
                    wmask,
                    (k, dlo),
                    (l.h, l.w),
                    (sy0, sx0, sw),
                    (iy, ox * stride),
                    nie,
                    prec,
                );
                emit(co, oy, ox, v);
            }
            let step = stride * nie;
            let mut ox = xi0;
            while ox + PIXEL_BLOCK <= xi1 {
                let center = (row + ox * stride - sx0) * nie;
                let vs = accum_block(&stage, wmask, &tap_off, center, step, nie, prec);
                for (p, &v) in vs.iter().enumerate() {
                    emit(co, oy, ox + p, v);
                }
                ox += PIXEL_BLOCK;
            }
            while ox < xi1 {
                let center = (row + ox * stride - sx0) * nie;
                let v = accum_interior(&stage, wmask, &tap_off, center, nie, prec);
                emit(co, oy, ox, v);
                ox += 1;
            }
            for ox in xi1..geom.ox1 {
                let v = accum_checked(
                    &stage,
                    wmask,
                    (k, dlo),
                    (l.h, l.w),
                    (sy0, sx0, sw),
                    (iy, ox * stride),
                    nie,
                    prec,
                );
                emit(co, oy, ox, v);
            }
        }
    }
    acc
}

/// Execute Algorithm 1 for a **micro-batch** of `B` resident images:
/// the same output rectangle and channel range as [`run_tile`], but
/// each output channel's sign-mask plane (borrowed from the shared
/// per-layer [`PackedLayerWeights`]) is fetched **once** and applied
/// to every image before the stream moves on — the batching schedule of
/// the paper's serving story (weights stream past `B` stationary
/// feature maps, so the off-chip weight fetch is paid once per block,
/// not once per image).
///
/// Per-image arithmetic is untouched: image `i`'s accumulator chains
/// run in exactly the order [`run_tile`] would give them (tap-outer,
/// channel-inner, same interior/border split), images are never mixed
/// into one chain, so each image's output is bit-identical to a
/// sequential single-image pass at both precisions — the
/// `tests/batch_equivalence.rs` invariant.
///
/// Pixels are written through `write(img, co, gy, gx, v)`. The returned
/// counters are the per-image [`analytic_counts`] summed over the batch
/// (compute scales with `B`); `stream_words`/`wbuf_reads` stay zero
/// here — the layer-level callers add [`weight_traffic`] **once per
/// batch**, which is where the B× amortization shows up.
#[allow(clippy::too_many_arguments)]
pub fn run_tile_batch(
    layer: &ConvLayer,
    weights: &PackedLayerWeights,
    gamma: &[f32],
    beta: &[f32],
    (co0, co1): (usize, usize),
    inputs: &[&dyn InputSurface],
    bypasses: Option<&[&dyn InputSurface]>,
    prec: Precision,
    geom: &TileGeom,
    write: &mut dyn FnMut(usize, usize, usize, usize, f32),
) -> AccessCounts {
    let l = layer;
    let b = inputs.len();
    if let Some(bps) = bypasses {
        assert_eq!(bps.len(), b, "one bypass surface per batched image");
    }
    let per_image = analytic_counts(l, (co0, co1), bypasses.is_some(), geom);
    let mut acc = AccessCounts::default();
    for _ in 0..b {
        acc.add(&per_image);
    }
    if b == 0 || co0 >= co1 || geom.oy0 >= geom.oy1 || geom.ox0 >= geom.ox1 {
        return acc;
    }
    let (k, stride) = (l.k, l.stride);
    let dlo = -((k / 2) as isize);
    let dhi = (k - 1) as isize + dlo;
    let group_size_out = l.n_out / l.groups;
    let nie = l.n_in / l.groups;
    let taps = k * k;

    let sy0 = ((geom.oy0 * stride) as isize + dlo).clamp(0, l.h as isize) as usize;
    let sy1 = (((geom.oy1 - 1) * stride) as isize + dhi + 1).clamp(0, l.h as isize) as usize;
    let sx0 = ((geom.ox0 * stride) as isize + dlo).clamp(0, l.w as isize) as usize;
    let sx1 = (((geom.ox1 - 1) * stride) as isize + dhi + 1).clamp(0, l.w as isize) as usize;
    let (sh, sw) = (sy1 - sy0, sx1 - sx0);

    let (yin_lo, yin_hi) = interior_range(l.h, stride, dlo, dhi);
    let (xin_lo, xin_hi) = interior_range(l.w, stride, dlo, dhi);
    let xi0 = xin_lo.clamp(geom.ox0, geom.ox1);
    let xi1 = xin_hi.clamp(xi0, geom.ox1);

    let tap_off: Vec<isize> = (0..taps)
        .map(|t| {
            let dy = (t / k) as isize + dlo;
            let dx = (t % k) as isize + dlo;
            (dy * sw as isize + dx) * nie as isize
        })
        .collect();

    debug_assert_eq!(weights.n_out, l.n_out, "mask planes built for this layer");
    debug_assert_eq!(weights.channel(co0).len(), taps * nie);
    // One resident staged window per image — "B feature maps stay
    // resident while the weights stream past".
    let mut stages: Vec<Vec<f32>> = (0..b).map(|_| vec![0.0f32; sh * sw * nie]).collect();
    let mut staged_group = usize::MAX;

    for co in co0..co1 {
        let g = co / group_size_out;
        if g != staged_group {
            for (img, stage) in inputs.iter().zip(stages.iter_mut()) {
                stage_input(*img, g * nie, nie, (sy0, sy1, sx0, sx1), stage);
            }
            staged_group = g;
        }
        // The weight block of this output channel — one borrow of the
        // per-layer mask plane, fetched once…
        let wmask = weights.channel(co);
        // …and applied to every resident image before the next block.
        for (bi, stage) in stages.iter().enumerate() {
            let bp = bypasses.map(|bps| bps[bi]);
            let mut emit = |oy: usize, ox: usize, mut v: f32| {
                if l.bnorm {
                    v = rnd(prec, v * gamma[co]);
                }
                if let Some(bp) = bp {
                    v = rnd(prec, v + bp.read(co, oy as isize, ox as isize));
                }
                v = rnd(prec, v + beta[co]);
                if l.relu && v < 0.0 {
                    v = 0.0;
                }
                write(bi, co, oy, ox, v);
            };
            for oy in geom.oy0..geom.oy1 {
                let iy = oy * stride;
                if oy < yin_lo || oy >= yin_hi {
                    for ox in geom.ox0..geom.ox1 {
                        let v = accum_checked(
                            stage,
                            wmask,
                            (k, dlo),
                            (l.h, l.w),
                            (sy0, sx0, sw),
                            (iy, ox * stride),
                            nie,
                            prec,
                        );
                        emit(oy, ox, v);
                    }
                    continue;
                }
                let row = (iy - sy0) * sw;
                for ox in geom.ox0..xi0 {
                    let v = accum_checked(
                        stage,
                        wmask,
                        (k, dlo),
                        (l.h, l.w),
                        (sy0, sx0, sw),
                        (iy, ox * stride),
                        nie,
                        prec,
                    );
                    emit(oy, ox, v);
                }
                let step = stride * nie;
                let mut ox = xi0;
                while ox + PIXEL_BLOCK <= xi1 {
                    let center = (row + ox * stride - sx0) * nie;
                    let vs = accum_block(stage, wmask, &tap_off, center, step, nie, prec);
                    for (p, &v) in vs.iter().enumerate() {
                        emit(oy, ox + p, v);
                    }
                    ox += PIXEL_BLOCK;
                }
                while ox < xi1 {
                    let center = (row + ox * stride - sx0) * nie;
                    let v = accum_interior(stage, wmask, &tap_off, center, nie, prec);
                    emit(oy, ox, v);
                    ox += 1;
                }
                for ox in xi1..geom.ox1 {
                    let v = accum_checked(
                        stage,
                        wmask,
                        (k, dlo),
                        (l.h, l.w),
                        (sy0, sx0, sw),
                        (iy, ox * stride),
                        nie,
                        prec,
                    );
                    emit(oy, ox, v);
                }
            }
        }
    }
    acc
}

/// Weight traffic of one whole layer on one chip (Tbl I schedule):
/// every stream word enters once, then is re-read from the weight
/// buffer per remaining pixel of the Tile-PU tile. Returns
/// `(stream_words, wbuf_reads)`.
pub fn weight_traffic(layer: &ConvLayer, c_par: usize, tile_pixels: u64) -> (u64, u64) {
    let n_in_eff = layer.n_in / layer.groups;
    let taps = layer.k * layer.k;
    let cout_tiles = layer.n_out.div_ceil(c_par) as u64;
    let stream_words = cout_tiles * taps as u64 * n_in_eff as u64;
    (stream_words, stream_words * (tile_pixels.max(1) - 1))
}

/// Resolve a thread-count knob: `0` means one worker per available
/// core (`std::thread::available_parallelism`, 1 if unknown).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bwn::pack_weights;
    use crate::testkit::reference_run_tile;
    use crate::util::SplitMix64;

    /// The kernel must not care how the caller addresses its memory:
    /// the same layer read through a plain FeatureMap and through an
    /// offset surface (simulating a mesh tile view) is bit-identical.
    #[test]
    fn kernel_is_surface_agnostic() {
        struct Shifted<'a> {
            fm: &'a FeatureMap,
        }
        impl InputSurface for Shifted<'_> {
            fn read(&self, ch: usize, gy: isize, gx: isize) -> f32 {
                // Same values, different address computation path (and
                // the default per-channel `gather`).
                self.fm.data[(ch * self.fm.h + gy as usize) * self.fm.w + gx as usize]
            }
        }
        let mut rng = SplitMix64::new(0xd47a);
        let l = ConvLayer::new("t", 4, 8, 6, 6, 3, 1);
        let w: Vec<f32> = (0..8 * 4 * 9).map(|_| rng.next_sym()).collect();
        let stream = pack_weights(&l, &w, 16);
        let packed = PackedLayerWeights::new(&stream);
        let gamma = vec![0.5f32; 8];
        let beta = vec![0.1f32; 8];
        let fm = FeatureMap::from_vec(4, 6, 6, (0..4 * 36).map(|_| rng.next_sym()).collect());
        let geom = TileGeom {
            oy0: 0,
            oy1: 6,
            ox0: 0,
            ox1: 6,
            iy0: 0,
            ix0: 0,
            tile_h: 2,
            tile_w: 2,
            in_tile_h: 2,
            in_tile_w: 2,
        };
        let mut a = vec![0.0f32; 8 * 36];
        let mut b = vec![0.0f32; 8 * 36];
        let acc_a = run_tile(
            &l,
            &packed,
            &gamma,
            &beta,
            (0, 8),
            &fm,
            None::<&FeatureMap>,
            Precision::F16,
            &geom,
            &mut |co, oy, ox, v| a[(co * 6 + oy) * 6 + ox] = v,
        );
        let shifted = Shifted { fm: &fm };
        let acc_b = run_tile(
            &l,
            &packed,
            &gamma,
            &beta,
            (0, 8),
            &shifted,
            None::<&FeatureMap>,
            Precision::F16,
            &geom,
            &mut |co, oy, ox, v| b[(co * 6 + oy) * 6 + ox] = v,
        );
        assert_eq!(a, b);
        assert_eq!(acc_a, acc_b);
    }

    /// Splitting the channel range must partition both the pixels and
    /// the counters exactly (the contract the threaded callers rely on).
    #[test]
    fn channel_ranges_partition_pixels_and_counters() {
        let mut rng = SplitMix64::new(0x5911);
        let l = ConvLayer::new("t", 3, 10, 5, 5, 3, 1);
        let w: Vec<f32> = (0..10 * 3 * 9).map(|_| rng.next_sym()).collect();
        let stream = pack_weights(&l, &w, 16);
        let packed = PackedLayerWeights::new(&stream);
        let gamma: Vec<f32> = (0..10).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..10).map(|_| rng.next_sym()).collect();
        let fm = FeatureMap::from_vec(3, 5, 5, (0..75).map(|_| rng.next_sym()).collect());
        let geom = TileGeom {
            oy0: 0,
            oy1: 5,
            ox0: 0,
            ox1: 5,
            iy0: 0,
            ix0: 0,
            tile_h: 1,
            tile_w: 1,
            in_tile_h: 1,
            in_tile_w: 1,
        };
        let run = |range: (usize, usize), out: &mut [f32]| {
            run_tile(
                &l,
                &packed,
                &gamma,
                &beta,
                range,
                &fm,
                None::<&FeatureMap>,
                Precision::F16,
                &geom,
                &mut |co, oy, ox, v| out[(co * 5 + oy) * 5 + ox] = v,
            )
        };
        let mut whole = vec![0.0f32; 10 * 25];
        let acc = run((0, 10), &mut whole);
        let mut split = vec![0.0f32; 10 * 25];
        let mut sum = AccessCounts::default();
        for (a, b) in [(0usize, 3usize), (3, 7), (7, 10)] {
            sum.add(&run((a, b), &mut split));
        }
        assert_eq!(whole, split);
        assert_eq!(acc, sum);
    }

    /// Fast unit-level anchor for the full property sweep in
    /// `tests/datapath_equivalence.rs`: one awkward fixed case (odd
    /// sizes, stride 2, groups, bypass) against the per-element
    /// counting oracle, both precisions.
    #[test]
    fn fast_path_matches_reference_oracle_fixed_case() {
        let mut rng = SplitMix64::new(0x0dd);
        let l = ConvLayer::new("t", 6, 10, 7, 5, 3, 2)
            .with_groups(2)
            .with_bypass(true);
        let nie = l.n_in / l.groups;
        let w: Vec<f32> = (0..l.n_out * nie * 9).map(|_| rng.next_sym()).collect();
        let stream = pack_weights(&l, &w, 16);
        let packed = PackedLayerWeights::new(&stream);
        let gamma: Vec<f32> = (0..10).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..10).map(|_| rng.next_sym()).collect();
        let fm = FeatureMap::from_vec(6, 7, 5, (0..6 * 35).map(|_| rng.next_sym()).collect());
        let (ho, wo) = (l.h_out(), l.w_out());
        let byp = FeatureMap::from_vec(
            10,
            ho,
            wo,
            (0..10 * ho * wo).map(|_| rng.next_sym()).collect(),
        );
        let geom = TileGeom {
            oy0: 0,
            oy1: ho,
            ox0: 0,
            ox1: wo,
            iy0: 0,
            ix0: 0,
            tile_h: 2,
            tile_w: 2,
            in_tile_h: 3,
            in_tile_w: 3,
        };
        for prec in [Precision::F16, Precision::F32] {
            let mut fast = vec![0.0f32; 10 * ho * wo];
            let mut refr = vec![0.0f32; 10 * ho * wo];
            let acc_fast = run_tile(
                &l,
                &packed,
                &gamma,
                &beta,
                (0, 10),
                &fm,
                Some(&byp),
                prec,
                &geom,
                &mut |co, oy, ox, v| fast[(co * ho + oy) * wo + ox] = v,
            );
            let acc_ref = reference_run_tile(
                &l,
                &stream,
                &gamma,
                &beta,
                (0, 10),
                &fm,
                Some(&byp),
                prec,
                &geom,
                &mut |co, oy, ox, v| refr[(co * ho + oy) * wo + ox] = v,
            );
            assert_eq!(fast, refr, "{prec:?} outputs diverged");
            assert_eq!(acc_fast, acc_ref, "{prec:?} counters diverged");
        }
    }

    #[test]
    fn analytic_counts_empty_ranges_are_zero() {
        let l = ConvLayer::new("t", 4, 8, 6, 6, 3, 1);
        let geom = TileGeom {
            oy0: 3,
            oy1: 3,
            ox0: 0,
            ox1: 6,
            iy0: 0,
            ix0: 0,
            tile_h: 1,
            tile_w: 1,
            in_tile_h: 1,
            in_tile_w: 1,
        };
        assert_eq!(
            analytic_counts(&l, (0, 8), false, &geom),
            AccessCounts::default()
        );
        let full = TileGeom { oy0: 0, oy1: 6, ..geom };
        assert_eq!(
            analytic_counts(&l, (5, 5), true, &full),
            AccessCounts::default()
        );
    }

    #[test]
    fn balanced_partition_keeps_every_worker_busy() {
        // 10 over 8 used to leave 3 workers idle under div_ceil chunks
        // (5 chunks of 2); the balanced split hands out 2,2,1,1,1,1,1,1.
        for (n, parts) in [
            (10usize, 8usize),
            (7, 3),
            (5, 4),
            (16, 16),
            (3, 64),
            (1, 1),
            (20, 7),
        ] {
            let ranges = partition_ranges(n, parts);
            assert_eq!(ranges.len(), parts.min(n), "({n}, {parts})");
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges.last().unwrap().1, n);
            for win in ranges.windows(2) {
                assert_eq!(win[0].1, win[1].0, "({n}, {parts}) not contiguous");
            }
            let (lo, hi) = (n / ranges.len(), n.div_ceil(ranges.len()));
            for &(a, b) in &ranges {
                assert!(b > a, "({n}, {parts}) empty range");
                assert!(
                    b - a == lo || b - a == hi,
                    "({n}, {parts}) unbalanced: {}",
                    b - a
                );
            }
        }
        assert!(partition_ranges(0, 4).is_empty());
    }

    #[test]
    fn weight_traffic_matches_table1_schedule() {
        // 16→64 3×3 on C=16, 8×8-pixel tiles: 4 tiles × 9 × 16 words,
        // each re-read 63 times.
        let l = ConvLayer::new("t", 16, 64, 56, 56, 3, 1);
        let (sw, wb) = weight_traffic(&l, 16, 64);
        assert_eq!(sw, 4 * 9 * 16);
        assert_eq!(wb, 4 * 9 * 16 * 63);
        // A degenerate 0-pixel tile never underflows.
        assert_eq!(weight_traffic(&l, 16, 0).1, 0);
    }

    /// The batch kernel is the single-image kernel run B times with the
    /// weight fetch hoisted: per-image outputs and the summed compute
    /// counters must match exactly, at both precisions, with bypass,
    /// groups and stride in play.
    #[test]
    fn batch_kernel_matches_per_image_runs() {
        let mut rng = SplitMix64::new(0xba7c);
        let l = ConvLayer::new("t", 6, 10, 7, 5, 3, 2)
            .with_groups(2)
            .with_bypass(true);
        let nie = l.n_in / l.groups;
        let w: Vec<f32> = (0..l.n_out * nie * 9).map(|_| rng.next_sym()).collect();
        let stream = pack_weights(&l, &w, 16);
        let packed = PackedLayerWeights::new(&stream);
        let gamma: Vec<f32> = (0..10).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..10).map(|_| rng.next_sym()).collect();
        let (ho, wo) = (l.h_out(), l.w_out());
        let geom = TileGeom {
            oy0: 0,
            oy1: ho,
            ox0: 0,
            ox1: wo,
            iy0: 0,
            ix0: 0,
            tile_h: 2,
            tile_w: 2,
            in_tile_h: 3,
            in_tile_w: 3,
        };
        const B: usize = 3;
        let fms: Vec<FeatureMap> = (0..B)
            .map(|_| FeatureMap::from_vec(6, 7, 5, (0..6 * 35).map(|_| rng.next_sym()).collect()))
            .collect();
        let byps: Vec<FeatureMap> = (0..B)
            .map(|_| {
                FeatureMap::from_vec(10, ho, wo, (0..10 * ho * wo).map(|_| rng.next_sym()).collect())
            })
            .collect();
        for prec in [Precision::F16, Precision::F32] {
            let mut seq = vec![vec![0.0f32; 10 * ho * wo]; B];
            let mut seq_acc = AccessCounts::default();
            for bi in 0..B {
                let out = &mut seq[bi];
                seq_acc.add(&run_tile(
                    &l,
                    &packed,
                    &gamma,
                    &beta,
                    (0, 10),
                    &fms[bi],
                    Some(&byps[bi]),
                    prec,
                    &geom,
                    &mut |co, oy, ox, v| out[(co * ho + oy) * wo + ox] = v,
                ));
            }
            let inputs: Vec<&dyn InputSurface> =
                fms.iter().map(|f| f as &dyn InputSurface).collect();
            let bypasses: Vec<&dyn InputSurface> =
                byps.iter().map(|f| f as &dyn InputSurface).collect();
            let mut batched = vec![vec![0.0f32; 10 * ho * wo]; B];
            let batch_acc = run_tile_batch(
                &l,
                &packed,
                &gamma,
                &beta,
                (0, 10),
                &inputs,
                Some(&bypasses),
                prec,
                &geom,
                &mut |bi, co, oy, ox, v| batched[bi][(co * ho + oy) * wo + ox] = v,
            );
            assert_eq!(seq, batched, "{prec:?} outputs diverged from per-image runs");
            assert_eq!(seq_acc, batch_acc, "{prec:?} compute counters diverged");
        }
    }

    #[test]
    fn resolve_threads_zero_means_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}

//! The one Tile-PU datapath kernel (Algorithm 1).
//!
//! Hyperdrive's central claim is that the *same* Tile-PU datapath scales
//! from a single chip to an m×n systolic mesh (§V). This module is that
//! datapath in software: [`run_tile`] executes the sign-mask accumulate
//! (the binary weight applied as the sign input of the FP16 adder,
//! Algorithm 1 line 17) followed by the stall-free scale → bypass →
//! bias → ReLU post sequence for a rectangle of output pixels, reading
//! its input through the [`InputSurface`] abstraction — a flat
//! [`FeatureMap`](super::fm::FeatureMap) on the single-chip simulator, a
//! halo-ringed `ExtTile` on the mesh. Both simulators call this one
//! kernel, so the Fig-10/Tbl-II traffic counters ([`AccessCounts`]) come
//! from a single source of truth and the functional-vs-mesh bit-exactness
//! checks compare two memory systems, not two arithmetic
//! implementations.
//!
//! The kernel is also the unit of parallelism: callers fan
//! [`run_tile`] invocations out over scoped threads (output-channel
//! ranges on a single chip, whole chips on the mesh — data-independent
//! between exchange phases, exactly the paper's execution model). Every
//! FP16 rounding step of one output pixel happens inside one invocation
//! in a fixed order, so results are bit-identical at any thread count.

use crate::bwn::WeightStream;
use crate::network::ConvLayer;
use crate::util::f16::round_f16;

use super::fm::FeatureMap;

/// Datapath precision of the simulated Tile-PUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Bit-exact FP16 (round every accumulate) — the taped-out chip.
    #[default]
    F16,
    /// f32 (matches the PJRT CPU artifacts; used for cross-validation).
    F32,
}

#[inline]
pub(crate) fn rnd(p: Precision, x: f32) -> f32 {
    match p {
        Precision::F16 => round_f16(x),
        Precision::F32 => x,
    }
}

/// Memory/IO traffic of one simulated layer (word granularity).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AccessCounts {
    /// FMM word reads (input FM fetches incl. neighbour-bank reads).
    pub fmm_reads: u64,
    /// FMM word writes (output pixels; bypass read-modify adds a read).
    pub fmm_writes: u64,
    /// Weight words fetched from the off-chip stream.
    pub stream_words: u64,
    /// Weight words re-read from the weight buffer.
    pub wbuf_reads: u64,
    /// Reads that crossed a Tile-PU boundary (neighbour bank access).
    pub neighbor_reads: u64,
    /// Post-phase multiplies (bnorm) on the shared per-tile multiplier.
    pub post_mults: u64,
    /// Post-phase adds (bias + bypass).
    pub post_adds: u64,
    /// FP16 accumulates in the Tile-PU adders.
    pub accumulates: u64,
}

impl AccessCounts {
    pub fn add(&mut self, o: &AccessCounts) {
        self.fmm_reads += o.fmm_reads;
        self.fmm_writes += o.fmm_writes;
        self.stream_words += o.stream_words;
        self.wbuf_reads += o.wbuf_reads;
        self.neighbor_reads += o.neighbor_reads;
        self.post_mults += o.post_mults;
        self.post_adds += o.post_adds;
        self.accumulates += o.accumulates;
    }
}

/// A conv-input view addressed in *global* FM coordinates.
///
/// The kernel performs the DDU's zero-padding itself (a padded tap skips
/// the accumulation — `v ± 0` is exact in FP16 and f32), so `read` is
/// only ever called with coordinates inside the global FM bounds;
/// implementations may assert on anything else (the mesh's `ExtTile`
/// does, which is what catches never-exchanged halo pixels).
pub trait InputSurface {
    /// Value of channel `ch` at global `(gy, gx)`; both in-FM.
    fn read(&self, ch: usize, gy: isize, gx: isize) -> f32;
}

impl InputSurface for FeatureMap {
    #[inline]
    fn read(&self, ch: usize, gy: isize, gx: isize) -> f32 {
        self.get(ch, gy as usize, gx as usize)
    }
}

/// Geometry of one [`run_tile`] invocation: which output rectangle to
/// compute and where the local Tile-PU patch grid sits, for
/// neighbour-read accounting.
#[derive(Debug, Clone, Copy)]
pub struct TileGeom {
    /// Output region `[oy0, oy1) × [ox0, ox1)` in global coordinates.
    pub oy0: usize,
    pub oy1: usize,
    pub ox0: usize,
    pub ox1: usize,
    /// Input-space origin of the local Tile-PU grid (the chip's owned
    /// input region starts here; 0 on a single chip). Reads at negative
    /// local coordinates are halo reads from a neighbouring chip and
    /// count as neighbour-bank traffic.
    pub iy0: isize,
    pub ix0: isize,
    /// Tile-PU patch size in output space (≥ 1).
    pub tile_h: usize,
    pub tile_w: usize,
    /// Tile-PU patch size in input space (≥ 1).
    pub in_tile_h: usize,
    pub in_tile_w: usize,
}

/// Execute Algorithm 1 for output channels `[co0, co1)` over the output
/// rectangle in `geom`, writing each finished pixel through `write(co,
/// gy, gx, v)` and returning the traffic counters of this invocation.
///
/// Loop order is the chip's exactly: filter-tap outer, input-channel
/// inner (lines 7–19), the binary weight applied as a sign-bit XOR on
/// the FP32 representation (line 17, hoisted per output channel into a
/// `u32` mask table — see DESIGN.md §Perf log), then the §IV-B
/// scale → bypass → bias → ReLU post sequence, optionally rounding
/// every intermediate to FP16 like the silicon.
#[allow(clippy::too_many_arguments)]
pub fn run_tile<S, B, W>(
    layer: &ConvLayer,
    stream: &WeightStream,
    gamma: &[f32],
    beta: &[f32],
    (co0, co1): (usize, usize),
    input: &S,
    bypass: Option<&B>,
    prec: Precision,
    geom: &TileGeom,
    write: &mut W,
) -> AccessCounts
where
    S: InputSurface + ?Sized,
    B: InputSurface + ?Sized,
    W: FnMut(usize, usize, usize, f32),
{
    let l = layer;
    let half = (l.k / 2) as isize;
    let group_size_out = l.n_out / l.groups;
    let n_in_eff = l.n_in / l.groups;
    let taps = l.k * l.k;
    let mut acc = AccessCounts::default();
    let mut wmask = vec![0u32; taps * n_in_eff];
    for co in co0..co1 {
        let g = co / group_size_out;
        let cin_base = g * n_in_eff;
        for tap in 0..taps {
            for ci in 0..n_in_eff {
                wmask[tap * n_in_eff + ci] = if stream.weight(co, ci, tap) > 0.0 {
                    0
                } else {
                    0x8000_0000
                };
            }
        }
        for oy in geom.oy0..geom.oy1 {
            let ty = ((oy - geom.oy0) / geom.tile_h) as isize;
            for ox in geom.ox0..geom.ox1 {
                let tx = ((ox - geom.ox0) / geom.tile_w) as isize;
                let mut v = 0.0f32;
                // Algorithm 1 lines 7–19: tap outer, input channel inner.
                for tap in 0..taps {
                    let dy = (tap / l.k) as isize - half;
                    let dx = (tap % l.k) as isize - half;
                    let iy = (oy * l.stride) as isize + dy;
                    let ix = (ox * l.stride) as isize + dx;
                    acc.accumulates += n_in_eff as u64;
                    acc.fmm_reads += n_in_eff as u64;
                    if iy < 0 || ix < 0 || iy >= l.h as isize || ix >= l.w as isize {
                        // Zero padding: the DDU injects zeros; v is
                        // unchanged (v ± 0 == v bit-exactly).
                        continue;
                    }
                    // Tile-PU patch of the read, in the local grid
                    // (negative → a halo pixel from a neighbour chip).
                    let t_in = (
                        (iy - geom.iy0).div_euclid(geom.in_tile_h as isize),
                        (ix - geom.ix0).div_euclid(geom.in_tile_w as isize),
                    );
                    if t_in != (ty, tx) {
                        acc.neighbor_reads += n_in_eff as u64;
                    }
                    let row = &wmask[tap * n_in_eff..(tap + 1) * n_in_eff];
                    // Line 17: sign-select accumulate (sign-bit XOR).
                    match prec {
                        Precision::F32 => {
                            for (ci, &mask) in row.iter().enumerate() {
                                let x = input.read(cin_base + ci, iy, ix);
                                v += f32::from_bits(x.to_bits() ^ mask);
                            }
                        }
                        Precision::F16 => {
                            for (ci, &mask) in row.iter().enumerate() {
                                let x = input.read(cin_base + ci, iy, ix);
                                v = round_f16(v + f32::from_bits(x.to_bits() ^ mask));
                            }
                        }
                    }
                }
                // §IV-B order: scale → bypass → bias → ReLU.
                if l.bnorm {
                    v = rnd(prec, v * gamma[co]);
                    acc.post_mults += 1;
                }
                if let Some(bp) = bypass {
                    v = rnd(prec, v + bp.read(co, oy as isize, ox as isize));
                    acc.fmm_reads += 1;
                    acc.post_adds += 1;
                }
                v = rnd(prec, v + beta[co]);
                acc.post_adds += 1;
                if l.relu && v < 0.0 {
                    v = 0.0;
                }
                write(co, oy, ox, v);
                acc.fmm_writes += 1;
            }
        }
    }
    acc
}

/// Weight traffic of one whole layer on one chip (Tbl I schedule):
/// every stream word enters once, then is re-read from the weight
/// buffer per remaining pixel of the Tile-PU tile. Returns
/// `(stream_words, wbuf_reads)`.
pub fn weight_traffic(layer: &ConvLayer, c_par: usize, tile_pixels: u64) -> (u64, u64) {
    let n_in_eff = layer.n_in / layer.groups;
    let taps = layer.k * layer.k;
    let cout_tiles = layer.n_out.div_ceil(c_par) as u64;
    let stream_words = cout_tiles * taps as u64 * n_in_eff as u64;
    (stream_words, stream_words * (tile_pixels.max(1) - 1))
}

/// Resolve a thread-count knob: `0` means one worker per available
/// core (`std::thread::available_parallelism`, 1 if unknown).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bwn::pack_weights;
    use crate::util::SplitMix64;

    /// The kernel must not care how the caller addresses its memory:
    /// the same layer read through a plain FeatureMap and through an
    /// offset surface (simulating a mesh tile view) is bit-identical.
    #[test]
    fn kernel_is_surface_agnostic() {
        struct Shifted<'a> {
            fm: &'a FeatureMap,
        }
        impl InputSurface for Shifted<'_> {
            fn read(&self, ch: usize, gy: isize, gx: isize) -> f32 {
                // Same values, different address computation path.
                self.fm.data[(ch * self.fm.h + gy as usize) * self.fm.w + gx as usize]
            }
        }
        let mut rng = SplitMix64::new(0xd47a);
        let l = ConvLayer::new("t", 4, 8, 6, 6, 3, 1);
        let w: Vec<f32> = (0..8 * 4 * 9).map(|_| rng.next_sym()).collect();
        let stream = pack_weights(&l, &w, 16);
        let gamma = vec![0.5f32; 8];
        let beta = vec![0.1f32; 8];
        let fm = FeatureMap::from_vec(4, 6, 6, (0..4 * 36).map(|_| rng.next_sym()).collect());
        let geom = TileGeom {
            oy0: 0,
            oy1: 6,
            ox0: 0,
            ox1: 6,
            iy0: 0,
            ix0: 0,
            tile_h: 2,
            tile_w: 2,
            in_tile_h: 2,
            in_tile_w: 2,
        };
        let mut a = vec![0.0f32; 8 * 36];
        let mut b = vec![0.0f32; 8 * 36];
        let acc_a = run_tile(
            &l,
            &stream,
            &gamma,
            &beta,
            (0, 8),
            &fm,
            None::<&FeatureMap>,
            Precision::F16,
            &geom,
            &mut |co, oy, ox, v| a[(co * 6 + oy) * 6 + ox] = v,
        );
        let shifted = Shifted { fm: &fm };
        let acc_b = run_tile(
            &l,
            &stream,
            &gamma,
            &beta,
            (0, 8),
            &shifted,
            None::<&FeatureMap>,
            Precision::F16,
            &geom,
            &mut |co, oy, ox, v| b[(co * 6 + oy) * 6 + ox] = v,
        );
        assert_eq!(a, b);
        assert_eq!(acc_a, acc_b);
    }

    /// Splitting the channel range must partition both the pixels and
    /// the counters exactly (the contract the threaded callers rely on).
    #[test]
    fn channel_ranges_partition_pixels_and_counters() {
        let mut rng = SplitMix64::new(0x5911);
        let l = ConvLayer::new("t", 3, 10, 5, 5, 3, 1);
        let w: Vec<f32> = (0..10 * 3 * 9).map(|_| rng.next_sym()).collect();
        let stream = pack_weights(&l, &w, 16);
        let gamma: Vec<f32> = (0..10).map(|_| 0.5 + rng.next_f32()).collect();
        let beta: Vec<f32> = (0..10).map(|_| rng.next_sym()).collect();
        let fm = FeatureMap::from_vec(3, 5, 5, (0..75).map(|_| rng.next_sym()).collect());
        let geom = TileGeom {
            oy0: 0,
            oy1: 5,
            ox0: 0,
            ox1: 5,
            iy0: 0,
            ix0: 0,
            tile_h: 1,
            tile_w: 1,
            in_tile_h: 1,
            in_tile_w: 1,
        };
        let run = |range: (usize, usize), out: &mut [f32]| {
            run_tile(
                &l,
                &stream,
                &gamma,
                &beta,
                range,
                &fm,
                None::<&FeatureMap>,
                Precision::F16,
                &geom,
                &mut |co, oy, ox, v| out[(co * 5 + oy) * 5 + ox] = v,
            )
        };
        let mut whole = vec![0.0f32; 10 * 25];
        let acc = run((0, 10), &mut whole);
        let mut split = vec![0.0f32; 10 * 25];
        let mut sum = AccessCounts::default();
        for (a, b) in [(0usize, 3usize), (3, 7), (7, 10)] {
            sum.add(&run((a, b), &mut split));
        }
        assert_eq!(whole, split);
        assert_eq!(acc, sum);
    }

    #[test]
    fn weight_traffic_matches_table1_schedule() {
        // 16→64 3×3 on C=16, 8×8-pixel tiles: 4 tiles × 9 × 16 words,
        // each re-read 63 times.
        let l = ConvLayer::new("t", 16, 64, 56, 56, 3, 1);
        let (sw, wb) = weight_traffic(&l, 16, 64);
        assert_eq!(sw, 4 * 9 * 16);
        assert_eq!(wb, 4 * 9 * 16 * 63);
        // A degenerate 0-pixel tile never underflows.
        assert_eq!(weight_traffic(&l, 16, 0).1, 0);
    }

    #[test]
    fn resolve_threads_zero_means_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}

//! Feature-map tensor used by the functional simulator.

use crate::util::f16::round_f16;

/// A (channels, height, width) feature map in row-major `[c][y][x]`.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureMap {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl FeatureMap {
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        FeatureMap {
            c,
            h,
            w,
            data: vec![0.0; c * h * w],
        }
    }

    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), c * h * w);
        FeatureMap { c, h, w, data }
    }

    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Zero-padded read (the DDU's padding logic): out-of-bounds → 0.
    #[inline]
    pub fn get_padded(&self, c: usize, y: isize, x: isize) -> f32 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0.0
        } else {
            self.get(c, y as usize, x as usize)
        }
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: f32) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Round every element to the nearest representable FP16 value
    /// (storage quantization when an external f32 FM enters the FMM).
    pub fn quantize_f16(&mut self) {
        for v in &mut self.data {
            *v = round_f16(*v);
        }
    }

    /// Extract the spatial sub-tile `[y0..y1) × [x0..x1)` of all
    /// channels — one `copy_from_slice` per row, not per-element
    /// `get`/`set` (rows are contiguous in the `[c][y][x]` layout).
    pub fn slice(&self, y0: usize, y1: usize, x0: usize, x1: usize) -> FeatureMap {
        let (sh, sw) = (y1 - y0, x1 - x0);
        let mut out = FeatureMap::zeros(self.c, sh, sw);
        for c in 0..self.c {
            for y in y0..y1 {
                let src = (c * self.h + y) * self.w + x0;
                let dst = (c * sh + (y - y0)) * sw;
                out.data[dst..dst + sw].copy_from_slice(&self.data[src..src + sw]);
            }
        }
        out
    }

    /// Channel-wise concatenation (YOLOv3 FPN merges).
    pub fn concat_channels(&self, other: &FeatureMap) -> FeatureMap {
        assert_eq!((self.h, self.w), (other.h, other.w));
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        FeatureMap::from_vec(self.c + other.c, self.h, self.w, data)
    }

    /// 2× nearest-neighbour upsample (YOLOv3's FPN laterals): each pixel
    /// is replicated into a 2×2 block. On the chip this is free DDU
    /// addressing — no arithmetic, no extra reads — but the stored FM
    /// is 4× larger.
    pub fn upsample2x_nearest(&self) -> FeatureMap {
        let mut out = FeatureMap::zeros(self.c, 2 * self.h, 2 * self.w);
        for c in 0..self.c {
            for y in 0..2 * self.h {
                for x in 0..2 * self.w {
                    out.set(c, y, x, self.get(c, y / 2, x / 2));
                }
            }
        }
        out
    }

    /// Maximum absolute difference to another FM of the same shape.
    /// NaN anywhere (e.g. a poisoned, never-exchanged halo pixel)
    /// propagates to the result — `f32::max` alone would silently drop
    /// it (caught by the mesh fault-injection test).
    pub fn max_abs_diff(&self, other: &FeatureMap) -> f32 {
        assert_eq!((self.c, self.h, self.w), (other.c, other.h, other.w));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, |m, d| {
                if m.is_nan() || d.is_nan() {
                    f32::NAN
                } else {
                    m.max(d)
                }
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_round_trip() {
        let mut fm = FeatureMap::zeros(2, 3, 4);
        fm.set(1, 2, 3, 5.0);
        assert_eq!(fm.get(1, 2, 3), 5.0);
        assert_eq!(fm.data[(1 * 3 + 2) * 4 + 3], 5.0);
    }

    #[test]
    fn padded_reads_are_zero_outside() {
        let mut fm = FeatureMap::zeros(1, 2, 2);
        fm.set(0, 0, 0, 7.0);
        assert_eq!(fm.get_padded(0, -1, 0), 0.0);
        assert_eq!(fm.get_padded(0, 0, 2), 0.0);
        assert_eq!(fm.get_padded(0, 0, 0), 7.0);
    }

    #[test]
    fn f16_quantization_rounds_storage() {
        let mut fm = FeatureMap::from_vec(1, 1, 2, vec![2049.0, 0.1]);
        fm.quantize_f16();
        assert_eq!(fm.get(0, 0, 0), 2048.0);
        assert!((fm.get(0, 0, 1) - 0.1).abs() < 1e-4);
    }

    #[test]
    fn slicing_extracts_subtile() {
        let mut fm = FeatureMap::zeros(1, 4, 4);
        for y in 0..4 {
            for x in 0..4 {
                fm.set(0, y, x, (y * 4 + x) as f32);
            }
        }
        let s = fm.slice(1, 3, 2, 4);
        assert_eq!((s.h, s.w), (2, 2));
        assert_eq!(s.get(0, 0, 0), 6.0); // (y=1, x=2)
        assert_eq!(s.get(0, 1, 1), 11.0); // (y=2, x=3)
    }

    #[test]
    fn max_abs_diff_propagates_nan() {
        let a = FeatureMap::from_vec(1, 1, 2, vec![1.0, f32::NAN]);
        let b = FeatureMap::from_vec(1, 1, 2, vec![1.0, 1.0]);
        assert!(a.max_abs_diff(&b).is_nan());
        let c = FeatureMap::from_vec(1, 1, 2, vec![1.0, 3.0]);
        assert_eq!(c.max_abs_diff(&b), 2.0);
    }

    #[test]
    fn upsample_replicates_2x2_blocks() {
        let fm = FeatureMap::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let up = fm.upsample2x_nearest();
        assert_eq!((up.c, up.h, up.w), (1, 4, 4));
        assert_eq!(
            up.data,
            vec![
                1.0, 1.0, 2.0, 2.0, //
                1.0, 1.0, 2.0, 2.0, //
                3.0, 3.0, 4.0, 4.0, //
                3.0, 3.0, 4.0, 4.0,
            ]
        );
    }

    #[test]
    fn concat_stacks_channels() {
        let a = FeatureMap::from_vec(1, 1, 2, vec![1.0, 2.0]);
        let b = FeatureMap::from_vec(2, 1, 2, vec![3.0, 4.0, 5.0, 6.0]);
        let c = a.concat_channels(&b);
        assert_eq!(c.c, 3);
        assert_eq!(c.get(2, 0, 1), 6.0);
    }
}

//! Multi-chip systolic mesh simulator (§V): the whole network executed on
//! an m×n array of chips, each holding only its FM tile plus the border
//! and corner halos received from its neighbours.
//!
//! Protocol fidelity: halo pixels start as NaN and are only overwritten
//! by the exchange phase — any read of a pixel that was never exchanged
//! poisons the output and fails the bit-exactness check against the
//! single-chip reference. Corner pixels travel via the vertical
//! neighbour (two hops, no diagonal wires, §V-B).

use std::collections::HashMap;

use crate::bwn::WeightStream;
use crate::coordinator::border::{link_flits, ExchangeFlags};
use crate::network::{Network, TensorRef};
use crate::util::f16::round_f16;

use super::chip::Precision;
use super::fm::FeatureMap;

/// Per-layer parameters for the mesh run (same content as
/// [`super::chip::LayerParams`], owned per step).
#[derive(Clone)]
pub struct StepParams {
    pub stream: WeightStream,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
}

/// Aggregate traffic statistics of a mesh run.
#[derive(Debug, Clone, Default)]
pub struct MeshStats {
    /// Bits exchanged over direct (N/S/E/W) links for borders.
    pub border_bits: u64,
    /// Bits for corner pixels (counted per hop; two hops each).
    pub corner_bits: u64,
    /// 4-bit link flits total (border interface serialization, §V-D).
    pub flits: u64,
    /// Input distribution bits (tiles + initial halo; not exchange).
    pub input_bits: u64,
    /// Exchange protocol flags, aggregated over chips.
    pub flags: ExchangeFlags,
}

/// One chip's view of one tensor: its owned tile extended by a 1-pixel
/// halo ring (NaN until received; zero where outside the global FM).
struct ExtTile {
    /// Owned global region `[y0, y1) × [x0, x1)`.
    y0: usize,
    y1: usize,
    x0: usize,
    x1: usize,
    /// Data covering `[y0-1, y1+1) × [x0-1, x1+1)` in global coords.
    data: FeatureMap,
}

impl ExtTile {
    fn new(c: usize, y0: usize, y1: usize, x0: usize, x1: usize, gh: usize, gw: usize) -> Self {
        let mut data = FeatureMap::zeros(c, y1 - y0 + 2, x1 - x0 + 2);
        // Ring: NaN inside the FM (must be exchanged), 0 outside (padding).
        for ch in 0..c {
            for ly in 0..data.h {
                for lx in 0..data.w {
                    let gy = y0 as isize + ly as isize - 1;
                    let gx = x0 as isize + lx as isize - 1;
                    let owned = gy >= y0 as isize
                        && gy < y1 as isize
                        && gx >= x0 as isize
                        && gx < x1 as isize;
                    let inside = gy >= 0 && gx >= 0 && (gy as usize) < gh && (gx as usize) < gw;
                    if !owned {
                        data.set(ch, ly, lx, if inside { f32::NAN } else { 0.0 });
                    }
                }
            }
        }
        ExtTile {
            y0,
            y1,
            x0,
            x1,
            data,
        }
    }

    #[inline]
    fn read(&self, c: usize, gy: isize, gx: isize) -> f32 {
        let ly = gy - self.y0 as isize + 1;
        let lx = gx - self.x0 as isize + 1;
        assert!(
            ly >= 0 && lx >= 0 && (ly as usize) < self.data.h && (lx as usize) < self.data.w,
            "read outside tile+halo: global ({gy},{gx}) for tile y[{},{}) x[{},{})",
            self.y0,
            self.y1,
            self.x0,
            self.x1
        );
        self.data.get(c, ly as usize, lx as usize)
    }

    #[inline]
    fn write_own(&mut self, c: usize, gy: usize, gx: usize, v: f32) {
        self.data
            .set(c, gy - self.y0 + 1, gx - self.x0 + 1, v);
    }

    /// Write a received halo pixel (global coords on the ring).
    #[inline]
    fn write_halo(&mut self, c: usize, gy: isize, gx: isize, v: f32) {
        let ly = (gy - self.y0 as isize + 1) as usize;
        let lx = (gx - self.x0 as isize + 1) as usize;
        self.data.set(c, ly, lx, v);
    }
}

/// Global coordinates of the 1-pixel halo ring around a tile.
fn ring_coords(
    y0: usize,
    y1: usize,
    x0: usize,
    x1: usize,
) -> impl Iterator<Item = (isize, isize)> {
    let (y0, y1, x0, x1) = (y0 as isize, y1 as isize, x0 as isize, x1 as isize);
    let top = (x0 - 1..=x1).map(move |x| (y0 - 1, x));
    let bottom = (x0 - 1..=x1).map(move |x| (y1, x));
    let left = (y0..y1).map(move |y| (y, x0 - 1));
    let right = (y0..y1).map(move |y| (y, x1));
    top.chain(bottom).chain(left).chain(right)
}

/// The mesh simulator.
pub struct MeshSim {
    pub rows: usize,
    pub cols: usize,
    pub prec: Precision,
    pub fm_bits: usize,
    /// Fault injection: drop the Nth border send of the whole run (the
    /// NaN-poisoned halo then propagates to the output — used to verify
    /// the protocol checking actually bites).
    pub fault_drop_send: Option<u64>,
}

impl MeshSim {
    pub fn new(rows: usize, cols: usize, prec: Precision) -> Self {
        MeshSim {
            rows,
            cols,
            prec,
            fm_bits: 16,
            fault_drop_send: None,
        }
    }

    fn bounds(&self, dim: usize, parts: usize, i: usize) -> (usize, usize) {
        assert_eq!(
            dim % parts,
            0,
            "mesh simulator requires FM dims divisible by the mesh ({dim} % {parts})"
        );
        let t = dim / parts;
        (i * t, (i + 1) * t)
    }

    #[inline]
    fn rnd(&self, x: f32) -> f32 {
        match self.prec {
            Precision::F16 => round_f16(x),
            Precision::F32 => x,
        }
    }

    /// Run a whole network on the mesh. `params[i]` belongs to step `i`.
    /// Returns the re-assembled final FM and the traffic statistics.
    pub fn run_network(
        &self,
        net: &Network,
        params: &[StepParams],
        input: &FeatureMap,
    ) -> (FeatureMap, MeshStats) {
        self.run_network_observed(net, params, input, None)
    }

    /// [`Self::run_network`] with a per-step observer: after each step
    /// (and its exchange phase) the observer receives the step index and
    /// the re-assembled global output FM — the engine's trace hook.
    pub fn run_network_traced(
        &self,
        net: &Network,
        params: &[StepParams],
        input: &FeatureMap,
        observe: &mut dyn FnMut(usize, &FeatureMap),
    ) -> (FeatureMap, MeshStats) {
        self.run_network_observed(net, params, input, Some(observe))
    }

    fn run_network_observed(
        &self,
        net: &Network,
        params: &[StepParams],
        input: &FeatureMap,
        mut observe: Option<&mut dyn FnMut(usize, &FeatureMap)>,
    ) -> (FeatureMap, MeshStats) {
        assert_eq!(params.len(), net.steps.len());
        let mut stats = MeshStats::default();

        // Consumer halo per tensor (0 → no exchange needed).
        let n = net.steps.len();
        let tid = |r: TensorRef| match r {
            TensorRef::Input => 0usize,
            TensorRef::Step(i) => 1 + i,
        };
        let mut halo = vec![0usize; n + 1];
        for s in &net.steps {
            let h = s.layer.k / 2;
            for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
                halo[tid(r)] = halo[tid(r)].max(h);
            }
        }

        // Per-chip tensor store: (row, col) → tensor id → ExtTile.
        let mut tiles: Vec<HashMap<usize, ExtTile>> =
            (0..self.rows * self.cols).map(|_| HashMap::new()).collect();

        // Distribute the input: owned tile + pre-filled halo ring (the
        // halo arrives as part of the input load, §V).
        let (ic, ih, iw) = (net.in_ch, net.in_h, net.in_w);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let (y0, y1) = self.bounds(ih, self.rows, r);
                let (x0, x1) = self.bounds(iw, self.cols, c);
                let mut t = ExtTile::new(ic, y0, y1, x0, x1, ih, iw);
                for ch in 0..ic {
                    for gy in y0..y1 {
                        for gx in x0..x1 {
                            t.write_own(ch, gy, gx, input.get(ch, gy, gx));
                        }
                    }
                }
                // Pre-fill the ring from the global input.
                if halo[0] > 0 {
                    for ch in 0..ic {
                        for (gy, gx) in ring_coords(y0, y1, x0, x1) {
                            if gy >= 0 && gx >= 0 && (gy as usize) < ih && (gx as usize) < iw {
                                t.write_halo(ch, gy, gx, input.get(ch, gy as usize, gx as usize));
                                stats.input_bits += self.fm_bits as u64;
                            }
                        }
                    }
                }
                stats.input_bits += (ic * (y1 - y0) * (x1 - x0) * self.fm_bits) as u64;
                tiles[r * self.cols + c].insert(0, t);
            }
        }

        // Execute steps.
        for (si, step) in net.steps.iter().enumerate() {
            let l = &step.layer;
            assert!(!step.upsample2x, "mesh sim does not model upsampling");
            let p = &params[si];
            let (ho, wo) = (l.h_out(), l.w_out());
            let half = (l.k / 2) as isize;
            let gso = l.n_out / l.groups;
            let nie = l.n_in / l.groups;
            let src_id = tid(step.src);
            let byp_id = step.bypass.map(tid);
            let cat_id = step.concat_extra.map(tid);
            let (src_c, _, _) = net.shape_of(step.src);

            // Compute each chip's output tile.
            for r in 0..self.rows {
                for c in 0..self.cols {
                    let idx = r * self.cols + c;
                    let (oy0, oy1) = self.bounds(ho, self.rows, r);
                    let (ox0, ox1) = self.bounds(wo, self.cols, c);
                    let mut out = ExtTile::new(l.n_out, oy0, oy1, ox0, ox1, ho, wo);
                    {
                        let chip = &tiles[idx];
                        let src = chip.get(&src_id).expect("src tile");
                        let cat = cat_id.map(|t| chip.get(&t).expect("concat tile"));
                        let byp = byp_id.map(|t| chip.get(&t).expect("bypass tile"));
                        let read_in = |ch: usize, gy: isize, gx: isize| -> f32 {
                            if ch < src_c {
                                src.read(ch, gy, gx)
                            } else {
                                cat.expect("channel beyond src without concat")
                                    .read(ch - src_c, gy, gx)
                            }
                        };
                        // Perf (§Perf log): hoist each output channel's
                        // binary weights into a sign-mask table (as in
                        // chip.rs) instead of div/mod stream lookups per
                        // MAC; padded taps skip the c_in loop (v ± 0 is
                        // exact).
                        let taps = l.k * l.k;
                        let mut wmask = vec![0u32; taps * nie];
                        for co in 0..l.n_out {
                            let cb = (co / gso) * nie;
                            for tap in 0..taps {
                                for ci in 0..nie {
                                    wmask[tap * nie + ci] =
                                        if p.stream.weight(co, ci, tap) > 0.0 {
                                            0
                                        } else {
                                            0x8000_0000
                                        };
                                }
                            }
                            for gy in oy0..oy1 {
                                for gx in ox0..ox1 {
                                    let mut v = 0.0f32;
                                    for tap in 0..taps {
                                        let dy = (tap / l.k) as isize - half;
                                        let dx = (tap % l.k) as isize - half;
                                        let iy = (gy * l.stride) as isize + dy;
                                        let ix = (gx * l.stride) as isize + dx;
                                        // Global zero padding at FM edges.
                                        if iy < 0
                                            || ix < 0
                                            || iy >= l.h as isize
                                            || ix >= l.w as isize
                                        {
                                            continue;
                                        }
                                        let row = &wmask[tap * nie..(tap + 1) * nie];
                                        for (ci, &mask) in row.iter().enumerate() {
                                            let x = read_in(cb + ci, iy, ix);
                                            v = self
                                                .rnd(v + f32::from_bits(x.to_bits() ^ mask));
                                        }
                                    }
                                    if l.bnorm {
                                        v = self.rnd(v * p.gamma[co]);
                                    }
                                    if let Some(bp) = byp {
                                        v = self.rnd(v + bp.read(co, gy as isize, gx as isize));
                                    }
                                    v = self.rnd(v + p.beta[co]);
                                    if l.relu && v < 0.0 {
                                        v = 0.0;
                                    }
                                    out.write_own(co, gy, gx, v);
                                }
                            }
                        }
                    }
                    tiles[idx].insert(1 + si, out);
                }
            }

            // Exchange phase for this tensor, if any consumer needs halo.
            if halo[1 + si] > 0 {
                self.exchange(1 + si, l.n_out, ho, wo, &mut tiles, &mut stats);
            }

            if let Some(obs) = observe.as_mut() {
                let fm = self.assemble(&tiles, 1 + si, l.n_out, ho, wo);
                obs(si, &fm);
            }
        }

        // Reassemble the final output.
        let (fc, fh, fw) = net.out_shape();
        let final_fm = self.assemble(&tiles, net.steps.len(), fc, fh, fw);
        assert!(stats.flags.is_quiescent(), "unmatched border sends");
        (final_fm, stats)
    }

    /// Re-assemble a distributed tensor's owned tiles into one global FM.
    fn assemble(
        &self,
        tiles: &[HashMap<usize, ExtTile>],
        tensor: usize,
        c: usize,
        h: usize,
        w: usize,
    ) -> FeatureMap {
        let mut fm = FeatureMap::zeros(c, h, w);
        for r in 0..self.rows {
            for col in 0..self.cols {
                let t = &tiles[r * self.cols + col][&tensor];
                for ch in 0..c {
                    for gy in t.y0..t.y1 {
                        for gx in t.x0..t.x1 {
                            fm.set(ch, gy, gx, t.read(ch, gy as isize, gx as isize));
                        }
                    }
                }
            }
        }
        fm
    }

    /// The send-once border/corner exchange for one tensor (§V-B).
    fn exchange(
        &self,
        tensor: usize,
        channels: usize,
        gh: usize,
        gw: usize,
        tiles: &mut [HashMap<usize, ExtTile>],
        stats: &mut MeshStats,
    ) {
        let idx = |r: usize, c: usize| r * self.cols + c;
        // Collect sends: (dst_chip, ch, gy, gx, value, hops).
        let mut sends: Vec<(usize, usize, isize, isize, f32, u32)> = Vec::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                let t = &tiles[idx(r, c)][&tensor];
                let (y0, y1, x0, x1) = (t.y0, t.y1, t.x0, t.x1);
                for ch in 0..channels {
                    // Direct borders: N/S rows, W/E cols.
                    if r > 0 {
                        for gx in x0..x1 {
                            sends.push((idx(r - 1, c), ch, y0 as isize, gx as isize,
                                        t.read(ch, y0 as isize, gx as isize), 1));
                        }
                    }
                    if r + 1 < self.rows {
                        for gx in x0..x1 {
                            sends.push((idx(r + 1, c), ch, y1 as isize - 1, gx as isize,
                                        t.read(ch, y1 as isize - 1, gx as isize), 1));
                        }
                    }
                    if c > 0 {
                        for gy in y0..y1 {
                            sends.push((idx(r, c - 1), ch, gy as isize, x0 as isize,
                                        t.read(ch, gy as isize, x0 as isize), 1));
                        }
                    }
                    if c + 1 < self.cols {
                        for gy in y0..y1 {
                            sends.push((idx(r, c + 1), ch, gy as isize, x1 as isize - 1,
                                        t.read(ch, gy as isize, x1 as isize - 1), 1));
                        }
                    }
                    // Corners: via the vertical neighbour (2 hops).
                    for (dr, dc) in [(-1isize, -1isize), (-1, 1), (1, -1), (1, 1)] {
                        let nr = r as isize + dr;
                        let nc = c as isize + dc;
                        if nr < 0 || nc < 0 || nr >= self.rows as isize || nc >= self.cols as isize
                        {
                            continue;
                        }
                        let gy = if dr < 0 { y0 as isize } else { y1 as isize - 1 };
                        let gx = if dc < 0 { x0 as isize } else { x1 as isize - 1 };
                        sends.push((
                            idx(nr as usize, nc as usize),
                            ch,
                            gy,
                            gx,
                            t.read(ch, gy, gx),
                            2,
                        ));
                        stats.flags.forwarded();
                    }
                }
            }
        }
        for (dst, ch, gy, gx, v, hops) in sends {
            // Fault injection: silently lose one transfer.
            let seq = stats.flags.completed + stats.flags.awaiting;
            if self.fault_drop_send == Some(seq) {
                continue;
            }
            stats.flags.sent();
            let bits = self.fm_bits as u64 * hops as u64;
            if hops == 1 {
                stats.border_bits += bits;
            } else {
                stats.corner_bits += bits;
            }
            stats.flits += link_flits(1, self.fm_bits) * hops as u64;
            let t = tiles[dst].get_mut(&tensor).expect("dst tile");
            // Only ring positions matter; interior duplicates are skipped
            // by construction (borders of the neighbour are our ring).
            let _ = (gh, gw);
            t.write_halo(ch, gy, gx, v);
            stats.flags.received();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bwn::pack_weights;
    use crate::model;
    use crate::network::{Network, TensorRef};
    use crate::simulator::chip::{run_layer, LayerParams};
    use crate::util::SplitMix64;

    fn random_params(net: &Network, seed: u64) -> Vec<StepParams> {
        let mut rng = SplitMix64::new(seed);
        net.steps
            .iter()
            .map(|s| {
                let l = &s.layer;
                let nie = l.n_in / l.groups;
                let w: Vec<f32> = (0..l.n_out * nie * l.k * l.k)
                    .map(|_| rng.next_sym())
                    .collect();
                // BWN-style scale α/fan-in keeps FP16 activations in
                // range over deep stacks (overflow → inf − inf = NaN).
                let fan_in = (nie * l.k * l.k) as f32;
                StepParams {
                    stream: pack_weights(l, &w, 16),
                    gamma: (0..l.n_out)
                        .map(|_| (0.25 + 0.5 * rng.next_f32()) / fan_in)
                        .collect(),
                    beta: (0..l.n_out).map(|_| 0.1 * rng.next_sym()).collect(),
                }
            })
            .collect()
    }

    fn single_chip_run(net: &Network, params: &[StepParams], input: &FeatureMap,
                       prec: Precision) -> FeatureMap {
        let mut outs: Vec<FeatureMap> = Vec::new();
        for (i, s) in net.steps.iter().enumerate() {
            let src = match s.src {
                TensorRef::Input => input,
                TensorRef::Step(j) => &outs[j],
            };
            let src = if let Some(cat) = s.concat_extra {
                let extra = match cat {
                    TensorRef::Input => input,
                    TensorRef::Step(j) => &outs[j],
                };
                src.concat_channels(extra)
            } else {
                src.clone()
            };
            let byp = s.bypass.map(|b| match b {
                TensorRef::Input => input.clone(),
                TensorRef::Step(j) => outs[j].clone(),
            });
            let lp = LayerParams {
                layer: &s.layer,
                stream: &params[i].stream,
                gamma: &params[i].gamma,
                beta: &params[i].beta,
            };
            let (o, _) = run_layer(&lp, &src, byp.as_ref(), prec, (7, 7));
            outs.push(o);
        }
        outs.pop().unwrap()
    }

    fn hypernet_input(seed: u64) -> FeatureMap {
        let mut rng = SplitMix64::new(seed);
        FeatureMap::from_vec(16, 32, 32, (0..16 * 32 * 32).map(|_| rng.next_sym()).collect())
    }

    #[test]
    fn mesh_2x2_matches_single_chip_bit_exactly_f16() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0xabcd);
        let input = hypernet_input(7);
        let single = single_chip_run(&net, &params, &input, Precision::F16);
        let mesh = MeshSim::new(2, 2, Precision::F16);
        let (out, stats) = mesh.run_network(&net, &params, &input);
        assert_eq!(out.max_abs_diff(&single), 0.0, "must be bit-exact");
        assert!(stats.border_bits > 0);
        assert!(stats.corner_bits > 0);
    }

    #[test]
    fn mesh_4x4_matches_single_chip() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0x1234);
        let input = hypernet_input(11);
        let single = single_chip_run(&net, &params, &input, Precision::F32);
        let mesh = MeshSim::new(4, 4, Precision::F32);
        let (out, _) = mesh.run_network(&net, &params, &input);
        assert_eq!(out.max_abs_diff(&single), 0.0);
    }

    #[test]
    fn asymmetric_mesh_matches() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0x777);
        let input = hypernet_input(3);
        let single = single_chip_run(&net, &params, &input, Precision::F16);
        let mesh = MeshSim::new(2, 4, Precision::F16);
        let (out, _) = mesh.run_network(&net, &params, &input);
        assert_eq!(out.max_abs_diff(&single), 0.0);
    }

    #[test]
    fn border_traffic_matches_coordinator_accounting() {
        // The functional exchange and the analytic Fig-11 accounting must
        // agree exactly (same rule: halo-consuming tensors only).
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0x99);
        let input = hypernet_input(5);
        let mesh = MeshSim::new(2, 2, Precision::F32);
        let (_, stats) = mesh.run_network(&net, &params, &input);
        let plan = crate::coordinator::tiling::MeshPlan {
            rows: 2,
            cols: 2,
            per_chip_wcl_words: 0,
        };
        let analytic = crate::coordinator::tiling::border_exchange_bits(&net, &plan, 16);
        assert_eq!(stats.border_bits + stats.corner_bits, analytic);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn indivisible_mesh_rejected() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 1);
        let input = hypernet_input(1);
        let mesh = MeshSim::new(3, 3, Precision::F32); // 32 % 3 != 0
        let _ = mesh.run_network(&net, &params, &input);
    }
}

//! Multi-chip systolic mesh simulator (§V): the whole network executed on
//! an m×n array of chips, each holding only its FM tile plus the border
//! and corner halos received from its neighbours.
//!
//! Every chip runs the *same* Tile-PU datapath kernel as the single-chip
//! simulator ([`super::datapath::run_tile`]) — only the memory front-end
//! differs (a halo-ringed `ExtTile` instead of a flat FM). Chips are
//! data-independent between exchange phases, exactly the paper's
//! execution model, so each step computes all chips concurrently on
//! scoped threads ([`MeshSim::threads`]) with a deterministic per-chip
//! reduction of the [`AccessCounts`].
//!
//! Protocol fidelity: halo pixels start as NaN and are only overwritten
//! by the exchange phase — any read of a pixel that was never exchanged
//! poisons the output and fails the bit-exactness check against the
//! single-chip reference. Corner pixels travel via the vertical
//! neighbour (two hops, no diagonal wires, §V-B). 2× nearest upsampling
//! (YOLOv3's FPN laterals) is free pixel replication inside each chip's
//! owned tile; the upsampled tensor's halo ring is NaN again and is
//! re-exchanged before any halo-consuming read.

use std::collections::HashMap;
use std::fmt;

use crate::bwn::{PackedLayerWeights, WeightStream};
use crate::coordinator::border::{link_flits, ExchangeFlags};
use crate::network::{ConvLayer, Network, TensorRef};

use super::chip::{AccessCounts, Precision};
use super::datapath::{self, InputSurface, TileGeom};
use super::fm::FeatureMap;

/// Per-layer parameters for the mesh run (same content as
/// [`super::chip::LayerParams`], owned per step).
#[derive(Clone)]
pub struct StepParams {
    pub stream: WeightStream,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
}

/// Aggregate traffic statistics of a mesh run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MeshStats {
    /// Bits exchanged over direct (N/S/E/W) links for borders.
    pub border_bits: u64,
    /// Bits for corner pixels (counted per hop; two hops each).
    pub corner_bits: u64,
    /// 4-bit link flits total (border interface serialization, §V-D).
    pub flits: u64,
    /// Input distribution bits (tiles + initial halo; not exchange).
    pub input_bits: u64,
    /// Exchange protocol flags, aggregated over chips.
    pub flags: ExchangeFlags,
    /// Per-chip FMM/WBuf/stream traffic summed over all chips and steps
    /// — produced by the same shared-kernel counters as the single-chip
    /// simulator's (Fig 10 / Tbl II source of truth). Reads that cross
    /// a *chip* boundary (halo reads) count as `neighbor_reads`, and
    /// every chip streams the full weight set (the broadcast of §V), so
    /// `stream_words` scales with the chip count.
    pub access: AccessCounts,
}

/// Typed failures of a mesh run — replacing the former `expect`-style
/// process aborts on missing tiles and mis-sized parameter lists, so
/// the engine can surface them as [`crate::engine::EngineError`]s.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MeshError {
    /// Chip `(row, col)` needed tensor id `tensor` as `role` (src /
    /// concat / bypass / halo destination) but never received it — a
    /// scheduling bug, since tiles are produced in step order.
    MissingTile {
        chip: (usize, usize),
        tensor: usize,
        role: &'static str,
    },
    /// One [`StepParams`] per network step is required.
    ParamsMismatch { params: usize, steps: usize },
    /// Chip `(row, col)` died before executing step `step` (injected via
    /// [`crate::faults::FaultPlan`]): its tile is gone and the step
    /// cannot complete. A real deployment would re-shard around it; the
    /// simulator surfaces the typed loss instead of silently-wrong pixels.
    ChipDead { chip: (usize, usize), step: usize },
    /// A halo border transfer into chip `(row, col)` failed its parity
    /// check: the payload was corrupted in flight. Detected — never
    /// applied to the feature map.
    CorruptExchange { chip: (usize, usize), tensor: usize },
}

impl fmt::Display for MeshError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MeshError::MissingTile { chip, tensor, role } => write!(
                f,
                "chip ({}, {}) has no tile for tensor {tensor} ({role})",
                chip.0, chip.1
            ),
            MeshError::ParamsMismatch { params, steps } => write!(
                f,
                "{params} step parameter sets for a {steps}-step network"
            ),
            MeshError::ChipDead { chip, step } => write!(
                f,
                "chip ({}, {}) died before step {step}",
                chip.0, chip.1
            ),
            MeshError::CorruptExchange { chip, tensor } => write!(
                f,
                "halo transfer of tensor {tensor} into chip ({}, {}) failed its checksum",
                chip.0, chip.1
            ),
        }
    }
}

impl std::error::Error for MeshError {}

/// One chip's view of one tensor: its owned tile extended by a 1-pixel
/// halo ring (NaN until received; zero where outside the global FM).
struct ExtTile {
    /// Owned global region `[y0, y1) × [x0, x1)`.
    y0: usize,
    y1: usize,
    x0: usize,
    x1: usize,
    /// Data covering `[y0-1, y1+1) × [x0-1, x1+1)` in global coords.
    data: FeatureMap,
}

impl ExtTile {
    fn new(c: usize, y0: usize, y1: usize, x0: usize, x1: usize, gh: usize, gw: usize) -> Self {
        let mut data = FeatureMap::zeros(c, y1 - y0 + 2, x1 - x0 + 2);
        // Ring: NaN inside the FM (must be exchanged), 0 outside (padding).
        for ch in 0..c {
            for ly in 0..data.h {
                for lx in 0..data.w {
                    let gy = y0 as isize + ly as isize - 1;
                    let gx = x0 as isize + lx as isize - 1;
                    let owned = gy >= y0 as isize
                        && gy < y1 as isize
                        && gx >= x0 as isize
                        && gx < x1 as isize;
                    let inside = gy >= 0 && gx >= 0 && (gy as usize) < gh && (gx as usize) < gw;
                    if !owned {
                        data.set(ch, ly, lx, if inside { f32::NAN } else { 0.0 });
                    }
                }
            }
        }
        ExtTile {
            y0,
            y1,
            x0,
            x1,
            data,
        }
    }

    /// Translate a global coordinate into the tile+halo-local flat
    /// pixel index, asserting it is inside the window — shared by the
    /// scalar `read` and the bulk `gather` so the bounds rule cannot
    /// diverge between them.
    #[inline]
    fn local_pixel(&self, gy: isize, gx: isize) -> usize {
        let ly = gy - self.y0 as isize + 1;
        let lx = gx - self.x0 as isize + 1;
        assert!(
            ly >= 0 && lx >= 0 && (ly as usize) < self.data.h && (lx as usize) < self.data.w,
            "read outside tile+halo: global ({gy},{gx}) for tile y[{},{}) x[{},{})",
            self.y0,
            self.y1,
            self.x0,
            self.x1
        );
        ly as usize * self.data.w + lx as usize
    }

    #[inline]
    fn read(&self, c: usize, gy: isize, gx: isize) -> f32 {
        let base = self.local_pixel(gy, gx);
        self.data.data[c * self.data.h * self.data.w + base]
    }

    #[inline]
    fn write_own(&mut self, c: usize, gy: usize, gx: usize, v: f32) {
        self.data
            .set(c, gy - self.y0 + 1, gx - self.x0 + 1, v);
    }

    /// Write a received halo pixel (global coords on the ring).
    #[inline]
    fn write_halo(&mut self, c: usize, gy: isize, gx: isize, v: f32) {
        let ly = (gy - self.y0 as isize + 1) as usize;
        let lx = (gx - self.x0 as isize + 1) as usize;
        self.data.set(c, ly, lx, v);
    }

    /// 2× nearest-neighbour upsample of the owned region (YOLOv3 FPN
    /// laterals). Replication is free on the chip (DDU addressing), so
    /// no traffic is counted; the halo ring of the result is NaN again
    /// and must be re-exchanged before any halo-consuming read.
    fn upsample2x(&self, c: usize, gh: usize, gw: usize) -> ExtTile {
        let mut up = ExtTile::new(c, 2 * self.y0, 2 * self.y1, 2 * self.x0, 2 * self.x1,
                                  2 * gh, 2 * gw);
        for ch in 0..c {
            for gy in 2 * self.y0..2 * self.y1 {
                for gx in 2 * self.x0..2 * self.x1 {
                    up.write_own(ch, gy, gx, self.read(ch, (gy / 2) as isize, (gx / 2) as isize));
                }
            }
        }
        up
    }
}

impl InputSurface for ExtTile {
    #[inline]
    fn read(&self, ch: usize, gy: isize, gx: isize) -> f32 {
        ExtTile::read(self, ch, gy, gx)
    }

    /// Fast staging path: translate the global coordinate (and run the
    /// tile+halo bounds check) once, then stream the channel plane.
    #[inline]
    fn gather(&self, ch0: usize, ch1: usize, gy: isize, gx: isize, out: &mut [f32]) {
        let base = self.local_pixel(gy, gx);
        let plane = self.data.h * self.data.w;
        for (slot, ch) in out.iter_mut().zip(ch0..ch1) {
            *slot = self.data.data[ch * plane + base];
        }
    }
}

/// One chip's conv-input view for a step: the `src` tile, extended
/// channel-wise by the optional `concat_extra` tile (YOLOv3's FPN
/// merges — concatenation is free on the chip, the tensors simply
/// occupy adjacent FMM segments).
struct ChipInput<'a> {
    src: &'a ExtTile,
    cat: Option<&'a ExtTile>,
    src_c: usize,
}

impl InputSurface for ChipInput<'_> {
    #[inline]
    fn read(&self, ch: usize, gy: isize, gx: isize) -> f32 {
        if ch < self.src_c {
            self.src.read(ch, gy, gx)
        } else {
            // Presence is validated before compute starts (MissingTile).
            self.cat
                .expect("concat tile validated per step")
                .read(ch - self.src_c, gy, gx)
        }
    }

    /// Split the requested channel range at the src/concat seam and
    /// forward to the tiles' fast gathers.
    #[inline]
    fn gather(&self, ch0: usize, ch1: usize, gy: isize, gx: isize, out: &mut [f32]) {
        let n_src = self.src_c.min(ch1).saturating_sub(ch0);
        if n_src > 0 {
            self.src.gather(ch0, ch0 + n_src, gy, gx, &mut out[..n_src]);
        }
        if ch0 + n_src < ch1 {
            let cat = self.cat.expect("concat tile validated per step");
            cat.gather(
                ch0.max(self.src_c) - self.src_c,
                ch1 - self.src_c,
                gy,
                gx,
                &mut out[n_src..],
            );
        }
    }
}

/// Everything one chip needs to compute its output tile of one step —
/// collected (and validated) up front so the compute fan-out is
/// infallible and borrows `tiles` only immutably.
struct ChipJob<'a> {
    idx: usize,
    oy0: usize,
    oy1: usize,
    ox0: usize,
    ox1: usize,
    input: ChipInput<'a>,
    byp: Option<&'a ExtTile>,
}

/// [`ChipJob`] for a micro-batch: the same owned output rectangle, but
/// one validated input view (and optional bypass tile) per resident
/// image.
struct ChipBatchJob<'a> {
    idx: usize,
    oy0: usize,
    oy1: usize,
    ox0: usize,
    ox1: usize,
    inputs: Vec<ChipInput<'a>>,
    byps: Option<Vec<&'a ExtTile>>,
}

/// Global coordinates of the 1-pixel halo ring around a tile.
fn ring_coords(
    y0: usize,
    y1: usize,
    x0: usize,
    x1: usize,
) -> impl Iterator<Item = (isize, isize)> {
    let (y0, y1, x0, x1) = (y0 as isize, y1 as isize, x0 as isize, x1 as isize);
    let top = (x0 - 1..=x1).map(move |x| (y0 - 1, x));
    let bottom = (x0 - 1..=x1).map(move |x| (y1, x));
    let left = (y0..y1).map(move |y| (y, x0 - 1));
    let right = (y0..y1).map(move |y| (y, x1));
    top.chain(bottom).chain(left).chain(right)
}

/// Half-open pixel rectangle `(y0, y1, x0, x1)` in global FM coords.
pub type Rect = (usize, usize, usize, usize);

/// Dirty work for one step of a video frame, on two grids: the conv
/// output grid (what the Tile-PUs recompute) and the stored tensor grid
/// (doubled when the step upsamples — what the resident tile refreshes).
#[derive(Debug, Clone, Default)]
pub struct VideoStepPlan {
    pub conv_rects: Vec<Rect>,
    pub out_rects: Vec<Rect>,
}

/// Dirty-region work list for one video frame, built by
/// [`crate::video::FrameSession`] from its per-tensor dirty maps — the
/// simulator stays agnostic of how dirtiness is tracked and only
/// executes rectangles.
#[derive(Debug, Clone, Default)]
pub struct VideoFramePlan {
    /// Dirty input rects to refresh (tiles + halo ring positions).
    pub input_rects: Vec<Rect>,
    /// One entry per network step.
    pub steps: Vec<VideoStepPlan>,
}

/// Resident per-chip state carried between frames of a video session:
/// every tensor's distributed tiles stay on-chip (the paper's
/// stationary-FM principle extended across time), so a frame only pays
/// for what changed.
pub struct MeshVideoState {
    /// (chip → tensor id → tile), exactly the store a full run builds.
    tiles: Vec<HashMap<usize, ExtTile>>,
    /// Pre-upsample conv tiles for upsampling steps (keyed `1 + si`):
    /// the incremental path regenerates dirty upsampled pixels from
    /// these instead of rebuilding the tile (whose fresh NaN halo ring
    /// clean neighbours would never refill).
    conv: Vec<HashMap<usize, ExtTile>>,
    /// Access counts of one full frame — the savings baseline.
    full_access: AccessCounts,
    /// Consumer halo per tensor id, precomputed at init.
    halo: Vec<usize>,
}

fn isect(r: Rect, y0: usize, y1: usize, x0: usize, x1: usize) -> Option<Rect> {
    let (a, b) = (r.0.max(y0), r.1.min(y1));
    let (c, d) = (r.2.max(x0), r.3.min(x1));
    (a < b && c < d).then_some((a, b, c, d))
}

/// The mesh simulator.
pub struct MeshSim {
    pub rows: usize,
    pub cols: usize,
    pub prec: Precision,
    pub fm_bits: usize,
    /// Each chip's internal M×N Tile-PU grid (neighbour-read
    /// accounting; the taped-out chip is 7×7).
    pub tiles_mn: (usize, usize),
    /// Worker threads for the per-step chip fan-out (`0` = one per
    /// available core). Results and statistics are bit-identical at any
    /// value; defaults to 1.
    pub threads: usize,
    /// Fault injection: drop the Nth border send of the whole run (the
    /// NaN-poisoned halo then propagates to the output — used to verify
    /// the protocol checking actually bites).
    pub fault_drop_send: Option<u64>,
    /// Seeded fault plan: per-step chip death (decision index
    /// `step * rows * cols + chip`) and in-flight halo corruption
    /// (decision index = the quiescent-flag transfer sequence, the same
    /// numbering `fault_drop_send` uses). `None` injects nothing.
    pub faults: Option<std::sync::Arc<crate::faults::FaultPlan>>,
}

impl MeshSim {
    pub fn new(rows: usize, cols: usize, prec: Precision) -> Self {
        MeshSim {
            rows,
            cols,
            prec,
            fm_bits: 16,
            tiles_mn: (7, 7),
            threads: 1,
            fault_drop_send: None,
            faults: None,
        }
    }

    /// Does the chip at linear index `idx` die before step `si`?
    fn chip_dies(&self, si: usize, idx: usize) -> bool {
        self.faults
            .as_ref()
            .is_some_and(|p| p.chip_death((si * self.rows * self.cols + idx) as u64))
    }

    fn bounds(&self, dim: usize, parts: usize, i: usize) -> (usize, usize) {
        assert_eq!(
            dim % parts,
            0,
            "mesh simulator requires FM dims divisible by the mesh ({dim} % {parts})"
        );
        let t = dim / parts;
        (i * t, (i + 1) * t)
    }

    /// Run a whole network on the mesh. `params[i]` belongs to step `i`.
    /// Returns the re-assembled final FM and the traffic statistics.
    pub fn run_network(
        &self,
        net: &Network,
        params: &[StepParams],
        input: &FeatureMap,
    ) -> Result<(FeatureMap, MeshStats), MeshError> {
        self.run_network_observed(net, params, input, None)
    }

    /// [`Self::run_network`] with a per-step observer: after each step
    /// (and its exchange phase) the observer receives the step index and
    /// the re-assembled global output FM — the engine's trace hook.
    pub fn run_network_traced(
        &self,
        net: &Network,
        params: &[StepParams],
        input: &FeatureMap,
        observe: &mut dyn FnMut(usize, &FeatureMap),
    ) -> Result<(FeatureMap, MeshStats), MeshError> {
        self.run_network_observed(net, params, input, Some(observe))
    }

    fn run_network_observed(
        &self,
        net: &Network,
        params: &[StepParams],
        input: &FeatureMap,
        mut observe: Option<&mut dyn FnMut(usize, &FeatureMap)>,
    ) -> Result<(FeatureMap, MeshStats), MeshError> {
        if params.len() != net.steps.len() {
            return Err(MeshError::ParamsMismatch {
                params: params.len(),
                steps: net.steps.len(),
            });
        }
        let mut stats = MeshStats::default();

        // Consumer halo per tensor (0 → no exchange needed).
        let n = net.steps.len();
        let tid = |r: TensorRef| match r {
            TensorRef::Input => 0usize,
            TensorRef::Step(i) => 1 + i,
        };
        let mut halo = vec![0usize; n + 1];
        for s in &net.steps {
            let h = s.layer.k / 2;
            for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
                halo[tid(r)] = halo[tid(r)].max(h);
            }
        }

        // Per-chip tensor store: (row, col) → tensor id → ExtTile.
        let mut tiles: Vec<HashMap<usize, ExtTile>> =
            (0..self.rows * self.cols).map(|_| HashMap::new()).collect();

        // Distribute the input: owned tile + pre-filled halo ring (the
        // halo arrives as part of the input load, §V).
        let (ic, ih, iw) = (net.in_ch, net.in_h, net.in_w);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let (y0, y1) = self.bounds(ih, self.rows, r);
                let (x0, x1) = self.bounds(iw, self.cols, c);
                let mut t = ExtTile::new(ic, y0, y1, x0, x1, ih, iw);
                for ch in 0..ic {
                    for gy in y0..y1 {
                        for gx in x0..x1 {
                            t.write_own(ch, gy, gx, input.get(ch, gy, gx));
                        }
                    }
                }
                // Pre-fill the ring from the global input.
                if halo[0] > 0 {
                    for ch in 0..ic {
                        for (gy, gx) in ring_coords(y0, y1, x0, x1) {
                            if gy >= 0 && gx >= 0 && (gy as usize) < ih && (gx as usize) < iw {
                                t.write_halo(ch, gy, gx, input.get(ch, gy as usize, gx as usize));
                                stats.input_bits += self.fm_bits as u64;
                            }
                        }
                    }
                }
                stats.input_bits += (ic * (y1 - y0) * (x1 - x0) * self.fm_bits) as u64;
                tiles[r * self.cols + c].insert(0, t);
            }
        }

        // Execute steps.
        for (si, step) in net.steps.iter().enumerate() {
            let l = &step.layer;
            let p = &params[si];
            let (ho, wo) = (l.h_out(), l.w_out());
            let src_id = tid(step.src);
            let byp_id = step.bypass.map(tid);
            let cat_id = step.concat_extra.map(tid);
            let (src_c, _, _) = net.shape_of(step.src);
            // One sign-mask expansion per mesh step, shared by every
            // chip of the broadcast (§V: same weights on all chips).
            let pw = PackedLayerWeights::new(&p.stream);
            let pw = &pw;

            // Collect each chip's validated inputs, then compute all
            // chips concurrently — they are data-independent between
            // exchange phases (§V execution model). Results come back
            // in chip index order, so the stats reduction and the tile
            // inserts are deterministic at any thread count.
            let results: Vec<(usize, ExtTile, AccessCounts)> = {
                let mut jobs = Vec::with_capacity(self.rows * self.cols);
                for r in 0..self.rows {
                    for c in 0..self.cols {
                        let idx = r * self.cols + c;
                        if self.chip_dies(si, idx) {
                            return Err(MeshError::ChipDead { chip: (r, c), step: si });
                        }
                        let chip = &tiles[idx];
                        let src = chip.get(&src_id).ok_or(MeshError::MissingTile {
                            chip: (r, c),
                            tensor: src_id,
                            role: "src",
                        })?;
                        let cat = match cat_id {
                            Some(t) => Some(chip.get(&t).ok_or(MeshError::MissingTile {
                                chip: (r, c),
                                tensor: t,
                                role: "concat",
                            })?),
                            None => None,
                        };
                        let byp = match byp_id {
                            Some(t) => Some(chip.get(&t).ok_or(MeshError::MissingTile {
                                chip: (r, c),
                                tensor: t,
                                role: "bypass",
                            })?),
                            None => None,
                        };
                        let (oy0, oy1) = self.bounds(ho, self.rows, r);
                        let (ox0, ox1) = self.bounds(wo, self.cols, c);
                        jobs.push(ChipJob {
                            idx,
                            oy0,
                            oy1,
                            ox0,
                            ox1,
                            input: ChipInput { src, cat, src_c },
                            byp,
                        });
                    }
                }
                let workers = datapath::resolve_threads(self.threads)
                    .max(1)
                    .min(jobs.len());
                if workers <= 1 {
                    jobs.iter()
                        .map(|j| self.compute_chip(j, l, p, pw, step.upsample2x, ho, wo))
                        .collect()
                } else {
                    // Balanced chip chunks (⌊n/w⌋ or ⌈n/w⌉ per worker),
                    // like the single-chip channel fan-out.
                    let ranges = datapath::partition_ranges(jobs.len(), workers);
                    std::thread::scope(|s| {
                        let handles: Vec<_> = ranges
                            .iter()
                            .map(|&(a, b)| {
                                let chunk = &jobs[a..b];
                                s.spawn(move || {
                                    chunk
                                        .iter()
                                        .map(|j| {
                                            self.compute_chip(j, l, p, pw, step.upsample2x, ho, wo)
                                        })
                                        .collect::<Vec<_>>()
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .flat_map(|h| h.join().expect("mesh worker panicked"))
                            .collect()
                    })
                }
            };
            for (idx, tile, acc) in results {
                stats.access.add(&acc);
                tiles[idx].insert(1 + si, tile);
            }

            // Exchange phase (on the possibly upsampled tensor), if any
            // consumer needs halo.
            let (oc, oh, ow) = net.shape_of(TensorRef::Step(si));
            if halo[1 + si] > 0 {
                self.exchange(1 + si, oc, &mut tiles, &mut stats)?;
            }

            if let Some(obs) = observe.as_mut() {
                let fm = self.assemble(&tiles, 1 + si, oc, oh, ow)?;
                obs(si, &fm);
            }
        }

        // Reassemble the final output.
        let (fc, fh, fw) = net.out_shape();
        let final_fm = self.assemble(&tiles, net.steps.len(), fc, fh, fw)?;
        assert!(stats.flags.is_quiescent(), "unmatched border sends");
        Ok((final_fm, stats))
    }

    /// Run a whole network on the mesh for a micro-batch of `B` images
    /// held resident simultaneously: every chip keeps `B` tile sets of
    /// each tensor, and each step broadcasts the weight stream **once
    /// per chip per batch** ([`datapath::run_tile_batch`]), so
    /// `MeshStats::access::stream_words` is 1/B of `B` sequential
    /// [`Self::run_network`] calls. Per-image outputs are bit-identical
    /// to the sequential runs (each image's rounding chains are
    /// untouched by batching); halo exchange and input distribution
    /// happen per image — activations are per-image state, only the
    /// weight traffic amortizes.
    pub fn run_network_batch(
        &self,
        net: &Network,
        params: &[StepParams],
        inputs: &[&FeatureMap],
    ) -> Result<(Vec<FeatureMap>, MeshStats), MeshError> {
        if params.len() != net.steps.len() {
            return Err(MeshError::ParamsMismatch {
                params: params.len(),
                steps: net.steps.len(),
            });
        }
        let b = inputs.len();
        let mut stats = MeshStats::default();
        if b == 0 {
            return Ok((Vec::new(), stats));
        }

        let n = net.steps.len();
        let tid = |r: TensorRef| match r {
            TensorRef::Input => 0usize,
            TensorRef::Step(i) => 1 + i,
        };
        let mut halo = vec![0usize; n + 1];
        for s in &net.steps {
            let h = s.layer.k / 2;
            for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
                halo[tid(r)] = halo[tid(r)].max(h);
            }
        }

        // Per-image, per-chip tensor stores: B resident tile sets.
        let mut tiles: Vec<Vec<HashMap<usize, ExtTile>>> = (0..b)
            .map(|_| (0..self.rows * self.cols).map(|_| HashMap::new()).collect())
            .collect();

        // Distribute every image (input loading is per-image traffic).
        let (ic, ih, iw) = (net.in_ch, net.in_h, net.in_w);
        for (bi, input) in inputs.iter().enumerate() {
            for r in 0..self.rows {
                for c in 0..self.cols {
                    let (y0, y1) = self.bounds(ih, self.rows, r);
                    let (x0, x1) = self.bounds(iw, self.cols, c);
                    let mut t = ExtTile::new(ic, y0, y1, x0, x1, ih, iw);
                    for ch in 0..ic {
                        for gy in y0..y1 {
                            for gx in x0..x1 {
                                t.write_own(ch, gy, gx, input.get(ch, gy, gx));
                            }
                        }
                    }
                    if halo[0] > 0 {
                        for ch in 0..ic {
                            for (gy, gx) in ring_coords(y0, y1, x0, x1) {
                                if gy >= 0 && gx >= 0 && (gy as usize) < ih && (gx as usize) < iw
                                {
                                    t.write_halo(
                                        ch,
                                        gy,
                                        gx,
                                        input.get(ch, gy as usize, gx as usize),
                                    );
                                    stats.input_bits += self.fm_bits as u64;
                                }
                            }
                        }
                    }
                    stats.input_bits += (ic * (y1 - y0) * (x1 - x0) * self.fm_bits) as u64;
                    tiles[bi][r * self.cols + c].insert(0, t);
                }
            }
        }

        for (si, step) in net.steps.iter().enumerate() {
            let l = &step.layer;
            let p = &params[si];
            let (ho, wo) = (l.h_out(), l.w_out());
            let src_id = tid(step.src);
            let byp_id = step.bypass.map(tid);
            let cat_id = step.concat_extra.map(tid);
            let (src_c, _, _) = net.shape_of(step.src);
            // One sign-mask expansion per mesh step, shared by every
            // chip and every batch slot of the broadcast.
            let pw = PackedLayerWeights::new(&p.stream);
            let pw = &pw;

            let results: Vec<(usize, Vec<ExtTile>, AccessCounts)> = {
                let mut jobs = Vec::with_capacity(self.rows * self.cols);
                for r in 0..self.rows {
                    for c in 0..self.cols {
                        let idx = r * self.cols + c;
                        if self.chip_dies(si, idx) {
                            return Err(MeshError::ChipDead { chip: (r, c), step: si });
                        }
                        let mut ins = Vec::with_capacity(b);
                        let mut byps = byp_id.map(|_| Vec::with_capacity(b));
                        for img in tiles.iter() {
                            let chip = &img[idx];
                            let src = chip.get(&src_id).ok_or(MeshError::MissingTile {
                                chip: (r, c),
                                tensor: src_id,
                                role: "src",
                            })?;
                            let cat = match cat_id {
                                Some(t) => Some(chip.get(&t).ok_or(MeshError::MissingTile {
                                    chip: (r, c),
                                    tensor: t,
                                    role: "concat",
                                })?),
                                None => None,
                            };
                            if let (Some(t), Some(list)) = (byp_id, byps.as_mut()) {
                                list.push(chip.get(&t).ok_or(MeshError::MissingTile {
                                    chip: (r, c),
                                    tensor: t,
                                    role: "bypass",
                                })?);
                            }
                            ins.push(ChipInput { src, cat, src_c });
                        }
                        let (oy0, oy1) = self.bounds(ho, self.rows, r);
                        let (ox0, ox1) = self.bounds(wo, self.cols, c);
                        jobs.push(ChipBatchJob {
                            idx,
                            oy0,
                            oy1,
                            ox0,
                            ox1,
                            inputs: ins,
                            byps,
                        });
                    }
                }
                let workers = datapath::resolve_threads(self.threads)
                    .max(1)
                    .min(jobs.len());
                if workers <= 1 {
                    jobs.iter()
                        .map(|j| self.compute_chip_batch(j, l, p, pw, step.upsample2x, ho, wo))
                        .collect()
                } else {
                    let ranges = datapath::partition_ranges(jobs.len(), workers);
                    std::thread::scope(|s| {
                        let handles: Vec<_> = ranges
                            .iter()
                            .map(|&(a, z)| {
                                let chunk = &jobs[a..z];
                                s.spawn(move || {
                                    chunk
                                        .iter()
                                        .map(|j| {
                                            self.compute_chip_batch(
                                                j,
                                                l,
                                                p,
                                                pw,
                                                step.upsample2x,
                                                ho,
                                                wo,
                                            )
                                        })
                                        .collect::<Vec<_>>()
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .flat_map(|h| h.join().expect("mesh batch worker panicked"))
                            .collect()
                    })
                }
            };
            for (idx, outs, acc) in results {
                stats.access.add(&acc);
                for (bi, tile) in outs.into_iter().enumerate() {
                    tiles[bi][idx].insert(1 + si, tile);
                }
            }

            // Halo exchange stays per image: activations do not amortize.
            let (oc, _, _) = net.shape_of(TensorRef::Step(si));
            if halo[1 + si] > 0 {
                for img in tiles.iter_mut() {
                    self.exchange(1 + si, oc, img, &mut stats)?;
                }
            }
        }

        let (fc, fh, fw) = net.out_shape();
        let outs = tiles
            .iter()
            .map(|img| self.assemble(img, net.steps.len(), fc, fh, fw))
            .collect::<Result<Vec<_>, _>>()?;
        assert!(stats.flags.is_quiescent(), "unmatched border sends");
        Ok((outs, stats))
    }

    /// One chip's batched compute of one step: the shared batch kernel
    /// over the chip's `B` resident input views, streaming each weight
    /// block once for the whole batch.
    #[allow(clippy::too_many_arguments)]
    fn compute_chip_batch(
        &self,
        job: &ChipBatchJob<'_>,
        l: &ConvLayer,
        p: &StepParams,
        pw: &PackedLayerWeights,
        upsample: bool,
        ho: usize,
        wo: usize,
    ) -> (usize, Vec<ExtTile>, AccessCounts) {
        let b = job.inputs.len();
        let (m, n) = self.tiles_mn;
        let out_h = job.oy1 - job.oy0;
        let out_w = job.ox1 - job.ox0;
        let geom = TileGeom {
            oy0: job.oy0,
            oy1: job.oy1,
            ox0: job.ox0,
            ox1: job.ox1,
            iy0: (job.oy0 * l.stride) as isize,
            ix0: (job.ox0 * l.stride) as isize,
            tile_h: out_h.div_ceil(m).max(1),
            tile_w: out_w.div_ceil(n).max(1),
            in_tile_h: (out_h * l.stride).div_ceil(m).max(1),
            in_tile_w: (out_w * l.stride).div_ceil(n).max(1),
        };
        let mut outs: Vec<ExtTile> = (0..b)
            .map(|_| ExtTile::new(l.n_out, job.oy0, job.oy1, job.ox0, job.ox1, ho, wo))
            .collect();
        let ins: Vec<&dyn InputSurface> =
            job.inputs.iter().map(|i| i as &dyn InputSurface).collect();
        let byps: Option<Vec<&dyn InputSurface>> = job
            .byps
            .as_ref()
            .map(|bs| bs.iter().map(|t| *t as &dyn InputSurface).collect());
        let mut acc = {
            let mut write = |bi: usize, co: usize, gy: usize, gx: usize, v: f32| {
                outs[bi].write_own(co, gy, gx, v)
            };
            datapath::run_tile_batch(
                l,
                pw,
                &p.gamma,
                &p.beta,
                (0, l.n_out),
                &ins,
                byps.as_deref(),
                self.prec,
                &geom,
                &mut write,
            )
        };
        // The broadcast of §V, once per *batch*: each stream word then
        // serves B × tile_pixels pixels from the weight buffer.
        let tile_pixels = (geom.tile_h * geom.tile_w) as u64;
        let (sw, _) = datapath::weight_traffic(l, p.stream.c, tile_pixels);
        acc.stream_words += sw;
        acc.wbuf_reads += sw * ((b as u64 * tile_pixels).max(1) - 1);
        if upsample {
            outs = outs
                .iter()
                .map(|o| o.upsample2x(l.n_out, ho, wo))
                .collect();
        }
        (job.idx, outs, acc)
    }

    /// One chip's compute of one step: the shared datapath kernel over
    /// the chip's owned output tile, then the free 2× replication if the
    /// step upsamples. Infallible by construction (inputs validated by
    /// the caller), so it can run on any worker thread.
    #[allow(clippy::too_many_arguments)]
    fn compute_chip(
        &self,
        job: &ChipJob<'_>,
        l: &ConvLayer,
        p: &StepParams,
        pw: &PackedLayerWeights,
        upsample: bool,
        ho: usize,
        wo: usize,
    ) -> (usize, ExtTile, AccessCounts) {
        let (m, n) = self.tiles_mn;
        let out_h = job.oy1 - job.oy0;
        let out_w = job.ox1 - job.ox0;
        // The chip's owned input region starts at stride× its output
        // origin (spatial dims divide evenly over the mesh); its M×N
        // Tile-PU grid tiles the per-chip region, like the single-chip
        // geometry tiles the whole FM.
        let geom = TileGeom {
            oy0: job.oy0,
            oy1: job.oy1,
            ox0: job.ox0,
            ox1: job.ox1,
            iy0: (job.oy0 * l.stride) as isize,
            ix0: (job.ox0 * l.stride) as isize,
            tile_h: out_h.div_ceil(m).max(1),
            tile_w: out_w.div_ceil(n).max(1),
            in_tile_h: (out_h * l.stride).div_ceil(m).max(1),
            in_tile_w: (out_w * l.stride).div_ceil(n).max(1),
        };
        let mut out = ExtTile::new(l.n_out, job.oy0, job.oy1, job.ox0, job.ox1, ho, wo);
        let mut acc = {
            let mut write =
                |co: usize, gy: usize, gx: usize, v: f32| out.write_own(co, gy, gx, v);
            datapath::run_tile(
                l,
                pw,
                &p.gamma,
                &p.beta,
                (0, l.n_out),
                &job.input,
                job.byp,
                self.prec,
                &geom,
                &mut write,
            )
        };
        // Every chip streams the full weight set (broadcast, §V) and
        // re-reads it per pixel of its own Tile-PU tiles.
        let (sw, wb) = datapath::weight_traffic(l, p.stream.c, (geom.tile_h * geom.tile_w) as u64);
        acc.stream_words += sw;
        acc.wbuf_reads += wb;
        if upsample {
            out = out.upsample2x(l.n_out, ho, wo);
        }
        (job.idx, out, acc)
    }

    /// Re-assemble a distributed tensor's owned tiles into one global FM.
    fn assemble(
        &self,
        tiles: &[HashMap<usize, ExtTile>],
        tensor: usize,
        c: usize,
        h: usize,
        w: usize,
    ) -> Result<FeatureMap, MeshError> {
        let mut fm = FeatureMap::zeros(c, h, w);
        for r in 0..self.rows {
            for col in 0..self.cols {
                let t = tiles[r * self.cols + col]
                    .get(&tensor)
                    .ok_or(MeshError::MissingTile {
                        chip: (r, col),
                        tensor,
                        role: "assemble",
                    })?;
                for ch in 0..c {
                    for gy in t.y0..t.y1 {
                        for gx in t.x0..t.x1 {
                            fm.set(ch, gy, gx, t.read(ch, gy as isize, gx as isize));
                        }
                    }
                }
            }
        }
        Ok(fm)
    }

    /// The send-once border/corner exchange for one tensor (§V-B).
    fn exchange(
        &self,
        tensor: usize,
        channels: usize,
        tiles: &mut [HashMap<usize, ExtTile>],
        stats: &mut MeshStats,
    ) -> Result<(), MeshError> {
        self.exchange_from(tensor, channels, tiles, stats, None)
    }

    /// [`Self::exchange`] restricted to senders flagged in `from` (the
    /// video mode's incremental halo refresh): a chip that recomputed
    /// nothing this frame holds exactly the border values its
    /// neighbours already cached, so it sends nothing and their halos
    /// stay valid; a dirty chip resends all its borders and corners.
    /// `None` means every chip sends (the full per-image exchange).
    fn exchange_from(
        &self,
        tensor: usize,
        channels: usize,
        tiles: &mut [HashMap<usize, ExtTile>],
        stats: &mut MeshStats,
        from: Option<&[bool]>,
    ) -> Result<(), MeshError> {
        let idx = |r: usize, c: usize| r * self.cols + c;
        // Collect sends: (dst_chip, ch, gy, gx, value, hops).
        let mut sends: Vec<(usize, usize, isize, isize, f32, u32)> = Vec::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                if from.is_some_and(|f| !f[idx(r, c)]) {
                    continue;
                }
                let t = tiles[idx(r, c)]
                    .get(&tensor)
                    .ok_or(MeshError::MissingTile {
                        chip: (r, c),
                        tensor,
                        role: "exchange source",
                    })?;
                let (y0, y1, x0, x1) = (t.y0, t.y1, t.x0, t.x1);
                for ch in 0..channels {
                    // Direct borders: N/S rows, W/E cols.
                    if r > 0 {
                        for gx in x0..x1 {
                            sends.push((idx(r - 1, c), ch, y0 as isize, gx as isize,
                                        t.read(ch, y0 as isize, gx as isize), 1));
                        }
                    }
                    if r + 1 < self.rows {
                        for gx in x0..x1 {
                            sends.push((idx(r + 1, c), ch, y1 as isize - 1, gx as isize,
                                        t.read(ch, y1 as isize - 1, gx as isize), 1));
                        }
                    }
                    if c > 0 {
                        for gy in y0..y1 {
                            sends.push((idx(r, c - 1), ch, gy as isize, x0 as isize,
                                        t.read(ch, gy as isize, x0 as isize), 1));
                        }
                    }
                    if c + 1 < self.cols {
                        for gy in y0..y1 {
                            sends.push((idx(r, c + 1), ch, gy as isize, x1 as isize - 1,
                                        t.read(ch, gy as isize, x1 as isize - 1), 1));
                        }
                    }
                    // Corners: via the vertical neighbour (2 hops).
                    for (dr, dc) in [(-1isize, -1isize), (-1, 1), (1, -1), (1, 1)] {
                        let nr = r as isize + dr;
                        let nc = c as isize + dc;
                        if nr < 0 || nc < 0 || nr >= self.rows as isize || nc >= self.cols as isize
                        {
                            continue;
                        }
                        let gy = if dr < 0 { y0 as isize } else { y1 as isize - 1 };
                        let gx = if dc < 0 { x0 as isize } else { x1 as isize - 1 };
                        sends.push((
                            idx(nr as usize, nc as usize),
                            ch,
                            gy,
                            gx,
                            t.read(ch, gy, gx),
                            2,
                        ));
                        stats.flags.forwarded();
                    }
                }
            }
        }
        for (dst, ch, gy, gx, v, hops) in sends {
            // Fault injection: silently lose one transfer.
            let seq = stats.flags.completed + stats.flags.awaiting;
            if self.fault_drop_send == Some(seq) {
                continue;
            }
            // Sender stamps a parity checksum over the payload bits, then
            // the fault plan may corrupt the payload "in flight" (single
            // bit flip). The receiver verifies before applying.
            let csum = crate::faults::halo_checksum(v.to_bits());
            let v = match &self.faults {
                Some(plan) if plan.corrupt_exchange(seq) => f32::from_bits(v.to_bits() ^ 1),
                _ => v,
            };
            stats.flags.sent();
            let bits = self.fm_bits as u64 * hops as u64;
            if hops == 1 {
                stats.border_bits += bits;
            } else {
                stats.corner_bits += bits;
            }
            stats.flits += link_flits(1, self.fm_bits) * hops as u64;
            if crate::faults::halo_checksum(v.to_bits()) != csum {
                return Err(MeshError::CorruptExchange {
                    chip: (dst / self.cols, dst % self.cols),
                    tensor,
                });
            }
            let t = tiles[dst].get_mut(&tensor).ok_or(MeshError::MissingTile {
                chip: (dst / self.cols, dst % self.cols),
                tensor,
                role: "halo destination",
            })?;
            // Only ring positions matter; interior duplicates are skipped
            // by construction (borders of the neighbour are our ring).
            t.write_halo(ch, gy, gx, v);
            stats.flags.received();
        }
        Ok(())
    }

    /// First frame of a video session: one full mesh run that *retains*
    /// every chip's resident tiles (plus, for upsampling steps, the
    /// pre-upsample conv tile the incremental regeneration reads from)
    /// and records the full-frame [`AccessCounts`] later frames report
    /// their savings against. Single-threaded — video sessions trade
    /// per-frame fan-out for cross-frame reuse, and determinism is free.
    pub fn video_init(
        &self,
        net: &Network,
        params: &[StepParams],
        input: &FeatureMap,
    ) -> Result<(FeatureMap, MeshStats, MeshVideoState), MeshError> {
        if params.len() != net.steps.len() {
            return Err(MeshError::ParamsMismatch {
                params: params.len(),
                steps: net.steps.len(),
            });
        }
        let mut stats = MeshStats::default();
        let n = net.steps.len();
        let tid = |r: TensorRef| match r {
            TensorRef::Input => 0usize,
            TensorRef::Step(i) => 1 + i,
        };
        let mut halo = vec![0usize; n + 1];
        for s in &net.steps {
            let h = s.layer.k / 2;
            for r in std::iter::once(s.src).chain(s.bypass).chain(s.concat_extra) {
                halo[tid(r)] = halo[tid(r)].max(h);
            }
        }

        let nchips = self.rows * self.cols;
        let mut tiles: Vec<HashMap<usize, ExtTile>> =
            (0..nchips).map(|_| HashMap::new()).collect();
        let mut conv: Vec<HashMap<usize, ExtTile>> =
            (0..nchips).map(|_| HashMap::new()).collect();

        // Distribute the input (same traffic accounting as a full run).
        let (ic, ih, iw) = (net.in_ch, net.in_h, net.in_w);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let (y0, y1) = self.bounds(ih, self.rows, r);
                let (x0, x1) = self.bounds(iw, self.cols, c);
                let mut t = ExtTile::new(ic, y0, y1, x0, x1, ih, iw);
                for ch in 0..ic {
                    for gy in y0..y1 {
                        for gx in x0..x1 {
                            t.write_own(ch, gy, gx, input.get(ch, gy, gx));
                        }
                    }
                }
                if halo[0] > 0 {
                    for ch in 0..ic {
                        for (gy, gx) in ring_coords(y0, y1, x0, x1) {
                            if gy >= 0 && gx >= 0 && (gy as usize) < ih && (gx as usize) < iw {
                                t.write_halo(ch, gy, gx, input.get(ch, gy as usize, gx as usize));
                                stats.input_bits += self.fm_bits as u64;
                            }
                        }
                    }
                }
                stats.input_bits += (ic * (y1 - y0) * (x1 - x0) * self.fm_bits) as u64;
                tiles[r * self.cols + c].insert(0, t);
            }
        }

        for (si, step) in net.steps.iter().enumerate() {
            let l = &step.layer;
            let p = &params[si];
            let (ho, wo) = (l.h_out(), l.w_out());
            let src_id = tid(step.src);
            let byp_id = step.bypass.map(tid);
            let cat_id = step.concat_extra.map(tid);
            let (src_c, _, _) = net.shape_of(step.src);
            let pw = PackedLayerWeights::new(&p.stream);

            let mut results: Vec<(usize, ExtTile, AccessCounts)> = Vec::with_capacity(nchips);
            for r in 0..self.rows {
                for c in 0..self.cols {
                    let idx = r * self.cols + c;
                    if self.chip_dies(si, idx) {
                        return Err(MeshError::ChipDead { chip: (r, c), step: si });
                    }
                    let chip = &tiles[idx];
                    let src = chip.get(&src_id).ok_or(MeshError::MissingTile {
                        chip: (r, c),
                        tensor: src_id,
                        role: "src",
                    })?;
                    let cat = match cat_id {
                        Some(t) => Some(chip.get(&t).ok_or(MeshError::MissingTile {
                            chip: (r, c),
                            tensor: t,
                            role: "concat",
                        })?),
                        None => None,
                    };
                    let byp = match byp_id {
                        Some(t) => Some(chip.get(&t).ok_or(MeshError::MissingTile {
                            chip: (r, c),
                            tensor: t,
                            role: "bypass",
                        })?),
                        None => None,
                    };
                    let (oy0, oy1) = self.bounds(ho, self.rows, r);
                    let (ox0, ox1) = self.bounds(wo, self.cols, c);
                    let job = ChipJob {
                        idx,
                        oy0,
                        oy1,
                        ox0,
                        ox1,
                        input: ChipInput { src, cat, src_c },
                        byp,
                    };
                    // Upsample handled below so the conv tile survives.
                    results.push(self.compute_chip(&job, l, p, &pw, false, ho, wo));
                }
            }
            for (idx, tile, acc) in results {
                stats.access.add(&acc);
                if step.upsample2x {
                    tiles[idx].insert(1 + si, tile.upsample2x(l.n_out, ho, wo));
                    conv[idx].insert(1 + si, tile);
                } else {
                    tiles[idx].insert(1 + si, tile);
                }
            }

            let (oc, _, _) = net.shape_of(TensorRef::Step(si));
            if halo[1 + si] > 0 {
                self.exchange(1 + si, oc, &mut tiles, &mut stats)?;
            }
        }

        let (fc, fh, fw) = net.out_shape();
        let final_fm = self.assemble(&tiles, n, fc, fh, fw)?;
        assert!(stats.flags.is_quiescent(), "unmatched border sends");
        let state = MeshVideoState {
            tiles,
            conv,
            full_access: stats.access,
            halo,
        };
        Ok((final_fm, stats, state))
    }

    /// One incremental video frame: refresh dirty input pixels, recompute
    /// each chip's owned slice of every dirty conv rectangle *in place*
    /// into its resident tile (clean pixels — and the halo ring — keep
    /// last frame's bit-exact values), regenerate dirty upsampled pixels
    /// from the cached conv tile, and re-exchange borders only from
    /// chips that recomputed something. `effective` is the session's
    /// effective input (last frame's values outside `plan.input_rects`),
    /// so resident tiles stay consistent with what the dirty maps were
    /// diffed against. The returned stats carry this frame's actual
    /// traffic with `saved_*` measured against the full-frame baseline.
    pub fn video_step(
        &self,
        net: &Network,
        params: &[StepParams],
        state: &mut MeshVideoState,
        effective: &FeatureMap,
        plan: &VideoFramePlan,
    ) -> Result<(FeatureMap, MeshStats), MeshError> {
        if params.len() != net.steps.len() {
            return Err(MeshError::ParamsMismatch {
                params: params.len(),
                steps: net.steps.len(),
            });
        }
        assert_eq!(plan.steps.len(), net.steps.len(), "plan/steps mismatch");
        let mut stats = MeshStats::default();
        let tid = |r: TensorRef| match r {
            TensorRef::Input => 0usize,
            TensorRef::Step(i) => 1 + i,
        };
        let nchips = self.rows * self.cols;
        let (m, n_pu) = self.tiles_mn;

        // Refresh dirty input pixels (owned + halo-ring positions); only
        // the refreshed pixels cost input-distribution traffic.
        let (ic, ih, iw) = (net.in_ch, net.in_h, net.in_w);
        if !plan.input_rects.is_empty() {
            for r in 0..self.rows {
                for c in 0..self.cols {
                    let idx = r * self.cols + c;
                    let (y0, y1) = self.bounds(ih, self.rows, r);
                    let (x0, x1) = self.bounds(iw, self.cols, c);
                    let t = tiles_get_mut(&mut state.tiles, idx, 0, (r, c), "video input")?;
                    for &rect in &plan.input_rects {
                        let Some((a, b, cx, d)) = isect(rect, y0, y1, x0, x1) else {
                            continue;
                        };
                        for ch in 0..ic {
                            for gy in a..b {
                                for gx in cx..d {
                                    t.write_own(ch, gy, gx, effective.get(ch, gy, gx));
                                }
                            }
                        }
                        stats.input_bits +=
                            (ic * (b - a) * (d - cx) * self.fm_bits) as u64;
                    }
                    if state.halo[0] > 0 {
                        for (gy, gx) in ring_coords(y0, y1, x0, x1) {
                            if gy < 0 || gx < 0 || gy as usize >= ih || gx as usize >= iw {
                                continue;
                            }
                            let (uy, ux) = (gy as usize, gx as usize);
                            if plan.input_rects.iter().any(|&(a, b, cx, d)| {
                                uy >= a && uy < b && ux >= cx && ux < d
                            }) {
                                for ch in 0..ic {
                                    t.write_halo(ch, gy, gx, effective.get(ch, uy, ux));
                                    stats.input_bits += self.fm_bits as u64;
                                }
                            }
                        }
                    }
                }
            }
        }

        for (si, step) in net.steps.iter().enumerate() {
            let l = &step.layer;
            let p = &params[si];
            let sp = &plan.steps[si];
            let (ho, wo) = (l.h_out(), l.w_out());
            let src_id = tid(step.src);
            let byp_id = step.bypass.map(tid);
            let cat_id = step.concat_extra.map(tid);
            let (src_c, _, _) = net.shape_of(step.src);
            let mut sent = vec![false; nchips];
            let pw = if sp.conv_rects.is_empty() {
                None
            } else {
                Some(PackedLayerWeights::new(&p.stream))
            };

            for r in 0..self.rows {
                for c in 0..self.cols {
                    let idx = r * self.cols + c;
                    if self.chip_dies(si, idx) {
                        return Err(MeshError::ChipDead { chip: (r, c), step: si });
                    }
                    let (oy0, oy1) = self.bounds(ho, self.rows, r);
                    let (ox0, ox1) = self.bounds(wo, self.cols, c);
                    let subs: Vec<Rect> = sp
                        .conv_rects
                        .iter()
                        .filter_map(|&rc| isect(rc, oy0, oy1, ox0, ox1))
                        .collect();
                    let dirty_pixels: u64 =
                        subs.iter().map(|&(a, b, cx, d)| ((b - a) * (d - cx)) as u64).sum();
                    if dirty_pixels == 0 && !step.upsample2x {
                        continue;
                    }
                    // Pull the tile we mutate out of its store so the
                    // input tiles can be borrowed immutably alongside.
                    let mut conv_tile = if step.upsample2x {
                        state.conv[idx].remove(&(1 + si)).ok_or(MeshError::MissingTile {
                            chip: (r, c),
                            tensor: 1 + si,
                            role: "video conv cache",
                        })?
                    } else {
                        state.tiles[idx].remove(&(1 + si)).ok_or(MeshError::MissingTile {
                            chip: (r, c),
                            tensor: 1 + si,
                            role: "video tile",
                        })?
                    };
                    if dirty_pixels > 0 {
                        let chip = &state.tiles[idx];
                        let src = chip.get(&src_id).ok_or(MeshError::MissingTile {
                            chip: (r, c),
                            tensor: src_id,
                            role: "src",
                        })?;
                        let cat = match cat_id {
                            Some(t) => Some(chip.get(&t).ok_or(MeshError::MissingTile {
                                chip: (r, c),
                                tensor: t,
                                role: "concat",
                            })?),
                            None => None,
                        };
                        let byp = match byp_id {
                            Some(t) => Some(chip.get(&t).ok_or(MeshError::MissingTile {
                                chip: (r, c),
                                tensor: t,
                                role: "bypass",
                            })?),
                            None => None,
                        };
                        let input = ChipInput { src, cat, src_c };
                        let out_h = oy1 - oy0;
                        let out_w = ox1 - ox0;
                        for &(a, b, cx, d) in &subs {
                            // Tile-PU grid stays anchored at the chip's
                            // owned region — only the output window
                            // shrinks to the dirty sub-rect.
                            let geom = TileGeom {
                                oy0: a,
                                oy1: b,
                                ox0: cx,
                                ox1: d,
                                iy0: (oy0 * l.stride) as isize,
                                ix0: (ox0 * l.stride) as isize,
                                tile_h: out_h.div_ceil(m).max(1),
                                tile_w: out_w.div_ceil(n_pu).max(1),
                                in_tile_h: (out_h * l.stride).div_ceil(m).max(1),
                                in_tile_w: (out_w * l.stride).div_ceil(n_pu).max(1),
                            };
                            let mut write = |co: usize, gy: usize, gx: usize, v: f32| {
                                conv_tile.write_own(co, gy, gx, v)
                            };
                            stats.access.add(&datapath::run_tile(
                                l,
                                pw.as_ref().expect("packed weights exist when rects do"),
                                &p.gamma,
                                &p.beta,
                                (0, l.n_out),
                                &input,
                                byp,
                                self.prec,
                                &geom,
                                &mut write,
                            ));
                        }
                        // Any dirty pixel restarts the weight stream for
                        // this chip; PUs share it over their dirty load.
                        let per_pu = dirty_pixels.div_ceil((m * n_pu) as u64);
                        let (sw, _) = datapath::weight_traffic(l, p.stream.c, per_pu);
                        stats.access.stream_words += sw;
                        stats.access.wbuf_reads += sw * (per_pu.max(1) - 1);
                        sent[idx] = true;
                    }
                    if step.upsample2x {
                        let mut up = state.tiles[idx].remove(&(1 + si)).ok_or(
                            MeshError::MissingTile {
                                chip: (r, c),
                                tensor: 1 + si,
                                role: "video upsampled tile",
                            },
                        )?;
                        // Regenerate dirty upsampled pixels from the
                        // (just-refreshed) conv tile; the cached tile's
                        // halo ring survives untouched.
                        for &rect in &sp.out_rects {
                            let Some((a, b, cx, d)) =
                                isect(rect, 2 * oy0, 2 * oy1, 2 * ox0, 2 * ox1)
                            else {
                                continue;
                            };
                            sent[idx] = true;
                            for ch in 0..l.n_out {
                                for gy in a..b {
                                    for gx in cx..d {
                                        up.write_own(
                                            ch,
                                            gy,
                                            gx,
                                            conv_tile.read(ch, (gy / 2) as isize, (gx / 2) as isize),
                                        );
                                    }
                                }
                            }
                        }
                        state.tiles[idx].insert(1 + si, up);
                        state.conv[idx].insert(1 + si, conv_tile);
                    } else {
                        state.tiles[idx].insert(1 + si, conv_tile);
                    }
                }
            }

            let (oc, _, _) = net.shape_of(TensorRef::Step(si));
            if state.halo[1 + si] > 0 && sent.iter().any(|&s| s) {
                self.exchange_from(1 + si, oc, &mut state.tiles, &mut stats, Some(&sent))?;
            }
        }

        let (fc, fh, fw) = net.out_shape();
        let final_fm = self.assemble(&state.tiles, net.steps.len(), fc, fh, fw)?;
        assert!(stats.flags.is_quiescent(), "unmatched border sends");
        stats.access = stats.access.with_saved_vs(&state.full_access);
        Ok((final_fm, stats))
    }
}

/// `tiles[idx].get_mut(tensor)` with the typed-error plumbing factored
/// out (borrow-checker-friendly free function).
fn tiles_get_mut<'a>(
    tiles: &'a mut [HashMap<usize, ExtTile>],
    idx: usize,
    tensor: usize,
    chip: (usize, usize),
    role: &'static str,
) -> Result<&'a mut ExtTile, MeshError> {
    tiles[idx].get_mut(&tensor).ok_or(MeshError::MissingTile {
        chip,
        tensor,
        role,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bwn::pack_weights;
    use crate::model;
    use crate::network::{Network, TensorRef};
    use crate::simulator::chip::{run_layer, LayerParams};
    use crate::util::SplitMix64;

    fn random_params(net: &Network, seed: u64) -> Vec<StepParams> {
        let mut rng = SplitMix64::new(seed);
        net.steps
            .iter()
            .map(|s| {
                let l = &s.layer;
                let nie = l.n_in / l.groups;
                let w: Vec<f32> = (0..l.n_out * nie * l.k * l.k)
                    .map(|_| rng.next_sym())
                    .collect();
                // BWN-style scale α/fan-in keeps FP16 activations in
                // range over deep stacks (overflow → inf − inf = NaN).
                let fan_in = (nie * l.k * l.k) as f32;
                StepParams {
                    stream: pack_weights(l, &w, 16),
                    gamma: (0..l.n_out)
                        .map(|_| (0.25 + 0.5 * rng.next_f32()) / fan_in)
                        .collect(),
                    beta: (0..l.n_out).map(|_| 0.1 * rng.next_sym()).collect(),
                }
            })
            .collect()
    }

    fn single_chip_run(net: &Network, params: &[StepParams], input: &FeatureMap,
                       prec: Precision) -> FeatureMap {
        let mut outs: Vec<FeatureMap> = Vec::new();
        for (i, s) in net.steps.iter().enumerate() {
            let src = match s.src {
                TensorRef::Input => input,
                TensorRef::Step(j) => &outs[j],
            };
            let src = if let Some(cat) = s.concat_extra {
                let extra = match cat {
                    TensorRef::Input => input,
                    TensorRef::Step(j) => &outs[j],
                };
                src.concat_channels(extra)
            } else {
                src.clone()
            };
            let byp = s.bypass.map(|b| match b {
                TensorRef::Input => input.clone(),
                TensorRef::Step(j) => outs[j].clone(),
            });
            let lp = LayerParams {
                layer: &s.layer,
                stream: &params[i].stream,
                gamma: &params[i].gamma,
                beta: &params[i].beta,
            };
            let (o, _) = run_layer(&lp, &src, byp.as_ref(), prec, (7, 7));
            outs.push(if s.upsample2x { o.upsample2x_nearest() } else { o });
        }
        outs.pop().unwrap()
    }

    fn hypernet_input(seed: u64) -> FeatureMap {
        let mut rng = SplitMix64::new(seed);
        FeatureMap::from_vec(16, 32, 32, (0..16 * 32 * 32).map(|_| rng.next_sym()).collect())
    }

    #[test]
    fn mesh_2x2_matches_single_chip_bit_exactly_f16() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0xabcd);
        let input = hypernet_input(7);
        let single = single_chip_run(&net, &params, &input, Precision::F16);
        let mesh = MeshSim::new(2, 2, Precision::F16);
        let (out, stats) = mesh.run_network(&net, &params, &input).unwrap();
        assert_eq!(out.max_abs_diff(&single), 0.0, "must be bit-exact");
        assert!(stats.border_bits > 0);
        assert!(stats.corner_bits > 0);
    }

    #[test]
    fn mesh_4x4_matches_single_chip() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0x1234);
        let input = hypernet_input(11);
        let single = single_chip_run(&net, &params, &input, Precision::F32);
        let mesh = MeshSim::new(4, 4, Precision::F32);
        let (out, _) = mesh.run_network(&net, &params, &input).unwrap();
        assert_eq!(out.max_abs_diff(&single), 0.0);
    }

    #[test]
    fn asymmetric_mesh_matches() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0x777);
        let input = hypernet_input(3);
        let single = single_chip_run(&net, &params, &input, Precision::F16);
        let mesh = MeshSim::new(2, 4, Precision::F16);
        let (out, _) = mesh.run_network(&net, &params, &input).unwrap();
        assert_eq!(out.max_abs_diff(&single), 0.0);
    }

    /// A small FPN-style network: strided conv whose output is 2×
    /// nearest-upsampled, a 3×3 consumer (halo re-exchange on the
    /// upsampled tensor), and a concat merge with the network input.
    fn upsample_net() -> Network {
        let mut net = Network::new("ups", 8, 8, 8);
        let a = net.push(
            ConvLayer::new("a", 8, 8, 8, 8, 3, 2),
            TensorRef::Input,
            None,
        );
        net.upsample_last(); // 4×4 → back to 8×8
        let b = net.push(
            ConvLayer::new("b", 8, 8, 8, 8, 3, 1),
            TensorRef::Step(a),
            None,
        );
        net.push_concat(
            ConvLayer::new("c", 16, 8, 8, 8, 1, 1),
            TensorRef::Step(b),
            Some(TensorRef::Input),
        );
        net.validate().unwrap();
        net
    }

    #[test]
    fn upsampled_tensor_matches_single_chip_bit_exactly() {
        let net = upsample_net();
        let params = random_params(&net, 0x0951);
        let mut rng = SplitMix64::new(21);
        let input =
            FeatureMap::from_vec(8, 8, 8, (0..8 * 64).map(|_| rng.next_sym()).collect());
        for prec in [Precision::F16, Precision::F32] {
            let single = single_chip_run(&net, &params, &input, prec);
            let mesh = MeshSim::new(2, 2, prec);
            let (out, stats) = mesh.run_network(&net, &params, &input).unwrap();
            assert_eq!(out.max_abs_diff(&single), 0.0, "{prec:?} diverged");
            // The upsampled tensor's halo was re-exchanged for `b`.
            assert!(stats.border_bits > 0);
        }
    }

    #[test]
    fn access_counts_aggregate_over_chips() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0x11);
        let input = hypernet_input(9);
        let mesh = MeshSim::new(2, 2, Precision::F32);
        let (_, stats) = mesh.run_network(&net, &params, &input).unwrap();
        // Every output pixel of every step is written exactly once
        // across all chips (upsample replication is free, not counted).
        let out_words: u64 = net.steps.iter().map(|s| s.layer.out_words()).sum();
        assert_eq!(stats.access.fmm_writes, out_words);
        // Weights are broadcast: each of the 4 chips streams the full
        // per-layer word count.
        let single: u64 = net
            .steps
            .iter()
            .map(|s| crate::simulator::datapath::weight_traffic(&s.layer, 16, 1).0)
            .sum();
        assert_eq!(stats.access.stream_words, 4 * single);
        assert!(stats.access.accumulates > 0 && stats.access.neighbor_reads > 0);
    }

    #[test]
    fn threaded_mesh_is_bit_identical_with_equal_stats() {
        let net = upsample_net();
        let params = random_params(&net, 0x7777);
        let mut rng = SplitMix64::new(5);
        let input =
            FeatureMap::from_vec(8, 8, 8, (0..8 * 64).map(|_| rng.next_sym()).collect());
        let base = MeshSim::new(2, 2, Precision::F16);
        let (want, want_stats) = base.run_network(&net, &params, &input).unwrap();
        for threads in [0usize, 2, 3, 16] {
            let mut sim = MeshSim::new(2, 2, Precision::F16);
            sim.threads = threads;
            let (got, stats) = sim.run_network(&net, &params, &input).unwrap();
            assert_eq!(got.data, want.data, "threads={threads}");
            assert_eq!(stats, want_stats, "threads={threads}");
        }
    }

    #[test]
    fn batched_mesh_matches_sequential_runs_with_amortized_stream() {
        // B resident images through the mesh: per-image bit-exactness
        // vs sequential runs, weight stream counted once per batch,
        // per-image exchange/input traffic unchanged — at both
        // precisions and with the upsample/concat network in play.
        for net in [model::network("hypernet20").unwrap(), upsample_net()] {
            let params = random_params(&net, 0xbeef);
            let mut rng = SplitMix64::new(17);
            const B: usize = 3;
            let inputs: Vec<FeatureMap> = (0..B)
                .map(|_| {
                    FeatureMap::from_vec(
                        net.in_ch,
                        net.in_h,
                        net.in_w,
                        (0..net.in_ch * net.in_h * net.in_w)
                            .map(|_| rng.next_sym())
                            .collect(),
                    )
                })
                .collect();
            for prec in [Precision::F16, Precision::F32] {
                let mesh = MeshSim::new(2, 2, prec);
                let mut seq_stats = MeshStats::default();
                let seq: Vec<FeatureMap> = inputs
                    .iter()
                    .map(|input| {
                        let (out, st) = mesh.run_network(&net, &params, input).unwrap();
                        seq_stats.access.add(&st.access);
                        seq_stats.border_bits += st.border_bits;
                        out
                    })
                    .collect();
                let in_refs: Vec<&FeatureMap> = inputs.iter().collect();
                for threads in [1usize, 3] {
                    let mut sim = MeshSim::new(2, 2, prec);
                    sim.threads = threads;
                    let (outs, stats) = sim.run_network_batch(&net, &params, &in_refs).unwrap();
                    assert_eq!(outs.len(), B);
                    for bi in 0..B {
                        assert_eq!(
                            outs[bi].max_abs_diff(&seq[bi]),
                            0.0,
                            "image {bi} diverged ({prec:?}, threads={threads})"
                        );
                    }
                    // Stream words once per batch; everything per-image
                    // (compute, exchange) unchanged.
                    assert_eq!(stats.access.stream_words * B as u64, seq_stats.access.stream_words);
                    assert_eq!(stats.access.fmm_writes, seq_stats.access.fmm_writes);
                    assert_eq!(stats.access.accumulates, seq_stats.access.accumulates);
                    assert_eq!(stats.border_bits, seq_stats.border_bits);
                }
            }
        }
    }

    #[test]
    fn params_mismatch_is_a_typed_error() {
        let net = model::network("hypernet20").unwrap();
        let mut params = random_params(&net, 1);
        params.pop();
        let input = hypernet_input(1);
        let mesh = MeshSim::new(2, 2, Precision::F32);
        let err = mesh.run_network(&net, &params, &input).unwrap_err();
        assert_eq!(
            err,
            MeshError::ParamsMismatch {
                params: 19,
                steps: 20
            }
        );
        assert!(err.to_string().contains("19"), "{err}");
    }

    #[test]
    fn border_traffic_matches_coordinator_accounting() {
        // The functional exchange and the analytic Fig-11 accounting must
        // agree exactly (same rule: halo-consuming tensors only).
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 0x99);
        let input = hypernet_input(5);
        let mesh = MeshSim::new(2, 2, Precision::F32);
        let (_, stats) = mesh.run_network(&net, &params, &input).unwrap();
        let plan = crate::coordinator::tiling::MeshPlan {
            rows: 2,
            cols: 2,
            per_chip_wcl_words: 0,
        };
        let analytic = crate::coordinator::tiling::border_exchange_bits(&net, &plan, 16);
        assert_eq!(stats.border_bits + stats.corner_bits, analytic);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn indivisible_mesh_rejected() {
        let net = model::network("hypernet20").unwrap();
        let params = random_params(&net, 1);
        let input = hypernet_input(1);
        let mesh = MeshSim::new(3, 3, Precision::F32); // 32 % 3 != 0
        let _ = mesh.run_network(&net, &params, &input);
    }
}

//! Functional + cycle-accurate simulation of the Hyperdrive silicon.
//!
//! * [`fm`] — feature-map tensors with optional bit-exact FP16 rounding
//!   (the chip's datapath precision).
//! * [`datapath`] — **the one Tile-PU datapath kernel** (Algorithm 1:
//!   sign-mask accumulate + scale→bypass→bias→ReLU) behind the
//!   [`datapath::InputSurface`] abstraction, counting every
//!   FMM/WBuf/stream access for the energy breakdown (Fig 10). Both
//!   simulators execute this kernel; only their memory front-ends
//!   differ — the paper's multi-chip scalability claim, in code.
//! * [`chip`] — one chip: drives the kernel over a flat FM, optionally
//!   fanned out over output channels on scoped threads
//!   ([`chip::run_layer_threads`]), bit-identical at any thread count.
//! * [`mesh`] — the m×n multi-chip systolic array (§V): per-chip FM
//!   tiles, border/corner memories, the send-once exchange protocol,
//!   free 2× nearest upsampling (YOLOv3 FPN), chips computed
//!   concurrently per step — validated bit-exactly against the
//!   single-chip reference.

pub mod banks;
pub mod chip;
pub mod datapath;
pub mod fm;
pub mod mesh;

pub use chip::{run_layer, run_layer_rects, run_layer_threads, AccessCounts, Precision};
pub use fm::FeatureMap;
pub use mesh::{MeshError, MeshSim, MeshVideoState, VideoFramePlan, VideoStepPlan};

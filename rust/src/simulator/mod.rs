//! Functional + cycle-accurate simulation of the Hyperdrive silicon.
//!
//! * [`fm`] — feature-map tensors with optional bit-exact FP16 rounding
//!   (the chip's datapath precision).
//! * [`chip`] — one chip: executes a layer exactly as Algorithm 1 does
//!   (tap-outer / c_in-inner accumulation order, fused
//!   scale→bypass→bias→ReLU) while counting every FMM/WBuf/stream access
//!   for the energy breakdown (Fig 10).
//! * [`mesh`] — the m×n multi-chip systolic array (§V): per-chip FM
//!   tiles, border/corner memories, the send-once exchange protocol —
//!   validated bit-exactly against the single-chip reference.

pub mod banks;
pub mod chip;
pub mod fm;
pub mod mesh;

pub use chip::{run_layer, AccessCounts, Precision};
pub use fm::FeatureMap;
pub use mesh::MeshSim;

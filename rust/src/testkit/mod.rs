//! Minimal property-based testing harness (the vendored crate set has no
//! `proptest`).
//!
//! [`check`] runs a property over `n` pseudo-random cases derived from a
//! base seed; on failure it panics with the failing *case seed* so the
//! exact case can be replayed in isolation with [`replay`].
//!
//! Also home of [`reference_run_tile`] — the pre-optimization per-element
//! datapath kernel kept as the oracle the fast
//! [`crate::simulator::datapath::run_tile`] is property-tested against
//! (and benchmarked against in `benches/hotpath.rs`).

pub mod reference;

pub use reference::reference_run_tile;

use crate::util::SplitMix64;

/// Number of cases properties run by default.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` deterministic pseudo-random cases.
///
/// `prop` receives a fresh [`SplitMix64`] per case and returns
/// `Err(message)` to fail. Panics with the case seed on first failure.
pub fn check_n<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    let mut seeder = SplitMix64::new(base_seed);
    for case in 0..cases {
        let case_seed = seeder.next_u64();
        let mut rng = SplitMix64::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (replay seed {case_seed:#018x}): {msg}"
            );
        }
    }
}

/// [`check_n`] with [`DEFAULT_CASES`] cases.
pub fn check<F>(name: &str, base_seed: u64, prop: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    check_n(name, base_seed, DEFAULT_CASES, prop);
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, mut prop: F)
where
    F: FnMut(&mut SplitMix64) -> Result<(), String>,
{
    let mut rng = SplitMix64::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replayed case {case_seed:#018x} failed: {msg}");
    }
}

/// Assert two f32 slices match within absolute + relative tolerance.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0usize;
        check_n("trivial", 1, 50, |rng| {
            ran += 1;
            let x = rng.next_f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check_n("always-fails", 2, 10, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_tolerances() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-5, 0.0).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
        assert!(assert_allclose(&[f32::NAN], &[1.0], 10.0, 10.0).is_err());
    }
}

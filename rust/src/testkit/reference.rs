//! The original (pre-optimization) Tile-PU datapath kernel, preserved
//! verbatim as the oracle for the fast path.
//!
//! [`reference_run_tile`] is the per-element kernel the simulator ran
//! before the staged/interior-split rewrite of
//! [`crate::simulator::datapath::run_tile`] (DESIGN.md §Perf log): it
//! reads every tap through a scalar [`InputSurface::read`], performs the
//! padding and Tile-PU patch bookkeeping per element, and increments
//! every [`AccessCounts`] field as the accesses happen. It is
//! deliberately *not* shared with the production kernel — the whole
//! point is that the two implementations are independent, so
//! `tests/datapath_equivalence.rs` can assert the fast path is
//! bit-identical (outputs *and* counters) at both precisions, and
//! `benches/hotpath.rs` can time the pre-optimization kernel as the
//! live baseline the speedup gate compares against.

use crate::bwn::WeightStream;
use crate::network::ConvLayer;
use crate::simulator::datapath::{rnd, AccessCounts, InputSurface, Precision, TileGeom};
use crate::util::f16::round_f16;

/// Execute Algorithm 1 for output channels `[co0, co1)` over the output
/// rectangle in `geom` — the original per-element implementation.
///
/// Same contract as [`crate::simulator::datapath::run_tile`]: tap-outer,
/// channel-inner accumulation with the binary weight as a sign-bit XOR,
/// then scale → bypass → bias → ReLU, every intermediate optionally
/// rounded to FP16. Counters are incremented per element (padded taps
/// included in `fmm_reads`/`accumulates`, exactly like the silicon's
/// always-issued fetches).
#[allow(clippy::too_many_arguments)]
pub fn reference_run_tile<S, B, W>(
    layer: &ConvLayer,
    stream: &WeightStream,
    gamma: &[f32],
    beta: &[f32],
    (co0, co1): (usize, usize),
    input: &S,
    bypass: Option<&B>,
    prec: Precision,
    geom: &TileGeom,
    write: &mut W,
) -> AccessCounts
where
    S: InputSurface + ?Sized,
    B: InputSurface + ?Sized,
    W: FnMut(usize, usize, usize, f32),
{
    let l = layer;
    let half = (l.k / 2) as isize;
    let group_size_out = l.n_out / l.groups;
    let n_in_eff = l.n_in / l.groups;
    let taps = l.k * l.k;
    let mut acc = AccessCounts::default();
    let mut wmask = vec![0u32; taps * n_in_eff];
    for co in co0..co1 {
        let g = co / group_size_out;
        let cin_base = g * n_in_eff;
        for tap in 0..taps {
            for ci in 0..n_in_eff {
                wmask[tap * n_in_eff + ci] = if stream.weight(co, ci, tap) > 0.0 {
                    0
                } else {
                    0x8000_0000
                };
            }
        }
        for oy in geom.oy0..geom.oy1 {
            let ty = ((oy - geom.oy0) / geom.tile_h) as isize;
            for ox in geom.ox0..geom.ox1 {
                let tx = ((ox - geom.ox0) / geom.tile_w) as isize;
                let mut v = 0.0f32;
                // Algorithm 1 lines 7–19: tap outer, input channel inner.
                for tap in 0..taps {
                    let dy = (tap / l.k) as isize - half;
                    let dx = (tap % l.k) as isize - half;
                    let iy = (oy * l.stride) as isize + dy;
                    let ix = (ox * l.stride) as isize + dx;
                    acc.accumulates += n_in_eff as u64;
                    acc.fmm_reads += n_in_eff as u64;
                    if iy < 0 || ix < 0 || iy >= l.h as isize || ix >= l.w as isize {
                        // Zero padding: the DDU injects zeros; v is
                        // unchanged (v ± 0 == v bit-exactly).
                        continue;
                    }
                    // Tile-PU patch of the read, in the local grid
                    // (negative → a halo pixel from a neighbour chip).
                    let t_in = (
                        (iy - geom.iy0).div_euclid(geom.in_tile_h as isize),
                        (ix - geom.ix0).div_euclid(geom.in_tile_w as isize),
                    );
                    if t_in != (ty, tx) {
                        acc.neighbor_reads += n_in_eff as u64;
                    }
                    let row = &wmask[tap * n_in_eff..(tap + 1) * n_in_eff];
                    // Line 17: sign-select accumulate (sign-bit XOR).
                    match prec {
                        Precision::F32 => {
                            for (ci, &mask) in row.iter().enumerate() {
                                let x = input.read(cin_base + ci, iy, ix);
                                v += f32::from_bits(x.to_bits() ^ mask);
                            }
                        }
                        Precision::F16 => {
                            for (ci, &mask) in row.iter().enumerate() {
                                let x = input.read(cin_base + ci, iy, ix);
                                v = round_f16(v + f32::from_bits(x.to_bits() ^ mask));
                            }
                        }
                    }
                }
                // §IV-B order: scale → bypass → bias → ReLU.
                if l.bnorm {
                    v = rnd(prec, v * gamma[co]);
                    acc.post_mults += 1;
                }
                if let Some(bp) = bypass {
                    v = rnd(prec, v + bp.read(co, oy as isize, ox as isize));
                    acc.fmm_reads += 1;
                    acc.post_adds += 1;
                }
                v = rnd(prec, v + beta[co]);
                acc.post_adds += 1;
                if l.relu && v < 0.0 {
                    v = 0.0;
                }
                write(co, oy, ox, v);
                acc.fmm_writes += 1;
            }
        }
    }
    acc
}
